//! Property tests (randomized, seeded, shrink-free) on scheduler
//! invariants: every task runs exactly once, scopes always join, stats
//! account for all work — across random pool sizes, task counts and
//! task durations.

use std::sync::atomic::{AtomicU32, Ordering};

use canny_par::scheduler::Pool;
use canny_par::util::Prng;

const CASES: usize = 25;

#[test]
fn prop_every_task_runs_exactly_once() {
    let mut rng = Prng::new(0xA11CE);
    for case in 0..CASES {
        let workers = 1 + rng.next_below(8);
        let n_tasks = 1 + rng.next_below(300);
        let pool = Pool::new(workers).unwrap();
        let counters: Vec<AtomicU32> = (0..n_tasks).map(|_| AtomicU32::new(0)).collect();
        pool.scope(|s| {
            for c in &counters {
                let spin = rng.next_below(2000) as u64;
                s.spawn(move || {
                    let mut acc = 0u64;
                    for k in 0..spin {
                        acc = acc.wrapping_add(k);
                    }
                    std::hint::black_box(acc);
                    c.fetch_add(1, Ordering::Relaxed);
                });
            }
        });
        for (i, c) in counters.iter().enumerate() {
            assert_eq!(
                c.load(Ordering::Relaxed),
                1,
                "case {case} (workers={workers}, tasks={n_tasks}): task {i}"
            );
        }
    }
}

#[test]
fn prop_task_counts_conserved() {
    let mut rng = Prng::new(0xB0B);
    for _ in 0..CASES {
        let workers = 1 + rng.next_below(6);
        let n_tasks = rng.next_below(200);
        let pool = Pool::new(workers).unwrap();
        pool.scope(|s| {
            for _ in 0..n_tasks {
                s.spawn(|| {
                    std::hint::black_box(1 + 1);
                });
            }
        });
        assert_eq!(pool.stats().total_tasks() as usize, n_tasks);
    }
}

#[test]
fn prop_sequential_scopes_isolated() {
    // Tasks from one scope never leak into the next join.
    let mut rng = Prng::new(0xC0C0);
    for _ in 0..CASES {
        let workers = 1 + rng.next_below(4);
        let pool = Pool::new(workers).unwrap();
        let mut total = 0usize;
        for _round in 0..3 {
            let n = rng.next_below(50);
            let counter = AtomicU32::new(0);
            pool.scope(|s| {
                for _ in 0..n {
                    let counter = &counter;
                    s.spawn(move || {
                        counter.fetch_add(1, Ordering::Relaxed);
                    });
                }
            });
            assert_eq!(counter.load(Ordering::Relaxed) as usize, n);
            total += n;
        }
        assert_eq!(pool.stats().total_tasks() as usize, total);
    }
}

#[test]
fn prop_nested_depth_random() {
    // Random nesting depth (1-3) with random fanouts never deadlocks
    // and runs every leaf exactly once.
    let mut rng = Prng::new(0xD00D);
    for _ in 0..12 {
        let workers = 1 + rng.next_below(4);
        let pool = Pool::new(workers).unwrap();
        let depth = 1 + rng.next_below(3);
        let fan = 1 + rng.next_below(4);
        let leaves = AtomicU32::new(0);
        fn recurse(pool: &Pool, depth: usize, fan: usize, leaves: &AtomicU32) {
            if depth == 0 {
                leaves.fetch_add(1, Ordering::Relaxed);
                return;
            }
            pool.scope(|s| {
                for _ in 0..fan {
                    s.spawn(move || recurse(pool, depth - 1, fan, leaves));
                }
            });
        }
        recurse(&pool, depth, fan, &leaves);
        assert_eq!(leaves.load(Ordering::Relaxed) as usize, fan.pow(depth as u32));
    }
}
