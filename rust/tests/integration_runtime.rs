//! Integration: the PJRT runtime against the real artifacts produced by
//! `make artifacts`. Skipped (with a loud message) if artifacts are
//! missing, so `cargo test` works pre-`make artifacts` too.

use std::path::{Path, PathBuf};

use canny_par::canny::{CannyParams, CannyPipeline};
use canny_par::coordinator::Detector;
use canny_par::image::synth::{generate, Scene};
use canny_par::runtime::{Manifest, XlaEngine};
use canny_par::scheduler::Pool;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Manifest::default_dir();
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: no artifacts at {} (run `make artifacts`)", dir.display());
        None
    }
}

#[test]
fn manifest_loads_and_lists_tiles() {
    let Some(dir) = artifacts_dir() else { return };
    let m = Manifest::load(&dir).unwrap();
    assert_eq!(m.halo, 4);
    let names: Vec<&str> = m.tiles.iter().map(|t| t.name.as_str()).collect();
    assert!(names.contains(&"t64"));
    assert!(names.contains(&"t128"));
    assert!(m.tile("t128").unwrap().entries.contains_key("canny_front"));
}

#[test]
fn engine_executes_fused_front() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = XlaEngine::load(&dir, "t64", 1).unwrap();
    assert_eq!(engine.tile_core(), (64, 64));
    let window = generate(Scene::Shapes { seed: 4 }, 72, 72);
    let (cls, nm) = engine.run_front(&window, 0.05, 0.15, 0).unwrap();
    assert_eq!((cls.width(), cls.height()), (64, 64));
    assert_eq!((nm.width(), nm.height()), (64, 64));
    assert!(cls.data().iter().all(|&v| v == 0.0 || v == 1.0 || v == 2.0));
}

#[test]
fn xla_front_matches_native_within_tolerance() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = XlaEngine::load(&dir, "t64", 1).unwrap();
    let window = generate(Scene::RemoteSensing { seed: 8, noise: 0.05 }, 72, 72);
    let (xcls, xnm) = engine.run_front(&window, 0.05, 0.15, 0).unwrap();
    let (ncls, nnm) = canny_par::canny::pipeline::front_serial_window(&window, 0.05, 0.15);
    // Magnitudes agree to f32 tolerance.
    let mut max_err = 0.0f32;
    for (a, b) in xnm.data().iter().zip(nnm.data()) {
        max_err = max_err.max((a - b).abs());
    }
    assert!(max_err < 1e-4, "nms magnitude max err {max_err}");
    // Class maps agree except at float-tie boundaries (< 0.1%).
    let diff = xcls.data().iter().zip(ncls.data()).filter(|(a, b)| a != b).count();
    assert!(
        (diff as f64) < 0.001 * ncls.len() as f64,
        "class maps differ at {diff}/{} pixels",
        ncls.len()
    );
}

#[test]
fn xla_pipeline_end_to_end_close_to_serial() {
    let Some(dir) = artifacts_dir() else { return };
    std::env::set_var("CANNY_ARTIFACTS", &dir);
    let det = Detector::builder()
        .engine(canny_par::canny::Engine::PatternsXla)
        .workers(2)
        .artifacts_dir(dir.to_str().unwrap())
        .tile_name("t64")
        .build()
        .unwrap();
    let img = generate(Scene::Shapes { seed: 7 }, 200, 150);
    let params = CannyParams::default();
    let xla_out = det.detect_full(&img, &params).unwrap();
    let serial = CannyPipeline::serial().detect(&img, &params).unwrap();
    let diff = xla_out.edges.diff_count(&serial.edges);
    assert!(
        (diff as f64) < 0.002 * img.len() as f64,
        "xla vs serial: {diff}/{} pixels differ",
        img.len()
    );
    // Per-tile costs recorded for the simulator.
    assert!(!xla_out.times.tile_costs_ns.is_empty());
}

#[test]
fn stage_artifacts_execute_and_chain() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = XlaEngine::load(&dir, "t128", 1).unwrap();
    let names = engine.entry_names();
    for required in ["gaussian_stage", "sobel_stage", "nms_stage", "threshold_stage"] {
        assert!(names.contains(&required), "missing {required}");
    }
    // Chain the stages: 136 -> 132 -> 130 -> 128, matching the fused
    // front. (A smooth scene: checkerboards are NMS-tie-degenerate and
    // amplify f32 fusion-order differences into many class flips.)
    let window = generate(Scene::RemoteSensing { seed: 12, noise: 0.04 }, 136, 136);
    let x = xla::Literal::vec1(window.data()).reshape(&[136, 136]).unwrap();
    let g = engine.run_entry("gaussian_stage", &[x], 0).unwrap();
    let sob = engine.run_entry("sobel_stage", &[g[0].clone()], 0).unwrap();
    let nm = engine
        .run_entry("nms_stage", &[sob[0].clone(), sob[1].clone()], 0)
        .unwrap();
    let lo = xla::Literal::vec1(&[0.05f32]);
    let hi = xla::Literal::vec1(&[0.15f32]);
    let cls = engine.run_entry("threshold_stage", &[nm[0].clone(), lo, hi], 0).unwrap();
    let staged = canny_par::runtime::engine::literal_to_image(&cls[0], 128, 128).unwrap();
    // Fused front on the same window must agree (modulo f32 fusion-order
    // ties, < 0.5% of pixels).
    let (fused, _) = engine.run_front(&window, 0.05, 0.15, 0).unwrap();
    let diff = staged.data().iter().zip(fused.data()).filter(|(a, b)| a != b).count();
    assert!(
        (diff as f64) < 0.005 * staged.len() as f64,
        "staged vs fused: {diff}/{} pixels differ",
        staged.len()
    );
}

#[test]
fn concurrent_tile_execution_is_safe() {
    // Race detector: concurrent execution across replicas must produce
    // bitwise the same results as serial execution of the same windows
    // (XLA vs XLA — no float-tie tolerance needed).
    let Some(dir) = artifacts_dir() else { return };
    let engine = XlaEngine::load(&dir, "t64", 4).unwrap();
    let pool = Pool::new(4).unwrap();
    let windows: Vec<_> =
        (0..16).map(|k| generate(Scene::Shapes { seed: k }, 72, 72)).collect();
    let serial: Vec<_> = windows
        .iter()
        .map(|w| engine.run_front(w, 0.05, 0.15, 0).unwrap())
        .collect();
    for round in 0..3 {
        let results = canny_par::patterns::par_map(&pool, &windows, 1, |i, w| {
            engine.run_front(w, 0.05, 0.15, i + round).map(|(c, n)| (c, n))
        });
        for (i, r) in results.iter().enumerate() {
            let (cls, nm) = r.as_ref().unwrap_or_else(|e| panic!("tile {i}: {e}"));
            assert_eq!(cls, &serial[i].0, "round {round} tile {i}: class map raced");
            assert_eq!(nm, &serial[i].1, "round {round} tile {i}: magnitude raced");
        }
    }
}

#[test]
fn engine_rejects_wrong_window_size() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = XlaEngine::load(&dir, "t64", 1).unwrap();
    let wrong = generate(Scene::Gradient, 70, 72);
    assert!(engine.run_front(&wrong, 0.05, 0.15, 0).is_err());
}

#[test]
fn manifest_missing_dir_fails_loudly() {
    let err = Manifest::load(Path::new("/nonexistent/artifacts")).unwrap_err().to_string();
    assert!(err.contains("make artifacts"), "{err}");
}
