//! Stream-tier integration tests: delta-gating correctness (the
//! bit-identity properties from the issue), real-time budget handling,
//! and the documented report schema.

use canny_par::canny::{CannyParams, Engine};
use canny_par::coordinator::Detector;
use canny_par::image::synth::{generate, Scene};
use canny_par::image::ImageF32;
use canny_par::obs::REQUIRED_LINE_KEYS;
use canny_par::stream::{
    run_stream, DeltaMode, DropPolicy, FrameSource, StreamOptions, StreamOutcome,
};
use canny_par::util::json::Json;

fn detector(engine: Engine, workers: usize) -> Detector {
    Detector::builder().engine(engine).workers(workers).build().unwrap()
}

fn run(det: &Detector, src: &FrameSource, delta: DeltaMode) -> StreamOutcome {
    let opts = StreamOptions { delta, keep_edges: true, ..StreamOptions::default() };
    run_stream("test", src, det, &opts).unwrap()
}

/// Property: with the gate forced all-dirty (`off`) the stream is
/// bit-identical to per-frame full detection — and with the exact gate
/// (threshold 0) it *stays* bit-identical even though most tiles are
/// reused, across the serial / patterns / tiled engines.
#[test]
fn gated_stream_bit_identical_to_full_detection() {
    let (w, h, n) = (96usize, 72usize, 4usize);
    let src = FrameSource::synthetic(5, n, w, h);
    let params = CannyParams::default();
    for (engine, workers) in
        [(Engine::Serial, 1), (Engine::Patterns, 3), (Engine::TiledPatterns, 2)]
    {
        let det = detector(engine, workers);
        let all_dirty = run(&det, &src, DeltaMode::Off);
        let gated = run(&det, &src, DeltaMode::Gate(0.0));
        assert_eq!(all_dirty.frames.len(), n);
        assert_eq!(gated.frames.len(), n);
        for k in 0..n {
            let frame = generate(Scene::Video { seed: 5, frame: k }, w, h);
            let want = det.detect(&frame, &params).unwrap();
            let got_off = all_dirty.frames[k].edges.as_ref().unwrap();
            assert_eq!(
                want.diff_count(got_off),
                0,
                "{engine:?} frame {k}: all-dirty stream diverged from full detection"
            );
            let got_gated = gated.frames[k].edges.as_ref().unwrap();
            assert_eq!(
                want.diff_count(got_gated),
                0,
                "{engine:?} frame {k}: exact-gated stream diverged from full detection"
            );
        }
        // The off run never gates; the exact run gates every frame but
        // the first.
        assert_eq!(all_dirty.report.gate.frames_gated, 0);
        assert_eq!(all_dirty.report.gate.frames_full, n as u64);
        assert_eq!(gated.report.gate.frames_gated, (n - 1) as u64);
        assert_eq!(gated.report.gate.frames_full, 1);
    }
}

/// Property: a fully static scene converges to 100% gate hits with
/// byte-identical edge maps across frames.
#[test]
fn static_scene_converges_to_full_gate_hits() {
    let src = FrameSource::parse("shapes:3", 6, 128, 96, 7).unwrap();
    let det = detector(Engine::Patterns, 2);
    let out = run(&det, &src, DeltaMode::default());
    let g = &out.report.gate;
    assert_eq!(g.frames_full, 1, "only the first frame runs a full front");
    assert_eq!(g.frames_gated, 5);
    assert_eq!(g.tiles_dirty, 0, "a static scene must not recompute any tile");
    assert!(g.tiles_clean > 0);
    assert!((g.hit_rate() - 1.0).abs() < 1e-12);
    let first = out.frames[0].edges.as_ref().unwrap();
    assert!(first.count_edges() > 0, "static scene still has real edges");
    for f in &out.frames[1..] {
        assert_eq!(first.diff_count(f.edges.as_ref().unwrap()), 0);
    }
}

/// On a moving `Scene::Video` stream the exact gate still finds real
/// reuse: the background is static, so a nonzero share of tiles is
/// clean (the acceptance criterion for `cannyd stream`).
#[test]
fn video_scene_reports_nonzero_gate_hits() {
    let src = FrameSource::synthetic(7, 3, 480, 480);
    let det = detector(Engine::Patterns, 4);
    let opts = StreamOptions { delta: DeltaMode::default(), ..StreamOptions::default() };
    let out = run_stream("video", &src, &det, &opts).unwrap();
    let g = &out.report.gate;
    assert_eq!(g.frames_gated, 2);
    assert!(
        g.tiles_clean > 0,
        "moving shapes on a static background must leave clean tiles (dirty={})",
        g.tiles_dirty
    );
    assert!(g.hit_rate() > 0.0);
    assert!(g.tiles_dirty > 0, "moving shapes must dirty some tiles");
    assert_eq!(out.report.frames_emitted, 3);
    assert!(out.report.edge_pixels > 0);
}

#[test]
fn drop_policy_skips_late_frames() {
    let src = FrameSource::parse("shapes:9", 5, 32, 24, 7).unwrap();
    let det = detector(Engine::Serial, 1);
    let opts = StreamOptions {
        frame_budget_ns: 100, // deadlines in the past by the time stages run
        drop_policy: DropPolicy::Drop,
        ..StreamOptions::default()
    };
    let out = run_stream("late", &src, &det, &opts).unwrap();
    let r = &out.report;
    assert!(r.dropped >= 1, "a 100ns budget must drop frames");
    assert_eq!(r.frames_emitted + r.dropped, r.frames_offered);
    assert!(r.late >= r.dropped);
    assert_eq!(r.degraded, 0);
    for f in out.frames.iter().filter(|f| f.dropped) {
        assert_eq!(f.edge_pixels, 0);
        assert!(f.edges.is_none());
    }
}

#[test]
fn degrade_policy_emits_from_the_cache() {
    let src = FrameSource::parse("shapes:9", 6, 48, 48, 7).unwrap();
    let det = detector(Engine::Serial, 1);
    let opts = StreamOptions {
        frame_budget_ns: 100,
        drop_policy: DropPolicy::Degrade,
        keep_edges: true,
        ..StreamOptions::default()
    };
    let out = run_stream("degrade", &src, &det, &opts).unwrap();
    let r = &out.report;
    assert_eq!(r.frames_emitted, r.frames_offered, "degrade never drops");
    assert_eq!(r.dropped, 0);
    assert!(r.degraded >= 1, "late frames with a warm cache must degrade");
    // The first frame has no cache, so it computes even when late.
    assert!(!out.frames[0].degraded);
    assert!(out.frames[0].edges.as_ref().unwrap().count_edges() > 0);
    // Degraded frames reuse the cached suppressed map; on a static
    // source their edges match the computed first frame exactly.
    let first = out.frames[0].edges.as_ref().unwrap();
    for f in out.frames.iter().filter(|f| f.degraded) {
        assert_eq!(first.diff_count(f.edges.as_ref().unwrap()), 0);
    }
}

/// Both `--delta-gate off` and the default produce the documented
/// stream-report schema (the `cannyd stream` acceptance shape).
#[test]
fn report_schema_matches_documentation() {
    let src = FrameSource::synthetic(7, 3, 64, 48);
    let det = detector(Engine::Patterns, 2);
    for delta in [DeltaMode::Off, DeltaMode::default()] {
        let out = run(&det, &src, delta);
        let j = out.report.to_json();
        for key in
            ["label", "source", "engine", "workers", "inflight", "wall_ns", "fps",
             "mpix_per_s", "edge_pixels", "frames", "gate", "budget", "stages",
             "jitter_ns", "cache", "overload", "slo"]
        {
            assert!(j.get(key).is_some(), "missing `{key}` ({delta:?})");
        }
        // Offline (budget 0): no deadlines, so the frame SLO has no
        // target and the overload counters are zero.
        assert_eq!(j.get("slo").unwrap().get("status").unwrap().as_str(), Some("no-data"));
        assert_eq!(j.get("overload").unwrap().get("shed_rejected").unwrap().as_usize(), Some(0));
        let frames = j.get("frames").unwrap();
        for key in ["offered", "emitted", "dropped", "degraded", "cached", "late"] {
            assert!(frames.get(key).is_some(), "missing frames.{key}");
        }
        // No cache attached: the section is the disabled snapshot.
        assert_eq!(j.get("cache").unwrap().get("enabled"), Some(&Json::Bool(false)));
        assert_eq!(frames.get("offered").unwrap().as_usize(), Some(3));
        assert_eq!(frames.get("emitted").unwrap().as_usize(), Some(3));
        let gate = j.get("gate").unwrap();
        for key in
            ["mode", "tiles_clean", "tiles_dirty", "frames_gated", "frames_full", "hit_rate"]
        {
            assert!(gate.get(key).is_some(), "missing gate.{key}");
        }
        assert_eq!(
            gate.get("mode").unwrap().as_str(),
            Some(if delta == DeltaMode::Off { "off" } else { "0" })
        );
        let stages = j.get("stages").unwrap();
        for span in ["decode", "front", "threshold", "hysteresis"] {
            let s = stages.get(span).unwrap_or_else(|| panic!("missing stages.{span}"));
            assert_eq!(s.get("frames").unwrap().as_usize(), Some(3));
            for key in ["wall_ns", "cpu_ns", "tasks"] {
                assert!(s.get(key).is_some(), "missing stages.{span}.{key}");
            }
        }
        for key in ["n", "p50", "p95", "p99", "max", "mean"] {
            assert!(j.get("jitter_ns").unwrap().get(key).is_some(), "missing jitter_ns.{key}");
        }
        let budget = j.get("budget").unwrap();
        assert_eq!(budget.get("frame_budget_ns").unwrap().as_usize(), Some(0));
        assert_eq!(budget.get("drop_policy").unwrap().as_str(), Some("drop"));
        // The dump round-trips through the crate's parser.
        assert_eq!(Json::parse(&out.report.to_json_string()).unwrap(), j);
    }
}

/// Ops plane, stream tier: `--telemetry-log` attaches the wall sampler
/// — every JSONL line carries the documented schema with
/// `tier: "stream"`, a per-core `utilization` section, and shed counts
/// (dropped frames) that agree with the final report.
#[test]
fn stream_telemetry_jsonl_counts_dropped_frames_as_sheds() {
    let dir = std::env::temp_dir().join("canny_stream_itests");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join(format!("{}_drop.jsonl", std::process::id()));
    let src = FrameSource::parse("shapes:9", 6, 32, 24, 7).unwrap();
    let det = detector(Engine::Serial, 1);
    let opts = StreamOptions {
        frame_budget_ns: 100, // deadlines in the past by front entry
        drop_policy: DropPolicy::Drop,
        telemetry_log: Some(path.clone()),
        telemetry_interval_ns: 5_000_000,
        ..StreamOptions::default()
    };
    let out = run_stream("shed", &src, &det, &opts).unwrap();
    let r = &out.report;
    assert!(r.dropped >= 1, "a 100ns budget must drop frames");
    let text = std::fs::read_to_string(&path).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert!(lines.len() >= 2, "initial sample plus final line expected");
    for (i, line) in lines.iter().enumerate() {
        let j = Json::parse(line).unwrap_or_else(|e| panic!("line {i} unparseable: {e:?}"));
        for key in REQUIRED_LINE_KEYS {
            assert!(j.get(key).is_some(), "line {i} missing `{key}`");
        }
        assert_eq!(j.get("tier").unwrap().as_str(), Some("stream"));
        assert_eq!(j.get("seq").unwrap().as_usize(), Some(i));
        // Wall sampler lines always carry the per-core busy sample.
        let util = j.get("utilization").unwrap_or_else(|| panic!("line {i} no utilization"));
        assert_eq!(util.get("cores").unwrap().as_usize(), Some(1));
        assert_eq!(j.get("lanes").unwrap().as_arr().unwrap().len(), 3, "decode/front/finish");
    }
    let last = Json::parse(lines.last().unwrap()).unwrap();
    let overload = last.get("overload").unwrap();
    assert_eq!(overload.get("policy").unwrap().as_str(), Some("drop"));
    assert_eq!(overload.get("shed_rejected").unwrap().as_usize(), Some(r.dropped as usize));
    assert_eq!(overload.get("shed_degraded").unwrap().as_usize(), Some(0));
    assert_eq!(
        last.get("queue").unwrap().get("offered").unwrap().as_usize(),
        Some(r.frames_offered as usize)
    );
    let status = last.get("slo").unwrap().get("status").unwrap().as_str().unwrap();
    assert!(["met", "missed", "no-data"].contains(&status), "bad status {status}");
}

/// Ops plane, stream tier: under a hopeless frame budget the degrade
/// policy's sheds land in the report's `overload` section and the
/// rolling frame-SLO window reports `missed` with its transition.
#[test]
fn stream_degrade_sheds_count_and_slo_window_misses() {
    let src = FrameSource::parse("shapes:9", 6, 48, 48, 7).unwrap();
    let det = detector(Engine::Serial, 1);
    let opts = StreamOptions {
        frame_budget_ns: 100,
        drop_policy: DropPolicy::Degrade,
        slo_window: 4,
        ..StreamOptions::default()
    };
    let out = run_stream("degrade-slo", &src, &det, &opts).unwrap();
    let r = &out.report;
    assert_eq!(r.frames_emitted, r.frames_offered, "degrade never drops");
    assert!(r.degraded >= 1, "late frames with a warm cache must degrade");
    // Every emitted frame's latency (vs. its 100ns capture slot) blows
    // the one-budget target, so the rolling window is missed and the
    // timeline records the transition.
    assert_eq!(r.slo.target_p99_ns, 100);
    assert_eq!(r.slo.status.name(), "missed");
    assert!(!r.slo.transitions.is_empty());
    let j = r.to_json();
    let overload = j.get("overload").unwrap();
    assert_eq!(overload.get("policy").unwrap().as_str(), Some("degrade"));
    assert_eq!(overload.get("shed_degraded").unwrap().as_usize(), Some(r.degraded as usize));
    assert_eq!(overload.get("shed_rejected").unwrap().as_usize(), Some(0));
    assert_eq!(j.get("slo").unwrap().get("status").unwrap().as_str(), Some("missed"));
    assert_eq!(j.get("slo").unwrap().get("window").unwrap().as_usize(), Some(4));
}

/// In-memory frame sources drive the executor directly (the embedding
/// API), and a mid-stream size change resets the gate instead of
/// corrupting the cache.
#[test]
fn in_memory_source_and_size_change() {
    let a = generate(Scene::Shapes { seed: 1 }, 64, 48);
    let b = generate(Scene::Shapes { seed: 1 }, 48, 64);
    let frames: Vec<ImageF32> = vec![a.clone(), a.clone(), b.clone(), b];
    let src = FrameSource::Frames(frames);
    let det = detector(Engine::Patterns, 2);
    let out = run(&det, &src, DeltaMode::default());
    let want = det.detect(&a, &CannyParams::default()).unwrap();
    assert_eq!(want.diff_count(out.frames[0].edges.as_ref().unwrap()), 0);
    // Frames 0 and 2 are full (first frame, size change); 1 and 3 gate
    // against an identical predecessor.
    assert_eq!(out.report.gate.frames_full, 2);
    assert_eq!(out.report.gate.frames_gated, 2);
    assert_eq!(out.report.gate.tiles_dirty, 0);
    assert_eq!(
        out.frames[2].edges.as_ref().unwrap().diff_count(out.frames[3].edges.as_ref().unwrap()),
        0
    );
}
