//! Integration: the shared artifact-cache tier ([`canny_par::cache`])
//! — bit-exactness of cache-served partial pipelines across engines,
//! byte-budget eviction, deterministic virtual-time reports with the
//! cache enabled, wall-clock multi-lane hammering, and cross-tier
//! (stream → serve) deduplication.

use std::sync::Arc;

use canny_par::cache::{ArtifactCache, ArtifactKey, CacheConfig, CacheTier};
use canny_par::canny::{Artifact, CannyParams, Engine, StageKind};
use canny_par::config::RunConfig;
use canny_par::coordinator::Detector;
use canny_par::image::synth::{generate, Scene};
use canny_par::service::{serve, ClockMode, Request, RequestKind, ServeOptions, Trace};
use canny_par::stream::{run_stream, FrameSource, StreamOptions};

fn exec_opts() -> ServeOptions {
    let mut o = ServeOptions::from_config(&RunConfig::default());
    o.execute = true;
    o.lanes = 1;
    o.max_batch = 1;
    o.batch_window_ns = 0;
    o.workers_per_lane = 1;
    o
}

fn mk(id: u64, arrival_us: u64, scene: Scene, w: usize, h: usize, kind: RequestKind) -> Request {
    Request { id, arrival_ns: arrival_us * 1_000, scene, width: w, height: h, kind }
}

/// Property: a re-threshold served from the shared cache is
/// bit-identical to a fresh full detection at the same thresholds —
/// for every engine, across scenes, shapes and threshold pairs — and
/// every engine offers byte-identical artifacts (so any engine may
/// consume any other engine's cache entries).
#[test]
fn cached_rethreshold_bit_identical_across_engines() {
    let shapes = [(48usize, 32usize), (64, 64)];
    let thresholds = [(0.02f32, 0.30f32), (0.05, 0.15), (0.10, 0.20)];
    for seed in [1u64, 9, 21] {
        for &(w, h) in &shapes {
            let img = generate(Scene::Shapes { seed }, w, h);
            let key = ArtifactKey::suppressed(&img);
            let mut reference_nm: Option<Vec<f32>> = None;
            for engine in [Engine::Serial, Engine::Patterns, Engine::TiledPatterns] {
                let det =
                    Detector::builder().engine(engine).workers(2).build().unwrap();
                let cache = ArtifactCache::new(CacheConfig::default());
                // Warm the tier the way a front-only request does.
                let front = det.plan().stop_after(StageKind::Nms);
                let mut out = det.run_plan(&front, Some(&img), det.params()).unwrap();
                let nm = out.take_suppressed().unwrap();
                // Engines must agree on the artifact bytes, or
                // cross-engine sharing would be unsound.
                match &reference_nm {
                    Some(want) => assert_eq!(
                        want.as_slice(),
                        nm.data(),
                        "{} front diverged for seed {seed} {w}x{h}",
                        engine.name()
                    ),
                    None => reference_nm = Some(nm.data().to_vec()),
                }
                assert!(cache.offer(key, Artifact::Suppressed(nm), 1_000_000, CacheTier::Serve));
                for &(lo, hi) in &thresholds {
                    let got = match cache.get(&key, CacheTier::Serve) {
                        Some(Artifact::Suppressed(nm)) => nm,
                        other => panic!("expected a suppressed artifact, got {other:?}"),
                    };
                    let params = CannyParams { lo, hi, ..CannyParams::default() };
                    let re = det.plan().from_suppressed(got);
                    let out = det.run_plan(&re, None, &params).unwrap();
                    let fresh = det.detect(&img, &params).unwrap();
                    assert_eq!(
                        out.edges().unwrap(),
                        &fresh,
                        "{} cache-served re-threshold diverged (seed {seed} {w}x{h} \
                         lo={lo} hi={hi})",
                        engine.name()
                    );
                }
            }
        }
    }
}

/// Acceptance: over-filling the budget keeps `bytes <= budget` via LRU
/// eviction, end to end through a serve run.
#[test]
fn serve_overfill_enforces_byte_budget_with_evictions() {
    let (w, h) = (64usize, 64);
    let entry_bytes = (w * h * 4) as u64;
    let mut o = exec_opts();
    // Room for ~3 entries over one shard; 10 distinct scenes offered.
    o.cache = CacheConfig {
        budget_bytes: 3 * entry_bytes + entry_bytes / 2,
        shards: 1,
        admit_min_ns_per_byte: 0.0,
    };
    let trace = Trace {
        requests: (0..10)
            .map(|k| {
                mk(k, k * 100, Scene::Shapes { seed: 100 + k }, w, h, RequestKind::FrontOnly)
            })
            .collect(),
    };
    let report = serve("overfill", &trace, &o).unwrap();
    assert_eq!(report.completed, 10);
    assert!(report.cache.enabled);
    assert_eq!(report.cache.inserts(), 10, "every distinct front is offered");
    assert!(
        report.cache.bytes <= o.cache.budget_bytes,
        "bytes {} over budget {}",
        report.cache.bytes,
        o.cache.budget_bytes
    );
    assert!(report.cache.evictions > 0, "over-filling must evict");
    assert!(report.cache.entries <= 3);
    assert!(report.cache.high_water_bytes <= o.cache.budget_bytes);
}

/// Acceptance: deterministic virtual-time serve reports stay
/// byte-identical across runs with the cache enabled (mixed kinds, so
/// the cache section carries non-trivial counts).
#[test]
fn virtual_replay_with_cache_is_byte_identical() {
    let scene = Scene::Shapes { seed: 77 };
    let trace = Trace {
        requests: vec![
            mk(0, 0, scene, 64, 64, RequestKind::FrontOnly),
            mk(1, 150, scene, 64, 64, RequestKind::ReThreshold { lo: 0.04, hi: 0.2 }),
            mk(2, 300, Scene::Checker { cell: 8 }, 64, 64, RequestKind::Full),
            mk(3, 450, scene, 64, 64, RequestKind::ReThreshold { lo: 0.02, hi: 0.3 }),
            mk(4, 600, Scene::Shapes { seed: 78 }, 48, 48, RequestKind::ReThreshold {
                lo: 0.05,
                hi: 0.15,
            }),
        ],
    };
    let mut o = exec_opts();
    o.workers_per_lane = 2;
    assert!(o.cache.enabled(), "default config must enable the tier");
    let a = serve("det", &trace, &o).unwrap();
    let b = serve("det", &trace, &o).unwrap();
    assert_eq!(a.to_json_string(), b.to_json_string());
    // The cache did real work in that replay: requests 1 and 3 hit the
    // front request 0 offered; request 4 (new content) misses and
    // fills.
    assert_eq!(a.cache.hits(), 2);
    assert_eq!(a.cache.misses(), 1);
    assert_eq!(a.cache.hits() + a.cache.misses(), a.cache.lookups());
}

/// Satellite: wall-clock multi-lane hammer — many lanes sharing one
/// tier under real contention must lose no updates (`hits + misses ==
/// lookups`, inserts accounted) and must produce exactly the edge
/// totals the deterministic virtual replay produces (a hit and a miss
/// are bit-equivalent, so cache races can never change results).
#[test]
fn wall_multi_lane_hammer_keeps_stats_and_results_consistent() {
    let scenes: Vec<Scene> = (0..4).map(|k| Scene::Shapes { seed: 50 + k }).collect();
    let n = 80u64;
    let trace = Trace {
        requests: (0..n)
            .map(|k| {
                let scene = scenes[(k % 4) as usize];
                let kind = if k % 5 == 0 {
                    RequestKind::FrontOnly
                } else {
                    RequestKind::ReThreshold { lo: 0.03 + 0.01 * ((k % 3) as f32), hi: 0.3 }
                };
                // 20 µs gaps: lanes overlap heavily on the wall clock.
                mk(k, k * 20, scene, 32, 32, kind)
            })
            .collect(),
    };
    let mut o = exec_opts();
    o.lanes = 4;
    o.queue_depth = 512; // deep enough that nothing is rejected
    let virt = serve("virt", &trace, &o).unwrap();
    let mut wo = o.clone();
    wo.clock = ClockMode::Wall;
    let wall = serve("wall", &trace, &wo).unwrap();

    for r in [&virt, &wall] {
        assert_eq!(r.offered, n);
        assert_eq!(r.completed, n, "deep queue must admit everything");
        let rethresholds = trace
            .requests
            .iter()
            .filter(|q| matches!(q.kind, RequestKind::ReThreshold { .. }))
            .count() as u64;
        // Every re-threshold consults exactly once; hits + misses must
        // account for every lookup even under cross-lane contention.
        assert_eq!(r.cache.lookups(), rethresholds, "clock {}", r.clock);
        assert_eq!(
            r.cache.hits() + r.cache.misses(),
            r.cache.lookups(),
            "clock {}",
            r.clock
        );
        assert!(r.cache.hits() > 0, "hot scenes must hit (clock {})", r.clock);
        assert_eq!(r.cache.bytes, r.cache.entries * 32 * 32 * 4);
    }
    // No lost updates: cache races may change who fills an entry but
    // never the bytes served, so edge totals agree across clocks.
    assert!(virt.edge_pixels > 0);
    assert_eq!(virt.edge_pixels, wall.edge_pixels);
}

/// Cross-tier dedup: a stream offers its frame fronts into a shared
/// tier; a serve run handed the same `Arc` re-thresholds the same
/// content and hits artifacts it never computed — and a second stream
/// over the same content is served whole from the cache, bit-identical.
#[test]
fn stream_offers_serve_and_streams_consume() {
    let (seed, frames, w, h) = (9u64, 5usize, 64usize, 48);
    let cache = Arc::new(ArtifactCache::new(CacheConfig::default()));
    let det = Detector::builder().workers(2).build().unwrap();
    let src = FrameSource::synthetic(seed, frames, w, h);

    let mut sopts = StreamOptions { cache: Some(Arc::clone(&cache)), ..Default::default() };
    sopts.keep_edges = true;
    let first = run_stream("warm", &src, &det, &sopts).unwrap();
    assert_eq!(first.report.frames_emitted, frames as u64);
    assert_eq!(first.report.cached, 0, "a cold tier serves nothing");
    let after_warm = cache.snapshot();
    assert!(after_warm.inserts() >= 1, "moving frames must be offered");
    assert_eq!(
        after_warm.tiers.iter().find(|(n, _)| *n == "stream").unwrap().1.inserts,
        after_warm.inserts(),
        "all inserts came from the stream tier"
    );

    // A serving run on the same content hits fronts the stream built.
    let trace = Trace {
        requests: (0..3)
            .map(|k| {
                mk(
                    k,
                    k * 100,
                    Scene::Video { seed, frame: k as usize },
                    w,
                    h,
                    RequestKind::ReThreshold { lo: 0.05, hi: 0.15 },
                )
            })
            .collect(),
    };
    let mut o = exec_opts();
    o.shared_cache = Some(Arc::clone(&cache));
    let report = serve("consume", &trace, &o).unwrap();
    assert_eq!(report.completed, 3);
    let serve_tier = report.cache.tiers.iter().find(|(n, _)| *n == "serve").unwrap().1;
    assert_eq!(serve_tier.hits, 3, "serve hit stream-built artifacts: {:?}", report.cache);
    assert_eq!(serve_tier.misses, 0);
    // The front never ran inside the serve run.
    assert_eq!(report.stage_runs.get("gaussian"), None, "stages: {:?}", report.stage_runs);
    assert_eq!(report.stage_runs.get("front"), None);
    assert_eq!(report.stage_runs.get("threshold"), Some(&3));

    // A second identical stream is served whole from the cache,
    // bit-identically.
    let second = run_stream("replay", &src, &det, &sopts).unwrap();
    assert_eq!(second.report.cached, frames as u64, "every frame deduped");
    assert_eq!(second.report.gate.frames_gated + second.report.gate.frames_full, 0);
    for (a, b) in first.frames.iter().zip(&second.frames) {
        assert!(b.cached);
        assert_eq!(a.edge_pixels, b.edge_pixels);
        assert_eq!(a.edges, b.edges, "frame {} diverged through the cache", a.index);
    }
}

/// The stream tier never offers inexact (nonzero-threshold gated)
/// maps: a lossy stream cannot poison exact consumers.
#[test]
fn lossy_gate_does_not_poison_the_shared_tier() {
    let cache = Arc::new(ArtifactCache::new(CacheConfig::default()));
    let det = Detector::builder().workers(1).build().unwrap();
    // Moving video under a generous threshold: frame 0 is ungated
    // (exact, offered); later frames are gated and — with drift
    // tolerated — potentially inexact, so they must never be offered
    // even when tiles recompute.
    let src = FrameSource::synthetic(3, 4, 48, 48);
    let opts = StreamOptions {
        cache: Some(Arc::clone(&cache)),
        delta: canny_par::stream::DeltaMode::Gate(0.5),
        ..Default::default()
    };
    let out = run_stream("lossy", &src, &det, &opts).unwrap();
    assert_eq!(out.report.frames_emitted, 4);
    let snap = cache.snapshot();
    // Frame 0 (ungated full front) is exact and offered; the gated
    // frames (cache misses — the content moves) must not be.
    assert!(out.report.gate.frames_gated > 0, "{:?}", out.report.gate);
    assert_eq!(snap.inserts(), 1, "{snap:?}");
}
