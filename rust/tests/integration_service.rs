//! Integration: the L3 serving tier — admission-queue backpressure,
//! deterministic synthetic-trace replay, batch-window coalescing, the
//! wall-clock driver, and StageTimes-calibrated virtual predictions,
//! end to end through `service::serve`.

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};

use canny_par::cache::CacheConfig;
use canny_par::canny::CannyParams;
use canny_par::config::RunConfig;
use canny_par::coordinator::Detector;
use canny_par::image::synth::{generate, Scene};
use canny_par::obs::{OverloadPolicy, REQUIRED_LINE_KEYS};
use canny_par::service::{
    calibrate_for, serve, ClockMode, Request, RequestKind, ServeOptions, Trace,
};
use canny_par::util::json::Json;

fn tmp_jsonl(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("canny_serve_itests");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(format!("{}_{name}", std::process::id()))
}

/// Default options with real execution off — pure scheduling, fast.
fn sched_opts() -> ServeOptions {
    let mut o = ServeOptions::from_config(&RunConfig::default());
    o.execute = false;
    o
}

fn burst(n: usize, w: usize, h: usize, gap_ns: u64) -> Trace {
    Trace {
        requests: (0..n)
            .map(|k| Request {
                id: k as u64,
                arrival_ns: k as u64 * gap_ns,
                scene: Scene::Checker { cell: 8 },
                width: w,
                height: h,
                kind: RequestKind::Full,
            })
            .collect(),
    }
}

#[test]
fn admission_queue_overflow_rejects_with_backpressure() {
    let mut o = sched_opts();
    o.lanes = 1;
    o.queue_depth = 4;
    o.max_batch = 4;
    o.batch_window_ns = 10_000_000; // 10 ms: nothing dispatches during the burst
    // 30 requests all at t=0: 4 fit in the waiting room, 26 bounce.
    let trace = burst(30, 64, 64, 0);
    let report = serve("overflow", &trace, &o).unwrap();
    assert_eq!(report.offered, 30);
    assert_eq!(report.admitted, 4);
    assert_eq!(report.rejected_full, 26);
    assert_eq!(report.completed, 4);
    assert_eq!(report.offered, report.completed + report.rejected());
    assert_eq!(report.queue_high_water, 4, "high-water == depth under overload");
    // The admitted batch dispatched at max fill, not at the window.
    assert_eq!(report.batches_formed, 1);
    assert!(report.queue_wait.max_ns < o.batch_window_ns);
}

#[test]
fn queue_drains_and_readmits_over_time() {
    let mut o = sched_opts();
    o.lanes = 1;
    o.queue_depth = 2;
    o.max_batch = 1; // every admission dispatches as a singleton
    o.batch_window_ns = 0;
    o.batch_overhead_ns = 100;
    o.cost_ns_per_pixel = 0;
    // Arrivals every 200 ns vs 100 ns service: the lane keeps up, so
    // nothing is ever rejected despite the tiny depth.
    let trace = burst(50, 32, 32, 200);
    let report = serve("drain", &trace, &o).unwrap();
    assert_eq!(report.rejected(), 0);
    assert_eq!(report.completed, 50);
    assert!(report.queue_high_water <= 2);
}

#[test]
fn oversize_requests_rejected_at_admission() {
    let mut o = sched_opts();
    o.lanes = 1;
    o.max_pixels = 64 * 64; // 96x96 requests are over budget
    let mut trace = burst(6, 64, 64, 100_000);
    trace.requests.extend(burst(3, 96, 96, 100_000).requests.into_iter().map(|mut r| {
        r.id += 6;
        r
    }));
    trace.requests.sort_by_key(|r| (r.arrival_ns, r.id));
    let report = serve("oversize", &trace, &o).unwrap();
    assert_eq!(report.rejected_oversize, 3);
    assert_eq!(report.completed, 6);
    assert_eq!(report.offered, report.completed + report.rejected());
}

#[test]
fn synthetic_replay_is_deterministic() {
    let o = sched_opts();
    let trace = Trace::synthetic(300, 42, 20_000.0);
    let a = serve("replay", &trace, &o).unwrap().to_json_string();
    let b = serve("replay", &Trace::synthetic(300, 42, 20_000.0), &o).unwrap().to_json_string();
    assert_eq!(a, b, "same seed must reproduce the report byte-for-byte");
    let c = serve("replay", &Trace::synthetic(300, 43, 20_000.0), &o).unwrap().to_json_string();
    assert_ne!(a, c, "different seed must change the report");
}

#[test]
fn real_compute_replay_is_deterministic_and_counts_edges() {
    let mut o = sched_opts();
    o.execute = true;
    o.workers_per_lane = 2;
    let trace = Trace::synthetic(12, 7, 5_000.0);
    let r1 = serve("exec", &trace, &o).unwrap();
    let r2 = serve("exec", &trace, &o).unwrap();
    assert!(r1.edge_pixels > 0, "real detections must find edges");
    assert_eq!(r1.to_json_string(), r2.to_json_string());
    assert_eq!(r1.completed, 12);
}

#[test]
fn batch_window_coalesces_same_shape_requests() {
    let mut o = sched_opts();
    o.lanes = 1;
    o.max_batch = 4;
    o.batch_window_ns = 1_000_000; // 1 ms
    // 12 same-shape requests at t=0 -> three full batches of 4.
    let report = serve("coalesce", &burst(12, 64, 64, 0), &o).unwrap();
    assert_eq!(report.batches_formed, 3);
    assert!((report.mean_batch_fill() - 4.0).abs() < 1e-9);

    // Zero window + spaced arrivals -> every request is its own batch.
    let mut singles = sched_opts();
    singles.lanes = 1;
    singles.max_batch = 4;
    singles.batch_window_ns = 0;
    let report = serve("singles", &burst(12, 64, 64, 50_000), &singles).unwrap();
    assert_eq!(report.batches_formed, 12);
    assert!((report.mean_batch_fill() - 1.0).abs() < 1e-9);
}

#[test]
fn report_carries_slo_and_per_lane_percentiles() {
    let mut o = sched_opts();
    o.lanes = 2;
    let trace = Trace::synthetic(200, 9, 20_000.0);
    let report = serve("slo", &trace, &o).unwrap();
    assert_eq!(report.lanes.len(), 2);
    for lane in &report.lanes {
        let l = lane.latency;
        assert!(l.p50_ns <= l.p95_ns && l.p95_ns <= l.p99_ns, "lane {} disordered", lane.lane);
    }
    // Virtual latencies include at least the dispatch overhead.
    assert!(report.latency.p50_ns >= o.batch_overhead_ns);
    // An impossible SLO target is reported as violated.
    let mut strict = sched_opts();
    strict.slo_p99_ns = 1;
    let r = serve("strict", &trace, &strict).unwrap();
    assert!(!r.slo_met());
    let json = r.to_json_string();
    assert!(json.contains("\"status\":\"missed\""), "{json}");
}

#[test]
fn all_rejected_run_reports_no_data_not_slo_met() {
    // Regression: zero completions used to read as a vacuous SLO pass.
    let mut o = sched_opts();
    o.max_pixels = 1; // every palette request is oversize
    let report = serve("rejected", &Trace::synthetic(20, 3, 5_000.0), &o).unwrap();
    assert_eq!(report.completed, 0);
    assert_eq!(report.rejected_oversize, 20);
    assert!(!report.slo_met());
    let json = report.to_json_string();
    assert!(json.contains("\"status\":\"no-data\""), "{json}");
}

#[test]
fn wall_clock_report_keeps_the_virtual_schema() {
    let mut o = sched_opts();
    o.clock = ClockMode::Wall;
    // Tiny modeled costs keep the sleeping lanes fast.
    o.batch_overhead_ns = 20_000;
    o.cost_ns_per_pixel = 0;
    // 40 requests at 50 kHz -> under a millisecond of paced arrivals.
    let trace = Trace::synthetic(40, 11, 50_000.0);
    let wall = serve("wall", &trace, &o).unwrap();
    let virt = serve("virt", &trace, &sched_opts()).unwrap();
    assert_eq!(wall.clock, "wall");
    assert_eq!(wall.offered, 40);
    assert_eq!(wall.offered, wall.completed + wall.rejected());
    assert!(wall.makespan_ns > 0);
    // Same report schema as the virtual driver, top-level and nested.
    let (wj, vj) = (wall.to_json(), virt.to_json());
    assert_eq!(wj.get("clock").unwrap().as_str(), Some("wall"));
    assert_eq!(vj.get("clock").unwrap().as_str(), Some("virtual"));
    let keys = |j: &Json| j.as_obj().unwrap().keys().cloned().collect::<Vec<_>>();
    assert_eq!(keys(&wj), keys(&vj));
    for section in ["queue", "batch", "slo", "latency_ns", "calibration", "cache"] {
        assert_eq!(
            keys(wj.get(section).unwrap()),
            keys(vj.get(section).unwrap()),
            "section {section} diverged"
        );
    }
}

/// Acceptance: a StageTimes-calibrated virtual replay predicts the
/// wall-clock p50 for the same trace.
///
/// Tolerance band: the virtual p50 must land within a **factor of 4**
/// of the wall p50 (either direction). The band is wide by design: the
/// calibration is a min-of-repeats lower-bound-ish estimate, CI hosts
/// are timeshared, and the wall driver pays real wake-up/jitter costs
/// the model folds into its fitted overhead — but a mis-calibrated
/// model (the old synthetic constants on a slow host, or a unit slip)
/// misses by an order of magnitude, which the band catches.
#[test]
fn calibrated_virtual_p50_tracks_wall_clock_p50() {
    let mut o = sched_opts();
    o.execute = true;
    o.lanes = 2;
    o.workers_per_lane = 2;
    o.max_batch = 1; // no coalescing: latency ≈ per-request service time
    o.batch_window_ns = 0; // dispatch immediately
    // 5 ms arrival gaps: lanes never saturate, so queueing is negligible
    // and p50 isolates the service-cost model.
    let trace = Trace::synthetic(30, 5, 200.0);
    let calib = calibrate_for(&trace, &o).unwrap();
    assert!(!calib.probes.is_empty());
    o.calibration = Some(calib);

    let virt = serve("virt", &trace, &o).unwrap();
    let mut wo = o.clone();
    wo.clock = ClockMode::Wall;
    let wall = serve("wall", &trace, &wo).unwrap();

    assert_eq!(virt.completed, 30);
    assert_eq!(wall.completed, 30);
    assert!(virt.edge_pixels > 0 && wall.edge_pixels > 0, "both modes ran real compute");
    let vp50 = virt.latency.p50_ns.max(1) as f64;
    let wp50 = wall.latency.p50_ns.max(1) as f64;
    let ratio = vp50 / wp50;
    assert!(
        (0.25..=4.0).contains(&ratio),
        "calibrated virtual p50 {vp50} ns vs wall p50 {wp50} ns: ratio {ratio:.3} \
         outside the documented 4x tolerance band"
    );
}

/// Acceptance: a re-threshold request served after a front-only warmer
/// completes without re-running Gaussian/Sobel/NMS (stage records),
/// and its edge counts equal full detections at those thresholds
/// (cache-equivalence).
#[test]
fn rethreshold_hits_the_cache_and_matches_full_detection() {
    let scene = Scene::Shapes { seed: 21 };
    let (w, h) = (64usize, 64);
    let mk = |id: u64, arrival_us: u64, kind: RequestKind| Request {
        id,
        arrival_ns: arrival_us * 1_000,
        scene,
        width: w,
        height: h,
        kind,
    };
    let trace = Trace {
        requests: vec![
            mk(0, 0, RequestKind::FrontOnly),
            mk(1, 200, RequestKind::ReThreshold { lo: 0.05, hi: 0.15 }),
            mk(2, 400, RequestKind::ReThreshold { lo: 0.02, hi: 0.30 }),
        ],
    };
    let mut o = sched_opts();
    o.execute = true;
    o.lanes = 1; // one lane => one cache => deterministic hit pattern
    o.max_batch = 1;
    o.batch_window_ns = 0;
    o.workers_per_lane = 2;
    let report = serve("rethresh", &trace, &o).unwrap();

    assert_eq!(report.completed, 3);
    assert_eq!(report.kinds.get("front-only"), Some(&1));
    assert_eq!(report.kinds.get("re-threshold"), Some(&2));
    // Both re-thresholds hit the map the front-only request offered
    // into the shared artifact tier (the report's `cache` section).
    assert!(report.cache.enabled);
    assert_eq!(report.cache.hits(), 2, "stages: {:?}", report.stage_runs);
    assert_eq!(report.cache.misses(), 0);
    assert_eq!(report.cache.inserts(), 1, "one front-only warm-up");
    let serve_tier = report.cache.tiers.iter().find(|(n, _)| *n == "serve").unwrap().1;
    assert_eq!(serve_tier.hits, 2, "hits are attributed to the serve tier");
    assert_eq!(report.cache.hits() + report.cache.misses(), report.cache.lookups());
    // The front ran exactly once (the warmer); re-thresholds ran only
    // threshold + hysteresis. Lane engines are planner-chosen, so the
    // front shows up as per-stage spans (patterns) or one fused span
    // (tiled) — either way, exactly once.
    let front_runs = report.stage_runs.get("gaussian").copied().unwrap_or(0)
        + report.stage_runs.get("front").copied().unwrap_or(0);
    assert_eq!(front_runs, 1, "stages: {:?}", report.stage_runs);
    assert_eq!(report.stage_runs.get("threshold"), Some(&2));
    assert_eq!(report.stage_runs.get("hysteresis"), Some(&2));

    // Cache-equivalence: summed edge pixels equal two full detections
    // at the requested thresholds (any engine — determinism invariant).
    let img = generate(scene, w, h);
    let det = Detector::builder().workers(2).build().unwrap();
    let expect: u64 = [(0.05, 0.15), (0.02, 0.30)]
        .iter()
        .map(|&(lo, hi)| {
            det.detect(&img, &CannyParams { lo, hi, ..CannyParams::default() })
                .unwrap()
                .count_edges() as u64
        })
        .sum();
    assert_eq!(report.edge_pixels, expect);
}

#[test]
fn rethreshold_with_cache_disabled_recomputes_the_front() {
    let scene = Scene::Shapes { seed: 9 };
    let mk = |id: u64, arrival_us: u64, kind: RequestKind| Request {
        id,
        arrival_ns: arrival_us * 1_000,
        scene,
        width: 48,
        height: 48,
        kind,
    };
    let trace = Trace {
        requests: vec![
            mk(0, 0, RequestKind::ReThreshold { lo: 0.05, hi: 0.15 }),
            mk(1, 200, RequestKind::ReThreshold { lo: 0.05, hi: 0.15 }),
        ],
    };
    let mut o = sched_opts();
    o.execute = true;
    o.lanes = 1;
    o.max_batch = 1;
    o.batch_window_ns = 0;
    o.workers_per_lane = 1;
    o.cache = CacheConfig::disabled(); // --cache-mb 0: recompute every time
    let report = serve("nocache", &trace, &o).unwrap();
    assert!(!report.cache.enabled);
    // A disabled tier is never consulted: no lookups, no hits — and
    // the front really ran twice.
    assert_eq!(report.cache.lookups(), 0);
    assert_eq!(report.cache.inserts(), 0);
    let front_runs = report.stage_runs.get("gaussian").copied().unwrap_or(0)
        + report.stage_runs.get("front").copied().unwrap_or(0);
    assert_eq!(front_runs, 2, "stages: {:?}", report.stage_runs);
}

/// Satellite: SIGINT (modeled by the interrupt flag the handler sets)
/// drains a wall-clock run gracefully — admitted requests complete,
/// pending arrivals are abandoned, and the report says so.
#[test]
fn wall_interrupt_drains_and_reports_partial() {
    static FLAG: AtomicBool = AtomicBool::new(false);
    let mut o = sched_opts();
    o.clock = ClockMode::Wall;
    o.interrupt = Some(&FLAG);
    o.lanes = 1;
    o.batch_overhead_ns = 1_000;
    o.cost_ns_per_pixel = 0;
    // 5 immediate arrivals, then 5 ten seconds out: the interrupt must
    // cut the replay long before the second group.
    let mut trace = burst(5, 32, 32, 10_000);
    for k in 0..5u64 {
        trace.requests.push(Request {
            id: 5 + k,
            arrival_ns: 10_000_000_000 + k,
            scene: Scene::Gradient,
            width: 32,
            height: 32,
            kind: RequestKind::Full,
        });
    }
    let raiser = std::thread::spawn(|| {
        std::thread::sleep(std::time::Duration::from_millis(80));
        FLAG.store(true, Ordering::SeqCst);
    });
    let start = std::time::Instant::now();
    let report = serve("interrupt", &trace, &o).unwrap();
    raiser.join().unwrap();
    assert!(
        start.elapsed() < std::time::Duration::from_secs(5),
        "interrupt did not cut the 10 s replay short"
    );
    assert!(report.interrupted);
    assert_eq!(report.offered, 5, "only the first burst reached admission");
    assert_eq!(report.offered, report.completed + report.rejected());
    assert_eq!(report.completed, 5, "admitted requests drained to completion");
    let json = report.to_json_string();
    assert!(json.contains("\"interrupted\":true"), "{json}");
}

/// Tentpole acceptance: a deterministic virtual replay with
/// `--telemetry-log` produces a byte-identical JSONL stream across two
/// runs, and every line carries the documented schema.
#[test]
fn telemetry_jsonl_is_byte_identical_across_virtual_replays() {
    let run = |path: PathBuf| {
        let mut o = sched_opts();
        o.lanes = 2;
        o.telemetry_log = Some(path.clone());
        o.telemetry_interval_ns = 1_000_000; // 1 ms of modeled time
        let trace = Trace::synthetic(200, 42, 20_000.0);
        let report = serve("telemetry", &trace, &o).unwrap();
        (std::fs::read_to_string(&path).unwrap(), report)
    };
    let (a, ra) = run(tmp_jsonl("tel_a.jsonl"));
    let (b, rb) = run(tmp_jsonl("tel_b.jsonl"));
    assert_eq!(a, b, "virtual telemetry replay must be byte-identical");
    assert_eq!(ra.to_json_string(), rb.to_json_string());
    let lines: Vec<&str> = a.lines().collect();
    assert!(lines.len() >= 2, "expected ticks plus the end-state line, got {}", lines.len());
    let mut prev_t = 0u64;
    for (i, line) in lines.iter().enumerate() {
        let j = Json::parse(line).unwrap_or_else(|e| panic!("line {i} unparseable: {e:?}"));
        for key in REQUIRED_LINE_KEYS {
            assert!(j.get(key).is_some(), "line {i} missing `{key}`");
        }
        assert_eq!(j.get("seq").unwrap().as_usize(), Some(i), "seq must count lines");
        assert_eq!(j.get("tier").unwrap().as_str(), Some("serve"));
        assert_eq!(j.get("lanes").unwrap().as_arr().unwrap().len(), 2);
        let t = j.get("t_ns").unwrap().as_usize().unwrap() as u64;
        assert!(t >= prev_t, "t_ns must be monotonic (line {i})");
        prev_t = t;
        assert!(j.get("utilization").is_none(), "virtual lines never carry utilization");
    }
    // The final end-state line accounts for the whole run.
    let last = Json::parse(lines.last().unwrap()).unwrap();
    assert_eq!(last.get("queue").unwrap().get("offered").unwrap().as_usize(), Some(200));
    let completed: usize = last
        .get("lanes")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|l| l.get("completed").unwrap().as_usize().unwrap())
        .sum();
    assert_eq!(completed as u64, ra.completed);
}

/// Tentpole acceptance: under a hopeless SLO target, `reject-new`
/// sheds every arrival after the first completion — counted in the
/// report *and* on the telemetry stream's final line.
#[test]
fn reject_new_sheds_a_burst_and_counts_everywhere() {
    let path = tmp_jsonl("shed_reject.jsonl");
    let mut o = sched_opts();
    o.lanes = 1;
    o.max_batch = 1;
    o.batch_window_ns = 0;
    o.batch_overhead_ns = 1_000;
    o.cost_ns_per_pixel = 0;
    o.slo_p99_ns = 1; // unmeetable: every completion misses
    o.slo_window = 4;
    o.overload_policy = OverloadPolicy::RejectNew;
    o.telemetry_log = Some(path.clone());
    o.telemetry_interval_ns = 1_000_000;
    // Arrivals 0.5 ms apart: request 0 completes (~1 µs) long before
    // request 1 arrives, so the window is `missed` at every later door.
    let report = serve("reject", &burst(10, 32, 32, 500_000), &o).unwrap();
    assert_eq!(report.completed, 1, "only the pre-miss request runs");
    assert_eq!(report.rejected_shed, 9);
    assert_eq!(report.offered, report.completed + report.rejected());
    assert_eq!(report.overload_policy, "reject-new");
    assert!(!report.slo_window.transitions.is_empty(), "missed transition recorded");
    let json = report.to_json_string();
    assert!(json.contains("\"rejected_shed\":9"), "{json}");
    // The stream agrees with the report.
    let text = std::fs::read_to_string(&path).unwrap();
    let last = Json::parse(text.lines().last().unwrap()).unwrap();
    let overload = last.get("overload").unwrap();
    assert_eq!(overload.get("policy").unwrap().as_str(), Some("reject-new"));
    assert_eq!(overload.get("shed_rejected").unwrap().as_usize(), Some(9));
    assert_eq!(last.get("slo").unwrap().get("status").unwrap().as_str(), Some("missed"));
    assert_eq!(last.get("health").unwrap().as_str(), Some("degraded"));
}

/// Tentpole acceptance: `degrade-to-front-only` admits everything but
/// rewrites full requests to the cheap front while the SLO is missed.
#[test]
fn degrade_to_front_only_rewrites_full_requests() {
    let mut o = sched_opts();
    o.lanes = 1;
    o.max_batch = 1;
    o.batch_window_ns = 0;
    o.batch_overhead_ns = 1_000;
    o.cost_ns_per_pixel = 0;
    o.slo_p99_ns = 1;
    o.slo_window = 4;
    o.overload_policy = OverloadPolicy::DegradeFront;
    let report = serve("degrade", &burst(10, 32, 32, 500_000), &o).unwrap();
    assert_eq!(report.completed, 10, "degraded requests still complete");
    assert_eq!(report.rejected(), 0, "degrade admits; it never rejects");
    assert_eq!(report.shed_degraded, 9);
    assert_eq!(report.kinds.get("full"), Some(&1));
    assert_eq!(report.kinds.get("front-only"), Some(&9));
    assert_eq!(report.overload_policy, "degrade-to-front-only");
    let j = report.to_json();
    assert_eq!(
        j.get("overload").unwrap().get("shed_degraded").unwrap().as_usize(),
        Some(9)
    );
}

/// Policy `none` observes the missed window but never sheds — and the
/// replay stays byte-identical run to run.
#[test]
fn overload_policy_none_only_observes() {
    let mut o = sched_opts();
    o.slo_p99_ns = 1;
    o.slo_window = 8;
    assert_eq!(o.overload_policy, OverloadPolicy::None, "none is the default");
    let trace = Trace::synthetic(100, 5, 20_000.0);
    let a = serve("observe", &trace, &o).unwrap();
    let b = serve("observe", &trace, &o).unwrap();
    assert_eq!(a.to_json_string(), b.to_json_string());
    assert_eq!(a.completed, 100, "nothing shed");
    assert_eq!(a.rejected_shed, 0);
    assert_eq!(a.shed_degraded, 0);
    assert!(a.slo_window.status.name() == "missed", "window still reports the miss");
}

/// Rolling-window CI schema check: validates the JSONL file the CI
/// serve step produced (`CANNYD_TELEMETRY_JSONL=...`), or generates one
/// in-process when the env var is absent (local runs).
#[test]
fn telemetry_jsonl_matches_documented_schema() {
    let text = match std::env::var("CANNYD_TELEMETRY_JSONL") {
        Ok(path) => std::fs::read_to_string(&path)
            .unwrap_or_else(|e| panic!("CANNYD_TELEMETRY_JSONL={path}: {e}")),
        Err(_) => {
            let path = tmp_jsonl("schema_local.jsonl");
            let mut o = sched_opts();
            o.telemetry_log = Some(path.clone());
            o.telemetry_interval_ns = 1_000_000;
            serve("schema", &Trace::synthetic(50, 3, 20_000.0), &o).unwrap();
            std::fs::read_to_string(&path).unwrap()
        }
    };
    let lines: Vec<&str> = text.lines().collect();
    assert!(!lines.is_empty(), "telemetry log must not be empty");
    for (i, line) in lines.iter().enumerate() {
        let j = Json::parse(line).unwrap_or_else(|e| panic!("line {i} unparseable: {e:?}"));
        for key in REQUIRED_LINE_KEYS {
            assert!(j.get(key).is_some(), "line {i} missing `{key}`");
        }
        assert_eq!(j.get("seq").unwrap().as_usize(), Some(i));
        let tier = j.get("tier").unwrap().as_str().unwrap();
        assert!(tier == "serve" || tier == "stream", "unknown tier {tier}");
        let status = j.get("slo").unwrap().get("status").unwrap().as_str().unwrap();
        assert!(["met", "missed", "no-data"].contains(&status), "bad status {status}");
        let health = j.get("health").unwrap().as_str().unwrap();
        assert!(["healthy", "degraded", "stalled"].contains(&health), "bad health {health}");
    }
}

#[test]
fn json_trace_replays_like_a_synthetic_one() {
    let text = r#"{"requests": [
        {"arrival_us": 0,   "width": 64, "height": 64, "scene": "checker:8"},
        {"arrival_us": 100, "width": 64, "height": 64, "scene": "checker:8"},
        {"arrival_us": 150, "width": 96, "height": 64, "scene": "shapes:3"}
    ]}"#;
    let trace = Trace::from_json(text).unwrap();
    let mut o = sched_opts();
    o.lanes = 1;
    let a = serve("json", &trace, &o).unwrap();
    let b = serve("json", &Trace::from_json(text).unwrap(), &o).unwrap();
    assert_eq!(a.to_json_string(), b.to_json_string());
    assert_eq!(a.offered, 3);
    assert_eq!(a.completed, 3);
    // Two shapes -> at least two batches.
    assert!(a.batches_formed >= 2);
}
