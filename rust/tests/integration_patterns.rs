//! Integration: the pattern catalogue composed the way the pipeline
//! composes it (maps feeding stencils feeding reductions), plus the
//! pipeline/farm throughput patterns under contention.

use std::sync::atomic::{AtomicUsize, Ordering};

use canny_par::patterns::{self, farm::farm_stream, pipeline::pipeline3};
use canny_par::scheduler::Pool;

#[test]
fn map_reduce_composition_deterministic() {
    let pool = Pool::new(4).unwrap();
    let data: Vec<f32> = (0..50_000).map(|i| ((i * 37) % 101) as f32 / 101.0).collect();
    // map: square; reduce: sum — run twice on different pools.
    let run = |pool: &Pool| {
        let sq = patterns::par_map(pool, &data, 512, |_, &x| x * x);
        patterns::par_reduce(pool, &sq, 512, 0.0f32, |&x| x, |a, b| a + b)
    };
    let a = run(&pool);
    let single = Pool::new(1).unwrap();
    let b = run(&single);
    assert_eq!(a.to_bits(), b.to_bits());
}

#[test]
fn scan_then_map_pipeline() {
    let pool = Pool::new(4).unwrap();
    let xs: Vec<u64> = (1..=10_000).collect();
    let prefix = patterns::par_scan(&pool, &xs, 128, |a, b| a + b);
    assert_eq!(prefix[9_999], 10_000 * 10_001 / 2);
    let diffs = patterns::par_map(&pool, &prefix, 128, |i, &p| {
        if i == 0 { p } else { p - prefix[i - 1] }
    });
    assert_eq!(diffs, xs);
}

#[test]
fn nested_scopes_tile_in_tile() {
    // Tiles spawning sub-tasks (the batch-of-images case): correctness
    // under nesting on a small pool.
    let pool = Pool::new(2).unwrap();
    let total = AtomicUsize::new(0);
    pool.scope(|outer| {
        for _ in 0..8 {
            let total = &total;
            let pool = &pool;
            outer.spawn(move || {
                pool.scope(|inner| {
                    for _ in 0..16 {
                        inner.spawn(|| {
                            total.fetch_add(1, Ordering::Relaxed);
                        });
                    }
                });
            });
        }
    });
    assert_eq!(total.load(Ordering::Relaxed), 8 * 16);
}

#[test]
fn pipeline_farm_combo_preserves_results() {
    // Stage 1 generates work, stage 2 farms it, stage 3 folds.
    let pool = Pool::new(4).unwrap();
    let out = pipeline3(
        0..20u64,
        4,
        |seed| (seed, vec![seed; 100]),
        |(seed, items)| {
            let (res, _) = farm_stream(&pool, items, 8, |_, v| v * 2);
            (seed, res.iter().sum::<u64>())
        },
        |(seed, sum)| {
            assert_eq!(sum, seed * 200);
            sum
        },
    );
    assert_eq!(out.len(), 20);
}

#[test]
fn steals_occur_under_imbalance() {
    let pool = Pool::new(4).unwrap();
    pool.stats().reset();
    // One long task queued first, many short after: thieves must steal.
    pool.scope(|s| {
        for i in 0..64 {
            s.spawn(move || {
                let reps = if i == 0 { 3_000_000 } else { 30_000 };
                let mut acc = 0u64;
                for k in 0..reps {
                    acc = acc.wrapping_add(k);
                }
                std::hint::black_box(acc);
            });
        }
    });
    let stats = pool.stats();
    assert_eq!(stats.total_tasks(), 64);
    assert!(stats.total_steals() > 0, "no steals despite imbalance");
}

#[test]
fn grain_one_and_huge_grain_equivalent() {
    let pool = Pool::new(3).unwrap();
    let xs: Vec<i64> = (0..999).collect();
    let a = patterns::par_map(&pool, &xs, 1, |_, &x| x * 3);
    let b = patterns::par_map(&pool, &xs, 10_000, |_, &x| x * 3);
    assert_eq!(a, b);
}

#[test]
fn busy_ns_bounded_by_wall_times_workers() {
    let pool = Pool::new(4).unwrap();
    pool.stats().reset();
    let sw = std::time::Instant::now();
    pool.scope(|s| {
        for _ in 0..32 {
            s.spawn(|| {
                let mut acc = 0u64;
                for k in 0..200_000u64 {
                    acc = acc.wrapping_add(k * k);
                }
                std::hint::black_box(acc);
            });
        }
    });
    let wall = sw.elapsed().as_nanos() as u64;
    let busy = pool.stats().total_busy_ns();
    assert!(
        busy <= wall * 4 + 4_000_000,
        "busy {busy} > wall {wall} * workers (+slack)"
    );
}
