//! Integration: the trace-analytics plane.
//!
//! Covers PR 10's guarantees end to end: tail-based sampling
//! (`--trace-sample slow:<ms>`) keeps exactly the traces whose
//! virtual-clock latency clears the bar, and two replays write
//! byte-identical sampled logs — for the in-process serve tier and a
//! real 2-worker cluster alike. Every histogram exemplar the
//! telemetry stream exports resolves to a trace retained in the
//! sampled log, the `cannyd analyze` subcommand aggregates span logs
//! and the committed bench baselines (`--against` deltas included),
//! and an injected latency excursion raises an anomaly alert naming a
//! retained exemplar.

use std::collections::BTreeSet;
use std::path::{Path, PathBuf};
use std::process::Command;

use canny_par::cluster::{run_cluster, ClusterOptions, WORKER_EXE_ENV};
use canny_par::config::RunConfig;
use canny_par::image::synth::Scene;
use canny_par::obs::AnomalyMonitor;
use canny_par::service::{serve, Request, RequestKind, ServeOptions, Trace};
use canny_par::util::json::Json;

/// Point the supervisor at the freshly built `cannyd` binary (the test
/// process is the libtest harness, not `cannyd`).
fn use_test_binary() {
    static ONCE: std::sync::Once = std::sync::Once::new();
    ONCE.call_once(|| std::env::set_var(WORKER_EXE_ENV, env!("CARGO_BIN_EXE_cannyd")));
}

fn tmp_path(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("canny_analyze_itests");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(format!("{}_{name}", std::process::id()))
}

/// A mixed-kind trace (full / front-only / re-threshold per content),
/// so latencies spread across kinds and sampling is non-trivial.
fn mixed_trace(contents: u64) -> Trace {
    let mut requests = Vec::new();
    let mut id = 0u64;
    let mut push = |scene: Scene, kind: RequestKind| {
        requests.push(Request {
            id,
            arrival_ns: id * 50_000,
            scene,
            width: 96,
            height: 64,
            kind,
        });
        id += 1;
    };
    for seed in 0..contents {
        push(Scene::Shapes { seed }, RequestKind::Full);
        push(Scene::Shapes { seed }, RequestKind::FrontOnly);
        push(Scene::Shapes { seed }, RequestKind::ReThreshold { lo: 0.03, hi: 0.25 });
    }
    Trace { requests }
}

fn read_lines(path: &PathBuf) -> Vec<Json> {
    let text = std::fs::read_to_string(path).unwrap();
    text.lines().map(|l| Json::parse(l).unwrap()).collect()
}

/// `(trace id, root dur_ns)` per trace in a span log — the root span's
/// duration is exactly the end-to-end latency the sampler judged.
fn root_latencies(spans: &[Json]) -> Vec<(String, u64)> {
    spans
        .iter()
        .filter(|s| s.get("id").unwrap().as_f64().unwrap() as u64 == 1)
        .map(|s| {
            (
                s.get("trace").unwrap().as_str().unwrap().to_string(),
                s.get("dur_ns").unwrap().as_f64().unwrap() as u64,
            )
        })
        .collect()
}

/// All exemplar trace ids on a telemetry line — the top-level
/// `exemplars` section plus any per-worker sections of a merged line.
fn exemplar_ids(line: &Json) -> Vec<String> {
    let mut out = Vec::new();
    let mut scoop = |j: &Json| {
        let Some(sections) = j.get("exemplars").and_then(Json::as_obj) else { return };
        for buckets in sections.values() {
            let Some(buckets) = buckets.as_obj() else { continue };
            for ex in buckets.values() {
                if let Some(t) = ex.get("trace").and_then(Json::as_str) {
                    out.push(t.to_string());
                }
            }
        }
    };
    scoop(line);
    if let Some(workers) = line.get("workers").and_then(Json::as_arr) {
        for w in workers {
            scoop(w);
        }
    }
    out
}

fn serve_cfg(trace_log: &str, telemetry_log: Option<&str>, sample: &str) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.set("engine", "serial").unwrap();
    cfg.set("workers", "1").unwrap();
    cfg.set("lanes", "2").unwrap();
    cfg.set("cache-mb", "8").unwrap();
    cfg.set("trace-log", trace_log).unwrap();
    cfg.set("trace-sample", sample).unwrap();
    if let Some(t) = telemetry_log {
        cfg.set("telemetry-log", t).unwrap();
    }
    cfg.validate().unwrap();
    cfg
}

fn run_serve(trace_log: &PathBuf, telemetry_log: Option<&PathBuf>, sample: &str) {
    let cfg = serve_cfg(
        &trace_log.display().to_string(),
        telemetry_log.map(|p| p.display().to_string()).as_deref(),
        sample,
    );
    serve("itest-analyze", &mixed_trace(4), &ServeOptions::from_config(&cfg)).unwrap();
}

/// Pick a `slow:<ms>` bar from a keep-everything reference run: the
/// maximum observed latency, converted exactly the way
/// `TraceSampler::from_spec` converts it back, so the expected kept
/// set is computed with bit-identical arithmetic.
fn slow_bar(latencies: &[(String, u64)]) -> (String, BTreeSet<String>) {
    let max = latencies.iter().map(|(_, d)| *d).max().unwrap();
    let ms = format!("{}", max as f64 / 1e6);
    let bar_ns = (ms.parse::<f64>().unwrap() * 1e6) as u64;
    let kept: BTreeSet<String> =
        latencies.iter().filter(|(_, d)| *d >= bar_ns).map(|(t, _)| t.clone()).collect();
    (ms, kept)
}

#[test]
fn sampled_serve_replays_are_byte_identical_and_exemplars_resolve() {
    // Reference run: keep everything, learn the latency distribution.
    let all_log = tmp_path("serve_all.jsonl");
    run_serve(&all_log, None, "all");
    let latencies = root_latencies(&read_lines(&all_log));
    assert_eq!(latencies.len(), 12, "one root span per request");
    let spread: BTreeSet<u64> = latencies.iter().map(|(_, d)| *d).collect();
    assert!(spread.len() > 1, "mixed kinds must spread latencies: {spread:?}");
    let (ms, expected) = slow_bar(&latencies);
    assert!(!expected.is_empty());
    assert!(expected.len() < latencies.len(), "the bar must actually drop traces");

    // Two sampled replays: byte-identical trace AND telemetry logs.
    let (log_a, tel_a) = (tmp_path("serve_slow_a.jsonl"), tmp_path("serve_slow_a_tel.jsonl"));
    let (log_b, tel_b) = (tmp_path("serve_slow_b.jsonl"), tmp_path("serve_slow_b_tel.jsonl"));
    let sample = format!("slow:{ms}");
    run_serve(&log_a, Some(&tel_a), &sample);
    run_serve(&log_b, Some(&tel_b), &sample);
    let bytes_a = std::fs::read(&log_a).unwrap();
    assert!(!bytes_a.is_empty());
    assert_eq!(bytes_a, std::fs::read(&log_b).unwrap(), "sampled trace replays must match");
    assert_eq!(
        std::fs::read(&tel_a).unwrap(),
        std::fs::read(&tel_b).unwrap(),
        "sampled telemetry replays must match"
    );

    // The sampler kept exactly the traces above the bar, whole trees.
    let spans = read_lines(&log_a);
    let kept: BTreeSet<String> = spans
        .iter()
        .map(|s| s.get("trace").unwrap().as_str().unwrap().to_string())
        .collect();
    assert_eq!(kept, expected, "slow:{ms} must keep exactly the traces above the bar");
    assert_eq!(root_latencies(&spans).len(), expected.len(), "kept trees keep their roots");

    // Every exported exemplar resolves to a retained trace.
    let tel_lines = read_lines(&tel_a);
    let exemplars: Vec<String> =
        tel_lines.iter().flat_map(|l| exemplar_ids(l)).collect();
    assert!(!exemplars.is_empty(), "kept traces must surface as exemplars");
    for id in &exemplars {
        assert!(kept.contains(id), "exemplar {id} does not resolve to a retained trace");
    }
    for f in [&all_log, &log_a, &log_b, &tel_a, &tel_b] {
        std::fs::remove_file(f).ok();
    }
}

fn cluster_cfg(trace_log: &PathBuf, telemetry_log: &PathBuf, sample: &str) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.set("engine", "serial").unwrap();
    cfg.set("workers", "2").unwrap();
    cfg.set("cache-mb", "8").unwrap();
    cfg.set("trace-log", &trace_log.display().to_string()).unwrap();
    cfg.set("telemetry-log", &telemetry_log.display().to_string()).unwrap();
    cfg.set("trace-sample", sample).unwrap();
    cfg.set("worker-telemetry-ms", "0.2").unwrap();
    cfg.validate().unwrap();
    cfg
}

fn run_cluster_sampled(trace_log: &PathBuf, telemetry_log: &PathBuf, sample: &str) {
    let cfg = cluster_cfg(trace_log, telemetry_log, sample);
    let out =
        run_cluster("itest-analyze-cluster", &mixed_trace(4), &ClusterOptions::from_config(&cfg))
            .unwrap();
    assert_eq!(out.report.completed, 12);
}

#[test]
fn sampled_cluster_replays_are_byte_identical_and_exemplars_resolve() {
    use_test_binary();
    // Reference run for the bar, as in the serve test.
    let (all_log, all_tel) = (tmp_path("cl_all.jsonl"), tmp_path("cl_all_tel.jsonl"));
    run_cluster_sampled(&all_log, &all_tel, "all");
    let latencies = root_latencies(&read_lines(&all_log));
    assert_eq!(latencies.len(), 12);
    let (ms, expected) = slow_bar(&latencies);
    assert!(!expected.is_empty() && expected.len() < latencies.len());

    let (ta, sa) = (tmp_path("cl_slow_a.jsonl"), tmp_path("cl_slow_a_tel.jsonl"));
    let (tb, sb) = (tmp_path("cl_slow_b.jsonl"), tmp_path("cl_slow_b_tel.jsonl"));
    let sample = format!("slow:{ms}");
    run_cluster_sampled(&ta, &sa, &sample);
    run_cluster_sampled(&tb, &sb, &sample);
    let trace_bytes = std::fs::read(&ta).unwrap();
    assert!(!trace_bytes.is_empty());
    assert_eq!(
        trace_bytes,
        std::fs::read(&tb).unwrap(),
        "sampled cluster trace replays must match"
    );
    assert_eq!(
        std::fs::read(&sa).unwrap(),
        std::fs::read(&sb).unwrap(),
        "sampled merged telemetry replays must match"
    );

    // The front door's verdict governed whole trees: kept traces carry
    // their worker service subtree (id 4 under the wire span), dropped
    // ones vanish entirely — never a torn tree.
    let spans = read_lines(&ta);
    let trace_of = |s: &Json| s.get("trace").unwrap().as_str().unwrap().to_string();
    let id_of = |s: &Json| s.get("id").unwrap().as_f64().unwrap() as u64;
    let kept: BTreeSet<String> = spans.iter().map(|s| trace_of(s)).collect();
    assert_eq!(kept, expected, "slow:{ms} must keep exactly the traces above the bar");
    for t in &kept {
        let tree: Vec<&Json> = spans.iter().filter(|s| trace_of(s) == *t).collect();
        assert!(tree.iter().any(|s| id_of(s) == 1), "kept trace {t} lost its root");
        let service = tree.iter().find(|s| id_of(s) == 4).expect("worker service span");
        assert_eq!(service.get("parent").unwrap().as_f64().unwrap() as u64, 3);
    }

    // Exemplars — front door and worker sections alike — resolve to
    // retained traces (workers note them only on guaranteed-keep
    // verdicts).
    let exemplars: Vec<String> =
        read_lines(&sa).iter().flat_map(exemplar_ids).collect();
    assert!(!exemplars.is_empty(), "kept traces must surface as worker exemplars");
    for id in &exemplars {
        assert!(kept.contains(id), "cluster exemplar {id} not in the retained trace set");
    }
    for f in [&all_log, &all_tel, &ta, &sa, &tb, &sb] {
        std::fs::remove_file(f).ok();
    }
}

fn cannyd_analyze(args: &[&str]) -> Json {
    let out = Command::new(env!("CARGO_BIN_EXE_cannyd"))
        .arg("analyze")
        .args(args)
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "analyze {args:?} failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    Json::parse(&String::from_utf8(out.stdout).unwrap()).unwrap()
}

#[test]
fn analyze_cli_aggregates_span_logs_and_diffs_baselines() {
    let log = tmp_path("analyze_serve.jsonl");
    run_serve(&log, None, "all");
    let log_s = log.display().to_string();
    let report = cannyd_analyze(&[&log_s]);
    assert_eq!(report.get("kind").unwrap().as_str(), Some("spans"));
    assert_eq!(report.get("traces").unwrap().as_usize(), Some(12));
    let agg = report.get("aggregates").unwrap().as_obj().unwrap();
    for name in ["request", "service", "queue_wait"] {
        let a = agg.get(name).unwrap_or_else(|| panic!("aggregates missing `{name}`"));
        assert!(a.get("count").unwrap().as_usize().unwrap() >= 12);
        assert!(a.get("p99_ns").unwrap().as_f64().unwrap() >= a.get("p50_ns").unwrap().as_f64().unwrap());
    }
    assert!(agg.keys().any(|k| k.starts_with("stage:")), "stage spans must aggregate");
    let paths = report.get("critical_paths").unwrap().as_obj().unwrap();
    assert!(!paths.is_empty());
    let shared: usize = paths.values().map(|n| n.as_usize().unwrap()).sum();
    assert_eq!(shared, 12, "every trace contributes one critical path");
    assert!(paths.keys().all(|p| p.starts_with("request>")), "{paths:?}");

    // A self-diff is all-zero deltas — the determinism statement again,
    // through the analyzer this time.
    let diff = cannyd_analyze(&[&log_s, "--against", &log_s]);
    let deltas = diff.get("deltas").unwrap().as_obj().unwrap();
    assert!(!deltas.is_empty());
    for (name, d) in deltas {
        assert_eq!(d.get("delta_p50_pct").unwrap().as_f64(), Some(0.0), "{name}");
        assert_eq!(d.get("delta_p99_pct").unwrap().as_f64(), Some(0.0), "{name}");
    }

    // The committed bench baselines analyze too, so fresh runs can be
    // diffed against the seed numbers with the same subcommand.
    let bench = Path::new(env!("CARGO_MANIFEST_DIR")).join("benches/baselines/BENCH_serve.json");
    let bench_s = bench.display().to_string();
    let base = cannyd_analyze(&[&bench_s, "--against", &bench_s]);
    assert_eq!(base.get("kind").unwrap().as_str(), Some("bench"));
    let d = base.get("deltas").unwrap().get("serve").expect("serve delta");
    assert_eq!(d.get("delta_p99_pct").unwrap().as_f64(), Some(0.0));
    assert!(d.get("base_p99_ns").unwrap().as_f64().unwrap() > 0.0);
    std::fs::remove_file(&log).ok();
}

#[test]
fn injected_latency_excursion_alerts_with_a_retained_exemplar() {
    // A real sampled run provides the steady-state line and the
    // retained trace set.
    let (log, tel) = (tmp_path("anomaly.jsonl"), tmp_path("anomaly_tel.jsonl"));
    run_serve(&log, Some(&tel), "slow:0");
    let kept: BTreeSet<String> = read_lines(&log)
        .iter()
        .map(|s| s.get("trace").unwrap().as_str().unwrap().to_string())
        .collect();
    let line = read_lines(&tel).into_iter().last().unwrap();
    assert!(!exemplar_ids(&line).is_empty(), "the final line must export exemplars");

    // Feed the same line until every detector is warm (flat series stay
    // quiet), then inject a 50x latency excursion.
    let mut monitor = AnomalyMonitor::from_sigma(3.0).unwrap();
    for _ in 0..12 {
        assert!(monitor.observe_line(&line).is_empty(), "steady state must stay quiet");
    }
    let mean = line.get("latency_ns").unwrap().get("mean").unwrap().as_f64().unwrap();
    assert!(mean > 0.0);
    let mut obj = line.as_obj().unwrap().clone();
    let mut lat = obj.get("latency_ns").unwrap().as_obj().unwrap().clone();
    lat.insert("mean".to_string(), Json::Num(mean * 50.0));
    obj.insert("latency_ns".to_string(), Json::Obj(lat));
    let alerts = monitor.observe_line(&Json::Obj(obj));
    let alert = alerts
        .iter()
        .find(|a| a.series == "latency_mean")
        .expect("the excursion must raise a latency_mean anomaly");
    assert!(alert.z >= 3.0);
    assert!(
        kept.contains(&alert.exemplar),
        "alert exemplar {} must resolve to a retained trace",
        alert.exemplar
    );
    assert!(alert.line().contains("scope=anomaly:latency_mean"));
    std::fs::remove_file(&log).ok();
    std::fs::remove_file(&tel).ok();
}
