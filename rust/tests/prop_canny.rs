//! Property tests on the detector: engine equivalence, tiling
//! invariance, hysteresis monotonicity — over random images, sizes,
//! thresholds, tiles and worker counts.

use canny_par::canny::{
    consts, gaussian, hysteresis, nms, sobel, threshold, CannyParams, CannyPipeline, StageKind,
    StagePlan,
};
use canny_par::image::ImageF32;
use canny_par::scheduler::Pool;
use canny_par::util::Prng;

const CASES: usize = 15;

fn random_image(rng: &mut Prng, w: usize, h: usize) -> ImageF32 {
    // Mix of structure (plateaus) and noise so hysteresis has work.
    let mut img = ImageF32::zeros(w, h);
    let cell = 4 + rng.next_below(16);
    for y in 0..h {
        for x in 0..w {
            let base = if ((x / cell) + (y / cell)) % 2 == 0 { 0.3 } else { 0.7 };
            img.set(y, x, (base + 0.05 * rng.next_gaussian()).clamp(0.0, 1.0));
        }
    }
    img
}

fn random_params(rng: &mut Prng) -> CannyParams {
    let lo = 0.02 + 0.1 * rng.next_f32();
    CannyParams {
        lo,
        hi: lo + 0.02 + 0.2 * rng.next_f32(),
        tile: [16, 32, 64, 128][rng.next_below(4)],
        parallel_hysteresis: false,
        band_grain: 0,
    }
}

#[test]
fn prop_engines_agree_on_random_inputs() {
    let mut rng = Prng::new(0xF00D);
    for case in 0..CASES {
        let w = 20 + rng.next_below(200);
        let h = 20 + rng.next_below(150);
        let img = random_image(&mut rng, w, h);
        let params = random_params(&mut rng);
        let workers = 1 + rng.next_below(6);
        let pool = Pool::new(workers).unwrap();
        let serial = CannyPipeline::serial().detect(&img, &params).unwrap();
        let patterns = CannyPipeline::patterns(&pool).detect(&img, &params).unwrap();
        let tiled = CannyPipeline::tiled(&pool).detect(&img, &params).unwrap();
        assert_eq!(
            serial.edges.diff_count(&patterns.edges),
            0,
            "case {case}: patterns({workers}w) {w}x{h} tile={}",
            params.tile
        );
        assert_eq!(
            serial.edges.diff_count(&tiled.edges),
            0,
            "case {case}: tiled({workers}w) {w}x{h} tile={}",
            params.tile
        );
    }
}

#[test]
fn prop_parallel_hysteresis_equals_serial() {
    let mut rng = Prng::new(0xFACE);
    let pool = Pool::new(4).unwrap();
    for case in 0..CASES {
        let w = 16 + rng.next_below(120);
        let h = 16 + rng.next_below(120);
        // Random class map with tunable strong/weak density.
        let p_strong = 0.01 + 0.05 * rng.next_f32();
        let p_weak = 0.1 + 0.4 * rng.next_f32();
        let mut cls = ImageF32::zeros(w, h);
        for y in 0..h {
            for x in 0..w {
                let r = rng.next_f32();
                cls.set(
                    y,
                    x,
                    if r < p_strong {
                        2.0
                    } else if r < p_strong + p_weak {
                        1.0
                    } else {
                        0.0
                    },
                );
            }
        }
        let ser = hysteresis::hysteresis_serial(&cls);
        let par = hysteresis::hysteresis_parallel(&pool, &cls);
        assert_eq!(ser.diff_count(&par), 0, "case {case} {w}x{h}");
    }
}

#[test]
fn prop_edges_subset_of_weak_or_strong() {
    // Every edge pixel must have been weak or strong; every strong
    // pixel must be an edge.
    let mut rng = Prng::new(0xBEEF);
    for _ in 0..CASES {
        let w = 20 + rng.next_below(100);
        let h = 20 + rng.next_below(100);
        let img = random_image(&mut rng, w, h);
        let params = random_params(&mut rng);
        let out = CannyPipeline::serial().detect(&img, &params).unwrap();
        for y in 0..h {
            for x in 0..w {
                let c = out.class_map.get(y, x);
                if out.edges.is_edge(y, x) {
                    assert!(c >= 1.0, "edge at ({y},{x}) with class {c}");
                }
                if c == 2.0 {
                    assert!(out.edges.is_edge(y, x), "strong at ({y},{x}) not an edge");
                }
            }
        }
    }
}

#[test]
fn prop_hysteresis_monotone_in_weak_set() {
    // Adding weak pixels can only grow the edge set (monotonicity).
    let mut rng = Prng::new(0xCAFE);
    for case in 0..CASES {
        let (w, h) = (40, 40);
        let mut cls = ImageF32::zeros(w, h);
        for y in 0..h {
            for x in 0..w {
                let r = rng.next_f32();
                cls.set(y, x, if r < 0.03 { 2.0 } else if r < 0.3 { 1.0 } else { 0.0 });
            }
        }
        let before = hysteresis::hysteresis_serial(&cls);
        // Promote some background to weak.
        let mut grown = cls.clone();
        for _ in 0..60 {
            let (y, x) = (rng.next_below(h), rng.next_below(w));
            if grown.get(y, x) == 0.0 {
                grown.set(y, x, 1.0);
            }
        }
        let after = hysteresis::hysteresis_serial(&grown);
        for i in 0..w * h {
            assert!(
                !(before.data()[i] != 0 && after.data()[i] == 0),
                "case {case}: edge lost at {i} after growing weak set"
            );
        }
    }
}

/// Satellite: every stop-stage artifact equals the corresponding
/// prefix of `front_serial` — across the serial, patterns and tiled
/// engines (the tiled engine runs partial prefixes unfused; the
/// property pins that path to the same values).
#[test]
fn prop_partial_plans_match_front_serial_prefix() {
    let mut rng = Prng::new(0x51A6);
    let pool = Pool::new(3).unwrap();
    for case in 0..8 {
        let w = 24 + rng.next_below(120);
        let h = 24 + rng.next_below(90);
        let img = random_image(&mut rng, w, h);
        let params = random_params(&mut rng);

        // The reference prefix, stage by stage (front_serial's body).
        let padded = img.pad_replicate(consts::HALO);
        let g = gaussian::gaussian(&padded);
        let (mag, dir) = sobel::sobel(&g);
        let nm = nms::nms(&mag, &dir);
        let cls = threshold::threshold(&nm, params.lo, params.hi);

        for pipe in
            [CannyPipeline::serial(), CannyPipeline::patterns(&pool), CannyPipeline::tiled(&pool)]
        {
            let engine = pipe.engine.name();
            let run = |stop: StageKind| {
                pipe.execute(&StagePlan::new().stop_after(stop), Some(&img), &params)
                    .unwrap_or_else(|e| panic!("case {case} {engine} stop {stop:?}: {e}"))
            };
            let ctx = |stop: &str| format!("case {case}: {engine} {w}x{h} stop {stop}");
            assert_eq!(run(StageKind::Pad).gray().unwrap(), &padded, "{}", ctx("pad"));
            assert_eq!(run(StageKind::Gaussian).gray().unwrap(), &g, "{}", ctx("gaussian"));
            let out = run(StageKind::Sobel);
            let (m, d) = out.gradient().unwrap();
            assert_eq!(m, &mag, "{}", ctx("sobel mag"));
            assert_eq!(d, &dir, "{}", ctx("sobel dir"));
            assert_eq!(run(StageKind::Nms).suppressed().unwrap(), &nm, "{}", ctx("nms"));
            let out = run(StageKind::Threshold);
            assert_eq!(out.class_map().unwrap(), &cls, "{}", ctx("threshold"));
            assert!(!out.ran(StageKind::Hysteresis), "{}", ctx("threshold overran"));
        }
    }
}

/// Satellite: resuming from a cached suppressed-magnitude map with any
/// thresholds equals running the whole pipeline with those thresholds.
#[test]
fn prop_rethreshold_from_cached_map_equals_full_run() {
    let mut rng = Prng::new(0xD1CE);
    let pool = Pool::new(2).unwrap();
    for case in 0..8 {
        let w = 24 + rng.next_below(100);
        let h = 24 + rng.next_below(80);
        let img = random_image(&mut rng, w, h);
        let params = random_params(&mut rng);
        let pipe = CannyPipeline::patterns(&pool);

        let front = StagePlan::new().stop_after(StageKind::Nms);
        let mut front_out = pipe.execute(&front, Some(&img), &params).unwrap();
        let nm = front_out.take_suppressed().unwrap();

        // New, independent thresholds.
        let lo = 0.01 + 0.1 * rng.next_f32();
        let re_params = CannyParams { lo, hi: lo + 0.01 + 0.25 * rng.next_f32(), ..params };
        let resume = StagePlan::new().from_suppressed(nm);
        let resumed = pipe.execute(&resume, None, &re_params).unwrap();
        let full = CannyPipeline::serial().detect(&img, &re_params).unwrap();
        assert_eq!(
            full.edges.diff_count(resumed.edges().unwrap()),
            0,
            "case {case}: {w}x{h} lo={} hi={}",
            re_params.lo,
            re_params.hi
        );
        for k in [StageKind::Pad, StageKind::Gaussian, StageKind::Sobel, StageKind::Nms] {
            assert!(!resumed.ran(k), "case {case}: resume re-ran {:?}", k);
        }
    }
}

#[test]
fn prop_tile_size_never_changes_result() {
    let mut rng = Prng::new(0x7157);
    let pool = Pool::new(3).unwrap();
    for _ in 0..8 {
        let w = 50 + rng.next_below(150);
        let h = 50 + rng.next_below(100);
        let img = random_image(&mut rng, w, h);
        let mut reference = None;
        for tile in [16usize, 24, 64, 96, 512] {
            let params = CannyParams { tile, ..CannyParams::default() };
            let out = CannyPipeline::tiled(&pool).detect(&img, &params).unwrap();
            match &reference {
                None => reference = Some(out.edges.clone()),
                Some(r) => {
                    assert_eq!(r.diff_count(&out.edges), 0, "{w}x{h} tile={tile}")
                }
            }
        }
    }
}
