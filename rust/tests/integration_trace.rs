//! Integration: the distributed-tracing and cluster-telemetry plane.
//!
//! Covers the PR's observability guarantees end to end: virtual-clock
//! serve replays write byte-identical trace logs (span JSONL and
//! Chrome trace-event JSON, both matching their documented schemas),
//! and a real 2-worker cluster stitches worker service subtrees under
//! the front door's spans while merging per-worker telemetry streams
//! into one deterministic cluster-wide JSONL.

use std::path::PathBuf;

use canny_par::cluster::{run_cluster, ClusterOptions, WORKER_EXE_ENV};
use canny_par::config::RunConfig;
use canny_par::image::synth::Scene;
use canny_par::obs::{REQUIRED_EVENT_KEYS, REQUIRED_SPAN_KEYS};
use canny_par::service::{serve, Request, RequestKind, ServeOptions, Trace};
use canny_par::util::json::Json;

/// Point the supervisor at the freshly built `cannyd` binary (the test
/// process is the libtest harness, not `cannyd`).
fn use_test_binary() {
    static ONCE: std::sync::Once = std::sync::Once::new();
    ONCE.call_once(|| std::env::set_var(WORKER_EXE_ENV, env!("CARGO_BIN_EXE_cannyd")));
}

fn tmp_path(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("canny_trace_itests");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(format!("{}_{name}", std::process::id()))
}

/// A mixed-kind trace: full detections plus front-only warms followed
/// by re-threshold sweeps, so traces carry every cache outcome.
fn mixed_trace(contents: u64) -> Trace {
    let mut requests = Vec::new();
    let mut id = 0u64;
    let mut push = |scene: Scene, kind: RequestKind| {
        requests.push(Request {
            id,
            arrival_ns: id * 50_000,
            scene,
            width: 96,
            height: 64,
            kind,
        });
        id += 1;
    };
    for seed in 0..contents {
        push(Scene::Shapes { seed }, RequestKind::Full);
        push(Scene::Shapes { seed }, RequestKind::FrontOnly);
        push(Scene::Shapes { seed }, RequestKind::ReThreshold { lo: 0.03, hi: 0.25 });
    }
    Trace { requests }
}

fn serve_cfg(trace_log: &str) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.set("engine", "serial").unwrap();
    cfg.set("workers", "1").unwrap();
    cfg.set("lanes", "2").unwrap();
    cfg.set("cache-mb", "8").unwrap();
    cfg.set("trace-log", trace_log).unwrap();
    cfg.validate().unwrap();
    cfg
}

fn run_serve_with_trace(path: &PathBuf) {
    let cfg = serve_cfg(&path.display().to_string());
    let opts = ServeOptions::from_config(&cfg);
    serve("itest-trace", &mixed_trace(4), &opts).unwrap();
}

#[test]
fn virtual_serve_replays_write_byte_identical_span_jsonl() {
    let a = tmp_path("serve_a.jsonl");
    let b = tmp_path("serve_b.jsonl");
    run_serve_with_trace(&a);
    run_serve_with_trace(&b);
    let bytes_a = std::fs::read(&a).unwrap();
    let bytes_b = std::fs::read(&b).unwrap();
    assert!(!bytes_a.is_empty(), "trace log must not be empty");
    assert_eq!(bytes_a, bytes_b, "virtual-clock replays must be byte-identical");

    // Every line is a span object with exactly the documented keys,
    // and every request tree stitches: root -> coalesce/queue on the
    // intake lane, service (+ stages) under the root on a serve lane.
    let text = String::from_utf8(bytes_a).unwrap();
    let spans: Vec<Json> = text.lines().map(|l| Json::parse(l).unwrap()).collect();
    for span in &spans {
        for key in REQUIRED_SPAN_KEYS {
            assert!(span.get(key).is_some(), "span line is missing `{key}`");
        }
    }
    let trace_of = |s: &Json| s.get("trace").unwrap().as_str().unwrap().to_string();
    let id_of = |s: &Json| s.get("id").unwrap().as_f64().unwrap() as u64;
    let roots: Vec<&Json> = spans.iter().filter(|s| id_of(s) == 1).collect();
    assert_eq!(roots.len(), 12, "one root span per request");
    for root in roots {
        let t = trace_of(root);
        let tree: Vec<&Json> = spans.iter().filter(|s| trace_of(s) == t).collect();
        assert!(tree.iter().any(|s| id_of(s) == 4), "trace {t} has no service span");
        assert!(tree.iter().any(|s| id_of(s) >= 6), "trace {t} has no stage spans");
    }
    // The cache consult outcomes show up as span attributes.
    let outcomes: Vec<String> = spans
        .iter()
        .filter_map(|s| s.get("attrs")?.get("outcome"))
        .map(|o| o.as_str().unwrap().to_string())
        .collect();
    assert!(outcomes.iter().any(|o| o == "offer"), "front-only warms must trace as offers");
    std::fs::remove_file(&a).ok();
    std::fs::remove_file(&b).ok();
}

#[test]
fn chrome_trace_export_has_the_documented_event_schema() {
    let path = tmp_path("serve_chrome.json");
    run_serve_with_trace(&path);
    let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
    let events = match doc.get("traceEvents") {
        Some(Json::Arr(a)) => a,
        other => panic!("traceEvents must be an array, got {other:?}"),
    };
    assert!(!events.is_empty());
    for ev in events {
        for key in REQUIRED_EVENT_KEYS {
            assert!(ev.get(key).is_some(), "chrome event is missing `{key}`");
        }
        assert!(matches!(ev.get("ph"), Some(Json::Str(p)) if p == "X"));
        assert!(ev.get("args").and_then(|a| a.get("trace")).is_some());
    }
    std::fs::remove_file(&path).ok();
}

fn cluster_cfg(trace_log: &PathBuf, telemetry_log: &PathBuf) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.set("engine", "serial").unwrap();
    cfg.set("workers", "2").unwrap();
    cfg.set("cache-mb", "8").unwrap();
    cfg.set("trace-log", &trace_log.display().to_string()).unwrap();
    cfg.set("telemetry-log", &telemetry_log.display().to_string()).unwrap();
    // Frequent worker frames on the modeled clock, so the merged
    // stream carries periodic lines, not just the hello/report pair.
    cfg.set("worker-telemetry-ms", "0.2").unwrap();
    cfg.validate().unwrap();
    cfg
}

fn run_cluster_with_obs(trace_log: &PathBuf, telemetry_log: &PathBuf) {
    let cfg = cluster_cfg(trace_log, telemetry_log);
    let opts = ClusterOptions::from_config(&cfg);
    let out = run_cluster("itest-cluster-trace", &mixed_trace(4), &opts).unwrap();
    assert_eq!(out.report.completed, 12);
}

#[test]
fn cluster_traces_stitch_and_replay_byte_identical() {
    use_test_binary();
    let (ta, sa) = (tmp_path("cluster_a.jsonl"), tmp_path("cluster_a_tel.jsonl"));
    let (tb, sb) = (tmp_path("cluster_b.jsonl"), tmp_path("cluster_b_tel.jsonl"));
    run_cluster_with_obs(&ta, &sa);
    run_cluster_with_obs(&tb, &sb);
    let trace_a = std::fs::read(&ta).unwrap();
    assert!(!trace_a.is_empty());
    assert_eq!(trace_a, std::fs::read(&tb).unwrap(), "cluster trace replays must be identical");
    let tel_a = std::fs::read(&sa).unwrap();
    assert!(!tel_a.is_empty());
    assert_eq!(tel_a, std::fs::read(&sb).unwrap(), "merged telemetry replays must be identical");

    // Every request's tree stitches across the process boundary: the
    // front door's root (id 1) and wire span (id 3), then the worker's
    // service subtree (id 4, parent 3) with stage spans, all under one
    // trace id.
    let text = String::from_utf8(trace_a).unwrap();
    let spans: Vec<Json> = text.lines().map(|l| Json::parse(l).unwrap()).collect();
    let trace_of = |s: &Json| s.get("trace").unwrap().as_str().unwrap().to_string();
    let id_of = |s: &Json| s.get("id").unwrap().as_f64().unwrap() as u64;
    let roots: Vec<&Json> = spans.iter().filter(|s| id_of(s) == 1).collect();
    assert_eq!(roots.len(), 12, "one root span per routed request");
    for root in roots {
        let t = trace_of(root);
        assert!(matches!(root.get("cat"), Some(Json::Str(c)) if c == "cluster"));
        let tree: Vec<&Json> = spans.iter().filter(|s| trace_of(s) == t).collect();
        let wire = tree.iter().find(|s| id_of(s) == 3).expect("wire span");
        let service = tree.iter().find(|s| id_of(s) == 4).expect("worker service span");
        assert_eq!(
            service.get("parent").unwrap().as_f64().unwrap() as u64,
            3,
            "the worker subtree must stitch under the wire span"
        );
        assert_eq!(
            service.get("tid").unwrap().as_f64(),
            wire.get("tid").unwrap().as_f64(),
            "wire and service render on the owning slot's lane"
        );
        assert!(tree.iter().any(|s| id_of(s) >= 6), "trace {t} has no worker stage spans");
    }
    for f in [&ta, &sa, &tb, &sb] {
        std::fs::remove_file(f).ok();
    }
}

#[test]
fn merged_cluster_telemetry_sums_the_worker_sections() {
    use_test_binary();
    let (trace_log, tel_log) = (tmp_path("merge.jsonl"), tmp_path("merge_tel.jsonl"));
    run_cluster_with_obs(&trace_log, &tel_log);
    let text = std::fs::read_to_string(&tel_log).unwrap();
    let lines: Vec<Json> = text.lines().map(|l| Json::parse(l).unwrap()).collect();
    assert!(lines.len() >= 3, "expected hello + periodic + final lines, got {}", lines.len());
    // The merged stream's own seq is dense from 1.
    for (i, line) in lines.iter().enumerate() {
        assert_eq!(line.get("seq").unwrap().as_f64().unwrap() as usize, i + 1);
        assert!(matches!(line.get("tier"), Some(Json::Str(t)) if t == "cluster"));
    }
    let last = lines.last().unwrap();
    let workers = match last.get("workers") {
        Some(Json::Arr(a)) => a,
        other => panic!("merged line must carry a workers array, got {other:?}"),
    };
    assert_eq!(workers.len(), 2, "both workers report in the final merged line");
    let admitted = |j: &Json| {
        j.get("queue").unwrap().get("admitted").unwrap().as_f64().unwrap() as u64
    };
    let lane_total: u64 = workers
        .iter()
        .map(|w| w.get("lanes").unwrap().as_arr().unwrap().len() as u64)
        .sum();
    assert_eq!(lane_total, 2, "one lane per worker, concatenated totals");
    assert_eq!(
        admitted(last),
        workers.iter().map(admitted).sum::<u64>(),
        "merged counters must equal the sum of the per-worker sections"
    );
    assert_eq!(admitted(last), 12, "every routed request is admitted by some worker");
    for w in workers {
        let seq = w.get("seq").unwrap().as_f64().unwrap() as u64;
        assert!(seq >= 1, "worker sections must carry a nonzero persistent-engine seq");
        assert!(matches!(w.get("tier"), Some(Json::Str(t)) if t == "worker"));
        assert!(w.get("worker").is_some());
    }
    std::fs::remove_file(&trace_log).ok();
    std::fs::remove_file(&tel_log).ok();
}
