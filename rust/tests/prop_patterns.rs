//! Property tests on the pattern catalogue: parallel == serial for any
//! input size / grain / worker count; reductions and scans bitwise
//! deterministic (the paper's determinism goal as an invariant).

use canny_par::patterns;
use canny_par::scheduler::Pool;
use canny_par::util::Prng;

const CASES: usize = 30;

fn random_vec(rng: &mut Prng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.next_f32() * 2.0 - 1.0).collect()
}

#[test]
fn prop_par_map_equals_serial_map() {
    let mut rng = Prng::new(1);
    for _ in 0..CASES {
        let workers = 1 + rng.next_below(8);
        let n = rng.next_below(5000);
        let grain = 1 + rng.next_below(600);
        let xs = random_vec(&mut rng, n);
        let pool = Pool::new(workers).unwrap();
        let par = patterns::par_map(&pool, &xs, grain, |i, &x| (x * 3.5 + i as f32).to_bits());
        let ser: Vec<u32> =
            xs.iter().enumerate().map(|(i, &x)| (x * 3.5 + i as f32).to_bits()).collect();
        assert_eq!(par, ser, "workers={workers} n={n} grain={grain}");
    }
}

#[test]
fn prop_par_reduce_bitwise_stable_across_workers() {
    let mut rng = Prng::new(2);
    for _ in 0..CASES {
        let n = rng.next_below(4000);
        let grain = 1 + rng.next_below(300);
        let xs = random_vec(&mut rng, n);
        let mut first: Option<u32> = None;
        for workers in [1usize, 2, 5, 8] {
            let pool = Pool::new(workers).unwrap();
            let sum = patterns::par_reduce(&pool, &xs, grain, 0.0f32, |&x| x, |a, b| a + b);
            match first {
                None => first = Some(sum.to_bits()),
                Some(f) => assert_eq!(
                    f,
                    sum.to_bits(),
                    "grain={grain} n={n} workers={workers}: f32 sum unstable"
                ),
            }
        }
    }
}

#[test]
fn prop_par_scan_equals_serial_scan() {
    let mut rng = Prng::new(3);
    for _ in 0..CASES {
        let workers = 1 + rng.next_below(6);
        let n = rng.next_below(3000);
        let grain = 1 + rng.next_below(250);
        let xs: Vec<u64> = (0..n).map(|_| rng.next_below(1000) as u64).collect();
        let pool = Pool::new(workers).unwrap();
        let par = patterns::par_scan(&pool, &xs, grain, |a, b| a.wrapping_add(*b));
        let mut acc = 0u64;
        let ser: Vec<u64> = xs
            .iter()
            .map(|&x| {
                acc = acc.wrapping_add(x);
                acc
            })
            .collect();
        assert_eq!(par, ser, "workers={workers} n={n} grain={grain}");
    }
}

#[test]
fn prop_farm_preserves_order_any_capacity() {
    let mut rng = Prng::new(4);
    for _ in 0..CASES {
        let workers = 1 + rng.next_below(6);
        let n = rng.next_below(400);
        let cap = 1 + rng.next_below(16);
        let pool = Pool::new(workers).unwrap();
        let (out, stats) =
            patterns::farm::farm_stream(&pool, 0..n, cap, |_, j| j * 7 + 1);
        assert_eq!(out, (0..n).map(|j| j * 7 + 1).collect::<Vec<_>>());
        assert_eq!(stats.jobs, n);
    }
}

#[test]
fn prop_pipeline_identity_composition() {
    let mut rng = Prng::new(5);
    for _ in 0..CASES {
        let n = rng.next_below(500);
        let cap = 1 + rng.next_below(8);
        let xs: Vec<u64> = (0..n as u64).collect();
        let out = patterns::pipeline::pipeline3(
            xs.clone(),
            cap,
            |x| x.wrapping_mul(3),
            |x| x.wrapping_add(11),
            |x| x,
        );
        let expect: Vec<u64> = xs.iter().map(|&x| x.wrapping_mul(3).wrapping_add(11)).collect();
        assert_eq!(out, expect, "n={n} cap={cap}");
    }
}

#[test]
fn prop_chunks_partition_any_input() {
    let mut rng = Prng::new(6);
    for _ in 0..200 {
        let len = rng.next_below(10_000);
        let grain = 1 + rng.next_below(1_000);
        let cs = patterns::chunks(len, grain);
        let mut next = 0usize;
        for c in &cs {
            assert_eq!(c.start, next);
            assert!(c.end - c.start <= grain);
            next = c.end;
        }
        assert_eq!(next, len);
    }
}
