//! Integration: the GCP coordinator — planner decisions feeding real
//! detections, batch server under load, reports feeding the simulator.

use canny_par::amdahl;
use canny_par::canny::CannyParams;
use canny_par::coordinator::batch::BatchJob;
use canny_par::coordinator::planner::Workload;
use canny_par::coordinator::{BatchServer, CpuTopology, Detector, Planner, RunReport};
use canny_par::image::synth::{generate, Scene};
use canny_par::profiler::UsageTrace;
use canny_par::simsched::simulate;

#[test]
fn planned_detection_end_to_end() {
    let topo = CpuTopology::i3_4cpu();
    let planner = Planner::new(topo);
    let work = Workload { image_w: 256, image_h: 192, batch: 1 };
    let plan = planner.plan(work, &CannyParams::default());
    let det = Detector::builder()
        .engine(plan.engine)
        .workers(plan.workers)
        .params(plan.params)
        .build()
        .unwrap();
    let img = generate(Scene::Shapes { seed: 20 }, work.image_w, work.image_h);
    let edges = det.detect_default(&img).unwrap();
    assert!(edges.count_edges() > 0);
    assert_eq!(det.n_workers(), 4);
}

#[test]
fn batch_server_streams_and_reports() {
    let det = Detector::builder().workers(4).build().unwrap();
    let jobs = (0..12).map(|k| BatchJob {
        id: k,
        image: generate(Scene::Shapes { seed: k as u64 }, 96, 96),
    });
    let report = BatchServer::new(&det).with_capacity(4).run(jobs, &CannyParams::default()).unwrap();
    assert_eq!(report.results.len(), 12);
    assert!(report.mpix_per_s() > 0.0);
    assert!(report.images_per_s() > 0.0);
    assert_eq!(report.pixels, 12 * 96 * 96);
}

#[test]
fn run_report_drives_simulator_with_sane_speedups() {
    // Real tiled run -> SimSpec -> simulated 1..8 core speedups must be
    // monotone non-decreasing (within tolerance) and Amdahl-bounded.
    let det = Detector::builder()
        .engine(canny_par::canny::Engine::TiledPatterns)
        .workers(2)
        .params(CannyParams { tile: 64, ..CannyParams::default() })
        .build()
        .unwrap();
    let img = generate(Scene::Shapes { seed: 33 }, 512, 384);
    let out = det.detect_full(&img, det.params()).unwrap();
    let report = RunReport::from_run("tiled", img.len(), &out.times, Some(&det.pool_stats()));
    let spec = report.to_sim_spec();
    assert!(spec.phases.iter().any(|p| !p.tasks_ns.is_empty()), "no parallel phase");

    let t1 = simulate(&spec, 1).makespan_ns as f64;
    let mut prev = 1.0;
    for cores in [2usize, 4, 8] {
        let s = t1 / simulate(&spec, cores).makespan_ns as f64;
        assert!(s >= prev * 0.98, "speedup regressed at {cores}: {s} < {prev}");
        // Amdahl bound from the spec's own serial fraction.
        let f = 1.0 - spec.serial_fraction();
        let bound = amdahl::speedup_symmetric(f, cores);
        assert!(s <= bound * 1.02, "cores={cores}: {s} > Amdahl bound {bound}");
        prev = s;
    }
}

#[test]
fn simulated_traces_show_paper_contrast() {
    // The F8-vs-F9 contrast: serial trace ~ 1/cores utilization,
    // parallel trace much higher.
    // A low-edge-density scene keeps the serial hysteresis negligible —
    // the regime the paper's figures show (front-dominated work).
    let det = Detector::builder()
        .engine(canny_par::canny::Engine::TiledPatterns)
        .workers(2)
        .params(CannyParams { tile: 64, ..CannyParams::default() })
        .build()
        .unwrap();
    let img = generate(Scene::Gradient, 768, 512);
    let tiled = det.detect_full(&img, det.params()).unwrap();
    let serial = canny_par::canny::CannyPipeline::serial().detect(&img, det.params()).unwrap();

    let spec_par = RunReport::from_run("p", img.len(), &tiled.times, None).to_sim_spec();
    let spec_ser = RunReport::from_run("s", img.len(), &serial.times, None).to_sim_spec();
    let cores = 4;
    let period = 200_000;
    let t_par = UsageTrace::from_sim(&simulate(&spec_par, cores), period, "opt");
    let t_ser = UsageTrace::from_sim(&simulate(&spec_ser, cores), period, "sub");
    assert!(
        t_ser.mean_total_pct() <= 100.0 / cores as f64 + 1.0,
        "serial trace too busy: {}",
        t_ser.mean_total_pct()
    );
    assert!(
        t_par.mean_total_pct() > t_ser.mean_total_pct() * 2.0,
        "parallel {} not >> serial {}",
        t_par.mean_total_pct(),
        t_ser.mean_total_pct()
    );
    // During the parallel phase all cores are saturated at some point.
    assert!(
        t_par.total_pct().iter().cloned().fold(0.0, f64::max) >= 100.0 - 1e-9,
        "parallel trace never reaches full utilization"
    );
}

#[test]
fn amdahl_fit_of_simulated_speedup_recovers_fraction() {
    let det = Detector::builder()
        .engine(canny_par::canny::Engine::TiledPatterns)
        .workers(2)
        .params(CannyParams { tile: 32, ..CannyParams::default() })
        .build()
        .unwrap();
    let img = generate(Scene::Checker { cell: 16 }, 384, 384);
    let out = det.detect_full(&img, det.params()).unwrap();
    let spec = RunReport::from_run("t", img.len(), &out.times, None).to_sim_spec();
    let true_f = 1.0 - spec.serial_fraction();
    let s4 = simulate(&spec, 1).makespan_ns as f64 / simulate(&spec, 4).makespan_ns as f64;
    let fitted = amdahl::fit_parallel_fraction(s4, 4);
    // Fit is approximate (scheduling gaps), but should be in the zone.
    assert!(
        (fitted - true_f).abs() < 0.15,
        "fitted f {fitted} vs actual {true_f} (s4 = {s4})"
    );
}

#[test]
fn topology_objects_used_by_planner() {
    for topo in CpuTopology::table1() {
        let planner = Planner::new(topo.clone());
        let plan = planner.plan(
            Workload { image_w: 1024, image_h: 768, batch: 1 },
            &CannyParams::default(),
        );
        assert_eq!(plan.workers, topo.logical_cpus);
    }
}
