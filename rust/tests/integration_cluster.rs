//! Integration: the multi-process cluster tier — real `cannyd worker`
//! child processes behind the front-door router, end to end through
//! `cluster::run_cluster`.
//!
//! Covers the four cluster guarantees: bit-identity with the
//! single-process path, survival of a worker kill mid-trace (restart +
//! requeue + alerts), digest-affine routing stability, and the merged
//! report schema.

use std::path::PathBuf;

use canny_par::cluster::proto::digest_string;
use canny_par::cluster::{
    run_cluster, ClusterOptions, RoutingRing, WorkerCore, WorkerFault, REQUIRED_CLUSTER_KEYS,
    REQUIRED_WORKER_KEYS, WORKER_EXE_ENV,
};
use canny_par::config::RunConfig;
use canny_par::image::synth::Scene;
use canny_par::service::{Request, RequestKind, Trace};
use canny_par::util::json::Json;

/// Point the supervisor at the freshly built `cannyd` binary (the test
/// process itself is the libtest harness, not `cannyd`, so respawning
/// `current_exe` would loop the test suite). `Once` so parallel tests
/// never race the env write against a `Command::spawn` env read.
fn use_test_binary() {
    static ONCE: std::sync::Once = std::sync::Once::new();
    ONCE.call_once(|| std::env::set_var(WORKER_EXE_ENV, env!("CARGO_BIN_EXE_cannyd")));
}

fn tmp_path(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("canny_cluster_itests");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(format!("{}_{name}", std::process::id()))
}

/// A fast deterministic config: serial engine (one thread per worker
/// process), small cache.
fn cluster_cfg() -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.set("engine", "serial").unwrap();
    cfg.set("workers", "2").unwrap();
    cfg.set("cache-mb", "8").unwrap();
    cfg.validate().unwrap();
    cfg
}

/// A mixed-kind trace over several distinct contents: full detections
/// plus front-only warms followed by re-threshold sweeps of the same
/// content (the pattern digest-affine routing exists for). Small frames
/// keep the suite fast.
fn mixed_trace(contents: u64) -> Trace {
    let mut requests = Vec::new();
    let mut id = 0u64;
    let mut push = |scene: Scene, kind: RequestKind| {
        requests.push(Request {
            id,
            arrival_ns: id * 50_000,
            scene,
            width: 96,
            height: 64,
            kind,
        });
        id += 1;
    };
    for seed in 0..contents {
        push(Scene::Shapes { seed }, RequestKind::Full);
        push(Scene::Shapes { seed }, RequestKind::FrontOnly);
        push(Scene::Shapes { seed }, RequestKind::ReThreshold { lo: 0.03, hi: 0.25 });
    }
    Trace { requests }
}

/// The single-process reference: the same requests through one
/// in-process `WorkerCore` (detector + cache), no sockets involved.
fn single_process_answers(cfg: &RunConfig, trace: &Trace) -> Vec<(u64, u64, String)> {
    let mut core = WorkerCore::from_config(cfg, 0).unwrap();
    trace
        .requests
        .iter()
        .map(|req| {
            let a = core.execute(req, None).unwrap();
            (req.id, a.edge_pixels, digest_string(&a.digest))
        })
        .collect()
}

#[test]
fn cluster_is_bit_identical_to_the_single_process_path() {
    use_test_binary();
    let cfg = cluster_cfg();
    let trace = mixed_trace(4);
    let opts = ClusterOptions::from_config(&cfg);
    let out = run_cluster("itest-identity", &trace, &opts).unwrap();

    assert_eq!(out.report.requests, trace.len() as u64);
    assert_eq!(out.report.completed, trace.len() as u64);
    assert_eq!(out.report.requeued, 0);
    assert_eq!(out.report.restarts, 0);
    assert_eq!(out.responses.len(), trace.len());

    let reference = single_process_answers(&cfg, &trace);
    for (resp, (id, edge_pixels, digest)) in out.responses.iter().zip(&reference) {
        assert_eq!(resp.id, *id);
        assert_eq!(
            resp.edge_pixels, *edge_pixels,
            "request {id}: cluster edge count diverged from the single-process path"
        );
        assert_eq!(
            &resp.digest, digest,
            "request {id}: cluster artifact digest diverged from the single-process path"
        );
    }
}

#[test]
fn cluster_survives_a_worker_kill_mid_trace() {
    use_test_binary();
    let cfg = cluster_cfg();
    let trace = mixed_trace(5);

    // Inject the crash on whichever slot owns the most requests, so the
    // death lands mid-queue rather than after the slot is already done.
    let ring = RoutingRing::new(2);
    let mut load = [0u64; 2];
    for req in &trace.requests {
        load[ring.route_request(req)] += 1;
    }
    let busy = if load[0] >= load[1] { 0 } else { 1 };

    let alert_log = tmp_path("kill_alerts.log");
    let mut opts = ClusterOptions::from_config(&cfg);
    opts.alert_log = alert_log.display().to_string();
    opts.fault = Some(WorkerFault { slot: busy, after: 1 });

    let out = run_cluster("itest-kill", &trace, &opts).unwrap();
    assert_eq!(
        out.report.completed,
        trace.len() as u64,
        "every request must complete despite the mid-trace worker death"
    );
    assert!(out.report.restarts >= 1, "the faulted worker must have been restarted");
    assert!(out.report.requeued >= 1, "the in-flight request must have been requeued");
    assert_eq!(
        out.report.alerts,
        2 * out.report.restarts,
        "each restart emits a stalled + recovered transition pair"
    );
    let alerts = std::fs::read_to_string(&alert_log).unwrap();
    let lines: Vec<&str> = alerts.lines().collect();
    assert_eq!(lines.len() as u64, out.report.alerts);
    assert!(lines
        .iter()
        .all(|l| l.starts_with("ALERT ") && l.contains(&format!("scope=cluster/worker{busy}"))));

    // Bit-identity holds across the restart: the respawned worker
    // recomputes (or re-warms) exactly what its predecessor would have.
    let reference = single_process_answers(&cfg, &trace);
    for (resp, (id, edge_pixels, digest)) in out.responses.iter().zip(&reference) {
        assert_eq!(resp.id, *id);
        assert_eq!(resp.edge_pixels, *edge_pixels, "request {id} diverged across the restart");
        assert_eq!(&resp.digest, digest, "request {id} digest diverged across the restart");
    }
    std::fs::remove_file(&alert_log).ok();
}

#[test]
fn routing_is_stable_and_digest_affine() {
    use_test_binary();
    let cfg = cluster_cfg();
    let trace = mixed_trace(6);
    let opts = ClusterOptions::from_config(&cfg);
    let out = run_cluster("itest-routing", &trace, &opts).unwrap();

    // Every response came from the slot the ring predicts — routing is
    // a pure function of content, reproducible outside the cluster.
    let ring = RoutingRing::new(opts.workers);
    for resp in &out.responses {
        let req = &trace.requests[resp.id as usize];
        assert_eq!(
            resp.slot,
            ring.route_request(req),
            "request {} was served off its ring slot",
            resp.id
        );
    }
    // Kind-blind affinity: all three kinds about one content share a
    // slot, so the re-threshold found the front its own worker warmed.
    for chunk in out.responses.chunks(3) {
        assert_eq!(chunk[0].slot, chunk[1].slot);
        assert_eq!(chunk[1].slot, chunk[2].slot);
    }
    // The warm actually paid off somewhere: with 6 contents over 2
    // workers, at least one per-worker cache section must show hits.
    let cache_hits: f64 = out
        .report
        .per_worker
        .iter()
        .map(|w| match w.get("cache").and_then(|c| c.get("hits")) {
            Some(Json::Num(n)) => *n,
            _ => 0.0,
        })
        .sum();
    assert!(cache_hits >= 1.0, "no worker cache hits — digest affinity is not paying off");
}

#[test]
fn merged_report_has_the_documented_schema() {
    use_test_binary();
    let cfg = cluster_cfg();
    let trace = mixed_trace(3);
    let opts = ClusterOptions::from_config(&cfg);
    let out = run_cluster("itest-schema", &trace, &opts).unwrap();

    let parsed = Json::parse(&out.report.to_json_string()).unwrap();
    for key in REQUIRED_CLUSTER_KEYS {
        assert!(parsed.get(key).is_some(), "cluster report is missing `{key}`");
    }
    assert!(matches!(parsed.get("tier"), Some(Json::Str(t)) if t == "cluster"));
    assert!(matches!(parsed.get("workers"), Some(Json::Num(n)) if *n == 2.0));
    for sub in ["n", "p50", "p95", "p99", "max", "mean"] {
        assert!(
            parsed.get("latency_ns").and_then(|l| l.get(sub)).is_some(),
            "latency_ns is missing `{sub}`"
        );
    }
    let per_worker = match parsed.get("per_worker") {
        Some(Json::Arr(a)) => a,
        other => panic!("per_worker must be an array, got {other:?}"),
    };
    assert_eq!(per_worker.len(), 2);
    let mut served_total = 0.0;
    for (slot, body) in per_worker.iter().enumerate() {
        for key in REQUIRED_WORKER_KEYS {
            assert!(body.get(key).is_some(), "worker {slot} report is missing `{key}`");
        }
        assert!(matches!(body.get("worker"), Some(Json::Num(n)) if *n == slot as f64));
        if let Some(Json::Num(n)) = body.get("served") {
            served_total += *n;
        }
    }
    assert_eq!(served_total, trace.len() as f64, "per-worker served counts must sum to the trace");
}
