//! Integration: the full detector across engines, scenes and parameter
//! ranges — the "deterministic output" claim end to end.

use canny_par::canny::{CannyParams, CannyPipeline, Engine};
use canny_par::coordinator::Detector;
use canny_par::image::synth::{generate, Scene};
use canny_par::image::ImageF32;
use canny_par::metrics;
use canny_par::scheduler::Pool;

fn scenes() -> Vec<(&'static str, ImageF32)> {
    vec![
        ("shapes", generate(Scene::Shapes { seed: 5 }, 200, 150)),
        ("remote", generate(Scene::RemoteSensing { seed: 5, noise: 0.05 }, 160, 120)),
        ("text", generate(Scene::Text { seed: 5 }, 180, 140)),
        ("checker", generate(Scene::Checker { cell: 10 }, 128, 128)),
        ("gradient", generate(Scene::Gradient, 100, 100)),
    ]
}

#[test]
fn all_native_engines_agree_on_all_scenes() {
    let pool = Pool::new(4).unwrap();
    let params = CannyParams::default();
    for (name, img) in scenes() {
        let serial = CannyPipeline::serial().detect(&img, &params).unwrap();
        let patterns = CannyPipeline::patterns(&pool).detect(&img, &params).unwrap();
        let tiled = CannyPipeline::tiled(&pool).detect(&img, &params).unwrap();
        assert_eq!(serial.edges.diff_count(&patterns.edges), 0, "{name}: patterns");
        assert_eq!(serial.edges.diff_count(&tiled.edges), 0, "{name}: tiled");
        assert_eq!(serial.class_map, patterns.class_map, "{name}: class map");
    }
}

#[test]
fn detection_repeatable_across_runs_and_pools() {
    let img = generate(Scene::Shapes { seed: 42 }, 300, 200);
    let params = CannyParams::default();
    let mut reference = None;
    for workers in [1usize, 2, 3, 8] {
        let pool = Pool::new(workers).unwrap();
        for _ in 0..3 {
            let out = CannyPipeline::patterns(&pool).detect(&img, &params).unwrap();
            match &reference {
                None => reference = Some(out.edges.clone()),
                Some(r) => assert_eq!(r.diff_count(&out.edges), 0, "workers={workers}"),
            }
        }
    }
}

#[test]
fn gradient_scene_has_no_false_edges() {
    // A smooth ramp must produce (almost) no edges at sane thresholds.
    let img = generate(Scene::Gradient, 128, 128);
    let out = CannyPipeline::serial().detect(&img, &CannyParams::default()).unwrap();
    assert!(
        out.edges.edge_density() < 0.001,
        "false-positive density {}",
        out.edges.edge_density()
    );
}

#[test]
fn checker_edges_localized_against_truth() {
    // Ground truth for a checkerboard: cell boundaries.
    let cell = 16usize;
    let n = 128usize;
    let img = generate(Scene::Checker { cell }, n, n);
    let out = CannyPipeline::serial().detect(&img, &CannyParams::default()).unwrap();
    let mut truth = vec![0u8; n * n];
    for y in 0..n {
        for x in 0..n {
            // Boundary between cells (either side of the seam).
            let on_x = x % cell == 0 || x % cell == cell - 1;
            let on_y = y % cell == 0 || y % cell == cell - 1;
            if (on_x && x > 0 && x < n - 1) || (on_y && y > 0 && y < n - 1) {
                truth[y * n + x] = 255;
            }
        }
    }
    let truth = canny_par::image::EdgeMap::new(n, n, truth).unwrap();
    let (precision, recall) = metrics::precision_recall(&out.edges, &truth, 1);
    assert!(precision > 0.95, "precision {precision}");
    assert!(recall > 0.55, "recall {recall}");
    let fom = metrics::pratt_fom(&out.edges, &truth);
    assert!(fom > 0.5, "FOM {fom}");
}

#[test]
fn thresholds_move_edge_counts_monotonically() {
    let img = generate(Scene::Shapes { seed: 9 }, 150, 150);
    let pipeline = CannyPipeline::serial();
    let mut last = usize::MAX;
    for hi in [0.08f32, 0.15, 0.3, 0.6] {
        let params = CannyParams { lo: hi / 3.0, hi, ..CannyParams::default() };
        let out = pipeline.detect(&img, &params).unwrap();
        let n = out.edges.count_edges();
        assert!(n <= last, "edges must not increase with hi (hi={hi}: {n} > {last})");
        last = n;
    }
}

#[test]
fn noise_robustness_via_gaussian_stage() {
    // Same scene with/without point noise: edge maps stay similar
    // (the paper's remote-sensing enhancement claim, [7]).
    let clean = generate(Scene::RemoteSensing { seed: 3, noise: 0.0 }, 128, 128);
    let noisy = generate(Scene::RemoteSensing { seed: 3, noise: 0.06 }, 128, 128);
    let params = CannyParams::default();
    let a = CannyPipeline::serial().detect(&clean, &params).unwrap();
    let b = CannyPipeline::serial().detect(&noisy, &params).unwrap();
    let (precision, recall) = metrics::precision_recall(&b.edges, &a.edges, 1);
    assert!(precision > 0.55, "precision {precision}");
    assert!(recall > 0.5, "recall {recall}");
}

#[test]
fn detector_facade_matches_pipeline() {
    let img = generate(Scene::Shapes { seed: 1 }, 100, 80);
    let det = Detector::builder().engine(Engine::TiledPatterns).workers(2).build().unwrap();
    let via_detector = det.detect_default(&img).unwrap();
    let serial = CannyPipeline::serial().detect(&img, det.params()).unwrap();
    assert_eq!(via_detector.diff_count(&serial.edges), 0);
}

#[test]
fn stage_times_are_consistent() {
    let img = generate(Scene::Shapes { seed: 2 }, 256, 256);
    let out = CannyPipeline::serial().detect(&img, &CannyParams::default()).unwrap();
    let t = &out.times;
    assert!(t.front_ns >= t.gaussian_ns + t.sobel_ns);
    assert!(t.total_ns >= t.front_ns + t.hysteresis_ns);
}

#[test]
fn extreme_thresholds_behave() {
    let img = generate(Scene::Checker { cell: 8 }, 64, 64);
    // hi = 0: everything >= 0 is strong -> all pixels edges.
    let all = CannyPipeline::serial()
        .detect(&img, &CannyParams { lo: 0.0, hi: 0.0, ..CannyParams::default() })
        .unwrap();
    assert!(all.edges.edge_density() > 0.2);
    // hi huge: nothing strong -> no edges at all.
    let none = CannyPipeline::serial()
        .detect(&img, &CannyParams { lo: 50.0, hi: 100.0, ..CannyParams::default() })
        .unwrap();
    assert_eq!(none.edges.count_edges(), 0);
}
