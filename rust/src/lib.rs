//! # canny-par — High-Performance Parallel Canny Edge Detector
//!
//! Production reproduction of *"High Performance Canny Edge Detector using
//! Parallel Patterns for Scalability on Modern Multicore Processors"*
//! (CS.DC 2017) as a three-layer Rust + JAX/Pallas stack:
//!
//! * **L3 (this crate)** — the paper's coordination contribution: a
//!   Cilk-style work-stealing scheduler ([`scheduler`]), the structured
//!   parallel-pattern catalogue ([`patterns`]), the GCP
//!   shell/kernel/core coordinator ([`coordinator`]), a sampling CPU
//!   profiler ([`profiler`]) and a deterministic multicore simulator
//!   ([`simsched`]) for the paper's 4/8-CPU topologies.
//! * **L3 serving tier** ([`service`]) — the multi-client front door:
//!   a bounded admission queue with backpressure, same-shape request
//!   batching under a max-delay window, N sharded detector lanes, and
//!   p50/p95/p99 SLO reporting — under **two clocks** (`cannyd serve
//!   --clock virtual|wall`): a deterministic virtual-time replay whose
//!   service-cost model can be calibrated end-to-end *and per stage*
//!   from measured [`canny::StageRecord`]s ([`service::calibrate`]),
//!   and a wall-clock mode running real lane threads on monotonic time
//!   that the calibrated predictions are validated against. Requests
//!   carry a kind (full | front-only | re-threshold), with re-threshold
//!   served from a per-lane suppressed-magnitude LRU.
//! * **L3 stream tier** ([`stream`]) — real-time frame streams: a
//!   [`stream::FrameSource`] feeds a pipeline-parallel decode → front →
//!   finish executor with a bounded in-flight window, **temporal
//!   delta-gating** (clean tiles reuse the previous frame's cached
//!   suppressed-magnitude artifact — exact at the default threshold 0),
//!   and a real-time frame budget that drops or degrades late frames
//!   (`cannyd stream`).
//! * **L3 cache tier** ([`cache`]) — a process-wide, content-addressed,
//!   sharded artifact cache under a global **byte budget** with
//!   cost-aware admission, shared by serving lanes and stream
//!   executors alike: a front computed anywhere (a `front-only`
//!   request, a decoded frame) serves re-thresholds and duplicate
//!   frames everywhere, bit-exactly (`--cache-mb`, `--cache-shards`,
//!   `--cache-admit-ns-per-byte`, `--stream-cache`).
//! * **L3 cluster tier** ([`cluster`]) — multi-process `cannyd`: a
//!   front-door router spawns and supervises N worker processes over
//!   loopback TCP (`cannyd cluster --workers N`), routing every request
//!   to the worker whose consistent-hash range owns its content digest
//!   — so the per-worker artifact caches behave like one sharded
//!   cluster cache — with heartbeat death detection, automatic restart
//!   + requeue, health-transition alerts (`--alert-log`), and a merged
//!   cluster report carrying per-worker serve/cache/telemetry
//!   sections. Responses are byte-identical to the single-process
//!   serve path (`--cluster-port`, `--worker-heartbeat-ms`).
//! * **L3 ops plane** ([`obs`]) — live telemetry for both tiers: a
//!   process-wide registry of atomic counters/gauges/histograms, a
//!   snapshot engine emitting periodic machine-readable JSONL
//!   (`--telemetry-log file.jsonl --telemetry-interval-ms N`;
//!   byte-identical across deterministic virtual replays), rolling SLO
//!   windows with a met/missed/no-data transition timeline
//!   (`--slo-window`), per-lane `healthy | degraded | stalled` health
//!   states, and explicit overload policies that shed or degrade new
//!   arrivals while the rolling SLO is missed (`--overload-policy
//!   none | reject-new | degrade-to-front-only`). On top of the
//!   counters sits **distributed tracing** ([`obs::trace`]): every
//!   admitted request carries a deterministic trace id through queue
//!   wait, batch coalesce, cache consult and per-stage execution — and
//!   across the cluster wire, so worker spans stitch under the front
//!   door's parent — exported as span JSONL or Chrome trace-event JSON
//!   (`--trace-log file.json`). The current merged telemetry snapshot
//!   is also served live over loopback TCP (`--obs-port`): connect,
//!   read one JSON line, done. On top of the *recording* plane sits an
//!   **analysis** plane ([`obs::sample`], [`obs::anomaly`],
//!   [`obs::analyze`]): tail-based trace sampling decides keep/drop
//!   *after* each request completes (`--trace-sample all | slow:<ms> |
//!   errors | head:<n>`), histogram buckets cite their worst kept
//!   trace as an exemplar in the telemetry stream, EWMA anomaly
//!   detectors raise `ALERT … scope=anomaly:…` lines naming that
//!   exemplar (`--anomaly-sigma`), and `cannyd analyze` aggregates any
//!   recorded file offline — span p50/p99 per kind, per-trace critical
//!   paths, deltas against a baseline (`--against`).
//! * **L2/L1 (python/, build-time only)** — the Canny front-end
//!   (Gaussian → Sobel → NMS → double threshold) as JAX + Pallas
//!   kernels, AOT-lowered to HLO text consumed by [`runtime`] through
//!   the XLA PJRT CPU client. Python is never on the request path.
//!
//! The native Rust stages in [`canny`] mirror the Pallas kernels
//! bit-for-bit-in-intent (same constants, same tie rules), so every
//! execution engine — serial, pattern-parallel native, pattern-parallel
//! XLA — produces the same edge map (the paper's "deterministic output"
//! goal).
//!
//! ## Quickstart
//!
//! ```no_run
//! use canny_par::canny::{CannyParams, Engine};
//! use canny_par::coordinator::Detector;
//! use canny_par::image::synth::{Scene, generate};
//!
//! let img = generate(Scene::Shapes { seed: 7 }, 512, 512);
//! let det = Detector::builder().workers(4).engine(Engine::Patterns).build().unwrap();
//! let edges = det.detect(&img, &CannyParams::default()).unwrap();
//! println!("{} edge pixels", edges.count_edges());
//! ```
//!
//! Partial pipelines via the **stage graph** ([`canny::plan`]): stop
//! after any stage, keep its typed artifact, and resume later without
//! recomputing the front — with uniform per-stage records
//! ([`canny::StageRecord`]) for accounting:
//!
//! ```no_run
//! use canny_par::canny::{CannyParams, StageKind};
//! use canny_par::coordinator::Detector;
//! use canny_par::image::synth::{Scene, generate};
//!
//! let det = Detector::builder().workers(2).build().unwrap();
//! let img = generate(Scene::Shapes { seed: 7 }, 256, 256);
//! let params = CannyParams::default();
//! // Run the front only (Gaussian -> Sobel -> NMS) and keep the
//! // suppressed-magnitude map.
//! let front = det.plan().stop_after(StageKind::Nms);
//! let mut out = det.run_plan(&front, Some(&img), &params).unwrap();
//! let nm = out.take_suppressed().unwrap();
//! // Re-threshold with new lo/hi without re-running the front.
//! let re = det.plan().from_suppressed(nm);
//! let tighter = CannyParams { lo: 0.02, hi: 0.25, ..params };
//! let out2 = det.run_plan(&re, None, &tighter).unwrap();
//! println!("{} edge pixels", out2.edges().unwrap().count_edges());
//! for r in &out2.records {
//!     println!("{}: {} ns over {} tasks", r.span_name(), r.wall_ns, r.tasks);
//! }
//! ```
//!
//! Sharing work through the **artifact cache** ([`cache`]): offer a
//! computed front once, then serve bit-identical re-thresholds of the
//! same content from the tier — across lanes, streams, or your own
//! embedding:
//!
//! ```no_run
//! use canny_par::cache::{ArtifactCache, ArtifactKey, CacheConfig, CacheTier};
//! use canny_par::canny::{Artifact, CannyParams, StageKind};
//! use canny_par::coordinator::Detector;
//! use canny_par::image::synth::{Scene, generate};
//!
//! let det = Detector::builder().workers(2).build().unwrap();
//! let cache = ArtifactCache::new(CacheConfig::default());
//! let img = generate(Scene::Shapes { seed: 7 }, 256, 256);
//! // Warm: run the front once and offer the suppressed map.
//! let front = det.plan().stop_after(StageKind::Nms);
//! let mut out = det.run_plan(&front, Some(&img), det.params()).unwrap();
//! let nm = out.take_suppressed().unwrap();
//! cache.offer(ArtifactKey::suppressed(&img), Artifact::Suppressed(nm),
//!             out.total_ns, CacheTier::Serve);
//! // Hit: any consumer with the same bytes skips the front entirely.
//! if let Some(Artifact::Suppressed(nm)) =
//!     cache.get(&ArtifactKey::suppressed(&img), CacheTier::Serve)
//! {
//!     let re = det.plan().from_suppressed(nm);
//!     let tighter = CannyParams { lo: 0.02, hi: 0.25, ..CannyParams::default() };
//!     let out = det.run_plan(&re, None, &tighter).unwrap();
//!     println!("{} edge pixels, {:?}", out.edges().unwrap().count_edges(),
//!              cache.snapshot());
//! }
//! ```
//!
//! Serving a request stream (the CLI equivalent is
//! `cannyd serve --synthetic 200 --lanes 2`), with the ops plane
//! writing a live telemetry stream and shedding under a missed SLO
//! (`cannyd serve --synthetic 200 --telemetry-log t.jsonl
//! --telemetry-interval-ms 5 --overload-policy reject-new`):
//!
//! ```no_run
//! use canny_par::config::RunConfig;
//! use canny_par::service::{serve, ServeOptions, Trace};
//!
//! let mut cfg = RunConfig::default();
//! cfg.set("telemetry-log", "/tmp/telemetry.jsonl").unwrap();
//! cfg.set("telemetry-interval-ms", "5").unwrap();
//! cfg.set("overload-policy", "reject-new").unwrap();
//! let trace = Trace::synthetic(200, cfg.seed, cfg.arrival_rate_hz);
//! let report = serve("quickstart", &trace, &ServeOptions::from_config(&cfg)).unwrap();
//! // The report's `overload` and `slo.window` sections carry the shed
//! // totals and the rolling-window status timeline; the JSONL file
//! // holds one snapshot per tick (byte-identical across virtual
//! // replays of the same trace).
//! println!("{}", report.to_json_string());
//! ```
//!
//! **Tracing** the same run ([`obs::trace`]): name the export file and
//! every admitted request becomes a span tree — root, queue wait, batch
//! coalesce, cache consult, one span per executed stage. A `.jsonl`
//! path selects span JSONL (one span object per line); a `.json` path
//! selects Chrome trace-event JSON — load it in `chrome://tracing` or
//! Perfetto, lanes as rows. Under `--clock virtual` two replays of the
//! same trace write byte-identical files:
//!
//! ```no_run
//! use canny_par::config::RunConfig;
//! use canny_par::service::{serve, ServeOptions, Trace};
//!
//! let mut cfg = RunConfig::default();
//! cfg.set("trace-log", "/tmp/spans.jsonl").unwrap();
//! let trace = Trace::synthetic(200, cfg.seed, cfg.arrival_rate_hz);
//! serve("traced", &trace, &ServeOptions::from_config(&cfg)).unwrap();
//! // /tmp/spans.jsonl now holds one span per line, grouped by a
//! // deterministic 24-hex trace id (content digest + admission seq).
//! ```
//!
//! The CLI equivalents are `cannyd serve --synthetic 200 --trace-log
//! spans.jsonl` and, for the multi-process tier, `cannyd cluster
//! --workers 2 --trace-log trace.json` — there the worker-side spans
//! travel back over the wire and stitch under the front door's
//! route/dispatch/wire spans, one trace per request end-to-end. Adding
//! `--obs-port P` (serve, stream or cluster) serves the newest merged
//! telemetry snapshot line to any loopback TCP client — connect, read
//! one JSON line (plus the newest `ALERT` line once one has fired),
//! connection closes.
//!
//! **Analyzing** a recorded run ([`obs::sample`], [`obs::analyze`]):
//! tail-based sampling keeps only the traces worth reading — the
//! verdict uses the request's *observed* latency, decided after it
//! completes — and the analyzer turns the retained file into per-span
//! aggregates and critical paths. Each exported histogram exemplar
//! cites a kept trace, so an anomaly alert (`--anomaly-sigma`) always
//! points at a trace that is actually in the file:
//!
//! ```no_run
//! use std::path::Path;
//! use canny_par::config::RunConfig;
//! use canny_par::obs::analyze;
//! use canny_par::service::{serve, ServeOptions, Trace};
//!
//! let mut cfg = RunConfig::default();
//! cfg.set("trace-log", "/tmp/slow.jsonl").unwrap();
//! cfg.set("trace-sample", "slow:2").unwrap(); // keep traces > 2 ms
//! cfg.set("anomaly-sigma", "3").unwrap();     // alert at 3 sigma
//! cfg.set("alert-log", "stderr").unwrap();
//! let trace = Trace::synthetic(200, cfg.seed, cfg.arrival_rate_hz);
//! serve("sampled", &trace, &ServeOptions::from_config(&cfg)).unwrap();
//! // Aggregate what was kept: count/p50/p99 per span kind, critical
//! // paths, optionally deltas against a baseline file.
//! let report = analyze(Path::new("/tmp/slow.jsonl"), None).unwrap();
//! println!("{}", report.dump());
//! ```
//!
//! The CLI equivalent is `cannyd serve --synthetic 200 --trace-log
//! slow.jsonl --trace-sample slow:2 --anomaly-sigma 3 --alert-log
//! stderr` followed by `cannyd analyze slow.jsonl [--against
//! baseline]` — bench baseline docs (`BENCH_*.json`) analyze too.
//!
//! Spreading the same trace over worker **processes** ([`cluster`]) —
//! the CLI equivalent is `cannyd cluster --workers 2 --synthetic 40`;
//! responses are bit-identical to the in-process serve above, and the
//! merged report carries one serve/cache/telemetry section per worker:
//!
//! ```no_run
//! use canny_par::cluster::{run_cluster, ClusterOptions};
//! use canny_par::config::RunConfig;
//! use canny_par::service::Trace;
//!
//! let mut cfg = RunConfig::default();
//! cfg.set("workers", "2").unwrap();       // processes, at this layer
//! cfg.set("alert-log", "stderr").unwrap(); // restart alerts, if any
//! let trace = Trace::synthetic(40, cfg.seed, cfg.arrival_rate_hz);
//! let out = run_cluster("quickstart", &trace, &ClusterOptions::from_config(&cfg)).unwrap();
//! assert_eq!(out.report.completed, 40);
//! println!("{}", out.report.to_json_string());
//! ```
//!
//! Processing a **frame stream** ([`stream`]) with temporal
//! delta-gating — clean tiles reuse the previous frame's cached
//! suppressed-magnitude artifact, dirty tiles recompute, and the
//! decode → front → finish stages run pipeline-parallel (the CLI
//! equivalent is `cannyd stream --synthetic-frames 32`):
//!
//! ```no_run
//! use canny_par::config::RunConfig;
//! use canny_par::coordinator::Detector;
//! use canny_par::stream::{run_stream, FrameSource, StreamOptions};
//!
//! let cfg = RunConfig::default();
//! let det = Detector::from_config(&cfg).unwrap();
//! let source = FrameSource::synthetic(cfg.seed, 32, 512, 512);
//! let out = run_stream("quickstart", &source, &det, &StreamOptions::from_config(&cfg))
//!     .unwrap();
//! println!(
//!     "{:.1} fps, gate hit-rate {:.0}%",
//!     out.report.fps(),
//!     100.0 * out.report.gate.hit_rate()
//! );
//! println!("{}", out.report.to_json_string());
//! ```
//!
//! ## Soundness
//!
//! Every scaling claim above rides on hand-rolled concurrency — the
//! disjoint-write [`util::shared_slice::SharedSlice`], the
//! work-stealing [`scheduler::Pool`], wall-clock serve lanes, the
//! sharded cache — so the invariants that keep it sound are enforced
//! mechanically, not by convention:
//!
//! * **`unsafe` is audited.** The crate denies `unsafe_op_in_unsafe_fn`
//!   (every unsafe operation sits in an explicit `unsafe {}` block, even
//!   inside `unsafe fn`), and the `pallas-lint` workspace tool
//!   (`tools/pallas-lint`, a gating CI job) requires every `unsafe`
//!   block/impl to carry an adjacent `// SAFETY:` justification and
//!   every `unsafe fn` a `# Safety` doc section.
//! * **Virtual-clock purity.** `Instant::now` / `SystemTime` are
//!   lint-forbidden outside `service/clock.rs`, `util/timer.rs` and
//!   `obs/snapshot.rs`; everything else takes time through injected
//!   clocks, which is what makes `--clock virtual` replays (and their
//!   telemetry streams) byte-identical.
//! * **Schema and flag parity.** The JSON keys the report/snapshot
//!   builders emit must match the schema blocks in the [`obs`],
//!   [`service`] and [`stream`] module docs, and the `cannyd` HELP text
//!   must match [`config::RunConfig::KEYS`] — both directions linted.
//! * **Lock discipline.** The lint rejects holding one mutex guard
//!   while locking another in `cache/shard.rs` / `service/server.rs`,
//!   and non-gating nightly CI runs ThreadSanitizer over the wall-clock
//!   integration tests plus Miri over the `SharedSlice` and pool unit
//!   tests. See `tools/pallas-lint/README.md` for running all of it
//!   locally.

#![deny(unsafe_op_in_unsafe_fn)]
#![warn(missing_debug_implementations)]

pub mod amdahl;
pub mod bench;
pub mod cache;
pub mod canny;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod error;
pub mod image;
pub mod metrics;
pub mod obs;
pub mod patterns;
pub mod profiler;
pub mod runtime;
pub mod scheduler;
pub mod service;
pub mod simsched;
pub mod stream;
pub mod util;

pub use error::{Error, Result};
