//! `cannyd` — the canny-par launcher.
//!
//! Subcommands:
//!   run        --input x.pgm --output edges.pgm [--engine …] [--workers n]
//!   gen        --scene shapes:7 --size 512x512 --output img.pgm
//!   batch      --count 16 --size 512x512 [--scene …]   (farm throughput)
//!   serve      --synthetic 200 | --requests trace.json   (serving tier;
//!              --clock virtual|wall, --calibration file.json|probe,
//!              --overload-policy none|reject-new|degrade-to-front-only)
//!   stream     --synthetic-frames 32 | --source dir:frames/   (frame-stream
//!              tier; --inflight, --delta-gate, --frame-budget-ms,
//!              --drop-policy)
//!   cluster    --workers 2 --synthetic 200   (multi-process front door:
//!              spawns `cannyd worker` children, digest-affine routing,
//!              restart-on-death, merged JSON cluster report)
//!   worker     (internal: spawned by `cluster`; --worker-id N
//!              --cluster-port P)
//!
//! Both tiers take `--telemetry-log file.jsonl --telemetry-interval-ms N
//! --slo-window N` (the ops plane; see the `obs` module docs), plus
//! `--trace-log FILE` (serve + cluster: per-request distributed trace,
//! span JSONL or Chrome trace-event JSON by extension) and
//! `--obs-port P` (live snapshot line over loopback TCP).
//!   calibrate  [--output calib.json]   (probe the service-cost model)
//!   profile    [--sim-cpus 4|8] [--engine serial|patterns]   (figures)
//!   analyze    trace.jsonl | telemetry.jsonl | BENCH_*.json
//!              [--against BASELINE]   (offline analytics over recorded
//!              files: per-span/series aggregates, critical paths,
//!              baseline deltas — one JSON report on stdout)
//!   info       (topology, artifacts, resolved config)
//!
//! Global flags are config keys (`--engine`, `--workers`, `--lo`, …),
//! see `config::RunConfig`; `--config file.conf` loads a file first.
//! Unknown flags, stray positionals and unknown subcommands are
//! rejected with an error rather than silently ignored.

use std::path::{Path, PathBuf};
use std::process::ExitCode;

use canny_par::canny::{Engine, StageKind};
use canny_par::cluster::{run_cluster, run_worker, ClusterOptions};
use canny_par::config::RunConfig;
use canny_par::service::clock::ClockMode;
use canny_par::service::install_sigint_drain;
use canny_par::coordinator::{topology, BatchServer, Detector, Planner, RunReport};
use canny_par::coordinator::batch::BatchJob;
use canny_par::coordinator::planner::Workload;
use canny_par::image::synth::{generate, Scene};
use canny_par::image::{pgm, ImageF32};
use canny_par::profiler::UsageTrace;
use canny_par::runtime::Manifest;
use canny_par::service::calibrate::{DEFAULT_PROBE_SHAPES, PROBE_REPEATS};
use canny_par::service::{calibrate_for, serve, Calibration, ServeOptions, Shape, Trace};
use canny_par::simsched::simulate;
use canny_par::stream::{run_stream, FrameSource, StreamOptions};
use canny_par::util::timer::human_ns;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match run(args) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("cannyd: error: {e}");
            ExitCode::FAILURE
        }
    }
}

/// Every subcommand (also the source of the command-flag union below).
const COMMANDS: &[&str] = &[
    "run", "gen", "batch", "serve", "stream", "cluster", "worker", "calibrate", "profile",
    "analyze", "info", "help",
];

/// Command-level flags (not config keys) each subcommand accepts.
fn allowed_extras(cmd: &str) -> &'static [&'static str] {
    match cmd {
        "run" => &["config", "input", "output", "scene", "size", "stop-after", "emit"],
        "gen" => &["config", "scene", "size", "output"],
        "batch" => &["config", "count", "size", "scene"],
        "serve" => &["config", "requests", "synthetic", "calibration"],
        "stream" => &["config", "source", "synthetic-frames", "size"],
        "cluster" => &["config", "requests", "synthetic"],
        "worker" => &["config", "worker-id"],
        "calibrate" => &["config", "output"],
        "profile" => &["config", "figure"],
        "analyze" => &["config", "against"],
        _ => &["config"],
    }
}

/// Is `k` a command-level flag for *some* subcommand? (Which commands
/// accept it is checked later, once the subcommand is known.)
fn is_extra_key(k: &str) -> bool {
    COMMANDS.iter().any(|c| allowed_extras(c).contains(&k))
}

fn run(args: Vec<String>) -> anyhow::Result<()> {
    if args.iter().any(|a| a == "--help" || a == "-h") {
        print!("{}", HELP);
        return Ok(());
    }
    // Split args into command-level flags (`extra`: --input, --requests,
    // …), config flags (`filtered`, fed to RunConfig::apply_cli) and
    // positionals. Anything that is neither is an error — flags are
    // never silently ignored.
    let mut extra: Vec<(String, String)> = Vec::new();
    let mut filtered: Vec<String> = Vec::new();
    let mut i = 0;
    while i < args.len() {
        let a = args[i].clone();
        if let Some(key) = a.strip_prefix("--") {
            let (k, inline_v) = match key.split_once('=') {
                Some((k, v)) => (k.to_string(), Some(v.to_string())),
                None => (key.to_string(), None),
            };
            if is_extra_key(&k) {
                let v = match inline_v {
                    Some(v) => v,
                    None => {
                        i += 1;
                        args.get(i)
                            .cloned()
                            .ok_or_else(|| anyhow::anyhow!("--{k} needs a value"))?
                    }
                };
                extra.push((k, v));
            } else if RunConfig::is_known_key(&k) {
                // Keep the flag (and its value token, so a value like
                // `-0.5` is never mistaken for a flag) for apply_cli.
                filtered.push(a.clone());
                if inline_v.is_none() && !RunConfig::is_flag_key(&k) {
                    i += 1;
                    let v = args
                        .get(i)
                        .cloned()
                        .ok_or_else(|| anyhow::anyhow!("--{k} needs a value"))?;
                    filtered.push(v);
                }
            } else {
                anyhow::bail!("unknown flag `--{k}` (run `cannyd help` for the flag list)");
            }
        } else if a.starts_with('-') && a.len() > 1 {
            anyhow::bail!("unknown flag `{a}` (flags are spelled `--key`)");
        } else {
            filtered.push(a);
        }
        i += 1;
    }
    let get = |k: &str| extra.iter().rev().find(|(ek, _)| ek == k).map(|(_, v)| v.clone());

    let mut cfg = RunConfig::default();
    if let Some(path) = get("config") {
        cfg.load_file(Path::new(&path))?;
    }
    let positional = cfg.apply_cli(&filtered)?;
    cfg.validate()?;
    let cmd = positional.first().map(|s| s.as_str()).unwrap_or("help");
    // `analyze` takes one positional operand (the recorded file);
    // every other command takes none.
    let stray = if cmd == "analyze" { positional.get(2) } else { positional.get(1) };
    if let Some(stray) = stray {
        anyhow::bail!("unexpected argument `{stray}` after `{cmd}`");
    }
    for (k, _) in &extra {
        if !allowed_extras(cmd).contains(&k.as_str()) {
            anyhow::bail!("flag --{k} is not valid for `{cmd}` (run `cannyd help`)");
        }
    }

    match cmd {
        "run" => cmd_run(
            &cfg,
            get("input"),
            get("output"),
            get("scene"),
            get("size"),
            get("stop-after"),
            get("emit"),
        ),
        "gen" => cmd_gen(&cfg, get("scene"), get("size"), get("output")),
        "batch" => cmd_batch(&cfg, get("count"), get("size"), get("scene")),
        "serve" => cmd_serve(&cfg, get("requests"), get("synthetic"), get("calibration")),
        "stream" => cmd_stream(&cfg, get("source"), get("synthetic-frames"), get("size")),
        "cluster" => cmd_cluster(&cfg, get("requests"), get("synthetic")),
        "worker" => cmd_worker(&cfg, get("worker-id")),
        "calibrate" => cmd_calibrate(&cfg, get("output")),
        "profile" => cmd_profile(&cfg, get("figure")),
        "analyze" => cmd_analyze(positional.get(1), get("against")),
        "info" => cmd_info(&cfg),
        "help" => {
            print!("{}", HELP);
            Ok(())
        }
        other => anyhow::bail!("unknown command `{other}` (run `cannyd help`)"),
    }
}

const HELP: &str = "\
cannyd — high-performance parallel Canny edge detector (CS.DC 2017 repro)

USAGE: cannyd <run|gen|batch|serve|stream|cluster|worker|calibrate|profile|analyze|info> [flags]

  run        detect edges:      --input x.pgm | --scene shapes:7 --size 512x512
                                [--output edges.pgm]
                                [--stop-after pad|gaussian|sobel|nms|threshold|
                                 hysteresis]  (partial pipeline + stage records)
                                [--emit gray|gradient|suppressed|class-map|edges]
  gen        generate an image: --scene checker:16 --size 512x512 --output x.pgm
  batch      farm throughput:   --count 16 --size 512x512 [--scene shapes]
  serve      serving tier:      --synthetic 200 | --requests trace.json
                                (admission queue -> batcher -> detector lanes;
                                 prints a JSON SLO report; --clock virtual
                                 replays deterministically, --clock wall runs
                                 real lane threads on monotonic time and drains
                                 gracefully on SIGINT (\"interrupted\": true);
                                 --calibration file.json|probe swaps the
                                 virtual cost model for a measured one;
                                 requests may carry \"kind\": full | front-only
                                 | re-threshold {lo, hi} — re-threshold hits the
                                 shared content-addressed artifact cache)
  stream     frame-stream tier: --synthetic-frames 32 [--size 512x512]
                                | --source video:SEED|SCENE|dir:PATH|trace:PATH
                                (decode -> delta-gated front -> finish, pipeline-
                                 parallel with a bounded in-flight window; prints
                                 a JSON stream report: fps, Mpix/s, gate hit-rate,
                                 per-stage aggregates, jitter p50/p95/p99)
  cluster    multi-process tier: --workers N processes behind a loopback
                                front door; --synthetic 200 | --requests
                                trace.json (digest-affine routing keeps each
                                content shard on one worker's cache; dead
                                workers are restarted and their in-flight
                                request requeued; prints a merged JSON
                                cluster report, schema in the cluster
                                module docs)
  worker     internal: one cluster worker process (spawned by `cluster`;
                                --worker-id N, connects to --cluster-port)
  calibrate  probe the service-cost model on this host and print/save it
                                [--output calib.json]
  profile    paper figures:     [--figure fig8|fig9|percore] [--sim-cpus 4|8]
  analyze    offline analytics: cannyd analyze trace.jsonl [--against FILE]
                                (span JSONL, telemetry JSONL and bench
                                 BENCH_*.json docs are sniffed by content;
                                 prints one JSON report — count/p50/p99 per
                                 span kind or telemetry series, per-trace
                                 critical paths, and per-name deltas against
                                 a baseline file; schema in the obs docs)
  info       topology + artifacts + resolved config

Config flags (all commands): --engine serial|patterns|tiled|xla
  --workers N  --lo F --hi F --tile N --parallel-hysteresis
  --band-grain N (hysteresis band rows per task, 0 = auto from planner)
  --artifacts DIR (alias: --artifacts-dir) --tile-name tNNN
  --xla-replicas N (compiled copies per entry, 0 = auto)
  --sample-period-us N (profiler usage-sampler period; default 200)
  --sim-cpus N --seed N --config FILE
Serve flags: --lanes N --queue-depth N --batch-window-us N --batch-max N
  --arrival-rate HZ --slo-p99-ms F --max-pixels N --clock virtual|wall
Cache flags (shared artifact tier, serve + stream):
  --cache-mb N (global byte budget in MiB, 0 = off; default 64)
  --cache-shards N (lock granularity; default 8)
  --cache-admit-ns-per-byte F (cost-aware admission bar, 0 = admit all)
Stream flags: --inflight N (bounded in-flight window)
  --delta-gate off|THRESH (temporal per-tile reuse; 0 = exact, default)
  --frame-budget-ms F (real-time deadline per frame, 0 = offline)
  --drop-policy drop|degrade|none (late-frame handling under a budget)
  --stream-cache (consult/offer frames in the shared artifact tier)
Cluster flags: --cluster-port P (front-door loopback port, 0 = ephemeral)
  --worker-heartbeat-ms N (dispatch read-timeout / liveness probe period)
  --worker-telemetry-ms N (how often each worker streams a telemetry
    frame to the front door on its own clock; default 100)
  --alert-log stderr|FILE (health-transition alert sink, also honored by
    serve; empty = off)
Ops-plane flags (serve + stream; --telemetry-log and --obs-port also
  honored by cluster, which merges every worker's stream):
  --telemetry-log FILE.jsonl (periodic snapshot stream; schema in the
    obs module docs; byte-identical across virtual serve replays)
  --telemetry-interval-ms F (snapshot period; default 100)
  --slo-window N (rolling SLO window over the last N completions;
    default 64; drives health states and overload decisions)
  --overload-policy none|reject-new|degrade-to-front-only (what happens
    to new serve arrivals while the rolling SLO is missed; default none
    = observe only)
  --trace-log FILE (per-request distributed trace: .jsonl = span JSONL,
    anything else = Chrome trace-event JSON for chrome://tracing;
    serve + cluster; byte-identical across virtual replays)
  --trace-sample all|slow:MS|errors|head:N (tail-based trace sampling:
    keep/drop is decided after a request completes, from its observed
    latency — slow:MS keeps traces slower than MS ms, errors keeps
    SLO-violating traces, head:N keeps 1-in-N; deterministic under
    --clock virtual; in cluster mode the front door's verdict governs
    the workers' subtrees; default all)
  --anomaly-sigma N (EWMA anomaly detection over the telemetry series;
    an observation more than N standard deviations from the running
    mean raises an ALERT line naming the worst exemplar trace; 0 = off)
  --obs-port P (loopback TCP: connect, read the current snapshot line
    as one JSON object, then — when one has fired — the newest ALERT
    line as a second line; connection closes after; 0 = off)

Unknown flags and subcommands are errors, not ignored.
";

fn parse_size(spec: Option<String>) -> anyhow::Result<(usize, usize)> {
    let spec = spec.unwrap_or_else(|| "512x512".into());
    let (w, h) = spec
        .split_once('x')
        .ok_or_else(|| anyhow::anyhow!("--size must be WxH, got `{spec}`"))?;
    Ok((w.parse()?, h.parse()?))
}

fn load_or_generate(
    cfg: &RunConfig,
    input: Option<String>,
    scene: Option<String>,
    size: Option<String>,
) -> anyhow::Result<ImageF32> {
    match input {
        Some(path) => Ok(pgm::read_pgm(Path::new(&path))?.to_f32()),
        None => {
            let scene = scene.unwrap_or_else(|| format!("shapes:{}", cfg.seed));
            let scene = Scene::parse(&scene)
                .ok_or_else(|| anyhow::anyhow!("unknown scene `{scene}`"))?;
            let (w, h) = parse_size(size)?;
            Ok(generate(scene, w, h))
        }
    }
}

/// Map an `--emit` artifact name to the default stop stage when
/// `--stop-after` is not given (for `gray`, the smoothed image rather
/// than the bare padded input).
fn emit_stage(emit: &str) -> anyhow::Result<StageKind> {
    match emit {
        "gray" => Ok(StageKind::Gaussian),
        "gradient" => Ok(StageKind::Sobel),
        "suppressed" => Ok(StageKind::Nms),
        "class-map" => Ok(StageKind::Threshold),
        "edges" => Ok(StageKind::Hysteresis),
        other => anyhow::bail!(
            "unknown artifact `{other}` (gray | gradient | suppressed | class-map | edges)"
        ),
    }
}

/// Is the artifact retained in the plan output at this stop? (Big
/// pre-NMS intermediates exist only when they are the stop artifact;
/// the suppressed map and class map survive to later stops.)
fn emit_available(emit: &str, stop: StageKind) -> bool {
    match emit {
        "gray" => matches!(stop, StageKind::Pad | StageKind::Gaussian),
        "gradient" => stop == StageKind::Sobel,
        "suppressed" => stop >= StageKind::Nms,
        "class-map" => stop >= StageKind::Threshold,
        "edges" => stop == StageKind::Hysteresis,
        _ => false,
    }
}

/// The artifact a given stop stage yields (for `--stop-after` with
/// `--output` but no explicit `--emit`).
fn stop_artifact(stop: StageKind) -> &'static str {
    match stop {
        StageKind::Pad | StageKind::Gaussian => "gray",
        StageKind::Sobel => "gradient",
        StageKind::Nms => "suppressed",
        StageKind::Threshold => "class-map",
        StageKind::Hysteresis => "edges",
    }
}

/// Write an f32 artifact as an 8-bit PGM, normalized to its own max
/// (gradient magnitudes and class maps are not in [0, 1]).
fn write_f32_pgm(path: &Path, img: &ImageF32) -> anyhow::Result<()> {
    let max = img.data().iter().cloned().fold(0.0f32, f32::max).max(1e-9);
    let mut scaled = ImageF32::zeros(img.width(), img.height());
    for y in 0..img.height() {
        for x in 0..img.width() {
            scaled.set(y, x, img.get(y, x) / max);
        }
    }
    pgm::write_pgm(path, &scaled.to_u8())?;
    Ok(())
}

fn cmd_run(
    cfg: &RunConfig,
    input: Option<String>,
    output: Option<String>,
    scene: Option<String>,
    size: Option<String>,
    stop_after: Option<String>,
    emit: Option<String>,
) -> anyhow::Result<()> {
    let img = load_or_generate(cfg, input, scene, size)?;
    let det = Detector::from_config(cfg)?;
    if stop_after.is_some() || emit.is_some() {
        return cmd_run_plan(cfg, &det, &img, output, stop_after, emit);
    }
    let out = det.detect_full(&img, &cfg.params)?;
    let report = RunReport::from_run(
        &format!("run[{}x{} {}]", img.width(), img.height(), cfg.engine.name()),
        img.len(),
        &out.times,
        Some(&det.pool_stats()),
    );
    println!("{}", report.summary());
    println!(
        "edges: {} ({:.2}% density)",
        out.edges.count_edges(),
        100.0 * out.edges.edge_density()
    );
    if let Some(path) = output {
        pgm::write_pgm(Path::new(&path), &out.edges.to_image())?;
        println!("wrote {path}");
    }
    Ok(())
}

/// `cannyd run --stop-after <stage>` / `--emit <artifact>`: execute a
/// partial [`canny_par::canny::StagePlan`], print per-stage records,
/// and optionally write the requested artifact.
fn cmd_run_plan(
    cfg: &RunConfig,
    det: &Detector,
    img: &ImageF32,
    output: Option<String>,
    stop_after: Option<String>,
    emit: Option<String>,
) -> anyhow::Result<()> {
    let emit_default_stop = emit.as_deref().map(emit_stage).transpose()?;
    let stop = match stop_after.as_deref() {
        Some(s) => StageKind::parse(s)
            .ok_or_else(|| anyhow::anyhow!(
                "unknown stage `{s}` (pad | gaussian | sobel | nms | threshold | hysteresis)"
            ))?,
        None => emit_default_stop.unwrap_or(StageKind::Hysteresis),
    };
    // `--output` without `--emit` writes the stop stage's own artifact
    // (matching plain `run`, which always honors --output).
    let emit = match (emit, &output) {
        (None, Some(_)) => Some(stop_artifact(stop).to_string()),
        (emit, _) => emit,
    };
    if let Some(emit) = emit.as_deref() {
        if !emit_available(emit, stop) {
            anyhow::bail!(
                "artifact `{emit}` is not retained when stopping after `{}` \
                 (gray: pad|gaussian, gradient: sobel, suppressed: nms+, \
                  class-map: threshold+, edges: hysteresis)",
                stop.name()
            );
        }
    }
    let plan = det.plan().stop_after(stop);
    let out = det.run_plan(&plan, Some(img), &cfg.params)?;
    println!(
        "plan[{}x{} {} stop={}]:",
        img.width(),
        img.height(),
        det.engine().name(),
        stop.name()
    );
    for r in &out.records {
        println!(
            "  {:<10} engine={:<8} wall={:>10} cpu={:>10} tasks={}",
            r.span_name(),
            r.engine.name(),
            human_ns(r.wall_ns),
            human_ns(r.cpu_ns),
            r.tasks
        );
    }
    println!("  total      {}", human_ns(out.total_ns));
    if let Some(emit) = emit {
        let path = output.unwrap_or_else(|| format!("{emit}.pgm"));
        let path = Path::new(&path);
        // Big pre-NMS intermediates are retained only when they are the
        // stop artifact, so emitting one requires stopping there.
        let missing = || {
            anyhow::anyhow!(
                "artifact `{emit}` is not retained at stop `{}` — \
                 add --stop-after {}",
                stop.name(),
                emit_stage(&emit).map(|k| k.name()).unwrap_or("?")
            )
        };
        match emit.as_str() {
            "edges" => {
                let e = out.edges().ok_or_else(missing)?;
                pgm::write_pgm(path, &e.to_image())?;
            }
            "gray" => write_f32_pgm(path, out.gray().ok_or_else(missing)?)?,
            "gradient" => write_f32_pgm(path, out.gradient().ok_or_else(missing)?.0)?,
            "suppressed" => write_f32_pgm(path, out.suppressed().ok_or_else(missing)?)?,
            "class-map" => write_f32_pgm(path, out.class_map().ok_or_else(missing)?)?,
            _ => unreachable!("validated by emit_stage"),
        }
        println!("wrote {}", path.display());
    }
    Ok(())
}

fn cmd_gen(
    cfg: &RunConfig,
    scene: Option<String>,
    size: Option<String>,
    output: Option<String>,
) -> anyhow::Result<()> {
    let img = load_or_generate(cfg, None, scene, size)?;
    let path = output.unwrap_or_else(|| "scene.pgm".into());
    pgm::write_pgm(Path::new(&path), &img.to_u8())?;
    println!("wrote {path} ({}x{})", img.width(), img.height());
    Ok(())
}

fn cmd_batch(
    cfg: &RunConfig,
    count: Option<String>,
    size: Option<String>,
    scene: Option<String>,
) -> anyhow::Result<()> {
    let n: usize = count.unwrap_or_else(|| "16".into()).parse()?;
    let (w, h) = parse_size(size)?;
    let base = scene.unwrap_or_else(|| "shapes".into());
    let det = Detector::from_config(cfg)?;
    let jobs: Vec<BatchJob> = (0..n)
        .map(|k| {
            let scene = Scene::parse(&format!("{base}:{}", cfg.seed + k as u64))
                .unwrap_or(Scene::Shapes { seed: cfg.seed + k as u64 });
            BatchJob { id: k, image: generate(scene, w, h) }
        })
        .collect();
    let report = BatchServer::new(&det).run(jobs, &cfg.params)?;
    println!(
        "batch: {} images ({}x{}) in {} -> {:.2} img/s, {:.2} Mpix/s, {} stalls",
        n,
        w,
        h,
        human_ns(report.wall_ns),
        report.images_per_s(),
        report.mpix_per_s(),
        report.farm.stalls
    );
    Ok(())
}

fn cmd_serve(
    cfg: &RunConfig,
    requests: Option<String>,
    synthetic: Option<String>,
    calibration: Option<String>,
) -> anyhow::Result<()> {
    let (label, trace) = match requests {
        Some(path) => {
            if synthetic.is_some() {
                anyhow::bail!("--requests and --synthetic are mutually exclusive");
            }
            (format!("serve[{path}]"), Trace::from_json_file(Path::new(&path))?)
        }
        None => {
            let n: usize = synthetic.unwrap_or_else(|| "200".into()).parse()?;
            (
                format!("serve[synthetic n={n} seed={}]", cfg.seed),
                Trace::synthetic(n, cfg.seed, cfg.arrival_rate_hz),
            )
        }
    };
    let mut opts = ServeOptions::from_config(cfg);
    // `--calibration probe` measures at startup; anything else is a
    // saved calibration JSON (deterministic replay).
    opts.calibration = match calibration.as_deref() {
        Some("probe") => Some(calibrate_for(&trace, &opts)?),
        Some(path) => Some(Calibration::from_json_file(Path::new(path))?),
        None => None,
    };
    if cfg.clock == ClockMode::Wall {
        // Ctrl-C drains in-flight requests and prints a partial report
        // with "interrupted": true.
        opts.interrupt = Some(install_sigint_drain());
    }
    opts.obs_endpoint = canny_par::obs::endpoint::from_config_port(cfg.obs_port)?;
    let report = serve(&label, &trace, &opts)?;
    println!("{}", report.to_json_string());
    Ok(())
}

/// `cannyd stream`: run a frame stream through the pipeline-parallel
/// executor with temporal delta-gating and print the JSON stream
/// report (schema documented in `canny_par::stream`).
fn cmd_stream(
    cfg: &RunConfig,
    source: Option<String>,
    synthetic_frames: Option<String>,
    size: Option<String>,
) -> anyhow::Result<()> {
    let frames: usize = synthetic_frames.unwrap_or_else(|| "64".into()).parse()?;
    let (w, h) = parse_size(size)?;
    let spec = source.unwrap_or_else(|| format!("video:{}", cfg.seed));
    let src = FrameSource::parse(&spec, frames, w, h, cfg.seed)?;
    let det = Detector::from_config(cfg)?;
    let mut opts = StreamOptions::from_config(cfg);
    opts.obs_endpoint = canny_par::obs::endpoint::from_config_port(cfg.obs_port)?;
    let label = format!("stream[{}]", src.describe());
    let out = run_stream(&label, &src, &det, &opts)?;
    println!("{}", out.report.to_json_string());
    Ok(())
}

/// `cannyd cluster`: spawn `--workers` worker processes, route the
/// trace across them by content digest, and print the merged cluster
/// report (schema documented in `canny_par::cluster`).
fn cmd_cluster(
    cfg: &RunConfig,
    requests: Option<String>,
    synthetic: Option<String>,
) -> anyhow::Result<()> {
    let (label, trace) = match requests {
        Some(path) => {
            if synthetic.is_some() {
                anyhow::bail!("--requests and --synthetic are mutually exclusive");
            }
            (format!("cluster[{path}]"), Trace::from_json_file(Path::new(&path))?)
        }
        None => {
            let n: usize = synthetic.unwrap_or_else(|| "200".into()).parse()?;
            (
                format!("cluster[synthetic n={n} seed={}]", cfg.seed),
                Trace::synthetic(n, cfg.seed, cfg.arrival_rate_hz),
            )
        }
    };
    let opts = ClusterOptions::from_config(cfg);
    let out = run_cluster(&label, &trace, &opts)?;
    println!("{}", out.report.to_json_string());
    Ok(())
}

/// `cannyd worker`: one cluster worker process. Connects back to the
/// front door, says hello, then serves request frames until `shutdown`.
/// Internal — spawned by `cmd_cluster`, not meant for direct use.
fn cmd_worker(cfg: &RunConfig, worker_id: Option<String>) -> anyhow::Result<()> {
    let id: usize = worker_id
        .ok_or_else(|| anyhow::anyhow!("worker needs --worker-id (spawned by `cluster`)"))?
        .parse()?;
    run_worker(cfg, id, cfg.cluster_port)?;
    Ok(())
}

/// Probe the service-cost model for the configured engine/workers on
/// the default shape grid; print the calibration JSON (and save it when
/// `--output` is given) for later `serve --calibration file.json` runs.
fn cmd_calibrate(cfg: &RunConfig, output: Option<String>) -> anyhow::Result<()> {
    let det = Detector::from_config(cfg)?;
    let shapes: Vec<Shape> =
        DEFAULT_PROBE_SHAPES.iter().map(|&(w, h)| Shape { width: w, height: h }).collect();
    let calib = Calibration::probe(&det, &shapes, PROBE_REPEATS)?;
    match output {
        Some(path) => {
            calib.save(Path::new(&path))?;
            eprintln!(
                "calibrated {} ({} workers): overhead {} ns + {:.3} ns/px -> wrote {path}",
                calib.engine, calib.workers, calib.overhead_ns, calib.cost_ns_per_pixel
            );
        }
        None => println!("{}", calib.to_json_string()),
    }
    Ok(())
}

fn cmd_profile(cfg: &RunConfig, figure: Option<String>) -> anyhow::Result<()> {
    // Measure the real pipeline once (tiled => per-tile costs), then
    // replay on the simulated topology to render the figures.
    let det = Detector::builder()
        .engine(Engine::TiledPatterns)
        .workers(cfg.workers.max(1))
        .params(cfg.params)
        .build()?;
    let img = generate(Scene::Shapes { seed: cfg.seed }, 1024, 1024);
    let serial_out = canny_par::canny::CannyPipeline::serial().detect(&img, &cfg.params)?;
    let tiled_out = det.detect_full(&img, &cfg.params)?;

    let serial_spec =
        RunReport::from_run("serial", img.len(), &serial_out.times, None).to_sim_spec();
    let tiled_spec =
        RunReport::from_run("tiled", img.len(), &tiled_out.times, None).to_sim_spec();

    let cpus = cfg.sim_cpus;
    let period = 1_000_000; // 1 ms virtual sampling
    let sub = UsageTrace::from_sim(
        &simulate(&serial_spec, cpus),
        period,
        &format!("suboptimal (serial) on {cpus} CPUs"),
    );
    let opt = UsageTrace::from_sim(
        &simulate(&tiled_spec, cpus),
        period,
        &format!("optimal (parallel patterns) on {cpus} CPUs"),
    );

    let which = figure.unwrap_or_else(|| "all".into());
    if which == "fig8" || which == "all" {
        println!("{}", sub.ascii_total(72, 10));
    }
    if which == "fig9" || which == "all" {
        println!("{}", opt.ascii_total(72, 10));
    }
    if which == "percore" || which == "all" {
        println!("{}", sub.ascii_per_core(72, 5));
        println!("{}", opt.ascii_per_core(72, 5));
    }
    println!(
        "busy samples: suboptimal {} vs optimal-equivalent rate {:.1}x (paper: 8,992 vs 34,884 = 3.88x)",
        sub.busy_samples(),
        opt.mean_total_pct() / sub.mean_total_pct().max(1e-9),
    );
    Ok(())
}

/// `cannyd analyze <file> [--against <file>]` — offline analytics over
/// a recorded span/telemetry JSONL file or a bench baseline doc. Pure
/// file-in, JSON-out; schema in the obs module docs.
fn cmd_analyze(input: Option<&String>, against: Option<String>) -> anyhow::Result<()> {
    let input = input.ok_or_else(|| {
        anyhow::anyhow!("analyze needs a file operand: `cannyd analyze trace.jsonl`")
    })?;
    let report =
        canny_par::obs::analyze(Path::new(input), against.as_deref().map(Path::new))?;
    println!("{}", report.dump());
    Ok(())
}

fn cmd_info(cfg: &RunConfig) -> anyhow::Result<()> {
    let topo = topology::CpuTopology::detect();
    println!("host topology : {} ({} physical)", topo.name, topo.physical_cores);
    for t in topology::CpuTopology::table1() {
        println!("table-1 sim   : {}", t.name);
    }
    match Manifest::load(Path::new(&cfg.artifacts_dir)) {
        Ok(m) => {
            println!("artifacts     : {} (halo {})", m.dir.display(), m.halo);
            for t in &m.tiles {
                println!(
                    "  tile {:>5}: core {}x{} entries [{}]",
                    t.name,
                    t.core_h,
                    t.core_w,
                    t.entries.keys().cloned().collect::<Vec<_>>().join(", ")
                );
            }
        }
        Err(e) => println!("artifacts     : unavailable ({e})"),
    }
    let plan = Planner::new(topo)
        .with_xla(PathBuf::from(&cfg.artifacts_dir).join("manifest.json").exists())
        .plan(Workload { image_w: 1024, image_h: 1024, batch: 1 }, &cfg.params);
    println!("plan @1024²   : engine={} workers={} tile={} ({})",
        plan.engine.name(), plan.workers, plan.params.tile, plan.rationale);
    println!("config:");
    for (k, v) in cfg.to_map() {
        println!("  {k} = {v}");
    }
    Ok(())
}
