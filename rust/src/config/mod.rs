//! Configuration system: a `key = value` config-file format plus CLI
//! `--key value` overrides (no clap offline — the parser is ~100 lines
//! and covered by tests). Precedence: defaults < file < CLI.

use std::collections::BTreeMap;
use std::path::Path;

use crate::canny::{CannyParams, Engine};
use crate::error::{Error, Result};

/// Fully-resolved run configuration for the `cannyd` launcher and the
/// coordinator's planner.
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// Execution engine.
    pub engine: Engine,
    /// Worker threads (0 = auto from topology).
    pub workers: usize,
    /// Canny thresholds + tiling.
    pub params: CannyParams,
    /// Artifacts directory for the XLA engine.
    pub artifacts_dir: String,
    /// Tile-config name in the manifest ("" = closest to params.tile).
    pub tile_name: String,
    /// XLA executable replicas (0 = one per worker).
    pub xla_replicas: usize,
    /// Profiler sampling period, microseconds.
    pub sample_period_us: u64,
    /// Simulated topology for figure benches (e.g. 4 or 8 virtual CPUs).
    pub sim_cpus: usize,
    /// RNG seed for synthetic scenes.
    pub seed: u64,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            engine: Engine::Patterns,
            workers: 0,
            params: CannyParams::default(),
            artifacts_dir: "artifacts".into(),
            tile_name: String::new(),
            xla_replicas: 0,
            sample_period_us: 200,
            sim_cpus: 8,
            seed: 7,
        }
    }
}

impl RunConfig {
    /// Apply one `key = value` setting.
    pub fn set(&mut self, key: &str, value: &str) -> Result<()> {
        let bad = |what: &str| Error::Config(format!("bad {what} `{value}` for key `{key}`"));
        match key {
            "engine" => {
                self.engine = Engine::parse(value).ok_or_else(|| bad("engine"))?;
            }
            "workers" => self.workers = value.parse().map_err(|_| bad("usize"))?,
            "lo" => self.params.lo = value.parse().map_err(|_| bad("f32"))?,
            "hi" => self.params.hi = value.parse().map_err(|_| bad("f32"))?,
            "tile" => self.params.tile = value.parse().map_err(|_| bad("usize"))?,
            "parallel-hysteresis" | "parallel_hysteresis" => {
                self.params.parallel_hysteresis = parse_bool(value).ok_or_else(|| bad("bool"))?
            }
            "band-grain" | "band_grain" => {
                self.params.band_grain = value.parse().map_err(|_| bad("usize"))?
            }
            "artifacts" | "artifacts-dir" => self.artifacts_dir = value.to_string(),
            "tile-name" | "tile_name" => self.tile_name = value.to_string(),
            "xla-replicas" | "xla_replicas" => {
                self.xla_replicas = value.parse().map_err(|_| bad("usize"))?
            }
            "sample-period-us" => {
                self.sample_period_us = value.parse().map_err(|_| bad("u64"))?
            }
            "sim-cpus" | "sim_cpus" => self.sim_cpus = value.parse().map_err(|_| bad("usize"))?,
            "seed" => self.seed = value.parse().map_err(|_| bad("u64"))?,
            _ => return Err(Error::Config(format!("unknown config key `{key}`"))),
        }
        Ok(())
    }

    /// Load `key = value` lines (# comments, blank lines ok).
    pub fn load_file(&mut self, path: &Path) -> Result<()> {
        let text = std::fs::read_to_string(path)?;
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line.split_once('=').ok_or_else(|| {
                Error::Config(format!("{}:{}: expected key = value", path.display(), lineno + 1))
            })?;
            self.set(k.trim(), v.trim())?;
        }
        Ok(())
    }

    /// Parse CLI args of the form `--key value` / `--key=value` /
    /// `--flag`. Returns positional (non-flag) args.
    pub fn apply_cli(&mut self, args: &[String]) -> Result<Vec<String>> {
        let mut positional = Vec::new();
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    self.set(k, v)?;
                } else if stripped == "parallel-hysteresis" {
                    self.set(stripped, "true")?;
                } else {
                    let v = args.get(i + 1).ok_or_else(|| {
                        Error::Config(format!("--{stripped} needs a value"))
                    })?;
                    self.set(stripped, v)?;
                    i += 1;
                }
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        Ok(positional)
    }

    /// Validate combined settings.
    pub fn validate(&self) -> Result<()> {
        self.params.validate()?;
        if self.sim_cpus == 0 {
            return Err(Error::Config("sim-cpus must be >= 1".into()));
        }
        Ok(())
    }

    /// Render as a `key = value` map (diagnostics / `cannyd info`).
    pub fn to_map(&self) -> BTreeMap<String, String> {
        let mut m = BTreeMap::new();
        m.insert("engine".into(), self.engine.name().into());
        m.insert("workers".into(), self.workers.to_string());
        m.insert("lo".into(), self.params.lo.to_string());
        m.insert("hi".into(), self.params.hi.to_string());
        m.insert("tile".into(), self.params.tile.to_string());
        m.insert(
            "parallel-hysteresis".into(),
            self.params.parallel_hysteresis.to_string(),
        );
        m.insert("artifacts".into(), self.artifacts_dir.clone());
        m.insert("sim-cpus".into(), self.sim_cpus.to_string());
        m.insert("seed".into(), self.seed.to_string());
        m
    }
}

fn parse_bool(v: &str) -> Option<bool> {
    match v {
        "1" | "true" | "yes" | "on" => Some(true),
        "0" | "false" | "no" | "off" => Some(false),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        RunConfig::default().validate().unwrap();
    }

    #[test]
    fn set_and_get() {
        let mut c = RunConfig::default();
        c.set("engine", "serial").unwrap();
        c.set("workers", "8").unwrap();
        c.set("lo", "0.03").unwrap();
        c.set("parallel-hysteresis", "true").unwrap();
        assert_eq!(c.engine, Engine::Serial);
        assert_eq!(c.workers, 8);
        assert!((c.params.lo - 0.03).abs() < 1e-9);
        assert!(c.params.parallel_hysteresis);
    }

    #[test]
    fn rejects_unknown_and_bad_values() {
        let mut c = RunConfig::default();
        assert!(c.set("nope", "1").is_err());
        assert!(c.set("workers", "lots").is_err());
        assert!(c.set("engine", "gpu").is_err());
    }

    #[test]
    fn cli_parsing_forms() {
        let mut c = RunConfig::default();
        let args: Vec<String> = ["run", "--workers", "4", "--engine=tiled", "--parallel-hysteresis", "x.pgm"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let pos = c.apply_cli(&args).unwrap();
        assert_eq!(pos, vec!["run", "x.pgm"]);
        assert_eq!(c.workers, 4);
        assert_eq!(c.engine, Engine::TiledPatterns);
        assert!(c.params.parallel_hysteresis);
    }

    #[test]
    fn cli_missing_value_errors() {
        let mut c = RunConfig::default();
        let args = vec!["--workers".to_string()];
        assert!(c.apply_cli(&args).is_err());
    }

    #[test]
    fn file_loading_with_comments() {
        let path = std::env::temp_dir().join("canny_cfg_test.conf");
        std::fs::write(&path, "# comment\nengine = xla\n\nworkers = 2 # trailing\n").unwrap();
        let mut c = RunConfig::default();
        c.load_file(&path).unwrap();
        assert_eq!(c.engine, Engine::PatternsXla);
        assert_eq!(c.workers, 2);
    }

    #[test]
    fn file_syntax_error_reported_with_line() {
        let path = std::env::temp_dir().join("canny_cfg_bad.conf");
        std::fs::write(&path, "workers 4\n").unwrap();
        let err = RunConfig::default().load_file(&path).map(|_| ()).unwrap_err().to_string();
        assert!(err.contains(":1:"), "{err}");
    }

    #[test]
    fn to_map_contains_core_keys() {
        let m = RunConfig::default().to_map();
        assert!(m.contains_key("engine"));
        assert!(m.contains_key("tile"));
    }
}
