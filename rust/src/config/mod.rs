//! Configuration system: a `key = value` config-file format plus CLI
//! `--key value` overrides (no clap offline — the parser is ~100 lines
//! and covered by tests). Precedence: defaults < file < CLI.

use std::collections::BTreeMap;
use std::path::Path;

use crate::canny::{CannyParams, Engine};
use crate::error::{Error, Result};
use crate::obs::OverloadPolicy;
use crate::service::clock::ClockMode;
use crate::service::slo::DEFAULT_SLO_WINDOW;
use crate::stream::{DeltaMode, DropPolicy};

/// Fully-resolved run configuration for the `cannyd` launcher and the
/// coordinator's planner.
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// Execution engine.
    pub engine: Engine,
    /// Worker threads (0 = auto from topology).
    pub workers: usize,
    /// Canny thresholds + tiling.
    pub params: CannyParams,
    /// Artifacts directory for the XLA engine.
    pub artifacts_dir: String,
    /// Tile-config name in the manifest ("" = closest to params.tile).
    pub tile_name: String,
    /// XLA executable replicas (0 = one per worker).
    pub xla_replicas: usize,
    /// Profiler sampling period, microseconds.
    pub sample_period_us: u64,
    /// Simulated topology for figure benches (e.g. 4 or 8 virtual CPUs).
    pub sim_cpus: usize,
    /// RNG seed for synthetic scenes.
    pub seed: u64,
    /// Serving tier (`cannyd serve`): worker lanes, each owning a detector.
    pub lanes: usize,
    /// Serving tier: max admitted-but-undispatched requests
    /// (backpressure bound — arrivals beyond it are rejected).
    pub queue_depth: usize,
    /// Serving tier: batch coalescing max-delay window, µs (virtual time).
    pub batch_window_us: u64,
    /// Serving tier: max requests coalesced into one batch.
    pub batch_max: usize,
    /// Serving tier: synthetic open-loop arrival rate, requests/second.
    pub arrival_rate_hz: f64,
    /// Serving tier: SLO target on aggregate p99 latency, milliseconds.
    pub slo_p99_ms: f64,
    /// Serving tier: per-request pixel budget (0 = unlimited); larger
    /// requests are rejected at admission with an `oversize` reason.
    pub max_pixels: usize,
    /// Serving tier: which clock drives the event loop —
    /// `virtual` (deterministic modeled-time replay, the default) or
    /// `wall` (real lane threads + monotonic time).
    pub clock: ClockMode,
    /// Shared artifact-cache tier ([`crate::cache`]): global byte
    /// budget in MiB over all shards (0 disables the tier — every
    /// re-threshold recomputes the front).
    pub cache_mb: usize,
    /// Cache tier: shard count (lock granularity across lanes/streams).
    pub cache_shards: usize,
    /// Cache tier: admission bar in recompute-nanoseconds per byte —
    /// artifacts cheaper to rebuild than this are not cached (0 admits
    /// everything).
    pub cache_admit_ns_per_byte: f64,
    /// Stream tier: offer each frame's suppressed-magnitude artifact
    /// into the shared cache (and consult it before running the front),
    /// so identical frames across streams — and serve requests on the
    /// same content — deduplicate.
    pub stream_cache: bool,
    /// Stream tier (`cannyd stream`): bounded in-flight window — the
    /// capacity of each inter-stage queue in the frame pipeline.
    pub inflight: usize,
    /// Stream tier: temporal delta-gating — `off`, or a per-pixel
    /// cleanliness threshold (`0` = exact reuse, the default).
    pub delta_gate: DeltaMode,
    /// Stream tier: real-time frame budget in milliseconds (0 =
    /// offline, no deadlines).
    pub frame_budget_ms: f64,
    /// Stream tier: what to do with frames past their deadline —
    /// `drop`, `degrade`, or `none`.
    pub drop_policy: DropPolicy,
    /// Ops plane: telemetry JSONL sink path ("" disables the snapshot
    /// stream; the final report's ops sections are always present).
    pub telemetry_log: String,
    /// Ops plane: snapshot tick interval, milliseconds (in the active
    /// clock — modeled time under `clock = virtual`).
    pub telemetry_interval_ms: f64,
    /// Ops plane: what to do with serve arrivals while the rolling SLO
    /// is missed — `none`, `reject-new`, or `degrade-to-front-only`.
    pub overload_policy: OverloadPolicy,
    /// Ops plane: rolling SLO window capacity, in completions.
    pub slo_window: usize,
    /// Ops plane: health-transition alert sink — "" disables, `stderr`
    /// streams to stderr, anything else is an alert-log file path.
    pub alert_log: String,
    /// Cluster tier: loopback TCP port the front-door listens on for
    /// worker connections (0 = ephemeral, the default; `cannyd worker`
    /// is told the real port via `--cluster-port`).
    pub cluster_port: u16,
    /// Cluster tier: per-dispatch read timeout, milliseconds — how long
    /// the router waits on a silent worker before probing its process
    /// for liveness (dead workers are restarted and the request
    /// requeued).
    pub worker_heartbeat_ms: u64,
    /// Observability: per-request trace sink path ("" disables
    /// tracing). A `.jsonl` extension writes span-JSONL; any other
    /// extension writes Chrome trace-event JSON (see [`crate::obs`]).
    pub trace_log: String,
    /// Observability: loopback TCP port serving the tier's current
    /// snapshot line (connect → one JSON line → close). 0 disables the
    /// endpoint.
    pub obs_port: u16,
    /// Cluster tier: how often each worker streams a `telemetry` frame
    /// (its current snapshot line) to the front door, milliseconds in
    /// the worker's clock domain.
    pub worker_telemetry_ms: f64,
    /// Observability: tail-based trace sampling policy — which
    /// completed requests keep their spans in `--trace-log`:
    /// `all` (default), `slow:<ms>`, `errors` (SLO violations), or
    /// `head:<1-in-n>` (see [`crate::obs::sample`]).
    pub trace_sample: String,
    /// Observability: anomaly-detection threshold in standard
    /// deviations over the rolling telemetry series (0 = off, the
    /// default; see [`crate::obs::anomaly`]).
    pub anomaly_sigma: f64,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            engine: Engine::Patterns,
            workers: 0,
            params: CannyParams::default(),
            artifacts_dir: "artifacts".into(),
            tile_name: String::new(),
            xla_replicas: 0,
            sample_period_us: 200,
            sim_cpus: 8,
            seed: 7,
            lanes: 2,
            queue_depth: 64,
            batch_window_us: 2000,
            batch_max: 8,
            arrival_rate_hz: 2000.0,
            slo_p99_ms: 50.0,
            max_pixels: 0,
            clock: ClockMode::Virtual,
            cache_mb: 64,
            cache_shards: 8,
            cache_admit_ns_per_byte: 0.0,
            stream_cache: false,
            inflight: 4,
            delta_gate: DeltaMode::default(),
            frame_budget_ms: 0.0,
            drop_policy: DropPolicy::Drop,
            telemetry_log: String::new(),
            telemetry_interval_ms: 100.0,
            overload_policy: OverloadPolicy::None,
            slo_window: DEFAULT_SLO_WINDOW,
            alert_log: String::new(),
            cluster_port: 0,
            worker_heartbeat_ms: 500,
            trace_log: String::new(),
            obs_port: 0,
            worker_telemetry_ms: 100.0,
            trace_sample: "all".into(),
            anomaly_sigma: 0.0,
        }
    }
}

impl RunConfig {
    /// Apply one `key = value` setting.
    pub fn set(&mut self, key: &str, value: &str) -> Result<()> {
        let bad = |what: &str| Error::Config(format!("bad {what} `{value}` for key `{key}`"));
        match key {
            "engine" => {
                self.engine = Engine::parse(value).ok_or_else(|| bad("engine"))?;
            }
            "workers" => self.workers = value.parse().map_err(|_| bad("usize"))?,
            "lo" => self.params.lo = value.parse().map_err(|_| bad("f32"))?,
            "hi" => self.params.hi = value.parse().map_err(|_| bad("f32"))?,
            "tile" => self.params.tile = value.parse().map_err(|_| bad("usize"))?,
            "parallel-hysteresis" | "parallel_hysteresis" => {
                self.params.parallel_hysteresis = parse_bool(value).ok_or_else(|| bad("bool"))?
            }
            "band-grain" | "band_grain" => {
                self.params.band_grain = value.parse().map_err(|_| bad("usize"))?
            }
            "artifacts" | "artifacts-dir" => self.artifacts_dir = value.to_string(),
            "tile-name" | "tile_name" => self.tile_name = value.to_string(),
            "xla-replicas" | "xla_replicas" => {
                self.xla_replicas = value.parse().map_err(|_| bad("usize"))?
            }
            "sample-period-us" => {
                self.sample_period_us = value.parse().map_err(|_| bad("u64"))?
            }
            "sim-cpus" | "sim_cpus" => self.sim_cpus = value.parse().map_err(|_| bad("usize"))?,
            "seed" => self.seed = value.parse().map_err(|_| bad("u64"))?,
            "lanes" => self.lanes = value.parse().map_err(|_| bad("usize"))?,
            "queue-depth" | "queue_depth" => {
                self.queue_depth = value.parse().map_err(|_| bad("usize"))?
            }
            "batch-window-us" | "batch_window_us" => {
                self.batch_window_us = value.parse().map_err(|_| bad("u64"))?
            }
            "batch-max" | "batch_max" => {
                self.batch_max = value.parse().map_err(|_| bad("usize"))?
            }
            "arrival-rate" | "arrival_rate" => {
                self.arrival_rate_hz = value.parse().map_err(|_| bad("f64"))?
            }
            "slo-p99-ms" | "slo_p99_ms" => {
                self.slo_p99_ms = value.parse().map_err(|_| bad("f64"))?
            }
            "max-pixels" | "max_pixels" => {
                self.max_pixels = value.parse().map_err(|_| bad("usize"))?
            }
            "clock" => {
                self.clock = ClockMode::parse(value).ok_or_else(|| bad("clock"))?
            }
            "cache-mb" | "cache_mb" => {
                self.cache_mb = value.parse().map_err(|_| bad("usize"))?
            }
            "cache-shards" | "cache_shards" => {
                self.cache_shards = value.parse().map_err(|_| bad("usize"))?
            }
            "cache-admit-ns-per-byte" | "cache_admit_ns_per_byte" => {
                self.cache_admit_ns_per_byte = value.parse().map_err(|_| bad("f64"))?
            }
            "stream-cache" | "stream_cache" => {
                self.stream_cache = parse_bool(value).ok_or_else(|| bad("bool"))?
            }
            "inflight" => self.inflight = value.parse().map_err(|_| bad("usize"))?,
            "delta-gate" | "delta_gate" => {
                self.delta_gate = DeltaMode::parse(value).ok_or_else(|| bad("delta-gate"))?
            }
            "frame-budget-ms" | "frame_budget_ms" => {
                self.frame_budget_ms = value.parse().map_err(|_| bad("f64"))?
            }
            "drop-policy" | "drop_policy" => {
                self.drop_policy = DropPolicy::parse(value).ok_or_else(|| bad("drop-policy"))?
            }
            "telemetry-log" | "telemetry_log" => self.telemetry_log = value.to_string(),
            "telemetry-interval-ms" | "telemetry_interval_ms" => {
                self.telemetry_interval_ms = value.parse().map_err(|_| bad("f64"))?
            }
            "overload-policy" | "overload_policy" => {
                self.overload_policy = OverloadPolicy::parse(value)?
            }
            "slo-window" | "slo_window" => {
                self.slo_window = value.parse().map_err(|_| bad("usize"))?
            }
            "alert-log" | "alert_log" => self.alert_log = value.to_string(),
            "cluster-port" | "cluster_port" => {
                self.cluster_port = value.parse().map_err(|_| bad("u16"))?
            }
            "worker-heartbeat-ms" | "worker_heartbeat_ms" => {
                self.worker_heartbeat_ms = value.parse().map_err(|_| bad("u64"))?
            }
            "trace-log" | "trace_log" => self.trace_log = value.to_string(),
            "obs-port" | "obs_port" => {
                self.obs_port = value.parse().map_err(|_| bad("u16"))?
            }
            "worker-telemetry-ms" | "worker_telemetry_ms" => {
                self.worker_telemetry_ms = value.parse().map_err(|_| bad("f64"))?
            }
            "trace-sample" | "trace_sample" => self.trace_sample = value.to_string(),
            "anomaly-sigma" | "anomaly_sigma" => {
                self.anomaly_sigma = value.parse().map_err(|_| bad("f64"))?
            }
            _ => return Err(Error::Config(format!("unknown config key `{key}`"))),
        }
        Ok(())
    }

    /// Every key spelling accepted by [`RunConfig::set`]. `cannyd` uses
    /// this to reject unknown `--flags` up front; keep it in lockstep
    /// with the `set` match (a test enforces the forward direction).
    pub const KEYS: &'static [&'static str] = &[
        "engine",
        "workers",
        "lo",
        "hi",
        "tile",
        "parallel-hysteresis",
        "parallel_hysteresis",
        "band-grain",
        "band_grain",
        "artifacts",
        "artifacts-dir",
        "tile-name",
        "tile_name",
        "xla-replicas",
        "xla_replicas",
        "sample-period-us",
        "sim-cpus",
        "sim_cpus",
        "seed",
        "lanes",
        "queue-depth",
        "queue_depth",
        "batch-window-us",
        "batch_window_us",
        "batch-max",
        "batch_max",
        "arrival-rate",
        "arrival_rate",
        "slo-p99-ms",
        "slo_p99_ms",
        "max-pixels",
        "max_pixels",
        "clock",
        "cache-mb",
        "cache_mb",
        "cache-shards",
        "cache_shards",
        "cache-admit-ns-per-byte",
        "cache_admit_ns_per_byte",
        "stream-cache",
        "stream_cache",
        "inflight",
        "delta-gate",
        "delta_gate",
        "frame-budget-ms",
        "frame_budget_ms",
        "drop-policy",
        "drop_policy",
        "telemetry-log",
        "telemetry_log",
        "telemetry-interval-ms",
        "telemetry_interval_ms",
        "overload-policy",
        "overload_policy",
        "slo-window",
        "slo_window",
        "alert-log",
        "alert_log",
        "cluster-port",
        "cluster_port",
        "worker-heartbeat-ms",
        "worker_heartbeat_ms",
        "trace-log",
        "trace_log",
        "obs-port",
        "obs_port",
        "worker-telemetry-ms",
        "worker_telemetry_ms",
        "trace-sample",
        "trace_sample",
        "anomaly-sigma",
        "anomaly_sigma",
    ];

    /// Is `key` a config key `set` would accept?
    pub fn is_known_key(key: &str) -> bool {
        Self::KEYS.contains(&key)
    }

    /// Boolean config keys: on the CLI, `--flag` with no value means
    /// `true`. The single source of the flag grammar — `apply_cli` and
    /// `cannyd`'s pre-parser both consult it.
    pub fn is_flag_key(key: &str) -> bool {
        matches!(
            key,
            "parallel-hysteresis" | "parallel_hysteresis" | "stream-cache" | "stream_cache"
        )
    }

    /// Load `key = value` lines (# comments, blank lines ok).
    pub fn load_file(&mut self, path: &Path) -> Result<()> {
        let text = std::fs::read_to_string(path)?;
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line.split_once('=').ok_or_else(|| {
                Error::Config(format!("{}:{}: expected key = value", path.display(), lineno + 1))
            })?;
            self.set(k.trim(), v.trim())?;
        }
        Ok(())
    }

    /// Parse CLI args of the form `--key value` / `--key=value` /
    /// `--flag`. Returns positional (non-flag) args.
    pub fn apply_cli(&mut self, args: &[String]) -> Result<Vec<String>> {
        let mut positional = Vec::new();
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    self.set(k, v)?;
                } else if Self::is_flag_key(stripped) {
                    self.set(stripped, "true")?;
                } else {
                    let v = args.get(i + 1).ok_or_else(|| {
                        Error::Config(format!("--{stripped} needs a value"))
                    })?;
                    self.set(stripped, v)?;
                    i += 1;
                }
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        Ok(positional)
    }

    /// Validate combined settings.
    pub fn validate(&self) -> Result<()> {
        self.params.validate()?;
        if self.sim_cpus == 0 {
            return Err(Error::Config("sim-cpus must be >= 1".into()));
        }
        if self.lanes == 0 {
            return Err(Error::Config("lanes must be >= 1".into()));
        }
        if self.queue_depth == 0 {
            return Err(Error::Config("queue-depth must be >= 1".into()));
        }
        if self.batch_max == 0 {
            return Err(Error::Config("batch-max must be >= 1".into()));
        }
        if !(self.arrival_rate_hz.is_finite() && self.arrival_rate_hz > 0.0) {
            return Err(Error::Config("arrival-rate must be > 0".into()));
        }
        if !(self.slo_p99_ms.is_finite() && self.slo_p99_ms > 0.0) {
            return Err(Error::Config("slo-p99-ms must be > 0".into()));
        }
        if self.cache_shards == 0 {
            return Err(Error::Config("cache-shards must be >= 1".into()));
        }
        if !(self.cache_admit_ns_per_byte.is_finite() && self.cache_admit_ns_per_byte >= 0.0) {
            return Err(Error::Config("cache-admit-ns-per-byte must be >= 0".into()));
        }
        if self.inflight == 0 {
            return Err(Error::Config("inflight must be >= 1".into()));
        }
        if !(self.frame_budget_ms.is_finite() && self.frame_budget_ms >= 0.0) {
            return Err(Error::Config("frame-budget-ms must be >= 0".into()));
        }
        if !(self.telemetry_interval_ms.is_finite() && self.telemetry_interval_ms > 0.0) {
            return Err(Error::Config("telemetry-interval-ms must be > 0".into()));
        }
        if self.slo_window == 0 {
            return Err(Error::Config("slo-window must be >= 1".into()));
        }
        if self.worker_heartbeat_ms == 0 {
            return Err(Error::Config("worker-heartbeat-ms must be >= 1".into()));
        }
        if !(self.worker_telemetry_ms.is_finite() && self.worker_telemetry_ms > 0.0) {
            return Err(Error::Config("worker-telemetry-ms must be > 0".into()));
        }
        // Parse-check the sampling spec now (the SLO target passed here
        // is irrelevant to validity).
        crate::obs::sample::TraceSampler::from_spec(&self.trace_sample, 0)?;
        if !(self.anomaly_sigma.is_finite() && self.anomaly_sigma >= 0.0) {
            return Err(Error::Config("anomaly-sigma must be >= 0".into()));
        }
        Ok(())
    }

    /// Render as a `key = value` map (diagnostics / `cannyd info`).
    pub fn to_map(&self) -> BTreeMap<String, String> {
        let mut m = BTreeMap::new();
        m.insert("engine".into(), self.engine.name().into());
        m.insert("workers".into(), self.workers.to_string());
        m.insert("lo".into(), self.params.lo.to_string());
        m.insert("hi".into(), self.params.hi.to_string());
        m.insert("tile".into(), self.params.tile.to_string());
        m.insert(
            "parallel-hysteresis".into(),
            self.params.parallel_hysteresis.to_string(),
        );
        m.insert("artifacts".into(), self.artifacts_dir.clone());
        m.insert("sim-cpus".into(), self.sim_cpus.to_string());
        m.insert("seed".into(), self.seed.to_string());
        m.insert("lanes".into(), self.lanes.to_string());
        m.insert("queue-depth".into(), self.queue_depth.to_string());
        m.insert("batch-window-us".into(), self.batch_window_us.to_string());
        m.insert("batch-max".into(), self.batch_max.to_string());
        m.insert("arrival-rate".into(), self.arrival_rate_hz.to_string());
        m.insert("slo-p99-ms".into(), self.slo_p99_ms.to_string());
        m.insert("max-pixels".into(), self.max_pixels.to_string());
        m.insert("clock".into(), self.clock.name().to_string());
        m.insert("cache-mb".into(), self.cache_mb.to_string());
        m.insert("cache-shards".into(), self.cache_shards.to_string());
        m.insert(
            "cache-admit-ns-per-byte".into(),
            self.cache_admit_ns_per_byte.to_string(),
        );
        m.insert("stream-cache".into(), self.stream_cache.to_string());
        m.insert("inflight".into(), self.inflight.to_string());
        m.insert("delta-gate".into(), self.delta_gate.name());
        m.insert("frame-budget-ms".into(), self.frame_budget_ms.to_string());
        m.insert("drop-policy".into(), self.drop_policy.name().to_string());
        m.insert("telemetry-log".into(), self.telemetry_log.clone());
        m.insert("telemetry-interval-ms".into(), self.telemetry_interval_ms.to_string());
        m.insert("overload-policy".into(), self.overload_policy.name().to_string());
        m.insert("slo-window".into(), self.slo_window.to_string());
        m.insert("alert-log".into(), self.alert_log.clone());
        m.insert("cluster-port".into(), self.cluster_port.to_string());
        m.insert("worker-heartbeat-ms".into(), self.worker_heartbeat_ms.to_string());
        m.insert("trace-log".into(), self.trace_log.clone());
        m.insert("obs-port".into(), self.obs_port.to_string());
        m.insert("worker-telemetry-ms".into(), self.worker_telemetry_ms.to_string());
        m.insert("trace-sample".into(), self.trace_sample.clone());
        m.insert("anomaly-sigma".into(), self.anomaly_sigma.to_string());
        m
    }
}

fn parse_bool(v: &str) -> Option<bool> {
    match v {
        "1" | "true" | "yes" | "on" => Some(true),
        "0" | "false" | "no" | "off" => Some(false),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        RunConfig::default().validate().unwrap();
    }

    #[test]
    fn set_and_get() {
        let mut c = RunConfig::default();
        c.set("engine", "serial").unwrap();
        c.set("workers", "8").unwrap();
        c.set("lo", "0.03").unwrap();
        c.set("parallel-hysteresis", "true").unwrap();
        assert_eq!(c.engine, Engine::Serial);
        assert_eq!(c.workers, 8);
        assert!((c.params.lo - 0.03).abs() < 1e-9);
        assert!(c.params.parallel_hysteresis);
    }

    #[test]
    fn rejects_unknown_and_bad_values() {
        let mut c = RunConfig::default();
        assert!(c.set("nope", "1").is_err());
        assert!(c.set("workers", "lots").is_err());
        assert!(c.set("engine", "gpu").is_err());
    }

    #[test]
    fn cli_parsing_forms() {
        let mut c = RunConfig::default();
        let args: Vec<String> = ["run", "--workers", "4", "--engine=tiled", "--parallel-hysteresis", "x.pgm"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let pos = c.apply_cli(&args).unwrap();
        assert_eq!(pos, vec!["run", "x.pgm"]);
        assert_eq!(c.workers, 4);
        assert_eq!(c.engine, Engine::TiledPatterns);
        assert!(c.params.parallel_hysteresis);
    }

    #[test]
    fn cli_underscore_bool_spelling() {
        let mut c = RunConfig::default();
        let args: Vec<String> =
            ["--parallel_hysteresis", "--workers", "2"].iter().map(|s| s.to_string()).collect();
        c.apply_cli(&args).unwrap();
        assert!(c.params.parallel_hysteresis);
        assert_eq!(c.workers, 2);
    }

    #[test]
    fn cli_missing_value_errors() {
        let mut c = RunConfig::default();
        let args = vec!["--workers".to_string()];
        assert!(c.apply_cli(&args).is_err());
    }

    #[test]
    fn file_loading_with_comments() {
        let path = std::env::temp_dir().join("canny_cfg_test.conf");
        std::fs::write(&path, "# comment\nengine = xla\n\nworkers = 2 # trailing\n").unwrap();
        let mut c = RunConfig::default();
        c.load_file(&path).unwrap();
        assert_eq!(c.engine, Engine::PatternsXla);
        assert_eq!(c.workers, 2);
    }

    #[test]
    fn file_syntax_error_reported_with_line() {
        let path = std::env::temp_dir().join("canny_cfg_bad.conf");
        std::fs::write(&path, "workers 4\n").unwrap();
        let err = RunConfig::default().load_file(&path).map(|_| ()).unwrap_err().to_string();
        assert!(err.contains(":1:"), "{err}");
    }

    #[test]
    fn to_map_contains_core_keys() {
        let m = RunConfig::default().to_map();
        assert!(m.contains_key("engine"));
        assert!(m.contains_key("tile"));
        assert!(m.contains_key("lanes"));
        assert!(m.contains_key("queue-depth"));
        assert!(m.contains_key("batch-window-us"));
    }

    #[test]
    fn clock_key_parses_both_modes() {
        let mut c = RunConfig::default();
        assert_eq!(c.clock, ClockMode::Virtual);
        c.set("clock", "wall").unwrap();
        assert_eq!(c.clock, ClockMode::Wall);
        c.set("clock", "virtual").unwrap();
        assert_eq!(c.clock, ClockMode::Virtual);
        assert!(c.set("clock", "sundial").is_err());
        assert_eq!(c.to_map().get("clock").map(String::as_str), Some("virtual"));
    }

    #[test]
    fn serve_keys_set_and_validate() {
        let mut c = RunConfig::default();
        c.set("lanes", "4").unwrap();
        c.set("queue-depth", "16").unwrap();
        c.set("batch-window-us", "500").unwrap();
        c.set("batch-max", "12").unwrap();
        c.set("arrival-rate", "1500.5").unwrap();
        c.set("slo-p99-ms", "10").unwrap();
        assert_eq!(c.lanes, 4);
        assert_eq!(c.queue_depth, 16);
        assert_eq!(c.batch_window_us, 500);
        assert_eq!(c.batch_max, 12);
        assert!((c.arrival_rate_hz - 1500.5).abs() < 1e-9);
        c.validate().unwrap();
        c.set("lanes", "0").unwrap();
        assert!(c.validate().is_err());
    }

    #[test]
    fn cache_keys_set_and_validate() {
        let mut c = RunConfig::default();
        assert_eq!(c.cache_mb, 64, "cache tier enabled by default");
        assert_eq!(c.cache_shards, 8);
        assert!(!c.stream_cache, "stream sharing is opt-in");
        c.set("cache-mb", "16").unwrap();
        c.set("cache-shards", "4").unwrap();
        c.set("cache-admit-ns-per-byte", "2.5").unwrap();
        c.set("stream-cache", "true").unwrap();
        assert_eq!(c.cache_mb, 16);
        assert_eq!(c.cache_shards, 4);
        assert!((c.cache_admit_ns_per_byte - 2.5).abs() < 1e-12);
        assert!(c.stream_cache);
        c.validate().unwrap();
        c.set("cache_mb", "0").unwrap();
        assert_eq!(c.cache_mb, 0, "0 disables the tier and still validates");
        c.validate().unwrap();
        c.set("cache-shards", "0").unwrap();
        assert!(c.validate().is_err());
        c.set("cache-shards", "2").unwrap();
        c.set("cache-admit-ns-per-byte", "-1").unwrap();
        assert!(c.validate().is_err());
        // `--stream-cache` is a bare flag on the CLI.
        assert!(RunConfig::is_flag_key("stream-cache"));
        let mut f = RunConfig::default();
        f.apply_cli(&["--stream-cache".to_string()]).unwrap();
        assert!(f.stream_cache);
        let m = RunConfig::default().to_map();
        assert_eq!(m.get("cache-mb").map(String::as_str), Some("64"));
        assert_eq!(m.get("cache-shards").map(String::as_str), Some("8"));
        assert_eq!(m.get("stream-cache").map(String::as_str), Some("false"));
    }

    #[test]
    fn stream_keys_set_and_validate() {
        let mut c = RunConfig::default();
        assert_eq!(c.delta_gate, DeltaMode::Gate(0.0), "default gate is exact reuse");
        assert_eq!(c.drop_policy, DropPolicy::Drop);
        c.set("inflight", "8").unwrap();
        c.set("delta-gate", "off").unwrap();
        c.set("frame-budget-ms", "16.7").unwrap();
        c.set("drop-policy", "none").unwrap();
        assert_eq!(c.inflight, 8);
        assert_eq!(c.delta_gate, DeltaMode::Off);
        assert!((c.frame_budget_ms - 16.7).abs() < 1e-9);
        assert_eq!(c.drop_policy, DropPolicy::Keep);
        c.set("delta_gate", "0.02").unwrap();
        assert_eq!(c.delta_gate, DeltaMode::Gate(0.02));
        c.validate().unwrap();
        assert!(c.set("delta-gate", "-1").is_err());
        assert!(c.set("drop-policy", "explode").is_err());
        c.set("inflight", "0").unwrap();
        assert!(c.validate().is_err());
        c.set("inflight", "4").unwrap();
        c.set("frame-budget-ms", "-2").unwrap();
        assert!(c.validate().is_err());
        let m = RunConfig::default().to_map();
        assert_eq!(m.get("delta-gate").map(String::as_str), Some("0"));
        assert_eq!(m.get("drop-policy").map(String::as_str), Some("drop"));
        assert_eq!(m.get("inflight").map(String::as_str), Some("4"));
    }

    #[test]
    fn ops_plane_keys_set_and_validate() {
        let mut c = RunConfig::default();
        assert!(c.telemetry_log.is_empty(), "telemetry stream is opt-in");
        assert!((c.telemetry_interval_ms - 100.0).abs() < 1e-9);
        assert_eq!(c.overload_policy, OverloadPolicy::None);
        assert_eq!(c.slo_window, DEFAULT_SLO_WINDOW);
        c.set("telemetry-log", "/tmp/t.jsonl").unwrap();
        c.set("telemetry-interval-ms", "2.5").unwrap();
        c.set("overload-policy", "degrade-to-front-only").unwrap();
        c.set("slo_window", "16").unwrap();
        assert_eq!(c.telemetry_log, "/tmp/t.jsonl");
        assert!((c.telemetry_interval_ms - 2.5).abs() < 1e-12);
        assert_eq!(c.overload_policy, OverloadPolicy::DegradeFront);
        assert_eq!(c.slo_window, 16);
        c.validate().unwrap();
        assert!(c.set("overload-policy", "panic").is_err());
        c.set("telemetry-interval-ms", "0").unwrap();
        assert!(c.validate().is_err());
        c.set("telemetry-interval-ms", "100").unwrap();
        c.set("slo-window", "0").unwrap();
        assert!(c.validate().is_err());
        let m = RunConfig::default().to_map();
        assert_eq!(m.get("overload-policy").map(String::as_str), Some("none"));
        assert_eq!(m.get("slo-window").map(String::as_str), Some("64"));
        assert_eq!(m.get("telemetry-interval-ms").map(String::as_str), Some("100"));
    }

    #[test]
    fn cluster_and_alert_keys_set_and_validate() {
        let mut c = RunConfig::default();
        assert!(c.alert_log.is_empty(), "alerting is opt-in");
        assert_eq!(c.cluster_port, 0, "ephemeral port by default");
        assert_eq!(c.worker_heartbeat_ms, 500);
        c.set("alert-log", "stderr").unwrap();
        c.set("cluster-port", "40123").unwrap();
        c.set("worker-heartbeat-ms", "250").unwrap();
        assert_eq!(c.alert_log, "stderr");
        assert_eq!(c.cluster_port, 40123);
        assert_eq!(c.worker_heartbeat_ms, 250);
        c.validate().unwrap();
        assert!(c.set("cluster-port", "70000").is_err(), "u16 range enforced");
        c.set("worker_heartbeat_ms", "0").unwrap();
        assert!(c.validate().is_err());
        let m = RunConfig::default().to_map();
        assert_eq!(m.get("cluster-port").map(String::as_str), Some("0"));
        assert_eq!(m.get("worker-heartbeat-ms").map(String::as_str), Some("500"));
        assert_eq!(m.get("alert-log").map(String::as_str), Some(""));
    }

    #[test]
    fn observability_keys_set_and_validate() {
        let mut c = RunConfig::default();
        assert!(c.trace_log.is_empty(), "tracing is opt-in");
        assert_eq!(c.obs_port, 0, "endpoint disabled by default");
        assert!((c.worker_telemetry_ms - 100.0).abs() < 1e-9);
        assert_eq!(c.trace_sample, "all", "tail sampling keeps everything by default");
        assert_eq!(c.anomaly_sigma, 0.0, "anomaly detection is opt-in");
        c.set("trace-log", "/tmp/trace.json").unwrap();
        c.set("obs-port", "47117").unwrap();
        c.set("worker-telemetry-ms", "25.5").unwrap();
        c.set("trace-sample", "slow:2.5").unwrap();
        c.set("anomaly_sigma", "3.5").unwrap();
        assert_eq!(c.trace_log, "/tmp/trace.json");
        assert_eq!(c.obs_port, 47117);
        assert!((c.worker_telemetry_ms - 25.5).abs() < 1e-12);
        assert_eq!(c.trace_sample, "slow:2.5");
        assert!((c.anomaly_sigma - 3.5).abs() < 1e-12);
        c.validate().unwrap();
        assert!(c.set("obs-port", "70000").is_err(), "u16 range enforced");
        assert!(c.set("anomaly-sigma", "three").is_err());
        c.set("trace_sample", "sometimes").unwrap();
        assert!(c.validate().is_err(), "bad sampling specs fail validate");
        c.set("trace-sample", "head:8").unwrap();
        c.set("anomaly-sigma", "-1").unwrap();
        assert!(c.validate().is_err(), "negative sigma fails validate");
        c.set("anomaly-sigma", "0").unwrap();
        c.set("worker_telemetry_ms", "0").unwrap();
        assert!(c.validate().is_err());
        let m = RunConfig::default().to_map();
        assert_eq!(m.get("trace-log").map(String::as_str), Some(""));
        assert_eq!(m.get("obs-port").map(String::as_str), Some("0"));
        assert_eq!(m.get("worker-telemetry-ms").map(String::as_str), Some("100"));
        assert_eq!(m.get("trace-sample").map(String::as_str), Some("all"));
        assert_eq!(m.get("anomaly-sigma").map(String::as_str), Some("0"));
    }

    #[test]
    fn every_known_key_is_settable() {
        for &key in RunConfig::KEYS {
            let mut c = RunConfig::default();
            let sample = match key {
                "engine" => "patterns",
                "artifacts" | "artifacts-dir" => "artifacts",
                "tile-name" | "tile_name" => "t128",
                "parallel-hysteresis" | "parallel_hysteresis" => "true",
                "stream-cache" | "stream_cache" => "true",
                "clock" => "wall",
                "delta-gate" | "delta_gate" => "0.05",
                "drop-policy" | "drop_policy" => "degrade",
                "telemetry-log" | "telemetry_log" => "/tmp/telemetry.jsonl",
                "overload-policy" | "overload_policy" => "reject-new",
                "alert-log" | "alert_log" => "stderr",
                _ => "4", // parses as usize / u64 / f32 / f64 alike
            };
            c.set(key, sample).unwrap_or_else(|e| panic!("KEYS lists `{key}` but set failed: {e}"));
            assert!(RunConfig::is_known_key(key));
        }
        assert!(!RunConfig::is_known_key("nope"));
    }
}
