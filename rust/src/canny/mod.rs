//! The Canny Edge Detector operator: native Rust stages that mirror the
//! L1 Pallas kernels (same constants, same tie rules — see
//! `python/compile/kernels/`), the serial + parallel hysteresis, and
//! the [`pipeline`] module tying everything into the three execution
//! engines (Serial / Patterns / PatternsXla).

pub mod consts;
pub mod gaussian;
pub mod hysteresis;
pub mod nms;
pub mod pipeline;
pub mod plan;
pub mod sobel;
pub mod threshold;

pub use pipeline::{CannyParams, CannyPipeline, DetectOutput, Engine, StageTimes};
pub use plan::{Artifact, PlanEntry, PlanOutput, StageKind, StagePlan, StageRecord};
pub use threshold::{CLASS_NONE, CLASS_STRONG, CLASS_WEAK};

use crate::image::ImageF32;

/// Reference whole-image serial Canny *front-end* (pre-hysteresis):
/// pads by the halo and runs gaussian → sobel → nms → threshold,
/// returning the class map and the suppressed magnitude, both
/// image-sized. Every engine must agree with this function exactly.
pub fn front_serial(img: &ImageF32, lo: f32, hi: f32) -> (ImageF32, ImageF32) {
    let padded = img.pad_replicate(consts::HALO);
    let g = gaussian::gaussian(&padded);
    let (mag, dir) = sobel::sobel(&g);
    let nm = nms::nms(&mag, &dir);
    debug_assert_eq!(nm.width(), img.width());
    debug_assert_eq!(nm.height(), img.height());
    let cls = threshold::threshold(&nm, lo, hi);
    (cls, nm)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::synth::{generate, Scene};

    #[test]
    fn front_serial_shapes() {
        let img = generate(Scene::Shapes { seed: 3 }, 50, 40);
        let (cls, nm) = front_serial(&img, 0.05, 0.15);
        assert_eq!((cls.width(), cls.height()), (50, 40));
        assert_eq!((nm.width(), nm.height()), (50, 40));
        // Class values restricted to {0, 1, 2}.
        assert!(cls.data().iter().all(|&v| v == 0.0 || v == 1.0 || v == 2.0));
    }

    #[test]
    fn front_detects_checker_edges() {
        let img = generate(Scene::Checker { cell: 8 }, 64, 64);
        let (cls, _) = front_serial(&img, 0.05, 0.15);
        let strong = cls.data().iter().filter(|&&v| v == 2.0).count();
        assert!(strong > 100, "strong={strong}");
    }

    #[test]
    fn flat_image_has_no_edges() {
        let img = ImageF32::zeros(32, 32);
        let (cls, nm) = front_serial(&img, 0.05, 0.15);
        assert!(cls.data().iter().all(|&v| v == 0.0));
        assert!(nm.data().iter().all(|&v| v == 0.0));
    }
}
