//! Stage 4a — double-threshold classification (the per-pixel, parallel
//! half of hysteresis), mirroring `python/compile/kernels/threshold.py`.

use crate::image::ImageF32;

/// Suppressed / not an edge.
pub const CLASS_NONE: f32 = 0.0;
/// Weak: kept only if connected to a strong pixel (stage 4b).
pub const CLASS_WEAK: f32 = 1.0;
/// Strong: definitely an edge.
pub const CLASS_STRONG: f32 = 2.0;

/// Classify one row.
#[inline]
pub fn threshold_row_into(src_row: &[f32], lo: f32, hi: f32, dst_row: &mut [f32]) {
    debug_assert_eq!(src_row.len(), dst_row.len());
    for (d, &m) in dst_row.iter_mut().zip(src_row) {
        *d = if m >= hi {
            CLASS_STRONG
        } else if m >= lo {
            CLASS_WEAK
        } else {
            CLASS_NONE
        };
    }
}

/// Double threshold. (H, W) → (H, W) class map in {0, 1, 2}.
pub fn threshold(m: &ImageF32, lo: f32, hi: f32) -> ImageF32 {
    assert!(lo <= hi, "lo {lo} > hi {hi}");
    let mut out = ImageF32::zeros(m.width(), m.height());
    let w = m.width();
    for y in 0..m.height() {
        let dst = &mut out.data_mut()[y * w..(y + 1) * w];
        threshold_row_into(m.row(y), lo, hi, dst);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn boundary_semantics_inclusive() {
        let m = ImageF32::from_vec(6, 1, vec![0.0, 0.399, 0.4, 1.199, 1.2, 9.0]).unwrap();
        let c = threshold(&m, 0.4, 1.2);
        assert_eq!(c.data(), &[0.0, 0.0, 1.0, 1.0, 2.0, 2.0]);
    }

    #[test]
    fn equal_lo_hi_means_no_weak() {
        let m = ImageF32::from_vec(3, 1, vec![0.1, 0.5, 0.9]).unwrap();
        let c = threshold(&m, 0.5, 0.5);
        assert_eq!(c.data(), &[0.0, 2.0, 2.0]);
    }

    #[test]
    #[should_panic(expected = "lo")]
    fn rejects_inverted_thresholds() {
        let m = ImageF32::zeros(2, 2);
        let _ = threshold(&m, 0.9, 0.1);
    }
}
