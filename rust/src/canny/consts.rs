//! Numeric contract with the Pallas kernels — these literals are the
//! f32 roundings of `python/compile/kernels/constants.py` and are
//! guarded by `python/tests/test_constants.py` on the python side and
//! the tests below on this side. Do not change one without the other.

/// Normalized 5-tap Gaussian (sigma = 1.4), f32-exact to the python taps.
pub const GAUSS5: [f32; 5] =
    [0.110_209_46, 0.236_912_01, 0.305_757_05, 0.236_912_01, 0.110_209_46];

/// tan(22.5°): direction-bin threshold (bin 0 vs diagonal).
pub const TAN22: f32 = 0.414_213_56;

/// tan(67.5°): direction-bin threshold (diagonal vs bin 2).
pub const TAN67: f32 = 2.414_213_56;

/// One-side halo consumed by the full front: gaussian 2 + sobel 1 + nms 1.
pub const HALO: usize = 4;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gauss_taps_normalized_and_symmetric() {
        let sum: f32 = GAUSS5.iter().sum();
        assert!((sum - 1.0).abs() < 1e-6, "sum={sum}");
        assert_eq!(GAUSS5[0], GAUSS5[4]);
        assert_eq!(GAUSS5[1], GAUSS5[3]);
    }

    #[test]
    fn gauss_taps_match_python_formula() {
        // exp(-k^2 / (2 * 1.4^2)) normalized, rounded through f32 — the
        // definition in python/compile/kernels/constants.py.
        let raw: Vec<f64> =
            (-2..=2).map(|k| (-((k * k) as f64) / (2.0 * 1.4 * 1.4)).exp()).collect();
        let s: f64 = raw.iter().sum();
        for (i, &r) in raw.iter().enumerate() {
            let expect = (r / s) as f32;
            assert!(
                (GAUSS5[i] - expect).abs() < 2e-7,
                "tap {i}: {} vs {expect}",
                GAUSS5[i]
            );
        }
    }

    #[test]
    fn tan_thresholds_match() {
        assert!((TAN22 - (22.5f64.to_radians().tan() as f32)).abs() < 1e-7);
        assert!((TAN67 - (67.5f64.to_radians().tan() as f32)).abs() < 1e-6);
    }
}
