//! Stage 3 — non-maximum suppression, mirroring
//! `python/compile/kernels/nms.py`: keep the centre magnitude iff it is
//! >= both neighbours along the quantized gradient direction (ties
//! keep — deterministic and identical across all three layers).

use crate::image::ImageF32;

/// Compute one NMS output row `y` (of the (H-2, W-2) result).
///
/// §Perf P2 note: an offset-LUT dispatch (`d as usize` indexing a
/// neighbour table) was tried and REVERTED — the indirect loads beat
/// the predictable compare chain by -30% on this host; natural scenes
/// are dominated by bins 0/2, which the branch predictor eats.
#[inline]
pub fn nms_row_into(mag: &ImageF32, dir: &ImageF32, y: usize, dst_row: &mut [f32]) {
    let w = mag.width();
    let w_out = w - 2;
    debug_assert_eq!(dst_row.len(), w_out);
    let up = mag.row(y);
    let mid = mag.row(y + 1);
    let down = mag.row(y + 2);
    let drow = dir.row(y + 1);
    for (j, dst) in dst_row.iter_mut().enumerate() {
        let m = mid[j + 1];
        let d = drow[j + 1];
        let (n1, n2) = if d == 0.0 {
            (mid[j], mid[j + 2]) // E/W
        } else if d == 2.0 {
            (up[j + 1], down[j + 1]) // N/S
        } else if d == 1.0 {
            (up[j], down[j + 2]) // NW/SE
        } else {
            (up[j + 2], down[j]) // NE/SW
        };
        *dst = if m >= n1 && m >= n2 { m } else { 0.0 };
    }
}

/// Non-maximum suppression. (H, W) ×2 → (H-2, W-2).
pub fn nms(mag: &ImageF32, dir: &ImageF32) -> ImageF32 {
    let (w, h) = (mag.width(), mag.height());
    assert_eq!((w, h), (dir.width(), dir.height()));
    assert!(w >= 3 && h >= 3, "nms needs >= 3x3");
    let (w_out, h_out) = (w - 2, h - 2);
    let mut out = ImageF32::zeros(w_out, h_out);
    for y in 0..h_out {
        let dst = &mut out.data_mut()[y * w_out..(y + 1) * w_out];
        nms_row_into(mag, dir, y, dst);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn img(w: usize, h: usize, f: impl Fn(usize, usize) -> f32) -> ImageF32 {
        let mut im = ImageF32::zeros(w, h);
        for y in 0..h {
            for x in 0..w {
                im.set(y, x, f(y, x));
            }
        }
        im
    }

    #[test]
    fn ridge_survives_flanks_suppressed() {
        // Vertical ridge at x=4, direction bin 0 (compare E/W).
        let mag = img(9, 9, |_, x| match x {
            4 => 2.0,
            3 | 5 => 1.0,
            _ => 0.0,
        });
        let dir = img(9, 9, |_, _| 0.0);
        let out = nms(&mag, &dir);
        for y in 0..7 {
            assert_eq!(out.get(y, 3), 2.0); // ridge kept (out x=3 == in x=4)
            assert_eq!(out.get(y, 2), 0.0); // flank suppressed
            assert_eq!(out.get(y, 4), 0.0);
        }
    }

    #[test]
    fn plateau_ties_keep_both() {
        // Two equal columns: >= semantics keeps both (documented choice).
        let mag = img(9, 9, |_, x| if x == 4 || x == 5 { 1.0 } else { 0.0 });
        let dir = img(9, 9, |_, _| 0.0);
        let out = nms(&mag, &dir);
        assert_eq!(out.get(3, 3), 1.0);
        assert_eq!(out.get(3, 4), 1.0);
    }

    #[test]
    fn direction_selects_neighbours() {
        // A bright pixel with a brighter N neighbour: suppressed under
        // bin 2 (N/S), kept under bin 0 (E/W).
        let mag = img(5, 5, |y, x| match (y, x) {
            (1, 2) => 3.0,
            (2, 2) => 2.0,
            _ => 0.0,
        });
        let bin2 = img(5, 5, |_, _| 2.0);
        let bin0 = img(5, 5, |_, _| 0.0);
        assert_eq!(nms(&mag, &bin2).get(1, 1), 0.0);
        assert_eq!(nms(&mag, &bin0).get(1, 1), 2.0);
    }

    #[test]
    fn zero_in_zero_out() {
        let z = ImageF32::zeros(8, 8);
        let out = nms(&z, &z);
        assert!(out.data().iter().all(|&v| v == 0.0));
    }
}
