//! The Canny pipeline with its execution engines — the heart of the
//! reproduction:
//!
//! * [`Engine::Serial`] — the paper's *suboptimal* baseline: every
//!   stage whole-image, one thread (Figures 8/9b/10).
//! * [`Engine::Patterns`] — the paper's contribution: each stage
//!   parallelized with the map/stencil patterns over row bands
//!   (`cilk_for` style), hysteresis left serial per the paper.
//! * [`Engine::TiledPatterns`] — fused-front tile decomposition: one
//!   task per tile runs all four front stages on a haloed window
//!   (better locality; the ablation bench compares).
//! * [`Engine::PatternsXla`] — tiles dispatched to the AOT-compiled
//!   JAX/Pallas fused front via PJRT ([`crate::runtime::XlaEngine`]),
//!   hysteresis in Rust. Python is long gone at this point.
//!
//! All engines produce the identical edge map (determinism tests
//! enforce it; XLA within f32 tolerance at class boundaries).
//!
//! Every engine path executes a [`StagePlan`] (see [`crate::canny::plan`]):
//! [`CannyPipeline::detect`] is the full image→edges plan, while
//! [`CannyPipeline::execute`] also runs partial prefixes (stop after any
//! stage) and mid-pipeline resumes (re-threshold a cached
//! suppressed-magnitude map). The fused-tile engines keep their fused
//! fast path whenever the plan covers the whole front; a *partial*
//! front prefix on those engines runs the unfused band-parallel stage
//! path instead (fusion has no per-stage boundary to stop at), which
//! produces identical artifacts by the determinism invariant.

use crate::canny::plan::{Artifact, PlanEntry, PlanOutput, StageKind, StagePlan, StageRecord};
use crate::canny::{consts, gaussian, hysteresis, nms, sobel, threshold};
use crate::error::{Error, Result};
use crate::image::tile::TileGrid;
use crate::image::{EdgeMap, ImageF32};
use crate::patterns;
use crate::runtime::XlaEngine;
use crate::scheduler::Pool;
use crate::util::timer::{thread_cpu_ns, Stopwatch};
use crate::util::SharedSlice;

/// Which implementation runs the front-end.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Engine {
    Serial,
    Patterns,
    TiledPatterns,
    PatternsXla,
}

impl Engine {
    pub fn parse(s: &str) -> Option<Engine> {
        match s {
            "serial" => Some(Engine::Serial),
            "patterns" => Some(Engine::Patterns),
            "tiled" | "tiled-patterns" => Some(Engine::TiledPatterns),
            "xla" | "patterns-xla" => Some(Engine::PatternsXla),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Engine::Serial => "serial",
            Engine::Patterns => "patterns",
            Engine::TiledPatterns => "tiled",
            Engine::PatternsXla => "xla",
        }
    }
}

/// Detector parameters.
#[derive(Clone, Copy, Debug)]
pub struct CannyParams {
    /// Low hysteresis threshold (on gradient magnitude).
    pub lo: f32,
    /// High hysteresis threshold.
    pub hi: f32,
    /// Tile core size for the tiled engines.
    pub tile: usize,
    /// Use the parallel hysteresis extension instead of the paper's
    /// serial walk.
    pub parallel_hysteresis: bool,
    /// Row-band grain for the stage-parallel engine (0 = auto).
    pub band_grain: usize,
}

impl Default for CannyParams {
    fn default() -> Self {
        CannyParams { lo: 0.05, hi: 0.15, tile: 128, parallel_hysteresis: false, band_grain: 0 }
    }
}

impl CannyParams {
    pub fn validate(&self) -> Result<()> {
        if !(self.lo.is_finite() && self.hi.is_finite()) || self.lo < 0.0 || self.hi < self.lo {
            return Err(Error::Config(format!(
                "thresholds must satisfy 0 <= lo <= hi, got lo={} hi={}",
                self.lo, self.hi
            )));
        }
        if self.tile == 0 {
            return Err(Error::Config("tile must be >= 1".into()));
        }
        Ok(())
    }
}

/// Wall-clock per stage plus per-tile CPU costs (the simulator's input).
///
/// Since the stage-graph redesign this is a **compatibility view**
/// computed from the uniform [`StageRecord`]s
/// ([`StageTimes::from_records`]); the benches, simulator specs and the
/// serving tier's end-to-end calibration keep consuming it unchanged.
#[derive(Clone, Debug, Default)]
pub struct StageTimes {
    pub pad_ns: u64,
    pub gaussian_ns: u64,
    pub sobel_ns: u64,
    pub nms_ns: u64,
    pub threshold_ns: u64,
    /// Fused front total for tiled engines (gaussian..threshold inside).
    pub front_ns: u64,
    pub hysteresis_ns: u64,
    pub total_ns: u64,
    /// Thread-CPU cost of each tile task (tiled engines only).
    pub tile_costs_ns: Vec<u64>,
}

impl StageTimes {
    /// Serial-work ns (everything not in parallel tasks).
    pub fn serial_ns(&self) -> u64 {
        self.pad_ns + self.hysteresis_ns
    }

    /// Build the legacy view from per-phase records: unfused stages map
    /// to their fields (`front_ns` = gaussian+sobel+nms+threshold, as
    /// the per-stage engines always reported), a fused span maps to
    /// `front_ns` + `tile_costs_ns`.
    pub fn from_records(records: &[StageRecord], total_ns: u64) -> StageTimes {
        let mut t = StageTimes { total_ns, ..StageTimes::default() };
        let mut fused = false;
        for r in records {
            if r.fused_from.is_some() {
                fused = true;
                t.front_ns += r.wall_ns;
                if !r.task_costs_ns.is_empty() {
                    t.tile_costs_ns = r.task_costs_ns.clone();
                }
                continue;
            }
            match r.kind {
                StageKind::Pad => t.pad_ns += r.wall_ns,
                StageKind::Gaussian => t.gaussian_ns += r.wall_ns,
                StageKind::Sobel => t.sobel_ns += r.wall_ns,
                StageKind::Nms => t.nms_ns += r.wall_ns,
                StageKind::Threshold => t.threshold_ns += r.wall_ns,
                StageKind::Hysteresis => t.hysteresis_ns += r.wall_ns,
            }
        }
        if !fused {
            t.front_ns = t.gaussian_ns + t.sobel_ns + t.nms_ns + t.threshold_ns;
        }
        t
    }

    /// Fieldwise minimum of two measurements of the *same* work — the
    /// noise-robust estimator min-of-repeats probing uses (preemption on
    /// a timeshared host only ever inflates a sample). Tile costs merge
    /// elementwise when the grids match, else the first is kept.
    pub fn min_with(&self, other: &StageTimes) -> StageTimes {
        StageTimes {
            pad_ns: self.pad_ns.min(other.pad_ns),
            gaussian_ns: self.gaussian_ns.min(other.gaussian_ns),
            sobel_ns: self.sobel_ns.min(other.sobel_ns),
            nms_ns: self.nms_ns.min(other.nms_ns),
            threshold_ns: self.threshold_ns.min(other.threshold_ns),
            front_ns: self.front_ns.min(other.front_ns),
            hysteresis_ns: self.hysteresis_ns.min(other.hysteresis_ns),
            total_ns: self.total_ns.min(other.total_ns),
            tile_costs_ns: if self.tile_costs_ns.len() == other.tile_costs_ns.len() {
                self.tile_costs_ns
                    .iter()
                    .zip(&other.tile_costs_ns)
                    .map(|(&a, &b)| a.min(b))
                    .collect()
            } else {
                self.tile_costs_ns.clone()
            },
        }
    }
}

/// Full detection output — built on top of a [`PlanOutput`]: the three
/// artifacts of the full plan moved into named fields, the legacy
/// [`StageTimes`] view, and the uniform per-phase records.
#[derive(Clone, Debug)]
pub struct DetectOutput {
    pub edges: EdgeMap,
    /// Class map (0/1/2) before connectivity.
    pub class_map: ImageF32,
    /// Suppressed gradient magnitude (for SNR metrics).
    pub nms_mag: ImageF32,
    /// Legacy per-stage view (see [`StageTimes::from_records`]).
    pub times: StageTimes,
    /// Uniform per-phase accounting (the per-stage calibration input).
    pub records: Vec<StageRecord>,
}

impl DetectOutput {
    /// Rebuild the classic output from a *full-plan* execution.
    pub fn from_plan(mut out: PlanOutput) -> Result<DetectOutput> {
        let times = out.stage_times();
        let records = std::mem::take(&mut out.records);
        let (mut edges, mut cls, mut nm) = (None, None, None);
        for a in out.artifacts {
            match a {
                Artifact::Edges(e) => edges = Some(e),
                Artifact::ClassMap(c) => cls = Some(c),
                Artifact::Suppressed(s) => nm = Some(s),
                _ => {}
            }
        }
        match (edges, cls, nm) {
            (Some(edges), Some(class_map), Some(nms_mag)) => {
                Ok(DetectOutput { edges, class_map, nms_mag, times, records })
            }
            _ => Err(Error::Config(
                "full detection plan did not yield edges + class-map + suppressed".into(),
            )),
        }
    }
}

/// The configured pipeline. Borrows its pool / XLA engine so the same
/// resources serve many detections (the batch server reuses both).
#[derive(Debug)]
pub struct CannyPipeline<'a> {
    pub engine: Engine,
    pub pool: Option<&'a Pool>,
    pub xla: Option<&'a XlaEngine>,
}

impl<'a> CannyPipeline<'a> {
    pub fn serial() -> CannyPipeline<'static> {
        CannyPipeline { engine: Engine::Serial, pool: None, xla: None }
    }

    pub fn patterns(pool: &'a Pool) -> CannyPipeline<'a> {
        CannyPipeline { engine: Engine::Patterns, pool: Some(pool), xla: None }
    }

    pub fn tiled(pool: &'a Pool) -> CannyPipeline<'a> {
        CannyPipeline { engine: Engine::TiledPatterns, pool: Some(pool), xla: None }
    }

    pub fn xla(pool: &'a Pool, engine: &'a XlaEngine) -> CannyPipeline<'a> {
        CannyPipeline { engine: Engine::PatternsXla, pool: Some(pool), xla: Some(engine) }
    }

    /// Run full detection (the image→edges plan).
    pub fn detect(&self, img: &ImageF32, params: &CannyParams) -> Result<DetectOutput> {
        DetectOutput::from_plan(self.execute(&StagePlan::new(), Some(img), params)?)
    }

    /// Execute a [`StagePlan`]. `img` is required iff the plan's entry
    /// is [`PlanEntry::Image`]. The plan's engine override (if any)
    /// beats this pipeline's engine; the fused-tile fast path runs
    /// whenever the plan covers the whole front from a raw image, and
    /// partial front prefixes run band-parallel per stage.
    pub fn execute(
        &self,
        plan: &StagePlan,
        img: Option<&ImageF32>,
        params: &CannyParams,
    ) -> Result<PlanOutput> {
        params.validate()?;
        plan.validate()?;
        match &plan.entry {
            PlanEntry::Image => {
                let img = img.ok_or_else(|| {
                    Error::Config("plan entry is the raw image but none was passed".into())
                })?;
                if img.width() < 1 || img.height() < 1 {
                    return Err(Error::Geometry("empty image".into()));
                }
            }
            PlanEntry::Suppressed(a) | PlanEntry::ClassMap(a) => {
                if img.is_some() {
                    return Err(Error::Config(
                        "plan resumes from a cached artifact; do not pass an image".into(),
                    ));
                }
                if a.width() < 1 || a.height() < 1 {
                    return Err(Error::Geometry("empty entry artifact".into()));
                }
            }
        }
        let engine = plan.engine.unwrap_or(self.engine);
        let total = Stopwatch::start();
        // The fused-tile fast path has no per-stage boundaries, so it
        // runs only when the plan covers the whole front *and* carries
        // no per-stage grain overrides; otherwise the band-parallel
        // stage path honors the plan exactly.
        let fused_ok = plan.stop >= StageKind::Threshold && plan.grains.is_empty();
        let mut out = match (&plan.entry, engine) {
            (PlanEntry::Image, Engine::TiledPatterns) if fused_ok => {
                self.exec_tiled(plan, img.expect("validated above"), params)?
            }
            (PlanEntry::Image, Engine::PatternsXla) if fused_ok => {
                self.exec_xla(plan, img.expect("validated above"), params)?
            }
            (_, Engine::Serial) => self.exec_stages(plan, img, params, false)?,
            _ => self.exec_stages(plan, img, params, true)?,
        };
        out.total_ns = total.elapsed_ns();
        Ok(out)
    }

    /// The deterministic synthetic image probes of a given shape run
    /// on — one seed per shape, shared by [`CannyPipeline::probe_shape`]
    /// and the serving tier's calibration probe so both measure the
    /// same content.
    pub fn probe_image(width: usize, height: usize) -> ImageF32 {
        let scene = crate::image::synth::Scene::Shapes {
            seed: ((width as u64) << 32) | height as u64,
        };
        crate::image::synth::generate(scene, width, height)
    }

    /// Measure [`StageTimes`] for a `width`×`height` detection on this
    /// engine: run the real pipeline `repeats` times (>= 1) on
    /// [`CannyPipeline::probe_image`] and keep the fieldwise minimum.
    /// (The serving tier's calibration runs the same loop over full
    /// [`DetectOutput`]s instead, to fit per-stage models from the
    /// records — see [`crate::service::calibrate`].)
    pub fn probe_shape(
        &self,
        width: usize,
        height: usize,
        repeats: usize,
        params: &CannyParams,
    ) -> Result<StageTimes> {
        let img = Self::probe_image(width, height);
        let mut best: Option<StageTimes> = None;
        for _ in 0..repeats.max(1) {
            let t = self.detect(&img, params)?.times;
            best = Some(match best {
                None => t,
                Some(b) => b.min_with(&t),
            });
        }
        Ok(best.expect("at least one repeat ran"))
    }

    fn need_pool(&self) -> Result<&'a Pool> {
        self.pool
            .ok_or_else(|| Error::Scheduler(format!("engine {:?} needs a pool", self.engine)))
    }

    /// Run the hysteresis stage for a plan and record it.
    fn run_hysteresis(
        &self,
        cls: &ImageF32,
        params: &CannyParams,
        plan: &StagePlan,
    ) -> Result<(EdgeMap, StageRecord)> {
        let use_par = plan.parallel_hysteresis.unwrap_or(params.parallel_hysteresis);
        let sw = Stopwatch::start();
        let cpu0 = thread_cpu_ns();
        let edges = if use_par {
            hysteresis::hysteresis_parallel(self.need_pool()?, cls)
        } else {
            hysteresis::hysteresis_serial(cls)
        };
        let wall_ns = sw.elapsed_ns();
        let rec = StageRecord {
            kind: StageKind::Hysteresis,
            fused_from: None,
            engine: if use_par { Engine::Patterns } else { Engine::Serial },
            wall_ns,
            cpu_ns: if use_par { wall_ns } else { thread_cpu_ns().saturating_sub(cpu0) },
            tasks: 1,
            task_costs_ns: Vec::new(),
        };
        Ok((edges, rec))
    }

    // ---- Per-stage execution (Serial whole-image, or Patterns row
    //      bands) — runs any plan: full chains, partial prefixes, and
    //      mid-pipeline resumes. ---------------------------------------

    fn exec_stages(
        &self,
        plan: &StagePlan,
        img: Option<&ImageF32>,
        params: &CannyParams,
        parallel: bool,
    ) -> Result<PlanOutput> {
        let pool = if parallel { Some(self.need_pool()?) } else { None };
        let eng = if parallel { Engine::Patterns } else { Engine::Serial };
        let mut records: Vec<StageRecord> = Vec::new();
        let mut artifacts: Vec<Artifact> = Vec::new();

        // One phase record. `tasks`/`cpu` conventions documented on
        // [`StageRecord`]: band phases carry the band count and a wall
        // proxy for CPU; serial phases carry the executing thread's CPU.
        let rec = |kind: StageKind, engine: Engine, wall_ns: u64, cpu_ns: u64, tasks: u64| {
            StageRecord {
                kind,
                fused_from: None,
                engine,
                wall_ns,
                cpu_ns,
                tasks,
                task_costs_ns: Vec::new(),
            }
        };

        // The suppressed-magnitude map Threshold reads: produced by the
        // front below (owned, echoed as an artifact), or borrowed from
        // the entry artifact (the re-threshold hot path — no copy).
        let mut front_nm: Option<ImageF32> = None;
        match &plan.entry {
            PlanEntry::ClassMap(cls) => {
                let (edges, r) = self.run_hysteresis(cls, params, plan)?;
                records.push(r);
                artifacts.push(Artifact::Edges(edges));
                return Ok(PlanOutput { artifacts, records, total_ns: 0 });
            }
            PlanEntry::Suppressed(_) => {}
            PlanEntry::Image => {
                let img = img.expect("validated in execute");
                // Base grain: identical to the historical stage-parallel
                // engine (one grain from the image height), overridable
                // per stage by the plan.
                let base_grain = |workers: usize| {
                    if params.band_grain > 0 {
                        params.band_grain
                    } else {
                        patterns::auto_grain(img.height(), workers)
                    }
                };
                let grain_of = |kind: StageKind, pool: &Pool| {
                    plan.grain_for(kind).unwrap_or_else(|| base_grain(pool.n_workers()))
                };

                // -- Pad (serial in every engine) -----------------------
                let sw = Stopwatch::start();
                let cpu0 = thread_cpu_ns();
                let padded = img.pad_replicate(consts::HALO);
                records.push(rec(
                    StageKind::Pad,
                    Engine::Serial,
                    sw.elapsed_ns(),
                    thread_cpu_ns().saturating_sub(cpu0),
                    1,
                ));
                if plan.stop == StageKind::Pad {
                    artifacts.push(Artifact::Gray(padded));
                    return Ok(PlanOutput { artifacts, records, total_ns: 0 });
                }

                // -- Gaussian ------------------------------------------
                let sw = Stopwatch::start();
                let cpu0 = thread_cpu_ns();
                let (g, tasks) = match pool {
                    Some(pool) => {
                        let grain = grain_of(StageKind::Gaussian, pool);
                        let g = gaussian_bands(pool, &padded, grain);
                        let bands =
                            patterns::chunks(padded.height(), grain).len() as u64;
                        (g, bands)
                    }
                    None => (gaussian::gaussian(&padded), 1),
                };
                let wall = sw.elapsed_ns();
                let cpu = if pool.is_some() {
                    wall
                } else {
                    thread_cpu_ns().saturating_sub(cpu0)
                };
                records.push(rec(StageKind::Gaussian, eng, wall, cpu, tasks));
                if plan.stop == StageKind::Gaussian {
                    artifacts.push(Artifact::Gray(g));
                    return Ok(PlanOutput { artifacts, records, total_ns: 0 });
                }

                // -- Sobel ---------------------------------------------
                let sw = Stopwatch::start();
                let cpu0 = thread_cpu_ns();
                let ((mag, dir), tasks) = match pool {
                    Some(pool) => {
                        let grain = grain_of(StageKind::Sobel, pool);
                        let md = sobel_bands(pool, &g, grain);
                        let bands =
                            patterns::chunks(g.height() - 2, grain).len() as u64;
                        (md, bands)
                    }
                    None => (sobel::sobel(&g), 1),
                };
                let wall = sw.elapsed_ns();
                let cpu = if pool.is_some() {
                    wall
                } else {
                    thread_cpu_ns().saturating_sub(cpu0)
                };
                records.push(rec(StageKind::Sobel, eng, wall, cpu, tasks));
                if plan.stop == StageKind::Sobel {
                    artifacts.push(Artifact::Gradient { mag, dir });
                    return Ok(PlanOutput { artifacts, records, total_ns: 0 });
                }

                // -- NMS -----------------------------------------------
                let sw = Stopwatch::start();
                let cpu0 = thread_cpu_ns();
                let (w, h) = (img.width(), img.height());
                let (nm_out, tasks) = match pool {
                    Some(pool) => {
                        let grain = grain_of(StageKind::Nms, pool);
                        let n = nms_bands(pool, &mag, &dir, w, h, grain);
                        (n, patterns::chunks(h, grain).len() as u64)
                    }
                    None => (nms::nms(&mag, &dir), 1),
                };
                let wall = sw.elapsed_ns();
                let cpu = if pool.is_some() {
                    wall
                } else {
                    thread_cpu_ns().saturating_sub(cpu0)
                };
                records.push(rec(StageKind::Nms, eng, wall, cpu, tasks));
                debug_assert_eq!(nm_out.width(), w);
                debug_assert_eq!(nm_out.height(), h);
                if plan.stop == StageKind::Nms {
                    artifacts.push(Artifact::Suppressed(nm_out));
                    return Ok(PlanOutput { artifacts, records, total_ns: 0 });
                }
                front_nm = Some(nm_out);
            }
        }

        // -- Threshold (from the front's map, or the entry artifact) ---
        let nm: &ImageF32 = match &plan.entry {
            PlanEntry::Suppressed(entry_nm) => entry_nm,
            _ => front_nm.as_ref().expect("front ran to NMS above"),
        };
        let sw = Stopwatch::start();
        let cpu0 = thread_cpu_ns();
        let (cls, tasks) = match pool {
            Some(pool) => {
                let grain = plan.grain_for(StageKind::Threshold).unwrap_or_else(|| {
                    if params.band_grain > 0 {
                        params.band_grain
                    } else {
                        patterns::auto_grain(nm.height(), pool.n_workers())
                    }
                });
                let c = threshold_bands(pool, nm, params.lo, params.hi, grain);
                (c, patterns::chunks(nm.height(), grain).len() as u64)
            }
            None => (threshold::threshold(nm, params.lo, params.hi), 1),
        };
        let wall = sw.elapsed_ns();
        let cpu = if pool.is_some() { wall } else { thread_cpu_ns().saturating_sub(cpu0) };
        records.push(rec(StageKind::Threshold, eng, wall, cpu, tasks));

        // -- Hysteresis ------------------------------------------------
        let edges = if plan.stop == StageKind::Hysteresis {
            let (edges, r) = self.run_hysteresis(&cls, params, plan)?;
            records.push(r);
            Some(edges)
        } else {
            None
        };

        // Entry artifacts are not echoed back; the front's own map is.
        if let Some(m) = front_nm {
            artifacts.push(Artifact::Suppressed(m));
        }
        artifacts.push(Artifact::ClassMap(cls));
        if let Some(edges) = edges {
            artifacts.push(Artifact::Edges(edges));
        }
        Ok(PlanOutput { artifacts, records, total_ns: 0 })
    }

    // ---- Fused-front tiles (native) -----------------------------------

    fn exec_tiled(
        &self,
        plan: &StagePlan,
        img: &ImageF32,
        params: &CannyParams,
    ) -> Result<PlanOutput> {
        let pool = self.need_pool()?;
        let (w, h) = (img.width(), img.height());
        let grid = TileGrid::new(w, h, params.tile, params.tile, consts::HALO)?;

        // No serial whole-image pad: each tile task clamps its own halo
        // (pad work rides inside the parallel phase — §Perf item P1).
        let sw = Stopwatch::start();
        let tiles: Vec<_> = grid.tiles().collect();
        let mut cls = ImageF32::zeros(w, h);
        let mut nm = ImageF32::zeros(w, h);
        let mut costs = vec![0u64; tiles.len()];
        {
            let cls_s = SharedSlice::new(cls.data_mut());
            let nm_s = SharedSlice::new(nm.data_mut());
            let cost_s = SharedSlice::new(&mut costs);
            let grid = &grid;
            patterns::par_map(pool, &tiles, 1, |i, t| {
                let t0 = thread_cpu_ns();
                let window = grid.extract_clamped(img, *t);
                let (tc, tn) = front_serial_window(&window, params.lo, params.hi);
                debug_assert_eq!(tc.width(), t.core_w);
                debug_assert_eq!(tc.height(), t.core_h);
                for ty in 0..t.core_h {
                    let row0 = (t.y0 + ty) * w + t.x0;
                    // SAFETY: tiles cover disjoint output regions.
                    let crow = unsafe { cls_s.range_mut(row0, row0 + t.core_w) };
                    crow.copy_from_slice(&tc.data()[ty * t.core_w..(ty + 1) * t.core_w]);
                    // SAFETY: same disjoint tile region, distinct buffer.
                    let nrow = unsafe { nm_s.range_mut(row0, row0 + t.core_w) };
                    nrow.copy_from_slice(&tn.data()[ty * t.core_w..(ty + 1) * t.core_w]);
                }
                // SAFETY: one writer per tile index.
                unsafe { cost_s.write(i, thread_cpu_ns() - t0) };
            });
        }
        let front_wall = sw.elapsed_ns();
        let mut records = vec![StageRecord {
            kind: StageKind::Threshold,
            // Pad happens inside each tile task, so the fused span
            // covers Pad..Threshold.
            fused_from: Some(StageKind::Pad),
            engine: Engine::TiledPatterns,
            wall_ns: front_wall,
            cpu_ns: costs.iter().sum(),
            tasks: costs.len() as u64,
            task_costs_ns: costs,
        }];

        let mut artifacts = vec![Artifact::Suppressed(nm)];
        if plan.stop == StageKind::Hysteresis {
            let (edges, r) = self.run_hysteresis(&cls, params, plan)?;
            records.push(r);
            artifacts.push(Artifact::ClassMap(cls));
            artifacts.push(Artifact::Edges(edges));
        } else {
            artifacts.push(Artifact::ClassMap(cls));
        }
        Ok(PlanOutput { artifacts, records, total_ns: 0 })
    }

    // ---- Fused-front tiles via PJRT (JAX/Pallas artifacts) ------------

    fn exec_xla(
        &self,
        plan: &StagePlan,
        img: &ImageF32,
        params: &CannyParams,
    ) -> Result<PlanOutput> {
        let pool = self.need_pool()?;
        let xla = self
            .xla
            .ok_or_else(|| Error::Xla("PatternsXla engine needs an XlaEngine".into()))?;
        let (core_h, core_w) = xla.tile_core();
        let halo = xla.halo();
        if halo != consts::HALO {
            return Err(Error::Artifact(format!(
                "artifact halo {halo} != native {}",
                consts::HALO
            )));
        }
        let (w, h) = (img.width(), img.height());
        let grid = TileGrid::new(w, h, core_w, core_h, halo)?;

        let sw = Stopwatch::start();
        let cpu0 = thread_cpu_ns();
        let padded = grid.pad_for_fixed(img);
        let mut records = vec![StageRecord {
            kind: StageKind::Pad,
            fused_from: None,
            engine: Engine::Serial,
            wall_ns: sw.elapsed_ns(),
            cpu_ns: thread_cpu_ns().saturating_sub(cpu0),
            tasks: 1,
            task_costs_ns: Vec::new(),
        }];

        let sw = Stopwatch::start();
        let tiles: Vec<_> = grid.tiles().collect();
        let mut cls = ImageF32::zeros(w, h);
        let mut nm = ImageF32::zeros(w, h);
        let mut costs = vec![0u64; tiles.len()];
        let mut errs: Vec<Option<Error>> = (0..tiles.len()).map(|_| None).collect();
        {
            let cls_s = SharedSlice::new(cls.data_mut());
            let nm_s = SharedSlice::new(nm.data_mut());
            let cost_s = SharedSlice::new(&mut costs);
            let err_s = SharedSlice::new(&mut errs);
            let grid = &grid;
            let padded = &padded;
            patterns::par_map(pool, &tiles, 1, |i, t| {
                let t0 = thread_cpu_ns();
                let window = grid.extract_fixed(padded, *t);
                match xla.run_front(&window, params.lo, params.hi, i) {
                    Ok((tc, tn)) => {
                        for ty in 0..t.core_h {
                            let row0 = (t.y0 + ty) * w + t.x0;
                            // SAFETY: disjoint tile regions / indices.
                            let crow = unsafe { cls_s.range_mut(row0, row0 + t.core_w) };
                            crow.copy_from_slice(&tc.data()[ty * core_w..ty * core_w + t.core_w]);
                            // SAFETY: same disjoint tile region, distinct buffer.
                            let nrow = unsafe { nm_s.range_mut(row0, row0 + t.core_w) };
                            nrow.copy_from_slice(&tn.data()[ty * core_w..ty * core_w + t.core_w]);
                        }
                    }
                    // SAFETY: one writer per tile index `i`.
                    Err(e) => unsafe { err_s.write(i, Some(e)) },
                }
                // SAFETY: one writer per index.
                unsafe { cost_s.write(i, thread_cpu_ns() - t0) };
            });
        }
        if let Some(e) = errs.into_iter().flatten().next() {
            return Err(e);
        }
        records.push(StageRecord {
            kind: StageKind::Threshold,
            fused_from: Some(StageKind::Gaussian),
            engine: Engine::PatternsXla,
            wall_ns: sw.elapsed_ns(),
            cpu_ns: costs.iter().sum(),
            tasks: costs.len() as u64,
            task_costs_ns: costs,
        });

        let mut artifacts = vec![Artifact::Suppressed(nm)];
        if plan.stop == StageKind::Hysteresis {
            let (edges, r) = self.run_hysteresis(&cls, params, plan)?;
            records.push(r);
            artifacts.push(Artifact::ClassMap(cls));
            artifacts.push(Artifact::Edges(edges));
        } else {
            artifacts.push(Artifact::ClassMap(cls));
        }
        Ok(PlanOutput { artifacts, records, total_ns: 0 })
    }
}

// ---- Band-parallel stage bodies (the paper's stage-parallel engine,
//      shared by full chains and partial plans) -----------------------

/// Gaussian over row bands: (ph, pw) → (ph-4, pw-4) in two passes.
fn gaussian_bands(pool: &Pool, padded: &ImageF32, grain: usize) -> ImageF32 {
    let (pw, ph) = (padded.width(), padded.height());
    // gauss rows: (ph, pw) -> (ph, pw-4)
    let mut g1 = ImageF32::zeros(pw - 4, ph);
    {
        let out = SharedSlice::new(g1.data_mut());
        let w_out = pw - 4;
        patterns::par_rows(pool, ph, grain, |band| {
            for y in band {
                // SAFETY: bands are disjoint row ranges.
                let dst = unsafe { out.range_mut(y * w_out, (y + 1) * w_out) };
                gaussian::gauss_row_into(padded.row(y), dst);
            }
        });
    }
    // gauss cols: (ph, pw-4) -> (ph-4, pw-4)
    let mut g2 = ImageF32::zeros(pw - 4, ph - 4);
    {
        let out = SharedSlice::new(g2.data_mut());
        let w_out = pw - 4;
        patterns::par_rows(pool, ph - 4, grain, |band| {
            for y in band {
                // SAFETY: disjoint rows.
                let dst = unsafe { out.range_mut(y * w_out, (y + 1) * w_out) };
                gaussian::gauss_col_row_into(&g1, y, dst);
            }
        });
    }
    g2
}

/// Sobel over row bands: (gh, gw) → (gh-2, gw-2) magnitude + direction.
fn sobel_bands(pool: &Pool, g: &ImageF32, grain: usize) -> (ImageF32, ImageF32) {
    let (sw_out, sh_out) = (g.width() - 2, g.height() - 2);
    let mut mag = ImageF32::zeros(sw_out, sh_out);
    let mut dir = ImageF32::zeros(sw_out, sh_out);
    {
        let mag_s = SharedSlice::new(mag.data_mut());
        let dir_s = SharedSlice::new(dir.data_mut());
        patterns::par_rows(pool, sh_out, grain, |band| {
            for y in band {
                // SAFETY: disjoint rows per band, distinct buffers.
                let m = unsafe { mag_s.range_mut(y * sw_out, (y + 1) * sw_out) };
                let d = unsafe { dir_s.range_mut(y * sw_out, (y + 1) * sw_out) };
                sobel::sobel_row_into(g, y, m, d);
            }
        });
    }
    (mag, dir)
}

/// NMS over row bands: gradient → (h, w) suppressed magnitude.
fn nms_bands(
    pool: &Pool,
    mag: &ImageF32,
    dir: &ImageF32,
    w: usize,
    h: usize,
    grain: usize,
) -> ImageF32 {
    let mut nm = ImageF32::zeros(w, h);
    {
        let nm_s = SharedSlice::new(nm.data_mut());
        patterns::par_rows(pool, h, grain, |band| {
            for y in band {
                // SAFETY: disjoint rows.
                let dst = unsafe { nm_s.range_mut(y * w, (y + 1) * w) };
                nms::nms_row_into(mag, dir, y, dst);
            }
        });
    }
    nm
}

/// Double threshold over row bands (elementwise map).
fn threshold_bands(pool: &Pool, nm: &ImageF32, lo: f32, hi: f32, grain: usize) -> ImageF32 {
    let (w, h) = (nm.width(), nm.height());
    let mut cls = ImageF32::zeros(w, h);
    {
        let cls_s = SharedSlice::new(cls.data_mut());
        patterns::par_rows(pool, h, grain, |band| {
            for y in band {
                // SAFETY: disjoint rows.
                let dst = unsafe { cls_s.range_mut(y * w, (y + 1) * w) };
                threshold::threshold_row_into(nm.row(y), lo, hi, dst);
            }
        });
    }
    cls
}

/// Serial Canny front on a haloed window: `(c + 2*HALO)²` → `c²`.
/// Shared by the tiled engine and the whole-image reference.
pub fn front_serial_window(window: &ImageF32, lo: f32, hi: f32) -> (ImageF32, ImageF32) {
    let nm = front_suppressed_window(window);
    let cls = threshold::threshold(&nm, lo, hi);
    (cls, nm)
}

/// Threshold-free front on a haloed window: Gaussian → Sobel → NMS,
/// `(c + 2*HALO)²` → `c²` suppressed magnitude. The stream tier's
/// delta gate recomputes dirty tiles through this (the global
/// Threshold + Hysteresis pass runs afterwards from the stitched
/// [`crate::canny::Artifact::Suppressed`] map), so a tile's suppressed
/// core never depends on the thresholds.
pub fn front_suppressed_window(window: &ImageF32) -> ImageF32 {
    let g = gaussian::gaussian(window);
    let (mag, dir) = sobel::sobel(&g);
    nms::nms(&mag, &dir)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::synth::{generate, Scene};

    fn test_image() -> ImageF32 {
        generate(Scene::Shapes { seed: 11 }, 150, 90)
    }

    #[test]
    fn serial_engine_runs() {
        let img = test_image();
        let out = CannyPipeline::serial().detect(&img, &CannyParams::default()).unwrap();
        assert_eq!(out.edges.width(), 150);
        assert!(out.edges.count_edges() > 0);
        assert!(out.times.total_ns > 0);
    }

    #[test]
    fn patterns_matches_serial_exactly() {
        let img = test_image();
        let params = CannyParams::default();
        let serial = CannyPipeline::serial().detect(&img, &params).unwrap();
        for workers in [1usize, 2, 4, 8] {
            let pool = Pool::new(workers).unwrap();
            let par = CannyPipeline::patterns(&pool).detect(&img, &params).unwrap();
            assert_eq!(
                serial.edges.diff_count(&par.edges),
                0,
                "patterns({workers}) diverged from serial"
            );
            assert_eq!(serial.class_map, par.class_map);
            assert_eq!(serial.nms_mag, par.nms_mag);
        }
    }

    #[test]
    fn tiled_matches_serial_exactly() {
        let img = test_image();
        let pool = Pool::new(4).unwrap();
        for tile in [32usize, 64, 128, 300] {
            let params = CannyParams { tile, ..CannyParams::default() };
            let serial = CannyPipeline::serial().detect(&img, &params).unwrap();
            let tiled = CannyPipeline::tiled(&pool).detect(&img, &params).unwrap();
            assert_eq!(
                serial.edges.diff_count(&tiled.edges),
                0,
                "tiled(tile={tile}) diverged"
            );
            assert_eq!(serial.class_map, tiled.class_map);
        }
    }

    #[test]
    fn tiled_records_tile_costs() {
        let img = test_image();
        let pool = Pool::new(2).unwrap();
        let params = CannyParams { tile: 64, ..CannyParams::default() };
        let out = CannyPipeline::tiled(&pool).detect(&img, &params).unwrap();
        // 150x90 at tile 64 -> 3x2 grid.
        assert_eq!(out.times.tile_costs_ns.len(), 6);
        assert!(out.times.tile_costs_ns.iter().all(|&c| c > 0));
        // The fused span carries the same costs as the compat view.
        let front = out.records.iter().find(|r| r.span_name() == "front").unwrap();
        assert_eq!(front.task_costs_ns, out.times.tile_costs_ns);
        assert_eq!(front.tasks, 6);
        assert!(front.covers(StageKind::Pad) && front.covers(StageKind::Threshold));
    }

    #[test]
    fn parallel_hysteresis_same_result() {
        let img = test_image();
        let pool = Pool::new(4).unwrap();
        let base = CannyParams::default();
        let par = CannyParams { parallel_hysteresis: true, ..base };
        let a = CannyPipeline::patterns(&pool).detect(&img, &base).unwrap();
        let b = CannyPipeline::patterns(&pool).detect(&img, &par).unwrap();
        assert_eq!(a.edges.diff_count(&b.edges), 0);
    }

    #[test]
    fn probe_shape_measures_and_min_merges() {
        let out = CannyPipeline::serial().probe_shape(64, 48, 2, &CannyParams::default()).unwrap();
        assert!(out.total_ns > 0);
        assert!(out.front_ns > 0);
        let a = StageTimes { total_ns: 10, gaussian_ns: 7, ..StageTimes::default() };
        let b = StageTimes { total_ns: 4, gaussian_ns: 9, ..StageTimes::default() };
        let m = a.min_with(&b);
        assert_eq!(m.total_ns, 4);
        assert_eq!(m.gaussian_ns, 7);
    }

    #[test]
    fn params_validation() {
        assert!(CannyParams { lo: -0.1, ..CannyParams::default() }.validate().is_err());
        assert!(CannyParams { lo: 0.5, hi: 0.1, ..CannyParams::default() }.validate().is_err());
        assert!(CannyParams { tile: 0, ..CannyParams::default() }.validate().is_err());
        assert!(CannyParams::default().validate().is_ok());
    }

    #[test]
    fn engine_parse_roundtrip() {
        for e in [Engine::Serial, Engine::Patterns, Engine::TiledPatterns, Engine::PatternsXla] {
            assert_eq!(Engine::parse(e.name()), Some(e));
        }
        assert_eq!(Engine::parse("bogus"), None);
    }

    #[test]
    fn patterns_without_pool_errors() {
        let img = test_image();
        let p = CannyPipeline { engine: Engine::Patterns, pool: None, xla: None };
        assert!(p.detect(&img, &CannyParams::default()).is_err());
    }

    #[test]
    fn tiny_image_single_tile() {
        let img = generate(Scene::Checker { cell: 2 }, 9, 7);
        let pool = Pool::new(2).unwrap();
        let params = CannyParams { tile: 128, ..CannyParams::default() };
        let serial = CannyPipeline::serial().detect(&img, &params).unwrap();
        let tiled = CannyPipeline::tiled(&pool).detect(&img, &params).unwrap();
        assert_eq!(serial.edges.diff_count(&tiled.edges), 0);
    }

    // ---- Stage-graph plans -------------------------------------------

    #[test]
    fn full_plan_execute_matches_detect() {
        let img = test_image();
        let params = CannyParams::default();
        let pool = Pool::new(3).unwrap();
        for pipe in [CannyPipeline::serial(), CannyPipeline::patterns(&pool)] {
            let det = pipe.detect(&img, &params).unwrap();
            let plan = pipe.execute(&StagePlan::new(), Some(&img), &params).unwrap();
            assert_eq!(det.edges.diff_count(plan.edges().unwrap()), 0);
            assert_eq!(&det.class_map, plan.class_map().unwrap());
            assert_eq!(&det.nms_mag, plan.suppressed().unwrap());
        }
    }

    #[test]
    fn serial_records_cover_every_stage() {
        let img = test_image();
        let out = CannyPipeline::serial().detect(&img, &CannyParams::default()).unwrap();
        let names: Vec<&str> = out.records.iter().map(|r| r.span_name()).collect();
        assert_eq!(names, ["pad", "gaussian", "sobel", "nms", "threshold", "hysteresis"]);
        assert!(out.records.iter().all(|r| r.tasks == 1));
        // Compat view reproduces the per-stage fields and the front sum.
        assert_eq!(
            out.times.front_ns,
            out.times.gaussian_ns + out.times.sobel_ns + out.times.nms_ns
                + out.times.threshold_ns
        );
        assert!(out.times.total_ns > 0);
    }

    #[test]
    fn patterns_records_count_bands() {
        let img = test_image();
        let pool = Pool::new(2).unwrap();
        let out = CannyPipeline::patterns(&pool).detect(&img, &CannyParams::default()).unwrap();
        let gauss = out.records.iter().find(|r| r.kind == StageKind::Gaussian).unwrap();
        assert_eq!(gauss.engine, Engine::Patterns);
        assert!(gauss.tasks >= 1);
    }

    #[test]
    fn partial_stops_yield_the_right_artifact() {
        let img = test_image();
        let params = CannyParams::default();
        let pipe = CannyPipeline::serial();
        let stops = [
            (StageKind::Pad, "gray"),
            (StageKind::Gaussian, "gray"),
            (StageKind::Sobel, "gradient"),
            (StageKind::Nms, "suppressed"),
            (StageKind::Threshold, "class-map"),
            (StageKind::Hysteresis, "edges"),
        ];
        for (stop, want) in stops {
            let plan = StagePlan::new().stop_after(stop);
            let out = pipe.execute(&plan, Some(&img), &params).unwrap();
            assert!(
                out.artifacts.iter().any(|a| a.name() == want),
                "stop {} missing artifact {want}",
                stop.name()
            );
            assert!(out.ran(stop));
            if stop < StageKind::Hysteresis {
                assert!(!out.ran(StageKind::Hysteresis), "stop {} overran", stop.name());
            }
        }
    }

    #[test]
    fn resume_from_suppressed_skips_the_front() {
        let img = test_image();
        let params = CannyParams::default();
        let pool = Pool::new(3).unwrap();
        let pipe = CannyPipeline::patterns(&pool);
        let full = pipe.detect(&img, &params).unwrap();

        let front = StagePlan::new().stop_after(StageKind::Nms);
        let mut front_out = pipe.execute(&front, Some(&img), &params).unwrap();
        let nm = front_out.take_suppressed().unwrap();
        assert_eq!(&nm, &full.nms_mag);

        let resume = StagePlan::new().from_suppressed(nm);
        let out = pipe.execute(&resume, None, &params).unwrap();
        assert_eq!(full.edges.diff_count(out.edges().unwrap()), 0);
        for k in [StageKind::Pad, StageKind::Gaussian, StageKind::Sobel, StageKind::Nms] {
            assert!(!out.ran(k), "resume re-ran {}", k.name());
        }
        assert!(out.ran(StageKind::Threshold) && out.ran(StageKind::Hysteresis));
        // Entry artifacts are not echoed back.
        assert!(out.suppressed().is_none());
    }

    #[test]
    fn resume_from_class_map_runs_hysteresis_only() {
        let img = test_image();
        let params = CannyParams::default();
        let full = CannyPipeline::serial().detect(&img, &params).unwrap();
        let plan = StagePlan::new().from_class_map(full.class_map.clone());
        let out = CannyPipeline::serial().execute(&plan, None, &params).unwrap();
        assert_eq!(out.records.len(), 1);
        assert_eq!(out.records[0].kind, StageKind::Hysteresis);
        assert_eq!(full.edges.diff_count(out.edges().unwrap()), 0);
    }

    #[test]
    fn tiled_partial_prefix_falls_back_to_band_stages() {
        let img = test_image();
        let params = CannyParams::default();
        let pool = Pool::new(2).unwrap();
        let plan = StagePlan::new().stop_after(StageKind::Nms);
        let out = CannyPipeline::tiled(&pool).execute(&plan, Some(&img), &params).unwrap();
        let serial = CannyPipeline::serial().detect(&img, &params).unwrap();
        assert_eq!(out.suppressed().unwrap(), &serial.nms_mag);
        // The prefix ran unfused (no "front" span).
        assert!(out.records.iter().all(|r| r.fused_from.is_none()));
    }

    #[test]
    fn plan_engine_override_beats_pipeline_engine() {
        let img = test_image();
        let params = CannyParams::default();
        // Serial pipeline + a Patterns override without a pool: error.
        let plan = StagePlan::new().engine(Engine::Patterns);
        assert!(CannyPipeline::serial().execute(&plan, Some(&img), &params).is_err());
        // Patterns pipeline + a Serial override: runs without touching
        // the pool-parallel path.
        let pool = Pool::new(2).unwrap();
        let plan = StagePlan::new().engine(Engine::Serial);
        let out = CannyPipeline::patterns(&pool).execute(&plan, Some(&img), &params).unwrap();
        assert!(out.records.iter().all(|r| r.engine == Engine::Serial));
    }

    #[test]
    fn execute_input_arity_is_validated() {
        let img = test_image();
        let params = CannyParams::default();
        let pipe = CannyPipeline::serial();
        // Image entry without an image.
        assert!(pipe.execute(&StagePlan::new(), None, &params).is_err());
        // Resume entry with a stray image.
        let plan = StagePlan::new().from_suppressed(ImageF32::zeros(8, 8));
        assert!(pipe.execute(&plan, Some(&img), &params).is_err());
        // Contradictory stop/entry rejected.
        let plan = StagePlan::new()
            .from_class_map(ImageF32::zeros(8, 8))
            .stop_after(StageKind::Threshold);
        assert!(pipe.execute(&plan, None, &params).is_err());
    }
}
