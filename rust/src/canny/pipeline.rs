//! The Canny pipeline with its execution engines — the heart of the
//! reproduction:
//!
//! * [`Engine::Serial`] — the paper's *suboptimal* baseline: every
//!   stage whole-image, one thread (Figures 8/9b/10).
//! * [`Engine::Patterns`] — the paper's contribution: each stage
//!   parallelized with the map/stencil patterns over row bands
//!   (`cilk_for` style), hysteresis left serial per the paper.
//! * [`Engine::TiledPatterns`] — fused-front tile decomposition: one
//!   task per tile runs all four front stages on a haloed window
//!   (better locality; the ablation bench compares).
//! * [`Engine::PatternsXla`] — tiles dispatched to the AOT-compiled
//!   JAX/Pallas fused front via PJRT ([`crate::runtime::XlaEngine`]),
//!   hysteresis in Rust. Python is long gone at this point.
//!
//! All engines produce the identical edge map (determinism tests
//! enforce it; XLA within f32 tolerance at class boundaries).

use crate::canny::{consts, gaussian, hysteresis, nms, sobel, threshold};
use crate::error::{Error, Result};
use crate::image::tile::TileGrid;
use crate::image::{EdgeMap, ImageF32};
use crate::patterns;
use crate::runtime::XlaEngine;
use crate::scheduler::Pool;
use crate::util::timer::{thread_cpu_ns, Stopwatch};
use crate::util::SharedSlice;

/// Which implementation runs the front-end.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Engine {
    Serial,
    Patterns,
    TiledPatterns,
    PatternsXla,
}

impl Engine {
    pub fn parse(s: &str) -> Option<Engine> {
        match s {
            "serial" => Some(Engine::Serial),
            "patterns" => Some(Engine::Patterns),
            "tiled" | "tiled-patterns" => Some(Engine::TiledPatterns),
            "xla" | "patterns-xla" => Some(Engine::PatternsXla),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Engine::Serial => "serial",
            Engine::Patterns => "patterns",
            Engine::TiledPatterns => "tiled",
            Engine::PatternsXla => "xla",
        }
    }
}

/// Detector parameters.
#[derive(Clone, Copy, Debug)]
pub struct CannyParams {
    /// Low hysteresis threshold (on gradient magnitude).
    pub lo: f32,
    /// High hysteresis threshold.
    pub hi: f32,
    /// Tile core size for the tiled engines.
    pub tile: usize,
    /// Use the parallel hysteresis extension instead of the paper's
    /// serial walk.
    pub parallel_hysteresis: bool,
    /// Row-band grain for the stage-parallel engine (0 = auto).
    pub band_grain: usize,
}

impl Default for CannyParams {
    fn default() -> Self {
        CannyParams { lo: 0.05, hi: 0.15, tile: 128, parallel_hysteresis: false, band_grain: 0 }
    }
}

impl CannyParams {
    pub fn validate(&self) -> Result<()> {
        if !(self.lo.is_finite() && self.hi.is_finite()) || self.lo < 0.0 || self.hi < self.lo {
            return Err(Error::Config(format!(
                "thresholds must satisfy 0 <= lo <= hi, got lo={} hi={}",
                self.lo, self.hi
            )));
        }
        if self.tile == 0 {
            return Err(Error::Config("tile must be >= 1".into()));
        }
        Ok(())
    }
}

/// Wall-clock per stage plus per-tile CPU costs (the simulator's input).
#[derive(Clone, Debug, Default)]
pub struct StageTimes {
    pub pad_ns: u64,
    pub gaussian_ns: u64,
    pub sobel_ns: u64,
    pub nms_ns: u64,
    pub threshold_ns: u64,
    /// Fused front total for tiled engines (gaussian..threshold inside).
    pub front_ns: u64,
    pub hysteresis_ns: u64,
    pub total_ns: u64,
    /// Thread-CPU cost of each tile task (tiled engines only).
    pub tile_costs_ns: Vec<u64>,
}

impl StageTimes {
    /// Serial-work ns (everything not in parallel tasks).
    pub fn serial_ns(&self) -> u64 {
        self.pad_ns + self.hysteresis_ns
    }

    /// Fieldwise minimum of two measurements of the *same* work — the
    /// noise-robust estimator min-of-repeats probing uses (preemption on
    /// a timeshared host only ever inflates a sample). Tile costs merge
    /// elementwise when the grids match, else the first is kept.
    pub fn min_with(&self, other: &StageTimes) -> StageTimes {
        StageTimes {
            pad_ns: self.pad_ns.min(other.pad_ns),
            gaussian_ns: self.gaussian_ns.min(other.gaussian_ns),
            sobel_ns: self.sobel_ns.min(other.sobel_ns),
            nms_ns: self.nms_ns.min(other.nms_ns),
            threshold_ns: self.threshold_ns.min(other.threshold_ns),
            front_ns: self.front_ns.min(other.front_ns),
            hysteresis_ns: self.hysteresis_ns.min(other.hysteresis_ns),
            total_ns: self.total_ns.min(other.total_ns),
            tile_costs_ns: if self.tile_costs_ns.len() == other.tile_costs_ns.len() {
                self.tile_costs_ns
                    .iter()
                    .zip(&other.tile_costs_ns)
                    .map(|(&a, &b)| a.min(b))
                    .collect()
            } else {
                self.tile_costs_ns.clone()
            },
        }
    }
}

/// Full detection output.
#[derive(Clone, Debug)]
pub struct DetectOutput {
    pub edges: EdgeMap,
    /// Class map (0/1/2) before connectivity.
    pub class_map: ImageF32,
    /// Suppressed gradient magnitude (for SNR metrics).
    pub nms_mag: ImageF32,
    pub times: StageTimes,
}

/// The configured pipeline. Borrows its pool / XLA engine so the same
/// resources serve many detections (the batch server reuses both).
pub struct CannyPipeline<'a> {
    pub engine: Engine,
    pub pool: Option<&'a Pool>,
    pub xla: Option<&'a XlaEngine>,
}

impl<'a> CannyPipeline<'a> {
    pub fn serial() -> CannyPipeline<'static> {
        CannyPipeline { engine: Engine::Serial, pool: None, xla: None }
    }

    pub fn patterns(pool: &'a Pool) -> CannyPipeline<'a> {
        CannyPipeline { engine: Engine::Patterns, pool: Some(pool), xla: None }
    }

    pub fn tiled(pool: &'a Pool) -> CannyPipeline<'a> {
        CannyPipeline { engine: Engine::TiledPatterns, pool: Some(pool), xla: None }
    }

    pub fn xla(pool: &'a Pool, engine: &'a XlaEngine) -> CannyPipeline<'a> {
        CannyPipeline { engine: Engine::PatternsXla, pool: Some(pool), xla: Some(engine) }
    }

    /// Run detection.
    pub fn detect(&self, img: &ImageF32, params: &CannyParams) -> Result<DetectOutput> {
        params.validate()?;
        if img.width() < 1 || img.height() < 1 {
            return Err(Error::Geometry("empty image".into()));
        }
        let total = Stopwatch::start();
        let mut out = match self.engine {
            Engine::Serial => self.detect_serial(img, params),
            Engine::Patterns => self.detect_patterns(img, params),
            Engine::TiledPatterns => self.detect_tiled(img, params),
            Engine::PatternsXla => self.detect_xla(img, params),
        }?;
        out.times.total_ns = total.elapsed_ns();
        Ok(out)
    }

    /// Measure [`StageTimes`] for a `width`×`height` detection on this
    /// engine: run the real pipeline `repeats` times (>= 1) on a
    /// deterministic synthetic scene of that shape and keep the
    /// fieldwise minimum. This is the per-shape probe the serving tier's
    /// cost calibration is fitted from.
    pub fn probe_shape(
        &self,
        width: usize,
        height: usize,
        repeats: usize,
        params: &CannyParams,
    ) -> Result<StageTimes> {
        let scene = crate::image::synth::Scene::Shapes {
            seed: ((width as u64) << 32) | height as u64,
        };
        let img = crate::image::synth::generate(scene, width, height);
        let mut best: Option<StageTimes> = None;
        for _ in 0..repeats.max(1) {
            let t = self.detect(&img, params)?.times;
            best = Some(match best {
                None => t,
                Some(b) => b.min_with(&t),
            });
        }
        Ok(best.expect("at least one repeat ran"))
    }

    fn need_pool(&self) -> Result<&'a Pool> {
        self.pool
            .ok_or_else(|| Error::Scheduler(format!("engine {:?} needs a pool", self.engine)))
    }

    fn finish_hysteresis(
        &self,
        cls: &ImageF32,
        params: &CannyParams,
        times: &mut StageTimes,
    ) -> Result<EdgeMap> {
        let sw = Stopwatch::start();
        let edges = if params.parallel_hysteresis {
            hysteresis::hysteresis_parallel(self.need_pool()?, cls)
        } else {
            hysteresis::hysteresis_serial(cls)
        };
        times.hysteresis_ns = sw.elapsed_ns();
        Ok(edges)
    }

    // ---- Serial (suboptimal baseline) --------------------------------

    fn detect_serial(&self, img: &ImageF32, params: &CannyParams) -> Result<DetectOutput> {
        let mut times = StageTimes::default();
        let sw = Stopwatch::start();
        let padded = img.pad_replicate(consts::HALO);
        times.pad_ns = sw.elapsed_ns();

        let sw = Stopwatch::start();
        let g = gaussian::gaussian(&padded);
        times.gaussian_ns = sw.elapsed_ns();

        let sw = Stopwatch::start();
        let (mag, dir) = sobel::sobel(&g);
        times.sobel_ns = sw.elapsed_ns();

        let sw = Stopwatch::start();
        let nm = nms::nms(&mag, &dir);
        times.nms_ns = sw.elapsed_ns();

        let sw = Stopwatch::start();
        let cls = threshold::threshold(&nm, params.lo, params.hi);
        times.threshold_ns = sw.elapsed_ns();
        times.front_ns =
            times.gaussian_ns + times.sobel_ns + times.nms_ns + times.threshold_ns;

        let edges = {
            let sw = Stopwatch::start();
            let e = hysteresis::hysteresis_serial(&cls);
            times.hysteresis_ns = sw.elapsed_ns();
            e
        };
        Ok(DetectOutput { edges, class_map: cls, nms_mag: nm, times })
    }

    // ---- Stage-parallel patterns (the paper's construction) ----------

    fn detect_patterns(&self, img: &ImageF32, params: &CannyParams) -> Result<DetectOutput> {
        let pool = self.need_pool()?;
        let mut times = StageTimes::default();
        let grain = if params.band_grain > 0 {
            params.band_grain
        } else {
            patterns::auto_grain(img.height(), pool.n_workers())
        };

        let sw = Stopwatch::start();
        let padded = img.pad_replicate(consts::HALO);
        times.pad_ns = sw.elapsed_ns();
        let (pw, ph) = (padded.width(), padded.height());

        // gauss rows: (ph, pw) -> (ph, pw-4)
        let sw = Stopwatch::start();
        let mut g1 = ImageF32::zeros(pw - 4, ph);
        {
            let out = SharedSlice::new(g1.data_mut());
            let w_out = pw - 4;
            patterns::par_rows(pool, ph, grain, |band| {
                for y in band {
                    // SAFETY: bands are disjoint row ranges.
                    let dst = unsafe { out.range_mut(y * w_out, (y + 1) * w_out) };
                    gaussian::gauss_row_into(padded.row(y), dst);
                }
            });
        }
        // gauss cols: (ph, pw-4) -> (ph-4, pw-4)
        let mut g2 = ImageF32::zeros(pw - 4, ph - 4);
        {
            let out = SharedSlice::new(g2.data_mut());
            let w_out = pw - 4;
            patterns::par_rows(pool, ph - 4, grain, |band| {
                for y in band {
                    // SAFETY: disjoint rows.
                    let dst = unsafe { out.range_mut(y * w_out, (y + 1) * w_out) };
                    gaussian::gauss_col_row_into(&g1, y, dst);
                }
            });
        }
        times.gaussian_ns = sw.elapsed_ns();

        // sobel: (ph-4, pw-4) -> (ph-6, pw-6)
        let sw = Stopwatch::start();
        let (sw_out, sh_out) = (pw - 6, ph - 6);
        let mut mag = ImageF32::zeros(sw_out, sh_out);
        let mut dir = ImageF32::zeros(sw_out, sh_out);
        {
            let mag_s = SharedSlice::new(mag.data_mut());
            let dir_s = SharedSlice::new(dir.data_mut());
            patterns::par_rows(pool, sh_out, grain, |band| {
                for y in band {
                    // SAFETY: disjoint rows per band, distinct buffers.
                    let m = unsafe { mag_s.range_mut(y * sw_out, (y + 1) * sw_out) };
                    let d = unsafe { dir_s.range_mut(y * sw_out, (y + 1) * sw_out) };
                    sobel::sobel_row_into(&g2, y, m, d);
                }
            });
        }
        times.sobel_ns = sw.elapsed_ns();

        // nms: (ph-6, pw-6) -> (ph-8, pw-8) == (h, w)
        let sw = Stopwatch::start();
        let (w, h) = (img.width(), img.height());
        let mut nm = ImageF32::zeros(w, h);
        {
            let nm_s = SharedSlice::new(nm.data_mut());
            patterns::par_rows(pool, h, grain, |band| {
                for y in band {
                    // SAFETY: disjoint rows.
                    let dst = unsafe { nm_s.range_mut(y * w, (y + 1) * w) };
                    nms::nms_row_into(&mag, &dir, y, dst);
                }
            });
        }
        times.nms_ns = sw.elapsed_ns();

        // threshold (elementwise map)
        let sw = Stopwatch::start();
        let mut cls = ImageF32::zeros(w, h);
        {
            let cls_s = SharedSlice::new(cls.data_mut());
            let (lo, hi) = (params.lo, params.hi);
            patterns::par_rows(pool, h, grain, |band| {
                for y in band {
                    // SAFETY: disjoint rows.
                    let dst = unsafe { cls_s.range_mut(y * w, (y + 1) * w) };
                    threshold::threshold_row_into(nm.row(y), lo, hi, dst);
                }
            });
        }
        times.threshold_ns = sw.elapsed_ns();
        times.front_ns =
            times.gaussian_ns + times.sobel_ns + times.nms_ns + times.threshold_ns;

        let edges = self.finish_hysteresis(&cls, params, &mut times)?;
        Ok(DetectOutput { edges, class_map: cls, nms_mag: nm, times })
    }

    // ---- Fused-front tiles (native) -----------------------------------

    fn detect_tiled(&self, img: &ImageF32, params: &CannyParams) -> Result<DetectOutput> {
        let pool = self.need_pool()?;
        let mut times = StageTimes::default();
        let (w, h) = (img.width(), img.height());
        let grid = TileGrid::new(w, h, params.tile, params.tile, consts::HALO)?;

        // No serial whole-image pad: each tile task clamps its own halo
        // (pad work rides inside the parallel phase — §Perf item P1).
        let sw = Stopwatch::start();
        let tiles: Vec<_> = grid.tiles().collect();
        let mut cls = ImageF32::zeros(w, h);
        let mut nm = ImageF32::zeros(w, h);
        let mut costs = vec![0u64; tiles.len()];
        {
            let cls_s = SharedSlice::new(cls.data_mut());
            let nm_s = SharedSlice::new(nm.data_mut());
            let cost_s = SharedSlice::new(&mut costs);
            let grid = &grid;
            patterns::par_map(pool, &tiles, 1, |i, t| {
                let t0 = thread_cpu_ns();
                let window = grid.extract_clamped(img, *t);
                let (tc, tn) = front_serial_window(&window, params.lo, params.hi);
                debug_assert_eq!(tc.width(), t.core_w);
                debug_assert_eq!(tc.height(), t.core_h);
                for ty in 0..t.core_h {
                    let row0 = (t.y0 + ty) * w + t.x0;
                    // SAFETY: tiles cover disjoint output regions.
                    let crow = unsafe { cls_s.range_mut(row0, row0 + t.core_w) };
                    crow.copy_from_slice(&tc.data()[ty * t.core_w..(ty + 1) * t.core_w]);
                    let nrow = unsafe { nm_s.range_mut(row0, row0 + t.core_w) };
                    nrow.copy_from_slice(&tn.data()[ty * t.core_w..(ty + 1) * t.core_w]);
                }
                // SAFETY: one writer per tile index.
                unsafe { cost_s.write(i, thread_cpu_ns() - t0) };
            });
        }
        times.front_ns = sw.elapsed_ns();
        times.tile_costs_ns = costs;

        let edges = self.finish_hysteresis(&cls, params, &mut times)?;
        Ok(DetectOutput { edges, class_map: cls, nms_mag: nm, times })
    }

    // ---- Fused-front tiles via PJRT (JAX/Pallas artifacts) ------------

    fn detect_xla(&self, img: &ImageF32, params: &CannyParams) -> Result<DetectOutput> {
        let pool = self.need_pool()?;
        let xla = self
            .xla
            .ok_or_else(|| Error::Xla("PatternsXla engine needs an XlaEngine".into()))?;
        let (core_h, core_w) = xla.tile_core();
        let halo = xla.halo();
        if halo != consts::HALO {
            return Err(Error::Artifact(format!(
                "artifact halo {halo} != native {}",
                consts::HALO
            )));
        }
        let mut times = StageTimes::default();
        let (w, h) = (img.width(), img.height());
        let grid = TileGrid::new(w, h, core_w, core_h, halo)?;

        let sw = Stopwatch::start();
        let padded = grid.pad_for_fixed(img);
        times.pad_ns = sw.elapsed_ns();

        let sw = Stopwatch::start();
        let tiles: Vec<_> = grid.tiles().collect();
        let mut cls = ImageF32::zeros(w, h);
        let mut nm = ImageF32::zeros(w, h);
        let mut costs = vec![0u64; tiles.len()];
        let mut errs: Vec<Option<Error>> = (0..tiles.len()).map(|_| None).collect();
        {
            let cls_s = SharedSlice::new(cls.data_mut());
            let nm_s = SharedSlice::new(nm.data_mut());
            let cost_s = SharedSlice::new(&mut costs);
            let err_s = SharedSlice::new(&mut errs);
            let grid = &grid;
            let padded = &padded;
            patterns::par_map(pool, &tiles, 1, |i, t| {
                let t0 = thread_cpu_ns();
                let window = grid.extract_fixed(padded, *t);
                match xla.run_front(&window, params.lo, params.hi, i) {
                    Ok((tc, tn)) => {
                        for ty in 0..t.core_h {
                            let row0 = (t.y0 + ty) * w + t.x0;
                            // SAFETY: disjoint tile regions / indices.
                            let crow = unsafe { cls_s.range_mut(row0, row0 + t.core_w) };
                            crow.copy_from_slice(&tc.data()[ty * core_w..ty * core_w + t.core_w]);
                            let nrow = unsafe { nm_s.range_mut(row0, row0 + t.core_w) };
                            nrow.copy_from_slice(&tn.data()[ty * core_w..ty * core_w + t.core_w]);
                        }
                    }
                    Err(e) => unsafe { err_s.write(i, Some(e)) },
                }
                // SAFETY: one writer per index.
                unsafe { cost_s.write(i, thread_cpu_ns() - t0) };
            });
        }
        if let Some(e) = errs.into_iter().flatten().next() {
            return Err(e);
        }
        times.front_ns = sw.elapsed_ns();
        times.tile_costs_ns = costs;

        let edges = self.finish_hysteresis(&cls, params, &mut times)?;
        Ok(DetectOutput { edges, class_map: cls, nms_mag: nm, times })
    }
}

/// Serial Canny front on a haloed window: `(c + 2*HALO)²` → `c²`.
/// Shared by the tiled engine and the whole-image reference.
pub fn front_serial_window(window: &ImageF32, lo: f32, hi: f32) -> (ImageF32, ImageF32) {
    let g = gaussian::gaussian(window);
    let (mag, dir) = sobel::sobel(&g);
    let nm = nms::nms(&mag, &dir);
    let cls = threshold::threshold(&nm, lo, hi);
    (cls, nm)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::synth::{generate, Scene};

    fn test_image() -> ImageF32 {
        generate(Scene::Shapes { seed: 11 }, 150, 90)
    }

    #[test]
    fn serial_engine_runs() {
        let img = test_image();
        let out = CannyPipeline::serial().detect(&img, &CannyParams::default()).unwrap();
        assert_eq!(out.edges.width(), 150);
        assert!(out.edges.count_edges() > 0);
        assert!(out.times.total_ns > 0);
    }

    #[test]
    fn patterns_matches_serial_exactly() {
        let img = test_image();
        let params = CannyParams::default();
        let serial = CannyPipeline::serial().detect(&img, &params).unwrap();
        for workers in [1usize, 2, 4, 8] {
            let pool = Pool::new(workers).unwrap();
            let par = CannyPipeline::patterns(&pool).detect(&img, &params).unwrap();
            assert_eq!(
                serial.edges.diff_count(&par.edges),
                0,
                "patterns({workers}) diverged from serial"
            );
            assert_eq!(serial.class_map, par.class_map);
            assert_eq!(serial.nms_mag, par.nms_mag);
        }
    }

    #[test]
    fn tiled_matches_serial_exactly() {
        let img = test_image();
        let pool = Pool::new(4).unwrap();
        for tile in [32usize, 64, 128, 300] {
            let params = CannyParams { tile, ..CannyParams::default() };
            let serial = CannyPipeline::serial().detect(&img, &params).unwrap();
            let tiled = CannyPipeline::tiled(&pool).detect(&img, &params).unwrap();
            assert_eq!(
                serial.edges.diff_count(&tiled.edges),
                0,
                "tiled(tile={tile}) diverged"
            );
            assert_eq!(serial.class_map, tiled.class_map);
        }
    }

    #[test]
    fn tiled_records_tile_costs() {
        let img = test_image();
        let pool = Pool::new(2).unwrap();
        let params = CannyParams { tile: 64, ..CannyParams::default() };
        let out = CannyPipeline::tiled(&pool).detect(&img, &params).unwrap();
        // 150x90 at tile 64 -> 3x2 grid.
        assert_eq!(out.times.tile_costs_ns.len(), 6);
        assert!(out.times.tile_costs_ns.iter().all(|&c| c > 0));
    }

    #[test]
    fn parallel_hysteresis_same_result() {
        let img = test_image();
        let pool = Pool::new(4).unwrap();
        let base = CannyParams::default();
        let par = CannyParams { parallel_hysteresis: true, ..base };
        let a = CannyPipeline::patterns(&pool).detect(&img, &base).unwrap();
        let b = CannyPipeline::patterns(&pool).detect(&img, &par).unwrap();
        assert_eq!(a.edges.diff_count(&b.edges), 0);
    }

    #[test]
    fn probe_shape_measures_and_min_merges() {
        let out = CannyPipeline::serial().probe_shape(64, 48, 2, &CannyParams::default()).unwrap();
        assert!(out.total_ns > 0);
        assert!(out.front_ns > 0);
        let a = StageTimes { total_ns: 10, gaussian_ns: 7, ..StageTimes::default() };
        let b = StageTimes { total_ns: 4, gaussian_ns: 9, ..StageTimes::default() };
        let m = a.min_with(&b);
        assert_eq!(m.total_ns, 4);
        assert_eq!(m.gaussian_ns, 7);
    }

    #[test]
    fn params_validation() {
        assert!(CannyParams { lo: -0.1, ..CannyParams::default() }.validate().is_err());
        assert!(CannyParams { lo: 0.5, hi: 0.1, ..CannyParams::default() }.validate().is_err());
        assert!(CannyParams { tile: 0, ..CannyParams::default() }.validate().is_err());
        assert!(CannyParams::default().validate().is_ok());
    }

    #[test]
    fn engine_parse_roundtrip() {
        for e in [Engine::Serial, Engine::Patterns, Engine::TiledPatterns, Engine::PatternsXla] {
            assert_eq!(Engine::parse(e.name()), Some(e));
        }
        assert_eq!(Engine::parse("bogus"), None);
    }

    #[test]
    fn patterns_without_pool_errors() {
        let img = test_image();
        let p = CannyPipeline { engine: Engine::Patterns, pool: None, xla: None };
        assert!(p.detect(&img, &CannyParams::default()).is_err());
    }

    #[test]
    fn tiny_image_single_tile() {
        let img = generate(Scene::Checker { cell: 2 }, 9, 7);
        let pool = Pool::new(2).unwrap();
        let params = CannyParams { tile: 128, ..CannyParams::default() };
        let serial = CannyPipeline::serial().detect(&img, &params).unwrap();
        let tiled = CannyPipeline::tiled(&pool).detect(&img, &params).unwrap();
        assert_eq!(serial.edges.diff_count(&tiled.edges), 0);
    }
}
