//! Stage 1 — separable 5×5 Gaussian blur (σ = 1.4), the paper's "filter
//! out any noise" step. Two 1-D passes; the inner loops are written as
//! flat slice MACs so the compiler auto-vectorizes them (the AVX/SIMD
//! angle of the paper on this host).
//!
//! Shape algebra matches the Pallas kernel: rows (H, W) → (H, W-4),
//! cols (H, W) → (H-4, W); composed (H, W) → (H-4, W-4).

use crate::canny::consts::GAUSS5;
use crate::image::ImageF32;

/// Horizontal pass into a caller-provided row buffer.
/// `src_row` has width W; `dst_row` must have width W-4.
#[inline]
pub fn gauss_row_into(src_row: &[f32], dst_row: &mut [f32]) {
    let w_out = dst_row.len();
    debug_assert_eq!(src_row.len(), w_out + 4);
    let [w0, w1, w2, w3, w4] = GAUSS5;
    for (j, d) in dst_row.iter_mut().enumerate() {
        // 5-tap MAC over contiguous input — vectorizable.
        *d = w0 * src_row[j]
            + w1 * src_row[j + 1]
            + w2 * src_row[j + 2]
            + w3 * src_row[j + 3]
            + w4 * src_row[j + 4];
    }
}

/// Vertical pass for one output row `y` (reads rows y..y+5 of `src`).
#[inline]
pub fn gauss_col_row_into(src: &ImageF32, y: usize, dst_row: &mut [f32]) {
    let w = src.width();
    debug_assert_eq!(dst_row.len(), w);
    let [w0, w1, w2, w3, w4] = GAUSS5;
    let r0 = src.row(y);
    let r1 = src.row(y + 1);
    let r2 = src.row(y + 2);
    let r3 = src.row(y + 3);
    let r4 = src.row(y + 4);
    for j in 0..w {
        dst_row[j] = w0 * r0[j] + w1 * r1[j] + w2 * r2[j] + w3 * r3[j] + w4 * r4[j];
    }
}

/// Horizontal 5-tap pass. (H, W) → (H, W-4).
pub fn gauss_rows(src: &ImageF32) -> ImageF32 {
    let (w, h) = (src.width(), src.height());
    assert!(w >= 5, "width {w} < 5");
    let mut out = ImageF32::zeros(w - 4, h);
    let w_out = w - 4;
    for y in 0..h {
        let src_row = src.row(y);
        let dst = &mut out.data_mut()[y * w_out..(y + 1) * w_out];
        gauss_row_into(src_row, dst);
    }
    out
}

/// Vertical 5-tap pass. (H, W) → (H-4, W).
pub fn gauss_cols(src: &ImageF32) -> ImageF32 {
    let (w, h) = (src.width(), src.height());
    assert!(h >= 5, "height {h} < 5");
    let mut out = ImageF32::zeros(w, h - 4);
    for y in 0..h - 4 {
        let dst = &mut out.data_mut()[y * w..(y + 1) * w];
        gauss_col_row_into(src, y, dst);
    }
    out
}

/// Separable blur. (H, W) → (H-4, W-4).
pub fn gaussian(src: &ImageF32) -> ImageF32 {
    gauss_cols(&gauss_rows(src))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ramp(w: usize, h: usize) -> ImageF32 {
        ImageF32::from_vec(w, h, (0..w * h).map(|i| (i % 97) as f32 / 97.0).collect()).unwrap()
    }

    #[test]
    fn shapes() {
        let img = ramp(20, 12);
        assert_eq!(gauss_rows(&img).width(), 16);
        assert_eq!(gauss_rows(&img).height(), 12);
        let g = gaussian(&img);
        assert_eq!((g.width(), g.height()), (16, 8));
    }

    #[test]
    fn constant_image_preserved() {
        let img = ImageF32::from_vec(10, 10, vec![0.6; 100]).unwrap();
        let g = gaussian(&img);
        for &v in g.data() {
            assert!((v - 0.6).abs() < 1e-6, "v={v}");
        }
    }

    #[test]
    fn matches_naive_2d_convolution() {
        let img = ramp(16, 14);
        let g = gaussian(&img);
        // Naive O(25) reference.
        for y in 0..g.height() {
            for x in 0..g.width() {
                let mut acc = 0.0f64;
                for ky in 0..5 {
                    for kx in 0..5 {
                        acc += (GAUSS5[ky] as f64)
                            * (GAUSS5[kx] as f64)
                            * img.get(y + ky, x + kx) as f64;
                    }
                }
                assert!(
                    (g.get(y, x) as f64 - acc).abs() < 1e-5,
                    "({y},{x}): {} vs {acc}",
                    g.get(y, x)
                );
            }
        }
    }

    #[test]
    fn blur_reduces_variance() {
        // White noise should lose energy under a low-pass filter.
        let mut rng = crate::util::Prng::new(99);
        let data: Vec<f32> = (0..64 * 64).map(|_| rng.next_f32()).collect();
        let img = ImageF32::from_vec(64, 64, data).unwrap();
        let g = gaussian(&img);
        let var = |im: &ImageF32| {
            let m = im.mean() as f64;
            im.data().iter().map(|&v| (v as f64 - m).powi(2)).sum::<f64>() / im.len() as f64
        };
        assert!(var(&g) < var(&img) * 0.5);
    }
}
