//! Stage 4b — hysteresis connectivity: weak pixels become edges iff
//! 8-connected (transitively) to a strong pixel.
//!
//! [`hysteresis_serial`] is the paper's choice: it deliberately leaves
//! this stage serial ("the serial elision it carries … the if statement
//! pattern") and reasons about the cost with Amdahl's law.
//!
//! [`hysteresis_parallel`] is the extension DESIGN.md calls out: weak→
//! edge promotion is *monotone*, so a parallel label-propagation with
//! atomic claims produces the identical fixpoint regardless of
//! interleaving — deterministic output without the serial elision. The
//! ablation bench quantifies what the paper left on the table.

use std::sync::atomic::{AtomicU8, Ordering};

use crate::canny::threshold::{CLASS_STRONG, CLASS_WEAK};
use crate::image::{EdgeMap, ImageF32};
use crate::patterns;
use crate::scheduler::Pool;

const NEIGHBOURS: [(i64, i64); 8] =
    [(-1, -1), (-1, 0), (-1, 1), (0, -1), (0, 1), (1, -1), (1, 0), (1, 1)];

/// Serial DFS from every strong pixel (the paper's step 4).
pub fn hysteresis_serial(cls: &ImageF32) -> EdgeMap {
    let (w, h) = (cls.width(), cls.height());
    let mut out = vec![0u8; w * h];
    let mut stack: Vec<(usize, usize)> = Vec::new();
    for y in 0..h {
        for x in 0..w {
            if cls.get(y, x) == CLASS_STRONG && out[y * w + x] == 0 {
                out[y * w + x] = 255;
                stack.push((y, x));
                while let Some((cy, cx)) = stack.pop() {
                    for (dy, dx) in NEIGHBOURS {
                        let ny = cy as i64 + dy;
                        let nx = cx as i64 + dx;
                        if ny < 0 || nx < 0 || ny >= h as i64 || nx >= w as i64 {
                            continue;
                        }
                        let (ny, nx) = (ny as usize, nx as usize);
                        let idx = ny * w + nx;
                        if out[idx] == 0 && cls.get(ny, nx) >= CLASS_WEAK {
                            out[idx] = 255;
                            stack.push((ny, nx));
                        }
                    }
                }
            }
        }
    }
    EdgeMap::new(w, h, out).expect("sized correctly")
}

/// Parallel label propagation: strong seeds are partitioned over
/// workers; each worker BFS-claims pixels with an atomic CAS. Because
/// promotion is monotone (0 → 255 once), the reachable set — and thus
/// the output — is schedule-independent.
pub fn hysteresis_parallel(pool: &Pool, cls: &ImageF32) -> EdgeMap {
    let (w, h) = (cls.width(), cls.height());
    let flags: Vec<AtomicU8> = (0..w * h).map(|_| AtomicU8::new(0)).collect();
    // Collect strong seeds (serial scan, cheap) then fan out.
    let seeds: Vec<usize> = (0..w * h)
        .filter(|&i| cls.data()[i] == CLASS_STRONG)
        .collect();
    let grain = patterns::auto_grain(seeds.len(), pool.n_workers());
    // One task per seed *band* (par_rows is just chunked indices), so
    // each task reuses a single BFS stack across its seeds. Claim the
    // seed with the atomic FIRST — on dense seed maps most seeds are
    // already claimed by a neighbour's flood, and losing the race must
    // cost a compare-exchange, not a heap allocation.
    patterns::par_rows(pool, seeds.len(), grain, |band| {
        let mut stack: Vec<usize> = Vec::new();
        for si in band {
            if flags[seeds[si]].swap(255, Ordering::AcqRel) != 0 {
                continue;
            }
            stack.push(seeds[si]);
            while let Some(idx) = stack.pop() {
                let (cy, cx) = (idx / w, idx % w);
                for (dy, dx) in NEIGHBOURS {
                    let ny = cy as i64 + dy;
                    let nx = cx as i64 + dx;
                    if ny < 0 || nx < 0 || ny >= h as i64 || nx >= w as i64 {
                        continue;
                    }
                    let nidx = ny as usize * w + nx as usize;
                    if cls.data()[nidx] >= CLASS_WEAK
                        && flags[nidx].swap(255, Ordering::AcqRel) == 0
                    {
                        stack.push(nidx);
                    }
                }
            }
        }
    });
    let out: Vec<u8> = flags.into_iter().map(|f| f.into_inner()).collect();
    EdgeMap::new(w, h, out).expect("sized correctly")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cls(w: usize, h: usize, strong: &[(usize, usize)], weak: &[(usize, usize)]) -> ImageF32 {
        let mut c = ImageF32::zeros(w, h);
        for &(y, x) in weak {
            c.set(y, x, CLASS_WEAK);
        }
        for &(y, x) in strong {
            c.set(y, x, CLASS_STRONG);
        }
        c
    }

    #[test]
    fn weak_connected_to_strong_survives() {
        let c = cls(8, 8, &[(4, 4)], &[(4, 5), (4, 6), (5, 5)]);
        let em = hysteresis_serial(&c);
        assert!(em.is_edge(4, 4));
        assert!(em.is_edge(4, 5));
        assert!(em.is_edge(4, 6)); // transitively connected
        assert!(em.is_edge(5, 5)); // diagonal connectivity
        assert_eq!(em.count_edges(), 4);
    }

    #[test]
    fn isolated_weak_dropped() {
        let c = cls(8, 8, &[(1, 1)], &[(6, 6)]);
        let em = hysteresis_serial(&c);
        assert!(em.is_edge(1, 1));
        assert!(!em.is_edge(6, 6));
        assert_eq!(em.count_edges(), 1);
    }

    #[test]
    fn weak_chain_propagates() {
        let weak: Vec<(usize, usize)> = (1..7).map(|x| (3usize, x)).collect();
        let c = cls(8, 8, &[(3, 0)], &weak);
        let em = hysteresis_serial(&c);
        for x in 0..7 {
            assert!(em.is_edge(3, x), "x={x}");
        }
    }

    #[test]
    fn parallel_matches_serial() {
        let pool = Pool::new(4).unwrap();
        let mut rng = crate::util::Prng::new(31);
        for _ in 0..10 {
            let (w, h) = (40, 30);
            let mut c = ImageF32::zeros(w, h);
            for y in 0..h {
                for x in 0..w {
                    let r = rng.next_f32();
                    c.set(y, x, if r > 0.95 { 2.0 } else if r > 0.6 { 1.0 } else { 0.0 });
                }
            }
            let a = hysteresis_serial(&c);
            let b = hysteresis_parallel(&pool, &c);
            assert_eq!(a.diff_count(&b), 0);
        }
    }

    #[test]
    fn parallel_deterministic_across_pool_sizes() {
        let c = cls(16, 16, &[(8, 8), (2, 2)], &[(8, 9), (8, 10), (3, 3), (4, 4)]);
        let p1 = Pool::new(1).unwrap();
        let p8 = Pool::new(8).unwrap();
        let a = hysteresis_parallel(&p1, &c);
        let b = hysteresis_parallel(&p8, &c);
        assert_eq!(a.diff_count(&b), 0);
    }

    #[test]
    fn empty_class_map() {
        let c = ImageF32::zeros(10, 10);
        assert_eq!(hysteresis_serial(&c).count_edges(), 0);
    }
}
