//! The **stage graph** behind the detection API: the Canny pipeline as
//! six addressable stages with typed artifacts, instead of a monolithic
//! `detect(img) -> edges` black box.
//!
//! A [`StagePlan`] selects
//!
//! * a **stop stage** — run only a prefix of the pipeline (front-only,
//!   gradient-only, NMS-only) and get that stage's [`Artifact`] back;
//! * an **entry artifact** — resume mid-pipeline from a cached
//!   intermediate (re-threshold a suppressed-magnitude map with new
//!   `lo`/`hi` without recomputing Gaussian/Sobel/NMS);
//! * per-stage **engine / grain overrides** — swap the front engine or
//!   pin a band grain for one stage without rebuilding the detector.
//!
//! Execution ([`crate::canny::CannyPipeline::execute`]) returns a
//! [`PlanOutput`]: the artifacts the plan produced plus one uniform
//! [`StageRecord`] per executed phase (`kind`, `engine`, `wall_ns`,
//! `cpu_ns`, `tasks`). The legacy [`StageTimes`] is now a view computed
//! from the records ([`StageTimes::from_records`]), kept for the
//! benches, the simulator specs and the serving tier's end-to-end
//! calibration.
//!
//! The full plan (`entry = Image`, `stop = Hysteresis`, no overrides)
//! is what [`CannyPipeline::detect`](crate::canny::CannyPipeline::detect)
//! runs; the fused-tile fast paths are preserved bit-for-bit, which the
//! engine-equivalence determinism tests enforce.

use crate::canny::pipeline::{Engine, StageTimes};
use crate::error::{Error, Result};
use crate::image::{EdgeMap, ImageF32};

/// The pipeline stages, in execution order (the derived `Ord` *is* the
/// pipeline order — `Gaussian < Nms` etc., used for prefix checks).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum StageKind {
    /// Replicate-pad the input by the halo.
    Pad,
    /// 5×5 separable Gaussian smoothing.
    Gaussian,
    /// Sobel gradient magnitude + direction.
    Sobel,
    /// Non-maximum suppression along the gradient direction.
    Nms,
    /// Double-threshold classification (none/weak/strong).
    Threshold,
    /// Weak→edge connectivity (the only data-dependent stage).
    Hysteresis,
}

impl StageKind {
    /// Every stage, pipeline order.
    pub const ALL: [StageKind; 6] = [
        StageKind::Pad,
        StageKind::Gaussian,
        StageKind::Sobel,
        StageKind::Nms,
        StageKind::Threshold,
        StageKind::Hysteresis,
    ];

    /// CLI / report name.
    pub fn name(&self) -> &'static str {
        match self {
            StageKind::Pad => "pad",
            StageKind::Gaussian => "gaussian",
            StageKind::Sobel => "sobel",
            StageKind::Nms => "nms",
            StageKind::Threshold => "threshold",
            StageKind::Hysteresis => "hysteresis",
        }
    }

    /// Parse a `--stop-after` value.
    pub fn parse(s: &str) -> Option<StageKind> {
        match s {
            "pad" => Some(StageKind::Pad),
            "gaussian" | "gauss" => Some(StageKind::Gaussian),
            "sobel" | "gradient" => Some(StageKind::Sobel),
            "nms" | "suppress" => Some(StageKind::Nms),
            "threshold" => Some(StageKind::Threshold),
            "hysteresis" | "edges" => Some(StageKind::Hysteresis),
            _ => None,
        }
    }
}

/// A typed pipeline product. Which variant a stage yields:
/// Pad/Gaussian → `Gray`, Sobel → `Gradient`, Nms → `Suppressed`,
/// Threshold → `ClassMap`, Hysteresis → `Edges`.
#[derive(Clone, Debug)]
pub enum Artifact {
    /// A grayscale field (the padded input, or the smoothed image).
    Gray(ImageF32),
    /// Gradient magnitude + direction.
    Gradient { mag: ImageF32, dir: ImageF32 },
    /// Suppressed gradient magnitude (image-sized) — the re-threshold
    /// entry artifact.
    Suppressed(ImageF32),
    /// 0/1/2 class map before connectivity.
    ClassMap(ImageF32),
    /// The final binary edge map.
    Edges(EdgeMap),
}

impl Artifact {
    /// CLI / report name (`--emit` values).
    pub fn name(&self) -> &'static str {
        match self {
            Artifact::Gray(_) => "gray",
            Artifact::Gradient { .. } => "gradient",
            Artifact::Suppressed(_) => "suppressed",
            Artifact::ClassMap(_) => "class-map",
            Artifact::Edges(_) => "edges",
        }
    }

    /// Payload bytes of the artifact's pixel data — the cache tier's
    /// cost unit ([`crate::cache`] budgets by size, not entry count).
    pub fn byte_size(&self) -> usize {
        const F32: usize = std::mem::size_of::<f32>();
        match self {
            Artifact::Gray(g) => g.len() * F32,
            Artifact::Gradient { mag, dir } => (mag.len() + dir.len()) * F32,
            Artifact::Suppressed(nm) => nm.len() * F32,
            Artifact::ClassMap(c) => c.len() * F32,
            Artifact::Edges(e) => e.data().len(),
        }
    }
}

/// Where a plan starts.
#[derive(Clone, Debug, Default)]
pub enum PlanEntry {
    /// From a raw image (passed to `execute`); runs from [`StageKind::Pad`].
    #[default]
    Image,
    /// Resume from a cached suppressed-magnitude map; runs from
    /// [`StageKind::Threshold`] — the re-threshold path.
    Suppressed(ImageF32),
    /// Resume from a class map; runs [`StageKind::Hysteresis`] only.
    ClassMap(ImageF32),
}

impl PlanEntry {
    /// First stage this entry executes.
    pub fn first_stage(&self) -> StageKind {
        match self {
            PlanEntry::Image => StageKind::Pad,
            PlanEntry::Suppressed(_) => StageKind::Threshold,
            PlanEntry::ClassMap(_) => StageKind::Hysteresis,
        }
    }
}

/// A composable execution plan over the stage graph. Built via
/// [`crate::coordinator::Detector::plan`] (or [`StagePlan::new`]) and
/// executed by [`crate::canny::CannyPipeline::execute`].
#[derive(Clone, Debug)]
pub struct StagePlan {
    /// Run through this stage inclusive (default: the whole pipeline).
    pub stop: StageKind,
    /// Where execution starts (default: from the raw image).
    pub entry: PlanEntry,
    /// Front-engine override (default: the pipeline's own engine).
    pub engine: Option<Engine>,
    /// Hysteresis-engine override (default: `params.parallel_hysteresis`).
    pub parallel_hysteresis: Option<bool>,
    /// Per-stage band-grain overrides (0 = auto), beating
    /// `params.band_grain` for that stage only.
    pub grains: Vec<(StageKind, usize)>,
}

impl Default for StagePlan {
    fn default() -> Self {
        StagePlan::new()
    }
}

impl StagePlan {
    /// The full plan: image in, edges out, no overrides.
    pub fn new() -> StagePlan {
        StagePlan {
            stop: StageKind::Hysteresis,
            entry: PlanEntry::Image,
            engine: None,
            parallel_hysteresis: None,
            grains: Vec::new(),
        }
    }

    /// Stop after `stage` (inclusive) and return its artifact.
    pub fn stop_after(mut self, stage: StageKind) -> Self {
        self.stop = stage;
        self
    }

    /// Resume from a cached suppressed-magnitude map (the re-threshold
    /// entry): only Threshold (and Hysteresis, per `stop`) run.
    pub fn from_suppressed(mut self, nm: ImageF32) -> Self {
        self.entry = PlanEntry::Suppressed(nm);
        self
    }

    /// Resume from a class map: only Hysteresis runs.
    pub fn from_class_map(mut self, cls: ImageF32) -> Self {
        self.entry = PlanEntry::ClassMap(cls);
        self
    }

    /// Override the front engine for this plan.
    pub fn engine(mut self, engine: Engine) -> Self {
        self.engine = Some(engine);
        self
    }

    /// Override the hysteresis engine for this plan.
    pub fn parallel_hysteresis(mut self, on: bool) -> Self {
        self.parallel_hysteresis = Some(on);
        self
    }

    /// Override the row-band grain for one stage (0 = auto). Grains
    /// apply to the band-parallel stage path: a plan carrying any
    /// grain override executes the fused-tile engines unfused, so the
    /// override is honored rather than silently dropped.
    pub fn stage_grain(mut self, stage: StageKind, grain: usize) -> Self {
        self.grains.retain(|(k, _)| *k != stage);
        self.grains.push((stage, grain));
        self
    }

    /// The grain override for `stage`, if any (and non-auto).
    pub fn grain_for(&self, stage: StageKind) -> Option<usize> {
        self.grains.iter().find(|(k, _)| *k == stage).map(|&(_, g)| g).filter(|&g| g > 0)
    }

    /// Is this the unmodified image→edges plan (the `detect` fast path)?
    pub fn is_full(&self) -> bool {
        matches!(self.entry, PlanEntry::Image) && self.stop == StageKind::Hysteresis
    }

    /// Check entry/stop consistency: the stop stage must not precede
    /// the entry's first stage.
    pub fn validate(&self) -> Result<()> {
        if self.stop < self.entry.first_stage() {
            return Err(Error::Config(format!(
                "plan stops at `{}` but its entry artifact resumes at `{}`",
                self.stop.name(),
                self.entry.first_stage().name()
            )));
        }
        Ok(())
    }
}

/// Uniform per-phase accounting: one record per executed phase. For the
/// fused-tile engines the whole front is one phase — `fused_from` marks
/// the first stage the phase covers and `kind` the last.
#[derive(Clone, Debug)]
pub struct StageRecord {
    /// The stage this record completes.
    pub kind: StageKind,
    /// When `Some(first)`, this record covers `first..=kind` fused into
    /// one phase (the tiled engines' fused front).
    pub fused_from: Option<StageKind>,
    /// Engine that executed the phase.
    pub engine: Engine,
    pub wall_ns: u64,
    /// Thread-CPU cost: summed per-task CPU where tasks are timed
    /// (fused tile fronts), the executing thread's CPU for serial
    /// phases, and the wall clock as a proxy for untimed band-parallel
    /// phases.
    pub cpu_ns: u64,
    /// Parallel tasks the phase decomposed into (1 for serial phases).
    pub tasks: u64,
    /// Per-task thread-CPU costs where measured (fused tile fronts) —
    /// the simulator's load-balance input.
    pub task_costs_ns: Vec<u64>,
}

impl StageRecord {
    /// Accounting name: the stage name, or `"front"` for a fused span.
    pub fn span_name(&self) -> &'static str {
        if self.fused_from.is_some() {
            "front"
        } else {
            self.kind.name()
        }
    }

    /// Does this record's phase cover `stage`?
    pub fn covers(&self, stage: StageKind) -> bool {
        match self.fused_from {
            Some(first) => first <= stage && stage <= self.kind,
            None => self.kind == stage,
        }
    }
}

/// What a plan execution returns: the artifacts the executed stages
/// produced (big intermediates before NMS are kept only when they *are*
/// the stop artifact; entry artifacts are not echoed back) plus the
/// per-phase records.
#[derive(Clone, Debug, Default)]
pub struct PlanOutput {
    pub artifacts: Vec<Artifact>,
    pub records: Vec<StageRecord>,
    pub total_ns: u64,
}

impl PlanOutput {
    fn find(&self, name: &str) -> Option<&Artifact> {
        self.artifacts.iter().find(|a| a.name() == name)
    }

    pub fn gray(&self) -> Option<&ImageF32> {
        match self.find("gray") {
            Some(Artifact::Gray(g)) => Some(g),
            _ => None,
        }
    }

    pub fn gradient(&self) -> Option<(&ImageF32, &ImageF32)> {
        match self.find("gradient") {
            Some(Artifact::Gradient { mag, dir }) => Some((mag, dir)),
            _ => None,
        }
    }

    pub fn suppressed(&self) -> Option<&ImageF32> {
        match self.find("suppressed") {
            Some(Artifact::Suppressed(nm)) => Some(nm),
            _ => None,
        }
    }

    pub fn class_map(&self) -> Option<&ImageF32> {
        match self.find("class-map") {
            Some(Artifact::ClassMap(c)) => Some(c),
            _ => None,
        }
    }

    pub fn edges(&self) -> Option<&EdgeMap> {
        match self.find("edges") {
            Some(Artifact::Edges(e)) => Some(e),
            _ => None,
        }
    }

    /// Move the suppressed-magnitude artifact out (the serving tier's
    /// cache-fill path — avoids a clone of the biggest artifact).
    pub fn take_suppressed(&mut self) -> Option<ImageF32> {
        let i = self.artifacts.iter().position(|a| matches!(a, Artifact::Suppressed(_)))?;
        match self.artifacts.remove(i) {
            Artifact::Suppressed(nm) => Some(nm),
            _ => unreachable!("position matched Suppressed"),
        }
    }

    /// Move the edge-map artifact out (the stream tier's per-frame
    /// emission path — avoids cloning every emitted frame).
    pub fn take_edges(&mut self) -> Option<EdgeMap> {
        let i = self.artifacts.iter().position(|a| matches!(a, Artifact::Edges(_)))?;
        match self.artifacts.remove(i) {
            Artifact::Edges(e) => Some(e),
            _ => unreachable!("position matched Edges"),
        }
    }

    /// Move the class-map artifact out (resume-from-class-map reuse).
    pub fn take_class_map(&mut self) -> Option<ImageF32> {
        let i = self.artifacts.iter().position(|a| matches!(a, Artifact::ClassMap(_)))?;
        match self.artifacts.remove(i) {
            Artifact::ClassMap(c) => Some(c),
            _ => unreachable!("position matched ClassMap"),
        }
    }

    /// Did any executed phase cover `stage`?
    pub fn ran(&self, stage: StageKind) -> bool {
        self.records.iter().any(|r| r.covers(stage))
    }

    /// The legacy [`StageTimes`] compatibility view over the records.
    pub fn stage_times(&self) -> StageTimes {
        StageTimes::from_records(&self.records, self.total_ns)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_order_and_parse() {
        assert!(StageKind::Pad < StageKind::Gaussian);
        assert!(StageKind::Threshold < StageKind::Hysteresis);
        for k in StageKind::ALL {
            assert_eq!(StageKind::parse(k.name()), Some(k));
        }
        assert_eq!(StageKind::parse("gradient"), Some(StageKind::Sobel));
        assert_eq!(StageKind::parse("edges"), Some(StageKind::Hysteresis));
        assert_eq!(StageKind::parse("bogus"), None);
    }

    #[test]
    fn plan_builders_and_validation() {
        let full = StagePlan::new();
        assert!(full.is_full());
        assert!(full.validate().is_ok());

        let front = StagePlan::new().stop_after(StageKind::Nms);
        assert!(!front.is_full());
        assert!(front.validate().is_ok());

        // Resuming from a suppressed map but stopping before Threshold
        // is contradictory.
        let bad = StagePlan::new()
            .from_suppressed(ImageF32::zeros(4, 4))
            .stop_after(StageKind::Sobel);
        assert!(bad.validate().is_err());

        let ok = StagePlan::new()
            .from_suppressed(ImageF32::zeros(4, 4))
            .stop_after(StageKind::Threshold);
        assert!(ok.validate().is_ok());
        assert_eq!(ok.entry.first_stage(), StageKind::Threshold);
    }

    #[test]
    fn grain_overrides_latest_wins_and_zero_is_auto() {
        let p = StagePlan::new()
            .stage_grain(StageKind::Gaussian, 8)
            .stage_grain(StageKind::Gaussian, 16)
            .stage_grain(StageKind::Sobel, 0);
        assert_eq!(p.grain_for(StageKind::Gaussian), Some(16));
        assert_eq!(p.grain_for(StageKind::Sobel), None, "0 means auto");
        assert_eq!(p.grain_for(StageKind::Nms), None);
    }

    #[test]
    fn record_span_names_and_coverage() {
        let fused = StageRecord {
            kind: StageKind::Threshold,
            fused_from: Some(StageKind::Pad),
            engine: Engine::TiledPatterns,
            wall_ns: 10,
            cpu_ns: 10,
            tasks: 4,
            task_costs_ns: vec![2, 3, 2, 3],
        };
        assert_eq!(fused.span_name(), "front");
        assert!(fused.covers(StageKind::Gaussian));
        assert!(fused.covers(StageKind::Threshold));
        assert!(!fused.covers(StageKind::Hysteresis));
        let plain = StageRecord {
            kind: StageKind::Nms,
            fused_from: None,
            engine: Engine::Serial,
            wall_ns: 5,
            cpu_ns: 5,
            tasks: 1,
            task_costs_ns: Vec::new(),
        };
        assert_eq!(plain.span_name(), "nms");
        assert!(plain.covers(StageKind::Nms));
        assert!(!plain.covers(StageKind::Sobel));
    }

    #[test]
    fn artifact_byte_sizes() {
        let f32s = |px: usize| px * 4;
        assert_eq!(Artifact::Gray(ImageF32::zeros(8, 4)).byte_size(), f32s(32));
        assert_eq!(
            Artifact::Gradient { mag: ImageF32::zeros(8, 4), dir: ImageF32::zeros(8, 4) }
                .byte_size(),
            f32s(64)
        );
        assert_eq!(Artifact::Suppressed(ImageF32::zeros(3, 3)).byte_size(), f32s(9));
        assert_eq!(Artifact::ClassMap(ImageF32::zeros(3, 3)).byte_size(), f32s(9));
        let edges = crate::image::EdgeMap::new(4, 2, vec![0; 8]).unwrap();
        assert_eq!(Artifact::Edges(edges).byte_size(), 8);
    }

    #[test]
    fn output_accessors_and_take() {
        let mut out = PlanOutput {
            artifacts: vec![
                Artifact::Suppressed(ImageF32::zeros(3, 2)),
                Artifact::ClassMap(ImageF32::zeros(3, 2)),
            ],
            records: Vec::new(),
            total_ns: 0,
        };
        assert!(out.suppressed().is_some());
        assert!(out.class_map().is_some());
        assert!(out.edges().is_none());
        let nm = out.take_suppressed().unwrap();
        assert_eq!((nm.width(), nm.height()), (3, 2));
        assert!(out.suppressed().is_none());
        assert!(out.take_suppressed().is_none());
    }

    #[test]
    fn take_edges_and_class_map_move_out() {
        let mut out = PlanOutput {
            artifacts: vec![
                Artifact::ClassMap(ImageF32::zeros(2, 2)),
                Artifact::Edges(crate::image::EdgeMap::new(2, 2, vec![0, 255, 0, 0]).unwrap()),
            ],
            records: Vec::new(),
            total_ns: 0,
        };
        let e = out.take_edges().unwrap();
        assert_eq!(e.count_edges(), 1);
        assert!(out.edges().is_none());
        assert!(out.take_edges().is_none());
        let c = out.take_class_map().unwrap();
        assert_eq!((c.width(), c.height()), (2, 2));
        assert!(out.take_class_map().is_none());
        assert!(out.artifacts.is_empty());
    }
}
