//! Stage 2 — fused Sobel gradient: Gx/Gy, magnitude, and branch-light
//! direction quantization (tangent comparisons, no atan2), mirroring
//! `python/compile/kernels/sobel.py` exactly.
//!
//! Direction encoding contract: 0 = E/W, 1 = NW/SE, 2 = N/S, 3 = NE/SW.

use crate::canny::consts::{TAN22, TAN67};
use crate::image::ImageF32;

/// Compute one output row `y` (of the (H-2, W-2) result) into buffers.
#[inline]
pub fn sobel_row_into(src: &ImageF32, y: usize, mag_row: &mut [f32], dir_row: &mut [f32]) {
    let w_out = src.width() - 2;
    debug_assert_eq!(mag_row.len(), w_out);
    debug_assert_eq!(dir_row.len(), w_out);
    let r0 = src.row(y);
    let r1 = src.row(y + 1);
    let r2 = src.row(y + 2);
    for j in 0..w_out {
        let (a, b, c) = (r0[j], r0[j + 1], r0[j + 2]);
        let (d, f) = (r1[j], r1[j + 2]);
        let (g, h, i) = (r2[j], r2[j + 1], r2[j + 2]);
        let gx = (c - a) + 2.0 * (f - d) + (i - g);
        let gy = (a + 2.0 * b + c) - (g + 2.0 * h + i);
        mag_row[j] = (gx * gx + gy * gy).sqrt();
        let adx = gx.abs();
        let ady = gy.abs();
        dir_row[j] = if ady <= TAN22 * adx {
            0.0
        } else if ady > TAN67 * adx {
            2.0
        } else if gx * gy >= 0.0 {
            1.0
        } else {
            3.0
        };
    }
}

/// Fused Sobel. (H, W) → (mag, dir) each (H-2, W-2).
pub fn sobel(src: &ImageF32) -> (ImageF32, ImageF32) {
    let (w, h) = (src.width(), src.height());
    assert!(w >= 3 && h >= 3, "sobel needs >= 3x3, got {w}x{h}");
    let (w_out, h_out) = (w - 2, h - 2);
    let mut mag = ImageF32::zeros(w_out, h_out);
    let mut dir = ImageF32::zeros(w_out, h_out);
    for y in 0..h_out {
        // Split disjoint row borrows.
        let mag_row_ptr = &mut mag.data_mut()[y * w_out..(y + 1) * w_out] as *mut [f32];
        let dir_row = &mut dir.data_mut()[y * w_out..(y + 1) * w_out];
        // SAFETY: mag and dir are distinct allocations; raw split only to
        // satisfy the borrow checker across the two &mut.
        let mag_row = unsafe { &mut *mag_row_ptr };
        sobel_row_into(src, y, mag_row, dir_row);
    }
    (mag, dir)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flat_is_zero() {
        let img = ImageF32::from_vec(8, 8, vec![0.4; 64]).unwrap();
        let (mag, dir) = sobel(&img);
        assert!(mag.data().iter().all(|&v| v == 0.0));
        assert!(dir.data().iter().all(|&v| v == 0.0));
    }

    #[test]
    fn vertical_step_gives_bin0() {
        // Left half dark, right half bright: horizontal gradient.
        let mut img = ImageF32::zeros(10, 10);
        for y in 0..10 {
            for x in 5..10 {
                img.set(y, x, 1.0);
            }
        }
        let (mag, dir) = sobel(&img);
        for y in 0..8 {
            assert!(mag.get(y, 4) > 0.0); // x=4 out maps to x=5 boundary
            assert_eq!(dir.get(y, 4), 0.0);
        }
    }

    #[test]
    fn horizontal_step_gives_bin2() {
        let mut img = ImageF32::zeros(10, 10);
        for y in 5..10 {
            for x in 0..10 {
                img.set(y, x, 1.0);
            }
        }
        let (mag, dir) = sobel(&img);
        for x in 0..8 {
            assert!(mag.get(4, x) > 0.0);
            assert_eq!(dir.get(4, x), 2.0);
        }
    }

    #[test]
    fn diagonal_step_gives_diagonal_bin() {
        // Bright below the main diagonal: gradient along the other diagonal.
        let mut img = ImageF32::zeros(12, 12);
        for y in 0..12 {
            for x in 0..12 {
                if x + y > 11 {
                    img.set(y, x, 1.0);
                }
            }
        }
        let (_, dir) = sobel(&img);
        // On the anti-diagonal boundary, direction must be a diagonal bin.
        let d = dir.get(5, 5);
        assert!(d == 1.0 || d == 3.0, "d={d}");
    }

    #[test]
    fn magnitude_scale_invariance() {
        // Doubling contrast doubles magnitude.
        let mut img = ImageF32::zeros(8, 8);
        for y in 0..8 {
            for x in 4..8 {
                img.set(y, x, 0.5);
            }
        }
        let (mag1, _) = sobel(&img);
        let img2 = ImageF32::from_vec(8, 8, img.data().iter().map(|v| v * 2.0).collect()).unwrap();
        let (mag2, _) = sobel(&img2);
        for (a, b) in mag1.data().iter().zip(mag2.data()) {
            assert!((b - 2.0 * a).abs() < 1e-6);
        }
    }
}
