//! Pipeline pattern: staged throughput parallelism with bounded
//! inter-stage queues (backpressure). Order-preserving: every stage is
//! sequential internally, so outputs arrive in input order — which
//! keeps the whole pattern deterministic.
//!
//! Two forms:
//!
//! * [`pipeline2`] / [`pipeline3`] — fixed-arity closure chains with
//!   distinct inter-stage types (the original paper-style form).
//! * [`pipeline_stages`] — a runtime-chosen list of [`DynStage`]s over
//!   one message type, the generalization the stream tier
//!   ([`crate::stream`]) builds its decode → front → finish executor
//!   on: stages are picked per run (delta-gated front, budget-aware
//!   finish) rather than baked into the call's arity.

use std::sync::mpsc::{sync_channel, Receiver, SyncSender};

/// Run a 2-stage pipeline over `inputs` with bounded queues of
/// `capacity`. Returns outputs in input order.
pub fn pipeline2<A, B, C, I, S1, S2>(
    inputs: I,
    capacity: usize,
    s1: S1,
    s2: S2,
) -> Vec<C>
where
    A: Send,
    B: Send,
    C: Send,
    I: IntoIterator<Item = A> + Send,
    S1: FnMut(A) -> B + Send,
    S2: FnMut(B) -> C + Send,
{
    std::thread::scope(|scope| {
        let (tx1, rx1) = sync_channel::<B>(capacity.max(1));
        let h1 = scope.spawn(move || run_stage(inputs, s1, tx1));
        let out = collect_stage(rx1, s2);
        h1.join().expect("pipeline stage 1 panicked");
        out
    })
}

/// Run a 3-stage pipeline over `inputs` with bounded queues of
/// `capacity` between stages. Returns outputs in input order.
pub fn pipeline3<A, B, C, D, I, S1, S2, S3>(
    inputs: I,
    capacity: usize,
    s1: S1,
    s2: S2,
    s3: S3,
) -> Vec<D>
where
    A: Send,
    B: Send,
    C: Send,
    D: Send,
    I: IntoIterator<Item = A> + Send,
    S1: FnMut(A) -> B + Send,
    S2: FnMut(B) -> C + Send,
    S3: FnMut(C) -> D + Send,
{
    std::thread::scope(|scope| {
        let (tx1, rx1) = sync_channel::<B>(capacity.max(1));
        let (tx2, rx2) = sync_channel::<C>(capacity.max(1));
        let h1 = scope.spawn(move || run_stage(inputs, s1, tx1));
        let h2 = scope.spawn(move || {
            let mut s2 = s2;
            for item in rx1 {
                if tx2.send(s2(item)).is_err() {
                    break;
                }
            }
        });
        let out = collect_stage(rx2, s3);
        h1.join().expect("pipeline stage 1 panicked");
        h2.join().expect("pipeline stage 2 panicked");
        out
    })
}

/// One stage of a [`pipeline_stages`] chain: transforms the pipeline's
/// uniform message type in place-of-arity (stages that do not apply to
/// a message — e.g. a finish stage seeing a dropped frame — pass it
/// through unchanged).
pub type DynStage<'a, M> = Box<dyn FnMut(M) -> M + Send + 'a>;

/// Run a *dynamic* stage list as a linear pipeline over `inputs` with
/// bounded queues of `capacity` between consecutive stages — the
/// generalization of [`pipeline2`]/[`pipeline3`] from fixed-arity
/// closures to a runtime-built chain. One thread feeds the inputs
/// (lazily: generator sources run pipelined too), each stage but the
/// last gets its own thread, and the last stage runs on the calling
/// thread while collecting. Stages are sequential internally, so
/// outputs arrive in input order (the same determinism contract as the
/// fixed-arity forms). An empty stage list just collects the inputs.
pub fn pipeline_stages<'a, M, I>(
    inputs: I,
    capacity: usize,
    stages: Vec<DynStage<'a, M>>,
) -> Vec<M>
where
    M: Send + 'a,
    I: IntoIterator<Item = M> + Send + 'a,
{
    std::thread::scope(|scope| {
        let cap = capacity.max(1);
        let mut stages = stages;
        let last = stages.pop();
        let (tx0, mut rx) = sync_channel::<M>(cap);
        let mut handles = Vec::new();
        handles.push(scope.spawn(move || {
            for item in inputs {
                if tx0.send(item).is_err() {
                    break;
                }
            }
        }));
        for mut stage in stages {
            let (tx, next_rx) = sync_channel::<M>(cap);
            let prev = rx;
            handles.push(scope.spawn(move || {
                for item in prev {
                    if tx.send(stage(item)).is_err() {
                        break;
                    }
                }
            }));
            rx = next_rx;
        }
        let mut out = Vec::new();
        match last {
            Some(mut f) => {
                for item in rx {
                    out.push(f(item));
                }
            }
            None => out.extend(rx),
        }
        for h in handles {
            h.join().expect("pipeline stage panicked");
        }
        out
    })
}

fn run_stage<A, B>(
    inputs: impl IntoIterator<Item = A>,
    mut f: impl FnMut(A) -> B,
    tx: SyncSender<B>,
) {
    for item in inputs {
        if tx.send(f(item)).is_err() {
            break;
        }
    }
}

fn collect_stage<B, C>(rx: Receiver<B>, mut f: impl FnMut(B) -> C) -> Vec<C> {
    let mut out = Vec::new();
    for item in rx {
        out.push(f(item));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pipeline2_preserves_order() {
        let out = pipeline2(0..100, 4, |x: i32| x * 2, |x| x + 1);
        let expect: Vec<i32> = (0..100).map(|x| x * 2 + 1).collect();
        assert_eq!(out, expect);
    }

    #[test]
    fn pipeline3_composes() {
        let out = pipeline3(0..50, 2, |x: u64| x + 1, |x| x * x, |x| format!("{x}"));
        assert_eq!(out[3], "16");
        assert_eq!(out.len(), 50);
    }

    #[test]
    fn pipeline_handles_empty_input() {
        let out = pipeline3(Vec::<u8>::new(), 2, |x| x, |x| x, |x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn capacity_one_still_completes() {
        // Backpressure with the tightest queue must not deadlock.
        let out = pipeline3(0..1000, 1, |x: u32| x, |x| x, |x| x);
        assert_eq!(out.len(), 1000);
    }

    #[test]
    fn pipeline_stages_matches_serial_composition() {
        let stages: Vec<DynStage<i64>> = vec![
            Box::new(|x| x + 1),
            Box::new(|x| x * 3),
            Box::new(|x| x - 2),
        ];
        let out = pipeline_stages(0..200i64, 4, stages);
        let expect: Vec<i64> = (0..200).map(|x| (x + 1) * 3 - 2).collect();
        assert_eq!(out, expect);
    }

    #[test]
    fn pipeline_stages_preserves_order_with_stateful_stages() {
        // A stateful (FnMut) stage tags each message with its arrival
        // rank; ranks must equal indices if order is preserved.
        let mut rank = 0usize;
        let stages: Vec<DynStage<(usize, usize)>> = vec![Box::new(move |(i, _)| {
            let r = rank;
            rank += 1;
            (i, r)
        })];
        let out = pipeline_stages((0..500).map(|i| (i, 0)), 2, stages);
        assert!(out.iter().all(|&(i, r)| i == r));
    }

    #[test]
    fn pipeline_stages_empty_and_no_stage_cases() {
        let none: Vec<DynStage<u8>> = Vec::new();
        assert_eq!(pipeline_stages(vec![1u8, 2, 3], 1, none), vec![1, 2, 3]);
        let one: Vec<DynStage<u8>> = vec![Box::new(|x| x * 2)];
        assert!(pipeline_stages(Vec::<u8>::new(), 4, one).is_empty());
    }

    #[test]
    fn pipeline_stages_borrows_environment() {
        // Stages may borrow locals (the stream executor borrows the
        // detector and frame source this way).
        let offset = 10i32;
        let sink = std::cell::Cell::new(0);
        {
            let stages: Vec<DynStage<i32>> = vec![Box::new(|x| x + offset)];
            let out = pipeline_stages(0..50, 3, stages);
            sink.set(out.iter().sum());
        }
        assert_eq!(sink.get(), (0..50).sum::<i32>() + 50 * 10);
    }

    #[test]
    fn stages_overlap_in_time() {
        // Stage 1 sleeps; with pipelining total time ~ max stage, not sum.
        use std::time::{Duration, Instant};
        let t0 = Instant::now();
        let _ = pipeline2(
            0..10,
            4,
            |x: u32| {
                std::thread::sleep(Duration::from_millis(5));
                x
            },
            |x| {
                std::thread::sleep(Duration::from_millis(5));
                x
            },
        );
        let elapsed = t0.elapsed();
        // Serial would be 100ms; pipelined ~55ms. Allow slack for CI.
        assert!(elapsed < Duration::from_millis(95), "no overlap: {elapsed:?}");
    }
}
