//! Pipeline pattern: staged throughput parallelism with bounded
//! inter-stage queues (backpressure). Order-preserving: every stage is
//! sequential internally, so outputs arrive in input order — which
//! keeps the whole pattern deterministic.
//!
//! Used by the video-stream example (generate → Canny front →
//! hysteresis) the way the paper's motivation describes real-time
//! image-processing pipelines.

use std::sync::mpsc::{sync_channel, Receiver, SyncSender};

/// Run a 2-stage pipeline over `inputs` with bounded queues of
/// `capacity`. Returns outputs in input order.
pub fn pipeline2<A, B, C, I, S1, S2>(
    inputs: I,
    capacity: usize,
    s1: S1,
    s2: S2,
) -> Vec<C>
where
    A: Send,
    B: Send,
    C: Send,
    I: IntoIterator<Item = A> + Send,
    S1: FnMut(A) -> B + Send,
    S2: FnMut(B) -> C + Send,
{
    std::thread::scope(|scope| {
        let (tx1, rx1) = sync_channel::<B>(capacity.max(1));
        let h1 = scope.spawn(move || run_stage(inputs, s1, tx1));
        let out = collect_stage(rx1, s2);
        h1.join().expect("pipeline stage 1 panicked");
        out
    })
}

/// Run a 3-stage pipeline over `inputs` with bounded queues of
/// `capacity` between stages. Returns outputs in input order.
pub fn pipeline3<A, B, C, D, I, S1, S2, S3>(
    inputs: I,
    capacity: usize,
    s1: S1,
    s2: S2,
    s3: S3,
) -> Vec<D>
where
    A: Send,
    B: Send,
    C: Send,
    D: Send,
    I: IntoIterator<Item = A> + Send,
    S1: FnMut(A) -> B + Send,
    S2: FnMut(B) -> C + Send,
    S3: FnMut(C) -> D + Send,
{
    std::thread::scope(|scope| {
        let (tx1, rx1) = sync_channel::<B>(capacity.max(1));
        let (tx2, rx2) = sync_channel::<C>(capacity.max(1));
        let h1 = scope.spawn(move || run_stage(inputs, s1, tx1));
        let h2 = scope.spawn(move || {
            let mut s2 = s2;
            for item in rx1 {
                if tx2.send(s2(item)).is_err() {
                    break;
                }
            }
        });
        let out = collect_stage(rx2, s3);
        h1.join().expect("pipeline stage 1 panicked");
        h2.join().expect("pipeline stage 2 panicked");
        out
    })
}

fn run_stage<A, B>(
    inputs: impl IntoIterator<Item = A>,
    mut f: impl FnMut(A) -> B,
    tx: SyncSender<B>,
) {
    for item in inputs {
        if tx.send(f(item)).is_err() {
            break;
        }
    }
}

fn collect_stage<B, C>(rx: Receiver<B>, mut f: impl FnMut(B) -> C) -> Vec<C> {
    let mut out = Vec::new();
    for item in rx {
        out.push(f(item));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pipeline2_preserves_order() {
        let out = pipeline2(0..100, 4, |x: i32| x * 2, |x| x + 1);
        let expect: Vec<i32> = (0..100).map(|x| x * 2 + 1).collect();
        assert_eq!(out, expect);
    }

    #[test]
    fn pipeline3_composes() {
        let out = pipeline3(0..50, 2, |x: u64| x + 1, |x| x * x, |x| format!("{x}"));
        assert_eq!(out[3], "16");
        assert_eq!(out.len(), 50);
    }

    #[test]
    fn pipeline_handles_empty_input() {
        let out = pipeline3(Vec::<u8>::new(), 2, |x| x, |x| x, |x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn capacity_one_still_completes() {
        // Backpressure with the tightest queue must not deadlock.
        let out = pipeline3(0..1000, 1, |x: u32| x, |x| x, |x| x);
        assert_eq!(out.len(), 1000);
    }

    #[test]
    fn stages_overlap_in_time() {
        // Stage 1 sleeps; with pipelining total time ~ max stage, not sum.
        use std::time::{Duration, Instant};
        let t0 = Instant::now();
        let _ = pipeline2(
            0..10,
            4,
            |x: u32| {
                std::thread::sleep(Duration::from_millis(5));
                x
            },
            |x| {
                std::thread::sleep(Duration::from_millis(5));
                x
            },
        );
        let elapsed = t0.elapsed();
        // Serial would be 100ms; pipelined ~55ms. Allow slack for CI.
        assert!(elapsed < Duration::from_millis(95), "no overlap: {elapsed:?}");
    }
}
