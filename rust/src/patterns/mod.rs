//! Structured parallel patterns (the paper's Figure 6 catalogue,
//! after McCool/Reinders/Robison) built on the work-stealing
//! [`crate::scheduler::Pool`].
//!
//! Every pattern is **deterministic**: outputs depend only on inputs,
//! never on scheduling. That is the paper's stated design goal
//! ("aiming for deterministic output") and it is achieved the same way
//! Cilk Plus patterns achieve it — disjoint writes for maps/stencils,
//! fixed-shape combination trees for reductions/scans.
//!
//! | paper pattern   | here                                        |
//! |-----------------|---------------------------------------------|
//! | map (cilk_for)  | [`par_map`], [`par_for`], [`par_rows`]      |
//! | stencil         | [`par_rows`] + halo discipline (see canny)  |
//! | reduce          | [`par_reduce`]                              |
//! | scan            | [`par_scan`]                                |
//! | fork–join       | [`Pool::scope`](crate::scheduler::Pool)     |
//! | pipeline        | [`pipeline::pipeline3`]                     |
//! | farm / workpile | [`farm::farm_stream`]                       |

pub mod farm;
pub mod pipeline;

use std::mem::MaybeUninit;
use std::ops::Range;

use crate::scheduler::Pool;
use crate::util::SharedSlice;

/// Deterministic chunk boundaries: `len` split into chunks of at most
/// `grain` (>= 1), identical for every run and worker count.
pub fn chunks(len: usize, grain: usize) -> Vec<Range<usize>> {
    let grain = grain.max(1);
    (0..len.div_ceil(grain)).map(|c| c * grain..((c + 1) * grain).min(len)).collect()
}

/// A sensible grain so that ~4 chunks exist per worker (steal slack
/// without drowning in scheduling overhead).
pub fn auto_grain(len: usize, workers: usize) -> usize {
    (len / (workers.max(1) * 4)).max(1)
}

/// Parallel map over a slice: `out[i] = f(i, &items[i])`.
pub fn par_map<T, R, F>(pool: &Pool, items: &[T], grain: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let n = items.len();
    let mut out: Vec<MaybeUninit<R>> = Vec::with_capacity(n);
    // SAFETY: every index is written exactly once below before assuming init.
    unsafe { out.set_len(n) };
    {
        let shared = SharedSlice::new(&mut out);
        let f = &f;
        pool.scope(|s| {
            for range in chunks(n, grain) {
                let shared = &shared;
                s.spawn(move || {
                    // SAFETY: chunk ranges are disjoint by construction.
                    let slots = unsafe { shared.range_mut(range.start, range.end) };
                    for (k, slot) in slots.iter_mut().enumerate() {
                        let i = range.start + k;
                        slot.write(f(i, &items[i]));
                    }
                });
            }
        });
    }
    // SAFETY: all n slots written (scope joined all chunks).
    unsafe { std::mem::transmute::<Vec<MaybeUninit<R>>, Vec<R>>(out) }
}

/// Parallel for over an index range (the `cilk_for` analogue).
pub fn par_for<F>(pool: &Pool, range: Range<usize>, grain: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    let len = range.end.saturating_sub(range.start);
    let base = range.start;
    let f = &f;
    pool.scope(|s| {
        for chunk in chunks(len, grain) {
            s.spawn(move || {
                for i in chunk {
                    f(base + i);
                }
            });
        }
    });
}

/// Parallel iteration over row bands: `f(y0..y1)` for disjoint bands
/// covering `0..height`. The workhorse for image stencils: each band
/// writes disjoint output rows, reads shared input freely.
pub fn par_rows<F>(pool: &Pool, height: usize, grain: usize, f: F)
where
    F: Fn(Range<usize>) + Sync,
{
    let f = &f;
    pool.scope(|s| {
        for band in chunks(height, grain) {
            s.spawn(move || f(band));
        }
    });
}

/// Deterministic parallel reduction: chunk partials computed in
/// parallel, combined left-to-right in chunk order. For f32 this gives
/// bitwise-stable results for a fixed `grain`, independent of workers.
pub fn par_reduce<T, A, M, C>(
    pool: &Pool,
    items: &[T],
    grain: usize,
    identity: A,
    map: M,
    combine: C,
) -> A
where
    T: Sync,
    A: Send + Sync + Clone,
    M: Fn(&T) -> A + Sync,
    C: Fn(A, A) -> A + Sync,
{
    let ranges = chunks(items.len(), grain);
    let partials = par_map(pool, &ranges, 1, |_, range| {
        let mut acc = identity.clone();
        for item in &items[range.clone()] {
            acc = combine(acc, map(item));
        }
        acc
    });
    partials.into_iter().fold(identity, combine)
}

/// Deterministic inclusive parallel scan (prefix op) with associative
/// `combine`. Three phases: chunk-local scans, serial chunk-offset
/// pass, parallel offset application — the textbook pattern.
pub fn par_scan<T, C>(pool: &Pool, items: &[T], grain: usize, combine: C) -> Vec<T>
where
    T: Send + Sync + Clone,
    C: Fn(&T, &T) -> T + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let ranges = chunks(n, grain);
    // Phase 1: local inclusive scans.
    let mut scanned: Vec<Vec<T>> = par_map(pool, &ranges, 1, |_, range| {
        let slice = &items[range.clone()];
        let mut acc = Vec::with_capacity(slice.len());
        for item in slice {
            let next = match acc.last() {
                None => item.clone(),
                Some(prev) => combine(prev, item),
            };
            acc.push(next);
        }
        acc
    });
    // Phase 2: serial exclusive scan of chunk totals.
    let mut offsets: Vec<Option<T>> = Vec::with_capacity(scanned.len());
    let mut running: Option<T> = None;
    for chunk in &scanned {
        offsets.push(running.clone());
        let total = chunk.last().expect("non-empty chunk");
        running = Some(match &running {
            None => total.clone(),
            Some(r) => combine(r, total),
        });
    }
    // Phase 3: apply offsets in parallel.
    {
        let offsets = &offsets;
        let combine = &combine;
        let chunk_refs: Vec<&mut Vec<T>> = scanned.iter_mut().collect();
        pool.scope(|s| {
            for (ci, chunk) in chunk_refs.into_iter().enumerate() {
                s.spawn(move || {
                    if let Some(off) = &offsets[ci] {
                        for v in chunk.iter_mut() {
                            *v = combine(off, v);
                        }
                    }
                });
            }
        });
    }
    scanned.into_iter().flatten().collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pool() -> Pool {
        Pool::new(4).unwrap()
    }

    #[test]
    fn chunks_cover_disjointly() {
        for (len, grain) in [(10, 3), (1, 1), (100, 7), (5, 100)] {
            let cs = chunks(len, grain);
            let mut next = 0;
            for c in &cs {
                assert_eq!(c.start, next);
                assert!(c.end > c.start);
                next = c.end;
            }
            assert_eq!(next, len);
        }
        assert!(chunks(0, 4).is_empty());
    }

    #[test]
    fn par_map_matches_serial() {
        let p = pool();
        let items: Vec<u64> = (0..1000).collect();
        let out = par_map(&p, &items, 13, |i, &x| x * 2 + i as u64);
        let expect: Vec<u64> = items.iter().enumerate().map(|(i, &x)| x * 2 + i as u64).collect();
        assert_eq!(out, expect);
    }

    #[test]
    fn par_map_empty_and_single() {
        let p = pool();
        let empty: Vec<u32> = vec![];
        assert!(par_map(&p, &empty, 4, |_, &x| x).is_empty());
        assert_eq!(par_map(&p, &[5u32], 4, |_, &x| x + 1), vec![6]);
    }

    #[test]
    fn par_for_visits_every_index_once() {
        let p = pool();
        let hits: Vec<std::sync::atomic::AtomicU32> =
            (0..500).map(|_| std::sync::atomic::AtomicU32::new(0)).collect();
        par_for(&p, 0..500, 7, |i| {
            hits[i].fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        });
        assert!(hits
            .iter()
            .all(|h| h.load(std::sync::atomic::Ordering::Relaxed) == 1));
    }

    #[test]
    fn par_rows_bands_cover() {
        let p = pool();
        let rows = std::sync::Mutex::new(vec![false; 97]);
        par_rows(&p, 97, 10, |band| {
            let mut g = rows.lock().unwrap();
            for y in band {
                assert!(!g[y]);
                g[y] = true;
            }
        });
        assert!(rows.lock().unwrap().iter().all(|&b| b));
    }

    #[test]
    fn par_reduce_deterministic_f32() {
        let p = pool();
        let items: Vec<f32> = (0..10_000).map(|i| (i as f32).sin()).collect();
        let a = par_reduce(&p, &items, 64, 0.0f32, |&x| x, |a, b| a + b);
        let b = par_reduce(&p, &items, 64, 0.0f32, |&x| x, |a, b| a + b);
        assert_eq!(a.to_bits(), b.to_bits(), "bitwise-unstable reduction");
        // And independent of worker count:
        let p1 = Pool::new(1).unwrap();
        let c = par_reduce(&p1, &items, 64, 0.0f32, |&x| x, |a, b| a + b);
        assert_eq!(a.to_bits(), c.to_bits());
    }

    #[test]
    fn par_reduce_max() {
        let p = pool();
        let items: Vec<i64> = vec![3, -1, 42, 7, 42, 0];
        let m = par_reduce(&p, &items, 2, i64::MIN, |&x| x, |a, b| a.max(b));
        assert_eq!(m, 42);
    }

    #[test]
    fn par_scan_matches_serial() {
        let p = pool();
        let items: Vec<u64> = (1..=100).collect();
        let out = par_scan(&p, &items, 9, |a, b| a + b);
        let mut expect = Vec::new();
        let mut acc = 0u64;
        for &x in &items {
            acc += x;
            expect.push(acc);
        }
        assert_eq!(out, expect);
    }

    #[test]
    fn par_scan_empty() {
        let p = pool();
        let empty: Vec<u32> = vec![];
        assert!(par_scan(&p, &empty, 4, |a, b| a + b).is_empty());
    }

    #[test]
    fn auto_grain_reasonable() {
        assert_eq!(auto_grain(0, 4), 1);
        assert_eq!(auto_grain(1600, 4), 100);
        assert!(auto_grain(3, 8) >= 1);
    }
}
