//! Task-farm / workpile pattern with bounded in-flight jobs
//! (backpressure) — the batch-IFE workload from the paper's motivation
//! ("large quantities of images … on the INTERNET").
//!
//! Jobs stream from an iterator; at most `capacity` are in flight; the
//! results vector is returned in submission order (deterministic).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};

use crate::scheduler::Pool;

/// Statistics from a farm run (backpressure visibility).
#[derive(Clone, Copy, Debug, Default)]
pub struct FarmStats {
    /// Jobs processed.
    pub jobs: usize,
    /// Times the feeder had to wait because `capacity` jobs were in flight.
    pub stalls: usize,
}

/// Stream `jobs` through the pool with at most `capacity` in flight.
pub fn farm_stream<J, R, F>(
    pool: &Pool,
    jobs: impl IntoIterator<Item = J>,
    capacity: usize,
    f: F,
) -> (Vec<R>, FarmStats)
where
    J: Send,
    R: Send,
    F: Fn(usize, J) -> R + Sync,
{
    let capacity = capacity.max(1);
    let results: Mutex<Vec<Option<R>>> = Mutex::new(Vec::new());
    let in_flight = AtomicUsize::new(0);
    let gate = (Mutex::new(()), Condvar::new());
    let mut stalls = 0usize;
    let mut submitted = 0usize;

    pool.scope(|s| {
        for (idx, job) in jobs.into_iter().enumerate() {
            // Backpressure: wait until a slot frees.
            if in_flight.load(Ordering::Acquire) >= capacity {
                stalls += 1;
                let mut g = gate.0.lock().unwrap();
                while in_flight.load(Ordering::Acquire) >= capacity {
                    g = gate.1.wait(g).unwrap();
                }
            }
            in_flight.fetch_add(1, Ordering::AcqRel);
            results.lock().unwrap().push(None);
            submitted += 1;
            let results = &results;
            let in_flight = &in_flight;
            let gate = &gate;
            let f = &f;
            s.spawn(move || {
                let r = f(idx, job);
                results.lock().unwrap()[idx] = Some(r);
                in_flight.fetch_sub(1, Ordering::AcqRel);
                let _g = gate.0.lock().unwrap();
                gate.1.notify_all();
            });
        }
    });

    let out: Vec<R> =
        results.into_inner().unwrap().into_iter().map(|r| r.expect("job completed")).collect();
    debug_assert_eq!(out.len(), submitted);
    (out, FarmStats { jobs: submitted, stalls })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_in_submission_order() {
        let pool = Pool::new(4).unwrap();
        let (out, stats) = farm_stream(&pool, 0..200, 8, |_, j: i32| j * j);
        let expect: Vec<i32> = (0..200).map(|j| j * j).collect();
        assert_eq!(out, expect);
        assert_eq!(stats.jobs, 200);
    }

    #[test]
    fn capacity_bounds_in_flight() {
        let pool = Pool::new(4).unwrap();
        let peak = AtomicUsize::new(0);
        let current = AtomicUsize::new(0);
        let cap = 3usize;
        let (_out, stats) = farm_stream(&pool, 0..100, cap, |_, _j: i32| {
            let c = current.fetch_add(1, Ordering::AcqRel) + 1;
            peak.fetch_max(c, Ordering::AcqRel);
            std::thread::sleep(std::time::Duration::from_micros(200));
            current.fetch_sub(1, Ordering::AcqRel);
        });
        assert!(peak.load(Ordering::Acquire) <= cap, "peak {} > cap", peak.load(Ordering::Acquire));
        assert!(stats.stalls > 0, "expected backpressure stalls");
    }

    #[test]
    fn empty_stream() {
        let pool = Pool::new(2).unwrap();
        let (out, stats) = farm_stream(&pool, Vec::<u8>::new(), 4, |_, j| j);
        assert!(out.is_empty());
        assert_eq!(stats.jobs, 0);
    }
}
