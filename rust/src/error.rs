//! Crate-wide error type.

use thiserror::Error;

/// Errors surfaced by the canny-par library.
#[derive(Error, Debug)]
pub enum Error {
    /// Image decoding / encoding problems (PGM/PPM codec).
    #[error("image codec: {0}")]
    Codec(String),

    /// Geometry problems: tile larger than image, zero dimensions, …
    #[error("geometry: {0}")]
    Geometry(String),

    /// Configuration parse / validation errors.
    #[error("config: {0}")]
    Config(String),

    /// Manifest / artifact problems (missing file, shape mismatch, JSON).
    #[error("artifact: {0}")]
    Artifact(String),

    /// XLA runtime errors (compile / execute / literal conversion).
    #[error("xla: {0}")]
    Xla(String),

    /// Scheduler misuse (e.g. zero workers).
    #[error("scheduler: {0}")]
    Scheduler(String),

    /// Underlying I/O error.
    #[error("io: {0}")]
    Io(#[from] std::io::Error),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;
