//! Cluster reporting: the per-worker report body each worker ships
//! over the wire, and the merged cluster report the front-door prints —
//! per-worker serve totals, the sharded-cache picture across processes,
//! forwarded telemetry snapshot lines, and router-side round-trip
//! latency percentiles. Schemas are documented in the
//! [`crate::cluster`] module docs and linted for parity.

use std::collections::BTreeMap;

use crate::cache::CacheSnapshot;
use crate::util::json::Json;

/// Keys of the merged cluster report object — what the CI smoke step
/// and the integration schema test assert against, and the contract
/// the `cluster/mod.rs` schema block documents.
pub const REQUIRED_CLUSTER_KEYS: [&str; 12] = [
    "alerts",
    "completed",
    "edge_pixels",
    "label",
    "latency_ns",
    "makespan_ns",
    "per_worker",
    "requests",
    "requeued",
    "restarts",
    "tier",
    "workers",
];

/// Keys of each entry in the merged report's `per_worker` array (the
/// same object a worker ships as its `worker_report` frame body).
pub const REQUIRED_WORKER_KEYS: [&str; 6] =
    ["cache", "edge_pixels", "kinds", "served", "telemetry", "worker"];

/// One worker process's end-of-run totals, built worker-side and
/// shipped as the `worker_report` frame body.
#[derive(Clone, Debug)]
pub struct WorkerReport {
    /// Supervisor slot index.
    pub worker: usize,
    /// Requests this incarnation served.
    pub served: u64,
    /// Edge pixels across its `full`/`re-threshold` responses.
    pub edge_pixels: u64,
    /// Per-request-kind counts (kind name -> served).
    pub kinds: BTreeMap<String, u64>,
    /// The worker's private [`crate::cache::ArtifactCache`] totals —
    /// one shard of the cluster-wide cache picture.
    pub cache: CacheSnapshot,
    /// The worker's final telemetry snapshot line (the PR 6 follow-up:
    /// the snapshot stream crossing the process boundary).
    pub telemetry: Json,
}

impl WorkerReport {
    /// The `worker_report` frame body / `per_worker` array entry.
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("worker".into(), Json::Num(self.worker as f64));
        m.insert("served".into(), Json::Num(self.served as f64));
        m.insert("edge_pixels".into(), Json::Num(self.edge_pixels as f64));
        m.insert(
            "kinds".into(),
            Json::Obj(
                self.kinds.iter().map(|(k, v)| (k.clone(), Json::Num(*v as f64))).collect(),
            ),
        );
        m.insert("cache".into(), self.cache.to_json());
        m.insert("telemetry".into(), self.telemetry.clone());
        Json::Obj(m)
    }
}

/// Nearest-rank percentile over an already-sorted slice (0 when empty)
/// — the same rank rule the serve tier's latency summaries use.
fn pct_ns(sorted: &[u64], q: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// The merged end-of-run cluster report (`cannyd cluster` prints its
/// JSON to stdout).
#[derive(Clone, Debug)]
pub struct ClusterReport {
    pub label: String,
    /// Worker slots (not incarnations — restarts are counted apart).
    pub workers: usize,
    /// Requests the trace offered to the router.
    pub requests: u64,
    /// Responses received (== `requests` on a clean run; the router
    /// requeues on worker death, so a completed run converges here).
    pub completed: u64,
    /// Requests resent to a restarted worker after their first
    /// dispatch died with the previous incarnation.
    pub requeued: u64,
    /// Worker restarts the supervisor performed.
    pub restarts: u64,
    /// Health-transition alert lines the supervisor emitted.
    pub alerts: u64,
    /// Wall nanoseconds from first dispatch to last response.
    pub makespan_ns: u64,
    /// Router-measured round-trip latencies (dispatch -> response).
    pub latencies_ns: Vec<u64>,
    /// One [`WorkerReport::to_json`] body per worker slot.
    pub per_worker: Vec<Json>,
}

impl ClusterReport {
    /// Edge pixels summed over the per-worker bodies.
    pub fn edge_pixels(&self) -> u64 {
        self.per_worker
            .iter()
            .filter_map(|w| w.get("edge_pixels").and_then(Json::as_f64))
            .map(|v| v as u64)
            .sum()
    }

    /// The merged report object (schema in [`crate::cluster`]).
    pub fn to_json(&self) -> Json {
        let num = |v: u64| Json::Num(v as f64);
        let mut sorted = self.latencies_ns.clone();
        sorted.sort_unstable();
        let mean = if sorted.is_empty() {
            0.0
        } else {
            sorted.iter().sum::<u64>() as f64 / sorted.len() as f64
        };
        let mut lat = BTreeMap::new();
        lat.insert("n".to_string(), num(sorted.len() as u64));
        lat.insert("p50".to_string(), num(pct_ns(&sorted, 0.50)));
        lat.insert("p95".to_string(), num(pct_ns(&sorted, 0.95)));
        lat.insert("p99".to_string(), num(pct_ns(&sorted, 0.99)));
        lat.insert("max".to_string(), num(sorted.last().copied().unwrap_or(0)));
        lat.insert("mean".to_string(), Json::Num(mean));

        let mut m = BTreeMap::new();
        m.insert("label".into(), Json::Str(self.label.clone()));
        m.insert("tier".into(), Json::Str("cluster".into()));
        m.insert("workers".into(), num(self.workers as u64));
        m.insert("requests".into(), num(self.requests));
        m.insert("completed".into(), num(self.completed));
        m.insert("requeued".into(), num(self.requeued));
        m.insert("restarts".into(), num(self.restarts));
        m.insert("alerts".into(), num(self.alerts));
        m.insert("makespan_ns".into(), num(self.makespan_ns));
        m.insert("edge_pixels".into(), num(self.edge_pixels()));
        m.insert("latency_ns".into(), Json::Obj(lat));
        m.insert("per_worker".into(), Json::Arr(self.per_worker.clone()));
        Json::Obj(m)
    }

    /// Compact JSON text (what `cannyd cluster` prints).
    pub fn to_json_string(&self) -> String {
        self.to_json().dump()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_worker(worker: usize, served: u64, edge_pixels: u64) -> WorkerReport {
        let mut kinds = BTreeMap::new();
        kinds.insert("full".to_string(), served);
        WorkerReport {
            worker,
            served,
            edge_pixels,
            kinds,
            cache: CacheSnapshot::default(),
            telemetry: Json::Null,
        }
    }

    #[test]
    fn worker_report_carries_required_keys() {
        let j = sample_worker(1, 4, 99).to_json();
        for key in REQUIRED_WORKER_KEYS {
            assert!(j.get(key).is_some(), "worker report is missing `{key}`");
        }
        assert_eq!(j.as_obj().unwrap().len(), REQUIRED_WORKER_KEYS.len());
        assert_eq!(j.get("kinds").unwrap().get("full").unwrap().as_usize(), Some(4));
        // Round-trips through the wire codec's parser.
        assert_eq!(Json::parse(&j.dump()).unwrap(), j);
    }

    #[test]
    fn merged_report_has_stable_schema() {
        let report = ClusterReport {
            label: "cluster[test]".into(),
            workers: 2,
            requests: 8,
            completed: 8,
            requeued: 1,
            restarts: 1,
            alerts: 2,
            makespan_ns: 5_000_000,
            latencies_ns: vec![300, 100, 200, 400, 800],
            per_worker: vec![
                sample_worker(0, 5, 70).to_json(),
                sample_worker(1, 3, 30).to_json(),
            ],
        };
        let j = report.to_json();
        for key in REQUIRED_CLUSTER_KEYS {
            assert!(j.get(key).is_some(), "cluster report is missing `{key}`");
        }
        assert_eq!(j.as_obj().unwrap().len(), REQUIRED_CLUSTER_KEYS.len());
        assert_eq!(j.get("tier").unwrap().as_str(), Some("cluster"));
        assert_eq!(j.get("edge_pixels").unwrap().as_usize(), Some(100));
        assert_eq!(j.get("per_worker").unwrap().as_arr().unwrap().len(), 2);
        let lat = j.get("latency_ns").unwrap();
        assert_eq!(lat.get("n").unwrap().as_usize(), Some(5));
        assert_eq!(lat.get("p50").unwrap().as_usize(), Some(300));
        assert_eq!(lat.get("max").unwrap().as_usize(), Some(800));
        assert!((lat.get("mean").unwrap().as_f64().unwrap() - 360.0).abs() < 1e-9);
        assert_eq!(Json::parse(&j.dump()).unwrap(), j);
    }

    #[test]
    fn empty_latencies_report_zeros() {
        let report = ClusterReport {
            label: "cluster[empty]".into(),
            workers: 1,
            requests: 0,
            completed: 0,
            requeued: 0,
            restarts: 0,
            alerts: 0,
            makespan_ns: 0,
            latencies_ns: vec![],
            per_worker: vec![],
        };
        let lat = report.to_json();
        let lat = lat.get("latency_ns").unwrap();
        assert_eq!(lat.get("p99").unwrap().as_usize(), Some(0));
        assert_eq!(lat.get("mean").unwrap().as_f64(), Some(0.0));
        assert_eq!(report.edge_pixels(), 0);
    }
}
