//! The **cluster tier** — multi-process `cannyd`: a front-door router
//! that spawns and supervises N `cannyd worker` processes over loopback
//! TCP and routes requests to them by content digest.
//!
//! The paper scales one detection across the cores of one process;
//! PR 4's serve tier scales a request stream across lanes *in* one
//! process. This tier is the next rung: the same request stream spread
//! over separate OS processes, which buys crash isolation (a worker
//! segfault costs a restart, not the run — exercised by the
//! kill/restart tests) and a sharded cluster cache for free. Routing is
//! **digest-affine** ([`router::RoutingRing`]): the worker whose hash
//! range owns a content digest serves *every* request about that
//! content, so each worker's private [`crate::cache::ArtifactCache`]
//! holds a disjoint content shard and a re-threshold sweep hits the
//! front its own worker warmed — no cross-process cache coherence
//! needed, the same trick that made the in-process cache shardable.
//!
//! Four moving parts:
//!
//! * [`proto`] — u32 big-endian length-prefixed JSON frames (schema
//!   below). Requests carry scene *specs*, never pixels: both ends
//!   regenerate content deterministically, the trace-file trick at the
//!   process boundary.
//! * [`worker`] — the child process: a full single-process serving
//!   stack (detector + cache + telemetry) behind a blocking frame loop.
//! * [`supervisor`] — spawn, `hello` handshake, and restart-on-death
//!   with health-transition alerts through the `--alert-log` sink.
//! * [`router`] — consistent-hash routing, closed-loop dispatch with
//!   requeue-on-death, and the merged [`report::ClusterReport`].
//!
//! Determinism carries across the process boundary: every engine
//! produces bit-identical artifacts, so `cannyd cluster --workers N` is
//! byte-identical in its responses to single-process `cannyd serve` on
//! the same trace — the integration suite asserts it, restarts and all.
//!
//! ## Wire frames (one JSON object per length-prefixed frame)
//!
//! ```json
//! {"frame": "hello", "worker": 0}
//! {"frame": "request", "id": 7, "arrival_ns": 1250000, "width": 128,
//!  "height": 96, "scene": "shapes:11", "kind": "re-threshold",
//!  "lo": 0.03, "hi": 0.21,
//!  "trace": "9f8a3c001122334400000007", "parent": 3,
//!  "sample": "slow:5000000"}
//! {"frame": "response", "id": 7, "edge_pixels": 1834,
//!  "digest": "9f8a3c00112233445566778899aabbcc", "t_ns": 2000000,
//!  "spans": [{"...": "span objects, schema in obs/mod.rs"}]}
//! {"frame": "telemetry", "worker": 0,
//!  "line": {"...": "a snapshot line, schema in obs/mod.rs"}}
//! {"frame": "ping", "t_ns": 41000000}
//! {"frame": "pong", "t_ns": 41000000}
//! {"frame": "report"}
//! {"frame": "worker_report", "body": {"...": "see per_worker below"}}
//! {"frame": "shutdown"}
//! ```
//!
//! `digest` is the 128-bit artifact digest as a 32-hex-char string
//! (JSON numbers are f64 and would round above 2^53). `trace`/`parent`
//! (request) and `t_ns`/`spans` (response) carry the distributed-trace
//! context when `--trace-log` is active: the worker's service subtree
//! stitches under the front door's wire span for that request.
//! `sample` rides with the trace context and is the front door's
//! tail-sampling policy in resolved wire form (`all`, `slow:<ns>`,
//! `errors:<slo_ns>`, `head:<n>` — see [`crate::obs::TraceSampler`]):
//! a worker that can predict the front door's drop verdict skips
//! building the subtree, and notes histogram exemplars only for
//! traces the front door is guaranteed to keep.
//! `telemetry` frames stream each worker's periodic snapshot lines to
//! the front door, which merges them into the cluster-wide telemetry
//! stream (schema in `obs/mod.rs`).
//!
//! ## Merged cluster report (`cannyd cluster` stdout)
//!
//! ```json
//! {
//!   "label": "cluster[synthetic n=40 seed=7]",
//!   "tier": "cluster",
//!   "workers": 2,
//!   "requests": 40,
//!   "completed": 40,
//!   "requeued": 1,
//!   "restarts": 1,
//!   "alerts": 2,
//!   "makespan_ns": 182000000,
//!   "edge_pixels": 51234,
//!   "latency_ns": {"n": 40, "p50": 2100000, "p95": 5400000,
//!                  "p99": 8100000, "max": 9000000, "mean": 2512000.5},
//!   "per_worker": [
//!     {"worker": 0, "served": 23, "edge_pixels": 30000,
//!      "kinds": {"full": 20, "front-only": 1, "re-threshold": 2},
//!      "cache": {"...": "a cache section, schema in service/mod.rs"},
//!      "telemetry": {"...": "a snapshot line, schema in obs/mod.rs"}}
//!   ]
//! }
//! ```
//!
//! `requests` counts trace arrivals, `completed` counts responses
//! (equal once every requeued request lands), `requeued`/`restarts`
//! count the recovery work, and `alerts` the health-transition lines
//! the supervisor emitted (two per restart). `per_worker` bodies are
//! exactly the `worker_report` frame bodies, slot order.

pub mod proto;
pub mod report;
pub mod router;
pub mod supervisor;
pub mod worker;

pub use report::{ClusterReport, WorkerReport, REQUIRED_CLUSTER_KEYS, REQUIRED_WORKER_KEYS};
pub use router::{
    route_digest, run_cluster, ClusterOptions, ClusterOutcome, ResponseRecord, RoutingRing,
    DEFAULT_WORKERS,
};
pub use supervisor::{Supervisor, WorkerFault, WorkerLink, WORKER_EXE_ENV};
pub use worker::{run_worker, WorkerCore, WORKER_FAULT_ENV};
