//! The worker-process side of the cluster tier: one `cannyd worker`
//! process per supervisor slot, each owning a full single-process
//! serving stack — a [`Detector`], a private [`ArtifactCache`] shard of
//! the cluster-wide cache picture, and a [`Telemetry`] registry
//! rendered through a **persistent** [`SnapshotEngine`], so the
//! snapshot lines this worker streams home carry a real monotonic
//! `seq`/`t_ns`, not a fresh engine's zeros.
//!
//! The loop is deliberately dumb: connect to the front door, announce
//! the slot with a `hello`, then serve one frame at a time. Requests
//! regenerate their image from the scene spec (the wire never carries
//! pixels), execute through the exact same detector/plan/cache idioms
//! the in-process serve tier uses, and answer with the edge count plus
//! a content digest of the output — the router's cross-process
//! bit-identity check. Determinism does the heavy lifting here: every
//! engine produces bit-identical artifacts, so a worker's answer for a
//! request is byte-equal to what `cannyd serve` would have produced.
//!
//! Two observability streams ride the same connection:
//!
//! * **Spans.** When a request frame carries trace context
//!   (`trace`/`parent`), the worker builds its service subtree with
//!   [`service_spans`] and ships it back inside the response — the
//!   front door stitches it under its wire span. Under the virtual
//!   clock the worker keeps a modeled logical clock (`vclock`): each
//!   request completes at `max(vclock, arrival) + service_ns`, the
//!   same cost model [`ServeOptions::service_ns_kind`] gives the
//!   in-process tier, so replays are byte-identical.
//! * **Telemetry frames.** The worker sends one snapshot line after
//!   `hello` (seq 0), another whenever `--worker-telemetry-ms` of its
//!   own clock has elapsed (at most one per request), and a final one
//!   on `report` — so the merged cluster stream always ends on this
//!   worker's drained state with a nonzero `seq`.
//!
//! Fault injection for the restart tests rides an environment variable
//! ([`WORKER_FAULT_ENV`]): when set, the worker calls
//! `std::process::exit(3)` *before* executing the fatal request, so the
//! router sees a dead connection with a request in flight — the
//! requeue path, not the clean-shutdown path.

use std::collections::BTreeMap;
use std::net::TcpStream;

use crate::cache::{ArtifactCache, ArtifactKey, CacheConfig, CacheTier};
use crate::canny::{Artifact, CannyParams, StageKind, StageRecord};
use crate::cluster::proto::{
    digest_string, frame_kind, hello_frame, parse_request, parse_sample, parse_trace, pong_frame,
    read_frame, response_frame, telemetry_frame, worker_report_frame, write_frame,
};
use crate::cluster::report::WorkerReport;
use crate::config::RunConfig;
use crate::coordinator::Detector;
use crate::error::{Error, Result};
use crate::image::synth::generate;
use crate::obs::{
    modeled_stage_durs, service_spans, SnapshotEngine, Span, Telemetry, TickInputs, TraceId,
    TraceSampler,
};
use crate::service::clock::{ClockMode, WallClock};
use crate::service::{Request, RequestKind, ServeOptions};
use crate::util::json::Json;

/// Environment variable for the kill/restart tests: `<n>` makes the
/// worker process exit (status 3) on receipt of its `n+1`-th request,
/// before executing it. The supervisor only sets it on the first
/// incarnation of the faulted slot, so the restarted process serves
/// normally.
pub const WORKER_FAULT_ENV: &str = "CANNYD_WORKER_EXIT_AFTER";

/// One executed request's answer, before it is framed for the wire.
#[derive(Clone, Debug)]
pub struct WorkerAnswer {
    /// Edge pixels in the output (0 for `front-only`, which produces
    /// no edges — it warms the cache).
    pub edge_pixels: u64,
    /// Content digest of the produced artifact: the edge map for
    /// `full`/`re-threshold`, the suppressed-magnitude key for
    /// `front-only`.
    pub digest: ArtifactKey,
    /// Completion time on the worker's clock: the modeled logical
    /// clock under `--clock virtual` (deterministic), measured
    /// monotonic ns under `--clock wall`.
    pub t_ns: u64,
    /// The request's service subtree ([`service_spans`]) when the
    /// request frame carried trace context; empty otherwise.
    pub spans: Vec<Span>,
}

/// The per-process serving engine: detector + cache + telemetry plus
/// the running totals the end-of-run [`WorkerReport`] is built from.
/// Pure compute — no sockets — so the unit tests drive it directly and
/// the wire loop ([`run_worker`]) stays a thin shell.
#[derive(Debug)]
pub struct WorkerCore {
    det: Detector,
    cache: ArtifactCache,
    telemetry: Telemetry,
    clock: WallClock,
    opts: ServeOptions,
    snap: SnapshotEngine,
    worker: usize,
    virtual_clock: bool,
    vclock: u64,
    served: u64,
    edge_pixels: u64,
    kinds: BTreeMap<String, u64>,
}

/// Fold freshly executed stage `records` into the worker's telemetry
/// and the request's stage-span skeleton. Measured walls are kept only
/// under the wall clock; virtual workers publish run counts with zero
/// walls and model span durations at completion time, keeping replays
/// byte-identical.
fn note_stages(
    tel: &Telemetry,
    stages: &mut Vec<(String, u64)>,
    records: &[StageRecord],
    measured: bool,
) {
    for r in records {
        let (wall, cpu) = if measured { (r.wall_ns, r.cpu_ns) } else { (0, 0) };
        tel.note_stage(r.span_name(), wall, cpu);
        stages.push((r.span_name().to_string(), wall));
    }
}

impl WorkerCore {
    /// Build from the forwarded [`RunConfig`] (the supervisor re-sends
    /// the detector/cache/clock flags on the worker command line).
    /// `worker` is the supervisor slot — the report identity and the
    /// Chrome-trace lane (`tid = worker + 1`) its spans render on.
    pub fn from_config(cfg: &RunConfig, worker: usize) -> Result<WorkerCore> {
        let opts = ServeOptions::from_config(cfg);
        let interval_ns = (cfg.worker_telemetry_ms.max(0.001) * 1e6) as u64;
        Ok(WorkerCore {
            det: Detector::from_config(cfg)?,
            cache: ArtifactCache::new(CacheConfig::from_config(cfg)),
            telemetry: Telemetry::new("worker", 1),
            clock: WallClock::start(),
            snap: SnapshotEngine::from_options(None, interval_ns, opts.overload_policy.name())?,
            worker,
            virtual_clock: opts.clock == ClockMode::Virtual,
            opts,
            vclock: 0,
            served: 0,
            edge_pixels: 0,
            kinds: BTreeMap::new(),
        })
    }

    /// Requests this incarnation has completed.
    pub fn served(&self) -> u64 {
        self.served
    }

    /// The worker's current clock reading: the modeled completion
    /// cursor under the virtual clock, measured monotonic ns otherwise.
    pub fn now_ns(&self) -> u64 {
        if self.virtual_clock {
            self.vclock
        } else {
            self.clock.now_ns()
        }
    }

    /// Execute one request: regenerate the scene, run the kind's
    /// pipeline span (consulting/warming the private artifact cache for
    /// partial kinds), and fold the totals into telemetry. With trace
    /// context `(trace_id, parent_span_id)` from the request frame, the
    /// answer carries the service subtree to stitch under the front
    /// door's wire span. `sampler` is the frame's tail-sampling policy
    /// ([`crate::cluster::proto::parse_sample`], decoded by
    /// [`TraceSampler::from_wire`]): a definite drop verdict skips
    /// building the subtree, a definite keep also pins this request as
    /// its latency bucket's exemplar, and an undecidable verdict ships
    /// the subtree conservatively for the front door to prune.
    pub fn execute(
        &mut self,
        req: &Request,
        trace: Option<(&str, u64)>,
        sampler: Option<&TraceSampler>,
    ) -> Result<WorkerAnswer> {
        let measured = !self.virtual_clock;
        let t0 = if self.virtual_clock {
            self.vclock.max(req.arrival_ns)
        } else {
            self.clock.now_ns()
        };
        self.telemetry.offered.inc();
        self.telemetry.admitted.inc();
        self.telemetry.lane(0).inflight.add(1);
        self.telemetry.lane(0).batches.inc();
        let img = generate(req.scene, req.width, req.height);
        let mut stages: Vec<(String, u64)> = Vec::new();
        let mut consult: Option<&'static str> = None;
        let (edge_pixels, digest) = match req.kind {
            RequestKind::Full => {
                let out = self.det.detect_full(&img, self.det.params())?;
                note_stages(&self.telemetry, &mut stages, &out.records, measured);
                (out.edges.count_edges() as u64, ArtifactKey::edges(&out.edges))
            }
            RequestKind::FrontOnly => {
                let key = ArtifactKey::suppressed(&img);
                let plan = self.det.plan().stop_after(StageKind::Nms);
                let mut out = self.det.run_plan(&plan, Some(&img), self.det.params())?;
                note_stages(&self.telemetry, &mut stages, &out.records, measured);
                consult = Some(if self.cache.enabled() { "offer" } else { "disabled" });
                if let Some(nm) = out.take_suppressed() {
                    self.cache.offer(key, Artifact::Suppressed(nm), out.total_ns, CacheTier::Serve);
                }
                (0, key)
            }
            RequestKind::ReThreshold { lo, hi } => {
                let params = CannyParams { lo, hi, ..*self.det.params() };
                let key = ArtifactKey::suppressed(&img);
                // Digest affinity is what makes this hit: the router
                // pins a scene's re-thresholds to this worker, so the
                // front computed once (here or by a front-only warm) is
                // reused across the whole threshold sweep.
                let (art, outcome) = self.cache.consult(&key, CacheTier::Serve);
                consult = Some(outcome);
                let nm = match art {
                    Some(Artifact::Suppressed(nm)) => nm,
                    _ => {
                        let plan = self.det.plan().stop_after(StageKind::Nms);
                        let mut out =
                            self.det.run_plan(&plan, Some(&img), self.det.params())?;
                        note_stages(&self.telemetry, &mut stages, &out.records, measured);
                        let nm = out.take_suppressed().ok_or_else(|| {
                            Error::Config("front plan produced no suppressed artifact".into())
                        })?;
                        self.cache.offer(
                            key,
                            Artifact::Suppressed(nm.clone()),
                            out.total_ns,
                            CacheTier::Serve,
                        );
                        nm
                    }
                };
                let plan = self.det.plan().from_suppressed(nm);
                let out = self.det.run_plan(&plan, None, &params)?;
                note_stages(&self.telemetry, &mut stages, &out.records, measured);
                let edges = out.edges().ok_or_else(|| {
                    Error::Config("re-threshold plan produced no edge map".into())
                })?;
                (edges.count_edges() as u64, ArtifactKey::edges(edges))
            }
        };
        let t_ns = if self.virtual_clock {
            let end = t0 + self.opts.service_ns_kind(req.kind, req.pixels());
            self.vclock = end;
            end
        } else {
            self.clock.now_ns()
        };
        // Virtual latency is modeled end-to-end (arrival → completion);
        // wall workers measure service time only — request arrival
        // offsets live on the front door's clock, not ours.
        let latency =
            if self.virtual_clock { t_ns.saturating_sub(req.arrival_ns) } else { t_ns - t0 };
        self.telemetry.completed.inc();
        self.telemetry.latency.record(latency);
        self.telemetry.lane(0).completed.inc();
        self.telemetry.lane(0).busy_ns.add(t_ns.saturating_sub(t0));
        self.telemetry.lane(0).heartbeat_ns.raise(t_ns);
        self.telemetry.lane(0).inflight.sub(1);
        self.served += 1;
        self.edge_pixels += edge_pixels;
        *self.kinds.entry(req.kind.name().to_string()).or_insert(0) += 1;
        // The worker-side tail-sampling verdict: `Some(true)` only when
        // the front door is guaranteed to reach the same keep decision
        // (shared virtual timeline, or a latency-blind policy) — the
        // only case where noting an exemplar is safe, since a worker
        // histogram must never cite a trace the front door discards.
        let verdict = match (&trace, sampler) {
            (None, _) => Some(false),
            (Some(_), None) => Some(true), // no policy on the wire = keep all
            (Some(_), Some(s)) => s.remote_verdict(self.virtual_clock, latency, req.id),
        };
        if let (Some((id, _)), Some(true)) = (&trace, verdict) {
            self.telemetry.latency.note_exemplar(latency, id);
        }
        let spans = match trace {
            None => Vec::new(),
            Some(_) if verdict == Some(false) => Vec::new(),
            Some((id, parent)) => {
                let cache = consult.map(|o| (o, self.opts.cache_lookup_ns(req.pixels())));
                let stage_spans: Vec<(String, u64)> = if measured {
                    stages
                } else {
                    let span = t_ns
                        .saturating_sub(t0)
                        .saturating_sub(cache.map_or(0, |(_, d)| d));
                    let durs = modeled_stage_durs(span, stages.len());
                    stages.into_iter().map(|(n, _)| n).zip(durs).collect()
                };
                service_spans(
                    &TraceId::from_wire(id),
                    self.worker as u64 + 1,
                    parent,
                    t0,
                    t_ns,
                    cache,
                    &stage_spans,
                )
            }
        };
        Ok(WorkerAnswer { edge_pixels, digest, t_ns, spans })
    }

    /// Render the worker's current snapshot line through the
    /// persistent [`SnapshotEngine`] — the body of `telemetry` frames
    /// and of the report's `telemetry` section. Every call advances the
    /// engine's dense `seq`, so the merged cluster stream sees a
    /// meaningful per-worker sequence, not a fresh engine's zero.
    pub fn snapshot_line(&mut self) -> Json {
        let t_ns = self.now_ns();
        let mut slo = BTreeMap::new();
        slo.insert("status".to_string(), Json::Str("none".into()));
        let inputs = TickInputs {
            t_ns,
            telemetry: &self.telemetry,
            cache: self.cache.snapshot(),
            slo: Json::Obj(slo),
            slo_missed: false,
            shedding_possible: false,
            utilization: None,
        };
        self.snap.render_line(&inputs)
    }

    /// The end-of-run report body, with the worker's final telemetry
    /// snapshot line rendered through the same persistent
    /// [`SnapshotEngine`] every `telemetry` frame used — the snapshot
    /// stream crosses the process boundary with a continuous `seq`.
    pub fn report(&mut self) -> WorkerReport {
        WorkerReport {
            worker: self.worker,
            served: self.served,
            edge_pixels: self.edge_pixels,
            kinds: self.kinds.clone(),
            cache: self.cache.snapshot(),
            telemetry: self.snapshot_line(),
        }
    }
}

/// The `cannyd worker` entry point: connect to the front door on
/// loopback, announce the slot, then serve frames until `shutdown` (or
/// until the connection drops — the supervisor owns our lifetime, so a
/// dead front door means exit).
pub fn run_worker(cfg: &RunConfig, worker: usize, port: u16) -> Result<()> {
    let mut stream = TcpStream::connect(("127.0.0.1", port))?;
    stream.set_nodelay(true).ok();
    write_frame(&mut stream, &hello_frame(worker))?;
    let mut core = WorkerCore::from_config(cfg, worker)?;
    let fault: Option<u64> =
        std::env::var(WORKER_FAULT_ENV).ok().and_then(|v| v.parse().ok());
    // Snapshot cadence on the worker's own clock — modeled (and so
    // deterministic) under virtual, measured under wall. Bounded to at
    // most one frame per request: the loop only wakes on frames.
    let interval_ns = (cfg.worker_telemetry_ms.max(0.001) * 1e6) as u64;
    let mut next_tel = interval_ns;
    // Announce-alive line (seq 0): the front door's merged stream shows
    // this incarnation before its first request lands.
    write_frame(&mut stream, &telemetry_frame(worker, core.snapshot_line()))?;
    loop {
        let frame = read_frame(&mut stream)?;
        match frame_kind(&frame) {
            Some("request") => {
                let req = parse_request(&frame)?;
                if fault.is_some_and(|after| core.served() >= after) {
                    // Die with the request un-answered: the router must
                    // detect the dead connection and requeue it onto
                    // our restarted incarnation.
                    std::process::exit(3);
                }
                let trace = parse_trace(&frame);
                let ctx = trace.as_ref().map(|(id, parent)| (id.as_str(), *parent));
                let sampler = parse_sample(&frame).and_then(|s| TraceSampler::from_wire(&s));
                let ans = core.execute(&req, ctx, sampler.as_ref())?;
                let resp = response_frame(
                    req.id,
                    ans.edge_pixels,
                    &digest_string(&ans.digest),
                    ans.t_ns,
                    &ans.spans,
                );
                write_frame(&mut stream, &resp)?;
                if core.now_ns() >= next_tel {
                    write_frame(&mut stream, &telemetry_frame(worker, core.snapshot_line()))?;
                    next_tel = (core.now_ns() / interval_ns + 1).saturating_mul(interval_ns);
                }
            }
            Some("ping") => {
                let t = frame.get("t_ns").and_then(Json::as_f64).unwrap_or(0.0) as u64;
                write_frame(&mut stream, &pong_frame(t))?;
            }
            Some("report") => {
                // One final snapshot frame (seq ≥ 1) so the merged
                // stream ends on this worker's drained state, then the
                // report body.
                write_frame(&mut stream, &telemetry_frame(worker, core.snapshot_line()))?;
                let body = core.report().to_json();
                write_frame(&mut stream, &worker_report_frame(body))?;
            }
            Some("shutdown") => return Ok(()),
            other => {
                return Err(Error::Config(format!(
                    "worker {worker}: unexpected frame `{}`",
                    other.unwrap_or("<none>")
                )))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::report::REQUIRED_WORKER_KEYS;
    use crate::image::synth::Scene;
    use crate::obs::REQUIRED_LINE_KEYS;

    fn test_cfg() -> RunConfig {
        let mut cfg = RunConfig::default();
        cfg.set("engine", "serial").unwrap();
        cfg.set("workers", "1").unwrap();
        cfg.set("cache-mb", "8").unwrap();
        cfg
    }

    fn req(id: u64, kind: RequestKind) -> Request {
        Request {
            id,
            arrival_ns: id * 1_000,
            scene: Scene::Shapes { seed: 21 },
            width: 64,
            height: 48,
            kind,
        }
    }

    #[test]
    fn full_requests_match_the_detector_exactly() {
        let mut core = WorkerCore::from_config(&test_cfg(), 0).unwrap();
        let r = req(0, RequestKind::Full);
        let ans = core.execute(&r, None, None).unwrap();
        let det = Detector::from_config(&test_cfg()).unwrap();
        let img = generate(r.scene, r.width, r.height);
        let edges = det.detect_full(&img, det.params()).unwrap().edges;
        assert_eq!(ans.edge_pixels, edges.count_edges() as u64);
        assert_eq!(ans.digest, ArtifactKey::edges(&edges));
        assert_eq!(core.served(), 1);
    }

    #[test]
    fn rethreshold_hits_the_cache_after_a_front_warm() {
        let mut core = WorkerCore::from_config(&test_cfg(), 0).unwrap();
        core.execute(&req(0, RequestKind::FrontOnly), None, None).unwrap();
        let a = core
            .execute(&req(1, RequestKind::ReThreshold { lo: 0.04, hi: 0.2 }), None, None)
            .unwrap();
        let snap = core.cache.snapshot();
        let serve = snap.tiers.iter().find(|(name, _)| *name == "serve").unwrap();
        assert_eq!(serve.1.hits, 1, "re-threshold should hit the warmed front");
        // The cached path produces the same bits as a cold worker.
        let mut cold = WorkerCore::from_config(&test_cfg(), 0).unwrap();
        let b = cold
            .execute(&req(1, RequestKind::ReThreshold { lo: 0.04, hi: 0.2 }), None, None)
            .unwrap();
        assert_eq!(a.digest, b.digest);
        assert_eq!(a.edge_pixels, b.edge_pixels);
    }

    #[test]
    fn report_carries_totals_and_a_telemetry_line() {
        let mut core = WorkerCore::from_config(&test_cfg(), 3).unwrap();
        core.execute(&req(0, RequestKind::Full), None, None).unwrap();
        core.execute(&req(1, RequestKind::FrontOnly), None, None).unwrap();
        let rep = core.report();
        assert_eq!(rep.worker, 3);
        assert_eq!(rep.served, 2);
        assert_eq!(rep.kinds.get("full"), Some(&1));
        assert_eq!(rep.kinds.get("front-only"), Some(&1));
        let j = rep.to_json();
        for key in REQUIRED_WORKER_KEYS {
            assert!(j.get(key).is_some(), "worker report is missing `{key}`");
        }
        // The forwarded telemetry line is a full snapshot line.
        for key in REQUIRED_LINE_KEYS {
            assert!(
                rep.telemetry.get(key).is_some(),
                "forwarded telemetry line is missing `{key}`"
            );
        }
        assert_eq!(
            rep.telemetry.get("lanes").unwrap().as_arr().unwrap().len(),
            1,
            "worker telemetry has exactly one lane"
        );
    }

    #[test]
    fn snapshot_lines_advance_seq_through_one_persistent_engine() {
        let mut core = WorkerCore::from_config(&test_cfg(), 0).unwrap();
        let first = core.snapshot_line();
        core.execute(&req(0, RequestKind::Full), None, None).unwrap();
        let second = core.snapshot_line();
        let seq = |line: &Json| line.get("seq").and_then(Json::as_f64).unwrap() as u64;
        assert_eq!(seq(&first), 0);
        assert_eq!(seq(&second), 1, "seq must advance across snapshot lines");
        assert_eq!(seq(&core.report().telemetry), 2, "the report line continues the stream");
    }

    #[test]
    fn wire_sampler_gates_spans_and_exemplars_under_the_virtual_clock() {
        let ctx = Some(("00112233445566770000002a", 3u64));
        let r = req(2, RequestKind::Full);
        // Threshold far above any modeled latency: definite drop — no
        // subtree ships and the histogram cites no exemplar.
        let drop = TraceSampler::from_wire("slow:3600000000000").unwrap();
        let mut core = WorkerCore::from_config(&test_cfg(), 1).unwrap();
        let ans = core.execute(&r, ctx, Some(&drop)).unwrap();
        assert!(ans.spans.is_empty(), "dropped traces ship no subtree");
        assert!(core.telemetry.latency.snapshot().exemplars.is_empty());
        // Threshold zero: every request is slow — definite keep, so the
        // subtree ships and the kept trace becomes the exemplar.
        let keep = TraceSampler::from_wire("slow:0").unwrap();
        let mut core = WorkerCore::from_config(&test_cfg(), 1).unwrap();
        let ans = core.execute(&r, ctx, Some(&keep)).unwrap();
        assert!(!ans.spans.is_empty(), "kept traces ship the subtree");
        let ex = core.telemetry.latency.snapshot().exemplars;
        assert_eq!(ex.len(), 1);
        assert!(ex.values().all(|(trace, _)| trace == "00112233445566770000002a"));
    }

    #[test]
    fn trace_context_yields_a_stitched_deterministic_subtree() {
        let ctx = Some(("00112233445566770000002a", 3u64));
        let mut core = WorkerCore::from_config(&test_cfg(), 1).unwrap();
        let r = req(2, RequestKind::ReThreshold { lo: 0.04, hi: 0.2 });
        let ans = core.execute(&r, ctx, None).unwrap();
        assert!(!ans.spans.is_empty());
        let svc = &ans.spans[0];
        assert_eq!(svc.name, "service");
        assert_eq!(svc.parent, Some(3), "service stitches under the wire span");
        assert_eq!(svc.tid, 2, "worker slot 1 renders on lane 2");
        assert!(ans.spans.iter().any(|s| s.name == "cache_consult"));
        assert!(ans.spans.iter().any(|s| s.name.starts_with("stage:")));
        // Default clock is virtual: completion is modeled past arrival
        // and a fresh core replays the exact same spans.
        assert!(ans.t_ns > r.arrival_ns);
        let mut again = WorkerCore::from_config(&test_cfg(), 1).unwrap();
        let b = again.execute(&r, ctx, None).unwrap();
        assert_eq!(ans.spans, b.spans, "virtual-clock spans replay identically");
        assert_eq!(ans.t_ns, b.t_ns);
    }
}
