//! The worker-process side of the cluster tier: one `cannyd worker`
//! process per supervisor slot, each owning a full single-process
//! serving stack — a [`Detector`], a private [`ArtifactCache`] shard of
//! the cluster-wide cache picture, and a [`Telemetry`] registry whose
//! final snapshot line ships home inside the worker's report.
//!
//! The loop is deliberately dumb: connect to the front door, announce
//! the slot with a `hello`, then serve one frame at a time. Requests
//! regenerate their image from the scene spec (the wire never carries
//! pixels), execute through the exact same detector/plan/cache idioms
//! the in-process serve tier uses, and answer with the edge count plus
//! a content digest of the output — the router's cross-process
//! bit-identity check. Determinism does the heavy lifting here: every
//! engine produces bit-identical artifacts, so a worker's answer for a
//! request is byte-equal to what `cannyd serve` would have produced.
//!
//! Fault injection for the restart tests rides an environment variable
//! ([`WORKER_FAULT_ENV`]): when set, the worker calls
//! `std::process::exit(3)` *before* executing the fatal request, so the
//! router sees a dead connection with a request in flight — the
//! requeue path, not the clean-shutdown path.

use std::collections::BTreeMap;
use std::net::TcpStream;

use crate::cache::{ArtifactCache, ArtifactKey, CacheConfig, CacheTier};
use crate::canny::{Artifact, CannyParams, StageKind};
use crate::cluster::proto::{
    digest_string, frame_kind, hello_frame, parse_request, pong_frame, read_frame,
    response_frame, worker_report_frame, write_frame,
};
use crate::cluster::report::WorkerReport;
use crate::config::RunConfig;
use crate::coordinator::Detector;
use crate::error::{Error, Result};
use crate::image::synth::generate;
use crate::obs::{SnapshotEngine, Telemetry, TickInputs};
use crate::service::clock::WallClock;
use crate::service::{Request, RequestKind};
use crate::util::json::Json;

/// Environment variable for the kill/restart tests: `<n>` makes the
/// worker process exit (status 3) on receipt of its `n+1`-th request,
/// before executing it. The supervisor only sets it on the first
/// incarnation of the faulted slot, so the restarted process serves
/// normally.
pub const WORKER_FAULT_ENV: &str = "CANNYD_WORKER_EXIT_AFTER";

/// One executed request's answer, before it is framed for the wire.
#[derive(Clone, Copy, Debug)]
pub struct WorkerAnswer {
    /// Edge pixels in the output (0 for `front-only`, which produces
    /// no edges — it warms the cache).
    pub edge_pixels: u64,
    /// Content digest of the produced artifact: the edge map for
    /// `full`/`re-threshold`, the suppressed-magnitude key for
    /// `front-only`.
    pub digest: ArtifactKey,
}

/// The per-process serving engine: detector + cache + telemetry plus
/// the running totals the end-of-run [`WorkerReport`] is built from.
/// Pure compute — no sockets — so the unit tests drive it directly and
/// the wire loop ([`run_worker`]) stays a thin shell.
#[derive(Debug)]
pub struct WorkerCore {
    det: Detector,
    cache: ArtifactCache,
    telemetry: Telemetry,
    clock: WallClock,
    served: u64,
    edge_pixels: u64,
    kinds: BTreeMap<String, u64>,
}

impl WorkerCore {
    /// Build from the forwarded [`RunConfig`] (the supervisor re-sends
    /// the detector/cache flags on the worker command line).
    pub fn from_config(cfg: &RunConfig) -> Result<WorkerCore> {
        Ok(WorkerCore {
            det: Detector::from_config(cfg)?,
            cache: ArtifactCache::new(CacheConfig::from_config(cfg)),
            telemetry: Telemetry::new("serve", 1),
            clock: WallClock::start(),
            served: 0,
            edge_pixels: 0,
            kinds: BTreeMap::new(),
        })
    }

    /// Requests this incarnation has completed.
    pub fn served(&self) -> u64 {
        self.served
    }

    /// Execute one request: regenerate the scene, run the kind's
    /// pipeline span (consulting/warming the private artifact cache for
    /// partial kinds), and fold the totals into telemetry.
    pub fn execute(&mut self, req: &Request) -> Result<WorkerAnswer> {
        let t0 = self.clock.now_ns();
        self.telemetry.offered.inc();
        self.telemetry.admitted.inc();
        self.telemetry.lane(0).inflight.add(1);
        self.telemetry.lane(0).batches.inc();
        let img = generate(req.scene, req.width, req.height);
        let answer = match req.kind {
            RequestKind::Full => {
                let out = self.det.detect_full(&img, self.det.params())?;
                WorkerAnswer {
                    edge_pixels: out.edges.count_edges() as u64,
                    digest: ArtifactKey::edges(&out.edges),
                }
            }
            RequestKind::FrontOnly => {
                let key = ArtifactKey::suppressed(&img);
                let plan = self.det.plan().stop_after(StageKind::Nms);
                let mut out = self.det.run_plan(&plan, Some(&img), self.det.params())?;
                if let Some(nm) = out.take_suppressed() {
                    self.cache.offer(key, Artifact::Suppressed(nm), out.total_ns, CacheTier::Serve);
                }
                WorkerAnswer { edge_pixels: 0, digest: key }
            }
            RequestKind::ReThreshold { lo, hi } => {
                let params = CannyParams { lo, hi, ..*self.det.params() };
                let key = ArtifactKey::suppressed(&img);
                // Digest affinity is what makes this hit: the router
                // pins a scene's re-thresholds to this worker, so the
                // front computed once (here or by a front-only warm) is
                // reused across the whole threshold sweep.
                let nm = match self.cache.get(&key, CacheTier::Serve) {
                    Some(Artifact::Suppressed(nm)) => nm,
                    _ => {
                        let plan = self.det.plan().stop_after(StageKind::Nms);
                        let mut out =
                            self.det.run_plan(&plan, Some(&img), self.det.params())?;
                        let nm = out.take_suppressed().ok_or_else(|| {
                            Error::Config("front plan produced no suppressed artifact".into())
                        })?;
                        self.cache.offer(
                            key,
                            Artifact::Suppressed(nm.clone()),
                            out.total_ns,
                            CacheTier::Serve,
                        );
                        nm
                    }
                };
                let plan = self.det.plan().from_suppressed(nm);
                let out = self.det.run_plan(&plan, None, &params)?;
                let edges = out.edges().ok_or_else(|| {
                    Error::Config("re-threshold plan produced no edge map".into())
                })?;
                WorkerAnswer {
                    edge_pixels: edges.count_edges() as u64,
                    digest: ArtifactKey::edges(edges),
                }
            }
        };
        let now = self.clock.now_ns();
        self.telemetry.completed.inc();
        self.telemetry.latency.record(now.saturating_sub(t0));
        self.telemetry.lane(0).completed.inc();
        self.telemetry.lane(0).busy_ns.add(now.saturating_sub(t0));
        self.telemetry.lane(0).heartbeat_ns.set(now);
        self.telemetry.lane(0).inflight.sub(1);
        self.served += 1;
        self.edge_pixels += answer.edge_pixels;
        *self.kinds.entry(req.kind.name().to_string()).or_insert(0) += 1;
        Ok(answer)
    }

    /// The end-of-run report body, with the worker's final telemetry
    /// snapshot line rendered through the same
    /// [`SnapshotEngine`] line builder the in-process tiers log from —
    /// the snapshot stream crossing the process boundary.
    pub fn report(&mut self, worker: usize) -> WorkerReport {
        let mut slo = BTreeMap::new();
        slo.insert("status".to_string(), Json::Str("none".into()));
        let inputs = TickInputs {
            t_ns: self.clock.now_ns(),
            telemetry: &self.telemetry,
            cache: self.cache.snapshot(),
            slo: Json::Obj(slo),
            slo_missed: false,
            shedding_possible: false,
            utilization: None,
        };
        let telemetry = SnapshotEngine::disabled().render_line(&inputs);
        WorkerReport {
            worker,
            served: self.served,
            edge_pixels: self.edge_pixels,
            kinds: self.kinds.clone(),
            cache: self.cache.snapshot(),
            telemetry,
        }
    }
}

/// The `cannyd worker` entry point: connect to the front door on
/// loopback, announce the slot, then serve frames until `shutdown` (or
/// until the connection drops — the supervisor owns our lifetime, so a
/// dead front door means exit).
pub fn run_worker(cfg: &RunConfig, worker: usize, port: u16) -> Result<()> {
    let mut stream = TcpStream::connect(("127.0.0.1", port))?;
    stream.set_nodelay(true).ok();
    write_frame(&mut stream, &hello_frame(worker))?;
    let mut core = WorkerCore::from_config(cfg)?;
    let fault: Option<u64> =
        std::env::var(WORKER_FAULT_ENV).ok().and_then(|v| v.parse().ok());
    loop {
        let frame = read_frame(&mut stream)?;
        match frame_kind(&frame) {
            Some("request") => {
                let req = parse_request(&frame)?;
                if fault.is_some_and(|after| core.served() >= after) {
                    // Die with the request un-answered: the router must
                    // detect the dead connection and requeue it onto
                    // our restarted incarnation.
                    std::process::exit(3);
                }
                let ans = core.execute(&req)?;
                let resp = response_frame(req.id, ans.edge_pixels, &digest_string(&ans.digest));
                write_frame(&mut stream, &resp)?;
            }
            Some("ping") => {
                let t = frame.get("t_ns").and_then(Json::as_f64).unwrap_or(0.0) as u64;
                write_frame(&mut stream, &pong_frame(t))?;
            }
            Some("report") => {
                let body = core.report(worker).to_json();
                write_frame(&mut stream, &worker_report_frame(body))?;
            }
            Some("shutdown") => return Ok(()),
            other => {
                return Err(Error::Config(format!(
                    "worker {worker}: unexpected frame `{}`",
                    other.unwrap_or("<none>")
                )))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::report::REQUIRED_WORKER_KEYS;
    use crate::image::synth::Scene;
    use crate::obs::REQUIRED_LINE_KEYS;

    fn test_cfg() -> RunConfig {
        let mut cfg = RunConfig::default();
        cfg.set("engine", "serial").unwrap();
        cfg.set("workers", "1").unwrap();
        cfg.set("cache-mb", "8").unwrap();
        cfg
    }

    fn req(id: u64, kind: RequestKind) -> Request {
        Request {
            id,
            arrival_ns: id * 1_000,
            scene: Scene::Shapes { seed: 21 },
            width: 64,
            height: 48,
            kind,
        }
    }

    #[test]
    fn full_requests_match_the_detector_exactly() {
        let mut core = WorkerCore::from_config(&test_cfg()).unwrap();
        let r = req(0, RequestKind::Full);
        let ans = core.execute(&r).unwrap();
        let det = Detector::from_config(&test_cfg()).unwrap();
        let img = generate(r.scene, r.width, r.height);
        let edges = det.detect_full(&img, det.params()).unwrap().edges;
        assert_eq!(ans.edge_pixels, edges.count_edges() as u64);
        assert_eq!(ans.digest, ArtifactKey::edges(&edges));
        assert_eq!(core.served(), 1);
    }

    #[test]
    fn rethreshold_hits_the_cache_after_a_front_warm() {
        let mut core = WorkerCore::from_config(&test_cfg()).unwrap();
        core.execute(&req(0, RequestKind::FrontOnly)).unwrap();
        let a = core.execute(&req(1, RequestKind::ReThreshold { lo: 0.04, hi: 0.2 })).unwrap();
        let snap = core.cache.snapshot();
        let serve = snap.tiers.iter().find(|(name, _)| *name == "serve").unwrap();
        assert_eq!(serve.1.hits, 1, "re-threshold should hit the warmed front");
        // The cached path produces the same bits as a cold worker.
        let mut cold = WorkerCore::from_config(&test_cfg()).unwrap();
        let b = cold.execute(&req(1, RequestKind::ReThreshold { lo: 0.04, hi: 0.2 })).unwrap();
        assert_eq!(a.digest, b.digest);
        assert_eq!(a.edge_pixels, b.edge_pixels);
    }

    #[test]
    fn report_carries_totals_and_a_telemetry_line() {
        let mut core = WorkerCore::from_config(&test_cfg()).unwrap();
        core.execute(&req(0, RequestKind::Full)).unwrap();
        core.execute(&req(1, RequestKind::FrontOnly)).unwrap();
        let rep = core.report(3);
        assert_eq!(rep.worker, 3);
        assert_eq!(rep.served, 2);
        assert_eq!(rep.kinds.get("full"), Some(&1));
        assert_eq!(rep.kinds.get("front-only"), Some(&1));
        let j = rep.to_json();
        for key in REQUIRED_WORKER_KEYS {
            assert!(j.get(key).is_some(), "worker report is missing `{key}`");
        }
        // The forwarded telemetry line is a full snapshot line.
        for key in REQUIRED_LINE_KEYS {
            assert!(
                rep.telemetry.get(key).is_some(),
                "forwarded telemetry line is missing `{key}`"
            );
        }
        assert_eq!(
            rep.telemetry.get("lanes").unwrap().as_arr().unwrap().len(),
            1,
            "worker telemetry has exactly one lane"
        );
    }
}
