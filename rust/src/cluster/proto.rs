//! Wire frames for the cluster tier: u32 big-endian length-prefixed
//! JSON over loopback TCP, reusing the dependency-free
//! [`crate::util::json`] codec. One frame = one JSON object with a
//! `frame` discriminator; the full schema lives in the
//! [`crate::cluster`] module docs (linted for parity by pallas-lint).
//!
//! JSON-over-TCP is deliberate: the frames are small (requests carry a
//! scene *spec*, never pixels — both sides regenerate content from the
//! deterministic scene generators, the same trick the trace file format
//! uses), the router is not the hot path (workers are), and a
//! text-diffable protocol keeps the kill/restart tests and the merged
//! report byte-deterministic. Digests are shipped as fixed-width hex
//! strings because `Json::Num` is an `f64` and would silently round a
//! full 64-bit FNV stream above 2^53.

use std::io::{Read, Write};

use crate::cache::ArtifactKey;
use crate::error::{Error, Result};
use crate::image::synth::Scene;
use crate::obs::trace::Span;
use crate::service::{Request, RequestKind};
use crate::util::json::Json;
use std::collections::BTreeMap;

/// Hard cap on one frame's payload. Frames carry specs and reports,
/// not pixels; anything near this size is a protocol violation, and
/// the cap keeps a corrupt length prefix from allocating gigabytes.
pub const MAX_FRAME_BYTES: usize = 16 * 1024 * 1024;

/// Write one length-prefixed frame and flush it (requests are
/// latency-sensitive; a buffered unflushed frame would stall the
/// worker's blocking read).
pub fn write_frame(w: &mut impl Write, frame: &Json) -> Result<()> {
    let bytes = frame.dump().into_bytes();
    if bytes.len() > MAX_FRAME_BYTES {
        return Err(Error::Config(format!(
            "cluster frame of {} bytes exceeds the {MAX_FRAME_BYTES}-byte cap",
            bytes.len()
        )));
    }
    w.write_all(&(bytes.len() as u32).to_be_bytes())?;
    w.write_all(&bytes)?;
    w.flush()?;
    Ok(())
}

/// Read one length-prefixed frame. I/O errors (including read
/// timeouts, surfaced as `WouldBlock`/`TimedOut`) pass through as
/// [`Error::Io`] so the router can distinguish a slow worker from a
/// dead one.
pub fn read_frame(r: &mut impl Read) -> Result<Json> {
    let mut len = [0u8; 4];
    r.read_exact(&mut len)?;
    let len = u32::from_be_bytes(len) as usize;
    if len > MAX_FRAME_BYTES {
        return Err(Error::Config(format!(
            "cluster frame length {len} exceeds the {MAX_FRAME_BYTES}-byte cap"
        )));
    }
    let mut buf = vec![0u8; len];
    r.read_exact(&mut buf)?;
    let text = std::str::from_utf8(&buf)
        .map_err(|_| Error::Config("cluster frame is not UTF-8".into()))?;
    Json::parse(text)
}

/// The `frame` discriminator of a parsed frame.
pub fn frame_kind(frame: &Json) -> Option<&str> {
    frame.get("frame")?.as_str()
}

/// A worker's 128-bit artifact digest as the fixed-width hex string
/// the wire carries (see the module doc for why not a number).
pub fn digest_string(key: &ArtifactKey) -> String {
    format!("{:016x}{:016x}", key.hi, key.lo)
}

/// `hello` — the first frame a worker sends after connecting; maps the
/// fresh TCP connection to its supervisor slot.
pub fn hello_frame(worker: usize) -> Json {
    let mut m = BTreeMap::new();
    m.insert("frame".into(), Json::Str("hello".into()));
    m.insert("worker".into(), Json::Num(worker as f64));
    Json::Obj(m)
}

/// Which slot a `hello` frame announces.
pub fn parse_hello(frame: &Json) -> Result<usize> {
    frame
        .get("worker")
        .and_then(Json::as_usize)
        .ok_or_else(|| Error::Config("hello frame is missing `worker`".into()))
}

/// `request` — one serve request, content shipped as a scene spec.
/// `trace` is the front door's trace context when tracing is enabled:
/// the request's trace id plus the parent span id the worker's
/// service subtree stitches under. `sample` is the front door's
/// tail-sampling policy in resolved wire form
/// ([`crate::obs::sample::TraceSampler::to_wire`]) — it rides with the
/// trace context so the worker can skip building span subtrees the
/// front door is guaranteed to discard.
pub fn request_frame(req: &Request, trace: Option<(&str, u64)>, sample: Option<&str>) -> Json {
    let mut m = BTreeMap::new();
    m.insert("frame".into(), Json::Str("request".into()));
    m.insert("id".into(), Json::Num(req.id as f64));
    m.insert("arrival_ns".into(), Json::Num(req.arrival_ns as f64));
    m.insert("width".into(), Json::Num(req.width as f64));
    m.insert("height".into(), Json::Num(req.height as f64));
    m.insert("scene".into(), Json::Str(req.scene.spec()));
    m.insert("kind".into(), Json::Str(req.kind.name().into()));
    if let RequestKind::ReThreshold { lo, hi } = req.kind {
        m.insert("lo".into(), Json::Num(lo as f64));
        m.insert("hi".into(), Json::Num(hi as f64));
    }
    if let Some((id, parent)) = trace {
        m.insert("trace".into(), Json::Str(id.into()));
        m.insert("parent".into(), Json::Num(parent as f64));
    }
    if let Some(spec) = sample {
        m.insert("sample".into(), Json::Str(spec.into()));
    }
    Json::Obj(m)
}

/// A `request` frame's trace context — `(trace id, parent span id)` —
/// if the front door attached one.
pub fn parse_trace(frame: &Json) -> Option<(String, u64)> {
    let id = frame.get("trace")?.as_str()?.to_string();
    let parent = frame.get("parent")?.as_f64()? as u64;
    Some((id, parent))
}

/// A `request` frame's tail-sampling wire spec, if the front door
/// attached one.
pub fn parse_sample(frame: &Json) -> Option<String> {
    Some(frame.get("sample")?.as_str()?.to_string())
}

/// Decode a `request` frame back into a [`Request`].
pub fn parse_request(frame: &Json) -> Result<Request> {
    let bad = |what: &str| Error::Config(format!("request frame is missing `{what}`"));
    let num =
        |key: &'static str| frame.get(key).and_then(Json::as_f64).ok_or_else(|| bad(key));
    let spec = frame.get("scene").and_then(Json::as_str).ok_or_else(|| bad("scene"))?;
    let scene = Scene::parse(spec)
        .ok_or_else(|| Error::Config(format!("request frame has unknown scene `{spec}`")))?;
    let kind = match frame.get("kind").and_then(Json::as_str).ok_or_else(|| bad("kind"))? {
        "full" => RequestKind::Full,
        "front-only" => RequestKind::FrontOnly,
        "re-threshold" => RequestKind::ReThreshold {
            lo: num("lo")? as f32,
            hi: num("hi")? as f32,
        },
        other => {
            return Err(Error::Config(format!("request frame has unknown kind `{other}`")))
        }
    };
    Ok(Request {
        id: num("id")? as u64,
        arrival_ns: num("arrival_ns")? as u64,
        scene,
        width: num("width")? as usize,
        height: num("height")? as usize,
        kind,
    })
}

/// `response` — the worker's answer to one request: edge count and
/// artifact digest, the worker-clock completion time, and (when the
/// request carried trace context) the worker's span subtree.
pub fn response_frame(id: u64, edge_pixels: u64, digest: &str, t_ns: u64, spans: &[Span]) -> Json {
    let mut m = BTreeMap::new();
    m.insert("frame".into(), Json::Str("response".into()));
    m.insert("id".into(), Json::Num(id as f64));
    m.insert("edge_pixels".into(), Json::Num(edge_pixels as f64));
    m.insert("digest".into(), Json::Str(digest.into()));
    m.insert("t_ns".into(), Json::Num(t_ns as f64));
    m.insert("spans".into(), Json::Arr(spans.iter().map(Span::to_json).collect()));
    Json::Obj(m)
}

/// A decoded `response` frame.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct WireResponse {
    pub id: u64,
    pub edge_pixels: u64,
    /// 32-hex-char artifact digest (see [`digest_string`]).
    pub digest: String,
    /// Completion time in the worker's clock domain (modeled ns under
    /// the virtual clock) — the end of the worker's service span.
    pub t_ns: u64,
    /// The worker's span subtree for this request (empty when the
    /// request carried no trace context).
    pub spans: Vec<Span>,
}

pub fn parse_response(frame: &Json) -> Result<WireResponse> {
    let bad = |what: &str| Error::Config(format!("response frame is missing `{what}`"));
    Ok(WireResponse {
        id: frame.get("id").and_then(Json::as_f64).ok_or_else(|| bad("id"))? as u64,
        edge_pixels: frame
            .get("edge_pixels")
            .and_then(Json::as_f64)
            .ok_or_else(|| bad("edge_pixels"))? as u64,
        digest: frame
            .get("digest")
            .and_then(Json::as_str)
            .ok_or_else(|| bad("digest"))?
            .to_string(),
        t_ns: frame.get("t_ns").and_then(Json::as_f64).ok_or_else(|| bad("t_ns"))? as u64,
        spans: frame
            .get("spans")
            .and_then(Json::as_arr)
            .ok_or_else(|| bad("spans"))?
            .iter()
            .map(Span::from_json)
            .collect::<Option<Vec<_>>>()
            .ok_or_else(|| bad("spans"))?,
    })
}

/// `telemetry` — a worker streams its current snapshot line to the
/// front door (periodically, and once just before its final report),
/// where lines merge into the cluster-wide telemetry stream
/// ([`crate::obs::merge`]).
pub fn telemetry_frame(worker: usize, line: Json) -> Json {
    let mut m = BTreeMap::new();
    m.insert("frame".into(), Json::Str("telemetry".into()));
    m.insert("worker".into(), Json::Num(worker as f64));
    m.insert("line".into(), line);
    Json::Obj(m)
}

/// Decode a `telemetry` frame into `(slot, snapshot line)`.
pub fn parse_telemetry(frame: &Json) -> Result<(usize, Json)> {
    let bad = |what: &str| Error::Config(format!("telemetry frame is missing `{what}`"));
    let worker = frame.get("worker").and_then(Json::as_usize).ok_or_else(|| bad("worker"))?;
    let line = frame.get("line").cloned().ok_or_else(|| bad("line"))?;
    Ok((worker, line))
}

/// `ping` / `pong` — supervisor liveness probes between requests.
pub fn ping_frame(t_ns: u64) -> Json {
    let mut m = BTreeMap::new();
    m.insert("frame".into(), Json::Str("ping".into()));
    m.insert("t_ns".into(), Json::Num(t_ns as f64));
    Json::Obj(m)
}

pub fn pong_frame(t_ns: u64) -> Json {
    let mut m = BTreeMap::new();
    m.insert("frame".into(), Json::Str("pong".into()));
    m.insert("t_ns".into(), Json::Num(t_ns as f64));
    Json::Obj(m)
}

/// `report` — ask the worker for its end-of-run report.
pub fn report_frame() -> Json {
    let mut m = BTreeMap::new();
    m.insert("frame".into(), Json::Str("report".into()));
    Json::Obj(m)
}

/// `worker_report` — the worker's answer: its per-process serve report
/// body (built by [`crate::cluster::report`]).
pub fn worker_report_frame(body: Json) -> Json {
    let mut m = BTreeMap::new();
    m.insert("frame".into(), Json::Str("worker_report".into()));
    m.insert("body".into(), body);
    Json::Obj(m)
}

pub fn parse_worker_report(frame: &Json) -> Result<Json> {
    frame
        .get("body")
        .cloned()
        .ok_or_else(|| Error::Config("worker_report frame is missing `body`".into()))
}

/// `shutdown` — the worker loop exits cleanly on receipt.
pub fn shutdown_frame() -> Json {
    let mut m = BTreeMap::new();
    m.insert("frame".into(), Json::Str("shutdown".into()));
    Json::Obj(m)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn round_trip(frame: &Json) -> Json {
        let mut buf = Vec::new();
        write_frame(&mut buf, frame).unwrap();
        // Prefix is big-endian payload length.
        let len = u32::from_be_bytes([buf[0], buf[1], buf[2], buf[3]]) as usize;
        assert_eq!(len, buf.len() - 4);
        read_frame(&mut Cursor::new(buf)).unwrap()
    }

    #[test]
    fn frames_round_trip_bytes() {
        for f in [
            hello_frame(3),
            ping_frame(42),
            pong_frame(42),
            report_frame(),
            shutdown_frame(),
            response_frame(7, 1234, "00ff", 0, &[]),
        ] {
            assert_eq!(round_trip(&f), f);
        }
        assert_eq!(frame_kind(&hello_frame(0)), Some("hello"));
        assert_eq!(parse_hello(&hello_frame(5)).unwrap(), 5);
    }

    #[test]
    fn back_to_back_frames_parse_in_order() {
        let mut buf = Vec::new();
        write_frame(&mut buf, &hello_frame(1)).unwrap();
        write_frame(&mut buf, &shutdown_frame()).unwrap();
        let mut r = Cursor::new(buf);
        assert_eq!(frame_kind(&read_frame(&mut r).unwrap()), Some("hello"));
        assert_eq!(frame_kind(&read_frame(&mut r).unwrap()), Some("shutdown"));
        // Stream exhausted -> clean I/O error, not garbage.
        assert!(read_frame(&mut r).is_err());
    }

    #[test]
    fn request_frames_round_trip_every_kind() {
        for kind in [
            RequestKind::Full,
            RequestKind::FrontOnly,
            RequestKind::ReThreshold { lo: 0.03, hi: 0.21 },
        ] {
            let req = Request {
                id: 9,
                arrival_ns: 1_250_000,
                scene: Scene::Shapes { seed: 11 },
                width: 128,
                height: 96,
                kind,
            };
            let back = parse_request(&round_trip(&request_frame(&req, None, None))).unwrap();
            assert_eq!(back.id, req.id);
            assert_eq!(back.arrival_ns, req.arrival_ns);
            assert_eq!(back.scene, req.scene);
            assert_eq!((back.width, back.height), (req.width, req.height));
            assert_eq!(back.kind.name(), req.kind.name());
            if let (
                RequestKind::ReThreshold { lo: a, hi: b },
                RequestKind::ReThreshold { lo: c, hi: d },
            ) = (req.kind, back.kind)
            {
                assert!((a - c).abs() < 1e-6 && (b - d).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn response_frames_round_trip() {
        use crate::obs::trace::{TraceId, SPAN_SERVICE, SPAN_WIRE};
        let key = ArtifactKey { hi: 0xdead_beef_0102_0304, lo: 0x0a0b_0c0d_0e0f_1011 };
        let digest = digest_string(&key);
        assert_eq!(digest.len(), 32);
        let trace = TraceId::derive(5, 2);
        let span = Span::new(&trace, SPAN_SERVICE, Some(SPAN_WIRE), "service", "exec", 1, 10, 90)
            .attr("outcome", "hit");
        let f = response_frame(41, 512, &digest, 2_000_000, &[span.clone()]);
        let r = parse_response(&round_trip(&f)).unwrap();
        let expect =
            WireResponse { id: 41, edge_pixels: 512, digest, t_ns: 2_000_000, spans: vec![span] };
        assert_eq!(r, expect);
    }

    #[test]
    fn trace_context_rides_the_request_frame() {
        let req = Request {
            id: 3,
            arrival_ns: 50_000,
            scene: Scene::Shapes { seed: 1 },
            width: 64,
            height: 48,
            kind: RequestKind::Full,
        };
        assert_eq!(parse_trace(&request_frame(&req, None, None)), None);
        assert_eq!(parse_sample(&request_frame(&req, None, None)), None);
        let f = round_trip(&request_frame(
            &req,
            Some(("00ab00ab00ab00ab00000003", 3)),
            Some("slow:2000000"),
        ));
        assert_eq!(parse_trace(&f), Some(("00ab00ab00ab00ab00000003".to_string(), 3)));
        assert_eq!(parse_sample(&f).as_deref(), Some("slow:2000000"));
        // The trace and sampling keys do not disturb request decoding.
        assert_eq!(parse_request(&f).unwrap().id, 3);
    }

    #[test]
    fn telemetry_frames_round_trip() {
        let mut line = BTreeMap::new();
        line.insert("seq".to_string(), Json::Num(4.0));
        line.insert("tier".to_string(), Json::Str("worker".into()));
        let f = telemetry_frame(1, Json::Obj(line.clone()));
        assert_eq!(frame_kind(&f), Some("telemetry"));
        let (slot, got) = parse_telemetry(&round_trip(&f)).unwrap();
        assert_eq!(slot, 1);
        assert_eq!(got, Json::Obj(line));
        assert!(parse_telemetry(&hello_frame(0)).is_err());
    }

    #[test]
    fn digest_string_keeps_all_bits() {
        // Two keys that differ only above f64's 2^53 integer range must
        // still produce distinct wire digests.
        let a = ArtifactKey { hi: (1u64 << 60) | 1, lo: 0 };
        let b = ArtifactKey { hi: 1u64 << 60, lo: 0 };
        assert_ne!(digest_string(&a), digest_string(&b));
    }

    #[test]
    fn worker_report_carries_body() {
        let mut body = BTreeMap::new();
        body.insert("served".to_string(), Json::Num(4.0));
        let f = worker_report_frame(Json::Obj(body.clone()));
        assert_eq!(parse_worker_report(&round_trip(&f)).unwrap(), Json::Obj(body));
    }

    #[test]
    fn oversized_and_corrupt_frames_are_rejected() {
        // A forged length prefix beyond the cap is refused before any
        // allocation of that size.
        let mut buf = Vec::new();
        buf.extend_from_slice(&(u32::MAX).to_be_bytes());
        assert!(read_frame(&mut Cursor::new(buf)).is_err());
        // Truncated payload.
        let mut buf = Vec::new();
        write_frame(&mut buf, &hello_frame(0)).unwrap();
        buf.truncate(buf.len() - 2);
        assert!(read_frame(&mut Cursor::new(buf)).is_err());
        // Bad discriminator handling stays at the caller; unknown scene
        // and kind are parse errors here.
        let mut m = BTreeMap::new();
        m.insert("frame".to_string(), Json::Str("request".into()));
        m.insert("scene".to_string(), Json::Str("nope".into()));
        assert!(parse_request(&Json::Obj(m)).is_err());
    }
}
