//! The front-door router: digest-affine dispatch of a request trace
//! over the worker fleet, with requeue-on-death.
//!
//! Routing is a consistent-hash ring ([`RoutingRing`]) over a content
//! digest of `(scene spec, width, height)` — deliberately *not* the
//! request kind or thresholds. Every request about the same content
//! lands on the same worker, so a `front-only` warm and the
//! `re-threshold` sweep that follows it hit one process's private
//! [`crate::cache::ArtifactCache`]: N worker caches behave like one
//! sharded cluster cache with zero cross-process invalidation traffic.
//! Virtual points (64 per slot) keep the content shares roughly even,
//! and the ring's stability property keeps most digests on their slot
//! when the fleet grows.
//!
//! Dispatch is closed-loop, one in-flight request per worker: the
//! cluster tier's first job is correctness (bit-identity with the
//! single-process path, restart-survival), and one-at-a-time dispatch
//! makes the requeue logic exact — a dead connection has at most one
//! un-answered request, which is resent to the restarted incarnation.
//! Reads poll at the heartbeat interval; a timeout probes the child
//! (`try_wait`) to distinguish a busy worker from a dead one, and the
//! poll loop buffers partial frames so a timeout mid-frame never
//! desyncs the stream.

use std::collections::BTreeMap;
use std::io::Read;
use std::path::Path;
use std::process::Child;
use std::sync::{Arc, Mutex};

use crate::cache::KeyHasher;
use crate::cluster::proto::{
    frame_kind, parse_response, parse_telemetry, parse_worker_report, report_frame,
    request_frame, shutdown_frame, write_frame, MAX_FRAME_BYTES,
};
use crate::cluster::report::ClusterReport;
use crate::cluster::supervisor::{Supervisor, WorkerFault, WorkerLink};
use crate::config::RunConfig;
use crate::error::{Error, Result};
use crate::obs::trace::SPAN_WIRE;
use crate::obs::{
    cluster_front_spans, content_digest, merged_line, AnomalyMonitor, HealthTracker, ObsEndpoint,
    TraceCollector, TraceId, TraceSampler,
};
use crate::service::clock::{ClockMode, WallClock};
use crate::service::{Request, Trace};
use crate::util::json::Json;

/// Virtual points per worker slot — enough to keep slot shares within
/// a few percent of even without making ring construction noticeable.
pub const VIRTUAL_POINTS: usize = 64;

/// Worker processes when `--workers` is 0/unset at the cluster layer.
pub const DEFAULT_WORKERS: usize = 2;

/// Incarnations one request may be dispatched to before the run fails:
/// the injected fault is one-shot, so a request that dies this often
/// points at a real crash loop.
const MAX_ATTEMPTS: u64 = 4;

/// Salt folded into every ring point so ring positions are unrelated
/// to any other use of the digest space.
const RING_SALT: u64 = 0x636c_7573_7465_7231;

/// The content digest a request is routed by: scene spec + geometry,
/// never the kind — kind-blindness is what gives re-thresholds cache
/// affinity with their warming front-only request.
pub fn route_digest(spec: &str, width: usize, height: usize) -> u64 {
    let mut h = KeyHasher::new();
    h.write(spec.as_bytes());
    h.write_u64(width as u64);
    h.write_u64(height as u64);
    let k = h.finish();
    k.hi ^ k.lo.rotate_left(32)
}

/// The consistent-hash routing ring: each slot owns the digests that
/// fall between its virtual points and their predecessors.
#[derive(Clone, Debug)]
pub struct RoutingRing {
    points: BTreeMap<u64, usize>,
    workers: usize,
}

impl RoutingRing {
    pub fn new(workers: usize) -> RoutingRing {
        let workers = workers.max(1);
        let mut points = BTreeMap::new();
        for slot in 0..workers {
            for v in 0..VIRTUAL_POINTS {
                let mut h = KeyHasher::new();
                h.write_u64(RING_SALT);
                h.write_u64(slot as u64);
                h.write_u64(v as u64);
                let k = h.finish();
                points.insert(k.hi ^ k.lo.rotate_left(32), slot);
            }
        }
        RoutingRing { points, workers }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// First virtual point at or after `digest`, wrapping at the top.
    pub fn route(&self, digest: u64) -> usize {
        self.points
            .range(digest..)
            .next()
            .or_else(|| self.points.iter().next())
            .map(|(_, slot)| *slot)
            .unwrap_or(0)
    }

    pub fn route_request(&self, req: &Request) -> usize {
        self.route(route_digest(&req.scene.spec(), req.width, req.height))
    }
}

/// How to run a cluster (built by `cannyd cluster` from the resolved
/// config; tests construct it directly to inject faults).
#[derive(Clone, Debug)]
pub struct ClusterOptions {
    /// Worker *processes* (the `--workers` flag reinterpreted at this
    /// layer; [`DEFAULT_WORKERS`] when 0).
    pub workers: usize,
    /// Front-door port (`--cluster-port`; 0 binds an ephemeral port).
    pub port: u16,
    /// Socket poll interval for death detection
    /// (`--worker-heartbeat-ms`).
    pub heartbeat_ms: u64,
    /// Alert sink spec (`--alert-log`): restarts emit health
    /// transitions through it.
    pub alert_log: String,
    /// The resolved config; the supervisor forwards its detector/cache
    /// allowlist to every worker.
    pub cfg: RunConfig,
    /// One-shot crash injection (tests only; `None` from the CLI).
    pub fault: Option<WorkerFault>,
}

impl ClusterOptions {
    pub fn from_config(cfg: &RunConfig) -> ClusterOptions {
        ClusterOptions {
            workers: if cfg.workers > 0 { cfg.workers } else { DEFAULT_WORKERS },
            port: cfg.cluster_port,
            heartbeat_ms: cfg.worker_heartbeat_ms,
            alert_log: cfg.alert_log.clone(),
            cfg: cfg.clone(),
            fault: None,
        }
    }
}

/// One routed response, kept in request order for the bit-identity
/// checks (`digest` is the wire's 32-hex-char artifact digest).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ResponseRecord {
    pub id: u64,
    pub slot: usize,
    pub edge_pixels: u64,
    pub digest: String,
}

/// What [`run_cluster`] hands back: the merged report plus every
/// routed response (sorted by request id).
#[derive(Clone, Debug)]
pub struct ClusterOutcome {
    pub report: ClusterReport,
    pub responses: Vec<ResponseRecord>,
}

/// Per-slot dispatch result, merged after the joins.
#[derive(Debug)]
struct SlotOutcome {
    slot: usize,
    records: Vec<ResponseRecord>,
    latencies: Vec<u64>,
    requeued: u64,
    /// Clock reading after the slot's last response (excludes the
    /// report/shutdown exchange).
    finished_ns: u64,
    body: Json,
    /// Every `telemetry` frame this slot's workers streamed, arrival
    /// order — merged into the cluster-wide stream after the joins.
    telemetry: Vec<(usize, Json)>,
}

/// The front door's live telemetry state, shared by every slot thread:
/// the latest snapshot line per worker plus the `--obs-port` endpoint
/// the merged cluster view is published to as frames arrive.
#[derive(Debug)]
struct TelemetryHub {
    endpoint: Option<Arc<ObsEndpoint>>,
    /// Latest line per worker slot and a running merge counter — the
    /// live view's `seq` (the deterministic file gets its own).
    latest: Mutex<(BTreeMap<usize, Json>, u64)>,
}

impl TelemetryHub {
    fn note(&self, worker: usize, line: &Json) {
        let Some(endpoint) = &self.endpoint else { return };
        let mut guard = self.latest.lock().expect("telemetry hub poisoned");
        guard.0.insert(worker, line.clone());
        guard.1 += 1;
        let merged = merged_line(&guard.0, guard.1);
        drop(guard);
        endpoint.publish(&merged.dump());
    }
}

/// Shared observability handles for the slot threads: the optional
/// trace collector, the tail-sampling policy whose front-door verdict
/// governs each request's whole trace (front spans and the worker's
/// shipped subtree together — never a torn trace), the live telemetry
/// hub, and whether span times are modeled (virtual clock,
/// byte-identical replays) or measured.
#[derive(Debug)]
struct ObsHandles {
    trace: Option<Arc<TraceCollector>>,
    sampler: TraceSampler,
    hub: TelemetryHub,
    virtual_clock: bool,
}

/// Read frames until a non-`telemetry` one arrives, folding telemetry
/// frames into the slot's collected stream and the live hub along the
/// way (workers interleave snapshot lines with responses on the same
/// connection). `Ok(None)` means the worker died.
fn read_data_frame(
    stream: &mut std::net::TcpStream,
    child: &mut Child,
    telemetry: &mut Vec<(usize, Json)>,
    obs: &ObsHandles,
) -> Result<Option<Json>> {
    loop {
        let Some(frame) = read_or_died(stream, child)? else { return Ok(None) };
        if frame_kind(&frame) != Some("telemetry") {
            return Ok(Some(frame));
        }
        let (worker, line) = parse_telemetry(&frame)?;
        obs.hub.note(worker, &line);
        telemetry.push((worker, line));
    }
}

/// Read one frame, tolerating heartbeat-interval timeouts: partial
/// bytes stay buffered (a timeout mid-frame must not desync the
/// stream), and each timeout probes the child. `Ok(None)` means the
/// worker is dead (EOF or a reaped child).
fn read_or_died(stream: &mut std::net::TcpStream, child: &mut Child) -> Result<Option<Json>> {
    let mut buf: Vec<u8> = Vec::new();
    let mut payload: Option<usize> = None;
    let mut scratch = [0u8; 4096];
    loop {
        let target = match payload {
            None => 4,
            Some(l) => 4 + l,
        };
        if buf.len() >= target {
            match payload {
                None => {
                    let len = u32::from_be_bytes([buf[0], buf[1], buf[2], buf[3]]) as usize;
                    if len > MAX_FRAME_BYTES {
                        return Err(Error::Config(format!(
                            "cluster frame length {len} exceeds the {MAX_FRAME_BYTES}-byte cap"
                        )));
                    }
                    payload = Some(len);
                }
                Some(l) => {
                    let text = std::str::from_utf8(&buf[4..4 + l])
                        .map_err(|_| Error::Config("cluster frame is not UTF-8".into()))?;
                    return Ok(Some(Json::parse(text)?));
                }
            }
            continue;
        }
        let want = (target - buf.len()).min(scratch.len());
        match stream.read(&mut scratch[..want]) {
            Ok(0) => return Ok(None),
            Ok(n) => buf.extend_from_slice(&scratch[..n]),
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock
                        | std::io::ErrorKind::TimedOut
                        | std::io::ErrorKind::Interrupted
                ) =>
            {
                if let Ok(Some(_)) = child.try_wait() {
                    return Ok(None);
                }
            }
            Err(e) => return Err(e.into()),
        }
    }
}

/// Drive one slot's queue to completion, restarting its worker as many
/// times as it takes (bounded by [`MAX_ATTEMPTS`] per request), then
/// collect the worker's report and shut it down.
fn drive_slot(
    mut link: WorkerLink,
    queue: Vec<Request>,
    sup: Arc<Supervisor>,
    clock: WallClock,
    obs: Arc<ObsHandles>,
) -> Result<SlotOutcome> {
    let slot = link.slot;
    link.stream.set_read_timeout(Some(sup.heartbeat()))?;
    let mut records = Vec::with_capacity(queue.len());
    let mut latencies = Vec::with_capacity(queue.len());
    let mut telemetry: Vec<(usize, Json)> = Vec::new();
    let mut requeued = 0u64;
    for req in &queue {
        let mut attempts = 0u64;
        // The trace id derives from content + request id, so a
        // requeued request keeps its identity across incarnations.
        let trace_id =
            TraceId::derive(content_digest(&req.scene.spec(), req.width, req.height), req.id);
        let ctx = obs.trace.as_ref().map(|_| (trace_id.as_str(), SPAN_WIRE));
        // The sampling policy rides the wire with the trace context so
        // the worker can pre-judge span shipping; the wire form carries
        // resolved-ns thresholds, never raw flag text.
        let wire_sample = ctx.map(|_| obs.sampler.to_wire());
        loop {
            attempts += 1;
            if attempts > MAX_ATTEMPTS {
                return Err(Error::Config(format!(
                    "slot {slot}: request {} failed across {MAX_ATTEMPTS} worker incarnations",
                    req.id
                )));
            }
            let sent_ns = clock.now_ns();
            let died =
                match write_frame(&mut link.stream, &request_frame(req, ctx, wire_sample.as_deref()))
                {
                Err(_) => true,
                Ok(()) => {
                    match read_data_frame(&mut link.stream, &mut link.child, &mut telemetry, &obs)?
                    {
                        None => true,
                        Some(frame) => {
                            let resp = parse_response(&frame)?;
                            if resp.id != req.id {
                                return Err(Error::Config(format!(
                                    "slot {slot}: got response {} while waiting on request {}",
                                    resp.id, req.id
                                )));
                            }
                            latencies.push(clock.now_ns().saturating_sub(sent_ns));
                            if let Some(trace) = &obs.trace {
                                // Virtual spans live on the modeled
                                // timeline both ends share; wall spans
                                // are measured here.
                                let (t0, t1) = if obs.virtual_clock {
                                    (req.arrival_ns, resp.t_ns)
                                } else {
                                    (sent_ns, clock.now_ns())
                                };
                                // The front door owns the tail-sampling
                                // verdict: a dropped request loses its
                                // front spans and the worker subtree
                                // together (a worker that shipped spans
                                // conservatively is overridden here).
                                if obs.sampler.keep(t1.saturating_sub(t0), req.id) {
                                    trace.record_all(cluster_front_spans(
                                        &trace_id, slot, t0, t1,
                                    ));
                                    trace.record_all(resp.spans);
                                }
                            }
                            records.push(ResponseRecord {
                                id: resp.id,
                                slot,
                                edge_pixels: resp.edge_pixels,
                                digest: resp.digest,
                            });
                            false
                        }
                    }
                }
            };
            if !died {
                break;
            }
            link = sup.respawn(link)?;
            link.stream.set_read_timeout(Some(sup.heartbeat()))?;
            requeued += 1;
        }
    }
    let finished_ns = clock.now_ns();
    write_frame(&mut link.stream, &report_frame())?;
    let frame = read_data_frame(&mut link.stream, &mut link.child, &mut telemetry, &obs)?
        .ok_or_else(|| Error::Config(format!("worker {slot} died before reporting")))?;
    let body = parse_worker_report(&frame)?;
    write_frame(&mut link.stream, &shutdown_frame())?;
    let _ = link.child.wait();
    Ok(SlotOutcome { slot, records, latencies, requeued, finished_ns, body, telemetry })
}

/// A numeric field off a snapshot line, for the deterministic sort of
/// the merged telemetry stream.
fn line_u64(line: &Json, key: &str) -> u64 {
    line.get(key).and_then(Json::as_f64).unwrap_or(0.0) as u64
}

/// Spawn the fleet, route and dispatch the whole trace, merge the
/// per-worker reports. The entry point behind `cannyd cluster`.
pub fn run_cluster(label: &str, trace: &Trace, opts: &ClusterOptions) -> Result<ClusterOutcome> {
    let workers = opts.workers.max(1);
    let tracker = HealthTracker::from_spec(&opts.alert_log)?;
    let endpoint = crate::obs::endpoint::from_config_port(opts.cfg.obs_port)?;
    if let Some(e) = &endpoint {
        // Prime the live window with an empty merged line so an early
        // probe sees the cluster schema, not a worker's raw line.
        e.publish(&merged_line(&BTreeMap::new(), 0).dump());
    }
    let slo_p99_ns = (opts.cfg.slo_p99_ms.max(0.0) * 1e6) as u64;
    let obs = Arc::new(ObsHandles {
        trace: TraceCollector::from_spec(&opts.cfg.trace_log),
        // `RunConfig::validate` rejects malformed specs; the
        // keep-everything fallback only covers unvalidated configs.
        sampler: TraceSampler::from_spec(&opts.cfg.trace_sample, slo_p99_ns)
            .unwrap_or_else(|_| TraceSampler::all()),
        hub: TelemetryHub { endpoint, latest: Mutex::new((BTreeMap::new(), 0)) },
        virtual_clock: opts.cfg.clock == ClockMode::Virtual,
    });
    let (sup, links) = Supervisor::start(
        workers,
        opts.port,
        opts.heartbeat_ms,
        &opts.cfg,
        opts.fault,
        tracker,
    )?;
    let sup = Arc::new(sup);
    let ring = RoutingRing::new(workers);
    let mut queues: Vec<Vec<Request>> = vec![Vec::new(); workers];
    for req in &trace.requests {
        queues[ring.route_request(req)].push(*req);
    }
    let clock = WallClock::start();
    let mut handles = Vec::with_capacity(links.len());
    for link in links {
        let queue = std::mem::take(&mut queues[link.slot]);
        let sup = Arc::clone(&sup);
        let obs = Arc::clone(&obs);
        handles.push(std::thread::spawn(move || drive_slot(link, queue, sup, clock, obs)));
    }
    let mut outcomes: Vec<SlotOutcome> = Vec::with_capacity(handles.len());
    for h in handles {
        let outcome =
            h.join().map_err(|_| Error::Config("cluster dispatch thread panicked".into()))??;
        outcomes.push(outcome);
    }
    outcomes.sort_by_key(|o| o.slot);

    // Merged cluster telemetry: replay every worker frame in one
    // deterministic order — worker clock, then slot, then per-worker
    // seq (each worker's frames arrive in seq order, so ties on a
    // modeled clock cannot reorder within a worker). Under the virtual
    // clock two runs of the same trace produce a byte-identical file.
    // The anomaly monitor (`--anomaly-sigma`) consumes the same merged
    // stream in the same order, appending its alerts to the
    // supervisor's sink — so cluster-level anomaly alerts are exactly
    // as deterministic as the merged file.
    let mut monitor = AnomalyMonitor::from_sigma(opts.cfg.anomaly_sigma);
    let mut anomaly_alerts = 0u64;
    if !opts.cfg.telemetry_log.is_empty() || monitor.is_some() {
        let mut frames: Vec<&(usize, Json)> =
            outcomes.iter().flat_map(|o| o.telemetry.iter()).collect();
        frames.sort_by_key(|(slot, line)| (line_u64(line, "t_ns"), *slot, line_u64(line, "seq")));
        let mut anomaly_tracker = match monitor.is_some() {
            true => Some(HealthTracker::from_spec_append(&opts.alert_log)?),
            false => None,
        };
        let mut latest: BTreeMap<usize, Json> = BTreeMap::new();
        let mut out = String::new();
        for (seq, (slot, line)) in frames.iter().enumerate() {
            latest.insert(*slot, line.clone());
            let merged = merged_line(&latest, seq as u64 + 1);
            if let (Some(mon), Some(tracker)) = (monitor.as_mut(), anomaly_tracker.as_mut()) {
                for alert in mon.observe_line(&merged) {
                    tracker.raise(alert.line());
                }
            }
            if !opts.cfg.telemetry_log.is_empty() {
                out.push_str(&merged.dump());
                out.push('\n');
            }
        }
        if let Some(tracker) = &anomaly_tracker {
            anomaly_alerts = tracker.emitted();
        }
        if !opts.cfg.telemetry_log.is_empty() {
            std::fs::write(Path::new(&opts.cfg.telemetry_log), out)?;
        }
    }
    if let Some(trace_log) = &obs.trace {
        trace_log.write()?;
    }
    if let Some(e) = &obs.hub.endpoint {
        e.stop();
    }

    let mut responses: Vec<ResponseRecord> =
        outcomes.iter().flat_map(|o| o.records.iter().cloned()).collect();
    responses.sort_by_key(|r| r.id);
    let mut latencies_ns: Vec<u64> =
        outcomes.iter().flat_map(|o| o.latencies.iter().copied()).collect();
    latencies_ns.sort_unstable();
    let report = ClusterReport {
        label: label.to_string(),
        workers,
        requests: trace.len() as u64,
        completed: responses.len() as u64,
        requeued: outcomes.iter().map(|o| o.requeued).sum(),
        restarts: sup.restarts(),
        alerts: sup.alerts_emitted() + anomaly_alerts,
        makespan_ns: outcomes.iter().map(|o| o.finished_ns).max().unwrap_or(0),
        latencies_ns,
        per_worker: outcomes.iter().map(|o| o.body.clone()).collect(),
    };
    Ok(ClusterOutcome { report, responses })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::synth::Scene;
    use crate::service::RequestKind;

    #[test]
    fn ring_routes_deterministically_and_in_range() {
        let a = RoutingRing::new(4);
        let b = RoutingRing::new(4);
        for d in (0..2000u64).map(|i| i.wrapping_mul(0x9e37_79b9_7f4a_7c15)) {
            let slot = a.route(d);
            assert!(slot < 4);
            assert_eq!(slot, b.route(d), "ring construction must be deterministic");
        }
    }

    #[test]
    fn ring_spreads_load_over_every_slot() {
        let ring = RoutingRing::new(4);
        let mut counts = [0usize; 4];
        for i in 0..4000u64 {
            counts[ring.route(i.wrapping_mul(0x9e37_79b9_7f4a_7c15))] += 1;
        }
        for (slot, &n) in counts.iter().enumerate() {
            assert!(n > 400, "slot {slot} got {n}/4000 digests — ring badly skewed");
        }
    }

    #[test]
    fn growing_the_ring_moves_a_minority_of_digests() {
        let three = RoutingRing::new(3);
        let four = RoutingRing::new(4);
        let total = 4000u64;
        let moved = (0..total)
            .map(|i| i.wrapping_mul(0x9e37_79b9_7f4a_7c15))
            .filter(|&d| three.route(d) != four.route(d))
            .count();
        // Ideal consistent hashing moves 1/4 of the space; allow slack
        // for virtual-point variance but fail on rehash-everything.
        assert!(
            moved < (total as usize) / 2,
            "{moved}/{total} digests moved when adding one slot"
        );
    }

    #[test]
    fn routing_is_content_affine_not_kind_affine() {
        let ring = RoutingRing::new(4);
        let mk = |kind| Request {
            id: 0,
            arrival_ns: 0,
            scene: Scene::Shapes { seed: 77 },
            width: 128,
            height: 96,
            kind,
        };
        let full = ring.route_request(&mk(RequestKind::Full));
        let front = ring.route_request(&mk(RequestKind::FrontOnly));
        let re = ring.route_request(&mk(RequestKind::ReThreshold { lo: 0.02, hi: 0.3 }));
        assert_eq!(full, front);
        assert_eq!(front, re, "re-thresholds must land on the warming worker");
        // Different content usually lands elsewhere; at minimum the
        // digest must change.
        let other = route_digest(&Scene::Shapes { seed: 78 }.spec(), 128, 96);
        assert_ne!(route_digest(&Scene::Shapes { seed: 77 }.spec(), 128, 96), other);
    }

    #[test]
    fn options_from_config_defaults() {
        let cfg = RunConfig::default();
        let opts = ClusterOptions::from_config(&cfg);
        assert_eq!(opts.workers, DEFAULT_WORKERS, "workers=0 means the cluster default");
        assert_eq!(opts.port, 0, "ephemeral port by default");
        assert!(opts.fault.is_none());
        let mut cfg = RunConfig::default();
        cfg.set("workers", "3").unwrap();
        assert_eq!(ClusterOptions::from_config(&cfg).workers, 3);
    }
}
