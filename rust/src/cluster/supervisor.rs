//! Process supervision for the cluster tier: spawn `cannyd worker`
//! children, map their loopback connections to slots via the `hello`
//! handshake, and restart dead workers on demand — each death and
//! recovery emitted as a health-transition alert through the shared
//! [`HealthTracker`] (satellite 2's sink, reused across the process
//! boundary) and counted into the merged cluster report.
//!
//! The supervisor is deliberately passive about liveness: the router's
//! dispatch threads are the ones blocked on worker sockets, so *they*
//! detect death (EOF, broken pipe, or a heartbeat-interval read timeout
//! whose `try_wait` probe finds the child gone) and call
//! [`Supervisor::respawn`]. The supervisor owns what must be shared:
//! the listener, the spawn recipe, the restart counter and the alert
//! tracker.
//!
//! Workers are spawned from an explicit config allowlist
//! ([`FORWARDED_KEYS`]) rather than the whole `to_map()`: a worker must
//! inherit the detector parameters and cache geometry (so its output
//! and cache behavior match the single-process tier bit-for-bit), but
//! must *not* inherit `workers` (a process count here, a thread count
//! there), the cluster/alert flags, or the serve-tier lane knobs.

use std::collections::BTreeMap;
use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use crate::cluster::proto::{parse_hello, read_frame};
use crate::cluster::worker::WORKER_FAULT_ENV;
use crate::config::RunConfig;
use crate::error::{Error, Result};
use crate::obs::{Health, HealthTracker};
use crate::service::clock::WallClock;

/// Env override for the worker executable. The integration tests set
/// it to `CARGO_BIN_EXE_cannyd` (the test process is not the `cannyd`
/// binary); unset, workers are respawns of the current executable.
pub const WORKER_EXE_ENV: &str = "CANNYD_CLUSTER_EXE";

/// Config keys the supervisor re-sends on each worker's command line:
/// detector parameters (output bits), cache geometry (shard behavior),
/// and the observability knobs workers must agree with the front door
/// on — the clock mode (so worker span times live in the same domain
/// the front door merges) and the telemetry-frame cadence. Everything
/// else stays at the worker's defaults; in particular `trace-log` and
/// `telemetry-log` are *not* forwarded — spans and snapshot lines ship
/// home over the wire, and only the front door writes files.
pub const FORWARDED_KEYS: &[&str] = &[
    "engine",
    "lo",
    "hi",
    "tile",
    "parallel-hysteresis",
    "seed",
    "cache-mb",
    "cache-shards",
    "cache-admit-ns-per-byte",
    "max-pixels",
    "clock",
    "worker-telemetry-ms",
];

/// How long a spawned worker gets to connect and say `hello` before
/// the cluster gives up on it.
const HANDSHAKE_TIMEOUT_NS: u64 = 30_000_000_000;

/// One-shot fault injection for the restart tests: the first
/// incarnation of `slot` is spawned with [`WORKER_FAULT_ENV`] set to
/// `after`, so it kills itself on request `after + 1`. Respawns never
/// carry the variable — the restarted worker serves normally.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WorkerFault {
    pub slot: usize,
    pub after: u64,
}

/// A live worker incarnation: the child process plus the connected,
/// hello-verified stream. Owned by the slot's dispatch thread; the
/// supervisor only sees it again inside [`Supervisor::respawn`].
#[derive(Debug)]
pub struct WorkerLink {
    pub slot: usize,
    pub stream: TcpStream,
    pub child: Child,
}

/// Listener state shared by startup and restarts. Hellos can arrive in
/// any order when several workers boot at once, so connections for
/// other slots are parked in `pending` instead of dropped.
#[derive(Debug)]
struct AcceptState {
    listener: TcpListener,
    pending: Vec<(usize, TcpStream)>,
}

/// The shared supervision core (one per `cannyd cluster` run).
#[derive(Debug)]
pub struct Supervisor {
    exe: PathBuf,
    args: Vec<String>,
    port: u16,
    heartbeat_ms: u64,
    accept: Mutex<AcceptState>,
    restarts: AtomicU64,
    tracker: Mutex<HealthTracker>,
    clock: WallClock,
}

/// The `--key=value` args forwarded to every worker (the
/// [`FORWARDED_KEYS`] slice of the resolved config).
pub fn forwarded_args(cfg: &RunConfig) -> Vec<String> {
    let map: BTreeMap<String, String> = cfg.to_map();
    FORWARDED_KEYS
        .iter()
        .filter_map(|k| map.get(*k).map(|v| format!("--{k}={v}")))
        .collect()
}

fn worker_exe() -> Result<PathBuf> {
    match std::env::var(WORKER_EXE_ENV) {
        Ok(path) if !path.is_empty() => Ok(PathBuf::from(path)),
        _ => Ok(std::env::current_exe()?),
    }
}

impl Supervisor {
    /// Bind the front door, spawn `workers` children and complete every
    /// handshake. Returns the supervisor plus one [`WorkerLink`] per
    /// slot, in slot order.
    pub fn start(
        workers: usize,
        port: u16,
        heartbeat_ms: u64,
        cfg: &RunConfig,
        fault: Option<WorkerFault>,
        tracker: HealthTracker,
    ) -> Result<(Supervisor, Vec<WorkerLink>)> {
        let listener = TcpListener::bind(("127.0.0.1", port))?;
        // Nonblocking accepts let the handshake loop interleave child
        // liveness probes instead of hanging on a worker that died
        // before connecting.
        listener.set_nonblocking(true)?;
        let port = listener.local_addr()?.port();
        let sup = Supervisor {
            exe: worker_exe()?,
            args: forwarded_args(cfg),
            port,
            heartbeat_ms: heartbeat_ms.max(1),
            accept: Mutex::new(AcceptState { listener, pending: Vec::new() }),
            restarts: AtomicU64::new(0),
            tracker: Mutex::new(tracker),
            clock: WallClock::start(),
        };
        let mut children = Vec::with_capacity(workers);
        for slot in 0..workers {
            let with_fault = fault.filter(|f| f.slot == slot).map(|f| f.after);
            children.push(sup.spawn_child(slot, with_fault)?);
        }
        let mut links = Vec::with_capacity(workers);
        for (slot, child) in children.into_iter().enumerate() {
            links.push(sup.accept_link(slot, child)?);
        }
        Ok((sup, links))
    }

    /// The actual bound port (resolves `--cluster-port 0`).
    pub fn port(&self) -> u16 {
        self.port
    }

    /// The read-timeout the dispatch threads poll worker sockets with.
    pub fn heartbeat(&self) -> Duration {
        Duration::from_millis(self.heartbeat_ms)
    }

    /// Worker restarts performed so far.
    pub fn restarts(&self) -> u64 {
        self.restarts.load(Ordering::Relaxed)
    }

    /// Health-transition alert lines emitted so far (two per restart:
    /// `healthy -> stalled` at death, `stalled -> healthy` once the
    /// replacement has said hello).
    pub fn alerts_emitted(&self) -> u64 {
        self.tracker.lock().expect("alert tracker poisoned").emitted()
    }

    /// Replace a dead incarnation: reap the old child, spawn a fresh
    /// one for the same slot (never with the fault env — the injected
    /// crash is one-shot) and complete its handshake.
    pub fn respawn(&self, old: WorkerLink) -> Result<WorkerLink> {
        let WorkerLink { slot, stream, mut child } = old;
        drop(stream);
        let _ = child.kill();
        let _ = child.wait();
        self.observe(slot, Health::Stalled);
        self.restarts.fetch_add(1, Ordering::Relaxed);
        let fresh = self.spawn_child(slot, None)?;
        let link = self.accept_link(slot, fresh)?;
        self.observe(slot, Health::Healthy);
        Ok(link)
    }

    fn observe(&self, slot: usize, health: Health) {
        let mut t = self.tracker.lock().expect("alert tracker poisoned");
        t.observe(self.clock.now_ns(), &format!("cluster/worker{slot}"), health);
    }

    fn spawn_child(&self, slot: usize, fault_after: Option<u64>) -> Result<Child> {
        let mut cmd = Command::new(&self.exe);
        cmd.arg("worker")
            .arg(format!("--worker-id={slot}"))
            .arg(format!("--cluster-port={}", self.port))
            .args(&self.args)
            .stdin(Stdio::null())
            // The merged cluster report owns stdout; worker noise would
            // corrupt it. Stderr passes through for alerts/panics.
            .stdout(Stdio::null())
            .stderr(Stdio::inherit());
        if let Some(after) = fault_after {
            cmd.env(WORKER_FAULT_ENV, after.to_string());
        }
        Ok(cmd.spawn()?)
    }

    /// Accept connections until `slot`'s hello arrives (other slots'
    /// hellos are parked), failing fast if the child exits first.
    fn accept_link(&self, slot: usize, mut child: Child) -> Result<WorkerLink> {
        let mut st = self.accept.lock().expect("cluster listener poisoned");
        if let Some(pos) = st.pending.iter().position(|(s, _)| *s == slot) {
            let (_, stream) = st.pending.remove(pos);
            return Ok(WorkerLink { slot, stream, child });
        }
        let t0 = self.clock.now_ns();
        loop {
            match st.listener.accept() {
                Ok((mut stream, _)) => {
                    stream.set_nonblocking(false)?;
                    stream.set_nodelay(true).ok();
                    stream.set_read_timeout(Some(Duration::from_secs(5)))?;
                    let hello = read_frame(&mut stream)?;
                    let s = parse_hello(&hello)?;
                    stream.set_read_timeout(None)?;
                    if s == slot {
                        return Ok(WorkerLink { slot, stream, child });
                    }
                    st.pending.push((s, stream));
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    if let Ok(Some(status)) = child.try_wait() {
                        return Err(Error::Config(format!(
                            "worker {slot} exited during handshake ({status})"
                        )));
                    }
                    if self.clock.now_ns().saturating_sub(t0) > HANDSHAKE_TIMEOUT_NS {
                        return Err(Error::Config(format!(
                            "worker {slot} did not say hello within the handshake timeout"
                        )));
                    }
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(e) => return Err(e.into()),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forwarded_args_cover_the_allowlist_and_nothing_else() {
        let mut cfg = RunConfig::default();
        cfg.set("engine", "serial").unwrap();
        cfg.set("workers", "7").unwrap();
        cfg.set("cache-mb", "16").unwrap();
        cfg.set("cluster-port", "9999").unwrap();
        cfg.set("alert-log", "stderr").unwrap();
        let args = forwarded_args(&cfg);
        assert_eq!(args.len(), FORWARDED_KEYS.len());
        assert!(args.contains(&"--engine=serial".to_string()));
        assert!(args.contains(&"--cache-mb=16".to_string()));
        // `workers` means processes at the cluster layer and threads in
        // the worker: never forwarded. Cluster/alert plumbing stays
        // router-side too.
        assert!(args.iter().all(|a| !a.starts_with("--workers")));
        assert!(args.iter().all(|a| !a.starts_with("--cluster-port")));
        assert!(args.iter().all(|a| !a.starts_with("--alert-log")));
    }

    #[test]
    fn fault_is_slot_scoped() {
        let fault = Some(WorkerFault { slot: 1, after: 2 });
        assert_eq!(fault.filter(|f| f.slot == 1).map(|f| f.after), Some(2));
        assert_eq!(fault.filter(|f| f.slot == 0).map(|f| f.after), None);
    }
}
