//! Edge-quality and statistical metrics: the quantitative backing for
//! the paper's qualitative claims (good detection → SNR, good
//! localization → Pratt's FOM, determinism → exact diffs, even load →
//! coefficient of variation).

use crate::image::{EdgeMap, ImageF32};

/// Peak signal-to-noise ratio between two images (dB). `+inf` if equal.
pub fn psnr(a: &ImageF32, b: &ImageF32) -> f64 {
    assert_eq!((a.width(), a.height()), (b.width(), b.height()));
    let mse: f64 = a
        .data()
        .iter()
        .zip(b.data())
        .map(|(&x, &y)| {
            let d = (x - y) as f64;
            d * d
        })
        .sum::<f64>()
        / a.len() as f64;
    if mse == 0.0 {
        f64::INFINITY
    } else {
        10.0 * (1.0 / mse).log10()
    }
}

/// Discrete analogue of the paper's detection-SNR criterion: edge
/// response amplitude over noise standard deviation, measured from the
/// gradient magnitude on edge vs non-edge pixels of a ground truth.
pub fn detection_snr(magnitude: &ImageF32, truth: &EdgeMap) -> f64 {
    assert_eq!((magnitude.width(), magnitude.height()), (truth.width(), truth.height()));
    let (mut sig, mut nsig) = (0.0f64, 0usize);
    let (mut noise_sq, mut nnoise) = (0.0f64, 0usize);
    for y in 0..truth.height() {
        for x in 0..truth.width() {
            let m = magnitude.get(y, x) as f64;
            if truth.is_edge(y, x) {
                sig += m;
                nsig += 1;
            } else {
                noise_sq += m * m;
                nnoise += 1;
            }
        }
    }
    if nsig == 0 || nnoise == 0 {
        return 0.0;
    }
    let a = sig / nsig as f64;
    let sigma = (noise_sq / nnoise as f64).sqrt();
    if sigma == 0.0 {
        f64::INFINITY
    } else {
        a / sigma
    }
}

/// Pratt's Figure of Merit: localization quality of `detected` against
/// `truth` (1.0 = perfect). `alpha` is the standard 1/9 scaling.
pub fn pratt_fom(detected: &EdgeMap, truth: &EdgeMap) -> f64 {
    assert_eq!((detected.width(), detected.height()), (truth.width(), truth.height()));
    let (w, h) = (truth.width(), truth.height());
    let truth_pts: Vec<(i64, i64)> = (0..h)
        .flat_map(|y| (0..w).filter(move |&x| truth.is_edge(y, x)).map(move |x| (y as i64, x as i64)))
        .collect();
    let n_truth = truth_pts.len();
    let n_det = detected.count_edges();
    if n_truth == 0 || n_det == 0 {
        return if n_truth == n_det { 1.0 } else { 0.0 };
    }
    // Distance transform via two-pass chamfer would be fancier; edge
    // sets here are small enough for a windowed nearest search.
    let alpha = 1.0 / 9.0;
    let mut sum = 0.0f64;
    // Bucket truth points by row for a banded nearest-neighbour query.
    let mut rows: Vec<Vec<i64>> = vec![Vec::new(); h];
    for &(y, x) in &truth_pts {
        rows[y as usize].push(x);
    }
    for y in 0..h {
        for x in 0..w {
            if !detected.is_edge(y, x) {
                continue;
            }
            let mut best = f64::INFINITY;
            // Search rows outward; stop when the row distance alone
            // exceeds the best found.
            for dy in 0..h as i64 {
                if (dy * dy) as f64 >= best {
                    break;
                }
                for ry in [y as i64 - dy, y as i64 + dy] {
                    if ry < 0 || ry >= h as i64 || (dy > 0 && ry == y as i64) {
                        continue;
                    }
                    for &rx in &rows[ry as usize] {
                        let d2 = (dy * dy + (rx - x as i64) * (rx - x as i64)) as f64;
                        if d2 < best {
                            best = d2;
                        }
                    }
                }
            }
            sum += 1.0 / (1.0 + alpha * best);
        }
    }
    sum / n_truth.max(n_det) as f64
}

/// Precision/recall of detected edges against a ground truth with a
/// tolerance of `tol` pixels (Chebyshev distance).
pub fn precision_recall(detected: &EdgeMap, truth: &EdgeMap, tol: usize) -> (f64, f64) {
    assert_eq!((detected.width(), detected.height()), (truth.width(), truth.height()));
    let near = |map: &EdgeMap, y: usize, x: usize| -> bool {
        let (w, h) = (map.width() as i64, map.height() as i64);
        let t = tol as i64;
        for dy in -t..=t {
            for dx in -t..=t {
                let (ny, nx) = (y as i64 + dy, x as i64 + dx);
                if ny >= 0 && ny < h && nx >= 0 && nx < w && map.is_edge(ny as usize, nx as usize)
                {
                    return true;
                }
            }
        }
        false
    };
    let (mut tp_p, mut n_p) = (0usize, 0usize);
    for y in 0..detected.height() {
        for x in 0..detected.width() {
            if detected.is_edge(y, x) {
                n_p += 1;
                if near(truth, y, x) {
                    tp_p += 1;
                }
            }
        }
    }
    let (mut tp_r, mut n_r) = (0usize, 0usize);
    for y in 0..truth.height() {
        for x in 0..truth.width() {
            if truth.is_edge(y, x) {
                n_r += 1;
                if near(detected, y, x) {
                    tp_r += 1;
                }
            }
        }
    }
    let precision = if n_p == 0 { 1.0 } else { tp_p as f64 / n_p as f64 };
    let recall = if n_r == 0 { 1.0 } else { tp_r as f64 / n_r as f64 };
    (precision, recall)
}

/// Coefficient of variation (stddev / mean) — the load-balance metric
/// for Figure 3 (0 = perfectly even distribution).
pub fn coefficient_of_variation(values: &[f64]) -> f64 {
    if values.is_empty() {
        return 0.0;
    }
    let mean = values.iter().sum::<f64>() / values.len() as f64;
    if mean == 0.0 {
        return 0.0;
    }
    let var =
        values.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / values.len() as f64;
    var.sqrt() / mean
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::EdgeMap;

    fn em(w: usize, h: usize, pts: &[(usize, usize)]) -> EdgeMap {
        let mut d = vec![0u8; w * h];
        for &(y, x) in pts {
            d[y * w + x] = 255;
        }
        EdgeMap::new(w, h, d).unwrap()
    }

    #[test]
    fn psnr_identical_is_inf() {
        let a = ImageF32::zeros(4, 4);
        assert!(psnr(&a, &a).is_infinite());
    }

    #[test]
    fn psnr_decreases_with_noise() {
        let a = ImageF32::zeros(8, 8);
        let mut b = ImageF32::zeros(8, 8);
        let mut c = ImageF32::zeros(8, 8);
        b.set(0, 0, 0.1);
        c.set(0, 0, 0.5);
        assert!(psnr(&a, &b) > psnr(&a, &c));
    }

    #[test]
    fn fom_perfect_match_is_one() {
        let t = em(10, 10, &[(5, 2), (5, 3), (5, 4)]);
        assert!((pratt_fom(&t, &t) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn fom_penalizes_displacement() {
        let t = em(10, 10, &[(5, 2), (5, 3), (5, 4)]);
        let near = em(10, 10, &[(6, 2), (6, 3), (6, 4)]);
        let far = em(10, 10, &[(9, 2), (9, 3), (9, 4)]);
        let f_near = pratt_fom(&near, &t);
        let f_far = pratt_fom(&far, &t);
        assert!(f_near > f_far, "{f_near} vs {f_far}");
        assert!(f_near < 1.0);
    }

    #[test]
    fn fom_empty_cases() {
        let none = em(4, 4, &[]);
        let some = em(4, 4, &[(1, 1)]);
        assert_eq!(pratt_fom(&none, &none), 1.0);
        assert_eq!(pratt_fom(&some, &none), 0.0);
        assert_eq!(pratt_fom(&none, &some), 0.0);
    }

    #[test]
    fn precision_recall_tolerant() {
        let t = em(10, 10, &[(5, 5)]);
        let d = em(10, 10, &[(5, 6)]); // off by one
        let (p0, r0) = precision_recall(&d, &t, 0);
        assert_eq!((p0, r0), (0.0, 0.0));
        let (p1, r1) = precision_recall(&d, &t, 1);
        assert_eq!((p1, r1), (1.0, 1.0));
    }

    #[test]
    fn detection_snr_strong_edges_win() {
        let mut mag = ImageF32::zeros(4, 4);
        let t = em(4, 4, &[(1, 1), (2, 2)]);
        mag.set(1, 1, 1.0);
        mag.set(2, 2, 1.0);
        mag.set(0, 3, 0.1); // background noise
        let snr = detection_snr(&mag, &t);
        assert!(snr > 10.0, "snr={snr}");
    }

    #[test]
    fn cov_uniform_is_zero() {
        assert_eq!(coefficient_of_variation(&[2.0, 2.0, 2.0]), 0.0);
        assert!(coefficient_of_variation(&[1.0, 3.0]) > 0.4);
    }
}
