//! The snapshot engine: turns the live [`Telemetry`] registry into
//! periodic machine-readable JSONL lines (`--telemetry-log file.jsonl
//! --telemetry-interval-ms N`; schema documented in [`crate::obs`]).
//!
//! Two drive modes, one emitter:
//!
//! * **virtual** — the deterministic serve driver calls
//!   [`SnapshotEngine::take_tick`] from its event loop, interleaving
//!   ticks with modeled completions in time order. Every value on a
//!   line is modeled, so two replays of the same trace produce
//!   byte-identical files.
//! * **wall** — [`WallSnapshotter`] runs a real sampler thread
//!   (the ops-plane sibling of [`crate::profiler::Sampler`]) that
//!   emits a line every interval, samples the worker pools' busy flags
//!   into a per-tick `utilization` section, and accumulates them into a
//!   [`UsageTrace`] — the paper's Figure-8/9 core-usage data without a
//!   separate profiler invocation.
//!
//! Lines are appended with a trailing newline each; the file is
//! truncated at engine creation so a run's log is self-contained.

use std::collections::BTreeMap;
use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::cache::CacheSnapshot;
use crate::error::{Error, Result};
use crate::obs::anomaly::AnomalyMonitor;
use crate::obs::endpoint::ObsEndpoint;
use crate::obs::health::{Health, HealthTracker, DEFAULT_STALL_AFTER_NS};
use crate::obs::registry::Telemetry;
use crate::profiler::{UsageSample, UsageTrace};
use crate::scheduler::PoolStats;
use crate::util::json::Json;

/// Everything one tick needs beyond the registry itself: the sections
/// owned by the driver (rolling SLO window, cache snapshot, wall-only
/// utilization sample).
#[derive(Debug)]
pub struct TickInputs<'a> {
    /// Tick time in the driver's clock domain (modeled ns under the
    /// virtual clock, monotonic ns under wall).
    pub t_ns: u64,
    pub telemetry: &'a Telemetry,
    /// Snapshot of the shared artifact cache (the disabled all-zero
    /// snapshot when no cache is attached).
    pub cache: CacheSnapshot,
    /// The rolling-SLO section ([`crate::service::slo::SloWindow`]'s
    /// JSON), carrying at least a `status` key.
    pub slo: Json,
    /// Is the rolling SLO currently missed? Drives the `degraded`
    /// health state when shedding is possible.
    pub slo_missed: bool,
    /// Can the run's overload policy shed at all? (`false` for policy
    /// `none`: a missed SLO is then reported, not acted on, and health
    /// stays `healthy`.)
    pub shedding_possible: bool,
    /// Per-core busy sample (wall snapshotter only; omitted — not
    /// zeroed — in virtual replays, where measured utilization would
    /// break byte-identity).
    pub utilization: Option<Json>,
}

/// The JSONL emitter. Owns the output file, the line sequence number
/// and the periodic-tick schedule; disabled (no `--telemetry-log`) it
/// is a no-op whose next tick never arrives.
#[derive(Debug)]
pub struct SnapshotEngine {
    out: Option<BufWriter<File>>,
    path: Option<PathBuf>,
    interval_ns: u64,
    policy: String,
    stall_after_ns: u64,
    seq: u64,
    ticks: u64,
    lines: u64,
    /// Health-transition alerting (`--alert-log`): each tick's derived
    /// lane and tier states are diffed against the last tick's, one
    /// line per change, counted into the registry's `alerts` counter.
    tracker: HealthTracker,
    /// Live snapshot endpoint (`--obs-port`): every built line is also
    /// published as the endpoint's current line, independent of whether
    /// a JSONL sink is attached.
    endpoint: Option<Arc<ObsEndpoint>>,
    /// Streaming anomaly detection (`--anomaly-sigma`): every built
    /// line is fed to the EWMA monitor; raised alerts go through the
    /// tracker's sink (and its `last_line`, which the endpoint serves).
    monitor: Option<AnomalyMonitor>,
}

impl SnapshotEngine {
    /// The inert engine: `enabled()` is false, `take_tick` never fires,
    /// `emit` does nothing.
    pub fn disabled() -> SnapshotEngine {
        SnapshotEngine {
            out: None,
            path: None,
            interval_ns: u64::MAX,
            policy: "none".into(),
            stall_after_ns: DEFAULT_STALL_AFTER_NS,
            seq: 0,
            ticks: 0,
            lines: 0,
            tracker: HealthTracker::off(),
            endpoint: None,
            monitor: None,
        }
    }

    /// Open (truncating) `path` for a run with the given tick interval
    /// and overload/drop policy name (echoed on every line).
    pub fn create(path: &Path, interval_ns: u64, policy: &str) -> Result<SnapshotEngine> {
        if interval_ns == 0 {
            return Err(Error::Config("telemetry interval must be > 0".into()));
        }
        let file = File::create(path)
            .map_err(|e| Error::Config(format!("telemetry log {}: {e}", path.display())))?;
        Ok(SnapshotEngine {
            out: Some(BufWriter::new(file)),
            path: Some(path.to_path_buf()),
            interval_ns,
            policy: policy.to_string(),
            stall_after_ns: DEFAULT_STALL_AFTER_NS,
            seq: 0,
            ticks: 0,
            lines: 0,
            tracker: HealthTracker::off(),
            endpoint: None,
            monitor: None,
        })
    }

    /// Build from options: `Some(path)` opens, `None` disables the
    /// JSONL sink but keeps the tick grid — so an attached alert
    /// tracker ([`SnapshotEngine::with_alerts`]) still gets health
    /// evaluated every interval even with no telemetry log.
    pub fn from_options(
        path: Option<&Path>,
        interval_ns: u64,
        policy: &str,
    ) -> Result<SnapshotEngine> {
        match path {
            Some(p) => SnapshotEngine::create(p, interval_ns, policy),
            None => {
                let mut e = SnapshotEngine::disabled();
                e.interval_ns = interval_ns.max(1);
                e.policy = policy.to_string();
                Ok(e)
            }
        }
    }

    /// Attach a health-transition alert tracker (`--alert-log`).
    pub fn with_alerts(mut self, tracker: HealthTracker) -> SnapshotEngine {
        self.tracker = tracker;
        self
    }

    /// Attach (or detach, with `None`) a live snapshot endpoint
    /// (`--obs-port`): every line this engine builds is published as
    /// the endpoint's current line, even when no JSONL sink is open.
    pub fn with_endpoint(mut self, endpoint: Option<Arc<ObsEndpoint>>) -> SnapshotEngine {
        self.endpoint = endpoint;
        self
    }

    /// Attach (or leave detached, with `None`) a streaming anomaly
    /// monitor (`--anomaly-sigma`): every built line is fed to the
    /// EWMA detectors, and raised alerts are emitted through the
    /// attached alert tracker (or just remembered for the endpoint's
    /// alert line when no `--alert-log` sink is configured).
    pub fn with_anomaly(mut self, monitor: Option<AnomalyMonitor>) -> SnapshotEngine {
        self.monitor = monitor;
        self
    }

    /// Is anomaly detection attached? (Like alerting, a monitor keeps
    /// the tick grid live without a JSONL sink.)
    pub fn anomaly_active(&self) -> bool {
        self.monitor.is_some()
    }

    /// Is a live snapshot endpoint attached? (Like alerting, an
    /// endpoint keeps the tick grid live without a JSONL sink.)
    pub fn endpoint_active(&self) -> bool {
        self.endpoint.is_some()
    }

    /// Is alerting attached? (Ticks fire for alert evaluation even
    /// when the JSONL sink is disabled.)
    pub fn alerts_active(&self) -> bool {
        self.tracker.active()
    }

    /// Alert lines emitted so far.
    pub fn alerts_emitted(&self) -> u64 {
        self.tracker.emitted()
    }

    pub fn enabled(&self) -> bool {
        self.out.is_some()
    }

    pub fn interval_ns(&self) -> u64 {
        self.interval_ns
    }

    /// Lines written so far.
    pub fn lines(&self) -> u64 {
        self.lines
    }

    /// When the next periodic tick is due (`u64::MAX` when disabled).
    /// The first tick fires at one interval, not at zero — a t=0 line
    /// would only ever hold zeros.
    pub fn next_tick_ns(&self) -> u64 {
        if !self.enabled()
            && !self.tracker.active()
            && self.endpoint.is_none()
            && self.monitor.is_none()
        {
            return u64::MAX;
        }
        (self.ticks + 1).saturating_mul(self.interval_ns)
    }

    /// Claim the next periodic tick if it is due at `now_ns`, returning
    /// its scheduled time. Drivers loop this to emit every tick that
    /// has become due, each stamped at its own grid point:
    ///
    /// ```ignore
    /// while let Some(t) = engine.take_tick(now_ns) {
    ///     engine.emit(TickInputs { t_ns: t, /* … */ })?;
    /// }
    /// ```
    pub fn take_tick(&mut self, now_ns: u64) -> Option<u64> {
        let due = self.next_tick_ns();
        if due > now_ns {
            return None;
        }
        self.ticks += 1;
        Some(due)
    }

    /// Append one snapshot line (and run alert evaluation, and publish
    /// to the live endpoint). No-op when the sink is disabled and
    /// neither an alert tracker nor an endpoint is attached; with only
    /// a tracker/endpoint, the line is built and published but not
    /// written.
    pub fn emit(&mut self, inputs: TickInputs) -> Result<()> {
        if self.out.is_none()
            && !self.tracker.active()
            && self.endpoint.is_none()
            && self.monitor.is_none()
        {
            return Ok(());
        }
        let line = self.build_line(&inputs);
        self.scan_anomalies(&line, inputs.telemetry);
        let rendered = line.dump();
        if let Some(ep) = &self.endpoint {
            ep.publish(&rendered);
            if let Some(alert) = self.tracker.last_line() {
                ep.publish_alert(alert);
            }
        }
        if let Some(out) = self.out.as_mut() {
            out.write_all(rendered.as_bytes())?;
            out.write_all(b"\n")?;
            self.lines += 1;
        }
        self.seq += 1;
        Ok(())
    }

    /// Feed one built line to the anomaly monitor (when attached),
    /// routing raised alerts through the tracker's sink and counting
    /// them into the registry. The line under scan is already built,
    /// so anomaly alerts surface on the *next* line's `alerts` counter
    /// — deterministic either way.
    fn scan_anomalies(&mut self, line: &Json, telemetry: &Telemetry) {
        let Some(monitor) = self.monitor.as_mut() else {
            return;
        };
        for alert in monitor.observe_line(line) {
            self.tracker.raise(alert.line());
            telemetry.alerts.inc();
        }
    }

    /// Flush and close, returning the number of lines written.
    pub fn close(mut self) -> Result<u64> {
        if let Some(mut out) = self.out.take() {
            out.flush()?;
        }
        Ok(self.lines)
    }

    pub fn path(&self) -> Option<&Path> {
        self.path.as_deref()
    }

    /// One snapshot line (the JSONL schema documented in
    /// [`crate::obs`]). Key order is `BTreeMap` order, values are
    /// whatever the registry holds — deterministic inputs, identical
    /// bytes.
    fn build_line(&mut self, inputs: &TickInputs) -> Json {
        let tel = inputs.telemetry;
        let num = |v: u64| Json::Num(v as f64);
        let shedding = inputs.slo_missed && inputs.shedding_possible;

        let mut lanes = Vec::with_capacity(tel.lanes.len());
        let mut states = Vec::with_capacity(tel.lanes.len());
        for (i, lane) in tel.lanes.iter().enumerate() {
            let health = Health::derive(
                inputs.t_ns,
                lane.heartbeat_ns.get(),
                lane.inflight.get(),
                self.stall_after_ns,
                shedding,
            );
            if self.tracker.observe(inputs.t_ns, &format!("{}/lane{i}", tel.tier), health) {
                tel.alerts.inc();
            }
            states.push(health);
            let mut m = BTreeMap::new();
            m.insert("batches".into(), num(lane.batches.get()));
            m.insert("busy_ns".into(), num(lane.busy_ns.get()));
            m.insert("completed".into(), num(lane.completed.get()));
            m.insert("health".into(), Json::Str(health.name().into()));
            m.insert("heartbeat_ns".into(), num(lane.heartbeat_ns.get()));
            m.insert("id".into(), Json::Num(i as f64));
            m.insert("inflight".into(), num(lane.inflight.get()));
            lanes.push(Json::Obj(m));
        }

        let lat = tel.latency.snapshot();
        // Exemplar-linked buckets: each latency bucket that has one
        // cites the trace id + value of its worst sampled observation.
        // Bucket keys (the bucket's inclusive upper bound, stringified)
        // are dynamic; the section shape is documented in
        // [`crate::obs`].
        let mut ex_latency = BTreeMap::new();
        for (hi, (trace, value_ns)) in &lat.exemplars {
            let mut e = BTreeMap::new();
            e.insert("trace".into(), Json::Str(trace.clone()));
            e.insert("value_ns".into(), num(*value_ns));
            ex_latency.insert(hi.to_string(), Json::Obj(e));
        }
        let mut exemplars = BTreeMap::new();
        exemplars.insert("latency".into(), Json::Obj(ex_latency));

        let mut latency = BTreeMap::new();
        latency.insert("count".into(), num(lat.count));
        latency.insert("max".into(), num(lat.max_ns));
        latency.insert("mean".into(), Json::Num(lat.mean_ns()));
        latency.insert("p50".into(), num(lat.quantile_ns(0.50)));
        latency.insert("p95".into(), num(lat.quantile_ns(0.95)));
        latency.insert("p99".into(), num(lat.quantile_ns(0.99)));

        let mut queue = BTreeMap::new();
        queue.insert("admitted".into(), num(tel.admitted.get()));
        queue.insert("depth".into(), num(tel.queue_depth.get()));
        queue.insert("high_water".into(), num(tel.queue_high_water.get()));
        queue.insert("offered".into(), num(tel.offered.get()));
        queue.insert("rejected".into(), num(tel.rejected.get()));

        let mut gate = BTreeMap::new();
        gate.insert("hit_rate".into(), Json::Num(tel.gate_hit_rate()));
        gate.insert("tiles_clean".into(), num(tel.gate_tiles_clean.get()));
        gate.insert("tiles_dirty".into(), num(tel.gate_tiles_dirty.get()));

        let mut overload = BTreeMap::new();
        overload.insert("policy".into(), Json::Str(self.policy.clone()));
        overload.insert("shed_degraded".into(), num(tel.shed_degraded.get()));
        overload.insert("shed_rejected".into(), num(tel.shed_rejected.get()));

        let stages: BTreeMap<String, Json> = tel
            .stage_tallies()
            .into_iter()
            .map(|(name, t)| {
                let mut m = BTreeMap::new();
                m.insert("cpu_ns".into(), num(t.cpu_ns));
                m.insert("runs".into(), num(t.runs));
                m.insert("wall_ns".into(), num(t.wall_ns));
                (name, Json::Obj(m))
            })
            .collect();

        let tier_health = Health::worst(states);
        if self.tracker.observe(inputs.t_ns, tel.tier, tier_health) {
            tel.alerts.inc();
        }

        let mut line = BTreeMap::new();
        line.insert("alerts".into(), num(tel.alerts.get()));
        line.insert("cache".into(), inputs.cache.to_json());
        line.insert("exemplars".into(), Json::Obj(exemplars));
        line.insert("gate".into(), Json::Obj(gate));
        line.insert("health".into(), Json::Str(tier_health.name().into()));
        line.insert("lanes".into(), Json::Arr(lanes));
        line.insert("latency_ns".into(), Json::Obj(latency));
        line.insert("overload".into(), Json::Obj(overload));
        line.insert("queue".into(), Json::Obj(queue));
        line.insert("seq".into(), num(self.seq));
        line.insert("slo".into(), inputs.slo.clone());
        line.insert("stages".into(), Json::Obj(stages));
        line.insert("t_ns".into(), num(inputs.t_ns));
        line.insert("tier".into(), Json::Str(tel.tier.into()));
        if let Some(util) = &inputs.utilization {
            line.insert("utilization".into(), util.clone());
        }
        Json::Obj(line)
    }

    /// Build one snapshot line without writing it to the JSONL sink —
    /// how a cluster worker renders its telemetry state into
    /// `telemetry` and `worker_report` frame bodies (the snapshot
    /// stream crossing the process boundary). Runs the same alert
    /// evaluation and endpoint publish as [`SnapshotEngine::emit`],
    /// and advances `seq` the same way, so shipped worker lines carry
    /// a meaningful dense sequence number.
    pub fn render_line(&mut self, inputs: &TickInputs) -> Json {
        let line = self.build_line(inputs);
        self.scan_anomalies(&line, inputs.telemetry);
        if let Some(ep) = &self.endpoint {
            ep.publish(&line.dump());
            if let Some(alert) = self.tracker.last_line() {
                ep.publish_alert(alert);
            }
        }
        self.seq += 1;
        line
    }
}

/// Keys every telemetry line carries (the CI schema check asserts
/// these; `utilization` is additionally present under wall clocks).
pub const REQUIRED_LINE_KEYS: [&str; 14] = [
    "alerts",
    "cache",
    "exemplars",
    "gate",
    "health",
    "lanes",
    "latency_ns",
    "overload",
    "queue",
    "seq",
    "slo",
    "stages",
    "t_ns",
    "tier",
];

/// Callback supplying the rolling-SLO section and its missed flag at
/// sample time (a lock around [`crate::service::slo::SloWindow`] on the
/// serve side).
pub type SloProbe = Box<dyn Fn() -> (Json, bool) + Send>;
/// Callback snapshotting the shared artifact cache at sample time.
pub type CacheProbe = Box<dyn Fn() -> CacheSnapshot + Send>;
/// Callback reading the run's clock (wall ns since the run started).
pub type ClockProbe = Box<dyn Fn() -> u64 + Send>;

/// The wall-clock sampler thread: emits a telemetry line every
/// interval (plus one final line at shutdown, so even a short run logs
/// its end state), sampling per-core busy flags from the lanes' worker
/// pools into the per-tick `utilization` section and into a
/// [`UsageTrace`].
#[derive(Debug)]
pub struct WallSnapshotter {
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<Result<(SnapshotEngine, Vec<UsageSample>)>>>,
    /// The engine when no thread was spawned (telemetry disabled).
    inert: Option<SnapshotEngine>,
    period_ns: u64,
    cores: usize,
}

impl WallSnapshotter {
    /// Spawn the sampler (or return an inert handle when the engine is
    /// disabled). `pools` are the lanes' worker pools — their
    /// concatenated busy flags form the utilization sample.
    #[allow(clippy::too_many_arguments)]
    pub fn start(
        engine: SnapshotEngine,
        telemetry: Arc<Telemetry>,
        pools: Vec<PoolStats>,
        now_fn: ClockProbe,
        cache_fn: CacheProbe,
        slo_fn: SloProbe,
        shedding_possible: bool,
    ) -> WallSnapshotter {
        let period_ns = engine.interval_ns();
        let cores: usize = pools.iter().map(|p| p.n_workers()).sum();
        // Spawn when any output is live: the JSONL sink, alert
        // evaluation, the `--obs-port` endpoint, or anomaly detection
        // (each works with no `--telemetry-log`).
        if !engine.enabled()
            && !engine.alerts_active()
            && !engine.endpoint_active()
            && !engine.anomaly_active()
        {
            return WallSnapshotter {
                stop: Arc::new(AtomicBool::new(true)),
                handle: None,
                inert: Some(engine),
                period_ns,
                cores,
            };
        }
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let handle = std::thread::Builder::new()
            .name("canny-telemetry".into())
            .spawn(move || {
                let mut engine = engine;
                let mut samples = Vec::new();
                loop {
                    let stopping = stop2.load(Ordering::Acquire);
                    let t_ns = now_fn();
                    let busy: Vec<bool> = pools
                        .iter()
                        .flat_map(|p| p.snapshot().into_iter().map(|w| w.busy))
                        .collect();
                    let utilization = usage_json(&busy);
                    samples.push(UsageSample { t_ns, busy });
                    let (slo, slo_missed) = slo_fn();
                    engine.emit(TickInputs {
                        t_ns,
                        telemetry: &telemetry,
                        cache: cache_fn(),
                        slo,
                        slo_missed,
                        shedding_possible,
                        utilization: Some(utilization),
                    })?;
                    if stopping {
                        return Ok((engine, samples));
                    }
                    std::thread::sleep(Duration::from_nanos(period_ns));
                }
            })
            .expect("spawn telemetry snapshotter");
        WallSnapshotter { stop, handle: Some(handle), inert: None, period_ns, cores }
    }

    /// Stop the sampler (after its final line) and collect the engine
    /// plus the per-core usage trace it accumulated.
    pub fn finish(mut self, label: &str) -> Result<(SnapshotEngine, UsageTrace)> {
        self.stop.store(true, Ordering::Release);
        let had_thread = self.handle.is_some();
        let (engine, samples) = match self.handle.take() {
            Some(h) => h.join().expect("telemetry snapshotter panicked")?,
            None => (self.inert.take().expect("inert engine present"), Vec::new()),
        };
        let trace = UsageTrace {
            cores: self.cores,
            period_ns: if !had_thread || self.period_ns == u64::MAX { 0 } else { self.period_ns },
            samples,
            label: label.into(),
        };
        Ok((engine, trace))
    }
}

impl Drop for WallSnapshotter {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// The per-tick `utilization` section from one busy-flag sample.
fn usage_json(busy: &[bool]) -> Json {
    let n = busy.iter().filter(|&&b| b).count();
    let mut m = BTreeMap::new();
    m.insert("busy".into(), Json::Num(n as f64));
    m.insert("cores".into(), Json::Num(busy.len() as f64));
    m.insert(
        "pct".into(),
        Json::Num(if busy.is_empty() { 0.0 } else { 100.0 * n as f64 / busy.len() as f64 }),
    );
    m.insert(
        "per_core".into(),
        Json::Arr(busy.iter().map(|&b| Json::Num(if b { 1.0 } else { 0.0 })).collect()),
    );
    Json::Obj(m)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn slo_stub(status: &str) -> Json {
        let mut m = BTreeMap::new();
        m.insert("status".into(), Json::Str(status.into()));
        Json::Obj(m)
    }

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("canny_obs_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(format!("{}_{name}", std::process::id()))
    }

    #[test]
    fn disabled_engine_is_inert() {
        let mut e = SnapshotEngine::disabled();
        assert!(!e.enabled());
        assert_eq!(e.next_tick_ns(), u64::MAX);
        assert_eq!(e.take_tick(u64::MAX - 1), None);
        let tel = Telemetry::new("serve", 1);
        e.emit(TickInputs {
            t_ns: 5,
            telemetry: &tel,
            cache: CacheSnapshot::default(),
            slo: slo_stub("no-data"),
            slo_missed: false,
            shedding_possible: false,
            utilization: None,
        })
        .unwrap();
        assert_eq!(e.close().unwrap(), 0);
    }

    #[test]
    fn tick_schedule_is_a_grid() {
        let path = tmp("grid.jsonl");
        let mut e = SnapshotEngine::create(&path, 100, "none").unwrap();
        assert_eq!(e.next_tick_ns(), 100);
        assert_eq!(e.take_tick(99), None);
        assert_eq!(e.take_tick(100), Some(100));
        assert_eq!(e.take_tick(350), Some(200));
        assert_eq!(e.take_tick(350), Some(300));
        assert_eq!(e.take_tick(350), None);
        assert_eq!(e.next_tick_ns(), 400);
        assert!(SnapshotEngine::create(&path, 0, "none").is_err());
    }

    #[test]
    fn lines_carry_required_keys_and_are_deterministic() {
        let write = |path: &PathBuf| {
            let mut e = SnapshotEngine::create(path, 100, "reject-new").unwrap();
            let tel = Telemetry::new("serve", 2);
            tel.offered.add(5);
            tel.admitted.add(4);
            tel.rejected.inc();
            tel.completed.add(3);
            tel.queue_depth.set(1);
            tel.queue_high_water.raise(2);
            tel.lane(0).completed.add(3);
            tel.lane(0).heartbeat_ns.set(90);
            tel.latency.record(1000);
            tel.latency.record(3000);
            tel.note_stage("gaussian", 0, 0);
            for t in [100u64, 200] {
                e.emit(TickInputs {
                    t_ns: t,
                    telemetry: &tel,
                    cache: CacheSnapshot::default(),
                    slo: slo_stub("met"),
                    slo_missed: false,
                    shedding_possible: true,
                    utilization: None,
                })
                .unwrap();
            }
            e.close().unwrap();
            std::fs::read_to_string(path).unwrap()
        };
        let a = write(&tmp("det_a.jsonl"));
        let b = write(&tmp("det_b.jsonl"));
        assert_eq!(a, b, "identical inputs must produce identical bytes");
        let lines: Vec<&str> = a.lines().collect();
        assert_eq!(lines.len(), 2);
        for (i, line) in lines.iter().enumerate() {
            let j = Json::parse(line).unwrap();
            for key in REQUIRED_LINE_KEYS {
                assert!(j.get(key).is_some(), "line {i} missing `{key}`");
            }
            assert_eq!(j.get("seq").unwrap().as_usize(), Some(i));
            assert_eq!(j.get("tier").unwrap().as_str(), Some("serve"));
            assert_eq!(j.get("health").unwrap().as_str(), Some("healthy"));
            assert_eq!(
                j.get("overload").unwrap().get("policy").unwrap().as_str(),
                Some("reject-new")
            );
            let lanes = j.get("lanes").unwrap().as_arr().unwrap();
            assert_eq!(lanes.len(), 2);
            assert_eq!(lanes[0].get("completed").unwrap().as_usize(), Some(3));
            assert_eq!(j.get("stages").unwrap().get("gaussian").unwrap().get("runs"), Some(&Json::Num(1.0)));
        }
    }

    #[test]
    fn shedding_and_stalls_reach_health() {
        let path = tmp("health.jsonl");
        let mut e = SnapshotEngine::create(&path, 10, "degrade-to-front-only").unwrap();
        let tel = Telemetry::new("serve", 1);
        // Missed SLO + active policy: degraded.
        e.emit(TickInputs {
            t_ns: 10,
            telemetry: &tel,
            cache: CacheSnapshot::default(),
            slo: slo_stub("missed"),
            slo_missed: true,
            shedding_possible: true,
            utilization: None,
        })
        .unwrap();
        // Stalled lane outranks: in-flight work, ancient heartbeat.
        tel.lane(0).inflight.set(1);
        tel.lane(0).heartbeat_ns.set(0);
        e.emit(TickInputs {
            t_ns: DEFAULT_STALL_AFTER_NS + 20,
            telemetry: &tel,
            cache: CacheSnapshot::default(),
            slo: slo_stub("missed"),
            slo_missed: true,
            shedding_possible: true,
            utilization: None,
        })
        .unwrap();
        e.close().unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<Json> = text.lines().map(|l| Json::parse(l).unwrap()).collect();
        assert_eq!(lines[0].get("health").unwrap().as_str(), Some("degraded"));
        assert_eq!(lines[1].get("health").unwrap().as_str(), Some("stalled"));
        assert_eq!(
            lines[1].get("lanes").unwrap().as_arr().unwrap()[0].get("health").unwrap().as_str(),
            Some("stalled")
        );
    }

    #[test]
    fn alerts_fire_without_a_telemetry_log() {
        use crate::obs::health::HealthTracker;
        let alert_path = tmp("alerts_only.log");
        let mut e = SnapshotEngine::from_options(None, 100, "degrade-to-front-only")
            .unwrap()
            .with_alerts(HealthTracker::to_file(&alert_path).unwrap());
        assert!(!e.enabled());
        assert!(e.alerts_active());
        // The tick grid stays live for alert evaluation.
        assert_eq!(e.next_tick_ns(), 100);
        assert_eq!(e.take_tick(100), Some(100));
        let tel = Telemetry::new("serve", 1);
        let degraded = |t_ns| TickInputs {
            t_ns,
            telemetry: &tel,
            cache: CacheSnapshot::default(),
            slo: slo_stub("missed"),
            slo_missed: true,
            shedding_possible: true,
            utilization: None,
        };
        e.emit(degraded(100)).unwrap();
        e.emit(degraded(200)).unwrap();
        // Lane + tier each transitioned healthy→degraded exactly once,
        // counted into the registry; no JSONL line was written.
        assert_eq!(e.alerts_emitted(), 2);
        assert_eq!(tel.alerts.get(), 2);
        assert_eq!(e.lines(), 0);
        let text = std::fs::read_to_string(&alert_path).unwrap();
        assert_eq!(text.lines().count(), 2);
        assert!(text.contains("scope=serve/lane0 from=healthy to=degraded"));
        assert!(text.contains("ALERT t_ns=100 scope=serve from=healthy to=degraded"));
    }

    #[test]
    fn alert_count_rides_the_snapshot_line() {
        use crate::obs::health::HealthTracker;
        let log = tmp("alerts_on_line.jsonl");
        let alert_path = tmp("alerts_on_line.log");
        let mut e = SnapshotEngine::create(&log, 10, "reject-new")
            .unwrap()
            .with_alerts(HealthTracker::to_file(&alert_path).unwrap());
        let tel = Telemetry::new("serve", 1);
        e.emit(TickInputs {
            t_ns: 10,
            telemetry: &tel,
            cache: CacheSnapshot::default(),
            slo: slo_stub("missed"),
            slo_missed: true,
            shedding_possible: true,
            utilization: None,
        })
        .unwrap();
        e.close().unwrap();
        let text = std::fs::read_to_string(&log).unwrap();
        let j = Json::parse(text.lines().next().unwrap()).unwrap();
        // lane0 and the tier both transitioned on this tick.
        assert_eq!(j.get("alerts").unwrap().as_usize(), Some(2));
        assert_eq!(j.get("health").unwrap().as_str(), Some("degraded"));
    }

    #[test]
    fn exemplars_ride_the_line() {
        let path = tmp("exemplars.jsonl");
        let mut e = SnapshotEngine::create(&path, 100, "none").unwrap();
        let tel = Telemetry::new("serve", 1);
        tel.latency.record(1000);
        tel.latency.note_exemplar(1000, "00000000000000010000000a");
        e.emit(TickInputs {
            t_ns: 100,
            telemetry: &tel,
            cache: CacheSnapshot::default(),
            slo: slo_stub("met"),
            slo_missed: false,
            shedding_possible: false,
            utilization: None,
        })
        .unwrap();
        e.close().unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let j = Json::parse(text.lines().next().unwrap()).unwrap();
        let buckets = j.get("exemplars").unwrap().get("latency").unwrap().as_obj().unwrap();
        assert_eq!(buckets.len(), 1);
        let (hi, ex) = buckets.iter().next().unwrap();
        assert_eq!(hi, "1023");
        assert_eq!(ex.get("trace").unwrap().as_str(), Some("00000000000000010000000a"));
        assert_eq!(ex.get("value_ns").unwrap().as_usize(), Some(1000));
    }

    #[test]
    fn anomaly_monitor_keeps_ticks_live_and_raises_through_the_engine() {
        use crate::obs::anomaly::AnomalyMonitor;
        let alert_path = tmp("anomaly_engine.log");
        let mut e = SnapshotEngine::from_options(None, 100, "none")
            .unwrap()
            .with_alerts(HealthTracker::to_file(&alert_path).unwrap())
            .with_anomaly(AnomalyMonitor::from_sigma(3.0));
        assert!(e.anomaly_active());
        assert_eq!(e.next_tick_ns(), 100, "a monitor keeps the tick grid live");
        let tel = Telemetry::new("serve", 1);
        let inputs = |t_ns| TickInputs {
            t_ns,
            telemetry: &tel,
            cache: CacheSnapshot::default(),
            slo: slo_stub("met"),
            slo_missed: false,
            shedding_possible: false,
            utilization: None,
        };
        // Warm the queue-depth detector flat, then spike it.
        for t in 1..=10u64 {
            e.emit(inputs(t * 100)).unwrap();
        }
        tel.queue_depth.set(10_000);
        e.emit(inputs(1100)).unwrap();
        let text = std::fs::read_to_string(&alert_path).unwrap();
        assert!(
            text.contains("scope=anomaly:queue_depth"),
            "expected an anomaly alert, got: {text:?}"
        );
        assert!(text.contains("exemplar=none"), "no traces sampled -> no exemplar: {text:?}");
        // The raised alert is counted into the registry for the next line.
        assert!(tel.alerts.get() >= 1);
    }

    #[test]
    fn usage_section_shape() {
        let j = usage_json(&[true, false, true, true]);
        assert_eq!(j.get("cores").unwrap().as_usize(), Some(4));
        assert_eq!(j.get("busy").unwrap().as_usize(), Some(3));
        assert_eq!(j.get("pct").unwrap().as_f64(), Some(75.0));
        assert_eq!(j.get("per_core").unwrap().as_arr().unwrap().len(), 4);
        assert_eq!(usage_json(&[]).get("pct").unwrap().as_f64(), Some(0.0));
    }
}
