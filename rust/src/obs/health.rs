//! Liveness and health derivation: each lane (and the tier as a whole)
//! is classified `healthy | degraded | stalled` from the heartbeat
//! gauges the workers publish ([`crate::obs::registry::LaneTelemetry`])
//! — the live equivalent of eyeballing a profiler timeline for a stuck
//! worker.

/// How long a lane may hold in-flight work without a heartbeat
/// (dispatch or completion) before it is reported stalled. Compared in
/// the driver's own clock domain — modeled nanoseconds under the
/// virtual clock, monotonic nanoseconds under wall — so the derivation
/// stays deterministic in replays.
pub const DEFAULT_STALL_AFTER_NS: u64 = 1_000_000_000;

/// A lane's (or the tier's) operational state.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Health {
    /// Alive and serving at full fidelity.
    Healthy,
    /// Alive, but the overload policy is actively shedding or degrading
    /// work (the tier's rolling SLO is missed).
    Degraded,
    /// Holding in-flight work with no heartbeat for longer than the
    /// stall threshold.
    Stalled,
}

impl Health {
    /// Snapshot/report string.
    pub fn name(&self) -> &'static str {
        match self {
            Health::Healthy => "healthy",
            Health::Degraded => "degraded",
            Health::Stalled => "stalled",
        }
    }

    /// Classify one lane. `shedding` is the tier-wide overload signal
    /// (rolling SLO missed under an active policy): a silent-but-busy
    /// lane is stalled regardless, an idle lane is never stalled (no
    /// work, no heartbeat expected).
    pub fn derive(
        now_ns: u64,
        heartbeat_ns: u64,
        inflight: u64,
        stall_after_ns: u64,
        shedding: bool,
    ) -> Health {
        if inflight > 0 && now_ns.saturating_sub(heartbeat_ns) > stall_after_ns {
            Health::Stalled
        } else if shedding {
            Health::Degraded
        } else {
            Health::Healthy
        }
    }

    /// The tier is as bad as its worst lane.
    pub fn worst(states: impl IntoIterator<Item = Health>) -> Health {
        let mut worst = Health::Healthy;
        for h in states {
            worst = match (worst, h) {
                (_, Health::Stalled) | (Health::Stalled, _) => Health::Stalled,
                (_, Health::Degraded) | (Health::Degraded, _) => Health::Degraded,
                _ => Health::Healthy,
            };
        }
        worst
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names() {
        assert_eq!(Health::Healthy.name(), "healthy");
        assert_eq!(Health::Degraded.name(), "degraded");
        assert_eq!(Health::Stalled.name(), "stalled");
    }

    #[test]
    fn derivation_matrix() {
        let stall = DEFAULT_STALL_AFTER_NS;
        // Fresh heartbeat, no shedding.
        assert_eq!(Health::derive(100, 90, 1, stall, false), Health::Healthy);
        // In-flight work, heartbeat too old.
        assert_eq!(Health::derive(stall + 200, 100, 1, stall, false), Health::Stalled);
        // Same silence but idle: not stalled.
        assert_eq!(Health::derive(stall + 200, 100, 0, stall, false), Health::Healthy);
        // Shedding marks a live lane degraded...
        assert_eq!(Health::derive(100, 90, 1, stall, true), Health::Degraded);
        // ...but a stall outranks it.
        assert_eq!(Health::derive(stall + 200, 100, 1, stall, true), Health::Stalled);
        // Clock going backwards (wall resets) never underflows.
        assert_eq!(Health::derive(50, 100, 1, stall, false), Health::Healthy);
    }

    #[test]
    fn worst_ranks() {
        use Health::*;
        assert_eq!(Health::worst([Healthy, Healthy]), Healthy);
        assert_eq!(Health::worst([Healthy, Degraded]), Degraded);
        assert_eq!(Health::worst([Degraded, Stalled, Healthy]), Stalled);
        assert_eq!(Health::worst([]), Healthy);
    }
}
