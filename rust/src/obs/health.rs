//! Liveness and health derivation: each lane (and the tier as a whole)
//! is classified `healthy | degraded | stalled` from the heartbeat
//! gauges the workers publish ([`crate::obs::registry::LaneTelemetry`])
//! — the live equivalent of eyeballing a profiler timeline for a stuck
//! worker.
//!
//! [`HealthTracker`] is the alerting hook on top: it remembers the
//! last state per scope (a lane, a tier, a cluster worker) and emits
//! one timestamped transition line per state change to an
//! [`AlertSink`] (`--alert-log stderr|FILE`), counted into the
//! telemetry registry's `alerts` counter.

use std::collections::BTreeMap;
use std::fs::File;
use std::io::Write;
use std::path::Path;

use crate::error::{Error, Result};

/// How long a lane may hold in-flight work without a heartbeat
/// (dispatch or completion) before it is reported stalled. Compared in
/// the driver's own clock domain — modeled nanoseconds under the
/// virtual clock, monotonic nanoseconds under wall — so the derivation
/// stays deterministic in replays.
pub const DEFAULT_STALL_AFTER_NS: u64 = 1_000_000_000;

/// A lane's (or the tier's) operational state.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Health {
    /// Alive and serving at full fidelity.
    Healthy,
    /// Alive, but the overload policy is actively shedding or degrading
    /// work (the tier's rolling SLO is missed).
    Degraded,
    /// Holding in-flight work with no heartbeat for longer than the
    /// stall threshold.
    Stalled,
}

impl Health {
    /// Snapshot/report string.
    pub fn name(&self) -> &'static str {
        match self {
            Health::Healthy => "healthy",
            Health::Degraded => "degraded",
            Health::Stalled => "stalled",
        }
    }

    /// Classify one lane. `shedding` is the tier-wide overload signal
    /// (rolling SLO missed under an active policy): a silent-but-busy
    /// lane is stalled regardless, an idle lane is never stalled (no
    /// work, no heartbeat expected).
    pub fn derive(
        now_ns: u64,
        heartbeat_ns: u64,
        inflight: u64,
        stall_after_ns: u64,
        shedding: bool,
    ) -> Health {
        if inflight > 0 && now_ns.saturating_sub(heartbeat_ns) > stall_after_ns {
            Health::Stalled
        } else if shedding {
            Health::Degraded
        } else {
            Health::Healthy
        }
    }

    /// The tier is as bad as its worst lane.
    pub fn worst(states: impl IntoIterator<Item = Health>) -> Health {
        let mut worst = Health::Healthy;
        for h in states {
            worst = match (worst, h) {
                (_, Health::Stalled) | (Health::Stalled, _) => Health::Stalled,
                (_, Health::Degraded) | (Health::Degraded, _) => Health::Degraded,
                _ => Health::Healthy,
            };
        }
        worst
    }
}

/// Where health-transition alert lines go.
#[derive(Debug)]
pub enum AlertSink {
    /// No alerting (the default — transitions are tracked nowhere).
    Off,
    /// `eprintln!` — rides whatever stderr the process inherited, which
    /// is how cluster workers' alerts surface in the front-door's
    /// stderr.
    Stderr,
    /// An append-only alert log opened by `--alert-log FILE`.
    File(File),
}

/// Tracks the last observed [`Health`] per scope and emits one line
/// per transition:
///
/// ```text
/// ALERT t_ns=1200000000 scope=serve/lane1 from=healthy to=stalled
/// ```
///
/// `t_ns` is whatever clock domain the caller observes in (modeled ns
/// under the virtual clock — byte-identical across replays — and
/// monotonic ns under wall), so the tracker itself never reads a
/// clock. The first observation of a scope is diffed against an
/// implicit `healthy` baseline: a tier that comes up healthy emits
/// nothing, a worker first seen dead alerts immediately.
#[derive(Debug)]
pub struct HealthTracker {
    sink: AlertSink,
    last: BTreeMap<String, Health>,
    emitted: u64,
    /// The newest alert line emitted through this tracker (health
    /// transition or raised anomaly) — what the `--obs-port` endpoint
    /// serves as its second line.
    last_line: Option<String>,
}

impl HealthTracker {
    fn with_sink(sink: AlertSink) -> HealthTracker {
        HealthTracker { sink, last: BTreeMap::new(), emitted: 0, last_line: None }
    }

    /// The inert tracker: `observe` updates no state, emits nothing.
    pub fn off() -> HealthTracker {
        HealthTracker::with_sink(AlertSink::Off)
    }

    pub fn stderr() -> HealthTracker {
        HealthTracker::with_sink(AlertSink::Stderr)
    }

    /// Open (truncating) an alert log — a run's alerts are
    /// self-contained, like the telemetry JSONL.
    pub fn to_file(path: &Path) -> Result<HealthTracker> {
        let file = File::create(path)
            .map_err(|e| Error::Config(format!("alert log {}: {e}", path.display())))?;
        Ok(HealthTracker::with_sink(AlertSink::File(file)))
    }

    /// Open an alert log for *appending* — for a second emitter joining
    /// a log another tracker already owns (the cluster front door's
    /// post-run anomaly fold appends after the supervisor's
    /// restart/health alerts without truncating them away).
    pub fn to_file_append(path: &Path) -> Result<HealthTracker> {
        let file = File::options()
            .create(true)
            .append(true)
            .open(path)
            .map_err(|e| Error::Config(format!("alert log {}: {e}", path.display())))?;
        Ok(HealthTracker::with_sink(AlertSink::File(file)))
    }

    /// Resolve the `--alert-log` spec: empty disables, the literal
    /// `stderr` streams to stderr, anything else is a file path.
    pub fn from_spec(spec: &str) -> Result<HealthTracker> {
        match spec {
            "" => Ok(HealthTracker::off()),
            "stderr" => Ok(HealthTracker::stderr()),
            path => HealthTracker::to_file(Path::new(path)),
        }
    }

    /// Like [`HealthTracker::from_spec`], but file sinks open in append
    /// mode.
    pub fn from_spec_append(spec: &str) -> Result<HealthTracker> {
        match spec {
            "" => Ok(HealthTracker::off()),
            "stderr" => Ok(HealthTracker::stderr()),
            path => HealthTracker::to_file_append(Path::new(path)),
        }
    }

    /// Is any sink attached? (Inert trackers skip all bookkeeping.)
    pub fn active(&self) -> bool {
        !matches!(self.sink, AlertSink::Off)
    }

    /// Transition lines emitted so far.
    pub fn emitted(&self) -> u64 {
        self.emitted
    }

    /// The newest alert line (health transition or raised anomaly),
    /// whatever sink it went to. `None` until something alerted.
    pub fn last_line(&self) -> Option<&str> {
        self.last_line.as_deref()
    }

    /// Record `scope`'s state at `t_ns`; emit and count a line when it
    /// changed. Returns whether a line was emitted. Sink write errors
    /// are swallowed — alerting is best-effort and must never take the
    /// serving path down with it.
    pub fn observe(&mut self, t_ns: u64, scope: &str, health: Health) -> bool {
        if !self.active() {
            return false;
        }
        let from = self.last.insert(scope.to_string(), health).unwrap_or(Health::Healthy);
        if from == health {
            return false;
        }
        let line = format!(
            "ALERT t_ns={t_ns} scope={scope} from={} to={}",
            from.name(),
            health.name()
        );
        self.write_line(line);
        true
    }

    /// Emit a pre-rendered alert line (the anomaly monitor's
    /// `scope=anomaly:*` lines arrive here already formatted). Unlike
    /// [`HealthTracker::observe`], this works even with no sink
    /// attached: the line is still remembered as
    /// [`HealthTracker::last_line`] and counted, so `--anomaly-sigma`
    /// alerts reach the `--obs-port` endpoint without requiring
    /// `--alert-log`.
    pub fn raise(&mut self, line: String) {
        self.write_line(line);
    }

    fn write_line(&mut self, line: String) {
        match &mut self.sink {
            AlertSink::Off => {}
            AlertSink::Stderr => eprintln!("{line}"),
            AlertSink::File(f) => {
                let _ = writeln!(f, "{line}");
                let _ = f.flush();
            }
        }
        self.last_line = Some(line);
        self.emitted += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names() {
        assert_eq!(Health::Healthy.name(), "healthy");
        assert_eq!(Health::Degraded.name(), "degraded");
        assert_eq!(Health::Stalled.name(), "stalled");
    }

    #[test]
    fn derivation_matrix() {
        let stall = DEFAULT_STALL_AFTER_NS;
        // Fresh heartbeat, no shedding.
        assert_eq!(Health::derive(100, 90, 1, stall, false), Health::Healthy);
        // In-flight work, heartbeat too old.
        assert_eq!(Health::derive(stall + 200, 100, 1, stall, false), Health::Stalled);
        // Same silence but idle: not stalled.
        assert_eq!(Health::derive(stall + 200, 100, 0, stall, false), Health::Healthy);
        // Shedding marks a live lane degraded...
        assert_eq!(Health::derive(100, 90, 1, stall, true), Health::Degraded);
        // ...but a stall outranks it.
        assert_eq!(Health::derive(stall + 200, 100, 1, stall, true), Health::Stalled);
        // Clock going backwards (wall resets) never underflows.
        assert_eq!(Health::derive(50, 100, 1, stall, false), Health::Healthy);
    }

    #[test]
    fn worst_ranks() {
        use Health::*;
        assert_eq!(Health::worst([Healthy, Healthy]), Healthy);
        assert_eq!(Health::worst([Healthy, Degraded]), Degraded);
        assert_eq!(Health::worst([Degraded, Stalled, Healthy]), Stalled);
        assert_eq!(Health::worst([]), Healthy);
    }

    #[test]
    fn inert_tracker_never_emits() {
        let mut t = HealthTracker::off();
        assert!(!t.active());
        assert!(!t.observe(10, "serve", Health::Stalled));
        assert!(!t.observe(20, "serve", Health::Healthy));
        assert_eq!(t.emitted(), 0);
    }

    #[test]
    fn file_tracker_emits_one_line_per_transition() {
        let dir = std::env::temp_dir().join("canny_obs_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("{}_alerts.log", std::process::id()));
        let mut t = HealthTracker::to_file(&path).unwrap();
        assert!(t.active());
        // First-seen healthy: matches the implicit baseline, no line.
        assert!(!t.observe(100, "serve", Health::Healthy));
        // Transition, repeat (held state), recovery, independent scope.
        assert!(t.observe(200, "serve", Health::Degraded));
        assert!(!t.observe(300, "serve", Health::Degraded));
        assert!(t.observe(400, "serve", Health::Healthy));
        assert!(t.observe(500, "cluster/worker1", Health::Stalled));
        assert_eq!(t.emitted(), 3);
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(
            lines,
            vec![
                "ALERT t_ns=200 scope=serve from=healthy to=degraded",
                "ALERT t_ns=400 scope=serve from=degraded to=healthy",
                "ALERT t_ns=500 scope=cluster/worker1 from=healthy to=stalled",
            ]
        );
    }

    #[test]
    fn spec_resolution() {
        assert!(!HealthTracker::from_spec("").unwrap().active());
        assert!(HealthTracker::from_spec("stderr").unwrap().active());
        assert!(matches!(HealthTracker::from_spec("stderr").unwrap().sink, AlertSink::Stderr));
        assert!(!HealthTracker::from_spec_append("").unwrap().active());
    }

    #[test]
    fn raised_lines_are_remembered_even_without_a_sink() {
        let mut t = HealthTracker::off();
        assert_eq!(t.last_line(), None);
        t.raise("ALERT t_ns=7 scope=anomaly:queue_depth z=5.00".to_string());
        assert_eq!(t.last_line(), Some("ALERT t_ns=7 scope=anomaly:queue_depth z=5.00"));
        assert_eq!(t.emitted(), 1);
    }

    #[test]
    fn append_sink_joins_an_existing_log() {
        let dir = std::env::temp_dir().join("canny_obs_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("{}_alerts_append.log", std::process::id()));
        let mut first = HealthTracker::to_file(&path).unwrap();
        assert!(first.observe(100, "serve", Health::Degraded));
        assert_eq!(first.last_line(), Some("ALERT t_ns=100 scope=serve from=healthy to=degraded"));
        drop(first);
        let mut second = HealthTracker::to_file_append(&path).unwrap();
        second.raise("ALERT t_ns=200 scope=anomaly:latency_mean z=4.10".to_string());
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2, "append must not truncate: {lines:?}");
        assert!(lines[0].contains("to=degraded"));
        assert!(lines[1].contains("anomaly:latency_mean"));
    }
}
