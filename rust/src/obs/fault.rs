//! The fault manager: explicit overload policies for the serving tier,
//! generalizing the stream tier's deadline `--drop-policy` to
//! SLO-driven admission control. When the rolling SLO window
//! ([`crate::service::slo::SloWindow`]) reports `missed`, every new
//! arrival passes through [`FaultManager::decide`] and is admitted,
//! rejected, or degraded per the configured [`OverloadPolicy`] — and
//! every shed decision is counted in the telemetry registry
//! ([`crate::obs::registry::Telemetry`]) so it is visible both live
//! (JSONL ticks) and in the final report.

use crate::error::{Error, Result};

/// What to do with new arrivals while the rolling SLO is missed.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum OverloadPolicy {
    /// Never shed: admit everything, let the queue's own backpressure
    /// (and the report's `missed` status) tell the story. This is the
    /// default, and it leaves a run byte-identical to one built before
    /// the ops plane existed.
    #[default]
    None,
    /// Reject new arrivals outright (counted as `rejected_shed` —
    /// conservation still holds: offered = completed + rejected).
    RejectNew,
    /// Rewrite `full` arrivals to `front-only` — the client gets the
    /// Gaussian→Sobel→NMS front (which also warms the shared artifact
    /// cache) at a fraction of the cost; partial-pipeline kinds pass
    /// through untouched, they are already cheap.
    DegradeFront,
}

impl OverloadPolicy {
    /// Config/report string.
    pub fn name(&self) -> &'static str {
        match self {
            OverloadPolicy::None => "none",
            OverloadPolicy::RejectNew => "reject-new",
            OverloadPolicy::DegradeFront => "degrade-to-front-only",
        }
    }

    /// Parse a `--overload-policy` value.
    pub fn parse(s: &str) -> Result<OverloadPolicy> {
        match s {
            "none" => Ok(OverloadPolicy::None),
            "reject-new" | "reject_new" | "reject" => Ok(OverloadPolicy::RejectNew),
            "degrade-to-front-only" | "degrade_to_front_only" | "degrade-front" | "degrade" => {
                Ok(OverloadPolicy::DegradeFront)
            }
            other => Err(Error::Config(format!(
                "unknown overload policy `{other}` (none | reject-new | degrade-to-front-only)"
            ))),
        }
    }
}

/// The verdict for one arrival.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShedDecision {
    /// Let it through unchanged.
    Admit,
    /// Turn it away before the queue.
    Reject,
    /// Admit, but rewritten to the front-only pipeline.
    Degrade,
}

/// Per-run policy engine. Stateless beyond its policy: the state it
/// reacts to is the rolling SLO status the caller reads from its
/// window, so virtual replays make identical decisions at identical
/// modeled times.
#[derive(Clone, Copy, Debug)]
pub struct FaultManager {
    policy: OverloadPolicy,
}

impl FaultManager {
    pub fn new(policy: OverloadPolicy) -> FaultManager {
        FaultManager { policy }
    }

    pub fn policy(&self) -> OverloadPolicy {
        self.policy
    }

    /// Can this manager ever shed? (Drives the `degraded` health state:
    /// a missed SLO under `none` is reported, not acted on.)
    pub fn active(&self) -> bool {
        self.policy != OverloadPolicy::None
    }

    /// Decide one arrival's fate. `slo_missed` is the rolling window's
    /// current status; `degradable` says whether the request kind has a
    /// cheaper form to fall back to (`full` does, partial pipelines do
    /// not).
    pub fn decide(&self, slo_missed: bool, degradable: bool) -> ShedDecision {
        if !slo_missed {
            return ShedDecision::Admit;
        }
        match self.policy {
            OverloadPolicy::None => ShedDecision::Admit,
            OverloadPolicy::RejectNew => ShedDecision::Reject,
            OverloadPolicy::DegradeFront => {
                if degradable {
                    ShedDecision::Degrade
                } else {
                    ShedDecision::Admit
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_name_roundtrip() {
        for p in [OverloadPolicy::None, OverloadPolicy::RejectNew, OverloadPolicy::DegradeFront] {
            assert_eq!(OverloadPolicy::parse(p.name()).unwrap(), p);
        }
        assert_eq!(OverloadPolicy::parse("reject_new").unwrap(), OverloadPolicy::RejectNew);
        assert_eq!(OverloadPolicy::parse("degrade").unwrap(), OverloadPolicy::DegradeFront);
        assert!(OverloadPolicy::parse("shrug").is_err());
        assert_eq!(OverloadPolicy::default(), OverloadPolicy::None);
    }

    #[test]
    fn decisions_follow_policy() {
        use ShedDecision::*;
        let none = FaultManager::new(OverloadPolicy::None);
        let reject = FaultManager::new(OverloadPolicy::RejectNew);
        let degrade = FaultManager::new(OverloadPolicy::DegradeFront);
        // SLO met: everyone admits.
        for m in [none, reject, degrade] {
            assert_eq!(m.decide(false, true), Admit);
            assert_eq!(m.decide(false, false), Admit);
        }
        // SLO missed.
        assert_eq!(none.decide(true, true), Admit);
        assert!(!none.active());
        assert_eq!(reject.decide(true, true), Reject);
        assert_eq!(reject.decide(true, false), Reject);
        assert!(reject.active());
        assert_eq!(degrade.decide(true, true), Degrade);
        // Nothing cheaper to fall back to: pass through.
        assert_eq!(degrade.decide(true, false), Admit);
    }
}
