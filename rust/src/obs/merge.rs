//! Cluster-wide telemetry merging: fold the per-worker snapshot lines
//! streamed over `telemetry` frames into one cluster-tier line with
//! aggregated totals plus raw per-worker sections (schema in
//! [`crate::obs`]).
//!
//! The fold is schema-driven rather than hand-written per key: numeric
//! fields sum (they are counters) unless named in [`MAX_KEYS`] (levels
//! and percentiles take the max), booleans OR, health/status strings
//! take the worst state, arrays concatenate, and objects recurse over
//! the union of their keys. That keeps the merge correct as snapshot
//! sections grow without this module needing to know about them.

use std::collections::{BTreeMap, BTreeSet};

use crate::util::json::Json;

/// Keys whose numeric values are *levels*, not totals: the merged
/// value is the max across workers instead of the sum.
const MAX_KEYS: &[&str] = &[
    "heartbeat_ns",
    "high_water",
    "hit_rate",
    "max",
    "mean",
    "p50",
    "p50_ns",
    "p95",
    "p95_ns",
    "p99",
    "p99_ns",
    "pct",
    "seq",
    "t_ns",
    "target_p99_ns",
];

/// Rank a health/SLO state string; higher is worse. Unknown states
/// rank worst so new states are never masked by the merge.
fn severity(s: &str) -> u32 {
    match s {
        "healthy" | "met" | "ok" => 0,
        "no-data" => 1,
        "degraded" => 2,
        "stalled" | "missed" => 3,
        _ => 4,
    }
}

/// Merge one field position across workers. `key` is the field's name
/// in the enclosing object (`None` at the top level), which selects
/// sum-vs-max for numbers and worst-state for strings.
fn merge_values(key: Option<&str>, vals: &[&Json]) -> Json {
    let vals: Vec<&Json> = vals.iter().copied().filter(|v| !matches!(v, Json::Null)).collect();
    let Some(first) = vals.first() else {
        return Json::Null;
    };
    match first {
        Json::Null => Json::Null,
        Json::Num(_) => {
            let nums = vals.iter().filter_map(|v| v.as_f64());
            if key.is_some_and(|k| MAX_KEYS.contains(&k)) {
                Json::Num(nums.fold(0.0, f64::max))
            } else {
                Json::Num(nums.sum())
            }
        }
        Json::Bool(_) => Json::Bool(vals.iter().any(|v| matches!(v, Json::Bool(true)))),
        Json::Str(_) => {
            if key.is_some_and(|k| k == "health" || k == "status") {
                let worst = vals
                    .iter()
                    .filter_map(|v| v.as_str())
                    .max_by_key(|s| severity(s))
                    .unwrap_or("healthy");
                Json::Str(worst.to_string())
            } else {
                (*first).clone()
            }
        }
        Json::Arr(_) => {
            let all = vals.iter().filter_map(|v| v.as_arr()).flatten().cloned().collect();
            Json::Arr(all)
        }
        Json::Obj(_) => {
            // Exemplar objects (`{trace, value_ns}`) are atomic: the
            // cluster-wide exemplar for a bucket is the single worst
            // observation, not a sum of values with an arbitrary trace.
            if vals.iter().all(|v| is_exemplar(v)) {
                let worst = vals
                    .iter()
                    .max_by(|a, b| {
                        let va = a.get("value_ns").and_then(Json::as_f64).unwrap_or(0.0);
                        let vb = b.get("value_ns").and_then(Json::as_f64).unwrap_or(0.0);
                        va.total_cmp(&vb)
                    })
                    .expect("non-empty checked above");
                return (*worst).clone();
            }
            let keys: BTreeSet<&String> =
                vals.iter().filter_map(|v| v.as_obj()).flat_map(|m| m.keys()).collect();
            let mut out = BTreeMap::new();
            for k in keys {
                let sub: Vec<&Json> = vals.iter().filter_map(|v| v.get(k)).collect();
                out.insert(k.clone(), merge_values(Some(k), &sub));
            }
            Json::Obj(out)
        }
    }
}

/// Is this object an exemplar leaf — exactly `{"trace": …,
/// "value_ns": …}`? (The shape test keys the merge rule; no other
/// snapshot object carries this exact key pair.)
fn is_exemplar(v: &Json) -> bool {
    v.as_obj().is_some_and(|m| {
        m.len() == 2 && m.contains_key("trace") && m.contains_key("value_ns")
    })
}

/// The sections a cluster line carries when no worker has reported
/// yet: every key from [`crate::obs::snapshot::REQUIRED_LINE_KEYS`]
/// that [`merged_line`] does not itself stamp, with empty/zero values.
/// Also the backfill source, so a merged line always carries the full
/// documented key set even while only some workers have reported.
pub fn zero_line() -> BTreeMap<String, Json> {
    let mut cache = BTreeMap::new();
    cache.insert("enabled".to_string(), Json::Bool(false));
    let mut slo = BTreeMap::new();
    slo.insert("status".to_string(), Json::Str("no-data".to_string()));
    let mut m = BTreeMap::new();
    m.insert("alerts".to_string(), Json::Num(0.0));
    m.insert("cache".to_string(), Json::Obj(cache));
    m.insert("exemplars".to_string(), Json::Obj(BTreeMap::new()));
    m.insert("gate".to_string(), Json::Obj(BTreeMap::new()));
    m.insert("health".to_string(), Json::Str("healthy".to_string()));
    m.insert("lanes".to_string(), Json::Arr(Vec::new()));
    m.insert("latency_ns".to_string(), Json::Obj(BTreeMap::new()));
    m.insert("overload".to_string(), Json::Obj(BTreeMap::new()));
    m.insert("queue".to_string(), Json::Obj(BTreeMap::new()));
    m.insert("slo".to_string(), Json::Obj(slo));
    m.insert("stages".to_string(), Json::Obj(BTreeMap::new()));
    m.insert("t_ns".to_string(), Json::Num(0.0));
    m
}

/// Merge the latest snapshot line from each worker (keyed by slot)
/// into one cluster-tier line: aggregated totals at the top level and
/// the raw per-worker lines under `workers`, each stamped with its
/// slot as a `worker` key. `seq` is the merged stream's own dense
/// sequence number (per-worker `seq`s stay visible in the sections).
pub fn merged_line(latest: &BTreeMap<usize, Json>, seq: u64) -> Json {
    let lines: Vec<&Json> = latest.values().collect();
    let mut m = match merge_values(None, &lines) {
        Json::Obj(m) => m,
        _ => BTreeMap::new(),
    };
    for (key, value) in zero_line() {
        m.entry(key).or_insert(value);
    }
    let workers: Vec<Json> = latest
        .iter()
        .map(|(slot, line)| {
            let mut w = match line {
                Json::Obj(o) => o.clone(),
                _ => BTreeMap::new(),
            };
            w.insert("worker".to_string(), Json::Num(*slot as f64));
            Json::Obj(w)
        })
        .collect();
    m.insert("seq".to_string(), Json::Num(seq as f64));
    m.insert("tier".to_string(), Json::Str("cluster".to_string()));
    m.insert("workers".to_string(), Json::Arr(workers));
    Json::Obj(m)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::snapshot::REQUIRED_LINE_KEYS;

    fn worker_line(seq: u64, t_ns: u64, admitted: u64, health: &str, p99: u64) -> Json {
        let text = format!(
            "{{\"alerts\": 1, \"health\": \"{health}\", \
             \"latency_ns\": {{\"count\": {admitted}, \"p99\": {p99}}}, \
             \"queue\": {{\"admitted\": {admitted}}}, \
             \"seq\": {seq}, \"t_ns\": {t_ns}, \"tier\": \"worker\"}}"
        );
        Json::parse(&text).unwrap()
    }

    #[test]
    fn counters_sum_and_levels_max() {
        let mut latest = BTreeMap::new();
        latest.insert(0, worker_line(3, 500, 10, "healthy", 900));
        latest.insert(1, worker_line(5, 700, 4, "healthy", 400));
        let line = merged_line(&latest, 2);
        assert_eq!(line.get("seq").unwrap().as_f64(), Some(2.0));
        assert_eq!(line.get("t_ns").unwrap().as_f64(), Some(700.0));
        assert_eq!(line.get("alerts").unwrap().as_f64(), Some(2.0));
        let queue = line.get("queue").unwrap();
        assert_eq!(queue.get("admitted").unwrap().as_f64(), Some(14.0));
        let lat = line.get("latency_ns").unwrap();
        assert_eq!(lat.get("count").unwrap().as_f64(), Some(14.0));
        assert_eq!(lat.get("p99").unwrap().as_f64(), Some(900.0));
        assert_eq!(line.get("tier").unwrap().as_str(), Some("cluster"));
    }

    #[test]
    fn worst_health_state_wins() {
        let mut latest = BTreeMap::new();
        latest.insert(0, worker_line(1, 10, 1, "healthy", 1));
        latest.insert(1, worker_line(1, 10, 1, "stalled", 1));
        latest.insert(2, worker_line(1, 10, 1, "degraded", 1));
        let line = merged_line(&latest, 0);
        assert_eq!(line.get("health").unwrap().as_str(), Some("stalled"));
    }

    #[test]
    fn empty_fleet_still_carries_the_documented_keys() {
        let line = merged_line(&BTreeMap::new(), 0);
        for key in REQUIRED_LINE_KEYS {
            assert!(line.get(key).is_some(), "zero-worker line missing `{key}`");
        }
        assert_eq!(line.get("workers").unwrap().as_arr().unwrap().len(), 0);
    }

    #[test]
    fn worker_sections_keep_slots_and_their_own_seq() {
        let mut latest = BTreeMap::new();
        latest.insert(0, worker_line(7, 100, 2, "healthy", 5));
        latest.insert(3, worker_line(9, 200, 2, "healthy", 5));
        let line = merged_line(&latest, 4);
        let workers = line.get("workers").unwrap().as_arr().unwrap();
        assert_eq!(workers.len(), 2);
        assert_eq!(workers[0].get("worker").unwrap().as_f64(), Some(0.0));
        assert_eq!(workers[0].get("seq").unwrap().as_f64(), Some(7.0));
        assert_eq!(workers[1].get("worker").unwrap().as_f64(), Some(3.0));
        assert_eq!(workers[1].get("seq").unwrap().as_f64(), Some(9.0));
    }

    #[test]
    fn exemplars_take_the_single_worst_observation() {
        let a = Json::parse(
            r#"{"exemplars": {"latency": {"1023": {"trace": "aa", "value_ns": 900},
                "8191": {"trace": "bb", "value_ns": 5000}}}}"#,
        )
        .unwrap();
        let b = Json::parse(
            r#"{"exemplars": {"latency": {"1023": {"trace": "cc", "value_ns": 1000}}}}"#,
        )
        .unwrap();
        let mut latest = BTreeMap::new();
        latest.insert(0, a);
        latest.insert(1, b);
        let line = merged_line(&latest, 0);
        let buckets = line.get("exemplars").unwrap().get("latency").unwrap();
        // Shared bucket: the worse observation wins wholesale — value
        // and trace travel together, never a summed value with a
        // first-seen trace.
        let shared = buckets.get("1023").unwrap();
        assert_eq!(shared.get("trace").unwrap().as_str(), Some("cc"));
        assert_eq!(shared.get("value_ns").unwrap().as_f64(), Some(1000.0));
        // A bucket only one worker reported passes through untouched.
        let solo = buckets.get("8191").unwrap();
        assert_eq!(solo.get("trace").unwrap().as_str(), Some("bb"));
    }

    #[test]
    fn merge_is_deterministic_in_report_order() {
        let a = worker_line(1, 50, 3, "degraded", 70);
        let b = worker_line(2, 60, 4, "healthy", 90);
        let forward = merge_values(None, &[&a, &b]);
        let reverse = merge_values(None, &[&b, &a]);
        assert_eq!(forward.dump(), reverse.dump());
    }
}
