//! Streaming anomaly detection over the telemetry snapshot stream
//! (`--anomaly-sigma`): EWMA mean/variance detectors watch rolling
//! series extracted from each tick line — completion-latency mean,
//! queue depth, cache and gate hit rates, per-stage mean wall — and
//! raise an alert through the run's [`crate::obs::health::AlertSink`]
//! when an observation lands more than `sigma` standard deviations
//! from the running mean. Each alert names the worst latency exemplar
//! exported on that line, so "queue depth spiked" comes with a
//! concrete trace id to pull from `--trace-log`.
//!
//! Alert line format (documented next to the health-transition format
//! in [`crate::obs`]):
//!
//! ```text
//! ALERT t_ns=<tick> scope=anomaly:<series> z=<z> value=<v> mean=<m> exemplar=<trace-id|none>
//! ```
//!
//! Determinism: detectors consume only values already on the built
//! snapshot line (`t_ns` included), never a clock — under virtual
//! replay the whole alert stream is byte-identical across runs, and
//! pallas-lint's clock-purity allowlist is unchanged. Off by default
//! (`--anomaly-sigma 0`).

use std::collections::BTreeMap;

use crate::util::json::Json;

/// Detectors stay silent for their first `WARMUP` observations — an
/// EWMA needs history before a z-score means anything.
pub const WARMUP: u64 = 8;

/// EWMA smoothing factor: ~last 6 ticks dominate, old regimes decay
/// fast enough that a recovered series stops alerting.
pub const ALPHA: f64 = 0.3;

/// One exponentially weighted mean/variance tracker.
#[derive(Clone, Copy, Debug, Default)]
pub struct EwmaDetector {
    mean: f64,
    var: f64,
    n: u64,
}

impl EwmaDetector {
    pub fn new() -> EwmaDetector {
        EwmaDetector::default()
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn samples(&self) -> u64 {
        self.n
    }

    /// Fold in one observation, returning its z-score against the
    /// state *before* the fold (`None` during warmup). The standard
    /// deviation is floored at 1% of the running mean so a flat-lined
    /// series (zero variance — the virtual clock's modeled stage walls,
    /// for instance) yields huge-but-finite z on a genuine jump and an
    /// exact 0 while it stays flat.
    pub fn observe(&mut self, x: f64) -> Option<f64> {
        let z = if self.n >= WARMUP {
            let sd = self.var.sqrt().max(self.mean.abs() * 0.01).max(1e-9);
            Some((x - self.mean) / sd)
        } else {
            None
        };
        if self.n == 0 {
            self.mean = x;
        } else {
            let d = x - self.mean;
            self.mean += ALPHA * d;
            self.var = (1.0 - ALPHA) * (self.var + ALPHA * d * d);
        }
        self.n += 1;
        z
    }
}

/// One raised anomaly, ready to be rendered as an alert line.
#[derive(Clone, Debug)]
pub struct AnomalyAlert {
    /// Tick timestamp of the offending line.
    pub t_ns: u64,
    /// Which series deviated (`latency_mean`, `queue_depth`,
    /// `gate_hit_rate`, `cache_hit_rate:<tier>`, `stage:<name>`).
    pub series: String,
    /// The offending observation.
    pub value: f64,
    /// The detector's running mean before the observation.
    pub mean: f64,
    /// How many standard deviations out it landed (signed).
    pub z: f64,
    /// Worst latency exemplar on the line, `"none"` when the line
    /// carried no exemplars (tracing off, or nothing sampled yet).
    pub exemplar: String,
}

impl AnomalyAlert {
    /// Render the alert line (fixed decimal precision keeps replays
    /// byte-identical).
    pub fn line(&self) -> String {
        format!(
            "ALERT t_ns={} scope=anomaly:{} z={:.2} value={:.2} mean={:.2} exemplar={}",
            self.t_ns, self.series, self.z, self.value, self.mean, self.exemplar
        )
    }
}

/// The per-run monitor: one [`EwmaDetector`] per telemetry series,
/// created lazily as series appear (stages show up after their first
/// run).
#[derive(Debug)]
pub struct AnomalyMonitor {
    sigma: f64,
    detectors: BTreeMap<String, EwmaDetector>,
    raised: u64,
}

impl AnomalyMonitor {
    /// `None` when `sigma <= 0` — the feature is off by default and
    /// costs nothing when off.
    pub fn from_sigma(sigma: f64) -> Option<AnomalyMonitor> {
        if sigma > 0.0 {
            Some(AnomalyMonitor { sigma, detectors: BTreeMap::new(), raised: 0 })
        } else {
            None
        }
    }

    /// Alerts raised so far.
    pub fn raised(&self) -> u64 {
        self.raised
    }

    /// Feed one built snapshot line (serve/stream tick, worker line, or
    /// a cluster merged line — they share the schema) and collect any
    /// alerts it triggers.
    pub fn observe_line(&mut self, line: &Json) -> Vec<AnomalyAlert> {
        let t_ns = line.get("t_ns").and_then(Json::as_f64).unwrap_or(0.0) as u64;
        let exemplar = worst_exemplar(line).unwrap_or_else(|| "none".to_string());
        let mut alerts = Vec::new();
        for (series, value) in extract_series(line) {
            let det = self.detectors.entry(series.clone()).or_default();
            let mean = det.mean();
            if let Some(z) = det.observe(value) {
                if z.abs() >= self.sigma {
                    alerts.push(AnomalyAlert {
                        t_ns,
                        series,
                        value,
                        mean,
                        z,
                        exemplar: exemplar.clone(),
                    });
                }
            }
        }
        self.raised += alerts.len() as u64;
        alerts
    }
}

/// Pull the watched series off a snapshot line, in deterministic
/// (sorted) order. Public because [`crate::obs::analyze`] aggregates
/// the exact same series offline — the alert a run raised and the
/// aggregate the report shows must name the same thing.
pub fn extract_series(line: &Json) -> Vec<(String, f64)> {
    let mut out = Vec::new();
    if let Some(mean) = line.get("latency_ns").and_then(|l| l.get("mean")).and_then(Json::as_f64)
    {
        out.push(("latency_mean".to_string(), mean));
    }
    if let Some(depth) = line.get("queue").and_then(|q| q.get("depth")).and_then(Json::as_f64) {
        out.push(("queue_depth".to_string(), depth));
    }
    if let Some(rate) = line.get("gate").and_then(|g| g.get("hit_rate")).and_then(Json::as_f64) {
        out.push(("gate_hit_rate".to_string(), rate));
    }
    if let Some(tiers) = line.get("cache").and_then(|c| c.get("tiers")).and_then(Json::as_obj) {
        for (tier, stats) in tiers {
            if let Some(rate) = stats.get("hit_rate").and_then(Json::as_f64) {
                out.push((format!("cache_hit_rate:{tier}"), rate));
            }
        }
    }
    if let Some(stages) = line.get("stages").and_then(Json::as_obj) {
        for (name, tally) in stages {
            let runs = tally.get("runs").and_then(Json::as_f64).unwrap_or(0.0);
            let wall = tally.get("wall_ns").and_then(Json::as_f64).unwrap_or(0.0);
            if runs > 0.0 {
                // Cumulative wall over cumulative runs: mean wall per
                // stage execution so far.
                out.push((format!("stage:{name}"), wall / runs));
            }
        }
    }
    out
}

/// The trace id of the line's worst (highest-value) latency exemplar.
fn worst_exemplar(line: &Json) -> Option<String> {
    let sections = line.get("exemplars").and_then(Json::as_obj)?;
    let mut best: Option<(f64, &str)> = None;
    for buckets in sections.values() {
        let Some(buckets) = buckets.as_obj() else { continue };
        for ex in buckets.values() {
            let v = ex.get("value_ns").and_then(Json::as_f64).unwrap_or(0.0);
            let trace = ex.get("trace").and_then(Json::as_str).unwrap_or("");
            if !trace.is_empty() && best.map_or(true, |(bv, _)| v >= bv) {
                best = Some((v, trace));
            }
        }
    }
    best.map(|(_, t)| t.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn line(t_ns: u64, latency_mean: f64, stage_wall: f64, runs: f64, trace: &str) -> Json {
        Json::parse(&format!(
            r#"{{"t_ns": {t_ns}, "latency_ns": {{"mean": {latency_mean}}},
                "queue": {{"depth": 1}}, "gate": {{"hit_rate": 0.5}},
                "exemplars": {{"latency": {{"2047": {{"trace": "{trace}", "value_ns": 1500}}}}}},
                "stages": {{"gaussian": {{"wall_ns": {stage_wall}, "runs": {runs}}}}}}}"#
        ))
        .unwrap()
    }

    #[test]
    fn detector_warms_up_then_scores() {
        let mut d = EwmaDetector::new();
        for _ in 0..WARMUP {
            assert_eq!(d.observe(100.0), None);
        }
        // Flat series: exactly zero deviation.
        assert_eq!(d.observe(100.0), Some(0.0));
        // A 10x jump on a near-flat series scores far out.
        let z = d.observe(1000.0).unwrap();
        assert!(z > 50.0, "z={z}");
    }

    #[test]
    fn monitor_is_off_at_zero_sigma() {
        assert!(AnomalyMonitor::from_sigma(0.0).is_none());
        assert!(AnomalyMonitor::from_sigma(-1.0).is_none());
        assert!(AnomalyMonitor::from_sigma(3.0).is_some());
    }

    #[test]
    fn slow_stage_fires_and_names_the_exemplar() {
        let mut m = AnomalyMonitor::from_sigma(3.0).unwrap();
        // Steady state: mean stage wall 1000ns per run.
        for i in 0..12u64 {
            let l = line(i * 1_000_000, 500.0, 1000.0 * (i + 1) as f64, (i + 1) as f64, "aaa");
            assert!(m.observe_line(&l).is_empty(), "tick {i} should be quiet");
        }
        // Injected slow stage: one run that costs 50x the usual wall
        // drags the cumulative mean up well past 3 sigma.
        let l = line(13_000_000, 500.0, 1000.0 * 12.0 + 50_000.0, 13.0, "deadbeef");
        let alerts = m.observe_line(&l);
        assert_eq!(alerts.len(), 1, "{alerts:?}");
        assert_eq!(alerts[0].series, "stage:gaussian");
        assert_eq!(alerts[0].exemplar, "deadbeef");
        assert_eq!(alerts[0].t_ns, 13_000_000);
        assert!(alerts[0].z >= 3.0);
        assert_eq!(m.raised(), 1);
        let rendered = alerts[0].line();
        assert!(rendered.starts_with("ALERT t_ns=13000000 scope=anomaly:stage:gaussian z="));
        assert!(rendered.ends_with("exemplar=deadbeef"), "{rendered}");
    }

    #[test]
    fn alert_stream_is_deterministic() {
        let feed = |m: &mut AnomalyMonitor| -> Vec<String> {
            let mut out = Vec::new();
            for i in 0..15u64 {
                let wall = if i == 13 { 90_000.0 } else { 1000.0 * (i + 1) as f64 };
                let runs = (i + 1) as f64;
                for a in m.observe_line(&line(i, 500.0, wall, runs, "t")) {
                    out.push(a.line());
                }
            }
            out
        };
        let mut a = AnomalyMonitor::from_sigma(3.0).unwrap();
        let mut b = AnomalyMonitor::from_sigma(3.0).unwrap();
        let (la, lb) = (feed(&mut a), feed(&mut b));
        assert!(!la.is_empty());
        assert_eq!(la, lb, "identical inputs must render identical alert lines");
    }

    #[test]
    fn missing_sections_and_exemplars_are_tolerated() {
        let mut m = AnomalyMonitor::from_sigma(1.0).unwrap();
        let bare = Json::parse(r#"{"t_ns": 5}"#).unwrap();
        assert!(m.observe_line(&bare).is_empty());
        // A line with series but no exemplars alerts with "none".
        let mut l = Json::parse(r#"{"t_ns": 1, "queue": {"depth": 0}}"#).unwrap();
        for _ in 0..WARMUP {
            m.observe_line(&l);
        }
        l = Json::parse(r#"{"t_ns": 2, "queue": {"depth": 1000}}"#).unwrap();
        let alerts = m.observe_line(&l);
        assert_eq!(alerts.len(), 1);
        assert_eq!(alerts[0].exemplar, "none");
    }
}
