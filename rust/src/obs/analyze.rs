//! Offline trace analytics — the engine behind `cannyd analyze <file>
//! [--against <file>]`. The recording plane (`--trace-log`,
//! `--telemetry-log`, the bench harness) writes deterministic JSON;
//! this module reads it back and answers the questions a run raises:
//! where did the time go per span kind, which call chain dominates a
//! trace, and how does this run compare to a baseline.
//!
//! Three input shapes are sniffed from the bytes, no flag needed:
//!
//! * **span JSONL** (`--trace-log trace.jsonl`) — aggregates `dur_ns`
//!   per span name and extracts each trace's *critical path* (the
//!   longest-duration child at every depth, rendered `root>child>…`).
//! * **telemetry JSONL** (`--telemetry-log`) — aggregates the same
//!   rolling series the anomaly monitor watches
//!   ([`crate::obs::anomaly::extract_series`]), one observation per
//!   snapshot line, so an `ALERT … scope=anomaly:stage:sobel` can be
//!   followed up with the series' full distribution.
//! * **bench docs** (`rust/benches/baselines/BENCH_*.json`) — the
//!   committed scalability baselines; each case's published
//!   `p50_ns`/`p99_ns` load directly, so `--against` can diff a fresh
//!   trace against the committed seed numbers.
//!
//! Quantiles are exact nearest-rank over the collected observations
//! (not histogram-bucket approximations — offline we can afford to
//! sort). The report is one [`Json`] document; schema and a worked
//! example live in [`crate::obs`]. Everything is pure file-in,
//! value-out: no clocks, no global state, byte-identical reports for
//! byte-identical inputs.

use std::collections::BTreeMap;
use std::fs;
use std::path::Path;

use crate::error::{Error, Result};
use crate::obs::anomaly::extract_series;
use crate::util::json::Json;

/// Critical-path extraction stops descending at this depth — a cycle
/// in a corrupt span file must not hang the analyzer.
const MAX_PATH_DEPTH: usize = 64;

/// What one input file reduces to, before rendering.
struct Loaded {
    /// `spans`, `telemetry`, or `bench`.
    kind: &'static str,
    /// Series name → `(count, p50_ns, p99_ns)`.
    aggregates: BTreeMap<String, (u64, u64, u64)>,
    /// Distinct trace ids (span inputs only).
    traces: Option<u64>,
    /// Critical path → number of traces sharing it (span inputs only).
    critical_paths: Option<BTreeMap<String, u64>>,
}

/// Analyze one recorded file, optionally diffing its aggregates
/// against a second (`--against`). Returns the report document
/// (schema in [`crate::obs`]); the caller prints it.
pub fn analyze(input: &Path, against: Option<&Path>) -> Result<Json> {
    let cur = load(input)?;
    let mut m = BTreeMap::new();
    m.insert("input".into(), Json::Str(input.display().to_string()));
    m.insert("kind".into(), Json::Str(cur.kind.into()));
    let mut aggregates = BTreeMap::new();
    for (name, (count, p50, p99)) in &cur.aggregates {
        let mut a = BTreeMap::new();
        a.insert("count".into(), Json::Num(*count as f64));
        a.insert("p50_ns".into(), Json::Num(*p50 as f64));
        a.insert("p99_ns".into(), Json::Num(*p99 as f64));
        aggregates.insert(name.clone(), Json::Obj(a));
    }
    m.insert("aggregates".into(), Json::Obj(aggregates));
    if let Some(traces) = cur.traces {
        m.insert("traces".into(), Json::Num(traces as f64));
    }
    if let Some(paths) = &cur.critical_paths {
        let paths =
            paths.iter().map(|(p, n)| (p.clone(), Json::Num(*n as f64))).collect::<BTreeMap<_, _>>();
        m.insert("critical_paths".into(), Json::Obj(paths));
    }
    if let Some(base_path) = against {
        let base = load(base_path)?;
        m.insert("against".into(), Json::Str(base_path.display().to_string()));
        let mut deltas = BTreeMap::new();
        for (name, (_, cur_p50, cur_p99)) in &cur.aggregates {
            let Some((_, base_p50, base_p99)) = base.aggregates.get(name) else { continue };
            let mut d = BTreeMap::new();
            d.insert("base_p50_ns".into(), Json::Num(*base_p50 as f64));
            d.insert("base_p99_ns".into(), Json::Num(*base_p99 as f64));
            d.insert("cur_p50_ns".into(), Json::Num(*cur_p50 as f64));
            d.insert("cur_p99_ns".into(), Json::Num(*cur_p99 as f64));
            d.insert("delta_p50_pct".into(), Json::Num(delta_pct(*base_p50, *cur_p50)));
            d.insert("delta_p99_pct".into(), Json::Num(delta_pct(*base_p99, *cur_p99)));
            deltas.insert(name.clone(), Json::Obj(d));
        }
        m.insert("deltas".into(), Json::Obj(deltas));
    }
    Ok(Json::Obj(m))
}

/// Percent change current-vs-base, rounded to 0.1 so reports stay
/// byte-stable; positive means the current run is slower. A zero base
/// has no meaningful ratio — reported as 0 when flat, 100 otherwise.
fn delta_pct(base: u64, cur: u64) -> f64 {
    if base == 0 {
        return if cur == 0 { 0.0 } else { 100.0 };
    }
    let pct = (cur as f64 - base as f64) / base as f64 * 100.0;
    (pct * 10.0).round() / 10.0
}

/// Exact nearest-rank quantile over an ascending-sorted slice.
fn quantile(sorted: &[f64], q: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let rank = (q * sorted.len() as f64).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1].round() as u64
}

/// Read a file and sniff its shape: a whole-file JSON object with a
/// `bench` key is a bench doc; otherwise it is JSONL whose first
/// parseable line decides span vs telemetry.
fn load(path: &Path) -> Result<Loaded> {
    let text = fs::read_to_string(path)
        .map_err(|e| Error::Config(format!("analyze: cannot read {}: {e}", path.display())))?;
    if let Ok(doc) = Json::parse(&text) {
        if doc.get("bench").is_some() {
            return load_bench(&doc);
        }
    }
    let mut lines = Vec::new();
    for (n, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let j = Json::parse(line).map_err(|e| {
            Error::Config(format!("analyze: {} line {}: {e}", path.display(), n + 1))
        })?;
        lines.push(j);
    }
    let Some(first) = lines.first() else {
        return Err(Error::Config(format!("analyze: {} is empty", path.display())));
    };
    if first.get("trace").is_some() && first.get("t0_ns").is_some() {
        Ok(load_spans(&lines))
    } else if first.get("tier").is_some() && first.get("seq").is_some() {
        Ok(load_telemetry(&lines))
    } else {
        Err(Error::Config(format!(
            "analyze: {} is neither span JSONL, telemetry JSONL, nor a bench doc",
            path.display()
        )))
    }
}

/// Bench docs publish their quantiles directly — one aggregate per
/// case. `BENCH_serve.json` is one flat case; `BENCH_cluster.json`
/// carries a `fleets` array, one case per fleet size.
fn load_bench(doc: &Json) -> Result<Loaded> {
    let bench = doc.get("bench").and_then(Json::as_str).unwrap_or("bench").to_string();
    let mut aggregates = BTreeMap::new();
    let case = |j: &Json, name: String, aggregates: &mut BTreeMap<String, (u64, u64, u64)>| {
        let n = |key: &str| j.get(key).and_then(Json::as_f64).unwrap_or(0.0) as u64;
        let count = if j.get("completed").is_some() { n("completed") } else { n("requests") };
        aggregates.insert(name, (count, n("p50_ns"), n("p99_ns")));
    };
    match doc.get("fleets").and_then(Json::as_arr) {
        Some(fleets) => {
            for fleet in fleets {
                let workers =
                    fleet.get("workers").and_then(Json::as_f64).unwrap_or(0.0) as u64;
                case(fleet, format!("{bench}:workers={workers}"), &mut aggregates);
            }
        }
        None => case(doc, bench, &mut aggregates),
    }
    Ok(Loaded { kind: "bench", aggregates, traces: None, critical_paths: None })
}

/// Span JSONL: `dur_ns` observations per span name, plus per-trace
/// critical paths. Span files are written sorted by
/// `(trace, id, t0_ns)`, but the walk re-groups defensively so a
/// concatenation of two logs still analyzes.
fn load_spans(lines: &[Json]) -> Loaded {
    let mut durs: BTreeMap<String, Vec<f64>> = BTreeMap::new();
    // trace → (id → (name, dur_ns, parent))
    let mut traces: BTreeMap<String, BTreeMap<u64, (String, f64, Option<u64>)>> = BTreeMap::new();
    for span in lines {
        let name = span.get("name").and_then(Json::as_str).unwrap_or("?").to_string();
        let dur = span.get("dur_ns").and_then(Json::as_f64).unwrap_or(0.0);
        let trace = span.get("trace").and_then(Json::as_str).unwrap_or("").to_string();
        let id = span.get("id").and_then(Json::as_f64).unwrap_or(0.0) as u64;
        let parent = span.get("parent").and_then(Json::as_f64).map(|p| p as u64);
        durs.entry(name.clone()).or_default().push(dur);
        traces.entry(trace).or_default().insert(id, (name, dur, parent));
    }
    let mut critical_paths: BTreeMap<String, u64> = BTreeMap::new();
    for spans in traces.values() {
        if let Some(path) = critical_path(spans) {
            *critical_paths.entry(path).or_insert(0) += 1;
        }
    }
    let mut aggregates = BTreeMap::new();
    for (name, mut vals) in durs {
        vals.sort_by(f64::total_cmp);
        aggregates
            .insert(name, (vals.len() as u64, quantile(&vals, 0.50), quantile(&vals, 0.99)));
    }
    Loaded {
        kind: "spans",
        aggregates,
        traces: Some(traces.len() as u64),
        critical_paths: Some(critical_paths),
    }
}

/// One trace's critical path: start at the root (no parent), descend
/// into the longest-duration child at every level (smallest id breaks
/// ties so the path is deterministic), join names with `>`.
fn critical_path(spans: &BTreeMap<u64, (String, f64, Option<u64>)>) -> Option<String> {
    let mut children: BTreeMap<u64, Vec<u64>> = BTreeMap::new();
    let mut root = None;
    for (id, (_, _, parent)) in spans {
        match parent {
            Some(p) => children.entry(*p).or_default().push(*id),
            None => root = root.or(Some(*id)),
        }
    }
    let mut cur = root?;
    let mut path = spans[&cur].0.clone();
    for _ in 0..MAX_PATH_DEPTH {
        let Some(kids) = children.get(&cur) else { break };
        // BTreeMap insertion gave ascending ids; `>` keeps the first
        // (smallest-id) maximum on duration ties.
        let Some(next) = kids
            .iter()
            .copied()
            .max_by(|a, b| match spans[a].1.total_cmp(&spans[b].1) {
                std::cmp::Ordering::Equal => b.cmp(a),
                o => o,
            })
        else {
            break;
        };
        path.push('>');
        path.push_str(&spans[&next].0);
        cur = next;
    }
    Some(path)
}

/// Telemetry JSONL: one observation per snapshot line per watched
/// series — the same extraction the live anomaly monitor uses, so
/// offline aggregates and online alerts name identical series.
fn load_telemetry(lines: &[Json]) -> Loaded {
    let mut series: BTreeMap<String, Vec<f64>> = BTreeMap::new();
    for line in lines {
        for (name, value) in extract_series(line) {
            series.entry(name).or_default().push(value);
        }
    }
    let mut aggregates = BTreeMap::new();
    for (name, mut vals) in series {
        vals.sort_by(f64::total_cmp);
        aggregates
            .insert(name, (vals.len() as u64, quantile(&vals, 0.50), quantile(&vals, 0.99)));
    }
    Loaded { kind: "telemetry", aggregates, traces: None, critical_paths: None }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("canny_analyze_tests");
        fs::create_dir_all(&dir).unwrap();
        dir.join(format!("{}_{name}", std::process::id()))
    }

    fn span_line(trace: &str, id: u64, parent: Option<u64>, name: &str, dur: u64) -> String {
        let parent = parent.map_or("null".to_string(), |p| p.to_string());
        format!(
            r#"{{"attrs": {{}}, "cat": "exec", "dur_ns": {dur}, "id": {id}, "name": "{name}", "parent": {parent}, "t0_ns": 0, "tid": 1, "trace": "{trace}"}}"#
        )
    }

    #[test]
    fn span_files_aggregate_and_extract_critical_paths() {
        let path = tmp("spans.jsonl");
        let mut text = String::new();
        for trace in ["aaaa", "bbbb"] {
            text.push_str(&span_line(trace, 1, None, "request", 5000));
            text.push('\n');
            text.push_str(&span_line(trace, 2, Some(1), "queue_wait", 500));
            text.push('\n');
            text.push_str(&span_line(trace, 3, Some(1), "service", 4000));
            text.push('\n');
            text.push_str(&span_line(trace, 4, Some(3), "stage:sobel", 3000));
            text.push('\n');
        }
        fs::write(&path, text).unwrap();
        let j = analyze(&path, None).unwrap();
        assert_eq!(j.get("kind").unwrap().as_str(), Some("spans"));
        assert_eq!(j.get("traces").unwrap().as_usize(), Some(2));
        let agg = j.get("aggregates").unwrap();
        assert_eq!(agg.get("service").unwrap().get("count").unwrap().as_usize(), Some(2));
        assert_eq!(agg.get("service").unwrap().get("p50_ns").unwrap().as_usize(), Some(4000));
        assert_eq!(agg.get("service").unwrap().get("p99_ns").unwrap().as_usize(), Some(4000));
        let paths = j.get("critical_paths").unwrap().as_obj().unwrap();
        assert_eq!(paths.len(), 1, "{paths:?}");
        assert_eq!(
            paths.get("request>service>stage:sobel").unwrap().as_usize(),
            Some(2),
            "both traces share the service-dominated path"
        );
    }

    #[test]
    fn telemetry_files_aggregate_the_monitored_series() {
        let path = tmp("telemetry.jsonl");
        let mut text = String::new();
        for (seq, mean) in [(0u64, 1000.0), (1, 2000.0), (2, 3000.0)] {
            text.push_str(&format!(
                r#"{{"seq": {seq}, "t_ns": {}, "tier": "serve", "latency_ns": {{"mean": {mean}}}, "queue": {{"depth": {seq}}}}}"#,
                seq * 100
            ));
            text.push('\n');
        }
        fs::write(&path, text).unwrap();
        let j = analyze(&path, None).unwrap();
        assert_eq!(j.get("kind").unwrap().as_str(), Some("telemetry"));
        assert!(j.get("traces").is_none());
        let lat = j.get("aggregates").unwrap().get("latency_mean").unwrap();
        assert_eq!(lat.get("count").unwrap().as_usize(), Some(3));
        assert_eq!(lat.get("p50_ns").unwrap().as_usize(), Some(2000));
        assert_eq!(lat.get("p99_ns").unwrap().as_usize(), Some(3000));
    }

    #[test]
    fn bench_docs_load_their_published_quantiles() {
        let serve = tmp("BENCH_serve.json");
        fs::write(
            &serve,
            r#"{"bench": "serve", "completed": 48, "p50_ns": 2450000, "p99_ns": 6200000}"#,
        )
        .unwrap();
        let j = analyze(&serve, None).unwrap();
        assert_eq!(j.get("kind").unwrap().as_str(), Some("bench"));
        let a = j.get("aggregates").unwrap().get("serve").unwrap();
        assert_eq!(a.get("count").unwrap().as_usize(), Some(48));
        assert_eq!(a.get("p50_ns").unwrap().as_usize(), Some(2450000));
        let cluster = tmp("BENCH_cluster.json");
        fs::write(
            &cluster,
            r#"{"bench": "cluster", "fleets": [{"completed": 32, "p50_ns": 1800000, "p99_ns": 5400000, "workers": 1}, {"completed": 32, "p50_ns": 1900000, "p99_ns": 6100000, "workers": 4}]}"#,
        )
        .unwrap();
        let j = analyze(&cluster, None).unwrap();
        let agg = j.get("aggregates").unwrap().as_obj().unwrap();
        assert_eq!(agg.len(), 2);
        assert!(agg.contains_key("cluster:workers=1"));
        assert_eq!(
            agg["cluster:workers=4"].get("p99_ns").unwrap().as_usize(),
            Some(6100000)
        );
    }

    #[test]
    fn against_diffs_shared_series_with_rounded_percentages() {
        let base = tmp("delta_base.json");
        let cur = tmp("delta_cur.json");
        fs::write(&base, r#"{"bench": "serve", "completed": 10, "p50_ns": 1000, "p99_ns": 2000}"#)
            .unwrap();
        fs::write(&cur, r#"{"bench": "serve", "completed": 10, "p50_ns": 1047, "p99_ns": 1500}"#)
            .unwrap();
        let j = analyze(&cur, Some(&base)).unwrap();
        assert_eq!(j.get("against").unwrap().as_str(), Some(base.to_str().unwrap()));
        let d = j.get("deltas").unwrap().get("serve").unwrap();
        assert_eq!(d.get("base_p50_ns").unwrap().as_usize(), Some(1000));
        assert_eq!(d.get("cur_p50_ns").unwrap().as_usize(), Some(1047));
        assert_eq!(d.get("delta_p50_pct").unwrap().as_f64(), Some(4.7));
        assert_eq!(d.get("delta_p99_pct").unwrap().as_f64(), Some(-25.0));
        // Self-comparison is an all-zero delta — and deterministic.
        let same = analyze(&cur, Some(&cur)).unwrap();
        let d = same.get("deltas").unwrap().get("serve").unwrap();
        assert_eq!(d.get("delta_p50_pct").unwrap().as_f64(), Some(0.0));
        assert_eq!(same.dump(), analyze(&cur, Some(&cur)).unwrap().dump());
    }

    #[test]
    fn unrecognized_and_empty_inputs_are_config_errors() {
        let path = tmp("garbage.jsonl");
        fs::write(&path, "{\"what\": 1}\n").unwrap();
        assert!(analyze(&path, None).is_err());
        let empty = tmp("empty.jsonl");
        fs::write(&empty, "").unwrap();
        assert!(analyze(&empty, None).is_err());
        assert!(analyze(Path::new("/nonexistent/nope.jsonl"), None).is_err());
    }

    #[test]
    fn quantiles_are_nearest_rank() {
        let v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(quantile(&v, 0.50), 50);
        assert_eq!(quantile(&v, 0.99), 99);
        assert_eq!(quantile(&[7.0], 0.99), 7);
        assert_eq!(quantile(&[], 0.5), 0);
    }
}
