//! Tail-based trace sampling (`--trace-sample`): the decision whether a
//! request's buffered spans reach `--trace-log` is made *after* the
//! request completes, when its end-to-end latency is known — so under
//! real load only the interesting traces (slow, SLO-violating) are
//! retained while the cheap majority is dropped before it ever touches
//! the trace file.
//!
//! Policies (`--trace-sample all|slow:<ms>|errors|head:<1-in-n>`):
//!
//! * `all` — keep every trace (the default; PR 9 behavior).
//! * `slow:<ms>` — keep traces whose end-to-end latency is at least
//!   `<ms>` milliseconds (`slow:0` keeps everything and exercises the
//!   sampling path end to end).
//! * `errors` — keep traces that violated the run's p99 SLO target
//!   (`--slo-p99-ms`); in this lossless pipeline an SLO violation *is*
//!   the error signal, there are no failed requests to catch.
//! * `head:<n>` — classic head sampling, kept for comparison: 1 in `n`
//!   by admission sequence number (`seq % n == 0`).
//!
//! Determinism contract: every verdict is a pure function of modeled
//! quantities — the virtual-clock latency and the admission sequence
//! number — so two replays of the same trace keep *identical* trace
//! sets and `--trace-log` stays byte-identical. No clock is read here
//! (pallas-lint rule 2 holds with an unchanged allowlist).
//!
//! Cluster mode: the front door's verdict governs the whole tree. The
//! sampler rides the request frame as the canonical wire form
//! ([`TraceSampler::to_wire`], thresholds pre-resolved to ns) next to
//! the trace context; a worker applies the verdict locally only when
//! it is decidable on both ends ([`TraceSampler::remote_verdict`]:
//! virtual clocks share the modeled latency, `head`/`all` need only the
//! request id), otherwise it ships its spans and the front door drops
//! the front half and the worker subtree *together* — a trace is never
//! torn.

use crate::error::{Error, Result};

/// What `--trace-sample` keeps (thresholds resolved to ns at parse
/// time, so verdicts need no further configuration).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SamplePolicy {
    /// Keep every trace.
    All,
    /// Keep traces at least this slow (end-to-end ns).
    Slow(u64),
    /// Keep SLO-violating traces (latency above the stored target ns).
    Errors,
    /// Keep 1 in `n` by admission sequence number.
    Head(u64),
}

/// The tail sampler: a parsed policy plus the resolved SLO target the
/// `errors` policy compares against.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TraceSampler {
    policy: SamplePolicy,
    slo_ns: u64,
}

impl TraceSampler {
    /// The keep-everything sampler (`--trace-sample all`, the default).
    pub fn all() -> TraceSampler {
        TraceSampler { policy: SamplePolicy::All, slo_ns: 0 }
    }

    /// Parse a `--trace-sample` spec. `slo_p99_ns` is the run's SLO
    /// target, captured here so `errors` verdicts are self-contained.
    pub fn from_spec(spec: &str, slo_p99_ns: u64) -> Result<TraceSampler> {
        let bad = || {
            Error::Config(format!(
                "--trace-sample `{spec}` (expected all | slow:<ms> | errors | head:<1-in-n>)"
            ))
        };
        let policy = match spec {
            "" | "all" => SamplePolicy::All,
            "errors" => SamplePolicy::Errors,
            _ => match spec.split_once(':') {
                Some(("slow", ms)) => {
                    let ms: f64 = ms.parse().map_err(|_| bad())?;
                    if !(ms >= 0.0) || !ms.is_finite() {
                        return Err(bad());
                    }
                    SamplePolicy::Slow((ms * 1e6) as u64)
                }
                Some(("head", n)) => {
                    let n: u64 = n.parse().map_err(|_| bad())?;
                    if n == 0 {
                        return Err(bad());
                    }
                    SamplePolicy::Head(n)
                }
                _ => return Err(bad()),
            },
        };
        Ok(TraceSampler { policy, slo_ns: slo_p99_ns })
    }

    pub fn policy(&self) -> SamplePolicy {
        self.policy
    }

    /// Does this sampler keep everything? (`all`, and `slow:0` — every
    /// latency clears a zero bar.)
    pub fn keeps_all(&self) -> bool {
        matches!(self.policy, SamplePolicy::All | SamplePolicy::Slow(0))
    }

    /// The tail verdict for one completed request: `latency_ns` is its
    /// end-to-end latency (modeled under the virtual clock), `seq` its
    /// admission sequence number (the request id).
    pub fn keep(&self, latency_ns: u64, seq: u64) -> bool {
        match self.policy {
            SamplePolicy::All => true,
            SamplePolicy::Slow(t) => latency_ns >= t,
            SamplePolicy::Errors => self.slo_ns > 0 && latency_ns > self.slo_ns,
            SamplePolicy::Head(n) => seq % n == 0,
        }
    }

    /// A worker-side verdict, or `None` when only the front door can
    /// decide. Decidable when both ends compute the same latency
    /// (virtual clocks share the modeled timeline) or when the policy
    /// ignores latency (`all`, `head`). Undecidable (wall-clock
    /// `slow`/`errors`, where the wire latency is measured at the front
    /// door) means: ship the spans, the front door drops the whole tree
    /// if its verdict says so.
    pub fn remote_verdict(
        &self,
        virtual_clock: bool,
        latency_ns: u64,
        seq: u64,
    ) -> Option<bool> {
        let decidable = virtual_clock
            || matches!(self.policy, SamplePolicy::All | SamplePolicy::Head(_));
        if decidable {
            Some(self.keep(latency_ns, seq))
        } else {
            None
        }
    }

    /// The canonical wire form the request frame carries (thresholds in
    /// resolved ns, so both ends apply bit-identical arithmetic):
    /// `all`, `slow:<ns>`, `errors:<slo_ns>`, `head:<n>`.
    pub fn to_wire(&self) -> String {
        match self.policy {
            SamplePolicy::All => "all".to_string(),
            SamplePolicy::Slow(t) => format!("slow:{t}"),
            SamplePolicy::Errors => format!("errors:{}", self.slo_ns),
            SamplePolicy::Head(n) => format!("head:{n}"),
        }
    }

    /// Parse the wire form (inverse of [`TraceSampler::to_wire`]);
    /// `None` on anything malformed — the worker then ships all spans
    /// and the front door's verdict still governs.
    pub fn from_wire(wire: &str) -> Option<TraceSampler> {
        if wire == "all" {
            return Some(TraceSampler::all());
        }
        let (kind, value) = wire.split_once(':')?;
        let value: u64 = value.parse().ok()?;
        match kind {
            "slow" => Some(TraceSampler { policy: SamplePolicy::Slow(value), slo_ns: 0 }),
            "errors" => Some(TraceSampler { policy: SamplePolicy::Errors, slo_ns: value }),
            "head" if value > 0 => {
                Some(TraceSampler { policy: SamplePolicy::Head(value), slo_ns: 0 })
            }
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn specs_parse_and_reject() {
        assert_eq!(TraceSampler::from_spec("all", 0).unwrap().policy(), SamplePolicy::All);
        assert_eq!(TraceSampler::from_spec("", 0).unwrap().policy(), SamplePolicy::All);
        assert_eq!(
            TraceSampler::from_spec("slow:2.5", 0).unwrap().policy(),
            SamplePolicy::Slow(2_500_000)
        );
        assert_eq!(
            TraceSampler::from_spec("errors", 7).unwrap().policy(),
            SamplePolicy::Errors
        );
        assert_eq!(
            TraceSampler::from_spec("head:10", 0).unwrap().policy(),
            SamplePolicy::Head(10)
        );
        for bad in ["slowest", "slow:", "slow:-1", "head:0", "head:x", "tail:3"] {
            assert!(TraceSampler::from_spec(bad, 0).is_err(), "`{bad}` should be rejected");
        }
    }

    #[test]
    fn verdicts_follow_the_policy() {
        let slow = TraceSampler::from_spec("slow:1", 0).unwrap();
        assert!(slow.keep(1_000_000, 0));
        assert!(slow.keep(2_000_000, 0));
        assert!(!slow.keep(999_999, 0));
        let errors = TraceSampler::from_spec("errors", 50_000_000).unwrap();
        assert!(errors.keep(50_000_001, 0));
        assert!(!errors.keep(50_000_000, 0));
        // No SLO target: `errors` keeps nothing rather than everything.
        assert!(!TraceSampler::from_spec("errors", 0).unwrap().keep(u64::MAX, 0));
        let head = TraceSampler::from_spec("head:3", 0).unwrap();
        let kept: Vec<u64> = (0..9).filter(|&s| head.keep(0, s)).collect();
        assert_eq!(kept, vec![0, 3, 6]);
        assert!(TraceSampler::all().keeps_all());
        assert!(TraceSampler::from_spec("slow:0", 0).unwrap().keeps_all());
        assert!(!slow.keeps_all());
    }

    #[test]
    fn wire_form_round_trips_with_resolved_ns() {
        for spec in ["all", "slow:2.5", "errors", "head:10"] {
            let s = TraceSampler::from_spec(spec, 50_000_000).unwrap();
            let back = TraceSampler::from_wire(&s.to_wire()).unwrap();
            assert_eq!(back.policy(), s.policy(), "{spec}");
            // The verdict function survives the wire (errors carries
            // its resolved SLO target along).
            for (lat, seq) in [(0, 0), (2_500_000, 1), (60_000_000, 3), (100, 10)] {
                assert_eq!(back.keep(lat, seq), s.keep(lat, seq), "{spec} @ {lat}/{seq}");
            }
        }
        assert_eq!(TraceSampler::from_spec("slow:2.5", 0).unwrap().to_wire(), "slow:2500000");
        assert!(TraceSampler::from_wire("slow:x").is_none());
        assert!(TraceSampler::from_wire("nope").is_none());
    }

    #[test]
    fn remote_verdicts_are_conservative_under_wall_clocks() {
        let slow = TraceSampler::from_spec("slow:1", 0).unwrap();
        // Virtual: both ends share the modeled latency — decidable.
        assert_eq!(slow.remote_verdict(true, 2_000_000, 0), Some(true));
        assert_eq!(slow.remote_verdict(false, 2_000_000, 0), None);
        // Latency-blind policies decide anywhere.
        let head = TraceSampler::from_spec("head:2", 0).unwrap();
        assert_eq!(head.remote_verdict(false, 0, 1), Some(false));
        assert_eq!(TraceSampler::all().remote_verdict(false, 0, 9), Some(true));
    }
}
