//! The **ops plane** — live telemetry, rolling SLO evaluation, health
//! states and overload shedding for the serve and stream tiers. The
//! paper's scalability story was watched through an offline sampling
//! profiler (§3.1); a long-lived `cannyd` needs the live equivalent:
//! every report used to be end-of-run only, this module makes the same
//! numbers observable *while the run is in flight*.
//!
//! Core pieces:
//!
//! * [`registry::Telemetry`] — the process-wide registry of atomic
//!   counters, gauges and fixed-bucket latency histograms that serve
//!   lanes, the stream executor and the artifact cache publish into.
//! * [`snapshot::SnapshotEngine`] — turns the registry into periodic
//!   JSONL lines (`--telemetry-log file.jsonl
//!   --telemetry-interval-ms N`). Under the **wall** clock a real
//!   sampler thread ([`snapshot::WallSnapshotter`]) emits every
//!   interval and samples per-core busy flags into a `utilization`
//!   section (accumulated into a [`crate::profiler::UsageTrace`] — the
//!   Figure-8/9 data free of charge); under the **virtual** clock the
//!   deterministic event loop emits ticks at modeled times, so two
//!   replays of the same trace write **byte-identical** files.
//! * [`health::Health`] — `healthy | degraded | stalled` per lane and
//!   for the tier, derived from heartbeat gauges (stall detection) and
//!   the shedding state (degradation).
//! * [`fault::FaultManager`] — explicit overload policies
//!   (`--overload-policy none | reject-new | degrade-to-front-only`)
//!   generalizing the stream tier's drop/degrade to the serve tier:
//!   when the rolling SLO window ([`crate::service::slo::SloWindow`])
//!   is missed, new arrivals are rejected or rewritten to the cheap
//!   front-only pipeline, every decision counted in the telemetry
//!   stream and the final report.
//!
//! Distributed-observability pieces (PR 9):
//!
//! * [`trace::TraceCollector`] — per-request distributed tracing
//!   (`--trace-log FILE`): every admitted request gets a deterministic
//!   [`trace::TraceId`] and a tree of [`trace::Span`] records (queue
//!   wait, batch coalesce, cache consult, per-stage execution, and in
//!   cluster mode the route/wire hops, with worker spans stitched
//!   under the front door's tree via trace context on the wire).
//!   Exported as span-JSONL (`.jsonl`) or Chrome trace-event JSON
//!   (any other extension) — schemas below.
//! * [`merge::merged_line`] — cluster-wide telemetry aggregation: the
//!   front door folds the workers' streamed snapshot lines into one
//!   cluster-tier line with totals plus per-worker sections.
//! * [`endpoint::ObsEndpoint`] — a live snapshot window on loopback
//!   TCP (`--obs-port`): connect and the server writes the tier's
//!   current snapshot line, then — when one has fired — the newest
//!   alert line as a second line, then closes. Framing is line-based
//!   (`\n`-terminated JSON, then an optional `ALERT …` line); before
//!   the first snapshot the connection closes clean with zero bytes.
//!   No HTTP; polling it never perturbs the deterministic
//!   `--telemetry-log` bytes.
//!
//! Trace-analytics pieces (PR 10):
//!
//! * [`sample::TraceSampler`] — **tail-based** trace sampling
//!   (`--trace-sample all | slow:<ms> | errors | head:<1-in-n>`): the
//!   keep/drop verdict is made *after* a request completes, from its
//!   observed latency, so `slow:5` retains exactly the traces you
//!   would grep for. Under the virtual clock verdicts derive from
//!   modeled quantities only — two replays pick identical trace sets.
//!   In cluster mode the front door's policy rides the request frame
//!   (a `sample` key next to the trace context) so workers skip
//!   building subtrees the front door will discard.
//! * [`registry::Histogram`] exemplars — each latency bucket cites the
//!   trace id + value of its worst **sampled** observation, exported
//!   in the snapshot line's `exemplars` section. Exemplars are noted
//!   only for kept traces, so every exported id resolves to a trace
//!   retained in `--trace-log`.
//! * [`anomaly::AnomalyMonitor`] — EWMA mean/variance detectors over
//!   the rolling telemetry series (`--anomaly-sigma <n>`, off at 0):
//!   each snapshot line is scored against the learned state and a
//!   `|z| >= sigma` excursion raises an `ALERT … scope=anomaly:…`
//!   line through the run's [`health::HealthTracker`], citing the
//!   worst exemplar trace id on the offending line.
//! * [`analyze::analyze`] — offline analytics over the files the run
//!   wrote: `cannyd analyze trace.jsonl` aggregates span latencies
//!   (count/p50/p99 per span kind) and extracts per-trace critical
//!   paths; telemetry JSONL and bench-compare `BENCH_*.json` docs are
//!   accepted too, and `--against baseline` adds per-name deltas.
//!
//! ## Telemetry JSONL schema (one object per line)
//!
//! ```json
//! {
//!   "alerts": 0,
//!   "cache": {"enabled": true, "...": "the serve/stream cache section",
//!             "tiers": {"serve": {"hit_rate": 0.75, "...": "…"},
//!                       "stream": {"hit_rate": 0.0, "...": "…"}}},
//!   "exemplars": {"latency": {"1048575": {"trace": "00779c4fb295f4db00000007",
//!                                         "value_ns": 1048000}}},
//!   "gate": {"hit_rate": 0.92, "tiles_clean": 736, "tiles_dirty": 64},
//!   "health": "healthy",
//!   "lanes": [{"batches": 12, "busy_ns": 81234567, "completed": 40,
//!              "health": "healthy", "heartbeat_ns": 99120334, "id": 0,
//!              "inflight": 2}],
//!   "latency_ns": {"count": 80, "max": 4123000, "mean": 1082350.5,
//!                  "p50": 1048575, "p95": 2097151, "p99": 4194303},
//!   "overload": {"policy": "reject-new", "shed_degraded": 0,
//!                "shed_rejected": 3},
//!   "queue": {"admitted": 83, "depth": 4, "high_water": 9,
//!             "offered": 90, "rejected": 7},
//!   "seq": 41,
//!   "slo": {"n": 64, "p50_ns": 1048575, "p95_ns": 2097151,
//!           "p99_ns": 4123000, "status": "met", "target_p99_ns": 50000000,
//!           "transitions": [{"status": "met", "t_ns": 1201000}],
//!           "transitions_truncated": 0, "window": 64},
//!   "stages": {"gaussian": {"cpu_ns": 0, "runs": 12, "wall_ns": 0}},
//!   "t_ns": 4100000,
//!   "tier": "serve",
//!   "utilization": {"busy": 3, "cores": 4, "pct": 75,
//!                   "per_core": [1, 1, 1, 0]}
//! }
//! ```
//!
//! Field notes:
//!
//! * Every line carries [`snapshot::REQUIRED_LINE_KEYS`] (what the CI
//!   schema check asserts). `utilization` is **wall-clock only**: a
//!   measured sample would break virtual-replay byte-identity, so
//!   deterministic replays omit the key rather than fake it.
//! * `alerts` counts alert lines the run's [`health::HealthTracker`]
//!   has emitted so far (`--alert-log stderr|FILE`). Health
//!   transitions use `ALERT t_ns=… scope=… from=… to=…`, one line per
//!   healthy↔degraded↔stalled change per lane/tier/worker scope.
//!   Anomaly excursions (`--anomaly-sigma`) use `ALERT t_ns=…
//!   scope=anomaly:<series> z=… value=… mean=… exemplar=<trace|none>`
//!   where `<series>` is `latency_mean`, `queue_depth`,
//!   `gate_hit_rate`, `cache_hit_rate:<tier>` or `stage:<name>`.
//!   Zero when alerting is off. Anomaly alerts raised while rendering
//!   a line are counted into the *next* line's `alerts` value.
//! * `exemplars.latency` maps a latency bucket's inclusive upper
//!   bound (stringified ns) to the `trace` id and `value_ns` of the
//!   worst observation sampled into that bucket; only tail-sampled
//!   (kept) traces are cited, so every id resolves in `--trace-log`.
//!   Empty when tracing or sampling retains nothing.
//! * `latency_ns` quantiles are bucket-resolution approximations from
//!   the cumulative power-of-two histogram (`count`/`mean`/`max` are
//!   exact); `slo` quantiles are exact nearest-rank over the rolling
//!   window of recent completions.
//! * `stages.*.wall_ns`/`cpu_ns` are measured under wall clocks and
//!   zero (runs only) under the virtual clock, for the same
//!   determinism reason the end-of-run report only carries run counts.
//! * `tier` is `"serve"` or `"stream"`; stream lines use the same
//!   schema with one `lanes` entry per pipeline stage (decode, front,
//!   finish), `gate` fed by the delta-gate, and `overload` counting
//!   deadline drops (`shed_rejected`) and degraded emissions
//!   (`shed_degraded`) under the stream's `--drop-policy`.
//! * The file is truncated at run start and each line ends in `\n`;
//!   `seq` is dense from 0. The last line is emitted at shutdown (wall)
//!   or after the final modeled completion (virtual), so the end state
//!   is always captured.
//!
//! The serve/stream **final reports** gain matching sections: `overload`
//! (policy + shed totals) and `slo.window` (rolling-window quantiles,
//! status and the met/missed/no-data transition timeline) — see
//! [`crate::service::slo::ServeReport`] and
//! [`crate::stream::StreamReport`].
//!
//! ## Cluster merged telemetry schema (one object per line)
//!
//! The cluster front door's `--telemetry-log` carries the same
//! top-level keys as above with `"tier": "cluster"`: counters are
//! summed across workers, levels/percentiles take the max, health and
//! SLO status take the worst state, and the raw per-worker lines ride
//! under `workers`, each stamped with its slot as a `worker` key
//! (nonzero `seq`/`t_ns` inside a section are the *worker's own*
//! stream position). Sections a worker has not reported yet are backed
//! by zero values, so every line carries the full documented key set:
//!
//! ```json
//! {
//!   "alerts": 0,
//!   "cache": {"enabled": true, "...": "summed cache section"},
//!   "gate": {},
//!   "health": "healthy",
//!   "lanes": [{"id": 0, "...": "all workers' lanes, concatenated"}],
//!   "latency_ns": {"count": 24, "max": 4123000, "p99": 4194303},
//!   "overload": {"policy": "none", "shed_degraded": 0, "shed_rejected": 0},
//!   "queue": {"admitted": 24, "depth": 0},
//!   "seq": 3,
//!   "slo": {"status": "no-data"},
//!   "stages": {"sobel": {"cpu_ns": 0, "runs": 24, "wall_ns": 0}},
//!   "t_ns": 5100000,
//!   "tier": "cluster",
//!   "workers": [{"seq": 2, "t_ns": 5100000, "tier": "worker",
//!                "worker": 0, "...": "the worker's full line"}]
//! }
//! ```
//!
//! ## Span JSONL schema (`--trace-log trace.jsonl`, one span per line)
//!
//! Spans are sorted by `(trace, id, t0_ns)` before writing, so the
//! file's bytes are independent of thread interleaving — and under the
//! virtual clock byte-identical across replays. `parent` is `null` on
//! a trace's root span; `attrs` carries free-form strings such as the
//! cache-consult `outcome` (`hit | miss | negative | disabled`, plus
//! `offer` for a front-only warm and `modeled` on execute-off runs)
//! and the route span's worker `slot`:
//!
//! ```json
//! {
//!   "attrs": {"outcome": "miss"},
//!   "cat": "exec",
//!   "dur_ns": 1350000,
//!   "id": 4,
//!   "name": "service",
//!   "parent": 1,
//!   "t0_ns": 50000,
//!   "tid": 2,
//!   "trace": "00779c4fb295f4db00000007"
//! }
//! ```
//!
//! ## Chrome trace-event schema (`--trace-log trace.json`)
//!
//! Any non-`.jsonl` extension writes one Chrome trace-event JSON
//! document (loadable in `chrome://tracing` / Perfetto): complete
//! events (`"ph": "X"`), `ts`/`dur` in microseconds, lanes = `tid`
//! (0 = front door / intake, `n + 1` = serve lane / worker slot `n`),
//! trace identity under `args`:
//!
//! ```json
//! {
//!   "traceEvents": [
//!     {"args": {"id": 1, "parent": null, "slot": "0",
//!               "trace": "00779c4fb295f4db00000007"},
//!      "cat": "cluster", "dur": 1350.5, "name": "request", "ph": "X",
//!      "pid": 1, "tid": 0, "ts": 50}
//!   ]
//! }
//! ```
//!
//! ## Analyze report schema (`cannyd analyze <file> [--against <file>]`)
//!
//! One JSON document on stdout. `kind` sniffs the input: `spans`
//! (span JSONL), `telemetry` (snapshot JSONL) or `bench`
//! (bench-compare `BENCH_*.json`). `aggregates` maps a series name
//! (span name, telemetry series, or bench case) to exact nearest-rank
//! quantiles over its observations; `traces` and `critical_paths`
//! (the per-trace longest child chain at each depth, rendered
//! `root>child>…`, mapped to how many traces share it) appear for
//! span inputs only. With `--against`, `deltas` carries the per-name
//! comparison for every series present in both files (`delta_*_pct`
//! rounded to 0.1, positive = current slower):
//!
//! ```json
//! {
//!   "against": "baseline.jsonl",
//!   "aggregates": {"service": {"count": 40, "p50_ns": 1048000,
//!                              "p99_ns": 4123000}},
//!   "critical_paths": {"request>service>stage:sobel": 24},
//!   "deltas": {"service": {"base_p50_ns": 1000000, "base_p99_ns": 4000000,
//!                          "cur_p50_ns": 1048000, "cur_p99_ns": 4123000,
//!                          "delta_p50_pct": 4.8, "delta_p99_pct": 3.1}},
//!   "input": "trace.jsonl",
//!   "kind": "spans",
//!   "traces": 40
//! }
//! ```

pub mod analyze;
pub mod anomaly;
pub mod endpoint;
pub mod fault;
pub mod health;
pub mod merge;
pub mod registry;
pub mod sample;
pub mod snapshot;
pub mod trace;

pub use analyze::analyze;
pub use anomaly::{AnomalyAlert, AnomalyMonitor, EwmaDetector};
pub use endpoint::ObsEndpoint;
pub use fault::{FaultManager, OverloadPolicy, ShedDecision};
pub use health::{AlertSink, Health, HealthTracker, DEFAULT_STALL_AFTER_NS};
pub use merge::{merged_line, zero_line};
pub use registry::{
    Counter, Gauge, Histogram, HistogramSnapshot, LaneTelemetry, StageTally, Telemetry,
};
pub use sample::{SamplePolicy, TraceSampler};
pub use snapshot::{
    CacheProbe, ClockProbe, SloProbe, SnapshotEngine, TickInputs, WallSnapshotter,
    REQUIRED_LINE_KEYS,
};
pub use trace::{
    cluster_front_spans, content_digest, modeled_stage_durs, request_spans, service_spans, Span,
    TraceCollector, TraceId, REQUIRED_EVENT_KEYS, REQUIRED_SPAN_KEYS,
};
