//! Distributed tracing: deterministic per-request trace ids and span
//! records across tiers and processes, exported as span-JSONL or
//! Chrome trace-event JSON (`--trace-log FILE`; both schemas are
//! documented in [`crate::obs`] and lint-checked for parity).
//!
//! Determinism contract: a [`TraceId`] is derived from the request's
//! *content digest* plus its *admission sequence number* — both modeled
//! quantities — and every span in a virtual-clock run carries modeled
//! times, so two replays of the same trace write byte-identical trace
//! files. [`TraceCollector::write`] sorts the buffered spans before
//! serializing, so thread interleaving never reaches the bytes.
//!
//! Span-id layout (fixed small ids, so cross-process stitching needs
//! no id allocator): serve trees are `root(1) → batch_coalesce(2) /
//! queue_wait(3) / service(4) → cache_consult(5) / stage(6+)`; cluster
//! trees are `root(1) → route(2) / wire(3) → service(4) → …` where the
//! service subtree is produced by the *worker process* and stitched
//! under the front door's wire span via the trace context carried in
//! the request/response frames ([`crate::cluster::proto`]).

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

use crate::error::Result;
use crate::util::json::Json;

/// Span id of a request's root span (serve and cluster trees alike).
pub const SPAN_ROOT: u64 = 1;
/// Serve tree: the batch-coalesce wait under the root.
pub const SPAN_COALESCE: u64 = 2;
/// Cluster tree: the routing decision (zero duration) under the root.
pub const SPAN_ROUTE: u64 = 2;
/// Serve tree: queue wait between batch formation and lane dispatch.
pub const SPAN_QUEUE: u64 = 3;
/// Cluster tree: the wire hop (dispatch → response) under the root;
/// the worker's service subtree stitches under this id.
pub const SPAN_WIRE: u64 = 3;
/// The service span: lane execution (serve) or worker execution
/// (cluster).
pub const SPAN_SERVICE: u64 = 4;
/// The cache-consult span under the service span.
pub const SPAN_CACHE: u64 = 5;
/// First stage span id; stage `i` of a plan is `SPAN_STAGE0 + i`.
pub const SPAN_STAGE0: u64 = 6;

/// Keys every span-JSONL line carries (schema in [`crate::obs`]).
pub const REQUIRED_SPAN_KEYS: [&str; 9] =
    ["attrs", "cat", "dur_ns", "id", "name", "parent", "t0_ns", "tid", "trace"];

/// Keys every exported Chrome trace event carries — the documented key
/// set the export tests validate against.
pub const REQUIRED_EVENT_KEYS: [&str; 8] =
    ["args", "cat", "dur", "name", "ph", "pid", "tid", "ts"];

/// A deterministic trace id: content digest + admission sequence
/// number, hex-packed. Virtual-clock replays of the same trace derive
/// identical ids, which is what keeps `--trace-log` byte-identical.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TraceId(String);

impl TraceId {
    /// Derive from a content digest and the admission sequence number.
    pub fn derive(digest: u64, seq: u64) -> TraceId {
        TraceId(format!("{digest:016x}{seq:08x}"))
    }

    /// Rewrap an id received over the wire (cluster workers never
    /// re-derive — the front door owns id assignment).
    pub fn from_wire(id: &str) -> TraceId {
        TraceId(id.to_string())
    }

    pub fn as_str(&self) -> &str {
        &self.0
    }
}

/// FNV-1a 64 over a request's content identity (scene spec + shape):
/// the digest half of [`TraceId::derive`]. Deliberately independent of
/// the cluster router's placement digest — tracing must neither
/// perturb nor depend on routing.
pub fn content_digest(spec: &str, width: usize, height: usize) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let eat = |h: &mut u64, b: u8| {
        *h ^= b as u64;
        *h = h.wrapping_mul(0x100_0000_01b3);
    };
    for b in spec.bytes() {
        eat(&mut h, b);
    }
    for v in [width as u64, height as u64] {
        for b in v.to_le_bytes() {
            eat(&mut h, b);
        }
    }
    h
}

/// One completed span: a named interval in a request's trace tree.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Span {
    /// Owning trace id ([`TraceId::derive`]).
    pub trace: String,
    /// Span id, unique within the trace (see the `SPAN_*` constants).
    pub id: u64,
    /// Parent span id; `None` for the root.
    pub parent: Option<u64>,
    /// Human-readable name (`request`, `queue_wait`, `stage:sobel`, …).
    pub name: String,
    /// Coarse category (`serve`, `cluster`, `stream`, `exec`, `cache`,
    /// `stage`).
    pub cat: String,
    /// Chrome-trace lane: 0 = front door / intake, `n + 1` = serve
    /// lane, worker slot, or stream pipeline stage `n`.
    pub tid: u64,
    /// Start time in the emitting process's clock domain (modeled ns
    /// under the virtual clock, measured ns under wall).
    pub t0_ns: u64,
    pub dur_ns: u64,
    /// Free-form string attributes (`outcome`, `slot`, …).
    pub attrs: BTreeMap<String, String>,
}

impl Span {
    /// Build a span with no attributes.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        trace: &TraceId,
        id: u64,
        parent: Option<u64>,
        name: &str,
        cat: &str,
        tid: u64,
        t0_ns: u64,
        dur_ns: u64,
    ) -> Span {
        Span {
            trace: trace.as_str().to_string(),
            id,
            parent,
            name: name.to_string(),
            cat: cat.to_string(),
            tid,
            t0_ns,
            dur_ns,
            attrs: BTreeMap::new(),
        }
    }

    /// Add one string attribute (builder style).
    pub fn attr(mut self, key: &str, value: &str) -> Span {
        self.attrs.insert(key.to_string(), value.to_string());
        self
    }

    /// The span-JSONL object for this span — also the wire form spans
    /// take inside cluster `response` frames.
    pub fn to_json(&self) -> Json {
        let attrs: BTreeMap<String, Json> =
            self.attrs.iter().map(|(k, v)| (k.clone(), Json::Str(v.clone()))).collect();
        let parent = match self.parent {
            Some(p) => Json::Num(p as f64),
            None => Json::Null,
        };
        let mut m = BTreeMap::new();
        m.insert("attrs".to_string(), Json::Obj(attrs));
        m.insert("cat".to_string(), Json::Str(self.cat.clone()));
        m.insert("dur_ns".to_string(), Json::Num(self.dur_ns as f64));
        m.insert("id".to_string(), Json::Num(self.id as f64));
        m.insert("name".to_string(), Json::Str(self.name.clone()));
        m.insert("parent".to_string(), parent);
        m.insert("t0_ns".to_string(), Json::Num(self.t0_ns as f64));
        m.insert("tid".to_string(), Json::Num(self.tid as f64));
        m.insert("trace".to_string(), Json::Str(self.trace.clone()));
        Json::Obj(m)
    }

    /// Parse a wire span (inverse of [`Span::to_json`]); `None` on any
    /// missing or mistyped field.
    pub fn from_json(j: &Json) -> Option<Span> {
        let attrs = j
            .get("attrs")?
            .as_obj()?
            .iter()
            .map(|(k, v)| Some((k.clone(), v.as_str()?.to_string())))
            .collect::<Option<BTreeMap<_, _>>>()?;
        let parent = match j.get("parent")? {
            Json::Null => None,
            p => Some(p.as_f64()? as u64),
        };
        Some(Span {
            trace: j.get("trace")?.as_str()?.to_string(),
            id: j.get("id")?.as_f64()? as u64,
            parent,
            name: j.get("name")?.as_str()?.to_string(),
            cat: j.get("cat")?.as_str()?.to_string(),
            tid: j.get("tid")?.as_f64()? as u64,
            t0_ns: j.get("t0_ns")?.as_f64()? as u64,
            dur_ns: j.get("dur_ns")?.as_f64()? as u64,
            attrs,
        })
    }
}

/// One Chrome trace event for a span: a complete event (`"ph": "X"`),
/// `ts`/`dur` in microseconds per the trace-event format, lanes keyed
/// by `tid`, trace identity preserved under `args`.
fn chrome_event(s: &Span) -> Json {
    let mut args = BTreeMap::new();
    args.insert("id".to_string(), Json::Num(s.id as f64));
    let parent = match s.parent {
        Some(p) => Json::Num(p as f64),
        None => Json::Null,
    };
    args.insert("parent".to_string(), parent);
    args.insert("trace".to_string(), Json::Str(s.trace.clone()));
    for (k, v) in &s.attrs {
        args.insert(k.clone(), Json::Str(v.clone()));
    }
    let mut m = BTreeMap::new();
    m.insert("args".to_string(), Json::Obj(args));
    m.insert("cat".to_string(), Json::Str(s.cat.clone()));
    m.insert("dur".to_string(), Json::Num(s.dur_ns as f64 / 1000.0));
    m.insert("name".to_string(), Json::Str(s.name.clone()));
    m.insert("ph".to_string(), Json::Str("X".to_string()));
    m.insert("pid".to_string(), Json::Num(1.0));
    m.insert("tid".to_string(), Json::Num(s.tid as f64));
    m.insert("ts".to_string(), Json::Num(s.t0_ns as f64 / 1000.0));
    Json::Obj(m)
}

/// Thread-safe span sink behind `--trace-log FILE`. Spans buffer in
/// memory and are written once at [`TraceCollector::write`] time,
/// sorted by `(trace, id, t0_ns)` — so the file's bytes never depend
/// on thread interleaving, only on span values.
///
/// The output format follows the extension: `.jsonl` writes one
/// span-JSONL object per line; anything else writes one Chrome
/// trace-event JSON document (loadable in `chrome://tracing` /
/// Perfetto; lanes = `tid`).
#[derive(Debug)]
pub struct TraceCollector {
    path: PathBuf,
    chrome: bool,
    spans: Mutex<Vec<Span>>,
}

impl TraceCollector {
    /// `Some` collector for a non-empty path spec, `None` (tracing
    /// off) for the empty string — the `--trace-log` default.
    pub fn from_spec(path: &str) -> Option<Arc<TraceCollector>> {
        if path.is_empty() {
            return None;
        }
        Some(Arc::new(TraceCollector {
            path: PathBuf::from(path),
            chrome: !path.ends_with(".jsonl"),
            spans: Mutex::new(Vec::new()),
        }))
    }

    /// Does this collector write Chrome trace-event JSON (vs
    /// span-JSONL)?
    pub fn is_chrome(&self) -> bool {
        self.chrome
    }

    /// Buffer one span.
    pub fn record(&self, span: Span) {
        self.spans.lock().expect("trace collector poisoned").push(span);
    }

    /// Buffer a request's whole span tree.
    pub fn record_all(&self, spans: Vec<Span>) {
        self.spans.lock().expect("trace collector poisoned").extend(spans);
    }

    /// Spans buffered so far.
    pub fn len(&self) -> usize {
        self.spans.lock().expect("trace collector poisoned").len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Sort every buffered span and write the trace file (truncating).
    /// Called once at end of run.
    pub fn write(&self) -> Result<()> {
        let mut spans = self.spans.lock().expect("trace collector poisoned").clone();
        spans.sort_by(|a, b| {
            (a.trace.as_str(), a.id, a.t0_ns).cmp(&(b.trace.as_str(), b.id, b.t0_ns))
        });
        let mut out = String::new();
        if self.chrome {
            let events: Vec<Json> = spans.iter().map(chrome_event).collect();
            let mut doc = BTreeMap::new();
            doc.insert("traceEvents".to_string(), Json::Arr(events));
            out.push_str(&Json::Obj(doc).dump());
            out.push('\n');
        } else {
            for s in &spans {
                out.push_str(&s.to_json().dump());
                out.push('\n');
            }
        }
        std::fs::write(&self.path, out)?;
        Ok(())
    }
}

/// Even split of `total_ns` across `n` stages, remainder on the last —
/// the modeled per-stage durations virtual-clock traces carry (stage
/// walls are only *measured* under wall clocks, where they feed spans
/// directly).
pub fn modeled_stage_durs(total_ns: u64, n: usize) -> Vec<u64> {
    if n == 0 {
        return Vec::new();
    }
    let base = total_ns / n as u64;
    let mut durs = vec![base; n];
    *durs.last_mut().expect("n > 0") = total_ns - base * (n as u64 - 1);
    durs
}

/// The service subtree (ids [`SPAN_SERVICE`], [`SPAN_CACHE`],
/// [`SPAN_STAGE0`]` + i`) under `parent`: lane/worker execution, the
/// optional cache consult (`(outcome, dur_ns)`), and one span per
/// executed stage, laid out sequentially from `t0_ns`.
pub fn service_spans(
    trace: &TraceId,
    tid: u64,
    parent: u64,
    t0_ns: u64,
    end_ns: u64,
    cache: Option<(&str, u64)>,
    stages: &[(String, u64)],
) -> Vec<Span> {
    let dur = end_ns.saturating_sub(t0_ns);
    let mut spans =
        vec![Span::new(trace, SPAN_SERVICE, Some(parent), "service", "exec", tid, t0_ns, dur)];
    let mut cursor = t0_ns;
    if let Some((outcome, dur_ns)) = cache {
        let span = Span::new(
            trace,
            SPAN_CACHE,
            Some(SPAN_SERVICE),
            "cache_consult",
            "cache",
            tid,
            cursor,
            dur_ns,
        )
        .attr("outcome", outcome);
        spans.push(span);
        cursor += dur_ns;
    }
    for (i, (name, d)) in stages.iter().enumerate() {
        let id = SPAN_STAGE0 + i as u64;
        let name = format!("stage:{name}");
        spans.push(Span::new(trace, id, Some(SPAN_SERVICE), &name, "stage", tid, cursor, *d));
        cursor += d;
    }
    spans
}

/// The serve tier's full request tree: root, batch-coalesce and
/// queue-wait spans on the intake lane (`tid` 0), then the service
/// subtree on the executing lane's `tid`.
#[allow(clippy::too_many_arguments)]
pub fn request_spans(
    trace: &TraceId,
    lane_tid: u64,
    arrival_ns: u64,
    formed_ns: u64,
    dispatch_ns: u64,
    complete_ns: u64,
    cache: Option<(&str, u64)>,
    stages: &[(String, u64)],
) -> Vec<Span> {
    let total = complete_ns.saturating_sub(arrival_ns);
    let root = Span::new(trace, SPAN_ROOT, None, "request", "serve", 0, arrival_ns, total);
    let coalesce = Span::new(
        trace,
        SPAN_COALESCE,
        Some(SPAN_ROOT),
        "batch_coalesce",
        "serve",
        0,
        arrival_ns,
        formed_ns.saturating_sub(arrival_ns),
    );
    let queue = Span::new(
        trace,
        SPAN_QUEUE,
        Some(SPAN_ROOT),
        "queue_wait",
        "serve",
        0,
        formed_ns,
        dispatch_ns.saturating_sub(formed_ns),
    );
    let mut spans = vec![root, coalesce, queue];
    let svc = service_spans(trace, lane_tid, SPAN_ROOT, dispatch_ns, complete_ns, cache, stages);
    spans.extend(svc);
    spans
}

/// The cluster front door's half of a request tree: root, the routing
/// decision (zero duration, `slot` attribute, intake lane) and the
/// wire hop on the worker slot's lane — the span the worker's service
/// subtree stitches under (its parent id travels in the request
/// frame's trace context).
pub fn cluster_front_spans(
    trace: &TraceId,
    slot: usize,
    arrival_ns: u64,
    complete_ns: u64,
) -> Vec<Span> {
    let dur = complete_ns.saturating_sub(arrival_ns);
    let tid = slot as u64 + 1;
    vec![
        Span::new(trace, SPAN_ROOT, None, "request", "cluster", 0, arrival_ns, dur),
        Span::new(trace, SPAN_ROUTE, Some(SPAN_ROOT), "route", "cluster", 0, arrival_ns, 0)
            .attr("slot", &slot.to_string()),
        Span::new(trace, SPAN_WIRE, Some(SPAN_ROOT), "wire", "cluster", tid, arrival_ns, dur),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("canny_trace_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(format!("{}_{name}", std::process::id()))
    }

    #[test]
    fn trace_ids_are_deterministic_and_distinct() {
        let d = content_digest("synthetic:3", 96, 64);
        assert_eq!(d, content_digest("synthetic:3", 96, 64));
        assert_ne!(d, content_digest("synthetic:4", 96, 64));
        assert_ne!(d, content_digest("synthetic:3", 64, 96));
        let id = TraceId::derive(d, 7);
        assert_eq!(id, TraceId::derive(d, 7));
        assert_ne!(id, TraceId::derive(d, 8));
        assert_eq!(id.as_str().len(), 24);
        assert_eq!(TraceId::from_wire(id.as_str()), id);
    }

    #[test]
    fn modeled_durs_sum_to_total() {
        assert_eq!(modeled_stage_durs(10, 0), Vec::<u64>::new());
        assert_eq!(modeled_stage_durs(10, 3), vec![3, 3, 4]);
        assert_eq!(modeled_stage_durs(9, 3), vec![3, 3, 3]);
        let durs = modeled_stage_durs(1_000_003, 4);
        assert_eq!(durs.iter().sum::<u64>(), 1_000_003);
    }

    #[test]
    fn span_json_round_trips() {
        let trace = TraceId::derive(0xdead_beef, 3);
        let span = Span::new(&trace, SPAN_CACHE, Some(SPAN_SERVICE), "cache", "cache", 2, 50, 9)
            .attr("outcome", "negative");
        let j = span.to_json();
        for key in REQUIRED_SPAN_KEYS {
            assert!(j.get(key).is_some(), "span json missing `{key}`");
        }
        assert_eq!(Span::from_json(&j), Some(span.clone()));
        let root = Span::new(&trace, SPAN_ROOT, None, "request", "serve", 0, 0, 100);
        let j = root.to_json();
        assert_eq!(j.get("parent"), Some(&Json::Null));
        assert_eq!(Span::from_json(&j), Some(root));
    }

    #[test]
    fn chrome_events_carry_the_documented_keys() {
        let trace = TraceId::derive(1, 1);
        let spans = cluster_front_spans(&trace, 0, 50_000, 1_400_000);
        for span in &spans {
            let ev = chrome_event(span);
            for key in REQUIRED_EVENT_KEYS {
                assert!(ev.get(key).is_some(), "chrome event missing `{key}`");
            }
            assert_eq!(ev.get("ph").unwrap().as_str(), Some("X"));
            let args = ev.get("args").unwrap();
            assert_eq!(args.get("trace").unwrap().as_str(), Some(trace.as_str()));
        }
        assert_eq!(spans[1].attrs.get("slot").map(String::as_str), Some("0"));
    }

    #[test]
    fn service_subtree_is_sequential_under_the_service_span() {
        let trace = TraceId::derive(9, 0);
        let stages = vec![("gaussian".to_string(), 40), ("sobel".to_string(), 60)];
        let spans = service_spans(&trace, 2, SPAN_WIRE, 100, 210, Some(("miss", 10)), &stages);
        assert_eq!(spans.len(), 4);
        assert_eq!(spans[0].parent, Some(SPAN_WIRE));
        assert_eq!(spans[0].dur_ns, 110);
        assert_eq!((spans[1].t0_ns, spans[1].dur_ns), (100, 10));
        assert_eq!((spans[2].t0_ns, spans[2].dur_ns), (110, 40));
        assert_eq!((spans[3].t0_ns, spans[3].dur_ns), (150, 60));
        assert_eq!(spans[3].name, "stage:sobel");
        for s in &spans[1..] {
            assert_eq!(s.parent, Some(SPAN_SERVICE));
            assert_eq!(s.tid, 2);
        }
    }

    #[test]
    fn request_tree_links_to_one_root() {
        let trace = TraceId::derive(5, 2);
        let stages = vec![("full".to_string(), 100)];
        let spans = request_spans(&trace, 1, 10, 30, 50, 150, None, &stages);
        assert_eq!(spans[0].id, SPAN_ROOT);
        assert_eq!(spans[0].parent, None);
        for s in &spans[1..] {
            assert!(s.parent.is_some());
        }
        let queue = spans.iter().find(|s| s.name == "queue_wait").unwrap();
        assert_eq!((queue.t0_ns, queue.dur_ns), (30, 20));
        let service = spans.iter().find(|s| s.id == SPAN_SERVICE).unwrap();
        assert_eq!(service.tid, 1);
        assert_eq!(service.parent, Some(SPAN_ROOT));
    }

    #[test]
    fn collector_writes_are_sorted_and_deterministic() {
        let trace_a = TraceId::derive(1, 0);
        let trace_b = TraceId::derive(1, 1);
        let path = tmp("sorted.jsonl");
        let write = |flipped: bool| {
            let c = TraceCollector::from_spec(path.to_str().unwrap()).unwrap();
            let mut spans = vec![
                Span::new(&trace_b, SPAN_ROOT, None, "request", "serve", 0, 40, 10),
                Span::new(&trace_a, SPAN_ROOT, None, "request", "serve", 0, 0, 10),
                Span::new(&trace_a, SPAN_SERVICE, Some(SPAN_ROOT), "service", "exec", 1, 2, 8),
            ];
            if flipped {
                spans.reverse();
            }
            c.record_all(spans);
            c.write().unwrap();
            std::fs::read_to_string(&path).unwrap()
        };
        let a = write(false);
        let b = write(true);
        assert_eq!(a, b, "record order must not reach the bytes");
        let lines: Vec<&str> = a.lines().collect();
        assert_eq!(lines.len(), 3);
        let first = Json::parse(lines[0]).unwrap();
        assert_eq!(first.get("trace").unwrap().as_str(), Some(trace_a.as_str()));
    }

    #[test]
    fn chrome_export_is_one_document() {
        let path = tmp("chrome.json");
        let c = TraceCollector::from_spec(path.to_str().unwrap()).unwrap();
        assert!(c.is_chrome());
        assert!(c.is_empty());
        let trace = TraceId::derive(3, 0);
        c.record(Span::new(&trace, SPAN_ROOT, None, "request", "serve", 0, 0, 10));
        assert_eq!(c.len(), 1);
        c.write().unwrap();
        let doc = Json::parse(std::fs::read_to_string(&path).unwrap().trim()).unwrap();
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(events.len(), 1);
        for key in REQUIRED_EVENT_KEYS {
            assert!(events[0].get(key).is_some(), "missing `{key}`");
        }
    }

    #[test]
    fn empty_spec_disables_tracing() {
        assert!(TraceCollector::from_spec("").is_none());
        let c = TraceCollector::from_spec("t.jsonl").unwrap();
        assert!(!c.is_chrome());
    }
}
