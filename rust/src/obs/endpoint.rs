//! `--obs-port`: a loopback TCP endpoint serving the tier's current
//! snapshot line. Protocol: connect → read — the server writes its
//! response and closes the connection. No HTTP, no request parsing —
//! `nc` or `bash -c 'cat </dev/tcp/127.0.0.1/PORT'` is a complete
//! client.
//!
//! **Framing** (newline-delimited, 0–2 lines then EOF):
//!
//! * line 1 — the newest snapshot JSON line;
//! * line 2 — present only when the run has alerted: the newest alert
//!   line (`ALERT …`, health transition or anomaly), distinguishable
//!   from line 1 by its non-`{` first byte.
//!
//! Before anything has been published the server closes the
//! connection without writing a byte (clean EOF, zero lines) — never
//! an empty line a parser would trip over.
//!
//! The endpoint is a *window*, not a log: it always serves the latest
//! published state, so polling it never perturbs the `--telemetry-log`
//! stream (whose bytes stay replay-deterministic). The accept thread
//! polls a nonblocking listener and so needs no clock reads — the
//! pallas-lint clock-purity allowlist stays unchanged.

use std::io::Write;
use std::net::TcpListener;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::{self, JoinHandle};
use std::time::Duration;

use crate::error::Result;

/// The accept loop: serve the latest snapshot line (plus the latest
/// alert line when one exists) to each connection, close, and re-check
/// the stop flag between polls. Empty state closes without writing
/// (see the module docs for the framing).
fn serve_loop(
    listener: TcpListener,
    latest: Arc<Mutex<String>>,
    latest_alert: Arc<Mutex<String>>,
    stop: Arc<AtomicBool>,
) {
    while !stop.load(Ordering::Acquire) {
        match listener.accept() {
            Ok((mut conn, _)) => {
                let line = latest.lock().expect("obs endpoint poisoned").clone();
                if line.is_empty() {
                    continue;
                }
                let _ = conn.write_all(line.as_bytes());
                let _ = conn.write_all(b"\n");
                let alert = latest_alert.lock().expect("obs endpoint poisoned").clone();
                if !alert.is_empty() {
                    let _ = conn.write_all(alert.as_bytes());
                    let _ = conn.write_all(b"\n");
                }
            }
            // WouldBlock (no pending connection) and transient accept
            // errors both back off the same way.
            Err(_) => thread::sleep(Duration::from_millis(5)),
        }
    }
}

/// A live snapshot endpoint on loopback TCP (see the module docs for
/// the wire protocol). Created by [`ObsEndpoint::start`]; any tier
/// publishes its current snapshot line via [`ObsEndpoint::publish`].
#[derive(Debug)]
pub struct ObsEndpoint {
    port: u16,
    latest: Arc<Mutex<String>>,
    latest_alert: Arc<Mutex<String>>,
    stop: Arc<AtomicBool>,
    handle: Mutex<Option<JoinHandle<()>>>,
}

impl ObsEndpoint {
    /// Bind `127.0.0.1:port` (0 = OS-assigned, see
    /// [`ObsEndpoint::port`]) and start the accept thread.
    pub fn start(port: u16) -> Result<Arc<ObsEndpoint>> {
        let listener = TcpListener::bind(("127.0.0.1", port))?;
        listener.set_nonblocking(true)?;
        let port = listener.local_addr()?.port();
        let latest = Arc::new(Mutex::new(String::new()));
        let latest_alert = Arc::new(Mutex::new(String::new()));
        let stop = Arc::new(AtomicBool::new(false));
        let thread_latest = Arc::clone(&latest);
        let thread_alert = Arc::clone(&latest_alert);
        let thread_stop = Arc::clone(&stop);
        let handle = thread::Builder::new()
            .name("obs-endpoint".to_string())
            .spawn(move || serve_loop(listener, thread_latest, thread_alert, thread_stop))?;
        Ok(Arc::new(ObsEndpoint {
            port,
            latest,
            latest_alert,
            stop,
            handle: Mutex::new(Some(handle)),
        }))
    }

    /// Replace the snapshot line served to subsequent connections.
    pub fn publish(&self, line: &str) {
        *self.latest.lock().expect("obs endpoint poisoned") = line.to_string();
    }

    /// Replace the alert line served (as line 2) to subsequent
    /// connections.
    pub fn publish_alert(&self, line: &str) {
        *self.latest_alert.lock().expect("obs endpoint poisoned") = line.to_string();
    }

    /// The bound port — the OS-assigned one when `start` was given 0.
    pub fn port(&self) -> u16 {
        self.port
    }

    /// Stop and join the accept thread. Idempotent; also runs on drop.
    pub fn stop(&self) {
        self.stop.store(true, Ordering::Release);
        if let Some(handle) = self.handle.lock().expect("obs endpoint poisoned").take() {
            let _ = handle.join();
        }
    }
}

impl Drop for ObsEndpoint {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Endpoint for a config's `obs-port` value: `None` when the port is 0
/// (the flag's default — endpoint disabled).
pub fn from_config_port(port: u16) -> Result<Option<Arc<ObsEndpoint>>> {
    if port == 0 {
        return Ok(None);
    }
    Ok(Some(ObsEndpoint::start(port)?))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Read;
    use std::net::TcpStream;

    fn fetch(port: u16) -> String {
        let mut line = String::new();
        TcpStream::connect(("127.0.0.1", port)).unwrap().read_to_string(&mut line).unwrap();
        line
    }

    #[test]
    fn endpoint_serves_the_latest_line_per_connection() {
        let ep = ObsEndpoint::start(0).unwrap();
        assert_ne!(ep.port(), 0);
        // Nothing published yet: clean close, zero bytes — not an
        // empty line.
        assert_eq!(fetch(ep.port()), "");
        ep.publish("{\"tier\": \"serve\"}");
        assert_eq!(fetch(ep.port()), "{\"tier\": \"serve\"}\n");
        ep.publish("{\"tier\": \"cluster\"}");
        assert_eq!(fetch(ep.port()), "{\"tier\": \"cluster\"}\n");
        ep.stop();
        ep.stop();
    }

    #[test]
    fn alert_line_rides_second() {
        let ep = ObsEndpoint::start(0).unwrap();
        ep.publish("{\"tier\": \"serve\"}");
        ep.publish_alert("ALERT t_ns=5 scope=anomaly:queue_depth z=4.00");
        assert_eq!(
            fetch(ep.port()),
            "{\"tier\": \"serve\"}\nALERT t_ns=5 scope=anomaly:queue_depth z=4.00\n"
        );
        // An alert with no snapshot line still closes cleanly empty:
        // the snapshot line frames the response.
        let ep2 = ObsEndpoint::start(0).unwrap();
        ep2.publish_alert("ALERT t_ns=1 scope=serve from=healthy to=degraded");
        assert_eq!(fetch(ep2.port()), "");
    }

    #[test]
    fn port_zero_in_config_means_disabled() {
        assert!(from_config_port(0).unwrap().is_none());
    }
}
