//! The process-wide telemetry registry: lock-free counters, gauges and
//! fixed-bucket latency histograms that the serve lanes, the stream
//! executor and the artifact cache publish into while they run.
//!
//! Everything here is written on hot paths, so the primitives are
//! `Relaxed` atomics (the same discipline as
//! [`crate::cache::stats::CacheStats`]): totals are exact whenever a
//! snapshot is taken after the publishing threads have quiesced, and
//! under the single-threaded virtual driver every intermediate snapshot
//! is exact too — which is what makes telemetry ticks byte-identical
//! across deterministic replays.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// A monotonically increasing event count.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A last-writer-wins instantaneous value (queue depth, heartbeat).
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }

    /// Raise the gauge to `v` if above the current value (high-water
    /// marks).
    pub fn raise(&self, v: u64) {
        self.0.fetch_max(v, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn sub(&self, n: u64) {
        // Saturating: a racy decrement below zero must not wrap to
        // u64::MAX in a live gauge.
        let mut cur = self.0.load(Ordering::Relaxed);
        loop {
            let next = cur.saturating_sub(n);
            match self.0.compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed) {
                Ok(_) => return,
                Err(seen) => cur = seen,
            }
        }
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Number of power-of-two buckets: bucket `i` counts values in
/// `[2^i, 2^(i+1))` nanoseconds (bucket 0 also holds zero), so the full
/// `u64` range is covered with no configuration.
pub const HIST_BUCKETS: usize = 64;

/// A fixed-bucket latency histogram. Recording is one atomic add; the
/// quantiles read out of a snapshot are *bucket-resolution
/// approximations* (the bucket's inclusive upper bound), while `count`,
/// `sum`/`mean` and `max` are exact.
///
/// Each bucket may additionally carry an **exemplar** — the trace id
/// and value of the worst observation that landed in it
/// ([`Histogram::note_exemplar`]) — linking the metric back to a
/// concrete retrievable trace. Exemplars live behind a `Mutex` (trace
/// ids are strings), so they are noted only for *sampled* requests —
/// at trace-retention granularity, never per hot-path record.
#[derive(Debug)]
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_ns: AtomicU64,
    max_ns: AtomicU64,
    exemplars: Mutex<BTreeMap<usize, (String, u64)>>,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram {
            buckets: (0..HIST_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
            max_ns: AtomicU64::new(0),
            exemplars: Mutex::new(BTreeMap::new()),
        }
    }
}

fn bucket_of(ns: u64) -> usize {
    if ns == 0 {
        0
    } else {
        (63 - ns.leading_zeros() as usize).min(HIST_BUCKETS - 1)
    }
}

/// Inclusive upper bound of bucket `i` (what approximate quantiles
/// report).
fn bucket_hi(i: usize) -> u64 {
    if i >= 63 {
        u64::MAX
    } else {
        (1u64 << (i + 1)) - 1
    }
}

impl Histogram {
    pub fn record(&self, ns: u64) {
        self.buckets[bucket_of(ns)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
        self.max_ns.fetch_max(ns, Ordering::Relaxed);
    }

    /// Attach an exemplar to `ns`'s bucket: the bucket remembers the
    /// worst (highest-value) observation it has seen and the trace id
    /// that produced it. Does **not** touch the counts — callers still
    /// [`Histogram::record`] every observation; exemplars are noted
    /// only for observations whose trace the tail sampler retained, so
    /// every exported exemplar resolves to a trace in `--trace-log`.
    pub fn note_exemplar(&self, ns: u64, trace: &str) {
        let mut map = self.exemplars.lock().expect("exemplars poisoned");
        let slot = map.entry(bucket_of(ns)).or_insert_with(|| (trace.to_string(), ns));
        if ns >= slot.1 {
            *slot = (trace.to_string(), ns);
        }
    }

    pub fn snapshot(&self) -> HistogramSnapshot {
        let exemplars = self
            .exemplars
            .lock()
            .expect("exemplars poisoned")
            .iter()
            .map(|(&i, (trace, ns))| (bucket_hi(i), (trace.clone(), *ns)))
            .collect();
        HistogramSnapshot {
            counts: self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect(),
            count: self.count.load(Ordering::Relaxed),
            sum_ns: self.sum_ns.load(Ordering::Relaxed),
            max_ns: self.max_ns.load(Ordering::Relaxed),
            exemplars,
        }
    }
}

/// A point-in-time copy of a [`Histogram`].
#[derive(Clone, Debug, Default)]
pub struct HistogramSnapshot {
    pub counts: Vec<u64>,
    pub count: u64,
    pub sum_ns: u64,
    pub max_ns: u64,
    /// Worst observation per bucket, keyed by the bucket's inclusive
    /// upper bound: `bucket_hi -> (trace id, observed ns)`.
    pub exemplars: BTreeMap<u64, (String, u64)>,
}

impl HistogramSnapshot {
    /// Approximate quantile: the inclusive upper bound of the bucket
    /// holding the nearest-rank sample (0 with no samples). Never
    /// reports above the exact observed `max_ns`.
    ///
    /// **Error bound.** Buckets are powers of two (`[2^i, 2^{i+1})`),
    /// so the reported value can only over-estimate, and by strictly
    /// less than one bucket: for a true nearest-rank sample `v ≥ 1`,
    /// `v ≤ reported ≤ 2v − 1` — an over-estimate of under 100%, i.e.
    /// correct to within a factor of two (and exact whenever the
    /// nearest-rank sample is the observed max, thanks to the `max_ns`
    /// clamp). Tested in `quantile_error_is_bounded_by_one_bucket`.
    pub fn quantile_ns(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((self.count - 1) as f64 * q).round() as u64;
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen > rank {
                return bucket_hi(i).min(self.max_ns);
            }
        }
        self.max_ns
    }

    pub fn mean_ns(&self) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        self.sum_ns as f64 / self.count as f64
    }
}

/// Live per-lane registers: the serve lanes (or, for the stream tier,
/// the pipeline stages) publish into one of these each.
#[derive(Debug, Default)]
pub struct LaneTelemetry {
    /// Requests currently executing on the lane.
    pub inflight: Gauge,
    /// Requests completed by the lane.
    pub completed: Counter,
    /// Batches dispatched to the lane.
    pub batches: Counter,
    /// Modeled/measured busy nanoseconds.
    pub busy_ns: Counter,
    /// Clock reading (virtual or wall, per the driver) of the lane's
    /// last sign of life: a dispatch or a completion. Health derivation
    /// ([`crate::obs::health`]) compares it against now.
    pub heartbeat_ns: Gauge,
}

/// One stage span's running totals (keyed by
/// [`crate::canny::StageRecord::span_name`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StageTally {
    pub wall_ns: u64,
    pub cpu_ns: u64,
    pub runs: u64,
}

/// The registry one tier (serve run or stream run) publishes into.
///
/// Shared as an `Arc` between lane/stage threads and the snapshot
/// engine under wall clocks; plainly owned by the single-threaded
/// virtual driver. The snapshot engine
/// ([`crate::obs::snapshot::SnapshotEngine`]) turns this into one
/// JSONL line per tick.
#[derive(Debug)]
pub struct Telemetry {
    /// `"serve"` or `"stream"` — echoed on every snapshot line.
    pub tier: &'static str,
    /// Instantaneous admission-queue occupancy.
    pub queue_depth: Gauge,
    /// Highest occupancy seen.
    pub queue_high_water: Gauge,
    /// Requests (or frames) that arrived, whatever their fate.
    pub offered: Counter,
    /// Requests admitted past the queue (frames entering the pipeline).
    pub admitted: Counter,
    /// All rejections: queue-full + oversize + shed.
    pub rejected: Counter,
    /// Completed requests (emitted frames).
    pub completed: Counter,
    /// Overload decisions: arrivals turned away by the fault manager
    /// (serve `reject-new`) or frames dropped at their deadline
    /// (stream `drop`).
    pub shed_rejected: Counter,
    /// Overload decisions: work completed in degraded form — serve
    /// `degrade-to-front-only` rewrites, stream `degrade` emissions.
    pub shed_degraded: Counter,
    /// Health-transition alert lines emitted by the run's
    /// [`crate::obs::health::HealthTracker`] (`--alert-log`).
    pub alerts: Counter,
    /// Cumulative completion latency (request enqueue→complete, or
    /// frame capture→emit).
    pub latency: Histogram,
    /// One register per serve lane; for the stream tier, one per
    /// pipeline stage (decode, front, finish).
    pub lanes: Vec<LaneTelemetry>,
    /// Delta-gate tiles served from the temporal cache (stream).
    pub gate_tiles_clean: Counter,
    /// Delta-gate tiles recomputed (stream).
    pub gate_tiles_dirty: Counter,
    /// Per-stage wall/cpu/run aggregates. A `Mutex` (not a lock-free
    /// map) because stages complete at batch granularity — a few locks
    /// per batch, never per pixel.
    stages: Mutex<BTreeMap<String, StageTally>>,
}

impl Telemetry {
    pub fn new(tier: &'static str, lanes: usize) -> Telemetry {
        Telemetry {
            tier,
            queue_depth: Gauge::default(),
            queue_high_water: Gauge::default(),
            offered: Counter::default(),
            admitted: Counter::default(),
            rejected: Counter::default(),
            completed: Counter::default(),
            shed_rejected: Counter::default(),
            shed_degraded: Counter::default(),
            alerts: Counter::default(),
            latency: Histogram::default(),
            lanes: (0..lanes).map(|_| LaneTelemetry::default()).collect(),
            gate_tiles_clean: Counter::default(),
            gate_tiles_dirty: Counter::default(),
            stages: Mutex::new(BTreeMap::new()),
        }
    }

    pub fn lane(&self, i: usize) -> &LaneTelemetry {
        &self.lanes[i]
    }

    /// Fold one executed stage span into the per-stage aggregates.
    /// Virtual replays pass zero wall/cpu (measured times are not
    /// deterministic; run counts are).
    pub fn note_stage(&self, name: &str, wall_ns: u64, cpu_ns: u64) {
        let mut map = self.stages.lock().expect("stage tallies poisoned");
        let t = map.entry(name.to_string()).or_default();
        t.wall_ns += wall_ns;
        t.cpu_ns += cpu_ns;
        t.runs += 1;
    }

    pub fn stage_tallies(&self) -> BTreeMap<String, StageTally> {
        self.stages.lock().expect("stage tallies poisoned").clone()
    }

    /// Gate hit rate so far (0 when nothing was gated).
    pub fn gate_hit_rate(&self) -> f64 {
        let clean = self.gate_tiles_clean.get();
        let total = clean + self.gate_tiles_dirty.get();
        if total == 0 {
            return 0.0;
        }
        clean as f64 / total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge_basics() {
        let c = Counter::default();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        let g = Gauge::default();
        g.set(7);
        g.raise(3);
        assert_eq!(g.get(), 7);
        g.raise(9);
        assert_eq!(g.get(), 9);
        g.add(2);
        g.sub(100);
        assert_eq!(g.get(), 0, "gauge decrement saturates at zero");
    }

    #[test]
    fn histogram_buckets_cover_u64() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 0);
        assert_eq!(bucket_of(2), 1);
        assert_eq!(bucket_of(3), 1);
        assert_eq!(bucket_of(1024), 10);
        assert_eq!(bucket_of(u64::MAX), 63);
        assert_eq!(bucket_hi(0), 1);
        assert_eq!(bucket_hi(10), 2047);
        assert_eq!(bucket_hi(63), u64::MAX);
    }

    #[test]
    fn histogram_quantiles_approximate_within_bucket() {
        let h = Histogram::default();
        for ns in [100u64, 200, 300, 400, 1_000_000] {
            h.record(ns);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 5);
        assert_eq!(s.max_ns, 1_000_000);
        assert!((s.mean_ns() - 200_200.0).abs() < 1e-9);
        // p50 falls in the [256,512) bucket -> reports 511.
        assert_eq!(s.quantile_ns(0.5), 511);
        // p99 -> the max sample's bucket, clamped to the exact max.
        assert_eq!(s.quantile_ns(0.99), 1_000_000);
        // Empty histogram.
        assert_eq!(HistogramSnapshot::default().quantile_ns(0.5), 0);
        assert_eq!(HistogramSnapshot::default().mean_ns(), 0.0);
    }

    #[test]
    fn quantile_error_is_bounded_by_one_bucket() {
        // For every scale and fill pattern: the reported quantile
        // never under-estimates the true nearest-rank sample and
        // never reaches 2x it (power-of-two buckets over-estimate by
        // strictly less than one bucket), documented on quantile_ns.
        for shift in 0..20u32 {
            let h = Histogram::default();
            let mut samples: Vec<u64> = (1..=17u64).map(|k| (k << shift) + k % 3).collect();
            for &ns in &samples {
                h.record(ns);
            }
            samples.sort_unstable();
            let s = h.snapshot();
            for q in [0.0, 0.5, 0.9, 0.99, 1.0] {
                let rank = ((s.count - 1) as f64 * q).round() as usize;
                let truth = samples[rank];
                let reported = s.quantile_ns(q);
                assert!(
                    reported >= truth,
                    "q={q} shift={shift}: reported {reported} under-estimates {truth}"
                );
                assert!(
                    reported < 2 * truth,
                    "q={q} shift={shift}: reported {reported} >= 2x true {truth}"
                );
            }
        }
    }

    #[test]
    fn exemplars_keep_the_worst_observation_per_bucket() {
        let h = Histogram::default();
        for ns in [100u64, 300, 310, 5_000] {
            h.record(ns);
        }
        // 300 and 310 share the [256,512) bucket: the worse one wins.
        h.note_exemplar(300, "trace-a");
        h.note_exemplar(310, "trace-b");
        h.note_exemplar(5_000, "trace-c");
        let s = h.snapshot();
        assert_eq!(s.exemplars.len(), 2);
        assert_eq!(s.exemplars[&511], ("trace-b".to_string(), 310));
        assert_eq!(s.exemplars[&8191], ("trace-c".to_string(), 5_000));
        // Counts are untouched by exemplar notes.
        assert_eq!(s.count, 4);
        // Ties resolve to the latest writer (replay-stable ordering).
        h.note_exemplar(310, "trace-d");
        assert_eq!(h.snapshot().exemplars[&511], ("trace-d".to_string(), 310));
    }

    #[test]
    fn quantile_never_exceeds_max() {
        let h = Histogram::default();
        h.record(1_025);
        let s = h.snapshot();
        // Bucket hi is 2047 but the only sample is 1025.
        assert_eq!(s.quantile_ns(1.0), 1_025);
    }

    #[test]
    fn telemetry_registers() {
        let t = Telemetry::new("serve", 2);
        assert_eq!(t.tier, "serve");
        assert_eq!(t.lanes.len(), 2);
        t.lane(0).inflight.add(3);
        t.lane(0).completed.add(3);
        t.lane(0).inflight.sub(3);
        assert_eq!(t.lane(0).inflight.get(), 0);
        assert_eq!(t.lane(0).completed.get(), 3);
        t.note_stage("gaussian", 10, 8);
        t.note_stage("gaussian", 5, 4);
        let stages = t.stage_tallies();
        assert_eq!(stages["gaussian"], StageTally { wall_ns: 15, cpu_ns: 12, runs: 2 });
        assert_eq!(t.gate_hit_rate(), 0.0);
        t.gate_tiles_clean.add(3);
        t.gate_tiles_dirty.add(1);
        assert!((t.gate_hit_rate() - 0.75).abs() < 1e-12);
    }
}
