//! The pipeline-parallel frame executor: decode → delta-gated front →
//! finish (threshold + hysteresis), each stage on its own thread with a
//! bounded in-flight window, ordered emission, and an optional
//! real-time frame budget with drop/degrade handling for late frames.
//!
//! Built on [`crate::patterns::pipeline::pipeline_stages`] — the
//! dynamic generalization of the fixed-arity `pipeline3` the old video
//! example hand-rolled — with the front stage farming dirty tiles over
//! the shared [`crate::scheduler::Pool`] (pipeline across stages, farm
//! within a frame: the paper's two patterns composed).

use std::collections::BTreeMap;
use std::time::Duration;

use crate::canny::{CannyParams, Engine, StageKind, StagePlan, StageRecord};
use crate::config::RunConfig;
use crate::coordinator::Detector;
use crate::error::{Error, Result};
use crate::image::EdgeMap;
use crate::patterns::pipeline::{pipeline_stages, DynStage};
use crate::service::LatencyStats;
use crate::stream::delta::{DeltaGate, DeltaMode};
use crate::stream::report::{GateReport, StreamReport};
use crate::stream::source::FrameSource;
use crate::util::timer::Stopwatch;

/// What to do with a frame that is already past its deadline when the
/// front stage dequeues it (real-time mode only).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DropPolicy {
    /// Skip it entirely: no front, no finish, no emission.
    Drop,
    /// Emit a degraded frame: reuse the last computed suppressed map
    /// wholesale (skip the front) and run only threshold + hysteresis.
    /// The map is kept by the executor, so this works with the delta
    /// gate off too; falls back to full processing when no map exists
    /// yet.
    Degrade,
    /// Process anyway; lateness is only counted.
    Keep,
}

impl DropPolicy {
    /// Parse a `--drop-policy` value.
    pub fn parse(s: &str) -> Option<DropPolicy> {
        match s {
            "drop" => Some(DropPolicy::Drop),
            "degrade" => Some(DropPolicy::Degrade),
            "none" | "keep" => Some(DropPolicy::Keep),
            _ => None,
        }
    }

    /// Config / report name.
    pub fn name(&self) -> &'static str {
        match self {
            DropPolicy::Drop => "drop",
            DropPolicy::Degrade => "degrade",
            DropPolicy::Keep => "none",
        }
    }
}

/// Stream-run configuration (the `cannyd stream` flag set).
#[derive(Clone, Debug)]
pub struct StreamOptions {
    /// Bounded in-flight window: capacity of each inter-stage queue.
    pub inflight: usize,
    /// Temporal delta-gating mode.
    pub delta: DeltaMode,
    /// Real-time frame budget in ns (0 = offline: process everything,
    /// as fast as possible, no deadlines).
    pub frame_budget_ns: u64,
    /// Late-frame handling under a budget.
    pub drop_policy: DropPolicy,
    /// Detection parameters. The stream tier reads thresholds from
    /// *here* (they feed the global finish pass), not from the
    /// detector's own defaults — embedders with custom `lo`/`hi` must
    /// set them on these options.
    pub params: CannyParams,
    /// Keep each emitted frame's [`EdgeMap`] in the outcome (tests,
    /// embedding programs); the CLI leaves this off.
    pub keep_edges: bool,
}

impl StreamOptions {
    /// Build from the resolved [`RunConfig`] (the CLI path).
    pub fn from_config(cfg: &RunConfig) -> StreamOptions {
        StreamOptions {
            inflight: cfg.inflight,
            delta: cfg.delta_gate,
            frame_budget_ns: (cfg.frame_budget_ms * 1e6) as u64,
            drop_policy: cfg.drop_policy,
            params: cfg.params,
            keep_edges: false,
        }
    }
}

impl Default for StreamOptions {
    fn default() -> Self {
        StreamOptions {
            inflight: 4,
            delta: DeltaMode::default(),
            frame_budget_ns: 0,
            drop_policy: DropPolicy::Drop,
            params: CannyParams::default(),
            keep_edges: false,
        }
    }
}

/// Per-frame result in source order.
#[derive(Clone, Debug)]
pub struct FrameResult {
    pub index: usize,
    /// Skipped entirely (late under [`DropPolicy::Drop`]).
    pub dropped: bool,
    /// Emitted from the cached suppressed map without a front pass.
    pub degraded: bool,
    /// Past its deadline at front entry (any policy).
    pub late: bool,
    /// Counted toward the gate hit-rate (a reference frame existed).
    pub gated: bool,
    pub tiles_clean: usize,
    pub tiles_dirty: usize,
    pub edge_pixels: u64,
    /// Present for emitted frames when
    /// [`StreamOptions::keep_edges`] was set.
    pub edges: Option<EdgeMap>,
}

/// Everything a stream run produced: the aggregate report plus the
/// ordered per-frame results.
#[derive(Clone, Debug)]
pub struct StreamOutcome {
    pub report: StreamReport,
    pub frames: Vec<FrameResult>,
}

/// The pipeline's uniform message (see
/// [`crate::patterns::pipeline::pipeline_stages`]): stages fill it in
/// as the frame moves decode → front → finish.
struct Slot {
    index: usize,
    image: Option<crate::image::ImageF32>,
    nm: Option<crate::image::ImageF32>,
    pixels: u64,
    deadline_ns: u64,
    decode_ns: u64,
    emit_ns: u64,
    dropped: bool,
    degraded: bool,
    late: bool,
    gated: bool,
    clean: usize,
    dirty: usize,
    edge_pixels: u64,
    edges: Option<EdgeMap>,
    records: Vec<StageRecord>,
    error: Option<Error>,
}

/// Run a frame stream through the detector. The three stages run
/// pipeline-parallel with at most `opts.inflight` frames queued between
/// consecutive stages; emission is in frame order.
pub fn run_stream(
    label: &str,
    source: &FrameSource,
    det: &Detector,
    opts: &StreamOptions,
) -> Result<StreamOutcome> {
    if opts.inflight == 0 {
        return Err(Error::Config("inflight must be >= 1".into()));
    }
    // The delta-gated front recomputes dirty tiles through the native
    // fused-tile path (there is no per-tile XLA gate executable), so an
    // XLA detector would silently run on CPU while the report claimed
    // otherwise — reject it instead of mislabeling.
    if det.engine() == Engine::PatternsXla {
        return Err(Error::Config(
            "the stream tier does not support the xla engine (the delta-gated front \
             recomputes tiles natively); use serial | patterns | tiled"
                .into(),
        ));
    }
    opts.params.validate()?;
    let n = source.len();
    let budget = opts.frame_budget_ns;
    let t0 = Stopwatch::start();

    // -- Stage 1 (source thread): acquire + decode, paced to the frame
    //    budget like a camera: frame k becomes available at k*budget.
    let inputs = (0..n).map(move |k| {
        if budget > 0 {
            let target = k as u64 * budget;
            let now = t0.elapsed_ns();
            if now < target {
                std::thread::sleep(Duration::from_nanos(target - now));
            }
        }
        let sw = Stopwatch::start();
        let (image, pixels, error) = match source.frame(k) {
            Ok(img) => {
                let px = img.len() as u64;
                (Some(img), px, None)
            }
            Err(e) => (None, 0, Some(e)),
        };
        Slot {
            index: k,
            image,
            nm: None,
            pixels,
            deadline_ns: if budget > 0 { (k as u64 + 1) * budget } else { 0 },
            decode_ns: sw.elapsed_ns(),
            emit_ns: 0,
            dropped: false,
            degraded: false,
            late: false,
            gated: false,
            clean: 0,
            dirty: 0,
            edge_pixels: 0,
            edges: None,
            records: Vec::new(),
            error,
        }
    });

    // -- Stage 2 (own thread): the delta-gated front. Dirty tiles farm
    //    over the detector's pool unless the engine is Serial. The
    //    front records carry the engine that actually executed them
    //    (the fused native tile path); the report's top-level `engine`
    //    is the detector engine, which drives the finish stages.
    let pool = if det.engine() != Engine::Serial { Some(det.pool()) } else { None };
    let front_engine =
        if pool.is_some() { Engine::TiledPatterns } else { Engine::Serial };
    let mut gate = DeltaGate::new(opts.delta);
    // The degrade path's stale-frame source. Owned by the executor —
    // not the gate — so degrading works with `--delta-gate off` too;
    // maintained only when the policy can use it.
    let mut degrade_nm: Option<crate::image::ImageF32> = None;
    let drop_policy = opts.drop_policy;
    let front: DynStage<Slot> = Box::new(move |mut s: Slot| {
        if s.error.is_some() {
            return s;
        }
        let img = s.image.take().expect("decoded frame present");
        if s.deadline_ns > 0 && t0.elapsed_ns() > s.deadline_ns {
            s.late = true;
            match drop_policy {
                DropPolicy::Drop => {
                    s.dropped = true;
                    return s;
                }
                DropPolicy::Degrade => {
                    // Prefer the gate's own cache; the executor-owned
                    // copy exists only for the gate-off case.
                    if let Some(nm) = gate.cached_nm().or(degrade_nm.as_ref()) {
                        if nm.width() == img.width() && nm.height() == img.height() {
                            s.nm = Some(nm.clone());
                            s.degraded = true;
                            return s;
                        }
                    }
                    // No usable map yet: compute normally below.
                }
                DropPolicy::Keep => {}
            }
        }
        match gate.advance(pool, img) {
            Ok(run) => {
                s.clean = run.clean;
                s.dirty = run.dirty;
                s.gated = run.gated;
                s.records.push(StageRecord {
                    kind: StageKind::Nms,
                    fused_from: Some(StageKind::Pad),
                    engine: front_engine,
                    wall_ns: run.wall_ns,
                    cpu_ns: run.cpu_ns,
                    tasks: run.task_costs_ns.len() as u64,
                    task_costs_ns: run.task_costs_ns,
                });
                if drop_policy == DropPolicy::Degrade && !gate.mode().is_on() {
                    degrade_nm = Some(run.nm.clone());
                }
                s.nm = Some(run.nm);
            }
            Err(e) => s.error = Some(e),
        }
        s
    });

    // -- Stage 3 (collector thread): global threshold + hysteresis from
    //    the stitched suppressed map, through the stage-graph API.
    let params = opts.params;
    let keep_edges = opts.keep_edges;
    let finish: DynStage<Slot> = Box::new(move |mut s: Slot| {
        if s.error.is_some() || s.dropped {
            return s;
        }
        let nm = s.nm.take().expect("front produced a suppressed map");
        let plan = StagePlan::new().from_suppressed(nm);
        match det.run_plan(&plan, None, &params) {
            Ok(mut out) => {
                s.records.append(&mut out.records);
                match out.take_edges() {
                    Some(edges) => {
                        s.edge_pixels = edges.count_edges() as u64;
                        if keep_edges {
                            s.edges = Some(edges);
                        }
                        s.emit_ns = t0.elapsed_ns();
                    }
                    None => {
                        s.error = Some(Error::Config(
                            "finish plan yielded no edge map".into(),
                        ))
                    }
                }
            }
            Err(e) => s.error = Some(e),
        }
        s
    });

    let slots = pipeline_stages(inputs, opts.inflight, vec![front, finish]);
    let wall_ns = t0.elapsed_ns();

    // -- Fold the ordered slots into the report.
    let mut report = StreamReport {
        label: label.to_string(),
        source: source.describe(),
        engine: det.engine().name().to_string(),
        workers: det.n_workers(),
        inflight: opts.inflight,
        frames_offered: n as u64,
        frames_emitted: 0,
        dropped: 0,
        degraded: 0,
        late: 0,
        wall_ns,
        pixels: 0,
        edge_pixels: 0,
        gate: GateReport {
            mode: opts.delta.name(),
            tiles_clean: 0,
            tiles_dirty: 0,
            frames_gated: 0,
            frames_full: 0,
        },
        frame_budget_ns: budget,
        drop_policy: opts.drop_policy.name().to_string(),
        stages: BTreeMap::new(),
        jitter: Default::default(),
    };
    let mut jitter = LatencyStats::new();
    let mut last_emit: Option<u64> = None;
    let mut frames = Vec::with_capacity(slots.len());
    for mut s in slots {
        if let Some(e) = s.error.take() {
            return Err(e);
        }
        report
            .stages
            .entry("decode".into())
            .or_default()
            .add(s.decode_ns, s.decode_ns, 1);
        for r in &s.records {
            report
                .stages
                .entry(r.span_name().into())
                .or_default()
                .add(r.wall_ns, r.cpu_ns, r.tasks);
        }
        if s.late {
            report.late += 1;
        }
        if s.dropped {
            report.dropped += 1;
        } else {
            report.frames_emitted += 1;
            report.pixels += s.pixels;
            report.edge_pixels += s.edge_pixels;
            if let Some(prev) = last_emit {
                jitter.record(s.emit_ns.saturating_sub(prev));
            }
            last_emit = Some(s.emit_ns);
        }
        if s.degraded {
            report.degraded += 1;
        } else if !s.dropped {
            if s.gated {
                report.gate.frames_gated += 1;
                report.gate.tiles_clean += s.clean as u64;
                report.gate.tiles_dirty += s.dirty as u64;
            } else {
                report.gate.frames_full += 1;
            }
        }
        frames.push(FrameResult {
            index: s.index,
            dropped: s.dropped,
            degraded: s.degraded,
            late: s.late,
            gated: s.gated,
            tiles_clean: s.clean,
            tiles_dirty: s.dirty,
            edge_pixels: s.edge_pixels,
            edges: s.edges.take(),
        });
    }
    report.jitter = jitter.summary();
    Ok(StreamOutcome { report, frames })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn drop_policy_parse_roundtrip() {
        for p in [DropPolicy::Drop, DropPolicy::Degrade, DropPolicy::Keep] {
            assert_eq!(DropPolicy::parse(p.name()), Some(p));
        }
        assert_eq!(DropPolicy::parse("keep"), Some(DropPolicy::Keep));
        assert_eq!(DropPolicy::parse("bogus"), None);
    }

    #[test]
    fn options_from_config_map_fields() {
        let mut cfg = RunConfig::default();
        cfg.set("inflight", "7").unwrap();
        cfg.set("delta-gate", "off").unwrap();
        cfg.set("frame-budget-ms", "2.5").unwrap();
        cfg.set("drop-policy", "degrade").unwrap();
        let opts = StreamOptions::from_config(&cfg);
        assert_eq!(opts.inflight, 7);
        assert_eq!(opts.delta, DeltaMode::Off);
        assert_eq!(opts.frame_budget_ns, 2_500_000);
        assert_eq!(opts.drop_policy, DropPolicy::Degrade);
        assert!(!opts.keep_edges);
    }

    #[test]
    fn zero_inflight_rejected() {
        let det = Detector::builder().workers(1).build().unwrap();
        let src = FrameSource::synthetic(1, 2, 32, 24);
        let opts = StreamOptions { inflight: 0, ..StreamOptions::default() };
        assert!(run_stream("t", &src, &det, &opts).is_err());
    }

    #[test]
    fn decode_error_surfaces() {
        let det = Detector::builder().workers(1).build().unwrap();
        let src = FrameSource::Directory {
            paths: vec![std::path::PathBuf::from("/nonexistent/frame_0.pgm")],
        };
        assert!(run_stream("t", &src, &det, &StreamOptions::default()).is_err());
    }
}
