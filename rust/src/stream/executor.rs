//! The pipeline-parallel frame executor: decode → delta-gated front →
//! finish (threshold + hysteresis), each stage on its own thread with a
//! bounded in-flight window, ordered emission, and an optional
//! real-time frame budget with drop/degrade handling for late frames.
//!
//! Built on [`crate::patterns::pipeline::pipeline_stages`] — the
//! dynamic generalization of the fixed-arity `pipeline3` the old video
//! example hand-rolled — with the front stage farming dirty tiles over
//! the shared [`crate::scheduler::Pool`] (pipeline across stages, farm
//! within a frame: the paper's two patterns composed).

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::cache::{ArtifactCache, ArtifactKey, CacheConfig, CacheTier};
use crate::canny::{Artifact, CannyParams, Engine, StageKind, StagePlan, StageRecord};
use crate::config::RunConfig;
use crate::coordinator::Detector;
use crate::error::{Error, Result};
use crate::image::EdgeMap;
use crate::obs::{
    AnomalyMonitor, HealthTracker, ObsEndpoint, SnapshotEngine, Telemetry, WallSnapshotter,
};
use crate::patterns::pipeline::{pipeline_stages, DynStage};
use crate::service::{LatencyStats, SloWindow, DEFAULT_SLO_WINDOW};
use crate::stream::delta::{DeltaGate, DeltaMode};
use crate::stream::report::{GateReport, StreamReport};
use crate::stream::source::FrameSource;
use crate::util::timer::Stopwatch;

/// What to do with a frame that is already past its deadline when the
/// front stage dequeues it (real-time mode only).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DropPolicy {
    /// Skip it entirely: no front, no finish, no emission.
    Drop,
    /// Emit a degraded frame: reuse the last computed suppressed map
    /// wholesale (skip the front) and run only threshold + hysteresis.
    /// The map is kept by the executor, so this works with the delta
    /// gate off too; falls back to full processing when no map exists
    /// yet.
    Degrade,
    /// Process anyway; lateness is only counted.
    Keep,
}

/// Lower bound on a full front's cost used as an offer's admission
/// estimate when no ungated front has been measured yet (a stream that
/// opens on cache hits has nothing to extrapolate from). Real fronts
/// run several ns/pixel single-threaded; 1 ns/pixel keeps the estimate
/// conservative but never zero, so an evicted hot entry can still
/// clear a reasonable admission bar and re-instate itself.
pub const FRONT_ESTIMATE_FLOOR_NS_PER_PIXEL: u64 = 1;

impl DropPolicy {
    /// Parse a `--drop-policy` value.
    pub fn parse(s: &str) -> Option<DropPolicy> {
        match s {
            "drop" => Some(DropPolicy::Drop),
            "degrade" => Some(DropPolicy::Degrade),
            "none" | "keep" => Some(DropPolicy::Keep),
            _ => None,
        }
    }

    /// Config / report name.
    pub fn name(&self) -> &'static str {
        match self {
            DropPolicy::Drop => "drop",
            DropPolicy::Degrade => "degrade",
            DropPolicy::Keep => "none",
        }
    }
}

/// Stream-run configuration (the `cannyd stream` flag set).
#[derive(Clone, Debug)]
pub struct StreamOptions {
    /// Bounded in-flight window: capacity of each inter-stage queue.
    pub inflight: usize,
    /// Temporal delta-gating mode.
    pub delta: DeltaMode,
    /// Real-time frame budget in ns (0 = offline: process everything,
    /// as fast as possible, no deadlines).
    pub frame_budget_ns: u64,
    /// Late-frame handling under a budget.
    pub drop_policy: DropPolicy,
    /// Detection parameters. The stream tier reads thresholds from
    /// *here* (they feed the global finish pass), not from the
    /// detector's own defaults — embedders with custom `lo`/`hi` must
    /// set them on these options.
    pub params: CannyParams,
    /// Keep each emitted frame's [`EdgeMap`] in the outcome (tests,
    /// embedding programs); the CLI leaves this off.
    pub keep_edges: bool,
    /// Shared artifact cache to consult before each front pass and to
    /// offer computed suppressed maps into ([`crate::cache`]). Hand the
    /// same `Arc` to several streams (or to a serving run via
    /// [`crate::service::ServeOptions::shared_cache`]) and identical
    /// frames deduplicate across them. `None` = the stream keeps only
    /// its own per-stream temporal gate.
    pub cache: Option<Arc<ArtifactCache>>,
    /// Telemetry JSONL destination (`--telemetry-log`); `None` disables
    /// the snapshot stream (see [`crate::obs`]).
    pub telemetry_log: Option<PathBuf>,
    /// Snapshot period in ns (`--telemetry-interval-ms`).
    pub telemetry_interval_ns: u64,
    /// Rolling frame-SLO window size (`--slo-window`): the last N
    /// emitted frames' latencies vs. the frame budget.
    pub slo_window: usize,
    /// Health/anomaly alert sink spec (`--alert-log`): "" disables,
    /// `stderr` streams, anything else is a file path.
    pub alert_log: String,
    /// Streaming anomaly detection over the telemetry tick grid
    /// (`--anomaly-sigma`, standard deviations; 0 disables).
    pub anomaly_sigma: f64,
    /// Live snapshot endpoint (`--obs-port`): every telemetry line the
    /// stream run builds is published as the endpoint's current line.
    /// `None` (the default — the CLI attaches it) leaves the tier
    /// unobserved over TCP.
    pub obs_endpoint: Option<Arc<ObsEndpoint>>,
}

impl StreamOptions {
    /// Build from the resolved [`RunConfig`] (the CLI path). The shared
    /// cache is attached when `stream-cache` is set and the tier is
    /// enabled (`cache-mb > 0`).
    pub fn from_config(cfg: &RunConfig) -> StreamOptions {
        StreamOptions {
            inflight: cfg.inflight,
            delta: cfg.delta_gate,
            frame_budget_ns: (cfg.frame_budget_ms * 1e6) as u64,
            drop_policy: cfg.drop_policy,
            params: cfg.params,
            keep_edges: false,
            cache: if cfg.stream_cache && cfg.cache_mb > 0 {
                Some(Arc::new(ArtifactCache::new(CacheConfig::from_config(cfg))))
            } else {
                None
            },
            telemetry_log: if cfg.telemetry_log.is_empty() {
                None
            } else {
                Some(PathBuf::from(&cfg.telemetry_log))
            },
            telemetry_interval_ns: (cfg.telemetry_interval_ms.max(0.0) * 1e6) as u64,
            slo_window: cfg.slo_window.max(1),
            alert_log: cfg.alert_log.clone(),
            anomaly_sigma: cfg.anomaly_sigma,
            obs_endpoint: None,
        }
    }
}

impl Default for StreamOptions {
    fn default() -> Self {
        StreamOptions {
            inflight: 4,
            delta: DeltaMode::default(),
            frame_budget_ns: 0,
            drop_policy: DropPolicy::Drop,
            params: CannyParams::default(),
            keep_edges: false,
            cache: None,
            telemetry_log: None,
            telemetry_interval_ns: 100_000_000,
            slo_window: DEFAULT_SLO_WINDOW,
            alert_log: String::new(),
            anomaly_sigma: 0.0,
            obs_endpoint: None,
        }
    }
}

/// Per-frame result in source order.
#[derive(Clone, Debug)]
pub struct FrameResult {
    pub index: usize,
    /// Skipped entirely (late under [`DropPolicy::Drop`]).
    pub dropped: bool,
    /// Emitted from the cached suppressed map without a front pass.
    pub degraded: bool,
    /// Past its deadline at front entry (any policy).
    pub late: bool,
    /// Counted toward the gate hit-rate (a reference frame existed).
    pub gated: bool,
    /// Served whole from the shared artifact cache (no gate, no front).
    pub cached: bool,
    pub tiles_clean: usize,
    pub tiles_dirty: usize,
    pub edge_pixels: u64,
    /// Present for emitted frames when
    /// [`StreamOptions::keep_edges`] was set.
    pub edges: Option<EdgeMap>,
}

/// Everything a stream run produced: the aggregate report plus the
/// ordered per-frame results.
#[derive(Clone, Debug)]
pub struct StreamOutcome {
    pub report: StreamReport,
    pub frames: Vec<FrameResult>,
}

/// The pipeline's uniform message (see
/// [`crate::patterns::pipeline::pipeline_stages`]): stages fill it in
/// as the frame moves decode → front → finish.
struct Slot {
    index: usize,
    image: Option<crate::image::ImageF32>,
    nm: Option<crate::image::ImageF32>,
    pixels: u64,
    deadline_ns: u64,
    decode_ns: u64,
    emit_ns: u64,
    dropped: bool,
    degraded: bool,
    late: bool,
    gated: bool,
    cached: bool,
    clean: usize,
    dirty: usize,
    edge_pixels: u64,
    edges: Option<EdgeMap>,
    records: Vec<StageRecord>,
    error: Option<Error>,
}

/// Run a frame stream through the detector. The three stages run
/// pipeline-parallel with at most `opts.inflight` frames queued between
/// consecutive stages; emission is in frame order.
pub fn run_stream(
    label: &str,
    source: &FrameSource,
    det: &Detector,
    opts: &StreamOptions,
) -> Result<StreamOutcome> {
    if opts.inflight == 0 {
        return Err(Error::Config("inflight must be >= 1".into()));
    }
    // The delta-gated front recomputes dirty tiles through the native
    // fused-tile path (there is no per-tile XLA gate executable), so an
    // XLA detector would silently run on CPU while the report claimed
    // otherwise — reject it instead of mislabeling.
    if det.engine() == Engine::PatternsXla {
        return Err(Error::Config(
            "the stream tier does not support the xla engine (the delta-gated front \
             recomputes tiles natively); use serial | patterns | tiled"
                .into(),
        ));
    }
    opts.params.validate()?;
    let n = source.len();
    let budget = opts.frame_budget_ns;
    let t0 = Stopwatch::start();

    // -- Ops plane: a "stream"-tier telemetry registry with one logical
    //    lane per pipeline stage (0 = decode, 1 = front, 2 = finish), a
    //    rolling frame-SLO window (emission latency vs. the frame
    //    budget; `no-data` offline, where there is no deadline), and —
    //    under `--telemetry-log` — the wall sampler thread emitting
    //    periodic JSONL snapshots. The stream tier is always
    //    wall-measured, so there is no virtual drive mode here.
    let telemetry = Arc::new(Telemetry::new("stream", 3));
    let window = Arc::new(Mutex::new(SloWindow::new(budget, opts.slo_window.max(1))));
    let snap = SnapshotEngine::from_options(
        opts.telemetry_log.as_deref(),
        opts.telemetry_interval_ns,
        opts.drop_policy.name(),
    )?
    .with_alerts(HealthTracker::from_spec(&opts.alert_log)?)
    .with_anomaly(AnomalyMonitor::from_sigma(opts.anomaly_sigma))
    .with_endpoint(opts.obs_endpoint.clone());
    // Late frames can only be shed (dropped/degraded) under a real-time
    // budget with a policy that acts on them.
    let shedding_possible = budget > 0 && opts.drop_policy != DropPolicy::Keep;
    let snapshotter = {
        let win = Arc::clone(&window);
        let cache_probe = opts.cache.clone();
        WallSnapshotter::start(
            snap,
            Arc::clone(&telemetry),
            vec![det.pool_stats()],
            Box::new(move || t0.elapsed_ns()),
            Box::new(move || match &cache_probe {
                Some(c) => c.snapshot(),
                None => ArtifactCache::disabled().snapshot(),
            }),
            Box::new(move || {
                let w = win.lock().expect("slo window lock");
                (w.to_json(), w.missed())
            }),
            shedding_possible,
        )
    };

    // -- Stage 1 (source thread): acquire + decode, paced to the frame
    //    budget like a camera: frame k becomes available at k*budget.
    let tel_src = Arc::clone(&telemetry);
    let inputs = (0..n).map(move |k| {
        if budget > 0 {
            let target = k as u64 * budget;
            let now = t0.elapsed_ns();
            if now < target {
                std::thread::sleep(Duration::from_nanos(target - now));
            }
        }
        let sw = Stopwatch::start();
        let (image, pixels, error) = match source.frame(k) {
            Ok(img) => {
                let px = img.len() as u64;
                (Some(img), px, None)
            }
            Err(e) => (None, 0, Some(e)),
        };
        let decode_ns = sw.elapsed_ns();
        // Every frame the source yields is "offered" and "admitted":
        // the stream tier has no front door to reject at — sheds happen
        // at the front stage's deadline check and count there.
        tel_src.offered.inc();
        tel_src.admitted.inc();
        let lane = tel_src.lane(0);
        lane.busy_ns.add(decode_ns);
        lane.completed.inc();
        lane.heartbeat_ns.raise(t0.elapsed_ns());
        tel_src.note_stage("decode", decode_ns, decode_ns);
        Slot {
            index: k,
            image,
            nm: None,
            pixels,
            deadline_ns: if budget > 0 { (k as u64 + 1) * budget } else { 0 },
            decode_ns,
            emit_ns: 0,
            dropped: false,
            degraded: false,
            late: false,
            gated: false,
            cached: false,
            clean: 0,
            dirty: 0,
            edge_pixels: 0,
            edges: None,
            records: Vec::new(),
            error,
        }
    });

    // -- Stage 2 (own thread): the delta-gated front. Dirty tiles farm
    //    over the detector's pool unless the engine is Serial. The
    //    front records carry the engine that actually executed them
    //    (the fused native tile path); the report's top-level `engine`
    //    is the detector engine, which drives the finish stages.
    let pool = if det.engine() != Engine::Serial { Some(det.pool()) } else { None };
    let front_engine =
        if pool.is_some() { Engine::TiledPatterns } else { Engine::Serial };
    let mut gate = DeltaGate::new(opts.delta);
    // The degrade path's stale-frame source. Owned by the executor —
    // not the gate — so degrading works with `--delta-gate off` too;
    // maintained only when the policy can use it.
    let mut degrade_nm: Option<crate::image::ImageF32> = None;
    let drop_policy = opts.drop_policy;
    let cache = opts.cache.clone();
    // The shared tier is content-addressed and its consumers (serve
    // re-threshold, other streams) assume bit-exact artifacts. A gated
    // frame under a nonzero threshold may carry tolerated drift, so
    // only exact maps are offered: ungated full fronts always, gated
    // ones only when the gate threshold is 0.
    let gate_exact = match opts.delta {
        DeltaMode::Off => true,
        DeltaMode::Gate(t) => t == 0.0,
    };
    // Admission estimate for gated offers: what a cross-tier hit
    // *saves* is a full front, not the delta-check + dirty-tile sliver
    // this frame happened to pay — a near-static frame's exact map is
    // exactly as valuable as a fully-recomputed one. Updated by every
    // ungated frame; until one has been measured (a stream can open on
    // a cache hit), offers fall back to a conservative per-pixel floor.
    let mut last_full_front_ns = 0u64;
    let mut front_core: DynStage<Slot> = Box::new(move |mut s: Slot| {
        if s.error.is_some() {
            return s;
        }
        let img = s.image.take().expect("decoded frame present");
        if s.deadline_ns > 0 && t0.elapsed_ns() > s.deadline_ns {
            s.late = true;
            match drop_policy {
                DropPolicy::Drop => {
                    s.dropped = true;
                    return s;
                }
                DropPolicy::Degrade => {
                    // Prefer the gate's own cache; the executor-owned
                    // copy exists only for the gate-off case.
                    if let Some(nm) = gate.cached_nm().or(degrade_nm.as_ref()) {
                        if nm.width() == img.width() && nm.height() == img.height() {
                            s.nm = Some(nm.clone());
                            s.degraded = true;
                            return s;
                        }
                    }
                    // No usable map yet: compute normally below.
                }
                DropPolicy::Keep => {}
            }
        }
        // Consult the shared tier first: another stream (or a serving
        // lane) may already have this exact frame's front. A hit skips
        // the gate and the front entirely; the pair is installed as the
        // gate's new temporal baseline so the *next* frame diffs
        // against the right predecessor.
        let key = cache
            .as_ref()
            .filter(|c| c.enabled())
            .map(|_| ArtifactKey::suppressed(&img));
        if let (Some(c), Some(k)) = (cache.as_deref(), key.as_ref()) {
            if let Some(Artifact::Suppressed(nm)) = c.get(k, CacheTier::Stream) {
                if gate.mode().is_on() {
                    if let Err(e) = gate.install(img, nm.clone()) {
                        s.error = Some(e);
                        return s;
                    }
                } else if drop_policy == DropPolicy::Degrade {
                    degrade_nm = Some(nm.clone());
                }
                s.cached = true;
                s.nm = Some(nm);
                return s;
            }
        }
        match gate.advance(pool, img) {
            Ok(run) => {
                s.clean = run.clean;
                s.dirty = run.dirty;
                s.gated = run.gated;
                s.records.push(StageRecord {
                    kind: StageKind::Nms,
                    fused_from: Some(StageKind::Pad),
                    engine: front_engine,
                    wall_ns: run.wall_ns,
                    cpu_ns: run.cpu_ns,
                    tasks: run.task_costs_ns.len() as u64,
                    task_costs_ns: run.task_costs_ns,
                });
                if drop_policy == DropPolicy::Degrade && !gate.mode().is_on() {
                    degrade_nm = Some(run.nm.clone());
                }
                if !run.gated {
                    last_full_front_ns = run.wall_ns;
                }
                // Offer this frame's front to the shared tier. This
                // path runs only after a cache miss (hits returned
                // above), so the key is known absent — offer every
                // exact map, including fully-clean gated frames (that's
                // how an evicted static stream re-instates itself).
                // Inexact gated maps never enter the tier.
                if let (Some(c), Some(k)) = (cache.as_deref(), key) {
                    if !run.gated || gate_exact {
                        let floor = s.pixels * FRONT_ESTIMATE_FLOOR_NS_PER_PIXEL;
                        c.offer(
                            k,
                            Artifact::Suppressed(run.nm.clone()),
                            run.wall_ns.max(last_full_front_ns).max(floor),
                            CacheTier::Stream,
                        );
                    }
                }
                s.nm = Some(run.nm);
            }
            Err(e) => s.error = Some(e),
        }
        s
    });
    // Telemetry shell around the front stage: lane 1 liveness/busy
    // accounting, shed counters (a dropped frame is a shed-rejected
    // arrival, a stale-map emission a shed-degraded one), gate tile
    // tallies and the front stage record.
    let tel_front = Arc::clone(&telemetry);
    let front: DynStage<Slot> = Box::new(move |s: Slot| {
        let lane = tel_front.lane(1);
        lane.inflight.set(1);
        lane.heartbeat_ns.raise(t0.elapsed_ns());
        let sw = Stopwatch::start();
        let s = front_core(s);
        lane.busy_ns.add(sw.elapsed_ns());
        lane.inflight.set(0);
        lane.completed.inc();
        lane.heartbeat_ns.raise(t0.elapsed_ns());
        // Dropped frames were already admitted at decode, so they count
        // only in the overload section (`queue.rejected` stays 0 for
        // the stream tier — there is no door to turn frames away at).
        if s.dropped {
            tel_front.shed_rejected.inc();
        }
        if s.degraded {
            tel_front.shed_degraded.inc();
        }
        tel_front.gate_tiles_clean.add(s.clean as u64);
        tel_front.gate_tiles_dirty.add(s.dirty as u64);
        if let Some(r) = s.records.last() {
            tel_front.note_stage(r.span_name(), r.wall_ns, r.cpu_ns);
        }
        s
    });

    // -- Stage 3 (collector thread): global threshold + hysteresis from
    //    the stitched suppressed map, through the stage-graph API.
    let params = opts.params;
    let keep_edges = opts.keep_edges;
    let mut finish_core: DynStage<Slot> = Box::new(move |mut s: Slot| {
        if s.error.is_some() || s.dropped {
            return s;
        }
        let nm = s.nm.take().expect("front produced a suppressed map");
        let plan = StagePlan::new().from_suppressed(nm);
        match det.run_plan(&plan, None, &params) {
            Ok(mut out) => {
                s.records.append(&mut out.records);
                match out.take_edges() {
                    Some(edges) => {
                        s.edge_pixels = edges.count_edges() as u64;
                        if keep_edges {
                            s.edges = Some(edges);
                        }
                        s.emit_ns = t0.elapsed_ns();
                    }
                    None => {
                        s.error = Some(Error::Config(
                            "finish plan yielded no edge map".into(),
                        ))
                    }
                }
            }
            Err(e) => s.error = Some(e),
        }
        s
    });
    // Telemetry shell around the finish stage: lane 2 accounting, the
    // finish stage records (the front's own record was already tallied
    // by its stage), the global completion counter, and — under a
    // real-time budget — the per-frame emission latency
    // (`emit_ns - k*budget`, i.e. lateness past the camera's capture
    // time) into both the histogram and the rolling SLO window.
    let tel_fin = Arc::clone(&telemetry);
    let win_fin = Arc::clone(&window);
    let finish: DynStage<Slot> = Box::new(move |s: Slot| {
        let lane = tel_fin.lane(2);
        lane.inflight.set(1);
        lane.heartbeat_ns.raise(t0.elapsed_ns());
        let seen = s.records.len();
        let sw = Stopwatch::start();
        let s = finish_core(s);
        lane.busy_ns.add(sw.elapsed_ns());
        lane.inflight.set(0);
        lane.heartbeat_ns.raise(t0.elapsed_ns());
        for r in &s.records[seen.min(s.records.len())..] {
            tel_fin.note_stage(r.span_name(), r.wall_ns, r.cpu_ns);
        }
        if !s.dropped && s.error.is_none() {
            lane.completed.inc();
            tel_fin.completed.inc();
            if budget > 0 {
                let lat = s.emit_ns.saturating_sub(s.index as u64 * budget);
                tel_fin.latency.record(lat);
                win_fin.lock().expect("slo window lock").record(s.emit_ns, lat);
            }
        }
        s
    });

    let slots = pipeline_stages(inputs, opts.inflight, vec![front, finish]);
    let wall_ns = t0.elapsed_ns();

    // Stop the sampler (it writes one final end-state line) and flush
    // the JSONL before folding the report.
    let (snap, _usage) = snapshotter.finish(label)?;
    snap.close()?;

    // -- Fold the ordered slots into the report.
    let mut report = StreamReport {
        label: label.to_string(),
        source: source.describe(),
        engine: det.engine().name().to_string(),
        workers: det.n_workers(),
        inflight: opts.inflight,
        frames_offered: n as u64,
        frames_emitted: 0,
        dropped: 0,
        degraded: 0,
        cached: 0,
        late: 0,
        wall_ns,
        pixels: 0,
        edge_pixels: 0,
        gate: GateReport {
            mode: opts.delta.name(),
            tiles_clean: 0,
            tiles_dirty: 0,
            frames_gated: 0,
            frames_full: 0,
        },
        frame_budget_ns: budget,
        drop_policy: opts.drop_policy.name().to_string(),
        stages: BTreeMap::new(),
        jitter: Default::default(),
        // Placeholder; refreshed below once the pipeline has joined.
        cache: ArtifactCache::disabled().snapshot(),
        slo: window.lock().expect("slo window lock").report(),
    };
    let mut jitter = LatencyStats::new();
    let mut last_emit: Option<u64> = None;
    let mut frames = Vec::with_capacity(slots.len());
    for mut s in slots {
        if let Some(e) = s.error.take() {
            return Err(e);
        }
        report
            .stages
            .entry("decode".into())
            .or_default()
            .add(s.decode_ns, s.decode_ns, 1);
        for r in &s.records {
            report
                .stages
                .entry(r.span_name().into())
                .or_default()
                .add(r.wall_ns, r.cpu_ns, r.tasks);
        }
        if s.late {
            report.late += 1;
        }
        if s.dropped {
            report.dropped += 1;
        } else {
            report.frames_emitted += 1;
            report.pixels += s.pixels;
            report.edge_pixels += s.edge_pixels;
            if let Some(prev) = last_emit {
                jitter.record(s.emit_ns.saturating_sub(prev));
            }
            last_emit = Some(s.emit_ns);
        }
        if s.cached {
            // Served whole from the shared tier: no gate verdict, no
            // front — its own bucket, like degraded frames.
            report.cached += 1;
        } else if s.degraded {
            report.degraded += 1;
        } else if !s.dropped {
            if s.gated {
                report.gate.frames_gated += 1;
                report.gate.tiles_clean += s.clean as u64;
                report.gate.tiles_dirty += s.dirty as u64;
            } else {
                report.gate.frames_full += 1;
            }
        }
        frames.push(FrameResult {
            index: s.index,
            dropped: s.dropped,
            degraded: s.degraded,
            late: s.late,
            gated: s.gated,
            cached: s.cached,
            tiles_clean: s.clean,
            tiles_dirty: s.dirty,
            edge_pixels: s.edge_pixels,
            edges: s.edges.take(),
        });
    }
    // Refresh the snapshot after the fold: the pipeline threads have
    // joined, so the counters are final.
    if let Some(c) = &opts.cache {
        report.cache = c.snapshot();
    }
    report.jitter = jitter.summary();
    Ok(StreamOutcome { report, frames })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn drop_policy_parse_roundtrip() {
        for p in [DropPolicy::Drop, DropPolicy::Degrade, DropPolicy::Keep] {
            assert_eq!(DropPolicy::parse(p.name()), Some(p));
        }
        assert_eq!(DropPolicy::parse("keep"), Some(DropPolicy::Keep));
        assert_eq!(DropPolicy::parse("bogus"), None);
    }

    #[test]
    fn options_from_config_map_fields() {
        let mut cfg = RunConfig::default();
        cfg.set("inflight", "7").unwrap();
        cfg.set("delta-gate", "off").unwrap();
        cfg.set("frame-budget-ms", "2.5").unwrap();
        cfg.set("drop-policy", "degrade").unwrap();
        cfg.set("telemetry-interval-ms", "2").unwrap();
        cfg.set("slo-window", "16").unwrap();
        let opts = StreamOptions::from_config(&cfg);
        assert_eq!(opts.inflight, 7);
        assert_eq!(opts.delta, DeltaMode::Off);
        assert_eq!(opts.frame_budget_ns, 2_500_000);
        assert_eq!(opts.drop_policy, DropPolicy::Degrade);
        assert!(!opts.keep_edges);
        assert!(opts.cache.is_none(), "cache sharing is opt-in");
        assert!(opts.telemetry_log.is_none(), "telemetry log is opt-in");
        assert_eq!(opts.telemetry_interval_ns, 2_000_000);
        assert_eq!(opts.slo_window, 16);
        assert!(opts.alert_log.is_empty(), "alerting is opt-in");
        assert_eq!(opts.anomaly_sigma, 0.0, "anomaly detection is opt-in");
        cfg.set("anomaly-sigma", "4").unwrap();
        cfg.set("alert-log", "stderr").unwrap();
        let obs = StreamOptions::from_config(&cfg);
        assert_eq!(obs.anomaly_sigma, 4.0);
        assert_eq!(obs.alert_log, "stderr");
        cfg.set("telemetry-log", "/tmp/stream_t.jsonl").unwrap();
        assert_eq!(
            StreamOptions::from_config(&cfg).telemetry_log.as_deref(),
            Some(std::path::Path::new("/tmp/stream_t.jsonl"))
        );
        cfg.set("stream-cache", "true").unwrap();
        let shared = StreamOptions::from_config(&cfg);
        assert!(shared.cache.as_ref().is_some_and(|c| c.enabled()));
        cfg.set("cache-mb", "0").unwrap();
        assert!(
            StreamOptions::from_config(&cfg).cache.is_none(),
            "a zero budget disables sharing even with --stream-cache"
        );
    }

    #[test]
    fn zero_inflight_rejected() {
        let det = Detector::builder().workers(1).build().unwrap();
        let src = FrameSource::synthetic(1, 2, 32, 24);
        let opts = StreamOptions { inflight: 0, ..StreamOptions::default() };
        assert!(run_stream("t", &src, &det, &opts).is_err());
    }

    #[test]
    fn decode_error_surfaces() {
        let det = Detector::builder().workers(1).build().unwrap();
        let src = FrameSource::Directory {
            paths: vec![std::path::PathBuf::from("/nonexistent/frame_0.pgm")],
        };
        assert!(run_stream("t", &src, &det, &StreamOptions::default()).is_err());
    }
}
