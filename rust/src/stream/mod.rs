//! The **frame-stream tier** — real-time video edge detection above the
//! per-image detector. The unit of work is a *frame stream*: the
//! workload class the paper benchmarks against its FPGA comparator
//! (~240 fps), previously exercised only by a toy example.
//!
//! ```text
//! FrameSource ──> decode ──> delta-gated front ──> finish ──> report
//!  (video:<seed>, (paced to  (per-tile change      (global      (fps, Mpix/s,
//!   dir:, trace:,  the frame  detection; dirty      Threshold +   gate hit-rate,
//!   scene specs)   budget)    tiles recompute,      Hysteresis    per-stage aggs,
//!                             clean tiles reuse     via            jitter p50/95/99)
//!                             the temporal cache)   StagePlan)
//! ```
//!
//! Three ideas compose:
//!
//! * **Pipeline across stages** ([`executor`]): decode, front and
//!   finish each run on their own thread with bounded queues
//!   (`--inflight`), built on the dynamic
//!   [`crate::patterns::pipeline::pipeline_stages`] generalization of
//!   the fixed-arity pipeline pattern. Emission is in frame order.
//! * **Farm within a frame** ([`delta`]): the front stage recomputes
//!   dirty gate tiles in parallel over the shared pool.
//! * **Temporal delta-gating** ([`delta::DeltaGate`]): per-tile change
//!   detection against the previous frame; clean tiles reuse their
//!   cached [`crate::canny::Artifact::Suppressed`] core — the serving
//!   tier's re-threshold cache generalized from per-request to
//!   per-stream temporal reuse. With the default threshold `0` the
//!   reuse is **exact** (bit-identical to full per-frame detection);
//!   near-static video becomes mostly re-threshold work.
//!
//! A real-time mode (`--frame-budget-ms`) paces acquisition like a
//! camera and handles frames that miss their deadline per
//! `--drop-policy`: `drop` (skip), `degrade` (emit from the cached
//! suppressed map, skipping the front), or `none` (process anyway,
//! count lateness).
//!
//! ## Stream report JSON schema (`cannyd stream`)
//!
//! ```json
//! {
//!   "label": "stream[video:7 n=32 512x512]",
//!   "source": "video:7 n=32 512x512",
//!   "engine": "patterns", "workers": 4, "inflight": 4,
//!   "frames": {"offered": 32, "emitted": 32, "dropped": 0,
//!              "degraded": 0, "cached": 0, "late": 0},
//!   "wall_ns": 812345678, "fps": 39.4, "mpix_per_s": 10.3,
//!   "edge_pixels": 104882,
//!   "gate": {"mode": "0", "tiles_clean": 5890, "tiles_dirty": 2046,
//!            "frames_gated": 31, "frames_full": 1, "hit_rate": 0.74},
//!   "budget": {"frame_budget_ns": 0, "drop_policy": "drop"},
//!   "stages": {
//!     "decode":     {"wall_ns": 1, "cpu_ns": 1, "tasks": 32, "frames": 32},
//!     "front":      {"wall_ns": 1, "cpu_ns": 1, "tasks": 8192, "frames": 32},
//!     "threshold":  {"wall_ns": 1, "cpu_ns": 1, "tasks": 256, "frames": 32},
//!     "hysteresis": {"wall_ns": 1, "cpu_ns": 1, "tasks": 32, "frames": 32}
//!   },
//!   "jitter_ns": {"n": 31, "p50": 1, "p95": 1, "p99": 1, "max": 1, "mean": 1.0},
//!   "cache": {"enabled": false, "...": "see the crate::service docs"},
//!   "overload": {"policy": "drop", "shed_rejected": 0, "shed_degraded": 0},
//!   "slo": {"window": 64, "target_p99_ns": 0, "n": 0, "status": "no-data",
//!           "...": "same schema as the serve report's slo.window"}
//! }
//! ```
//!
//! `gate.mode` is `"off"` or the cleanliness threshold; `hit_rate` is
//! `tiles_clean / (tiles_clean + tiles_dirty)` over gated frames (the
//! first frame and post-resize frames count as `frames_full`, not
//! misses). `stages` aggregates one entry per executed
//! [`crate::canny::StageRecord`] span plus the synthesized `decode`
//! span; `jitter_ns` summarizes inter-emission gaps.
//!
//! ## The ops plane ([`crate::obs`])
//!
//! Stream runs publish into a `"stream"`-tier [`crate::obs::Telemetry`]
//! registry — one logical lane per pipeline stage (decode / front /
//! finish), the gate's tile tallies, and the drop policy's shed
//! decisions (`dropped` → `shed_rejected`, `degraded` →
//! `shed_degraded`). `--telemetry-log file.jsonl
//! --telemetry-interval-ms N` attaches the wall sampler thread, which
//! emits one JSONL snapshot per interval (schema in [`crate::obs`],
//! `tier: "stream"`) plus a final end-state line, with a per-core
//! `utilization` section sampled from the detector's worker pool.
//! Under a real-time budget the rolling frame-SLO window
//! (`--slo-window N`) tracks emission latency — `emit_ns - k*budget`,
//! lateness past the camera's capture time — against a target of one
//! frame budget; the report's `slo` section carries its windowed
//! percentiles and met/missed transition timeline (`no-data` offline,
//! where frames have no deadlines).
//!
//! ## The shared artifact cache (`--stream-cache`)
//!
//! With `--stream-cache` (and `--cache-mb > 0`) the executor plugs into
//! the process-wide [`crate::cache::ArtifactCache`]: before running the
//! front it consults the tier under the frame's content-addressed key —
//! a hit reuses the suppressed map whole (counted in `frames.cached`
//! and the gate adopts it as its temporal baseline) — and every *exact*
//! computed front is offered back (measured wall time as the admission
//! policy's recompute estimate; gated maps under a nonzero threshold
//! are never offered, since they may carry tolerated drift). Two
//! streams playing the same content — or a stream and a serving run
//! handed the same `Arc` via
//! [`crate::service::ServeOptions::shared_cache`] — deduplicate their
//! fronts. The report's `cache` section (schema in [`crate::service`])
//! snapshots the tier; per-tier counters separate `stream` from `serve`
//! traffic.
//!
//! ## Frame-trace JSON schema (`--source trace:frames.json`)
//!
//! ```json
//! {"frames": [
//!   {"file": "frames/frame_0001.pgm"},
//!   {"scene": "video:3:1", "width": 640, "height": 360},
//!   {"scene": "shapes:9"}
//! ]}
//! ```
//!
//! Entries are replayed in order; `scene` entries without sizes use the
//! run's `--size`.
//!
//! Entry points: `cannyd stream --synthetic-frames 32 --delta-gate 0`
//! (or `--source dir:frames/ --frame-budget-ms 16.7 --drop-policy
//! degrade`), or programmatically via [`run_stream`] — see the crate
//! quickstart in [`crate`].

pub mod delta;
pub mod executor;
pub mod report;
pub mod source;

pub use delta::{DeltaGate, DeltaMode, GateRun, GATE_TILE};
pub use executor::{run_stream, DropPolicy, FrameResult, StreamOptions, StreamOutcome};
pub use report::{GateReport, StageAgg, StreamReport};
pub use source::{FrameSource, TraceFrame};
