//! Frame acquisition for the stream tier: where frames come from
//! before the pipeline-parallel executor sees them. Mirrors the
//! acquisition / pipeline split industrial vision stacks use — a
//! source only knows how to produce frame `k`, never how frames are
//! scheduled, gated or dropped.

use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::image::synth::{generate, Scene};
use crate::image::{pgm, ImageF32};
use crate::util::json::Json;

/// A finite, indexable stream of frames. All variants are pull-based:
/// the executor's source stage calls [`FrameSource::frame`] lazily, so
/// decode overlaps detection (pipeline parallelism starts at
/// acquisition).
#[derive(Clone, Debug)]
pub enum FrameSource {
    /// `Scene::Video` frames: one moving-shapes scene per index, built
    /// through the shared [`Scene::parse`] `video:<seed>:<frame>` spec.
    Synthetic { seed: u64, frames: usize, width: usize, height: usize },
    /// A fixed (non-video) scene repeated every frame — a fully static
    /// stream, the delta gate's best case.
    Static { scene: Scene, frames: usize, width: usize, height: usize },
    /// In-memory frames (tests and embedding programs).
    Frames(Vec<ImageF32>),
    /// A directory of numbered PGM/PPM files, replayed in numeric
    /// order.
    Directory { paths: Vec<PathBuf> },
    /// A recorded trace: an explicit frame list mixing files and scene
    /// specs (see the JSON schema in [`crate::stream`]).
    Trace { entries: Vec<TraceFrame> },
}

/// One entry of a [`FrameSource::Trace`].
#[derive(Clone, Debug)]
pub enum TraceFrame {
    /// Decode this image file.
    File(PathBuf),
    /// Generate this scene spec at the given size.
    Scene { spec: String, width: usize, height: usize },
}

impl FrameSource {
    /// A `Scene::Video` source (the `video:<seed>` spec).
    pub fn synthetic(seed: u64, frames: usize, width: usize, height: usize) -> FrameSource {
        FrameSource::Synthetic { seed, frames, width, height }
    }

    /// Parse a CLI source spec:
    ///
    /// * `video` / `video:<seed>` — moving synthetic scene (`frames`
    ///   frames of `width`x`height`; bare `video` uses `default_seed`);
    ///   `video:<seed>:<frame>` pins that one frame (a static stream,
    ///   same spelling `cannyd run --scene` accepts);
    /// * any other [`Scene::parse`] spec (`shapes:3`, `checker:16`, …)
    ///   — that scene repeated `frames` times (a static stream);
    /// * `dir:<path>` — every `.pgm`/`.ppm` in the directory, numeric
    ///   filename order;
    /// * `trace:<path>` — a recorded JSON frame trace.
    pub fn parse(
        spec: &str,
        frames: usize,
        width: usize,
        height: usize,
        default_seed: u64,
    ) -> Result<FrameSource> {
        if frames == 0 {
            return Err(Error::Config("stream needs >= 1 frame".into()));
        }
        if let Some(path) = spec.strip_prefix("dir:") {
            return FrameSource::from_dir(Path::new(path));
        }
        if let Some(path) = spec.strip_prefix("trace:") {
            return FrameSource::from_trace_file(Path::new(path), width, height);
        }
        let (name, arg) = match spec.split_once(':') {
            Some((n, a)) => (n, Some(a)),
            None => (spec, None),
        };
        if name == "video" {
            return match arg {
                None => Ok(FrameSource::Synthetic { seed: default_seed, frames, width, height }),
                Some(a) => match a.split_once(':') {
                    // `video:<seed>`: a moving stream, one frame per index.
                    None => {
                        let seed = a.parse::<u64>().map_err(|_| {
                            Error::Config(format!("bad video seed `{a}` in `{spec}`"))
                        })?;
                        Ok(FrameSource::Synthetic { seed, frames, width, height })
                    }
                    // `video:<seed>:<frame>` (the `--scene` spelling) pins
                    // one frame: a static stream. Parsed strictly — the
                    // lenient Scene defaults would mask typos.
                    Some((s, f)) => match (s.parse::<u64>(), f.parse::<usize>()) {
                        (Ok(seed), Ok(frame)) => Ok(FrameSource::Static {
                            scene: Scene::Video { seed, frame },
                            frames,
                            width,
                            height,
                        }),
                        _ => Err(Error::Config(format!(
                            "bad video spec `{spec}` (video[:seed[:frame]])"
                        ))),
                    },
                },
            };
        }
        match Scene::parse(spec) {
            Some(scene) => Ok(FrameSource::Static { scene, frames, width, height }),
            None => Err(Error::Config(format!(
                "unknown stream source `{spec}` (video[:seed[:frame]] | <scene spec> | dir:PATH | trace:PATH)"
            ))),
        }
    }

    /// All `.pgm`/`.ppm` files under `dir`, ordered by the numeric
    /// value embedded in the file stem (then by name), so `frame_2.pgm`
    /// precedes `frame_10.pgm`.
    pub fn from_dir(dir: &Path) -> Result<FrameSource> {
        let mut paths: Vec<PathBuf> = std::fs::read_dir(dir)?
            .filter_map(|e| e.ok().map(|e| e.path()))
            .filter(|p| {
                matches!(
                    p.extension().and_then(|e| e.to_str()),
                    Some("pgm") | Some("ppm")
                )
            })
            .collect();
        if paths.is_empty() {
            return Err(Error::Config(format!(
                "no .pgm/.ppm frames in `{}`",
                dir.display()
            )));
        }
        paths.sort_by_key(|p| {
            let name = p.file_stem().and_then(|s| s.to_str()).unwrap_or("").to_string();
            (numeric_key(&name), name)
        });
        Ok(FrameSource::Directory { paths })
    }

    /// Load a recorded frame trace (schema in [`crate::stream`]); scene
    /// entries without explicit sizes fall back to `width`x`height`.
    pub fn from_trace_file(path: &Path, width: usize, height: usize) -> Result<FrameSource> {
        let text = std::fs::read_to_string(path)?;
        let j = Json::parse(&text)
            .map_err(|e| Error::Config(format!("{}: {e}", path.display())))?;
        let frames = j
            .get("frames")
            .and_then(|f| f.as_arr())
            .ok_or_else(|| {
                Error::Config(format!("{}: missing `frames` array", path.display()))
            })?;
        let mut entries = Vec::with_capacity(frames.len());
        for (k, f) in frames.iter().enumerate() {
            if let Some(file) = f.get("file").and_then(|v| v.as_str()) {
                entries.push(TraceFrame::File(PathBuf::from(file)));
            } else if let Some(spec) = f.get("scene").and_then(|v| v.as_str()) {
                entries.push(TraceFrame::Scene {
                    spec: spec.to_string(),
                    width: f.get("width").and_then(|v| v.as_usize()).unwrap_or(width),
                    height: f.get("height").and_then(|v| v.as_usize()).unwrap_or(height),
                });
            } else {
                return Err(Error::Config(format!(
                    "{}: frame {k} needs `file` or `scene`",
                    path.display()
                )));
            }
        }
        if entries.is_empty() {
            return Err(Error::Config(format!("{}: empty frame trace", path.display())));
        }
        Ok(FrameSource::Trace { entries })
    }

    /// Number of frames this source yields.
    pub fn len(&self) -> usize {
        match self {
            FrameSource::Synthetic { frames, .. } | FrameSource::Static { frames, .. } => *frames,
            FrameSource::Frames(v) => v.len(),
            FrameSource::Directory { paths } => paths.len(),
            FrameSource::Trace { entries } => entries.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Produce (decode or generate) frame `k`.
    pub fn frame(&self, k: usize) -> Result<ImageF32> {
        match self {
            FrameSource::Synthetic { seed, width, height, .. } => {
                // One parser for CLI scenes and stream frames: frame k
                // is exactly `--scene video:<seed>:<k>`.
                let spec = format!("video:{seed}:{k}");
                let scene = Scene::parse(&spec)
                    .ok_or_else(|| Error::Config(format!("bad scene spec `{spec}`")))?;
                Ok(generate(scene, *width, *height))
            }
            FrameSource::Static { scene, width, height, .. } => {
                Ok(generate(*scene, *width, *height))
            }
            FrameSource::Frames(v) => v
                .get(k)
                .cloned()
                .ok_or_else(|| Error::Config(format!("frame {k} out of range"))),
            FrameSource::Directory { paths } => Ok(pgm::read_pgm(&paths[k])?.to_f32()),
            FrameSource::Trace { entries } => match &entries[k] {
                TraceFrame::File(p) => Ok(pgm::read_pgm(p)?.to_f32()),
                TraceFrame::Scene { spec, width, height } => {
                    let scene = Scene::parse(spec)
                        .ok_or_else(|| Error::Config(format!("bad scene spec `{spec}`")))?;
                    Ok(generate(scene, *width, *height))
                }
            },
        }
    }

    /// Report / label description.
    pub fn describe(&self) -> String {
        match self {
            FrameSource::Synthetic { seed, frames, width, height } => {
                format!("video:{seed} n={frames} {width}x{height}")
            }
            FrameSource::Static { scene, frames, width, height } => {
                format!("{scene:?} n={frames} {width}x{height} (static)")
            }
            FrameSource::Frames(v) => format!("frames n={}", v.len()),
            FrameSource::Directory { paths } => format!("dir n={}", paths.len()),
            FrameSource::Trace { entries } => format!("trace n={}", entries.len()),
        }
    }
}

/// The last run of ASCII digits in `name`, as the primary sort key for
/// numbered frame files (`usize::MAX` when there is none).
fn numeric_key(name: &str) -> u64 {
    let mut best: Option<u64> = None;
    let mut cur: Option<u64> = None;
    for c in name.chars() {
        match c.to_digit(10) {
            Some(d) => {
                cur = Some(cur.unwrap_or(0).saturating_mul(10).saturating_add(d as u64));
            }
            None => {
                if cur.is_some() {
                    best = cur.take();
                }
            }
        }
    }
    if cur.is_some() {
        best = cur;
    }
    best.unwrap_or(u64::MAX)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_frames_match_scene_parser() {
        let src = FrameSource::synthetic(3, 4, 48, 32);
        assert_eq!(src.len(), 4);
        let f2 = src.frame(2).unwrap();
        let direct = generate(Scene::Video { seed: 3, frame: 2 }, 48, 32);
        assert_eq!(f2, direct);
        assert_ne!(src.frame(0).unwrap(), f2, "video frames must move");
    }

    #[test]
    fn parse_specs() {
        match FrameSource::parse("video:9", 8, 64, 48, 7).unwrap() {
            FrameSource::Synthetic { seed, frames, width, height } => {
                assert_eq!((seed, frames, width, height), (9, 8, 64, 48));
            }
            other => panic!("wrong source {other:?}"),
        }
        match FrameSource::parse("video", 8, 64, 48, 7).unwrap() {
            FrameSource::Synthetic { seed, .. } => assert_eq!(seed, 7),
            other => panic!("wrong source {other:?}"),
        }
        match FrameSource::parse("checker:8", 3, 32, 32, 7).unwrap() {
            FrameSource::Static { frames, .. } => assert_eq!(frames, 3),
            other => panic!("wrong source {other:?}"),
        }
        // `video:<seed>:<frame>` (the --scene spelling) pins one frame.
        match FrameSource::parse("video:3:12", 4, 32, 32, 7).unwrap() {
            FrameSource::Static { scene, frames, .. } => {
                assert_eq!(scene, Scene::Video { seed: 3, frame: 12 });
                assert_eq!(frames, 4);
            }
            other => panic!("wrong source {other:?}"),
        }
        assert!(FrameSource::parse("nope", 8, 64, 48, 7).is_err());
        assert!(FrameSource::parse("video:bogus", 8, 64, 48, 7).is_err());
        assert!(FrameSource::parse("video:3:x", 8, 64, 48, 7).is_err());
        assert!(FrameSource::parse("video", 0, 64, 48, 7).is_err());
    }

    #[test]
    fn static_source_repeats_exactly() {
        let src = FrameSource::parse("shapes:5", 3, 40, 30, 7).unwrap();
        assert_eq!(src.frame(0).unwrap(), src.frame(2).unwrap());
    }

    #[test]
    fn directory_orders_numerically() {
        let dir = std::env::temp_dir().join("canny_stream_dir_test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        for (name, v) in [("frame_10.pgm", 10u8), ("frame_2.pgm", 2), ("frame_1.pgm", 1)] {
            let img = crate::image::ImageU8::from_vec(1, 1, vec![v]).unwrap();
            pgm::write_pgm(&dir.join(name), &img).unwrap();
        }
        let src = FrameSource::from_dir(&dir).unwrap();
        assert_eq!(src.len(), 3);
        // Numeric, not lexicographic: 1, 2, 10.
        let vals: Vec<f32> = (0..3).map(|k| src.frame(k).unwrap().get(0, 0)).collect();
        assert!(vals[0] < vals[1] && vals[1] < vals[2], "{vals:?}");
        assert!(FrameSource::from_dir(&dir.join("missing")).is_err());
    }

    #[test]
    fn trace_mixes_files_and_scenes() {
        let dir = std::env::temp_dir().join("canny_stream_trace_test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let img = crate::image::ImageU8::from_vec(2, 2, vec![1, 2, 3, 4]).unwrap();
        let img_path = dir.join("f0.pgm");
        pgm::write_pgm(&img_path, &img).unwrap();
        let trace = dir.join("trace.json");
        std::fs::write(
            &trace,
            format!(
                "{{\"frames\": [{{\"file\": \"{}\"}}, {{\"scene\": \"video:3:1\", \"width\": 16, \"height\": 12}}, {{\"scene\": \"gradient\"}}]}}",
                img_path.display()
            ),
        )
        .unwrap();
        let src = FrameSource::from_trace_file(&trace, 24, 20).unwrap();
        assert_eq!(src.len(), 3);
        assert_eq!(src.frame(0).unwrap(), img.to_f32());
        let f1 = src.frame(1).unwrap();
        assert_eq!((f1.width(), f1.height()), (16, 12));
        // Default size applies when the entry has none.
        let f2 = src.frame(2).unwrap();
        assert_eq!((f2.width(), f2.height()), (24, 20));
        // Malformed entries rejected.
        std::fs::write(&trace, "{\"frames\": [{\"neither\": 1}]}").unwrap();
        assert!(FrameSource::from_trace_file(&trace, 8, 8).is_err());
        std::fs::write(&trace, "{\"frames\": []}").unwrap();
        assert!(FrameSource::from_trace_file(&trace, 8, 8).is_err());
    }

    #[test]
    fn numeric_key_extracts_last_run() {
        assert_eq!(numeric_key("frame_12"), 12);
        assert_eq!(numeric_key("cam2_frame_003"), 3);
        assert_eq!(numeric_key("noframe"), u64::MAX);
    }
}
