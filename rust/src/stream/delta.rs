//! Temporal delta-gating: per-tile change detection against the
//! previous frame, so near-static video pays only for what moved.
//!
//! The gate keeps the previous frame and the suppressed-magnitude map
//! ([`crate::canny::Artifact::Suppressed`]) that matches it. For each
//! new frame every gate tile compares its *haloed* input window against
//! the previous frame ([`TileGrid::tile_delta`]):
//!
//! * **clean** (difference <= threshold) — the cached suppressed core
//!   is reused untouched;
//! * **dirty** — the Gaussian → Sobel → NMS front recomputes on the
//!   tile's clamped window (in parallel over the pool — the farm
//!   pattern within a frame) and overwrites the cached core.
//!
//! Because [`crate::canny::consts::HALO`] covers the full dependency
//! cone of the front, a byte-identical haloed window implies a
//! byte-identical suppressed core. With threshold `0` the gate is
//! therefore **exact**: the stitched map is bit-identical to a full
//! per-frame front, for static *and* moving scenes — the generalization
//! of the serving tier's re-threshold cache from per-request to
//! per-stream temporal reuse. Thresholds above `0` trade exactness for
//! more reuse, with bounded staleness: each tile carries its
//! *accumulated* drift since its core was last recomputed (the
//! triangle inequality upper-bounds the true difference to the cached
//! reference), so a slow fade cannot stay "clean" forever.
//!
//! The global Threshold + Hysteresis pass runs afterwards from the
//! stitched map (hysteresis connectivity is image-global, so it is
//! never gated).

use crate::canny::consts;
use crate::canny::pipeline::front_suppressed_window;
use crate::error::Result;
use crate::image::tile::TileGrid;
use crate::image::ImageF32;
use crate::patterns;
use crate::scheduler::Pool;
use crate::util::timer::{thread_cpu_ns, Stopwatch};
use crate::util::SharedSlice;

/// Default gate-tile core size. Deliberately finer than the engines'
/// detection tile (128): gating granularity bounds how much of the
/// image a small moving object dirties, and a 32px core keeps the
/// dirty footprint of a typical shape to a few tiles.
pub const GATE_TILE: usize = 32;

/// Gate configuration: off (recompute every tile every frame), or on
/// with a max-abs-difference cleanliness threshold (`0` = exact reuse).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum DeltaMode {
    /// No temporal reuse; every frame recomputes the full front.
    Off,
    /// Reuse tiles whose haloed window has *accumulated* at most this
    /// per-pixel absolute difference since the tile was last
    /// recomputed (`0.0` = byte-identical only).
    Gate(f32),
}

impl DeltaMode {
    /// Parse a `--delta-gate` value: `off`, or a finite threshold >= 0.
    pub fn parse(s: &str) -> Option<DeltaMode> {
        if s == "off" {
            return Some(DeltaMode::Off);
        }
        s.parse::<f32>()
            .ok()
            .filter(|t| t.is_finite() && *t >= 0.0)
            .map(DeltaMode::Gate)
    }

    /// Config / report rendering (inverse of [`DeltaMode::parse`]).
    pub fn name(&self) -> String {
        match self {
            DeltaMode::Off => "off".into(),
            DeltaMode::Gate(t) => format!("{t}"),
        }
    }

    pub fn is_on(&self) -> bool {
        matches!(self, DeltaMode::Gate(_))
    }
}

impl Default for DeltaMode {
    /// Exact reuse: gated output is bit-identical to full detection.
    fn default() -> Self {
        DeltaMode::Gate(0.0)
    }
}

/// What one [`DeltaGate::advance`] did.
#[derive(Clone, Debug)]
pub struct GateRun {
    /// The stitched suppressed-magnitude map for this frame (the
    /// finish stage's [`crate::canny::StagePlan::from_suppressed`]
    /// entry artifact).
    pub nm: ImageF32,
    /// Tiles reused from the cache.
    pub clean: usize,
    /// Tiles recomputed.
    pub dirty: usize,
    /// False when no usable reference existed (first frame, size
    /// change, or [`DeltaMode::Off`]) — the frame ran a full front and
    /// does not count toward the gate hit-rate.
    pub gated: bool,
    pub wall_ns: u64,
    /// Summed per-tile thread-CPU cost.
    pub cpu_ns: u64,
    /// Per-tile thread-CPU costs (delta check + any recompute), one
    /// entry per gate tile — the parallel tasks of the frame.
    pub task_costs_ns: Vec<u64>,
}

/// The per-stream temporal cache + gate state. One gate per stream
/// (state carries across frames); not shareable across streams.
#[derive(Clone, Debug)]
pub struct DeltaGate {
    mode: DeltaMode,
    tile: usize,
    /// The previous frame (the per-frame delta baseline).
    prev: Option<ImageF32>,
    /// Cached suppressed magnitude. Invariant (threshold 0): for every
    /// gate tile, equals the exact front output of `prev` over that
    /// tile's core.
    nm: Option<ImageF32>,
    /// Per-tile drift accumulated since that tile's core was last
    /// recomputed: the sum of per-frame `tile_delta`s, an upper bound
    /// (triangle inequality) on the true difference between the
    /// current window and the one the cached core was computed from.
    /// Cleanliness tests `acc + delta <= threshold`, so nonzero
    /// thresholds bound total staleness, not just frame-to-frame
    /// flicker.
    acc: Vec<f32>,
}

impl DeltaGate {
    pub fn new(mode: DeltaMode) -> DeltaGate {
        DeltaGate::with_tile(mode, GATE_TILE)
    }

    /// Override the gate-tile core size (tests / tuning).
    pub fn with_tile(mode: DeltaMode, tile: usize) -> DeltaGate {
        DeltaGate { mode, tile: tile.max(1), prev: None, nm: None, acc: Vec::new() }
    }

    pub fn mode(&self) -> DeltaMode {
        self.mode
    }

    /// The cached suppressed map, if any (always `None` in
    /// [`DeltaMode::Off`], which keeps no cache).
    pub fn cached_nm(&self) -> Option<&ImageF32> {
        self.nm.as_ref()
    }

    /// Install an externally-computed `(frame, suppressed)` pair as the
    /// gate's reference — the shared-artifact-cache hit path: when a
    /// frame's exact front came from [`crate::cache::ArtifactCache`]
    /// (computed by another stream or a serving lane), the gate must
    /// adopt it as the new temporal baseline or the *next* frame would
    /// diff against a stale predecessor. The pair is exact by
    /// construction (content-addressed keys), so the drift accumulator
    /// resets to zero. No-op in [`DeltaMode::Off`], which keeps no
    /// state.
    pub fn install(&mut self, img: ImageF32, nm: ImageF32) -> Result<()> {
        if !self.mode.is_on() {
            return Ok(());
        }
        debug_assert_eq!((img.width(), img.height()), (nm.width(), nm.height()));
        let grid = TileGrid::new(img.width(), img.height(), self.tile, self.tile, consts::HALO)?;
        self.acc = vec![0.0; grid.tiles().count()];
        self.prev = Some(img);
        self.nm = Some(nm);
        Ok(())
    }

    /// Gate one frame: classify every tile, recompute the dirty ones
    /// (on `pool` when given, serially otherwise — both produce
    /// identical bytes), update the cache, and return the stitched map.
    /// Takes the frame by value: it becomes the next delta baseline
    /// without a copy.
    pub fn advance(&mut self, pool: Option<&Pool>, img: ImageF32) -> Result<GateRun> {
        let sw = Stopwatch::start();
        let (w, h) = (img.width(), img.height());
        let grid = TileGrid::new(w, h, self.tile, self.tile, consts::HALO)?;
        let tiles: Vec<_> = grid.tiles().collect();

        // A reference exists when gating is on and the cache (including
        // the drift accumulator) matches this frame's geometry;
        // otherwise the whole frame is dirty.
        let threshold = match (self.mode, &self.prev, &self.nm) {
            (DeltaMode::Gate(th), Some(p), Some(_))
                if p.width() == w && p.height() == h && self.acc.len() == tiles.len() =>
            {
                Some(th)
            }
            _ => None,
        };
        // Take (not clone) the cached map: clean cores are already in
        // place, dirty cores get overwritten below.
        let mut nm = match threshold {
            Some(_) => self.nm.take().expect("reference guard checked the cache"),
            None => ImageF32::zeros(w, h),
        };
        let prev = self.prev.as_ref();
        let acc = &self.acc;

        // Per tile: (dirty, accumulated drift after this frame, cpu ns).
        let results: Vec<(bool, f32, u64)>;
        {
            let nm_s = SharedSlice::new(nm.data_mut());
            let grid = &grid;
            let task = |i: usize, t: &crate::image::tile::Tile| {
                let c0 = thread_cpu_ns();
                let (dirty, drift) = match (threshold, prev) {
                    (Some(th), Some(prev)) => {
                        // Early-exit scan: once past the remaining
                        // budget the tile is dirty regardless of the
                        // exact max (the accumulator resets anyway).
                        let budget = th - acc[i];
                        let drift = acc[i] + grid.tile_delta_exceeds(prev, &img, *t, budget);
                        (drift > th, drift)
                    }
                    _ => (true, 0.0),
                };
                if dirty {
                    let window = grid.extract_clamped(&img, *t);
                    let tn = front_suppressed_window(&window);
                    debug_assert_eq!((tn.width(), tn.height()), (t.core_w, t.core_h));
                    for ty in 0..t.core_h {
                        let row0 = (t.y0 + ty) * w + t.x0;
                        // SAFETY: tiles cover disjoint output regions.
                        let row = unsafe { nm_s.range_mut(row0, row0 + t.core_w) };
                        row.copy_from_slice(&tn.data()[ty * t.core_w..(ty + 1) * t.core_w]);
                    }
                }
                // A recomputed core is the new reference: drift resets.
                (dirty, if dirty { 0.0 } else { drift }, thread_cpu_ns().saturating_sub(c0))
            };
            results = match pool {
                Some(pool) => patterns::par_map(pool, &tiles, 1, task),
                None => tiles.iter().enumerate().map(|(i, t)| task(i, t)).collect(),
            };
        }

        let dirty = results.iter().filter(|(d, _, _)| *d).count();
        let task_costs_ns: Vec<u64> = results.iter().map(|&(_, _, c)| c).collect();
        let cpu_ns = task_costs_ns.iter().sum();
        // Off mode never reads the cache — skip the cache maintenance
        // (and its nm clone) entirely. The frame itself moves into the
        // baseline without a copy.
        if self.mode.is_on() {
            self.prev = Some(img);
            self.nm = Some(nm.clone());
            self.acc = results.iter().map(|&(_, a, _)| a).collect();
        }
        Ok(GateRun {
            nm,
            clean: tiles.len() - dirty,
            dirty,
            gated: threshold.is_some(),
            wall_ns: sw.elapsed_ns(),
            cpu_ns,
            task_costs_ns,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::canny::front_serial;
    use crate::image::synth::{generate, Scene};

    #[test]
    fn mode_parse_roundtrip() {
        assert_eq!(DeltaMode::parse("off"), Some(DeltaMode::Off));
        assert_eq!(DeltaMode::parse("0"), Some(DeltaMode::Gate(0.0)));
        assert_eq!(DeltaMode::parse("0.05"), Some(DeltaMode::Gate(0.05)));
        assert_eq!(DeltaMode::parse("-1"), None);
        assert_eq!(DeltaMode::parse("inf"), None);
        assert_eq!(DeltaMode::parse("nope"), None);
        assert_eq!(DeltaMode::Off.name(), "off");
        assert_eq!(DeltaMode::parse(&DeltaMode::Gate(0.05).name()), Some(DeltaMode::Gate(0.05)));
        assert!(DeltaMode::default().is_on());
    }

    #[test]
    fn first_frame_is_full_and_matches_reference() {
        let img = generate(Scene::Shapes { seed: 4 }, 70, 50);
        let mut gate = DeltaGate::with_tile(DeltaMode::default(), 16);
        let run = gate.advance(None, img.clone()).unwrap();
        assert!(!run.gated);
        assert_eq!(run.clean, 0);
        let (_, want) = front_serial(&img, 0.05, 0.15);
        assert_eq!(run.nm, want, "first-frame front diverged from the serial reference");
    }

    #[test]
    fn static_frame_is_all_clean_and_byte_identical() {
        let img = generate(Scene::Shapes { seed: 4 }, 70, 50);
        let mut gate = DeltaGate::with_tile(DeltaMode::default(), 16);
        let first = gate.advance(None, img.clone()).unwrap();
        let second = gate.advance(None, img).unwrap();
        assert!(second.gated);
        assert_eq!(second.dirty, 0);
        assert_eq!(second.clean, first.clean + first.dirty);
        assert_eq!(second.nm, first.nm);
    }

    #[test]
    fn moving_frame_stays_exact_at_zero_threshold() {
        // The induction invariant: even when only some tiles recompute,
        // the stitched map equals a full front of the current frame.
        let mut gate = DeltaGate::with_tile(DeltaMode::Gate(0.0), 16);
        for k in 0..3 {
            let img = generate(Scene::Video { seed: 3, frame: k }, 96, 64);
            let run = gate.advance(None, img.clone()).unwrap();
            let (_, want) = front_serial(&img, 0.05, 0.15);
            assert_eq!(run.nm, want, "frame {k} diverged");
        }
    }

    #[test]
    fn pool_and_serial_recompute_agree() {
        let pool = crate::scheduler::Pool::new(3).unwrap();
        let frames: Vec<ImageF32> =
            (0..3).map(|k| generate(Scene::Video { seed: 9, frame: k }, 80, 60)).collect();
        let mut a = DeltaGate::with_tile(DeltaMode::default(), 16);
        let mut b = DeltaGate::with_tile(DeltaMode::default(), 16);
        for f in &frames {
            let ra = a.advance(Some(&pool), f.clone()).unwrap();
            let rb = b.advance(None, f.clone()).unwrap();
            assert_eq!(ra.nm, rb.nm);
            assert_eq!((ra.clean, ra.dirty), (rb.clean, rb.dirty));
        }
    }

    #[test]
    fn off_mode_never_gates() {
        let img = generate(Scene::Shapes { seed: 4 }, 48, 48);
        let mut gate = DeltaGate::with_tile(DeltaMode::Off, 16);
        for _ in 0..2 {
            let run = gate.advance(None, img.clone()).unwrap();
            assert!(!run.gated);
            assert_eq!(run.clean, 0);
        }
    }

    #[test]
    fn nonzero_threshold_bounds_accumulated_drift() {
        // A slow fade: +0.04/frame against a 0.05 threshold. Frame 1 is
        // within the budget (clean); by frame 2 the *accumulated* drift
        // (0.08) exceeds it, so tiles must recompute — staleness is
        // bounded, not just frame-to-frame flicker.
        let mut gate = DeltaGate::with_tile(DeltaMode::Gate(0.05), 16);
        let frame = |v: f32| {
            let mut img = ImageF32::zeros(32, 32);
            for p in img.data_mut() {
                *p = v;
            }
            img
        };
        let r0 = gate.advance(None, frame(0.20)).unwrap();
        assert!(!r0.gated);
        let r1 = gate.advance(None, frame(0.24)).unwrap();
        assert!(r1.gated);
        assert_eq!(r1.dirty, 0, "one 0.04 step stays under the 0.05 budget");
        let r2 = gate.advance(None, frame(0.28)).unwrap();
        assert_eq!(r2.clean, 0, "accumulated 0.08 drift must recompute every tile");
        // Recomputing reset the accumulator: the next 0.04 step is
        // clean again.
        let r3 = gate.advance(None, frame(0.32)).unwrap();
        assert_eq!(r3.dirty, 0);
    }

    #[test]
    fn off_mode_keeps_no_cache() {
        let img = generate(Scene::Shapes { seed: 4 }, 48, 48);
        let mut gate = DeltaGate::with_tile(DeltaMode::Off, 16);
        gate.advance(None, img).unwrap();
        assert!(gate.cached_nm().is_none(), "off mode must not pay for a cache");
    }

    #[test]
    fn install_becomes_the_gate_baseline() {
        // Frame 0's exact front arrives from the shared cache; the gate
        // adopts it and frame 0 replayed is then fully clean.
        let img = generate(Scene::Shapes { seed: 11 }, 64, 48);
        let (_, nm) = front_serial(&img, 0.05, 0.15);
        let mut gate = DeltaGate::with_tile(DeltaMode::default(), 16);
        gate.install(img.clone(), nm.clone()).unwrap();
        let run = gate.advance(None, img.clone()).unwrap();
        assert!(run.gated, "installed baseline must gate the next frame");
        assert_eq!(run.dirty, 0);
        assert_eq!(run.nm, nm);
        // A moving next frame stays exact against the installed
        // reference.
        let next = generate(Scene::Video { seed: 11, frame: 1 }, 64, 48);
        let mut gate2 = DeltaGate::with_tile(DeltaMode::Gate(0.0), 16);
        gate2.install(img, nm).unwrap();
        let run2 = gate2.advance(None, next.clone()).unwrap();
        let (_, want) = front_serial(&next, 0.05, 0.15);
        assert_eq!(run2.nm, want);
        // Off mode ignores installs entirely.
        let mut off = DeltaGate::with_tile(DeltaMode::Off, 16);
        off.install(generate(Scene::Gradient, 32, 32), ImageF32::zeros(32, 32)).unwrap();
        assert!(off.cached_nm().is_none());
    }

    #[test]
    fn size_change_resets_the_reference() {
        let mut gate = DeltaGate::with_tile(DeltaMode::default(), 16);
        let a = generate(Scene::Shapes { seed: 4 }, 48, 48);
        gate.advance(None, a).unwrap();
        let b = generate(Scene::Shapes { seed: 4 }, 64, 32);
        let run = gate.advance(None, b.clone()).unwrap();
        assert!(!run.gated, "mismatched geometry must not be gated");
        let (_, want) = front_serial(&b, 0.05, 0.15);
        assert_eq!(run.nm, want);
    }
}
