//! The stream report: what `cannyd stream` prints — throughput, gate
//! effectiveness, per-stage accounting and emission jitter, serialized
//! through [`crate::util::json::Json`] (deterministic key order; the
//! values themselves are measured wall-clock quantities). The schema is
//! documented in [`crate::stream`].

use std::collections::BTreeMap;

use crate::cache::CacheSnapshot;
use crate::service::{LatencySummary, WindowReport};
use crate::util::json::Json;

/// Aggregate of the [`crate::canny::StageRecord`]s one stage span
/// produced across the whole stream (plus the synthesized `decode`
/// span).
#[derive(Clone, Copy, Debug, Default)]
pub struct StageAgg {
    /// Summed wall time of the span's phases.
    pub wall_ns: u64,
    /// Summed thread-CPU cost.
    pub cpu_ns: u64,
    /// Summed parallel tasks (gate tiles for `front`, bands for
    /// `threshold`, 1 per frame for serial spans).
    pub tasks: u64,
    /// Frames that executed the span.
    pub frames: u64,
}

impl StageAgg {
    pub fn add(&mut self, wall_ns: u64, cpu_ns: u64, tasks: u64) {
        self.wall_ns += wall_ns;
        self.cpu_ns += cpu_ns;
        self.tasks += tasks;
        self.frames += 1;
    }

    fn to_json(self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("wall_ns".into(), Json::Num(self.wall_ns as f64));
        m.insert("cpu_ns".into(), Json::Num(self.cpu_ns as f64));
        m.insert("tasks".into(), Json::Num(self.tasks as f64));
        m.insert("frames".into(), Json::Num(self.frames as f64));
        Json::Obj(m)
    }
}

/// Delta-gate tallies over the stream. Degraded and dropped frames
/// never ran the gate and count in neither bucket.
#[derive(Clone, Debug)]
pub struct GateReport {
    /// `"off"` or the cleanliness threshold (`"0"` = exact reuse).
    pub mode: String,
    /// Tiles reused from the temporal cache (gated frames only).
    pub tiles_clean: u64,
    /// Tiles recomputed (gated frames only).
    pub tiles_dirty: u64,
    /// Frames classified against a reference frame.
    pub frames_gated: u64,
    /// Frames that ran a full front (first frame, size changes, or
    /// every computed frame when the gate is off).
    pub frames_full: u64,
}

impl GateReport {
    /// Fraction of gated tiles served from the cache (0 when nothing
    /// was gated).
    pub fn hit_rate(&self) -> f64 {
        let total = self.tiles_clean + self.tiles_dirty;
        if total == 0 {
            return 0.0;
        }
        self.tiles_clean as f64 / total as f64
    }

    fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("mode".into(), Json::Str(self.mode.clone()));
        m.insert("tiles_clean".into(), Json::Num(self.tiles_clean as f64));
        m.insert("tiles_dirty".into(), Json::Num(self.tiles_dirty as f64));
        m.insert("frames_gated".into(), Json::Num(self.frames_gated as f64));
        m.insert("frames_full".into(), Json::Num(self.frames_full as f64));
        m.insert("hit_rate".into(), Json::Num(self.hit_rate()));
        Json::Obj(m)
    }
}

/// The complete stream report (schema in [`crate::stream`]).
#[derive(Clone, Debug)]
pub struct StreamReport {
    pub label: String,
    /// Source description ([`crate::stream::FrameSource::describe`]).
    pub source: String,
    /// The detector engine (drives the finish stages; the gated front
    /// always runs the fused native tile path, as its stage records
    /// show). XLA detectors are rejected by the stream tier.
    pub engine: String,
    pub workers: usize,
    pub inflight: usize,
    pub frames_offered: u64,
    /// Frames that produced an edge map (includes degraded and cached
    /// ones).
    pub frames_emitted: u64,
    pub dropped: u64,
    pub degraded: u64,
    /// Frames whose suppressed map came whole from the shared artifact
    /// cache (no gate, no front) — cross-stream dedup at work.
    pub cached: u64,
    /// Frames past their deadline at front entry, whatever the policy.
    pub late: u64,
    pub wall_ns: u64,
    /// Input pixels of emitted frames.
    pub pixels: u64,
    /// Summed edge pixels over emitted frames.
    pub edge_pixels: u64,
    pub gate: GateReport,
    /// 0 = offline (no deadlines).
    pub frame_budget_ns: u64,
    pub drop_policy: String,
    /// Per-span aggregates keyed by
    /// [`crate::canny::StageRecord::span_name`] plus `decode`.
    pub stages: BTreeMap<String, StageAgg>,
    /// Inter-emission gap percentiles (the pacing smoothness measure).
    pub jitter: LatencySummary,
    /// Snapshot of the shared artifact cache (`--stream-cache`); the
    /// disabled all-zero snapshot when no cache is attached. Same
    /// schema as the serve report's `cache` section.
    pub cache: CacheSnapshot,
    /// Rolling frame-SLO window over emission latency vs. the frame
    /// budget (`--slo-window`): `no-data` offline (budget 0), otherwise
    /// the last-N windowed percentiles and the met/missed transition
    /// timeline. Same schema as the serve report's `slo.window`.
    pub slo: WindowReport,
}

impl StreamReport {
    /// Emitted frames per wall second.
    pub fn fps(&self) -> f64 {
        if self.wall_ns == 0 {
            return 0.0;
        }
        self.frames_emitted as f64 / (self.wall_ns as f64 / 1e9)
    }

    /// Megapixels of emitted input per wall second.
    pub fn mpix_per_s(&self) -> f64 {
        if self.wall_ns == 0 {
            return 0.0;
        }
        self.pixels as f64 / 1e6 / (self.wall_ns as f64 / 1e9)
    }

    /// Structured report (sorted keys — a deterministic dump for any
    /// given set of measured values).
    pub fn to_json(&self) -> Json {
        let num = |v: u64| Json::Num(v as f64);
        let mut m = BTreeMap::new();
        m.insert("label".into(), Json::Str(self.label.clone()));
        m.insert("source".into(), Json::Str(self.source.clone()));
        m.insert("engine".into(), Json::Str(self.engine.clone()));
        m.insert("workers".into(), Json::Num(self.workers as f64));
        m.insert("inflight".into(), Json::Num(self.inflight as f64));

        let mut f = BTreeMap::new();
        f.insert("offered".into(), num(self.frames_offered));
        f.insert("emitted".into(), num(self.frames_emitted));
        f.insert("dropped".into(), num(self.dropped));
        f.insert("degraded".into(), num(self.degraded));
        f.insert("cached".into(), num(self.cached));
        f.insert("late".into(), num(self.late));
        m.insert("frames".into(), Json::Obj(f));

        m.insert("wall_ns".into(), num(self.wall_ns));
        m.insert("fps".into(), Json::Num(self.fps()));
        m.insert("mpix_per_s".into(), Json::Num(self.mpix_per_s()));
        m.insert("edge_pixels".into(), num(self.edge_pixels));
        m.insert("gate".into(), self.gate.to_json());

        let mut b = BTreeMap::new();
        b.insert("frame_budget_ns".into(), num(self.frame_budget_ns));
        b.insert("drop_policy".into(), Json::Str(self.drop_policy.clone()));
        m.insert("budget".into(), Json::Obj(b));

        // Overload section, mirroring the serve report's: the stream
        // tier's shed decisions are its dropped (shed_rejected) and
        // degraded (shed_degraded) late frames under the drop policy.
        let mut o = BTreeMap::new();
        o.insert("policy".into(), Json::Str(self.drop_policy.clone()));
        o.insert("shed_rejected".into(), num(self.dropped));
        o.insert("shed_degraded".into(), num(self.degraded));
        m.insert("overload".into(), Json::Obj(o));
        m.insert("slo".into(), self.slo.to_json());

        m.insert(
            "stages".into(),
            Json::Obj(self.stages.iter().map(|(k, v)| (k.clone(), v.to_json())).collect()),
        );
        m.insert("jitter_ns".into(), self.jitter.to_json());
        m.insert("cache".into(), self.cache.to_json());
        Json::Obj(m)
    }

    /// The JSON text `cannyd stream` prints.
    pub fn to_json_string(&self) -> String {
        self.to_json().dump()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report() -> StreamReport {
        let mut stages = BTreeMap::new();
        let mut front = StageAgg::default();
        front.add(4_000_000, 3_000_000, 64);
        front.add(1_000_000, 500_000, 8);
        stages.insert("front".to_string(), front);
        StreamReport {
            label: "t".into(),
            source: "video:7 n=2 64x48".into(),
            engine: "patterns".into(),
            workers: 2,
            inflight: 4,
            frames_offered: 2,
            frames_emitted: 2,
            dropped: 0,
            degraded: 0,
            cached: 0,
            late: 0,
            wall_ns: 1_000_000_000,
            pixels: 2 * 64 * 48,
            edge_pixels: 321,
            gate: GateReport {
                mode: "0".into(),
                tiles_clean: 56,
                tiles_dirty: 8,
                frames_gated: 1,
                frames_full: 1,
            },
            frame_budget_ns: 0,
            drop_policy: "drop".into(),
            stages,
            jitter: LatencySummary::default(),
            cache: crate::cache::ArtifactCache::disabled().snapshot(),
            slo: WindowReport::empty(0, 64),
        }
    }

    #[test]
    fn rates_and_hit_rate() {
        let r = report();
        assert!((r.fps() - 2.0).abs() < 1e-9);
        assert!((r.mpix_per_s() - 2.0 * 64.0 * 48.0 / 1e6).abs() < 1e-9);
        assert!((r.gate.hit_rate() - 56.0 / 64.0).abs() < 1e-12);
        let empty = GateReport {
            mode: "off".into(),
            tiles_clean: 0,
            tiles_dirty: 0,
            frames_gated: 0,
            frames_full: 2,
        };
        assert_eq!(empty.hit_rate(), 0.0);
    }

    #[test]
    fn stage_agg_accumulates() {
        let mut a = StageAgg::default();
        a.add(10, 5, 3);
        a.add(20, 10, 1);
        assert_eq!((a.wall_ns, a.cpu_ns, a.tasks, a.frames), (30, 15, 4, 2));
    }

    #[test]
    fn json_schema_fields() {
        let j = report().to_json();
        assert_eq!(j.get("engine").unwrap().as_str(), Some("patterns"));
        let frames = j.get("frames").unwrap();
        for k in ["offered", "emitted", "dropped", "degraded", "cached", "late"] {
            assert!(frames.get(k).is_some(), "frames.{k} missing");
        }
        let cache = j.get("cache").unwrap();
        assert_eq!(cache.get("enabled"), Some(&Json::Bool(false)));
        assert!(cache.get("tiers").unwrap().get("stream").is_some());
        let gate = j.get("gate").unwrap();
        assert_eq!(gate.get("mode").unwrap().as_str(), Some("0"));
        assert!((gate.get("hit_rate").unwrap().as_f64().unwrap() - 0.875).abs() < 1e-12);
        let front = j.get("stages").unwrap().get("front").unwrap();
        assert_eq!(front.get("wall_ns").unwrap().as_usize(), Some(5_000_000));
        assert_eq!(front.get("frames").unwrap().as_usize(), Some(2));
        assert!(j.get("jitter_ns").unwrap().get("p99").is_some());
        assert_eq!(j.get("budget").unwrap().get("drop_policy").unwrap().as_str(), Some("drop"));
        let overload = j.get("overload").unwrap();
        assert_eq!(overload.get("policy").unwrap().as_str(), Some("drop"));
        assert_eq!(overload.get("shed_rejected").unwrap().as_usize(), Some(0));
        assert_eq!(overload.get("shed_degraded").unwrap().as_usize(), Some(0));
        assert_eq!(j.get("slo").unwrap().get("status").unwrap().as_str(), Some("no-data"));
        // Round-trips through the parser.
        let text = report().to_json_string();
        assert_eq!(Json::parse(&text).unwrap(), j);
    }
}
