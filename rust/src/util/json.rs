//! Minimal recursive-descent JSON parser — just enough for
//! `artifacts/manifest.json` (objects, arrays, strings, numbers, bools,
//! null). No serde available offline; ~200 lines keeps the runtime
//! self-contained.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document from text.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(Error::Artifact(format!("trailing JSON at byte {}", p.i)));
        }
        Ok(v)
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Object field or error (for required manifest fields).
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key)
            .ok_or_else(|| Error::Artifact(format!("missing manifest field `{key}`")))
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Convenience: `[1,2]` -> `vec![1, 2]`.
    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()?.iter().map(|j| j.as_usize()).collect()
    }

    /// Serialize back to compact JSON text. Deterministic: object keys
    /// come out in `BTreeMap` order and integral numbers render without
    /// a fractional part, so equal values always produce equal bytes —
    /// the property the serving report's replay tests rely on.
    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if !n.is_finite() {
                    // JSON has no Inf/NaN; null is the conventional stand-in.
                    out.push_str("null");
                } else if n.fract() == 0.0 {
                    // Full integral value, however large: u64-scale
                    // byte counters must not saturate through an i64
                    // cast or degrade to a rounded shortest-round-trip
                    // decimal. `{:.0}` prints the exact integer this
                    // f64 holds (every integral f64 is exact).
                    out.push_str(&format!("{n:.0}"));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn err<T>(&self, msg: &str) -> Result<T> {
        Err(Error::Artifact(format!("JSON parse error at byte {}: {msg}", self.i)))
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            self.err(&format!("expected `{}`", c as char))
        }
    }

    /// Parse 4 hex digits starting at byte `start` (a `\uXXXX` payload).
    fn hex4(&self, start: usize) -> Result<u32> {
        let end = start + 4;
        if end > self.b.len() {
            return Err(Error::Artifact("truncated \\u escape".into()));
        }
        let hex = std::str::from_utf8(&self.b[start..end])
            .map_err(|_| Error::Artifact("bad \\u escape".into()))?;
        u32::from_str_radix(hex, 16).map_err(|_| Error::Artifact("bad \\u escape".into()))
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            self.err(&format!("expected `{s}`"))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => self.err("expected a JSON value"),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            m.insert(k, self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return self.err("expected `,` or `}`"),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return self.err("expected `,` or `]`"),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return self.err("unterminated string"),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hi = self.hex4(self.i + 1)?;
                            self.i += 4;
                            let cp = if (0xD800..0xDC00).contains(&hi) {
                                // A high surrogate: JSON encodes
                                // non-BMP characters as a UTF-16 pair
                                // of escapes — combine with the low
                                // half when one follows, else fall
                                // through to U+FFFD (lone surrogate).
                                let lo_follows = self.b.get(self.i + 1) == Some(&b'\\')
                                    && self.b.get(self.i + 2) == Some(&b'u');
                                match lo_follows.then(|| self.hex4(self.i + 3)) {
                                    Some(Ok(lo)) if (0xDC00..0xE000).contains(&lo) => {
                                        // Past `\u` + the low half's 4
                                        // hex digits.
                                        self.i += 6;
                                        0x1_0000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                                    }
                                    _ => hi,
                                }
                            } else {
                                hi
                            };
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => return self.err("bad escape"),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar (manifest content is ASCII,
                    // but be correct anyway).
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.b.len() && (self.b[self.i] & 0xC0) == 0x80 {
                        self.i += 1;
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..self.i]).unwrap_or("\u{fffd}"));
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| Error::Artifact(format!("bad number `{text}`")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("3.5").unwrap(), Json::Num(3.5));
        assert_eq!(Json::parse("-12").unwrap(), Json::Num(-12.0));
        assert_eq!(Json::parse("1e3").unwrap(), Json::Num(1000.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("c")
        );
        assert_eq!(j.get("d"), Some(&Json::Null));
    }

    #[test]
    fn parses_escapes() {
        let j = Json::parse(r#""a\nb\t\"q\" A""#).unwrap();
        assert_eq!(j.as_str(), Some("a\nb\t\"q\" A"));
    }

    #[test]
    fn surrogate_pairs_combine() {
        // U+1F600 (😀) in JSON's UTF-16 escape form: a \ud83d\ude00
        // pair must decode to one character, not two U+FFFD.
        let j = Json::parse(r#""\ud83d\ude00""#).unwrap();
        assert_eq!(j.as_str(), Some("😀"));
        // Pairs embedded in surrounding text, twice over (U+1F4A9).
        let j = Json::parse(r#""a\ud83d\ude00b\ud83d\udca9""#).unwrap();
        assert_eq!(j.as_str(), Some("a😀b💩"));
        // The combined character survives a dump/parse round trip.
        assert_eq!(Json::parse(&j.dump()).unwrap(), j);
        // Raw (non-escaped) UTF-8 still passes through untouched.
        assert_eq!(Json::parse(r#""😀""#).unwrap().as_str(), Some("😀"));
    }

    #[test]
    fn lone_surrogates_become_replacement_chars() {
        // High half with nothing after it.
        assert_eq!(Json::parse(r#""\ud83d""#).unwrap().as_str(), Some("\u{fffd}"));
        // High half followed by a non-escape.
        assert_eq!(Json::parse(r#""\ud83dxy""#).unwrap().as_str(), Some("\u{fffd}xy"));
        // High half followed by a non-surrogate escape: both survive
        // on their own terms.
        assert_eq!(Json::parse(r#""\ud83dA""#).unwrap().as_str(), Some("\u{fffd}A"));
        // Two high halves: neither combines.
        assert_eq!(
            Json::parse(r#""\ud83d\ud83d""#).unwrap().as_str(),
            Some("\u{fffd}\u{fffd}")
        );
        // A lone low half.
        assert_eq!(Json::parse(r#""\ude00""#).unwrap().as_str(), Some("\u{fffd}"));
        // Truncated escapes still error.
        assert!(Json::parse(r#""\ud83d\u00""#).is_err());
        assert!(Json::parse(r#""\u12""#).is_err());
    }

    #[test]
    fn dump_emits_full_u64_scale_integers() {
        // 2^63 (as f64): above i64::MAX, so an `as i64` rendering would
        // saturate to 2^63 - 1, and the pre-fix fallback printed the
        // shortest-round-trip decimal (…776000) instead of the exact
        // integral value. Byte counters live at this scale.
        let big = 9_223_372_036_854_775_808.0f64;
        let text = Json::Num(big).dump();
        assert_eq!(text, "9223372036854775808");
        assert_eq!(Json::parse(&text).unwrap(), Json::Num(big));
        // 2^64 (u64::MAX rounds here as f64): full digits, round trip.
        let two64 = 18_446_744_073_709_551_616.0f64;
        assert_eq!(Json::Num(two64).dump(), "18446744073709551616");
        assert_eq!(Json::parse(&Json::Num(two64).dump()).unwrap(), Json::Num(two64));
        // Negative side too.
        assert_eq!(Json::Num(-two64).dump(), "-18446744073709551616");
        // Small integral values keep their classic rendering.
        assert_eq!(Json::Num(4.0).dump(), "4");
        assert_eq!(Json::Num(-12.0).dump(), "-12");
        // Fractional values are untouched.
        assert_eq!(Json::Num(2.5).dump(), "2.5");
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} extra").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn usize_vec_helper() {
        let j = Json::parse("[136, 136]").unwrap();
        assert_eq!(j.as_usize_vec(), Some(vec![136, 136]));
        assert_eq!(Json::parse("[1, \"x\"]").unwrap().as_usize_vec(), None);
    }

    #[test]
    fn dump_roundtrips_through_parse() {
        let j = Json::parse(r#"{"a": [1, 2.5, {"b": "c\nd"}], "e": null, "f": true}"#).unwrap();
        let text = j.dump();
        assert_eq!(Json::parse(&text).unwrap(), j);
        // Integral numbers render without a fractional part.
        assert!(text.contains("[1,2.5,"), "{text}");
    }

    #[test]
    fn dump_is_deterministic_and_escaped() {
        let mut m = BTreeMap::new();
        m.insert("z".to_string(), Json::Num(4.0));
        m.insert("a".to_string(), Json::Str("q\"\\\u{1}".into()));
        let j = Json::Obj(m);
        assert_eq!(j.dump(), j.dump());
        // Keys in BTreeMap order, controls escaped.
        assert_eq!(j.dump(), "{\"a\":\"q\\\"\\\\\\u0001\",\"z\":4}");
        assert_eq!(Json::parse(&j.dump()).unwrap(), j);
    }

    #[test]
    fn dump_nonfinite_as_null() {
        assert_eq!(Json::Num(f64::NAN).dump(), "null");
        assert_eq!(Json::Num(f64::INFINITY).dump(), "null");
    }

    #[test]
    fn parses_real_manifest_shape() {
        let j = Json::parse(
            r#"{"format":1,"halo":4,"tiles":[{"name":"t128","core":[128,128],
               "entries":{"canny_front":{"file":"f.hlo.txt","inputs":[[136,136],[1],[1]],
               "outputs":[[128,128],[128,128]]}}}]}"#,
        )
        .unwrap();
        assert_eq!(j.req("halo").unwrap().as_usize(), Some(4));
        let tiles = j.req("tiles").unwrap().as_arr().unwrap();
        assert_eq!(tiles[0].req("core").unwrap().as_usize_vec(), Some(vec![128, 128]));
    }
}
