//! Timing helpers shared by the bench harness and the profiler: a
//! monotonic stopwatch, thread-CPU-time readings (for the simulator's
//! cost measurements on a timeshared host) and simple summary stats.

use std::time::{Duration, Instant};

/// Monotonic stopwatch.
#[derive(Clone, Copy, Debug)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_ns(&self) -> u64 {
        self.elapsed().as_nanos() as u64
    }
}

/// CLOCK_THREAD_CPUTIME_ID in nanoseconds — CPU time consumed by the
/// *calling thread* only. On a 1-CPU container this is the honest task
/// cost measure (wall-clock includes other threads' timeslices).
pub fn thread_cpu_ns() -> u64 {
    let mut ts = libc::timespec { tv_sec: 0, tv_nsec: 0 };
    // SAFETY: valid pointer, documented clock id.
    unsafe {
        libc::clock_gettime(libc::CLOCK_THREAD_CPUTIME_ID, &mut ts);
    }
    ts.tv_sec as u64 * 1_000_000_000 + ts.tv_nsec as u64
}

/// CLOCK_PROCESS_CPUTIME_ID in nanoseconds (all threads).
pub fn process_cpu_ns() -> u64 {
    let mut ts = libc::timespec { tv_sec: 0, tv_nsec: 0 };
    // SAFETY: valid pointer, documented clock id.
    unsafe {
        libc::clock_gettime(libc::CLOCK_PROCESS_CPUTIME_ID, &mut ts);
    }
    ts.tv_sec as u64 * 1_000_000_000 + ts.tv_nsec as u64
}

/// Summary statistics over a set of duration samples (ns).
#[derive(Clone, Debug, Default)]
pub struct Summary {
    pub n: usize,
    pub min_ns: u64,
    pub p10_ns: u64,
    pub median_ns: u64,
    pub p90_ns: u64,
    pub max_ns: u64,
    pub mean_ns: f64,
}

impl Summary {
    pub fn from_samples(mut samples: Vec<u64>) -> Summary {
        if samples.is_empty() {
            return Summary::default();
        }
        samples.sort_unstable();
        let n = samples.len();
        let q = |p: f64| samples[((n - 1) as f64 * p).round() as usize];
        Summary {
            n,
            min_ns: samples[0],
            p10_ns: q(0.10),
            median_ns: q(0.50),
            p90_ns: q(0.90),
            max_ns: samples[n - 1],
            mean_ns: samples.iter().sum::<u64>() as f64 / n as f64,
        }
    }

    /// "12.3 ms" style rendering of the median.
    pub fn human_median(&self) -> String {
        human_ns(self.median_ns)
    }
}

/// Render nanoseconds for humans.
pub fn human_ns(ns: u64) -> String {
    if ns >= 1_000_000_000 {
        format!("{:.2} s", ns as f64 / 1e9)
    } else if ns >= 1_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else if ns >= 1_000 {
        format!("{:.2} µs", ns as f64 / 1e3)
    } else {
        format!("{ns} ns")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_monotone() {
        let sw = Stopwatch::start();
        std::thread::sleep(Duration::from_millis(2));
        assert!(sw.elapsed_ns() >= 1_000_000);
    }

    #[test]
    fn thread_cpu_advances_under_load() {
        let a = thread_cpu_ns();
        let mut acc = 0u64;
        for i in 0..2_000_000u64 {
            acc = acc.wrapping_add(i.wrapping_mul(2654435761));
        }
        std::hint::black_box(acc);
        assert!(thread_cpu_ns() > a);
    }

    #[test]
    fn summary_quantiles() {
        let s = Summary::from_samples((1..=100).collect());
        assert_eq!(s.n, 100);
        assert_eq!(s.min_ns, 1);
        assert_eq!(s.max_ns, 100);
        assert!(s.median_ns == 50 || s.median_ns == 51, "median={}", s.median_ns);
        assert!(s.p90_ns >= 89 && s.p90_ns <= 91);
        assert!((s.mean_ns - 50.5).abs() < 1e-9);
    }

    #[test]
    fn human_rendering() {
        assert_eq!(human_ns(500), "500 ns");
        assert_eq!(human_ns(1_500), "1.50 µs");
        assert_eq!(human_ns(2_500_000), "2.50 ms");
        assert_eq!(human_ns(3_000_000_000), "3.00 s");
    }
}
