//! Small self-contained utilities: deterministic PRNG, minimal JSON
//! parser (for `artifacts/manifest.json` — no serde offline), shared
//! disjoint-write slices for the pattern implementations, and timing
//! helpers for the bench harness.

pub mod json;
pub mod prng;
pub mod shared_slice;
pub mod timer;

pub use prng::Prng;
pub use shared_slice::SharedSlice;
