//! Deterministic xoshiro256** PRNG.
//!
//! Used everywhere randomness is needed (synthetic scenes, steal-victim
//! selection, property-test generators) so that every run — and every
//! figure regenerated from a run — is reproducible from a seed.

/// xoshiro256** by Blackman & Vigna (public domain reference impl).
#[derive(Clone, Debug)]
pub struct Prng {
    s: [u64; 4],
}

impl Prng {
    /// Seed via splitmix64 so short/low-entropy seeds still give good
    /// state separation.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        Prng { s: [next(), next(), next(), next()] }
    }

    /// Next raw u64.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f32 in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        ((self.next_u64() >> 40) as f32) * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform f64 in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        ((self.next_u64() >> 11) as f64) * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform usize in [0, n). n must be > 0.
    pub fn next_below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform i64 in [lo, hi).
    pub fn next_range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(hi > lo);
        lo + (self.next_u64() % (hi - lo) as u64) as i64
    }

    /// Approximately standard-normal sample (Box–Muller on one pair).
    pub fn next_gaussian(&mut self) -> f32 {
        let u1 = (self.next_f64()).max(1e-12);
        let u2 = self.next_f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// Fork an independent stream (for per-worker / per-tile use).
    pub fn fork(&mut self, stream: u64) -> Prng {
        Prng::new(self.next_u64() ^ stream.wrapping_mul(0xA24B_AED4_963E_E407))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Prng::new(42);
        let mut b = Prng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Prng::new(1);
        let mut b = Prng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut p = Prng::new(7);
        for _ in 0..10_000 {
            let v = p.next_f32();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn next_below_bounds() {
        let mut p = Prng::new(9);
        for n in 1..50usize {
            for _ in 0..100 {
                assert!(p.next_below(n) < n);
            }
        }
    }

    #[test]
    fn gaussian_moments_plausible() {
        let mut p = Prng::new(1234);
        let n = 50_000;
        let (mut sum, mut sq) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let v = p.next_gaussian() as f64;
            sum += v;
            sq += v * v;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Prng::new(5);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
