//! Disjoint-write shared slice: the unsafe core that lets the parallel
//! patterns write results from many workers into one output buffer
//! without locks.
//!
//! Safety contract: callers must guarantee that concurrently-written
//! index ranges are disjoint. Every pattern in [`crate::patterns`]
//! derives its ranges from a deterministic chunking of `0..len`, which
//! makes the contract auditable at the call site (and is what makes the
//! patterns deterministic, per the paper's goal).

use std::cell::UnsafeCell;

/// A `&mut [T]` that can be shared across scoped threads for disjoint
/// range writes.
pub struct SharedSlice<'a, T> {
    data: &'a UnsafeCell<[T]>,
}

// SAFETY: access discipline (disjoint ranges) is enforced by callers per
// the module contract; T: Send suffices because only &mut-style access
// to disjoint elements happens.
unsafe impl<'a, T: Send> Send for SharedSlice<'a, T> {}
unsafe impl<'a, T: Send> Sync for SharedSlice<'a, T> {}

impl<'a, T> SharedSlice<'a, T> {
    /// Wrap a mutable slice.
    pub fn new(slice: &'a mut [T]) -> Self {
        // SAFETY: UnsafeCell<[T]> has the same layout as [T].
        let data = unsafe { &*(slice as *mut [T] as *const UnsafeCell<[T]>) };
        SharedSlice { data }
    }

    /// Total length.
    pub fn len(&self) -> usize {
        // Reads the fat-pointer metadata only (no dereference).
        let ptr: *mut [T] = self.data.get();
        ptr.len()
    }

    /// Whether the slice is empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Get a mutable sub-slice for `range`.
    ///
    /// # Safety
    /// The caller must ensure no other thread concurrently accesses any
    /// index in `range`.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn range_mut(&self, start: usize, end: usize) -> &mut [T] {
        debug_assert!(start <= end && end <= self.len());
        // SAFETY: the pointer covers the whole backing slice by
        // construction and `range` is in bounds (debug-asserted); the
        // caller upholds exclusivity per this fn's contract.
        unsafe {
            let base = (*self.data.get()).as_mut_ptr();
            std::slice::from_raw_parts_mut(base.add(start), end - start)
        }
    }

    /// Write one element.
    ///
    /// # Safety
    /// The caller must ensure no other thread concurrently accesses
    /// index `i`.
    pub unsafe fn write(&self, i: usize, value: T) {
        debug_assert!(i < self.len());
        // SAFETY: `i` is in bounds (debug-asserted) and the caller
        // upholds exclusivity per this fn's contract.
        unsafe {
            let base = (*self.data.get()).as_mut_ptr();
            base.add(i).write(value);
        }
    }
}

// Manual impl: shows only the length — reading elements through `&self`
// would race with concurrent writers, and `T: Debug` must not be
// required of callers.
impl<T> std::fmt::Debug for SharedSlice<'_, T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SharedSlice").field("len", &self.len()).finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disjoint_writes_land() {
        let mut v = vec![0u32; 100];
        {
            let s = SharedSlice::new(&mut v);
            std::thread::scope(|scope| {
                for chunk in 0..4 {
                    let s = &s;
                    scope.spawn(move || {
                        let (lo, hi) = (chunk * 25, chunk * 25 + 25);
                        let part = unsafe { s.range_mut(lo, hi) };
                        for (k, slot) in part.iter_mut().enumerate() {
                            *slot = (lo + k) as u32;
                        }
                    });
                }
            });
        }
        assert!(v.iter().enumerate().all(|(i, &x)| x == i as u32));
    }

    #[test]
    fn single_writes_land() {
        let mut v = vec![0u8; 16];
        {
            let s = SharedSlice::new(&mut v);
            for i in 0..16 {
                unsafe { s.write(i, i as u8 * 2) };
            }
        }
        assert_eq!(v[15], 30);
    }
}
