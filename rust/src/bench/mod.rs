//! Minimal benchmark harness (criterion is unavailable offline): warmup
//! + timed iterations + robust summary stats, plus helpers the figure
//! benches share (output directory, markdown-ish tables).

use std::path::PathBuf;

use crate::util::timer::{human_ns, Stopwatch, Summary};

/// Run `f` for `warmup` untimed and `iters` timed iterations.
pub fn bench<R>(warmup: usize, iters: usize, mut f: impl FnMut() -> R) -> Summary {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters.max(1) {
        let sw = Stopwatch::start();
        std::hint::black_box(f());
        samples.push(sw.elapsed_ns());
    }
    Summary::from_samples(samples)
}

/// Print one bench result line (standardized for bench_output.txt).
pub fn report(name: &str, s: &Summary) {
    println!(
        "bench {name:<42} median {:>12}  p10 {:>12}  p90 {:>12}  n={}",
        human_ns(s.median_ns),
        human_ns(s.p10_ns),
        human_ns(s.p90_ns),
        s.n
    );
}

/// Where figure CSVs/charts land (`target/figures`).
pub fn figures_dir() -> PathBuf {
    let dir = PathBuf::from("target/figures");
    let _ = std::fs::create_dir_all(&dir);
    dir
}

/// Simple aligned table printer for bench output.
#[derive(Clone, Debug)]
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    pub fn to_string(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = fmt_row(&self.headers);
        out.push('\n');
        out.push_str(&"-".repeat(out.len().saturating_sub(1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.to_string());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_iters() {
        let s = bench(1, 5, || std::hint::black_box((0..1000u64).sum::<u64>()));
        assert_eq!(s.n, 5);
        assert!(s.median_ns > 0);
        assert!(s.min_ns <= s.median_ns && s.median_ns <= s.max_ns);
    }

    #[test]
    fn table_alignment() {
        let mut t = Table::new(&["name", "value"]);
        t.row(&["a".into(), "1".into()]);
        t.row(&["longer".into(), "22".into()]);
        let s = t.to_string();
        assert!(s.contains("name"));
        assert!(s.lines().count() == 4);
    }
}
