//! Amdahl's-law speedup models, including the paper's asymmetric
//! multicore corollary (Hill & Marty form):
//!
//! ```text
//! Speedup_asymmetric(f, n, r) = 1 / ( (1-f)/perf(r) + f/(perf(r) + n - r) )
//! ```
//!
//! where `n` is the total core budget (in base-core equivalents), `r`
//! the resources fused into the one big core that runs the serial
//! fraction, and `perf(r) = sqrt(r)` (Pollack's rule), the standard
//! assumption the paper inherits from Hill & Marty.
//!
//! The paper invokes this model to argue that the serial hysteresis
//! stage (its deliberately-unparallelized step 4) should run on a big
//! core of an asymmetric multicore. [`fit_parallel_fraction`] inverts
//! the symmetric model to estimate the achieved `f` from measured
//! speedups (used by the `amdahl_model` bench to tie model to data).

/// Pollack's-rule performance of a core built from `r` base cores.
pub fn perf(r: f64) -> f64 {
    r.max(1.0).sqrt()
}

/// Classic (symmetric) Amdahl speedup with parallel fraction `f` on `n`
/// equal cores.
pub fn speedup_symmetric(f: f64, n: usize) -> f64 {
    assert!((0.0..=1.0).contains(&f));
    assert!(n >= 1);
    1.0 / ((1.0 - f) + f / n as f64)
}

/// The paper's asymmetric-multicore speedup: one big core of `r`
/// base-core equivalents plus `n - r` small cores.
pub fn speedup_asymmetric(f: f64, n: usize, r: usize) -> f64 {
    assert!((0.0..=1.0).contains(&f));
    assert!(n >= 1 && r >= 1 && r <= n);
    let pr = perf(r as f64);
    1.0 / ((1.0 - f) / pr + f / (pr + (n - r) as f64))
}

/// The `r` (1..=n) maximizing [`speedup_asymmetric`] for given `f`, `n`.
pub fn best_asymmetric_r(f: f64, n: usize) -> usize {
    (1..=n)
        .max_by(|&a, &b| {
            speedup_asymmetric(f, n, a)
                .partial_cmp(&speedup_asymmetric(f, n, b))
                .unwrap()
        })
        .unwrap_or(1)
}

/// Estimate the parallel fraction `f` from a measured speedup `s` on
/// `n` symmetric cores (inverse Amdahl; the "Karp–Flatt"-style fit).
pub fn fit_parallel_fraction(s: f64, n: usize) -> f64 {
    if n <= 1 || s <= 0.0 {
        return 0.0;
    }
    let n = n as f64;
    // s = 1 / ((1-f) + f/n)  =>  f = (1 - 1/s) / (1 - 1/n)
    (((1.0 - 1.0 / s) / (1.0 - 1.0 / n)).clamp(0.0, 1.0) * 1e12).round() / 1e12
}

/// A speedup curve sample for the model benches.
#[derive(Clone, Debug)]
pub struct CurvePoint {
    pub n: usize,
    pub symmetric: f64,
    pub asymmetric_best: f64,
    pub best_r: usize,
}

/// Speedup curve for `f` over core counts `ns`.
pub fn curve(f: f64, ns: &[usize]) -> Vec<CurvePoint> {
    ns.iter()
        .map(|&n| {
            let best_r = best_asymmetric_r(f, n);
            CurvePoint {
                n,
                symmetric: speedup_symmetric(f, n),
                asymmetric_best: speedup_asymmetric(f, n, best_r),
                best_r,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn symmetric_limits() {
        assert!((speedup_symmetric(0.0, 8) - 1.0).abs() < 1e-12);
        assert!((speedup_symmetric(1.0, 8) - 8.0).abs() < 1e-12);
        // f = 0.95, n -> inf caps at 20.
        assert!(speedup_symmetric(0.95, 100_000) < 20.0);
    }

    #[test]
    fn asymmetric_reduces_to_symmetric_at_r1() {
        for &f in &[0.3, 0.7, 0.95] {
            for &n in &[2usize, 4, 8, 16] {
                let a = speedup_asymmetric(f, n, 1);
                let s = speedup_symmetric(f, n);
                // perf(1) = 1: 1/((1-f) + f/(1 + n - 1)) == symmetric.
                assert!((a - s).abs() < 1e-12, "f={f} n={n}: {a} vs {s}");
            }
        }
    }

    #[test]
    fn asymmetric_beats_symmetric_for_serial_heavy() {
        // With a large serial fraction, some r > 1 must win (Hill&Marty).
        let f = 0.5;
        let n = 16;
        let r = best_asymmetric_r(f, n);
        assert!(r > 1);
        assert!(speedup_asymmetric(f, n, r) > speedup_symmetric(f, n));
    }

    #[test]
    fn monotone_in_f() {
        for &n in &[4usize, 8] {
            let mut prev = 0.0;
            for k in 0..=10 {
                let s = speedup_symmetric(k as f64 / 10.0, n);
                assert!(s >= prev);
                prev = s;
            }
        }
    }

    #[test]
    fn fit_inverts_model() {
        for &f in &[0.2, 0.6, 0.9, 0.99] {
            for &n in &[2usize, 4, 8] {
                let s = speedup_symmetric(f, n);
                let fhat = fit_parallel_fraction(s, n);
                assert!((fhat - f).abs() < 1e-9, "f={f} n={n} fhat={fhat}");
            }
        }
    }

    #[test]
    fn fit_clamps() {
        assert_eq!(fit_parallel_fraction(0.5, 4), 0.0); // "slowdown" -> 0
        assert_eq!(fit_parallel_fraction(100.0, 4), 1.0); // superlinear -> 1
    }

    #[test]
    fn curve_has_all_points() {
        let c = curve(0.9, &[1, 2, 4, 8]);
        assert_eq!(c.len(), 4);
        assert!(c[3].symmetric > c[1].symmetric);
        assert!(c.iter().all(|p| p.asymmetric_best >= p.symmetric - 1e-12));
    }
}
