//! Sampling CPU profiler — the substitute for the paper's Visual Studio
//! profiler (which samples every 10M processor cycles). Two sources
//! produce the same artifact type:
//!
//! * [`Sampler`] — a real sampling thread reading per-worker busy flags
//!   from a live [`crate::scheduler::PoolStats`];
//! * [`UsageTrace::from_sim`] — sampled from a deterministic
//!   [`crate::simsched::SimResult`] (virtual topology).
//! * [`crate::obs::WallSnapshotter`] — the ops plane's telemetry
//!   sampler accumulates the same [`UsageTrace`] while it writes each
//!   busy-flag sample into the per-tick `utilization` section of the
//!   `--telemetry-log` JSONL stream, so a serving or stream run gets
//!   the Figure-8/9 core-usage data without a separate profiler
//!   invocation.
//!
//! [`UsageTrace`] renders the paper's figures: total-CPU% over
//! wall-clock (Figures 8/9) and per-core% (Figures 9b–12), as CSV for
//! plotting and as ASCII charts for the terminal; `busy_samples()`
//! reproduces the §3.1 sample-count comparison.

use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::error::Result;
use crate::scheduler::PoolStats;
use crate::simsched::SimResult;
use crate::util::timer::Stopwatch;

/// One sample: which workers were busy at a point in time.
#[derive(Clone, Debug)]
pub struct UsageSample {
    pub t_ns: u64,
    pub busy: Vec<bool>,
}

/// A utilization trace over time for `cores` workers.
#[derive(Clone, Debug)]
pub struct UsageTrace {
    pub cores: usize,
    pub period_ns: u64,
    pub samples: Vec<UsageSample>,
    /// Optional label ("suboptimal 4 CPUs", …) used in chart titles.
    pub label: String,
}

impl UsageTrace {
    /// Build from a finished simulation.
    pub fn from_sim(sim: &SimResult, period_ns: u64, label: &str) -> UsageTrace {
        let grid = sim.sample(period_ns);
        UsageTrace {
            cores: sim.cores,
            period_ns,
            samples: grid
                .into_iter()
                .enumerate()
                .map(|(k, busy)| UsageSample { t_ns: k as u64 * period_ns, busy })
                .collect(),
            label: label.into(),
        }
    }

    /// Total CPU usage (%) per sample — the Figure 8/9 series.
    pub fn total_pct(&self) -> Vec<f64> {
        self.samples
            .iter()
            .map(|s| 100.0 * s.busy.iter().filter(|&&b| b).count() as f64 / self.cores as f64)
            .collect()
    }

    /// Per-core usage (%) over windows of `window` samples — the
    /// Figure 9b-12 series (smoothed like a profiler's core graphs).
    pub fn per_core_pct(&self, window: usize) -> Vec<Vec<f64>> {
        let window = window.max(1);
        (0..self.cores)
            .map(|c| {
                self.samples
                    .chunks(window)
                    .map(|chunk| {
                        100.0 * chunk.iter().filter(|s| s.busy[c]).count() as f64
                            / chunk.len() as f64
                    })
                    .collect()
            })
            .collect()
    }

    /// Mean total utilization in [0, 100].
    pub fn mean_total_pct(&self) -> f64 {
        let series = self.total_pct();
        if series.is_empty() {
            return 0.0;
        }
        series.iter().sum::<f64>() / series.len() as f64
    }

    /// Number of busy (worker, sample) pairs — the profiler "samples
    /// collected" counter from the paper's §3.1 (a busy core produces a
    /// sample each tick, an idle one does not).
    pub fn busy_samples(&self) -> usize {
        self.samples.iter().map(|s| s.busy.iter().filter(|&&b| b).count()).sum()
    }

    /// Write `t_ns,core0,...,coreN-1,total_pct` CSV.
    pub fn write_csv(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut out = String::new();
        out.push_str("t_ns");
        for c in 0..self.cores {
            out.push_str(&format!(",core{c}"));
        }
        out.push_str(",total_pct\n");
        for s in &self.samples {
            out.push_str(&s.t_ns.to_string());
            let busy = s.busy.iter().filter(|&&b| b).count();
            for &b in &s.busy {
                out.push_str(if b { ",1" } else { ",0" });
            }
            out.push_str(&format!(",{:.1}\n", 100.0 * busy as f64 / self.cores as f64));
        }
        std::fs::write(path, out)?;
        Ok(())
    }

    /// ASCII chart of total CPU usage over time (Figures 8/9 rendering).
    pub fn ascii_total(&self, width: usize, height: usize) -> String {
        ascii_chart(
            &format!("{} — total CPU usage (%)", self.label),
            &self.total_pct(),
            width,
            height,
        )
    }

    /// ASCII charts per core (Figures 9b-12 rendering).
    pub fn ascii_per_core(&self, width: usize, height: usize) -> String {
        let window = (self.samples.len() / width.max(1)).max(1);
        let series = self.per_core_pct(window);
        let mut out = String::new();
        for (c, s) in series.iter().enumerate() {
            out.push_str(&ascii_chart(
                &format!("{} — CPU {c} usage (%)", self.label),
                s,
                width,
                height,
            ));
            out.push('\n');
        }
        out
    }
}

/// Render a 0-100 series as an ASCII area chart.
pub fn ascii_chart(title: &str, series: &[f64], width: usize, height: usize) -> String {
    let width = width.max(10);
    let height = height.max(3);
    let mut out = format!("{title}\n");
    if series.is_empty() {
        out.push_str("(no samples)\n");
        return out;
    }
    // Downsample/average the series to `width` columns.
    let cols: Vec<f64> = (0..width)
        .map(|c| {
            let lo = c * series.len() / width;
            let hi = ((c + 1) * series.len() / width).clamp(lo + 1, series.len());
            series[lo..hi].iter().sum::<f64>() / (hi - lo) as f64
        })
        .collect();
    for row in (0..height).rev() {
        let threshold = (row as f64 + 0.5) * 100.0 / height as f64;
        let label = if row == height - 1 {
            "100|"
        } else if row == 0 {
            "  0|"
        } else {
            "   |"
        };
        out.push_str(label);
        for &v in &cols {
            out.push(if v >= threshold { '█' } else { ' ' });
        }
        out.push('\n');
    }
    out.push_str(&format!("    +{}\n", "-".repeat(width)));
    out
}

/// Live sampler over a pool's stats (the VS-profiler substitute).
#[derive(Debug)]
pub struct Sampler {
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<Vec<UsageSample>>>,
    period_ns: u64,
    cores: usize,
}

impl Sampler {
    /// Begin sampling `stats` every `period`.
    pub fn start(stats: PoolStats, period: Duration) -> Sampler {
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let cores = stats.n_workers();
        let period_ns = period.as_nanos() as u64;
        let handle = std::thread::Builder::new()
            .name("canny-sampler".into())
            .spawn(move || {
                // Monotonic time through the shared Stopwatch, not a
                // bare Instant — the clock-purity lint allows direct
                // wall reads only inside util/timer.rs.
                let sw = Stopwatch::start();
                let mut samples = Vec::new();
                while !stop2.load(Ordering::Acquire) {
                    let snap = stats.snapshot();
                    samples.push(UsageSample {
                        t_ns: sw.elapsed_ns(),
                        busy: snap.iter().map(|w| w.busy).collect(),
                    });
                    std::thread::sleep(period);
                }
                samples
            })
            .expect("spawn sampler");
        Sampler { stop, handle: Some(handle), period_ns, cores }
    }

    /// Stop and collect the trace.
    pub fn finish(mut self, label: &str) -> UsageTrace {
        self.stop.store(true, Ordering::Release);
        let samples = self.handle.take().expect("not finished twice").join().expect("sampler");
        UsageTrace { cores: self.cores, period_ns: self.period_ns, samples, label: label.into() }
    }
}

impl Drop for Sampler {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simsched::{simulate, SimPhase, SimSpec};

    fn sim_trace() -> UsageTrace {
        let spec = SimSpec {
            phases: vec![
                SimPhase::serial("s", 400),
                SimPhase::parallel("p", vec![100; 16]),
            ],
        };
        let sim = simulate(&spec, 4);
        UsageTrace::from_sim(&sim, 50, "test")
    }

    #[test]
    fn totals_bounded_and_shaped() {
        let t = sim_trace();
        let totals = t.total_pct();
        assert!(!totals.is_empty());
        assert!(totals.iter().all(|&p| (0.0..=100.0).contains(&p)));
        // Serial prefix: exactly one of four cores busy = 25%.
        assert!((totals[0] - 25.0).abs() < 1e-9);
    }

    #[test]
    fn busy_samples_scale_with_parallelism() {
        let spec = SimSpec { phases: vec![SimPhase::parallel("p", vec![100; 32])] };
        let serial_like = UsageTrace::from_sim(&simulate(&spec, 1), 10, "1");
        let parallel = UsageTrace::from_sim(&simulate(&spec, 4), 10, "4");
        // Same work, 4 cores -> ~4x busy sample *rate*; total busy samples
        // are work-proportional and thus roughly equal; the *multiplier*
        // appears in samples-per-wallclock. Check rate:
        let rate_serial = serial_like.busy_samples() as f64 / serial_like.samples.len() as f64;
        let rate_parallel = parallel.busy_samples() as f64 / parallel.samples.len() as f64;
        assert!(rate_parallel > 3.0 * rate_serial, "{rate_parallel} vs {rate_serial}");
    }

    #[test]
    fn per_core_pct_shapes() {
        let t = sim_trace();
        let per = t.per_core_pct(4);
        assert_eq!(per.len(), 4);
        assert!(per.iter().all(|s| s.iter().all(|&p| (0.0..=100.0).contains(&p))));
        // Core 0 runs the serial phase: more busy than core 3 overall.
        let mean = |s: &[f64]| s.iter().sum::<f64>() / s.len() as f64;
        assert!(mean(&per[0]) >= mean(&per[3]));
    }

    #[test]
    fn csv_roundtrip_shape() {
        let t = sim_trace();
        let path = std::env::temp_dir().join("canny_trace_test/x.csv");
        t.write_csv(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines[0], "t_ns,core0,core1,core2,core3,total_pct");
        assert_eq!(lines.len(), t.samples.len() + 1);
    }

    #[test]
    fn ascii_chart_renders() {
        let t = sim_trace();
        let chart = t.ascii_total(40, 8);
        assert!(chart.contains("100|"));
        assert!(chart.contains('█'));
        let per = t.ascii_per_core(40, 4);
        assert!(per.matches("CPU").count() == 4);
    }

    #[test]
    fn live_sampler_collects() {
        use crate::scheduler::Pool;
        let pool = Pool::new(2).unwrap();
        let sampler = Sampler::start(pool.stats(), Duration::from_micros(200));
        pool.scope(|s| {
            for _ in 0..8 {
                // Sleep keeps the busy flag set for a deterministic span
                // even on a 1-CPU host where spin work may be descheduled.
                s.spawn(|| std::thread::sleep(Duration::from_millis(4)));
            }
        });
        let trace = sampler.finish("live");
        assert_eq!(trace.cores, 2);
        assert!(!trace.samples.is_empty());
        assert!(trace.busy_samples() > 0, "sampler saw no busy workers");
    }
}
