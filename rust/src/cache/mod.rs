//! The **shared artifact cache tier** — process-wide, content-addressed
//! reuse of pipeline artifacts across serving lanes *and* stream
//! executors.
//!
//! The paper's thesis is keeping every core busy; at serving scale the
//! complementary lever is not recomputing at all when content repeats.
//! Hot images — thumbnails, static frames, repeated re-threshold
//! sweeps — show up on many lanes and many streams; the per-lane
//! suppressed-magnitude LRU from the stage-graph PR could only reuse
//! within one lane. This tier promotes it to one process-wide store:
//!
//! ```text
//!              ArtifactKey = FNV-128(image bytes ++ params ++ span)
//!                         │
//! lane 0 ──┐              ▼
//! lane 1 ──┤      ┌─ shard 0 (Mutex + LRU, budget/N bytes) ─┐
//!   …      ├────> ├─ shard 1                                ├─> stats
//! lane N ──┤      │   …                                     │   (per-tier
//! stream ──┘      └─ shard S-1 ─────────────────────────────┘    counters)
//! ```
//!
//! * [`key`] — content-addressed 128-bit digests: identical pixels
//!   produce identical keys regardless of which tier computed them, so
//!   a stream's decoded frame can serve a lane's re-threshold request.
//! * [`shard`] — N-way sharded `Mutex` LRU stores under one global
//!   **byte budget** (entries costed by artifact size); a lookup locks
//!   only its shard, so the hot path never serializes.
//! * [`policy`] — cost-aware admission: an artifact is admitted only
//!   when its calibrated recompute cost per byte clears
//!   [`CacheConfig::admit_min_ns_per_byte`], so cheap tiny artifacts
//!   cannot evict expensive ones.
//! * [`stats`] — hit/miss/eviction/admission accounting per caller
//!   tier, snapshotted into the reports' `cache` JSON section.
//!
//! Rejected offers are additionally remembered in a small bounded
//! **negative set**: a repeat offer of a digest the cache has already
//! turned away (policy reject or too-large) is refused from that set
//! without re-running admission math or taking a shard lock, and
//! callers can probe [`ArtifactCache::was_rejected`] before even
//! materializing an artifact. Refusals replay the original reject
//! counter and add to `negative_hits`, so counter totals match what
//! the slow path would have produced.
//!
//! Configured via `--cache-mb`, `--cache-shards`,
//! `--cache-admit-ns-per-byte` (see [`crate::config::RunConfig`]);
//! `--cache-mb 0` disables the tier entirely (every consult misses
//! without counting, every offer is dropped).

pub mod key;
pub mod policy;
pub mod shard;
pub mod stats;

pub use key::{ArtifactKey, KeyHasher};
pub use policy::AdmissionPolicy;
pub use stats::{CacheSnapshot, CacheTier, TierSnapshot};

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::Ordering;
use std::sync::Mutex;

use crate::cache::shard::{InsertOutcome, ShardStore};
use crate::cache::stats::CacheStats;
use crate::canny::Artifact;
use crate::config::RunConfig;

/// Resolved cache configuration (the `cache-*` config keys).
#[derive(Clone, Debug, PartialEq)]
pub struct CacheConfig {
    /// Global byte budget over all shards; 0 disables the tier.
    pub budget_bytes: u64,
    /// Shard count (lock granularity), clamped to >= 1. Trade-off: a
    /// single artifact can never exceed its shard's slice of the
    /// budget (`budget_bytes / shards`), so more shards means less
    /// lock contention *and* a smaller largest-cacheable artifact
    /// (rejections land in the `too_large` counter). The default 8
    /// shards over 64 MiB caps entries at 8 MiB — a 2-megapixel f32
    /// suppressed map.
    pub shards: usize,
    /// Admission bar in recompute-ns per byte (0 admits everything).
    pub admit_min_ns_per_byte: f64,
}

impl Default for CacheConfig {
    /// 64 MiB over 8 shards, admit-all — enough for dozens of
    /// megapixel-class suppressed maps.
    fn default() -> Self {
        CacheConfig { budget_bytes: 64 << 20, shards: 8, admit_min_ns_per_byte: 0.0 }
    }
}

impl CacheConfig {
    /// Build from the resolved [`RunConfig`] (`cache-mb`,
    /// `cache-shards`, `cache-admit-ns-per-byte`).
    pub fn from_config(cfg: &RunConfig) -> CacheConfig {
        CacheConfig {
            budget_bytes: (cfg.cache_mb as u64) << 20,
            shards: cfg.cache_shards.max(1),
            admit_min_ns_per_byte: cfg.cache_admit_ns_per_byte.max(0.0),
        }
    }

    /// The disabled tier (`--cache-mb 0`).
    pub fn disabled() -> CacheConfig {
        CacheConfig { budget_bytes: 0, ..CacheConfig::default() }
    }

    pub fn enabled(&self) -> bool {
        self.budget_bytes > 0
    }
}

/// How many rejected digests the negative set remembers before the
/// oldest age out (FIFO). Keys are 16 bytes, so the whole set costs a
/// few tens of KiB — noise next to the byte budget it protects.
const NEGATIVE_CAP: usize = 1024;

/// Why an offer was refused — replayed on negative hits so the
/// per-tier reject counters stay exactly what re-running the slow
/// path would have produced.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum RejectReason {
    /// Failed the cost-per-byte admission policy.
    Policy,
    /// Exceeded a shard's slice of the byte budget.
    TooLarge,
}

/// Bounded FIFO memory of rejected digests — the negative cache.
/// Rejection is sticky: once a digest is remembered, repeat offers are
/// refused without re-running admission until the entry ages out
/// (`NEGATIVE_CAP` newer rejects later).
#[derive(Debug, Default)]
struct NegativeSet {
    reasons: BTreeMap<ArtifactKey, RejectReason>,
    order: VecDeque<ArtifactKey>,
}

impl NegativeSet {
    fn remember(&mut self, key: ArtifactKey, reason: RejectReason) {
        if self.reasons.insert(key, reason).is_none() {
            self.order.push_back(key);
            while self.order.len() > NEGATIVE_CAP {
                if let Some(old) = self.order.pop_front() {
                    self.reasons.remove(&old);
                }
            }
        }
    }

    fn reason(&self, key: &ArtifactKey) -> Option<RejectReason> {
        self.reasons.get(key).copied()
    }

    fn len(&self) -> usize {
        self.reasons.len()
    }
}

/// The process-wide artifact cache: share one `Arc<ArtifactCache>`
/// between every serving lane and stream executor that should
/// deduplicate work. All methods take `&self` — the sharded interior
/// carries its own locking.
#[derive(Debug)]
pub struct ArtifactCache {
    cfg: CacheConfig,
    shards: Vec<ShardStore>,
    policy: AdmissionPolicy,
    stats: CacheStats,
    /// Rejected-key memory. Locked only on offers and `was_rejected`
    /// probes, released before any shard lock is taken (never nested).
    negative: Mutex<NegativeSet>,
}

impl ArtifactCache {
    /// Build with the budget split evenly over the shards (remainder
    /// bytes go to the lowest shards, so the slices sum exactly to the
    /// budget and `bytes() <= budget` holds globally).
    pub fn new(cfg: CacheConfig) -> ArtifactCache {
        let n = cfg.shards.max(1);
        let base = cfg.budget_bytes / n as u64;
        let rem = cfg.budget_bytes % n as u64;
        let shards = (0..n)
            .map(|i| ShardStore::new(base + u64::from((i as u64) < rem)))
            .collect();
        ArtifactCache {
            policy: AdmissionPolicy::new(cfg.admit_min_ns_per_byte),
            shards,
            stats: CacheStats::default(),
            negative: Mutex::new(NegativeSet::default()),
            cfg,
        }
    }

    /// A permanently-empty tier (every get misses silently, every offer
    /// is dropped) — the `--cache-mb 0` path.
    pub fn disabled() -> ArtifactCache {
        ArtifactCache::new(CacheConfig::disabled())
    }

    pub fn enabled(&self) -> bool {
        self.cfg.enabled()
    }

    pub fn config(&self) -> &CacheConfig {
        &self.cfg
    }

    /// Look up an artifact, counting a hit or miss for `tier`. Returns
    /// an owned clone — callers consume entry artifacts (plan entry
    /// points take them by value). The pixel copy happens *outside* the
    /// shard lock (entries are `Arc`-shared internally), so concurrent
    /// hits on one shard never serialize on a memcpy. A disabled cache
    /// returns `None` without counting anything.
    pub fn get(&self, key: &ArtifactKey, tier: CacheTier) -> Option<Artifact> {
        if !self.enabled() {
            return None;
        }
        let t = self.stats.tier(tier);
        t.lookups.fetch_add(1, Ordering::Relaxed);
        match self.shards[key.shard(self.shards.len())].get(key) {
            Some(shared) => {
                t.hits.fetch_add(1, Ordering::Relaxed);
                Some((*shared).clone())
            }
            None => {
                t.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Has this digest already been turned away (policy reject or
    /// too-large)? A true answer means a repeat [`ArtifactCache::offer`]
    /// would be refused from the negative set — callers can skip
    /// materializing the artifact at all. Does not count anything.
    pub fn was_rejected(&self, key: &ArtifactKey) -> bool {
        self.enabled()
            && self.negative.lock().expect("negative set lock poisoned").reason(key).is_some()
    }

    /// Look up an artifact *and* name the outcome for a trace span:
    /// `disabled`, `hit`, `miss`, or `negative` (a miss whose digest is
    /// in the rejected-key memory, so recomputing it for a re-offer is
    /// wasted work). Counts exactly what [`ArtifactCache::get`] counts —
    /// the negative probe itself counts nothing — so traced and
    /// untraced runs keep byte-identical cache statistics.
    pub fn consult(&self, key: &ArtifactKey, tier: CacheTier) -> (Option<Artifact>, &'static str) {
        if !self.enabled() {
            return (None, "disabled");
        }
        let negative = self.was_rejected(key);
        let art = self.get(key, tier);
        let outcome = match (&art, negative) {
            (Some(_), _) => "hit",
            (None, true) => "negative",
            (None, false) => "miss",
        };
        (art, outcome)
    }

    /// Offer an artifact for residency. `recompute_ns` is the caller's
    /// estimate of what a future hit saves (calibrated kind cost for
    /// serving lanes, measured front wall for streams); the admission
    /// policy weighs it against the artifact's byte cost. Returns true
    /// when the artifact was stored.
    ///
    /// A digest the cache has already rejected is refused straight from
    /// the negative set (sticky until it ages out): the original reject
    /// counter is replayed — totals match the slow path — plus one
    /// `negative_hits`, and no shard lock is taken.
    pub fn offer(
        &self,
        key: ArtifactKey,
        artifact: Artifact,
        recompute_ns: u64,
        tier: CacheTier,
    ) -> bool {
        if !self.enabled() {
            return false;
        }
        let bytes = artifact.byte_size() as u64;
        let t = self.stats.tier(tier);
        let remembered = self.negative.lock().expect("negative set lock poisoned").reason(&key);
        if let Some(reason) = remembered {
            match reason {
                RejectReason::Policy => t.admission_rejects.fetch_add(1, Ordering::Relaxed),
                RejectReason::TooLarge => t.too_large.fetch_add(1, Ordering::Relaxed),
            };
            self.stats.negative_hits.fetch_add(1, Ordering::Relaxed);
            return false;
        }
        if !self.policy.admits(recompute_ns, bytes) {
            t.admission_rejects.fetch_add(1, Ordering::Relaxed);
            self.negative
                .lock()
                .expect("negative set lock poisoned")
                .remember(key, RejectReason::Policy);
            return false;
        }
        match self.shards[key.shard(self.shards.len())].insert(key, artifact, bytes) {
            InsertOutcome::Stored { evicted, .. } => {
                t.inserts.fetch_add(1, Ordering::Relaxed);
                self.stats.evictions.fetch_add(evicted, Ordering::Relaxed);
                true
            }
            // Larger than a shard's slice of the budget
            // (`budget / shards`): structurally uncacheable under this
            // configuration, counted apart from the policy rejects so
            // operators can tell "raise --cache-mb or lower
            // --cache-shards" from "raise the admission bar".
            InsertOutcome::TooLarge => {
                t.too_large.fetch_add(1, Ordering::Relaxed);
                self.negative
                    .lock()
                    .expect("negative set lock poisoned")
                    .remember(key, RejectReason::TooLarge);
                false
            }
        }
    }

    /// Authoritative byte occupancy (sums the shards).
    pub fn bytes(&self) -> u64 {
        self.shards.iter().map(ShardStore::bytes).sum()
    }

    /// Total live entries.
    pub fn len(&self) -> usize {
        self.shards.iter().map(ShardStore::len).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Counter + occupancy snapshot for the reports' `cache` section.
    /// `high_water_bytes` sums the per-shard peaks (tracked under each
    /// shard's lock): an upper bound on peak global occupancy that can
    /// never exceed the budget.
    pub fn snapshot(&self) -> CacheSnapshot {
        CacheSnapshot {
            enabled: self.enabled(),
            budget_bytes: self.cfg.budget_bytes,
            shards: self.shards.len(),
            admit_min_ns_per_byte: self.cfg.admit_min_ns_per_byte,
            bytes: self.bytes(),
            entries: self.len() as u64,
            high_water_bytes: self.shards.iter().map(ShardStore::high_water_bytes).sum(),
            evictions: self.stats.evictions.load(Ordering::Relaxed),
            negative_hits: self.stats.negative_hits.load(Ordering::Relaxed),
            negative_entries: self.negative.lock().expect("negative set lock poisoned").len()
                as u64,
            tiers: self.stats.snapshot_tiers(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::synth::{generate, Scene};
    use crate::image::ImageF32;

    fn suppressed(px_w: usize) -> Artifact {
        Artifact::Suppressed(ImageF32::zeros(px_w, 1))
    }

    fn key_n(n: u64) -> ArtifactKey {
        ArtifactKey { hi: n.wrapping_mul(0x9e37_79b9_7f4a_7c15), lo: n }
    }

    #[test]
    fn hit_miss_roundtrip_and_tier_counters() {
        let c = ArtifactCache::new(CacheConfig { budget_bytes: 1 << 20, ..Default::default() });
        let img = generate(Scene::Shapes { seed: 3 }, 32, 24);
        let key = ArtifactKey::suppressed(&img);
        assert!(c.get(&key, CacheTier::Serve).is_none());
        assert!(c.offer(key, suppressed(32 * 24), 1_000_000, CacheTier::Stream));
        match c.get(&key, CacheTier::Serve) {
            Some(Artifact::Suppressed(nm)) => assert_eq!(nm.len(), 32 * 24),
            other => panic!("unexpected {other:?}"),
        }
        let snap = c.snapshot();
        assert_eq!(snap.lookups(), 2);
        assert_eq!(snap.hits(), 1);
        assert_eq!(snap.misses(), 1);
        assert_eq!(snap.hits() + snap.misses(), snap.lookups());
        let serve = snap.tiers.iter().find(|(n, _)| *n == "serve").unwrap().1;
        let stream = snap.tiers.iter().find(|(n, _)| *n == "stream").unwrap().1;
        assert_eq!((serve.lookups, serve.hits, serve.misses), (2, 1, 1));
        assert_eq!((stream.inserts, stream.lookups), (1, 0));
        assert_eq!(snap.entries, 1);
        assert_eq!(snap.bytes, (32 * 24 * 4) as u64);
    }

    #[test]
    fn consult_names_outcomes_and_counts_like_get() {
        let c = ArtifactCache::new(CacheConfig { budget_bytes: 1 << 20, ..Default::default() });
        let (art, outcome) = c.consult(&key_n(1), CacheTier::Serve);
        assert!(art.is_none());
        assert_eq!(outcome, "miss");
        assert!(c.offer(key_n(1), suppressed(64), 1_000_000, CacheTier::Serve));
        let (art, outcome) = c.consult(&key_n(1), CacheTier::Serve);
        assert!(art.is_some());
        assert_eq!(outcome, "hit");
        // A digest refused by the admission policy lands in the
        // negative set; consulting it names the wasted-recompute case.
        let picky = ArtifactCache::new(CacheConfig {
            budget_bytes: 1 << 20,
            shards: 2,
            admit_min_ns_per_byte: 1e12,
        });
        assert!(!picky.offer(key_n(2), suppressed(64), 1, CacheTier::Serve));
        let (art, outcome) = picky.consult(&key_n(2), CacheTier::Serve);
        assert!(art.is_none());
        assert_eq!(outcome, "negative");
        // Counter parity with get: the negative probe adds nothing.
        let snap = picky.snapshot();
        assert_eq!(snap.lookups(), 1);
        assert_eq!(snap.misses(), 1);
        // Disabled tier: no outcome counting at all.
        let off = ArtifactCache::disabled();
        let (art, outcome) = off.consult(&key_n(3), CacheTier::Serve);
        assert!(art.is_none());
        assert_eq!(outcome, "disabled");
        assert_eq!(off.snapshot().lookups(), 0);
    }

    #[test]
    fn byte_budget_enforced_across_shards_with_evictions() {
        // 4 shards x 1 KiB slices; 40 KiB of offers must evict.
        let c = ArtifactCache::new(CacheConfig {
            budget_bytes: 4096,
            shards: 4,
            admit_min_ns_per_byte: 0.0,
        });
        for n in 0..40 {
            c.offer(key_n(n), suppressed(256), 1_000_000, CacheTier::Serve);
        }
        let snap = c.snapshot();
        assert!(snap.bytes <= 4096, "bytes {} over budget", snap.bytes);
        assert_eq!(snap.bytes, c.bytes());
        assert!(snap.evictions > 0);
        assert!(snap.high_water_bytes <= 4096);
        assert!(snap.entries < 40);
    }

    #[test]
    fn admission_policy_rejects_cheap_bulk() {
        let c = ArtifactCache::new(CacheConfig {
            budget_bytes: 1 << 20,
            shards: 2,
            admit_min_ns_per_byte: 10.0,
        });
        // 1024 bytes at 100 ns: 0.1 ns/byte, far under the 10 ns bar.
        assert!(!c.offer(key_n(1), suppressed(256), 100, CacheTier::Serve));
        // Same bytes at 1 ms recompute: ~1000 ns/byte, admitted.
        assert!(c.offer(key_n(2), suppressed(256), 1_000_000, CacheTier::Serve));
        let snap = c.snapshot();
        assert_eq!(snap.admission_rejects(), 1);
        assert_eq!(snap.inserts(), 1);
        assert_eq!(snap.entries, 1);
    }

    #[test]
    fn disabled_cache_is_inert() {
        let c = ArtifactCache::disabled();
        assert!(!c.enabled());
        let key = key_n(7);
        assert!(!c.offer(key, suppressed(16), u64::MAX, CacheTier::Serve));
        assert!(c.get(&key, CacheTier::Serve).is_none());
        let snap = c.snapshot();
        assert!(!snap.enabled);
        assert_eq!((snap.lookups(), snap.inserts(), snap.bytes), (0, 0, 0));
        // Schema stays complete: both tiers present even when inert.
        assert_eq!(snap.tiers.len(), 2);
    }

    #[test]
    fn oversize_artifact_counts_as_too_large() {
        // 8 KiB budget over 4 shards: the per-shard slice is 2 KiB, so
        // a 4 KiB artifact can never fit even though the global budget
        // could hold it — counted apart from policy rejects.
        let c = ArtifactCache::new(CacheConfig {
            budget_bytes: 8192,
            shards: 4,
            admit_min_ns_per_byte: 0.0,
        });
        assert!(!c.offer(key_n(1), suppressed(1024), u64::MAX, CacheTier::Stream));
        let snap = c.snapshot();
        assert_eq!(snap.too_large(), 1);
        assert_eq!(snap.admission_rejects(), 0);
        assert_eq!(snap.entries, 0);
    }

    #[test]
    fn repeat_rejected_offers_hit_the_negative_set() {
        let c = ArtifactCache::new(CacheConfig {
            budget_bytes: 1 << 20,
            shards: 2,
            admit_min_ns_per_byte: 10.0,
        });
        assert!(!c.was_rejected(&key_n(1)));
        // First cheap offer runs the policy and is remembered.
        assert!(!c.offer(key_n(1), suppressed(256), 100, CacheTier::Serve));
        assert!(c.was_rejected(&key_n(1)));
        let snap = c.snapshot();
        assert_eq!((snap.admission_rejects(), snap.negative_hits, snap.negative_entries), (1, 0, 1));
        // Repeat offer — even with a recompute cost that would now
        // clear the bar — is refused from the negative set (sticky),
        // replaying the policy-reject counter plus one negative hit.
        assert!(!c.offer(key_n(1), suppressed(256), u64::MAX, CacheTier::Serve));
        let snap = c.snapshot();
        assert_eq!((snap.admission_rejects(), snap.negative_hits, snap.negative_entries), (2, 1, 1));
        assert_eq!((snap.inserts(), snap.entries), (0, 0));
        // Admitted digests never enter the set.
        assert!(c.offer(key_n(2), suppressed(256), 1_000_000, CacheTier::Serve));
        assert!(!c.was_rejected(&key_n(2)));
    }

    #[test]
    fn too_large_rejects_replay_their_own_counter() {
        // 2 KiB shard slices: a 4 KiB artifact is structurally
        // uncacheable; the repeat refusal must count as too_large
        // again, not as a policy reject.
        let c = ArtifactCache::new(CacheConfig {
            budget_bytes: 8192,
            shards: 4,
            admit_min_ns_per_byte: 0.0,
        });
        assert!(!c.offer(key_n(9), suppressed(1024), u64::MAX, CacheTier::Stream));
        assert!(!c.offer(key_n(9), suppressed(1024), u64::MAX, CacheTier::Stream));
        let snap = c.snapshot();
        assert_eq!(snap.too_large(), 2);
        assert_eq!(snap.admission_rejects(), 0);
        assert_eq!((snap.negative_hits, snap.negative_entries), (1, 1));
    }

    #[test]
    fn negative_set_is_bounded_fifo() {
        let c = ArtifactCache::new(CacheConfig {
            budget_bytes: 1 << 20,
            shards: 2,
            admit_min_ns_per_byte: 10.0,
        });
        let extra = 40;
        for n in 0..(NEGATIVE_CAP + extra) as u64 {
            c.offer(key_n(n), suppressed(256), 100, CacheTier::Serve);
        }
        let snap = c.snapshot();
        assert_eq!(snap.negative_entries, NEGATIVE_CAP as u64);
        // Oldest rejects aged out, newest are still remembered.
        assert!(!c.was_rejected(&key_n(0)));
        assert!(!c.was_rejected(&key_n(extra as u64 - 1)));
        assert!(c.was_rejected(&key_n(extra as u64)));
        assert!(c.was_rejected(&key_n((NEGATIVE_CAP + extra - 1) as u64)));
    }

    #[test]
    fn disabled_cache_has_no_negative_memory() {
        let c = ArtifactCache::disabled();
        assert!(!c.offer(key_n(3), suppressed(16), 0, CacheTier::Serve));
        assert!(!c.was_rejected(&key_n(3)));
        let snap = c.snapshot();
        assert_eq!((snap.negative_hits, snap.negative_entries), (0, 0));
    }

    #[test]
    fn budget_split_sums_exactly_with_remainder_low() {
        let c = ArtifactCache::new(CacheConfig {
            budget_bytes: 10,
            shards: 4,
            admit_min_ns_per_byte: 0.0,
        });
        let slices: Vec<u64> = c.shards.iter().map(ShardStore::budget_bytes).collect();
        assert_eq!(slices, vec![3, 3, 2, 2]);
        assert_eq!(slices.iter().sum::<u64>(), 10);
    }
}
