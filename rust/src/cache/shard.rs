//! The sharded stores behind [`crate::cache::ArtifactCache`]: N
//! independent `Mutex`-guarded LRU maps, each owning a slice of the
//! global byte budget.
//!
//! Sharding is the concurrency design (A Survey of Multithreading Image
//! Analysis: shared state must not serialize the hot path): a lookup
//! locks only the one shard its key hashes to, so lanes and stream
//! executors hitting different shards never contend. Entries are costed
//! by **artifact bytes**, not entry count — a 4 MB suppressed map and a
//! 16 kB thumbnail are not the same occupancy — and each shard evicts
//! its own least-recently-used entries whenever its byte slice
//! overflows, so the global invariant `sum(shard bytes) <= budget`
//! holds without any cross-shard coordination.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use crate::cache::key::ArtifactKey;
use crate::canny::Artifact;

/// What [`ShardStore::insert`] did.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum InsertOutcome {
    /// Stored (possibly replacing the same key), evicting `evicted`
    /// LRU entries worth `removed_bytes` (replacement bytes included).
    Stored { evicted: u64, added_bytes: u64, removed_bytes: u64 },
    /// The artifact alone exceeds this shard's byte slice — never
    /// admissible, nothing changed.
    TooLarge,
}

#[derive(Debug)]
struct Entry {
    /// `Arc`-wrapped so a lookup hands back a reference-count bump, not
    /// a multi-megabyte deep copy made while holding the shard lock.
    artifact: Arc<Artifact>,
    bytes: u64,
    /// Recency tick (monotonic per shard); the `recency` index maps it
    /// back to the key, so LRU order is a `BTreeMap` range scan.
    tick: u64,
}

#[derive(Debug, Default)]
struct ShardState {
    entries: BTreeMap<ArtifactKey, Entry>,
    /// tick -> key, oldest first. In lockstep with `entries`.
    recency: BTreeMap<u64, ArtifactKey>,
    tick: u64,
    bytes: u64,
    /// Peak post-insert occupancy of this shard. Tracked under the
    /// lock — a detached global counter would race across the
    /// insert/account boundary and could wrap.
    high_water: u64,
}

/// One shard: a byte-budgeted LRU map behind its own lock.
#[derive(Debug)]
pub struct ShardStore {
    budget_bytes: u64,
    state: Mutex<ShardState>,
}

impl ShardStore {
    pub fn new(budget_bytes: u64) -> ShardStore {
        ShardStore { budget_bytes, state: Mutex::new(ShardState::default()) }
    }

    /// Look up a key, refreshing its recency. Returns the shared
    /// handle; only the reference count is touched under the lock, so
    /// concurrent same-shard lookups never serialize on a pixel copy.
    pub fn get(&self, key: &ArtifactKey) -> Option<Arc<Artifact>> {
        let mut s = self.state.lock().expect("cache shard lock");
        let old_tick = s.entries.get(key)?.tick;
        s.tick += 1;
        let tick = s.tick;
        s.recency.remove(&old_tick);
        s.recency.insert(tick, *key);
        let e = s.entries.get_mut(key).expect("entry present");
        e.tick = tick;
        Some(Arc::clone(&e.artifact))
    }

    /// Insert (or refresh) an entry of `bytes` cost, then evict LRU
    /// entries until this shard is back under its byte slice. The entry
    /// just inserted is the most recent, so it is never the eviction
    /// victim.
    pub fn insert(&self, key: ArtifactKey, artifact: Artifact, bytes: u64) -> InsertOutcome {
        if bytes > self.budget_bytes {
            return InsertOutcome::TooLarge;
        }
        let mut s = self.state.lock().expect("cache shard lock");
        let mut evicted = 0u64;
        let mut removed_bytes = 0u64;
        if let Some(old) = s.entries.remove(&key) {
            s.recency.remove(&old.tick);
            s.bytes -= old.bytes;
            removed_bytes += old.bytes;
        }
        s.tick += 1;
        let tick = s.tick;
        s.bytes += bytes;
        s.entries.insert(key, Entry { artifact: Arc::new(artifact), bytes, tick });
        s.recency.insert(tick, key);
        while s.bytes > self.budget_bytes {
            let (&t, &k) = s.recency.iter().next().expect("over budget implies entries");
            s.recency.remove(&t);
            let e = s.entries.remove(&k).expect("recency index in lockstep");
            s.bytes -= e.bytes;
            removed_bytes += e.bytes;
            evicted += 1;
        }
        s.high_water = s.high_water.max(s.bytes);
        InsertOutcome::Stored { evicted, added_bytes: bytes, removed_bytes }
    }

    /// This shard's slice of the global byte budget.
    pub fn budget_bytes(&self) -> u64 {
        self.budget_bytes
    }

    /// Current byte occupancy.
    pub fn bytes(&self) -> u64 {
        self.state.lock().expect("cache shard lock").bytes
    }

    /// Peak post-insert occupancy this shard has seen (never exceeds
    /// its budget slice).
    pub fn high_water_bytes(&self) -> u64 {
        self.state.lock().expect("cache shard lock").high_water
    }

    /// Current entry count.
    pub fn len(&self) -> usize {
        self.state.lock().expect("cache shard lock").entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::ImageF32;

    fn key(n: u64) -> ArtifactKey {
        ArtifactKey { hi: n, lo: !n }
    }

    fn art(px: usize) -> Artifact {
        Artifact::Suppressed(ImageF32::zeros(px, 1))
    }

    #[test]
    fn get_refreshes_recency_and_evicts_lru() {
        // Budget fits two 32-byte entries (8 px * 4 B).
        let s = ShardStore::new(64);
        assert_eq!(
            s.insert(key(1), art(8), 32),
            InsertOutcome::Stored { evicted: 0, added_bytes: 32, removed_bytes: 0 }
        );
        s.insert(key(2), art(8), 32);
        assert!(s.get(&key(1)).is_some(), "refresh 1");
        // 3 overflows the budget: 2 is now the LRU and must go.
        match s.insert(key(3), art(8), 32) {
            InsertOutcome::Stored { evicted, removed_bytes, .. } => {
                assert_eq!(evicted, 1);
                assert_eq!(removed_bytes, 32);
            }
            other => panic!("unexpected {other:?}"),
        }
        assert!(s.get(&key(2)).is_none());
        assert!(s.get(&key(1)).is_some());
        assert!(s.get(&key(3)).is_some());
        assert_eq!(s.len(), 2);
        assert!(s.bytes() <= 64);
    }

    #[test]
    fn replacement_updates_bytes_not_count() {
        let s = ShardStore::new(1000);
        s.insert(key(1), art(8), 32);
        match s.insert(key(1), art(16), 64) {
            InsertOutcome::Stored { evicted, added_bytes, removed_bytes } => {
                assert_eq!(evicted, 0);
                assert_eq!(added_bytes, 64);
                assert_eq!(removed_bytes, 32);
            }
            other => panic!("unexpected {other:?}"),
        }
        assert_eq!(s.len(), 1);
        assert_eq!(s.bytes(), 64);
    }

    #[test]
    fn oversize_entry_rejected_untouched() {
        let s = ShardStore::new(16);
        assert_eq!(s.insert(key(1), art(8), 32), InsertOutcome::TooLarge);
        assert!(s.is_empty());
        assert_eq!(s.bytes(), 0);
    }

    #[test]
    fn overfill_stays_under_budget_with_evictions() {
        let s = ShardStore::new(100);
        let mut evictions = 0;
        for n in 0..50 {
            if let InsertOutcome::Stored { evicted, .. } = s.insert(key(n), art(8), 32) {
                evictions += evicted;
            }
        }
        assert!(s.bytes() <= 100, "bytes {} over budget", s.bytes());
        assert!(evictions > 0);
        assert_eq!(s.len() as u64 * 32, s.bytes());
    }
}
