//! Cache accounting: lock-free counters updated on the hot path,
//! snapshotted into the deterministic JSON `cache` section the serve
//! and stream reports carry.
//!
//! Counters are per **caller tier** (`serve` lanes vs the `stream`
//! executor) so a shared cache's report shows who is producing and who
//! is consuming — the cross-tier dedup story is visible, not inferred.
//! All counters are `Relaxed` atomics: totals are exact once the run's
//! threads have joined (which is when reports are built), and the
//! virtual driver is single-threaded, so its reports are byte-identical
//! across runs.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::util::json::Json;

/// Who is calling into the cache (the per-tier counter index).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CacheTier {
    /// A serving lane (front-only warms, re-threshold consults).
    Serve,
    /// The stream executor (frames consult, computed fronts offer).
    Stream,
}

impl CacheTier {
    pub const ALL: [CacheTier; 2] = [CacheTier::Serve, CacheTier::Stream];

    /// Report key.
    pub fn name(&self) -> &'static str {
        match self {
            CacheTier::Serve => "serve",
            CacheTier::Stream => "stream",
        }
    }

    fn index(&self) -> usize {
        match self {
            CacheTier::Serve => 0,
            CacheTier::Stream => 1,
        }
    }
}

/// One tier's counters.
#[derive(Debug, Default)]
pub struct TierCounters {
    pub lookups: AtomicU64,
    pub hits: AtomicU64,
    pub misses: AtomicU64,
    pub inserts: AtomicU64,
    pub admission_rejects: AtomicU64,
    /// Offers whose artifact exceeds a shard's budget slice
    /// (`budget / shards`) — structurally uncacheable under the current
    /// configuration, as opposed to failing the cost-per-byte policy.
    pub too_large: AtomicU64,
}

impl TierCounters {
    fn snapshot(&self) -> TierSnapshot {
        TierSnapshot {
            lookups: self.lookups.load(Ordering::Relaxed),
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            inserts: self.inserts.load(Ordering::Relaxed),
            admission_rejects: self.admission_rejects.load(Ordering::Relaxed),
            too_large: self.too_large.load(Ordering::Relaxed),
        }
    }
}

/// Live counters owned by [`crate::cache::ArtifactCache`]. Byte
/// occupancy and high-water marks live in the shards (updated under
/// their locks — a detached global counter would race across the
/// insert/account boundary); only cross-shard event counts live here.
#[derive(Debug, Default)]
pub struct CacheStats {
    tiers: [TierCounters; 2],
    pub evictions: AtomicU64,
    /// Consults (gets or offers) answered from the negative set — a
    /// previously-rejected digest refused again without touching its
    /// shard. Cross-tier like `evictions`: the set is global.
    pub negative_hits: AtomicU64,
}

impl CacheStats {
    pub fn tier(&self, tier: CacheTier) -> &TierCounters {
        &self.tiers[tier.index()]
    }

    pub fn snapshot_tiers(&self) -> Vec<(&'static str, TierSnapshot)> {
        CacheTier::ALL.iter().map(|t| (t.name(), self.tier(*t).snapshot())).collect()
    }
}

/// One tier's totals at snapshot time.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TierSnapshot {
    pub lookups: u64,
    pub hits: u64,
    pub misses: u64,
    pub inserts: u64,
    pub admission_rejects: u64,
    pub too_large: u64,
}

impl TierSnapshot {
    /// Fraction of this tier's lookups served from the cache (0 when
    /// the tier never looked anything up) — the per-tier effectiveness
    /// number ops dashboards plot from the telemetry stream.
    pub fn hit_rate(&self) -> f64 {
        if self.lookups == 0 {
            return 0.0;
        }
        self.hits as f64 / self.lookups as f64
    }

    fn to_json(self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("lookups".into(), Json::Num(self.lookups as f64));
        m.insert("hits".into(), Json::Num(self.hits as f64));
        m.insert("hit_rate".into(), Json::Num(self.hit_rate()));
        m.insert("misses".into(), Json::Num(self.misses as f64));
        m.insert("inserts".into(), Json::Num(self.inserts as f64));
        m.insert("admission_rejects".into(), Json::Num(self.admission_rejects as f64));
        m.insert("too_large".into(), Json::Num(self.too_large as f64));
        Json::Obj(m)
    }
}

/// Everything the report's `cache` section carries: configuration echo
/// plus counter totals. [`Default`] is the disabled cache (all zeros).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct CacheSnapshot {
    pub enabled: bool,
    pub budget_bytes: u64,
    pub shards: usize,
    pub admit_min_ns_per_byte: f64,
    pub bytes: u64,
    pub entries: u64,
    /// Sum of per-shard post-insert peaks — an upper bound on the peak
    /// global occupancy, and never above `budget_bytes`.
    pub high_water_bytes: u64,
    pub evictions: u64,
    /// Consults refused by the negative (rejected-key) set without
    /// re-running admission or touching a shard lock.
    pub negative_hits: u64,
    /// Rejected digests currently remembered by the negative set.
    pub negative_entries: u64,
    /// Per-tier counters, every tier always present (stable schema).
    pub tiers: Vec<(&'static str, TierSnapshot)>,
}

impl CacheSnapshot {
    /// Aggregate over tiers.
    pub fn lookups(&self) -> u64 {
        self.tiers.iter().map(|(_, t)| t.lookups).sum()
    }

    pub fn hits(&self) -> u64 {
        self.tiers.iter().map(|(_, t)| t.hits).sum()
    }

    pub fn misses(&self) -> u64 {
        self.tiers.iter().map(|(_, t)| t.misses).sum()
    }

    pub fn inserts(&self) -> u64 {
        self.tiers.iter().map(|(_, t)| t.inserts).sum()
    }

    pub fn admission_rejects(&self) -> u64 {
        self.tiers.iter().map(|(_, t)| t.admission_rejects).sum()
    }

    pub fn too_large(&self) -> u64 {
        self.tiers.iter().map(|(_, t)| t.too_large).sum()
    }

    /// The `cache` report section (schema documented in
    /// [`crate::service`] and [`crate::stream`]).
    pub fn to_json(&self) -> Json {
        let num = |v: u64| Json::Num(v as f64);
        let mut m = BTreeMap::new();
        m.insert("enabled".into(), Json::Bool(self.enabled));
        m.insert("budget_bytes".into(), num(self.budget_bytes));
        m.insert("shards".into(), Json::Num(self.shards as f64));
        m.insert("admit_min_ns_per_byte".into(), Json::Num(self.admit_min_ns_per_byte));
        m.insert("bytes".into(), num(self.bytes));
        m.insert("entries".into(), num(self.entries));
        m.insert("high_water_bytes".into(), num(self.high_water_bytes));
        m.insert("evictions".into(), num(self.evictions));
        m.insert("negative_hits".into(), num(self.negative_hits));
        m.insert("negative_entries".into(), num(self.negative_entries));
        m.insert("lookups".into(), num(self.lookups()));
        m.insert("hits".into(), num(self.hits()));
        m.insert("misses".into(), num(self.misses()));
        m.insert("inserts".into(), num(self.inserts()));
        m.insert("admission_rejects".into(), num(self.admission_rejects()));
        m.insert("too_large".into(), num(self.too_large()));
        m.insert(
            "tiers".into(),
            Json::Obj(
                self.tiers.iter().map(|(name, t)| (name.to_string(), t.to_json())).collect(),
            ),
        );
        Json::Obj(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tier_names_distinct() {
        assert_ne!(CacheTier::Serve.name(), CacheTier::Stream.name());
        assert_ne!(CacheTier::Serve.index(), CacheTier::Stream.index());
    }

    #[test]
    fn tier_counters_snapshot_roundtrip() {
        let s = CacheStats::default();
        s.tier(CacheTier::Serve).lookups.fetch_add(3, Ordering::Relaxed);
        s.tier(CacheTier::Serve).hits.fetch_add(2, Ordering::Relaxed);
        s.tier(CacheTier::Stream).too_large.fetch_add(1, Ordering::Relaxed);
        s.evictions.fetch_add(4, Ordering::Relaxed);
        let tiers = s.snapshot_tiers();
        assert_eq!(tiers.len(), 2);
        assert_eq!(tiers[0].0, "serve");
        assert_eq!((tiers[0].1.lookups, tiers[0].1.hits), (3, 2));
        assert_eq!(tiers[1].1.too_large, 1);
        assert_eq!(s.evictions.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn snapshot_json_has_stable_schema() {
        let snap = CacheSnapshot {
            enabled: true,
            budget_bytes: 1024,
            shards: 4,
            admit_min_ns_per_byte: 0.5,
            bytes: 96,
            entries: 3,
            high_water_bytes: 128,
            evictions: 2,
            negative_hits: 4,
            negative_entries: 1,
            tiers: vec![
                (
                    "serve",
                    TierSnapshot {
                        lookups: 5,
                        hits: 3,
                        misses: 2,
                        inserts: 2,
                        admission_rejects: 1,
                        too_large: 0,
                    },
                ),
                ("stream", TierSnapshot::default()),
            ],
        };
        assert_eq!(snap.lookups(), 5);
        assert_eq!(snap.hits() + snap.misses(), snap.lookups());
        assert!((snap.tiers[0].1.hit_rate() - 0.6).abs() < 1e-12);
        assert_eq!(TierSnapshot::default().hit_rate(), 0.0);
        let j = snap.to_json();
        assert_eq!(j.get("enabled"), Some(&Json::Bool(true)));
        assert_eq!(j.get("hits").unwrap().as_usize(), Some(3));
        assert_eq!(j.get("negative_hits").unwrap().as_usize(), Some(4));
        assert_eq!(j.get("negative_entries").unwrap().as_usize(), Some(1));
        assert_eq!(
            j.get("tiers").unwrap().get("serve").unwrap().get("lookups").unwrap().as_usize(),
            Some(5)
        );
        assert!(
            (j.get("tiers").unwrap().get("serve").unwrap().get("hit_rate").unwrap().as_f64()
                .unwrap()
                - 0.6)
                .abs()
                < 1e-12
        );
        assert!(j.get("tiers").unwrap().get("stream").is_some());
        // Round-trips through the parser (report embedding).
        assert_eq!(Json::parse(&j.dump()).unwrap(), j);
        // The disabled default is all-zero and schema-complete once the
        // tiers are filled in (ArtifactCache::disabled_snapshot does).
        assert!(!CacheSnapshot::default().enabled);
    }
}
