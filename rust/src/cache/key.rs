//! Content-addressed cache keys: a 128-bit FNV-style digest over the
//! image bytes, a parameter fingerprint, and the pipeline span the
//! artifact covers.
//!
//! Content addressing (rather than `(scene, shape)` identity, which the
//! old per-lane `SuppressedCache` used) is what lets *different*
//! producers deduplicate: a serving lane warming the cache with a
//! front-only request and a stream executor offering a decoded frame
//! produce the same key whenever the pixels are the same — so a
//! re-threshold request can hit an artifact a video stream computed.
//!
//! The digest is two independent 64-bit FNV-style streams over the
//! same input (different offset bases), concatenated to 128 bits.
//! Pixel data is folded a **word at a time** (one XOR + multiply per
//! u32 per stream, not per byte) so the digest runs at multiple GB/s —
//! it sits on the hot path of every stream frame and every
//! partial-kind request, and the virtual clock's modeled lookup cost
//! ([`crate::service::server::CACHE_HASH_PIXELS_PER_NS`]) assumes this
//! rate. Byte-slice input still folds per byte; the two forms are
//! deliberately not byte-compatible with standard FNV-1a.
//! Non-cryptographic by design: keys never cross a trust boundary, and
//! 128 bits keeps accidental collisions out of reach for any realistic
//! working set. No external dependencies.
//!
//! The parameter fingerprint folds in only the parameters the span's
//! *output* depends on. Every engine produces bit-identical artifacts
//! (the determinism invariant), and the front (Pad→NMS) ignores the
//! hysteresis thresholds entirely — so a `Suppressed` artifact computed
//! for one `lo`/`hi` pair is correctly shared across a whole
//! re-threshold sweep. Spans covering Threshold or Hysteresis do fold
//! `lo`/`hi` in, since those stages' outputs depend on them.

use crate::canny::{CannyParams, StageKind};
use crate::image::{EdgeMap, ImageF32};

const FNV_OFFSET_A: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_OFFSET_B: u64 = 0xcbf2_9ce4_8422_2325 ^ 0x9e37_79b9_7f4a_7c15;
const FNV_PRIME: u64 = 0x1_0000_0000_01b3;

/// A 128-bit content digest — the cache's lookup key.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct ArtifactKey {
    pub hi: u64,
    pub lo: u64,
}

impl ArtifactKey {
    /// Which of `n` shards this key lives in.
    pub fn shard(&self, n: usize) -> usize {
        debug_assert!(n > 0);
        // hi and lo are independent streams; fold both so shard choice
        // is not blind to half the digest.
        ((self.hi ^ self.lo.rotate_left(32)) % n as u64) as usize
    }

    /// Key for the suppressed-magnitude artifact of `img` — the
    /// Pad→NMS span. Threshold-free by construction: every `lo`/`hi`
    /// re-threshold of the same content shares this key.
    pub fn suppressed(img: &ImageF32) -> ArtifactKey {
        ArtifactKey::for_span(img, None, StageKind::Pad, StageKind::Nms)
    }

    /// Digest of a finished edge map — dimensions plus the 0/1 mask
    /// bytes. Not a cache key (edge maps are cheap to rebuild from a
    /// suppressed artifact); the cluster tier uses it to assert that a
    /// routed worker produced bit-identical output to the
    /// single-process path.
    pub fn edges(edges: &EdgeMap) -> ArtifactKey {
        let mut h = KeyHasher::new();
        h.write_u64(edges.width() as u64);
        h.write_u64(edges.height() as u64);
        h.write(edges.data());
        h.finish()
    }

    /// General form: digest `img`'s bytes, the `first..=last` span tag,
    /// and the parameters `last` depends on (`lo`/`hi` once the span
    /// reaches Threshold; earlier stages are parameter-free — tiling
    /// and grain choices never change artifact bytes).
    pub fn for_span(
        img: &ImageF32,
        params: Option<&CannyParams>,
        first: StageKind,
        last: StageKind,
    ) -> ArtifactKey {
        let mut h = KeyHasher::new();
        h.write_u64(first as u64);
        h.write_u64(last as u64);
        h.write_u64(img.width() as u64);
        h.write_u64(img.height() as u64);
        if last >= StageKind::Threshold {
            let p = params.copied().unwrap_or_default();
            h.write_u64(p.lo.to_bits() as u64);
            h.write_u64(p.hi.to_bits() as u64);
        }
        for &v in img.data() {
            h.write_u32(v.to_bits());
        }
        h.finish()
    }
}

/// Incremental digest builder (two FNV-1a streams).
#[derive(Clone, Debug)]
pub struct KeyHasher {
    a: u64,
    b: u64,
}

impl Default for KeyHasher {
    fn default() -> Self {
        KeyHasher::new()
    }
}

impl KeyHasher {
    pub fn new() -> KeyHasher {
        KeyHasher { a: FNV_OFFSET_A, b: FNV_OFFSET_B }
    }

    #[inline]
    pub fn write_byte(&mut self, v: u8) {
        self.a = (self.a ^ v as u64).wrapping_mul(FNV_PRIME);
        self.b = (self.b ^ v as u64).wrapping_mul(FNV_PRIME);
    }

    pub fn write(&mut self, bytes: &[u8]) {
        for &v in bytes {
            self.write_byte(v);
        }
    }

    /// Fold a whole word per stream — the pixel-data fast path (4 bytes
    /// per multiply instead of 1).
    #[inline]
    pub fn write_u32(&mut self, v: u32) {
        self.a = (self.a ^ v as u64).wrapping_mul(FNV_PRIME);
        self.b = (self.b ^ v as u64).wrapping_mul(FNV_PRIME);
    }

    #[inline]
    pub fn write_u64(&mut self, v: u64) {
        self.a = (self.a ^ v).wrapping_mul(FNV_PRIME);
        self.b = (self.b ^ v).wrapping_mul(FNV_PRIME);
    }

    pub fn finish(self) -> ArtifactKey {
        ArtifactKey { hi: self.a, lo: self.b }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::synth::{generate, Scene};

    #[test]
    fn identical_content_identical_key() {
        let a = generate(Scene::Shapes { seed: 5 }, 48, 32);
        let b = generate(Scene::Shapes { seed: 5 }, 48, 32);
        assert_eq!(ArtifactKey::suppressed(&a), ArtifactKey::suppressed(&b));
    }

    #[test]
    fn different_content_different_key() {
        let a = generate(Scene::Shapes { seed: 5 }, 48, 32);
        let b = generate(Scene::Shapes { seed: 6 }, 48, 32);
        assert_ne!(ArtifactKey::suppressed(&a), ArtifactKey::suppressed(&b));
        // A single-pixel flip changes the digest.
        let mut c = a.clone();
        c.set(7, 7, c.get(7, 7) + 0.25);
        assert_ne!(ArtifactKey::suppressed(&a), ArtifactKey::suppressed(&c));
    }

    #[test]
    fn dimensions_are_part_of_the_key() {
        // Same bytes, transposed geometry: distinct artifacts, distinct
        // keys.
        let a = ImageF32::from_vec(4, 2, vec![0.5; 8]).unwrap();
        let b = ImageF32::from_vec(2, 4, vec![0.5; 8]).unwrap();
        assert_ne!(ArtifactKey::suppressed(&a), ArtifactKey::suppressed(&b));
    }

    #[test]
    fn span_is_part_of_the_key() {
        let img = generate(Scene::Gradient, 16, 16);
        let front = ArtifactKey::for_span(&img, None, StageKind::Pad, StageKind::Nms);
        let grad = ArtifactKey::for_span(&img, None, StageKind::Pad, StageKind::Sobel);
        assert_ne!(front, grad);
    }

    #[test]
    fn thresholds_fingerprint_only_threshold_spans() {
        let img = generate(Scene::Gradient, 16, 16);
        let p1 = CannyParams { lo: 0.05, hi: 0.15, ..CannyParams::default() };
        let p2 = CannyParams { lo: 0.02, hi: 0.30, ..CannyParams::default() };
        // The front ignores lo/hi: a re-threshold sweep shares one key.
        assert_eq!(
            ArtifactKey::for_span(&img, Some(&p1), StageKind::Pad, StageKind::Nms),
            ArtifactKey::for_span(&img, Some(&p2), StageKind::Pad, StageKind::Nms),
        );
        // A span reaching Threshold depends on them.
        assert_ne!(
            ArtifactKey::for_span(&img, Some(&p1), StageKind::Pad, StageKind::Threshold),
            ArtifactKey::for_span(&img, Some(&p2), StageKind::Pad, StageKind::Threshold),
        );
    }

    #[test]
    fn edge_digest_tracks_content_and_geometry() {
        use crate::image::EdgeMap;
        let mut bytes = vec![0u8; 24];
        bytes[8] = 255;
        let a = EdgeMap::new(6, 4, bytes.clone()).unwrap();
        let b = EdgeMap::new(6, 4, bytes.clone()).unwrap();
        assert_eq!(ArtifactKey::edges(&a), ArtifactKey::edges(&b));
        bytes[15] = 255;
        let c = EdgeMap::new(6, 4, bytes.clone()).unwrap();
        assert_ne!(ArtifactKey::edges(&a), ArtifactKey::edges(&c));
        // Same bytes, transposed geometry: distinct digests.
        let d = EdgeMap::new(4, 6, bytes).unwrap();
        assert_ne!(ArtifactKey::edges(&c), ArtifactKey::edges(&d));
    }

    #[test]
    fn shard_choice_in_range_and_stable() {
        let img = generate(Scene::Shapes { seed: 1 }, 24, 24);
        let k = ArtifactKey::suppressed(&img);
        for n in 1..9 {
            assert!(k.shard(n) < n);
            assert_eq!(k.shard(n), k.shard(n));
        }
    }
}
