//! Cost-aware admission: an artifact earns cache residency by the
//! recompute time a future hit saves **per byte it occupies**, not by
//! mere recency. Without this, a burst of tiny cheap artifacts (small
//! re-threshold probes) can evict a handful of expensive megapixel
//! fronts that took orders of magnitude longer to build — strictly
//! worse for aggregate throughput.
//!
//! The caller supplies `recompute_ns`: the serving tier passes its
//! calibrated kind cost ([`crate::service::ServeOptions::service_ns_kind`],
//! which uses the per-stage [`crate::service::calibrate::StageCost`]
//! fits when a calibration is installed), and the stream tier passes
//! the measured wall time of the last *full* front pass (a delta-gated
//! frame's own wall covers only its dirty tiles, but a hit on its
//! exact map still saves a whole front). Both are estimates of the
//! same quantity: what a hit saves.

/// Admission threshold in nanoseconds-of-recompute per byte-of-cache.
/// `0.0` admits everything (the default — pure LRU behavior).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AdmissionPolicy {
    pub min_ns_per_byte: f64,
}

impl Default for AdmissionPolicy {
    fn default() -> Self {
        AdmissionPolicy { min_ns_per_byte: 0.0 }
    }
}

impl AdmissionPolicy {
    pub fn new(min_ns_per_byte: f64) -> AdmissionPolicy {
        AdmissionPolicy { min_ns_per_byte: min_ns_per_byte.max(0.0) }
    }

    /// Does an artifact costing `recompute_ns` to rebuild and `bytes`
    /// to keep clear the bar? Zero-byte artifacts are vacuously free to
    /// keep.
    pub fn admits(&self, recompute_ns: u64, bytes: u64) -> bool {
        if self.min_ns_per_byte <= 0.0 || bytes == 0 {
            return true;
        }
        recompute_ns as f64 / bytes as f64 >= self.min_ns_per_byte
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_threshold_admits_everything() {
        let p = AdmissionPolicy::default();
        assert!(p.admits(0, u64::MAX));
        assert!(p.admits(u64::MAX, 1));
    }

    #[test]
    fn threshold_gates_on_ns_per_byte() {
        // 2 ns/byte bar: 1000 ns over 400 bytes (2.5) clears it, over
        // 600 bytes (1.67) does not.
        let p = AdmissionPolicy::new(2.0);
        assert!(p.admits(1_000, 400));
        assert!(!p.admits(1_000, 600));
        assert!(p.admits(1_000, 500), "exactly at the bar admits");
        assert!(p.admits(123, 0), "zero-byte artifacts are free");
    }

    #[test]
    fn negative_threshold_clamps_to_admit_all() {
        let p = AdmissionPolicy::new(-5.0);
        assert!(p.admits(0, 1_000_000));
    }
}
