//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt` +
//! `manifest.json` produced by `python/compile/aot.py`) and executes
//! them on the XLA CPU client from the L3 hot path.
//!
//! Python never runs here — the HLO text is the entire interchange.

pub mod engine;
pub mod manifest;

pub use engine::XlaEngine;
pub use manifest::{ArtifactEntry, Manifest, TileConfig};
