//! The XLA execution engine: compiles HLO-text artifacts once at
//! startup (PJRT CPU client) and executes them per tile from worker
//! threads.
//!
//! Concurrency model: `PjRtLoadedExecutable::execute` takes `&self`
//! through a raw C handle. We keep `replicas` independently-compiled
//! copies of each entry, each behind its own mutex; worker `slot`s hash
//! onto replicas so concurrent tiles don't serialize on one handle.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Mutex;

use crate::error::{Error, Result};
use crate::image::ImageF32;
use crate::runtime::manifest::{ArtifactEntry, Manifest, TileConfig};

/// One compiled executable behind a mutex.
struct ExeSlot(Mutex<xla::PjRtLoadedExecutable>);

// SAFETY: PJRT CPU executables are internally thread-safe for execute;
// we additionally serialize per-slot through the mutex. The raw handles
// are only freed on drop, which happens once (owned here).
unsafe impl Send for ExeSlot {}
unsafe impl Sync for ExeSlot {}

struct Entry {
    meta: ArtifactEntry,
    slots: Vec<ExeSlot>,
}

/// Loads + runs the AOT artifacts for one tile configuration.
pub struct XlaEngine {
    client: xla::PjRtClient,
    tile_name: String,
    core_h: usize,
    core_w: usize,
    halo: usize,
    entries: BTreeMap<String, Entry>,
}

// Manual impl: the PJRT client handle is opaque.
impl std::fmt::Debug for XlaEngine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("XlaEngine")
            .field("tile_name", &self.tile_name)
            .field("core_h", &self.core_h)
            .field("core_w", &self.core_w)
            .field("halo", &self.halo)
            .field("entries", &self.entries.len())
            .finish()
    }
}

// SAFETY: the client handle is only used for compile (startup) and is
// thread-safe in the CPU plugin; see ExeSlot for executables.
unsafe impl Send for XlaEngine {}
unsafe impl Sync for XlaEngine {}

impl XlaEngine {
    /// Load `tile_name` from the artifacts at `dir`, compiling
    /// `replicas` copies of each entry point.
    pub fn load(dir: &Path, tile_name: &str, replicas: usize) -> Result<XlaEngine> {
        let manifest = Manifest::load(dir)?;
        Self::from_manifest(&manifest, tile_name, replicas)
    }

    /// Load from an already-parsed manifest.
    pub fn from_manifest(
        manifest: &Manifest,
        tile_name: &str,
        replicas: usize,
    ) -> Result<XlaEngine> {
        let tile: &TileConfig = manifest.tile(tile_name)?;
        let client = xla::PjRtClient::cpu()?;
        let replicas = replicas.max(1);
        let mut entries = BTreeMap::new();
        for (name, meta) in &tile.entries {
            let proto = xla::HloModuleProto::from_text_file(
                meta.path
                    .to_str()
                    .ok_or_else(|| Error::Artifact("non-utf8 artifact path".into()))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let slots = (0..replicas)
                .map(|_| Ok(ExeSlot(Mutex::new(client.compile(&comp)?))))
                .collect::<Result<Vec<_>>>()?;
            entries.insert(name.clone(), Entry { meta: meta.clone(), slots });
        }
        Ok(XlaEngine {
            client,
            tile_name: tile.name.clone(),
            core_h: tile.core_h,
            core_w: tile.core_w,
            halo: manifest.halo,
            entries,
        })
    }

    pub fn tile_name(&self) -> &str {
        &self.tile_name
    }

    /// (core_h, core_w) of the fixed tile this engine executes.
    pub fn tile_core(&self) -> (usize, usize) {
        (self.core_h, self.core_w)
    }

    pub fn halo(&self) -> usize {
        self.halo
    }

    /// PJRT platform string (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Entry names available at this tile.
    pub fn entry_names(&self) -> Vec<&str> {
        self.entries.keys().map(|s| s.as_str()).collect()
    }

    /// Execute entry `name` with `inputs`; returns output literals.
    /// `slot` selects the executable replica (use the worker index).
    pub fn run_entry(
        &self,
        name: &str,
        inputs: &[xla::Literal],
        slot: usize,
    ) -> Result<Vec<xla::Literal>> {
        let entry = self
            .entries
            .get(name)
            .ok_or_else(|| Error::Artifact(format!("no entry `{name}` at {}", self.tile_name)))?;
        if inputs.len() != entry.meta.inputs.len() {
            return Err(Error::Xla(format!(
                "{name}: {} inputs given, {} expected",
                inputs.len(),
                entry.meta.inputs.len()
            )));
        }
        let exe = &entry.slots[slot % entry.slots.len()];
        let guard = exe.0.lock().unwrap();
        let result = guard.execute::<xla::Literal>(inputs)?;
        drop(guard);
        let literal = result[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: always a tuple.
        let outs = literal
            .to_tuple()
            .map_err(|e| Error::Xla(format!("{name}: untupling failed: {e}")))?;
        if outs.len() != entry.meta.outputs.len() {
            return Err(Error::Xla(format!(
                "{name}: {} outputs, {} expected",
                outs.len(),
                entry.meta.outputs.len()
            )));
        }
        Ok(outs)
    }

    /// Execute the fused Canny front on one padded tile window
    /// (`(core+2h) x (core+2h)`), returning image-shaped (class, nms)
    /// of exactly `core` size.
    pub fn run_front(
        &self,
        window: &ImageF32,
        lo: f32,
        hi: f32,
        slot: usize,
    ) -> Result<(ImageF32, ImageF32)> {
        let (ph, pw) = (self.core_h + 2 * self.halo, self.core_w + 2 * self.halo);
        if window.height() != ph || window.width() != pw {
            return Err(Error::Geometry(format!(
                "window {}x{} != expected {}x{}",
                window.height(),
                window.width(),
                ph,
                pw
            )));
        }
        let x = xla::Literal::vec1(window.data()).reshape(&[ph as i64, pw as i64])?;
        let lo = xla::Literal::vec1(&[lo]);
        let hi = xla::Literal::vec1(&[hi]);
        let outs = self.run_entry("canny_front", &[x, lo, hi], slot)?;
        let cls = literal_to_image(&outs[0], self.core_w, self.core_h)?;
        let nm = literal_to_image(&outs[1], self.core_w, self.core_h)?;
        Ok((cls, nm))
    }
}

/// Convert an f32 literal of known shape into an image.
pub fn literal_to_image(lit: &xla::Literal, width: usize, height: usize) -> Result<ImageF32> {
    let v = lit.to_vec::<f32>()?;
    ImageF32::from_vec(width, height, v)
}

// Engine construction is exercised by rust/tests/integration_runtime.rs
// (requires `make artifacts`); unit tests here cover the helpers only.
#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let img = ImageF32::from_vec(3, 2, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]).unwrap();
        let lit = xla::Literal::vec1(img.data()).reshape(&[2, 3]).unwrap();
        let back = literal_to_image(&lit, 3, 2).unwrap();
        assert_eq!(back, img);
    }
}
