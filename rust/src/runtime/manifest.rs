//! `artifacts/manifest.json` loader: which HLO files exist, their tile
//! geometry and parameter shapes. The manifest is the contract between
//! `python/compile/aot.py` and this runtime; shapes are re-validated
//! here so a stale artifacts/ directory fails loudly, not numerically.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::util::json::Json;

/// One lowered entry point (e.g. `canny_front` at tile t128).
#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    pub name: String,
    /// HLO text file, absolute.
    pub path: PathBuf,
    /// Input shapes (row-major dims; scalars are `[1]`).
    pub inputs: Vec<Vec<usize>>,
    /// Output shapes.
    pub outputs: Vec<Vec<usize>>,
}

/// One tile configuration (core size + its entry points).
#[derive(Clone, Debug)]
pub struct TileConfig {
    pub name: String,
    pub core_h: usize,
    pub core_w: usize,
    pub entries: BTreeMap<String, ArtifactEntry>,
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub halo: usize,
    pub tiles: Vec<TileConfig>,
}

impl Manifest {
    /// Load and validate `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json")).map_err(|e| {
            Error::Artifact(format!(
                "cannot read {}/manifest.json (run `make artifacts`): {e}",
                dir.display()
            ))
        })?;
        let root = Json::parse(&text)?;
        let format = root.req("format")?.as_usize().unwrap_or(0);
        if format != 1 {
            return Err(Error::Artifact(format!("unsupported manifest format {format}")));
        }
        let halo = root
            .req("halo")?
            .as_usize()
            .ok_or_else(|| Error::Artifact("halo not a number".into()))?;
        let mut tiles = Vec::new();
        for t in root.req("tiles")?.as_arr().unwrap_or(&[]) {
            let name = t
                .req("name")?
                .as_str()
                .ok_or_else(|| Error::Artifact("tile name".into()))?
                .to_string();
            let core = t
                .req("core")?
                .as_usize_vec()
                .filter(|v| v.len() == 2)
                .ok_or_else(|| Error::Artifact(format!("tile {name}: bad core")))?;
            let mut entries = BTreeMap::new();
            for (ename, e) in t.req("entries")?.as_obj().into_iter().flatten() {
                let file = e
                    .req("file")?
                    .as_str()
                    .ok_or_else(|| Error::Artifact(format!("{ename}: file")))?;
                let shapes = |key: &str| -> Result<Vec<Vec<usize>>> {
                    e.req(key)?
                        .as_arr()
                        .ok_or_else(|| Error::Artifact(format!("{ename}: {key}")))?
                        .iter()
                        .map(|s| {
                            s.as_usize_vec()
                                .ok_or_else(|| Error::Artifact(format!("{ename}: {key} dims")))
                        })
                        .collect()
                };
                let entry = ArtifactEntry {
                    name: ename.clone(),
                    path: dir.join(file),
                    inputs: shapes("inputs")?,
                    outputs: shapes("outputs")?,
                };
                if !entry.path.exists() {
                    return Err(Error::Artifact(format!(
                        "manifest references missing file {}",
                        entry.path.display()
                    )));
                }
                entries.insert(ename.clone(), entry);
            }
            // Geometry validation: canny_front input must be core + 2*halo.
            if let Some(front) = entries.get("canny_front") {
                let expect = vec![core[0] + 2 * halo, core[1] + 2 * halo];
                if front.inputs.first() != Some(&expect) {
                    return Err(Error::Artifact(format!(
                        "tile {name}: canny_front input {:?} != core+2*halo {:?}",
                        front.inputs.first(),
                        expect
                    )));
                }
            }
            tiles.push(TileConfig { name, core_h: core[0], core_w: core[1], entries });
        }
        if tiles.is_empty() {
            return Err(Error::Artifact("manifest has no tiles".into()));
        }
        Ok(Manifest { dir: dir.to_path_buf(), halo, tiles })
    }

    /// Default artifacts location: `$CANNY_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("CANNY_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }

    /// Find a tile config by name.
    pub fn tile(&self, name: &str) -> Result<&TileConfig> {
        self.tiles
            .iter()
            .find(|t| t.name == name)
            .ok_or_else(|| Error::Artifact(format!("no tile config `{name}` in manifest")))
    }

    /// The tile whose core height is closest to `want` (planner helper).
    pub fn closest_tile(&self, want: usize) -> &TileConfig {
        self.tiles
            .iter()
            .min_by_key(|t| t.core_h.abs_diff(want))
            .expect("manifest non-empty")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_fixture(dir: &Path, manifest: &str, files: &[&str]) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.json"), manifest).unwrap();
        for f in files {
            std::fs::write(dir.join(f), "ENTRY {}").unwrap();
        }
    }

    const GOOD: &str = r#"{"format":1,"halo":4,"tiles":[
        {"name":"t8","core":[8,8],"entries":{
            "canny_front":{"file":"f.hlo.txt","inputs":[[16,16],[1],[1]],
                           "outputs":[[8,8],[8,8]]}}}]}"#;

    #[test]
    fn loads_valid_manifest() {
        let dir = std::env::temp_dir().join("canny_manifest_ok");
        write_fixture(&dir, GOOD, &["f.hlo.txt"]);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.halo, 4);
        assert_eq!(m.tiles.len(), 1);
        let t = m.tile("t8").unwrap();
        assert_eq!((t.core_h, t.core_w), (8, 8));
        assert!(t.entries.contains_key("canny_front"));
    }

    #[test]
    fn rejects_missing_file() {
        let dir = std::env::temp_dir().join("canny_manifest_missing");
        write_fixture(&dir, GOOD, &[]); // no f.hlo.txt
        assert!(Manifest::load(&dir).is_err());
    }

    #[test]
    fn rejects_bad_geometry() {
        let bad = GOOD.replace("[[16,16]", "[[15,16]");
        let dir = std::env::temp_dir().join("canny_manifest_geom");
        write_fixture(&dir, &bad, &["f.hlo.txt"]);
        let err = Manifest::load(&dir).unwrap_err().to_string();
        assert!(err.contains("core+2*halo"), "{err}");
    }

    #[test]
    fn rejects_unknown_format() {
        let bad = GOOD.replace("\"format\":1", "\"format\":9");
        let dir = std::env::temp_dir().join("canny_manifest_fmt");
        write_fixture(&dir, &bad, &["f.hlo.txt"]);
        assert!(Manifest::load(&dir).is_err());
    }

    #[test]
    fn closest_tile_picks_nearest() {
        let two = r#"{"format":1,"halo":4,"tiles":[
            {"name":"t8","core":[8,8],"entries":{}},
            {"name":"t64","core":[64,64],"entries":{}}]}"#;
        let dir = std::env::temp_dir().join("canny_manifest_two");
        write_fixture(&dir, two, &[]);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.closest_tile(10).name, "t8");
        assert_eq!(m.closest_tile(100).name, "t64");
    }
}
