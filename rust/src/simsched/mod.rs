//! Deterministic discrete-event simulator of a work-stealing multicore
//! — the substitute for the paper's physical i3 (2c/4t) and i7 (4c/8t)
//! testbeds on this 1-CPU host (DESIGN.md §3).
//!
//! The *real* pattern decomposition runs once to produce a
//! [`SimSpec`]: per-task costs measured with thread-CPU-time plus the
//! serial fractions (pad/assemble/hysteresis). The simulator then
//! replays the same Cilk steal policy — spawner pushes tiles to its own
//! deque, owner pops LIFO, idle virtual cores steal FIFO — over virtual
//! time on `n` virtual cores. Outputs are per-core busy intervals, from
//! which the profiler renders the paper's Figures 8–12, and makespans,
//! from which Table-1-style speedups are computed.
//!
//! This measures exactly what the paper's figures measure — scheduling
//! behaviour (idle vs evenly-utilized cores) — while being fully
//! reproducible from a seed.

pub mod trace;

pub use trace::{SimResult, Interval};

/// One fork–join phase: an optional serial prologue (runs on core 0),
/// a bag of parallel tasks (tile costs, ns), and a serial epilogue.
#[derive(Clone, Debug, Default)]
pub struct SimPhase {
    pub label: String,
    pub serial_before_ns: u64,
    pub tasks_ns: Vec<u64>,
    pub serial_after_ns: u64,
}

impl SimPhase {
    pub fn serial(label: &str, ns: u64) -> SimPhase {
        SimPhase { label: label.into(), serial_before_ns: ns, ..Default::default() }
    }

    pub fn parallel(label: &str, tasks_ns: Vec<u64>) -> SimPhase {
        SimPhase { label: label.into(), tasks_ns, ..Default::default() }
    }

    /// Total work in this phase.
    pub fn work_ns(&self) -> u64 {
        self.serial_before_ns + self.tasks_ns.iter().sum::<u64>() + self.serial_after_ns
    }
}

/// A whole run: phases executed in order with a full barrier between
/// them (the paper's stage structure: gauss → sobel → nms → hysteresis).
#[derive(Clone, Debug, Default)]
pub struct SimSpec {
    pub phases: Vec<SimPhase>,
}

impl SimSpec {
    /// Total work across phases (= ideal serial time).
    pub fn work_ns(&self) -> u64 {
        self.phases.iter().map(|p| p.work_ns()).sum()
    }

    /// The serial fraction `1 - f` of Amdahl's law implied by the spec.
    pub fn serial_fraction(&self) -> f64 {
        let serial: u64 = self
            .phases
            .iter()
            .map(|p| p.serial_before_ns + p.serial_after_ns)
            .sum();
        serial as f64 / self.work_ns().max(1) as f64
    }
}

/// Simulate `spec` on `cores` virtual cores.
///
/// Steal policy (mirrors [`crate::scheduler`]): all tasks of a phase
/// are spawned from core 0, which then pops its deque LIFO (last tile
/// first); each idle core repeatedly steals the *oldest* task (FIFO)
/// from the only non-empty deque. Ready cores are served in core-id
/// order at equal times, making the whole simulation deterministic.
pub fn simulate(spec: &SimSpec, cores: usize) -> SimResult {
    assert!(cores >= 1);
    let mut now = 0u64; // virtual ns
    let mut result = SimResult::new(cores);

    for phase in &spec.phases {
        if phase.serial_before_ns > 0 {
            result.push_interval(0, now, now + phase.serial_before_ns, &phase.label);
            now += phase.serial_before_ns;
        }
        if !phase.tasks_ns.is_empty() {
            // Deque after spawn: front = task 0, back = task n-1.
            // Core 0 pops back; thieves steal front.
            let mut front = 0usize;
            let mut back = phase.tasks_ns.len(); // exclusive
            // Per-core next-free time; all free at `now`.
            let mut free_at = vec![now; cores];
            loop {
                if front >= back {
                    break;
                }
                // The next core to become free (ties -> lowest id).
                let core = (0..cores)
                    .min_by_key(|&c| (free_at[c], c))
                    .expect("cores >= 1");
                let t = free_at[core];
                // Assign next task per steal policy.
                let (task_idx, stolen) = if core == 0 {
                    back -= 1;
                    (back, false)
                } else {
                    let i = front;
                    front += 1;
                    (i, true)
                };
                let cost = phase.tasks_ns[task_idx].max(1);
                result.push_interval(core, t, t + cost, &phase.label);
                if stolen {
                    result.steals[core] += 1;
                }
                result.tasks[core] += 1;
                free_at[core] = t + cost;
            }
            now = free_at.into_iter().max().unwrap_or(now);
        }
        if phase.serial_after_ns > 0 {
            result.push_interval(0, now, now + phase.serial_after_ns, &phase.label);
            now += phase.serial_after_ns;
        }
    }
    result.makespan_ns = now;
    result
}

/// Speedup of an n-core simulation over the 1-core simulation.
pub fn speedup(spec: &SimSpec, cores: usize) -> f64 {
    let t1 = simulate(spec, 1).makespan_ns as f64;
    let tn = simulate(spec, cores).makespan_ns as f64;
    t1 / tn.max(1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn flat_spec(n_tasks: usize, cost: u64) -> SimSpec {
        SimSpec { phases: vec![SimPhase::parallel("p", vec![cost; n_tasks])] }
    }

    #[test]
    fn single_core_runs_everything_serially() {
        let spec = flat_spec(10, 100);
        let r = simulate(&spec, 1);
        assert_eq!(r.makespan_ns, 1000);
        assert_eq!(r.busy_ns[0], 1000);
        assert_eq!(r.tasks[0], 10);
        assert_eq!(r.steals[0], 0);
    }

    #[test]
    fn perfect_scaling_on_even_tasks() {
        let spec = flat_spec(16, 100);
        for cores in [2usize, 4, 8] {
            let r = simulate(&spec, cores);
            assert_eq!(r.makespan_ns, 1600 / cores as u64, "cores={cores}");
            // All cores equally busy.
            assert!(r.busy_ns.iter().all(|&b| b == 1600 / cores as u64));
        }
    }

    #[test]
    fn work_conserved() {
        let spec = SimSpec {
            phases: vec![
                SimPhase::serial("pad", 50),
                SimPhase::parallel("front", vec![10, 20, 30, 40, 50, 60, 70]),
                SimPhase {
                    label: "hyst".into(),
                    serial_before_ns: 0,
                    tasks_ns: vec![],
                    serial_after_ns: 100,
                },
            ],
        };
        for cores in [1usize, 2, 4, 8] {
            let r = simulate(&spec, cores);
            assert_eq!(r.busy_ns.iter().sum::<u64>(), spec.work_ns(), "cores={cores}");
        }
    }

    #[test]
    fn serial_phase_occupies_core0_only() {
        let spec = SimSpec { phases: vec![SimPhase::serial("s", 500)] };
        let r = simulate(&spec, 4);
        assert_eq!(r.busy_ns[0], 500);
        assert!(r.busy_ns[1..].iter().all(|&b| b == 0));
        assert_eq!(r.makespan_ns, 500);
    }

    #[test]
    fn steals_happen_on_multicore() {
        let r = simulate(&flat_spec(32, 100), 4);
        let total_steals: u64 = r.steals.iter().sum();
        assert!(total_steals > 0);
        assert_eq!(r.steals[0], 0, "core 0 owns the deque");
    }

    #[test]
    fn deterministic() {
        let spec = flat_spec(37, 113);
        let a = simulate(&spec, 8);
        let b = simulate(&spec, 8);
        assert_eq!(a.busy_ns, b.busy_ns);
        assert_eq!(a.makespan_ns, b.makespan_ns);
    }

    #[test]
    fn amdahl_limit_respected() {
        // 50% serial work caps speedup at 2 regardless of cores.
        let spec = SimSpec {
            phases: vec![
                SimPhase::serial("s", 1000),
                SimPhase::parallel("p", vec![125; 8]),
            ],
        };
        let s8 = speedup(&spec, 8);
        assert!(s8 < 2.0 + 1e-9, "s8={s8}");
        assert!(s8 > 1.5, "s8={s8}");
    }

    #[test]
    fn uneven_tasks_still_balance_reasonably() {
        // One huge task + many small: makespan >= huge task.
        let mut tasks = vec![50u64; 30];
        tasks.push(2000);
        let spec = SimSpec { phases: vec![SimPhase::parallel("p", tasks)] };
        let r = simulate(&spec, 4);
        assert!(r.makespan_ns >= 2000);
        // But not much worse: LIFO pop means core 0 takes the big task
        // last... steal order FIFO; bound loosely.
        assert!(r.makespan_ns <= 2000 + 1500, "makespan {}", r.makespan_ns);
    }

    #[test]
    fn serial_fraction_computed() {
        let spec = SimSpec {
            phases: vec![SimPhase::serial("s", 100), SimPhase::parallel("p", vec![100; 3])],
        };
        assert!((spec.serial_fraction() - 0.25).abs() < 1e-12);
    }
}
