//! Simulation results: per-core busy intervals and conversions into the
//! profiler's utilization traces (the common artifact format behind the
//! paper's figures, whether measured or simulated).

/// One busy interval on a virtual core.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Interval {
    pub start_ns: u64,
    pub end_ns: u64,
    /// Phase label ("gaussian", "front", "hysteresis", …).
    pub label: String,
}

/// Output of [`super::simulate`].
#[derive(Clone, Debug)]
pub struct SimResult {
    pub cores: usize,
    pub makespan_ns: u64,
    /// Per-core busy time.
    pub busy_ns: Vec<u64>,
    /// Per-core busy intervals, time-ordered.
    pub intervals: Vec<Vec<Interval>>,
    /// Per-core tasks executed.
    pub tasks: Vec<u64>,
    /// Per-core successful steals.
    pub steals: Vec<u64>,
}

impl SimResult {
    pub(crate) fn new(cores: usize) -> SimResult {
        SimResult {
            cores,
            makespan_ns: 0,
            busy_ns: vec![0; cores],
            intervals: vec![Vec::new(); cores],
            tasks: vec![0; cores],
            steals: vec![0; cores],
        }
    }

    pub(crate) fn push_interval(&mut self, core: usize, start: u64, end: u64, label: &str) {
        debug_assert!(end > start);
        self.busy_ns[core] += end - start;
        self.intervals[core].push(Interval { start_ns: start, end_ns: end, label: label.into() });
    }

    /// Whether `core` is busy at time `t` (ns).
    pub fn busy_at(&self, core: usize, t: u64) -> bool {
        // Intervals are time-ordered; binary search the candidate.
        let v = &self.intervals[core];
        match v.binary_search_by(|iv| {
            if iv.end_ns <= t {
                std::cmp::Ordering::Less
            } else if iv.start_ns > t {
                std::cmp::Ordering::Greater
            } else {
                std::cmp::Ordering::Equal
            }
        }) {
            Ok(_) => true,
            Err(_) => false,
        }
    }

    /// Average utilization of each core over the makespan, in [0, 1].
    pub fn per_core_utilization(&self) -> Vec<f64> {
        self.busy_ns
            .iter()
            .map(|&b| b as f64 / self.makespan_ns.max(1) as f64)
            .collect()
    }

    /// Mean total utilization (sum of core busy / cores*makespan).
    pub fn total_utilization(&self) -> f64 {
        let total: u64 = self.busy_ns.iter().sum();
        total as f64 / (self.makespan_ns.max(1) * self.cores as u64) as f64
    }

    /// Sample per-core busy state every `period_ns` over the makespan:
    /// the simulated equivalent of the paper's 10M-cycle sampling
    /// profiler. Returns `samples[t][core] = busy?`.
    pub fn sample(&self, period_ns: u64) -> Vec<Vec<bool>> {
        let period = period_ns.max(1);
        let n = (self.makespan_ns / period) as usize + 1;
        (0..n)
            .map(|k| {
                let t = k as u64 * period;
                (0..self.cores).map(|c| self.busy_at(c, t)).collect()
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn simple() -> SimResult {
        let mut r = SimResult::new(2);
        r.push_interval(0, 0, 100, "a");
        r.push_interval(0, 150, 250, "b");
        r.push_interval(1, 50, 120, "a");
        r.makespan_ns = 250;
        r
    }

    #[test]
    fn busy_at_interval_boundaries() {
        let r = simple();
        assert!(r.busy_at(0, 0));
        assert!(r.busy_at(0, 99));
        assert!(!r.busy_at(0, 100)); // end exclusive
        assert!(!r.busy_at(0, 120));
        assert!(r.busy_at(0, 200));
        assert!(r.busy_at(1, 50));
        assert!(!r.busy_at(1, 10));
    }

    #[test]
    fn utilization_math() {
        let r = simple();
        let per = r.per_core_utilization();
        assert!((per[0] - 200.0 / 250.0).abs() < 1e-12);
        assert!((per[1] - 70.0 / 250.0).abs() < 1e-12);
        assert!((r.total_utilization() - 270.0 / 500.0).abs() < 1e-12);
    }

    #[test]
    fn sampling_counts_busy_points() {
        let r = simple();
        let s = r.sample(50);
        // t = 0,50,100,150,200,250
        assert_eq!(s.len(), 6);
        assert_eq!(s[0], vec![true, false]);
        assert_eq!(s[1], vec![true, true]);
        assert_eq!(s[2], vec![false, true]);
        assert_eq!(s[3], vec![true, false]);
        assert_eq!(s[4], vec![true, false]);
        assert_eq!(s[5], vec![false, false]);
    }
}
