//! SLO accounting for the serving tier: per-request latency samples
//! (enqueue→dispatch→complete) rolled into p50/p95/p99 summaries per
//! lane and in aggregate, and the deterministic JSON serving report
//! `cannyd serve` prints.

use std::collections::BTreeMap;

use crate::util::json::Json;

/// Latency sample sink (virtual ns). Order-insensitive: summaries sort.
#[derive(Clone, Debug, Default)]
pub struct LatencyStats {
    samples: Vec<u64>,
}

impl LatencyStats {
    pub fn new() -> LatencyStats {
        LatencyStats::default()
    }

    pub fn record(&mut self, ns: u64) {
        self.samples.push(ns);
    }

    pub fn count(&self) -> usize {
        self.samples.len()
    }

    /// Nearest-rank summary (same quantile convention as
    /// [`crate::util::timer::Summary`]). Empty stats summarize to zeros.
    pub fn summary(&self) -> LatencySummary {
        if self.samples.is_empty() {
            return LatencySummary::default();
        }
        let mut sorted = self.samples.clone();
        sorted.sort_unstable();
        let n = sorted.len();
        let q = |p: f64| sorted[((n - 1) as f64 * p).round() as usize];
        LatencySummary {
            n,
            p50_ns: q(0.50),
            p95_ns: q(0.95),
            p99_ns: q(0.99),
            max_ns: sorted[n - 1],
            mean_ns: sorted.iter().sum::<u64>() as f64 / n as f64,
        }
    }
}

/// Sorted-once percentile snapshot of a [`LatencyStats`].
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct LatencySummary {
    pub n: usize,
    pub p50_ns: u64,
    pub p95_ns: u64,
    pub p99_ns: u64,
    pub max_ns: u64,
    pub mean_ns: f64,
}

impl LatencySummary {
    fn to_json(self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("n".into(), Json::Num(self.n as f64));
        m.insert("p50".into(), Json::Num(self.p50_ns as f64));
        m.insert("p95".into(), Json::Num(self.p95_ns as f64));
        m.insert("p99".into(), Json::Num(self.p99_ns as f64));
        m.insert("max".into(), Json::Num(self.max_ns as f64));
        m.insert("mean".into(), Json::Num(self.mean_ns));
        Json::Obj(m)
    }
}

/// Per-lane slice of the serving report.
#[derive(Clone, Debug)]
pub struct LaneReport {
    pub lane: usize,
    pub requests: u64,
    pub batches: u64,
    /// Virtual ns this lane spent serving.
    pub busy_ns: u64,
    pub latency: LatencySummary,
}

/// The complete serving report — everything `cannyd serve` knows about
/// a replayed trace. Serialized via [`ServeReport::to_json_string`];
/// field values are virtual-time quantities, so the same trace + seed
/// produces a byte-identical report on a given host.
#[derive(Clone, Debug)]
pub struct ServeReport {
    pub label: String,
    pub seed: u64,
    /// Engine the planner chose for the lanes.
    pub engine: String,
    pub workers_per_lane: usize,
    pub offered: u64,
    pub admitted: u64,
    pub rejected_full: u64,
    pub rejected_oversize: u64,
    pub completed: u64,
    pub queue_depth: usize,
    pub queue_high_water: usize,
    pub batch_window_ns: u64,
    pub max_batch: usize,
    pub batches_formed: u64,
    /// Virtual time of the last completion.
    pub makespan_ns: u64,
    /// Sum of detected edge pixels over all completed requests (0 when
    /// execution is disabled) — the proof real compute happened.
    pub edge_pixels: u64,
    /// End-to-end latency (arrival → complete), all lanes.
    pub latency: LatencySummary,
    /// Waiting-room latency (arrival → dispatch), all lanes.
    pub queue_wait: LatencySummary,
    pub lanes: Vec<LaneReport>,
    pub slo_target_p99_ns: u64,
}

impl ServeReport {
    /// Total rejections, all reasons.
    pub fn rejected(&self) -> u64 {
        self.rejected_full + self.rejected_oversize
    }

    /// Did the aggregate p99 stay within the SLO target? Vacuously true
    /// with no completions.
    pub fn slo_met(&self) -> bool {
        self.completed == 0 || self.latency.p99_ns <= self.slo_target_p99_ns
    }

    /// Completions per virtual second.
    pub fn throughput_rps(&self) -> f64 {
        if self.makespan_ns == 0 {
            return 0.0;
        }
        self.completed as f64 / (self.makespan_ns as f64 / 1e9)
    }

    /// Mean requests per formed batch (coalescing effectiveness).
    pub fn mean_batch_fill(&self) -> f64 {
        if self.batches_formed == 0 {
            return 0.0;
        }
        self.completed as f64 / self.batches_formed as f64
    }

    /// Structured report (object keys are sorted — deterministic dump).
    pub fn to_json(&self) -> Json {
        let num = |v: u64| Json::Num(v as f64);
        let mut m = BTreeMap::new();
        m.insert("label".into(), Json::Str(self.label.clone()));
        m.insert("seed".into(), num(self.seed));
        m.insert("engine".into(), Json::Str(self.engine.clone()));
        m.insert("workers_per_lane".into(), Json::Num(self.workers_per_lane as f64));
        m.insert("offered".into(), num(self.offered));
        m.insert("admitted".into(), num(self.admitted));
        m.insert("rejected".into(), num(self.rejected()));
        m.insert("completed".into(), num(self.completed));
        m.insert("makespan_ns".into(), num(self.makespan_ns));
        m.insert("throughput_rps".into(), Json::Num(self.throughput_rps()));
        m.insert("edge_pixels".into(), num(self.edge_pixels));

        let mut queue = BTreeMap::new();
        queue.insert("depth".into(), Json::Num(self.queue_depth as f64));
        queue.insert("high_water".into(), Json::Num(self.queue_high_water as f64));
        queue.insert("rejected_full".into(), num(self.rejected_full));
        queue.insert("rejected_oversize".into(), num(self.rejected_oversize));
        m.insert("queue".into(), Json::Obj(queue));

        let mut batch = BTreeMap::new();
        batch.insert("window_ns".into(), num(self.batch_window_ns));
        batch.insert("max".into(), Json::Num(self.max_batch as f64));
        batch.insert("formed".into(), num(self.batches_formed));
        batch.insert("mean_fill".into(), Json::Num(self.mean_batch_fill()));
        m.insert("batch".into(), Json::Obj(batch));

        m.insert("latency_ns".into(), self.latency.to_json());
        m.insert("queue_wait_ns".into(), self.queue_wait.to_json());

        let lanes = self
            .lanes
            .iter()
            .map(|l| {
                let mut lm = BTreeMap::new();
                lm.insert("lane".into(), Json::Num(l.lane as f64));
                lm.insert("requests".into(), num(l.requests));
                lm.insert("batches".into(), num(l.batches));
                lm.insert("busy_ns".into(), num(l.busy_ns));
                lm.insert(
                    "utilization".into(),
                    Json::Num(if self.makespan_ns == 0 {
                        0.0
                    } else {
                        l.busy_ns as f64 / self.makespan_ns as f64
                    }),
                );
                lm.insert("latency_ns".into(), l.latency.to_json());
                Json::Obj(lm)
            })
            .collect();
        m.insert("lanes".into(), Json::Arr(lanes));

        let mut slo = BTreeMap::new();
        slo.insert("target_p99_ns".into(), num(self.slo_target_p99_ns));
        slo.insert("p99_ns".into(), num(self.latency.p99_ns));
        slo.insert("met".into(), Json::Bool(self.slo_met()));
        m.insert("slo".into(), Json::Obj(slo));

        Json::Obj(m)
    }

    /// The JSON text `cannyd serve` prints.
    pub fn to_json_string(&self) -> String {
        self.to_json().dump()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_stats_summarize_to_zero() {
        let s = LatencyStats::new().summary();
        assert_eq!(s, LatencySummary::default());
    }

    #[test]
    fn quantiles_ordered() {
        let mut st = LatencyStats::new();
        for v in (1..=1000).rev() {
            st.record(v);
        }
        let s = st.summary();
        assert_eq!(s.n, 1000);
        assert!(s.p50_ns <= s.p95_ns && s.p95_ns <= s.p99_ns && s.p99_ns <= s.max_ns);
        assert_eq!(s.max_ns, 1000);
        assert!(s.p50_ns == 500 || s.p50_ns == 501, "p50={}", s.p50_ns);
        assert!((s.mean_ns - 500.5).abs() < 1e-9);
    }

    fn report() -> ServeReport {
        ServeReport {
            label: "t".into(),
            seed: 7,
            engine: "patterns".into(),
            workers_per_lane: 2,
            offered: 10,
            admitted: 8,
            rejected_full: 2,
            rejected_oversize: 0,
            completed: 8,
            queue_depth: 4,
            queue_high_water: 4,
            batch_window_ns: 2_000_000,
            max_batch: 4,
            batches_formed: 2,
            makespan_ns: 1_000_000_000,
            edge_pixels: 1234,
            latency: LatencySummary { n: 8, p99_ns: 5_000_000, ..Default::default() },
            queue_wait: LatencySummary::default(),
            lanes: vec![LaneReport {
                lane: 0,
                requests: 8,
                batches: 2,
                busy_ns: 500_000_000,
                latency: LatencySummary::default(),
            }],
            slo_target_p99_ns: 50_000_000,
        }
    }

    #[test]
    fn report_math() {
        let r = report();
        assert_eq!(r.rejected(), 2);
        assert!(r.slo_met());
        assert!((r.throughput_rps() - 8.0).abs() < 1e-9);
        assert!((r.mean_batch_fill() - 4.0).abs() < 1e-9);
    }

    #[test]
    fn report_json_has_required_fields() {
        let j = report().to_json();
        assert_eq!(j.get("queue").unwrap().get("high_water").unwrap().as_usize(), Some(4));
        assert_eq!(j.get("batch").unwrap().get("formed").unwrap().as_usize(), Some(2));
        assert_eq!(j.get("rejected").unwrap().as_usize(), Some(2));
        let lanes = j.get("lanes").unwrap().as_arr().unwrap();
        assert!(lanes[0].get("latency_ns").unwrap().get("p99").is_some());
        assert_eq!(j.get("slo").unwrap().get("met"), Some(&Json::Bool(true)));
        // The dump round-trips through the parser.
        let text = report().to_json_string();
        assert_eq!(Json::parse(&text).unwrap(), j);
    }

    #[test]
    fn slo_violation_detected() {
        let mut r = report();
        r.slo_target_p99_ns = 1;
        assert!(!r.slo_met());
    }
}
