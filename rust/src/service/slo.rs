//! SLO accounting for the serving tier: per-request latency samples
//! (enqueue→dispatch→complete) rolled into p50/p95/p99 summaries per
//! lane and in aggregate, and the deterministic JSON serving report
//! `cannyd serve` prints. The same schema serves both clocks — the
//! `clock` field says whether the numbers are modeled or measured, and
//! the `calibration` section says which cost model produced (or would
//! predict) them.

use std::collections::BTreeMap;

use crate::cache::CacheSnapshot;
use crate::service::calibrate::Calibration;
use crate::util::json::Json;

/// Latency sample sink (ns, in the active clock). Order-insensitive:
/// summaries sort.
#[derive(Clone, Debug, Default)]
pub struct LatencyStats {
    samples: Vec<u64>,
}

impl LatencyStats {
    pub fn new() -> LatencyStats {
        LatencyStats::default()
    }

    pub fn record(&mut self, ns: u64) {
        self.samples.push(ns);
    }

    /// Fold another sink's samples into this one (lane → aggregate).
    pub fn merge(&mut self, other: &LatencyStats) {
        self.samples.extend_from_slice(&other.samples);
    }

    pub fn count(&self) -> usize {
        self.samples.len()
    }

    /// Nearest-rank summary (same quantile convention as
    /// [`crate::util::timer::Summary`]). Empty stats summarize to zeros.
    pub fn summary(&self) -> LatencySummary {
        if self.samples.is_empty() {
            return LatencySummary::default();
        }
        let mut sorted = self.samples.clone();
        sorted.sort_unstable();
        let n = sorted.len();
        let q = |p: f64| sorted[((n - 1) as f64 * p).round() as usize];
        LatencySummary {
            n,
            p50_ns: q(0.50),
            p95_ns: q(0.95),
            p99_ns: q(0.99),
            max_ns: sorted[n - 1],
            mean_ns: sorted.iter().sum::<u64>() as f64 / n as f64,
        }
    }
}

/// Sorted-once percentile snapshot of a [`LatencyStats`].
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct LatencySummary {
    pub n: usize,
    pub p50_ns: u64,
    pub p95_ns: u64,
    pub p99_ns: u64,
    pub max_ns: u64,
    pub mean_ns: f64,
}

impl LatencySummary {
    /// Structured `{n, p50, p95, p99, max, mean}` object — shared by the
    /// serving report and the stream report's jitter section.
    pub fn to_json(self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("n".into(), Json::Num(self.n as f64));
        m.insert("p50".into(), Json::Num(self.p50_ns as f64));
        m.insert("p95".into(), Json::Num(self.p95_ns as f64));
        m.insert("p99".into(), Json::Num(self.p99_ns as f64));
        m.insert("max".into(), Json::Num(self.max_ns as f64));
        m.insert("mean".into(), Json::Num(self.mean_ns));
        Json::Obj(m)
    }
}

/// Per-lane slice of the serving report.
#[derive(Clone, Debug)]
pub struct LaneReport {
    pub lane: usize,
    pub requests: u64,
    pub batches: u64,
    /// Ns this lane spent serving (modeled or measured per `clock`).
    pub busy_ns: u64,
    pub latency: LatencySummary,
}

/// Three-state SLO verdict: a run with zero completions has no latency
/// evidence, so it can neither meet nor miss the target.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SloStatus {
    Met,
    Missed,
    NoData,
}

impl SloStatus {
    /// The string the report's `slo.status` field carries.
    pub fn name(&self) -> &'static str {
        match self {
            SloStatus::Met => "met",
            SloStatus::Missed => "missed",
            SloStatus::NoData => "no-data",
        }
    }
}

/// Which service-cost model timed (virtual) or would predict (wall) the
/// run — echoed in the report's `calibration` section.
#[derive(Clone, Debug)]
pub enum CostModel {
    /// The built-in synthetic constants.
    Synthetic { overhead_ns: u64, cost_ns_per_pixel: u64 },
    /// A [`StageTimes`](crate::canny::StageTimes)-fitted calibration.
    Calibrated(Calibration),
}

impl CostModel {
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        match self {
            CostModel::Synthetic { overhead_ns, cost_ns_per_pixel } => {
                m.insert("source".into(), Json::Str("synthetic".into()));
                m.insert("overhead_ns".into(), Json::Num(*overhead_ns as f64));
                m.insert("cost_ns_per_pixel".into(), Json::Num(*cost_ns_per_pixel as f64));
            }
            CostModel::Calibrated(c) => {
                m.insert("source".into(), Json::Str("measured".into()));
                m.insert("engine".into(), Json::Str(c.engine.clone()));
                m.insert("workers".into(), Json::Num(c.workers as f64));
                m.insert("overhead_ns".into(), Json::Num(c.overhead_ns as f64));
                m.insert("cost_ns_per_pixel".into(), Json::Num(c.cost_ns_per_pixel));
                m.insert("probes".into(), Json::Num(c.probes.len() as f64));
                m.insert("stages".into(), Json::Num(c.stages.len() as f64));
            }
        }
        Json::Obj(m)
    }
}

/// The complete serving report — everything `cannyd serve` knows about
/// a replayed trace. Serialized via [`ServeReport::to_json_string`];
/// under the virtual clock all field values are modeled quantities, so
/// the same trace + seed produces a byte-identical report on a given
/// host. Under the wall clock the same fields carry measured values.
#[derive(Clone, Debug)]
pub struct ServeReport {
    pub label: String,
    pub seed: u64,
    /// Which clock drove the run: "virtual" or "wall".
    pub clock: String,
    /// Engine the planner chose for the lanes.
    pub engine: String,
    pub workers_per_lane: usize,
    /// True when a wall-clock run was drained early by SIGINT: arrivals
    /// after the interrupt were never offered, admitted requests were
    /// completed, and every number below describes the partial run.
    pub interrupted: bool,
    pub offered: u64,
    pub admitted: u64,
    pub rejected_full: u64,
    pub rejected_oversize: u64,
    pub completed: u64,
    pub queue_depth: usize,
    pub queue_high_water: usize,
    pub batch_window_ns: u64,
    pub max_batch: usize,
    pub batches_formed: u64,
    /// Requests that entered a formed batch — the batch-fill
    /// denominator's numerator. Stays correct even when completions lag
    /// (dropped lanes, truncated replays), unlike `completed`.
    pub requests_batched: u64,
    /// Time of the last completion (ns since serve start).
    pub makespan_ns: u64,
    /// Sum of detected edge pixels over all completed requests (0 when
    /// execution is disabled) — the proof real compute happened.
    pub edge_pixels: u64,
    /// End-to-end latency (arrival → complete), all lanes.
    pub latency: LatencySummary,
    /// Waiting-room latency (arrival → dispatch), all lanes.
    pub queue_wait: LatencySummary,
    pub lanes: Vec<LaneReport>,
    pub slo_target_p99_ns: u64,
    /// The service-cost model in effect (see [`CostModel`]).
    pub cost_model: CostModel,
    /// Completed requests per [`RequestKind`](crate::service::RequestKind)
    /// name.
    pub kinds: BTreeMap<String, u64>,
    /// Executed pipeline phases per stage-span name, summed over lanes
    /// (empty when execution is off) — the proof of which stages ran:
    /// a re-threshold serving path must grow `threshold`/`hysteresis`
    /// without growing `gaussian`/`sobel`/`nms`.
    pub stage_runs: BTreeMap<String, u64>,
    /// End-of-run snapshot of the shared artifact cache
    /// ([`crate::cache::ArtifactCache`]): config echo, hit/miss/insert
    /// counters per caller tier, byte occupancy and evictions.
    pub cache: CacheSnapshot,
}

impl ServeReport {
    /// Total rejections, all reasons.
    pub fn rejected(&self) -> u64 {
        self.rejected_full + self.rejected_oversize
    }

    /// Three-state SLO verdict on the aggregate p99. Zero completions
    /// is `NoData`, never a vacuous pass — an all-rejected run must not
    /// read as "SLO met".
    pub fn slo_status(&self) -> SloStatus {
        if self.completed == 0 {
            SloStatus::NoData
        } else if self.latency.p99_ns <= self.slo_target_p99_ns {
            SloStatus::Met
        } else {
            SloStatus::Missed
        }
    }

    /// Strictly-met convenience: true only with evidence
    /// ([`SloStatus::Met`]).
    pub fn slo_met(&self) -> bool {
        self.slo_status() == SloStatus::Met
    }

    /// Completions per second (of the active clock).
    pub fn throughput_rps(&self) -> f64 {
        if self.makespan_ns == 0 {
            return 0.0;
        }
        self.completed as f64 / (self.makespan_ns as f64 / 1e9)
    }

    /// Mean requests per formed batch (coalescing effectiveness).
    /// Counts batched requests — not completions, which undercount when
    /// admitted requests are dropped or a replay is truncated.
    pub fn mean_batch_fill(&self) -> f64 {
        if self.batches_formed == 0 {
            return 0.0;
        }
        self.requests_batched as f64 / self.batches_formed as f64
    }

    /// Structured report (object keys are sorted — deterministic dump).
    pub fn to_json(&self) -> Json {
        let num = |v: u64| Json::Num(v as f64);
        let mut m = BTreeMap::new();
        m.insert("label".into(), Json::Str(self.label.clone()));
        m.insert("seed".into(), num(self.seed));
        m.insert("clock".into(), Json::Str(self.clock.clone()));
        m.insert("engine".into(), Json::Str(self.engine.clone()));
        m.insert("workers_per_lane".into(), Json::Num(self.workers_per_lane as f64));
        m.insert("interrupted".into(), Json::Bool(self.interrupted));
        m.insert("offered".into(), num(self.offered));
        m.insert("admitted".into(), num(self.admitted));
        m.insert("rejected".into(), num(self.rejected()));
        m.insert("completed".into(), num(self.completed));
        m.insert("makespan_ns".into(), num(self.makespan_ns));
        m.insert("throughput_rps".into(), Json::Num(self.throughput_rps()));
        m.insert("edge_pixels".into(), num(self.edge_pixels));
        m.insert("calibration".into(), self.cost_model.to_json());

        let mut queue = BTreeMap::new();
        queue.insert("depth".into(), Json::Num(self.queue_depth as f64));
        queue.insert("high_water".into(), Json::Num(self.queue_high_water as f64));
        queue.insert("rejected_full".into(), num(self.rejected_full));
        queue.insert("rejected_oversize".into(), num(self.rejected_oversize));
        m.insert("queue".into(), Json::Obj(queue));

        let mut batch = BTreeMap::new();
        batch.insert("window_ns".into(), num(self.batch_window_ns));
        batch.insert("max".into(), Json::Num(self.max_batch as f64));
        batch.insert("formed".into(), num(self.batches_formed));
        batch.insert("requests".into(), num(self.requests_batched));
        batch.insert("mean_fill".into(), Json::Num(self.mean_batch_fill()));
        m.insert("batch".into(), Json::Obj(batch));

        m.insert(
            "kinds".into(),
            Json::Obj(self.kinds.iter().map(|(k, &v)| (k.clone(), num(v))).collect()),
        );
        m.insert(
            "stages".into(),
            Json::Obj(self.stage_runs.iter().map(|(k, &v)| (k.clone(), num(v))).collect()),
        );
        m.insert("cache".into(), self.cache.to_json());

        m.insert("latency_ns".into(), self.latency.to_json());
        m.insert("queue_wait_ns".into(), self.queue_wait.to_json());

        let lanes = self
            .lanes
            .iter()
            .map(|l| {
                let mut lm = BTreeMap::new();
                lm.insert("lane".into(), Json::Num(l.lane as f64));
                lm.insert("requests".into(), num(l.requests));
                lm.insert("batches".into(), num(l.batches));
                lm.insert("busy_ns".into(), num(l.busy_ns));
                lm.insert(
                    "utilization".into(),
                    Json::Num(if self.makespan_ns == 0 {
                        0.0
                    } else {
                        l.busy_ns as f64 / self.makespan_ns as f64
                    }),
                );
                lm.insert("latency_ns".into(), l.latency.to_json());
                Json::Obj(lm)
            })
            .collect();
        m.insert("lanes".into(), Json::Arr(lanes));

        let mut slo = BTreeMap::new();
        slo.insert("target_p99_ns".into(), num(self.slo_target_p99_ns));
        slo.insert("p99_ns".into(), num(self.latency.p99_ns));
        slo.insert("status".into(), Json::Str(self.slo_status().name().into()));
        m.insert("slo".into(), Json::Obj(slo));

        Json::Obj(m)
    }

    /// The JSON text `cannyd serve` prints.
    pub fn to_json_string(&self) -> String {
        self.to_json().dump()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_stats_summarize_to_zero() {
        let s = LatencyStats::new().summary();
        assert_eq!(s, LatencySummary::default());
    }

    #[test]
    fn quantiles_ordered() {
        let mut st = LatencyStats::new();
        for v in (1..=1000).rev() {
            st.record(v);
        }
        let s = st.summary();
        assert_eq!(s.n, 1000);
        assert!(s.p50_ns <= s.p95_ns && s.p95_ns <= s.p99_ns && s.p99_ns <= s.max_ns);
        assert_eq!(s.max_ns, 1000);
        assert!(s.p50_ns == 500 || s.p50_ns == 501, "p50={}", s.p50_ns);
        assert!((s.mean_ns - 500.5).abs() < 1e-9);
    }

    #[test]
    fn nearest_rank_edge_cases() {
        // n = 1: every quantile is the single sample.
        let mut one = LatencyStats::new();
        one.record(42);
        let s = one.summary();
        assert_eq!((s.n, s.p50_ns, s.p95_ns, s.p99_ns, s.max_ns), (1, 42, 42, 42, 42));
        assert!((s.mean_ns - 42.0).abs() < 1e-12);

        // n = 2: nearest-rank rounds 0.5 up, so p50 is the *larger*
        // sample (documented convention, shared with util::timer).
        let mut two = LatencyStats::new();
        two.record(10);
        two.record(30);
        let s = two.summary();
        assert_eq!(s.p50_ns, 30);
        assert_eq!(s.p95_ns, 30);
        assert_eq!(s.max_ns, 30);
        assert!((s.mean_ns - 20.0).abs() < 1e-12);

        // All-equal samples: every quantile collapses to that value.
        let mut eq = LatencyStats::new();
        for _ in 0..17 {
            eq.record(7);
        }
        let s = eq.summary();
        assert_eq!((s.p50_ns, s.p95_ns, s.p99_ns, s.max_ns), (7, 7, 7, 7));
        assert!((s.mean_ns - 7.0).abs() < 1e-12);
    }

    #[test]
    fn merge_concatenates_samples() {
        let mut a = LatencyStats::new();
        a.record(1);
        a.record(9);
        let mut b = LatencyStats::new();
        b.record(5);
        a.merge(&b);
        assert_eq!(a.count(), 3);
        assert_eq!(a.summary().p50_ns, 5);
    }

    fn report() -> ServeReport {
        ServeReport {
            label: "t".into(),
            seed: 7,
            clock: "virtual".into(),
            engine: "patterns".into(),
            workers_per_lane: 2,
            interrupted: false,
            offered: 10,
            admitted: 8,
            rejected_full: 2,
            rejected_oversize: 0,
            completed: 8,
            queue_depth: 4,
            queue_high_water: 4,
            batch_window_ns: 2_000_000,
            max_batch: 4,
            batches_formed: 2,
            requests_batched: 8,
            makespan_ns: 1_000_000_000,
            edge_pixels: 1234,
            latency: LatencySummary { n: 8, p99_ns: 5_000_000, ..Default::default() },
            queue_wait: LatencySummary::default(),
            lanes: vec![LaneReport {
                lane: 0,
                requests: 8,
                batches: 2,
                busy_ns: 500_000_000,
                latency: LatencySummary::default(),
            }],
            slo_target_p99_ns: 50_000_000,
            cost_model: CostModel::Synthetic { overhead_ns: 100_000, cost_ns_per_pixel: 4 },
            kinds: [("full".to_string(), 8u64)].into_iter().collect(),
            stage_runs: BTreeMap::new(),
            cache: crate::cache::ArtifactCache::disabled().snapshot(),
        }
    }

    #[test]
    fn report_math() {
        let r = report();
        assert_eq!(r.rejected(), 2);
        assert_eq!(r.slo_status(), SloStatus::Met);
        assert!(r.slo_met());
        assert!((r.throughput_rps() - 8.0).abs() < 1e-9);
        assert!((r.mean_batch_fill() - 4.0).abs() < 1e-9);
    }

    #[test]
    fn batch_fill_counts_batched_requests_not_completions() {
        // Regression: 8 requests entered batches but only 5 completed
        // (e.g. a truncated replay). Fill must stay 8/2, not 5/2.
        let mut r = report();
        r.completed = 5;
        assert!((r.mean_batch_fill() - 4.0).abs() < 1e-9);
        let j = r.to_json();
        assert_eq!(j.get("batch").unwrap().get("requests").unwrap().as_usize(), Some(8));
    }

    #[test]
    fn report_json_has_required_fields() {
        let j = report().to_json();
        assert_eq!(j.get("interrupted"), Some(&Json::Bool(false)));
        assert_eq!(j.get("kinds").unwrap().get("full").unwrap().as_usize(), Some(8));
        let cache = j.get("cache").unwrap();
        assert_eq!(cache.get("enabled"), Some(&Json::Bool(false)));
        assert_eq!(cache.get("hits").unwrap().as_usize(), Some(0));
        assert!(cache.get("tiers").unwrap().get("serve").is_some());
        assert!(cache.get("tiers").unwrap().get("stream").is_some());
        assert!(j.get("stages").unwrap().as_obj().unwrap().is_empty());
        assert_eq!(j.get("queue").unwrap().get("high_water").unwrap().as_usize(), Some(4));
        assert_eq!(j.get("batch").unwrap().get("formed").unwrap().as_usize(), Some(2));
        assert_eq!(j.get("rejected").unwrap().as_usize(), Some(2));
        assert_eq!(j.get("clock").unwrap().as_str(), Some("virtual"));
        let calib = j.get("calibration").unwrap();
        assert_eq!(calib.get("source").unwrap().as_str(), Some("synthetic"));
        assert_eq!(calib.get("overhead_ns").unwrap().as_usize(), Some(100_000));
        let lanes = j.get("lanes").unwrap().as_arr().unwrap();
        assert!(lanes[0].get("latency_ns").unwrap().get("p99").is_some());
        assert_eq!(j.get("slo").unwrap().get("status").unwrap().as_str(), Some("met"));
        // The dump round-trips through the parser.
        let text = report().to_json_string();
        assert_eq!(Json::parse(&text).unwrap(), j);
    }

    #[test]
    fn calibrated_cost_model_serializes_provenance() {
        let mut r = report();
        r.cost_model = CostModel::Calibrated(Calibration {
            engine: "tiled".into(),
            workers: 4,
            overhead_ns: 88_000,
            cost_ns_per_pixel: 3.25,
            stages: Vec::new(),
            probes: Vec::new(),
        });
        let c = r.to_json();
        let calib = c.get("calibration").unwrap();
        assert_eq!(calib.get("source").unwrap().as_str(), Some("measured"));
        assert_eq!(calib.get("engine").unwrap().as_str(), Some("tiled"));
        assert_eq!(calib.get("probes").unwrap().as_usize(), Some(0));
    }

    #[test]
    fn slo_three_states() {
        let mut r = report();
        r.slo_target_p99_ns = 1;
        assert_eq!(r.slo_status(), SloStatus::Missed);
        assert!(!r.slo_met());
        assert!(r.to_json_string().contains("\"status\":\"missed\""));
        // Zero completions: no-data, not a vacuous pass.
        r.completed = 0;
        assert_eq!(r.slo_status(), SloStatus::NoData);
        assert!(!r.slo_met());
        assert!(r.to_json_string().contains("\"status\":\"no-data\""));
        assert_eq!(SloStatus::NoData.name(), "no-data");
    }
}
