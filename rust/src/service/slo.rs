//! SLO accounting for the serving tier: per-request latency samples
//! (enqueue→dispatch→complete) rolled into p50/p95/p99 summaries per
//! lane and in aggregate, and the deterministic JSON serving report
//! `cannyd serve` prints. The same schema serves both clocks — the
//! `clock` field says whether the numbers are modeled or measured, and
//! the `calibration` section says which cost model produced (or would
//! predict) them.
//!
//! End-of-run quantiles answer "did the run meet its SLO"; the
//! **rolling window** ([`SloWindow`]) answers "is it meeting it *right
//! now*": a ring of the most recent completions, re-evaluated on every
//! record into a windowed p50/p95/p99 and a
//! `met | missed | no-data` status timeline. The ops plane
//! ([`crate::obs`]) reads the window live — each telemetry tick carries
//! its JSON, and the fault manager sheds new arrivals while it reports
//! `missed` — and the final report carries it as `slo.window`.

use std::collections::{BTreeMap, VecDeque};

use crate::cache::CacheSnapshot;
use crate::service::calibrate::Calibration;
use crate::util::json::Json;

/// Latency sample sink (ns, in the active clock). Order-insensitive:
/// summaries sort.
#[derive(Clone, Debug, Default)]
pub struct LatencyStats {
    samples: Vec<u64>,
}

impl LatencyStats {
    pub fn new() -> LatencyStats {
        LatencyStats::default()
    }

    pub fn record(&mut self, ns: u64) {
        self.samples.push(ns);
    }

    /// Fold another sink's samples into this one (lane → aggregate).
    pub fn merge(&mut self, other: &LatencyStats) {
        self.samples.extend_from_slice(&other.samples);
    }

    pub fn count(&self) -> usize {
        self.samples.len()
    }

    /// Nearest-rank summary (same quantile convention as
    /// [`crate::util::timer::Summary`]). Empty stats summarize to zeros.
    pub fn summary(&self) -> LatencySummary {
        if self.samples.is_empty() {
            return LatencySummary::default();
        }
        let mut sorted = self.samples.clone();
        sorted.sort_unstable();
        let n = sorted.len();
        let q = |p: f64| sorted[((n - 1) as f64 * p).round() as usize];
        LatencySummary {
            n,
            p50_ns: q(0.50),
            p95_ns: q(0.95),
            p99_ns: q(0.99),
            max_ns: sorted[n - 1],
            mean_ns: sorted.iter().sum::<u64>() as f64 / n as f64,
        }
    }
}

/// Sorted-once percentile snapshot of a [`LatencyStats`].
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct LatencySummary {
    pub n: usize,
    pub p50_ns: u64,
    pub p95_ns: u64,
    pub p99_ns: u64,
    pub max_ns: u64,
    pub mean_ns: f64,
}

impl LatencySummary {
    /// Structured `{n, p50, p95, p99, max, mean}` object — shared by the
    /// serving report and the stream report's jitter section.
    pub fn to_json(self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("n".into(), Json::Num(self.n as f64));
        m.insert("p50".into(), Json::Num(self.p50_ns as f64));
        m.insert("p95".into(), Json::Num(self.p95_ns as f64));
        m.insert("p99".into(), Json::Num(self.p99_ns as f64));
        m.insert("max".into(), Json::Num(self.max_ns as f64));
        m.insert("mean".into(), Json::Num(self.mean_ns));
        Json::Obj(m)
    }
}

/// Per-lane slice of the serving report.
#[derive(Clone, Debug)]
pub struct LaneReport {
    pub lane: usize,
    pub requests: u64,
    pub batches: u64,
    /// Ns this lane spent serving (modeled or measured per `clock`).
    pub busy_ns: u64,
    pub latency: LatencySummary,
}

/// Three-state SLO verdict: a run with zero completions has no latency
/// evidence, so it can neither meet nor miss the target.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SloStatus {
    Met,
    Missed,
    NoData,
}

impl SloStatus {
    /// The string the report's `slo.status` field carries.
    pub fn name(&self) -> &'static str {
        match self {
            SloStatus::Met => "met",
            SloStatus::Missed => "missed",
            SloStatus::NoData => "no-data",
        }
    }
}

/// Default rolling-window capacity (`--slo-window`).
pub const DEFAULT_SLO_WINDOW: usize = 64;

/// Cap on the recorded status timeline: a pathological run flapping
/// met↔missed every completion must not grow the report without bound.
/// Transitions past the cap are counted in `transitions_truncated`.
pub const MAX_TRANSITIONS: usize = 256;

/// Rolling-window SLO evaluation: a ring of the most recent completion
/// latencies, re-evaluated on every [`SloWindow::record`] into exact
/// nearest-rank windowed quantiles and a three-state status. Status
/// *changes* are appended to a timeline stamped with the completion
/// time that caused them — under the virtual clock these are modeled
/// times, so the timeline is deterministic across replays.
#[derive(Clone, Debug)]
pub struct SloWindow {
    target_p99_ns: u64,
    capacity: usize,
    ring: VecDeque<u64>,
    status: SloStatus,
    transitions: Vec<(u64, SloStatus)>,
    truncated: u64,
}

impl SloWindow {
    /// `target_p99_ns == 0` means "no target": the window still tracks
    /// quantiles but the status stays `no-data` (the stream tier with
    /// no frame budget). Capacity is clamped to at least 1.
    pub fn new(target_p99_ns: u64, capacity: usize) -> SloWindow {
        SloWindow {
            target_p99_ns,
            capacity: capacity.max(1),
            ring: VecDeque::new(),
            status: SloStatus::NoData,
            transitions: Vec::new(),
            truncated: 0,
        }
    }

    pub fn target_p99_ns(&self) -> u64 {
        self.target_p99_ns
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Completions currently in the window.
    pub fn len(&self) -> usize {
        self.ring.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ring.is_empty()
    }

    /// Fold one completion (at time `t_ns`, with end-to-end latency
    /// `latency_ns`) into the window and re-evaluate the status.
    pub fn record(&mut self, t_ns: u64, latency_ns: u64) {
        if self.ring.len() == self.capacity {
            self.ring.pop_front();
        }
        self.ring.push_back(latency_ns);
        let next = if self.target_p99_ns == 0 {
            SloStatus::NoData
        } else if self.summary().p99_ns <= self.target_p99_ns {
            SloStatus::Met
        } else {
            SloStatus::Missed
        };
        if next != self.status {
            if self.transitions.len() < MAX_TRANSITIONS {
                self.transitions.push((t_ns, next));
            } else {
                self.truncated += 1;
            }
            self.status = next;
        }
    }

    pub fn status(&self) -> SloStatus {
        self.status
    }

    /// Is the rolling SLO currently missed? (The fault manager's shed
    /// signal.)
    pub fn missed(&self) -> bool {
        self.status == SloStatus::Missed
    }

    /// Exact nearest-rank quantiles over the current window contents
    /// (the same convention as [`LatencyStats::summary`]).
    pub fn summary(&self) -> LatencySummary {
        let mut stats = LatencyStats::new();
        for &ns in &self.ring {
            stats.record(ns);
        }
        stats.summary()
    }

    pub fn transitions(&self) -> &[(u64, SloStatus)] {
        &self.transitions
    }

    /// Freeze the window into its report form.
    pub fn report(&self) -> WindowReport {
        WindowReport {
            capacity: self.capacity,
            target_p99_ns: self.target_p99_ns,
            summary: self.summary(),
            status: self.status,
            transitions: self.transitions.clone(),
            transitions_truncated: self.truncated,
        }
    }

    /// The `slo` telemetry-tick section / the report's `slo.window`.
    pub fn to_json(&self) -> Json {
        self.report().to_json()
    }
}

/// A frozen [`SloWindow`]: what the final report's `slo.window` section
/// and each telemetry tick's `slo` section carry.
#[derive(Clone, Debug)]
pub struct WindowReport {
    pub capacity: usize,
    pub target_p99_ns: u64,
    /// Exact quantiles over the window contents at freeze time.
    pub summary: LatencySummary,
    pub status: SloStatus,
    /// `(t_ns, status)` timeline of status *changes*, capped at
    /// [`MAX_TRANSITIONS`].
    pub transitions: Vec<(u64, SloStatus)>,
    pub transitions_truncated: u64,
}

impl WindowReport {
    /// The no-completions window (reports built without a live window).
    pub fn empty(target_p99_ns: u64, capacity: usize) -> WindowReport {
        SloWindow::new(target_p99_ns, capacity).report()
    }

    pub fn to_json(&self) -> Json {
        let num = |v: u64| Json::Num(v as f64);
        let mut m = BTreeMap::new();
        m.insert("window".into(), Json::Num(self.capacity as f64));
        m.insert("target_p99_ns".into(), num(self.target_p99_ns));
        m.insert("n".into(), Json::Num(self.summary.n as f64));
        m.insert("p50_ns".into(), num(self.summary.p50_ns));
        m.insert("p95_ns".into(), num(self.summary.p95_ns));
        m.insert("p99_ns".into(), num(self.summary.p99_ns));
        m.insert("status".into(), Json::Str(self.status.name().into()));
        m.insert(
            "transitions".into(),
            Json::Arr(
                self.transitions
                    .iter()
                    .map(|(t, s)| {
                        let mut tm = BTreeMap::new();
                        tm.insert("status".into(), Json::Str(s.name().into()));
                        tm.insert("t_ns".into(), num(*t));
                        Json::Obj(tm)
                    })
                    .collect(),
            ),
        );
        m.insert("transitions_truncated".into(), num(self.transitions_truncated));
        Json::Obj(m)
    }
}

/// Which service-cost model timed (virtual) or would predict (wall) the
/// run — echoed in the report's `calibration` section.
#[derive(Clone, Debug)]
pub enum CostModel {
    /// The built-in synthetic constants.
    Synthetic { overhead_ns: u64, cost_ns_per_pixel: u64 },
    /// A [`StageTimes`](crate::canny::StageTimes)-fitted calibration.
    Calibrated(Calibration),
}

impl CostModel {
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        match self {
            CostModel::Synthetic { overhead_ns, cost_ns_per_pixel } => {
                m.insert("source".into(), Json::Str("synthetic".into()));
                m.insert("overhead_ns".into(), Json::Num(*overhead_ns as f64));
                m.insert("cost_ns_per_pixel".into(), Json::Num(*cost_ns_per_pixel as f64));
            }
            CostModel::Calibrated(c) => {
                m.insert("source".into(), Json::Str("measured".into()));
                m.insert("engine".into(), Json::Str(c.engine.clone()));
                m.insert("workers".into(), Json::Num(c.workers as f64));
                m.insert("overhead_ns".into(), Json::Num(c.overhead_ns as f64));
                m.insert("cost_ns_per_pixel".into(), Json::Num(c.cost_ns_per_pixel));
                m.insert("probes".into(), Json::Num(c.probes.len() as f64));
                m.insert("stages".into(), Json::Num(c.stages.len() as f64));
            }
        }
        Json::Obj(m)
    }
}

/// The complete serving report — everything `cannyd serve` knows about
/// a replayed trace. Serialized via [`ServeReport::to_json_string`];
/// under the virtual clock all field values are modeled quantities, so
/// the same trace + seed produces a byte-identical report on a given
/// host. Under the wall clock the same fields carry measured values.
#[derive(Clone, Debug)]
pub struct ServeReport {
    pub label: String,
    pub seed: u64,
    /// Which clock drove the run: "virtual" or "wall".
    pub clock: String,
    /// Engine the planner chose for the lanes.
    pub engine: String,
    pub workers_per_lane: usize,
    /// True when a wall-clock run was drained early by SIGINT: arrivals
    /// after the interrupt were never offered, admitted requests were
    /// completed, and every number below describes the partial run.
    pub interrupted: bool,
    pub offered: u64,
    pub admitted: u64,
    pub rejected_full: u64,
    pub rejected_oversize: u64,
    /// Arrivals turned away by the overload policy (`reject-new` while
    /// the rolling SLO was missed). Part of [`ServeReport::rejected`]:
    /// conservation (`offered == completed + rejected`) still holds.
    pub rejected_shed: u64,
    /// `full` arrivals rewritten to `front-only` by the
    /// `degrade-to-front-only` policy (these complete, in degraded
    /// form).
    pub shed_degraded: u64,
    /// The overload policy in effect ([`crate::obs::OverloadPolicy`]
    /// name).
    pub overload_policy: String,
    pub completed: u64,
    pub queue_depth: usize,
    pub queue_high_water: usize,
    pub batch_window_ns: u64,
    pub max_batch: usize,
    pub batches_formed: u64,
    /// Requests that entered a formed batch — the batch-fill
    /// denominator's numerator. Stays correct even when completions lag
    /// (dropped lanes, truncated replays), unlike `completed`.
    pub requests_batched: u64,
    /// Time of the last completion (ns since serve start).
    pub makespan_ns: u64,
    /// Sum of detected edge pixels over all completed requests (0 when
    /// execution is disabled) — the proof real compute happened.
    pub edge_pixels: u64,
    /// End-to-end latency (arrival → complete), all lanes.
    pub latency: LatencySummary,
    /// Waiting-room latency (arrival → dispatch), all lanes.
    pub queue_wait: LatencySummary,
    pub lanes: Vec<LaneReport>,
    pub slo_target_p99_ns: u64,
    /// The rolling SLO window frozen at run end: windowed quantiles,
    /// live status, and the met/missed/no-data transition timeline.
    pub slo_window: WindowReport,
    /// The service-cost model in effect (see [`CostModel`]).
    pub cost_model: CostModel,
    /// Completed requests per [`RequestKind`](crate::service::RequestKind)
    /// name.
    pub kinds: BTreeMap<String, u64>,
    /// Executed pipeline phases per stage-span name, summed over lanes
    /// (empty when execution is off) — the proof of which stages ran:
    /// a re-threshold serving path must grow `threshold`/`hysteresis`
    /// without growing `gaussian`/`sobel`/`nms`.
    pub stage_runs: BTreeMap<String, u64>,
    /// End-of-run snapshot of the shared artifact cache
    /// ([`crate::cache::ArtifactCache`]): config echo, hit/miss/insert
    /// counters per caller tier, byte occupancy and evictions.
    pub cache: CacheSnapshot,
}

impl ServeReport {
    /// Total rejections, all reasons (queue-full, oversize, shed).
    pub fn rejected(&self) -> u64 {
        self.rejected_full + self.rejected_oversize + self.rejected_shed
    }

    /// Three-state SLO verdict on the aggregate p99. Zero completions
    /// is `NoData`, never a vacuous pass — an all-rejected run must not
    /// read as "SLO met".
    pub fn slo_status(&self) -> SloStatus {
        if self.completed == 0 {
            SloStatus::NoData
        } else if self.latency.p99_ns <= self.slo_target_p99_ns {
            SloStatus::Met
        } else {
            SloStatus::Missed
        }
    }

    /// Strictly-met convenience: true only with evidence
    /// ([`SloStatus::Met`]).
    pub fn slo_met(&self) -> bool {
        self.slo_status() == SloStatus::Met
    }

    /// Completions per second (of the active clock).
    pub fn throughput_rps(&self) -> f64 {
        if self.makespan_ns == 0 {
            return 0.0;
        }
        self.completed as f64 / (self.makespan_ns as f64 / 1e9)
    }

    /// Mean requests per formed batch (coalescing effectiveness).
    /// Counts batched requests — not completions, which undercount when
    /// admitted requests are dropped or a replay is truncated.
    pub fn mean_batch_fill(&self) -> f64 {
        if self.batches_formed == 0 {
            return 0.0;
        }
        self.requests_batched as f64 / self.batches_formed as f64
    }

    /// Structured report (object keys are sorted — deterministic dump).
    pub fn to_json(&self) -> Json {
        let num = |v: u64| Json::Num(v as f64);
        let mut m = BTreeMap::new();
        m.insert("label".into(), Json::Str(self.label.clone()));
        m.insert("seed".into(), num(self.seed));
        m.insert("clock".into(), Json::Str(self.clock.clone()));
        m.insert("engine".into(), Json::Str(self.engine.clone()));
        m.insert("workers_per_lane".into(), Json::Num(self.workers_per_lane as f64));
        m.insert("interrupted".into(), Json::Bool(self.interrupted));
        m.insert("offered".into(), num(self.offered));
        m.insert("admitted".into(), num(self.admitted));
        m.insert("rejected".into(), num(self.rejected()));
        m.insert("completed".into(), num(self.completed));
        m.insert("makespan_ns".into(), num(self.makespan_ns));
        m.insert("throughput_rps".into(), Json::Num(self.throughput_rps()));
        m.insert("edge_pixels".into(), num(self.edge_pixels));
        m.insert("calibration".into(), self.cost_model.to_json());

        let mut queue = BTreeMap::new();
        queue.insert("depth".into(), Json::Num(self.queue_depth as f64));
        queue.insert("high_water".into(), Json::Num(self.queue_high_water as f64));
        queue.insert("rejected_full".into(), num(self.rejected_full));
        queue.insert("rejected_oversize".into(), num(self.rejected_oversize));
        queue.insert("rejected_shed".into(), num(self.rejected_shed));
        m.insert("queue".into(), Json::Obj(queue));

        let mut overload = BTreeMap::new();
        overload.insert("policy".into(), Json::Str(self.overload_policy.clone()));
        overload.insert("shed_degraded".into(), num(self.shed_degraded));
        overload.insert("shed_rejected".into(), num(self.rejected_shed));
        m.insert("overload".into(), Json::Obj(overload));

        let mut batch = BTreeMap::new();
        batch.insert("window_ns".into(), num(self.batch_window_ns));
        batch.insert("max".into(), Json::Num(self.max_batch as f64));
        batch.insert("formed".into(), num(self.batches_formed));
        batch.insert("requests".into(), num(self.requests_batched));
        batch.insert("mean_fill".into(), Json::Num(self.mean_batch_fill()));
        m.insert("batch".into(), Json::Obj(batch));

        m.insert(
            "kinds".into(),
            Json::Obj(self.kinds.iter().map(|(k, &v)| (k.clone(), num(v))).collect()),
        );
        m.insert(
            "stages".into(),
            Json::Obj(self.stage_runs.iter().map(|(k, &v)| (k.clone(), num(v))).collect()),
        );
        m.insert("cache".into(), self.cache.to_json());

        m.insert("latency_ns".into(), self.latency.to_json());
        m.insert("queue_wait_ns".into(), self.queue_wait.to_json());

        let lanes = self
            .lanes
            .iter()
            .map(|l| {
                let mut lm = BTreeMap::new();
                lm.insert("lane".into(), Json::Num(l.lane as f64));
                lm.insert("requests".into(), num(l.requests));
                lm.insert("batches".into(), num(l.batches));
                lm.insert("busy_ns".into(), num(l.busy_ns));
                lm.insert(
                    "utilization".into(),
                    Json::Num(if self.makespan_ns == 0 {
                        0.0
                    } else {
                        l.busy_ns as f64 / self.makespan_ns as f64
                    }),
                );
                lm.insert("latency_ns".into(), l.latency.to_json());
                Json::Obj(lm)
            })
            .collect();
        m.insert("lanes".into(), Json::Arr(lanes));

        let mut slo = BTreeMap::new();
        slo.insert("target_p99_ns".into(), num(self.slo_target_p99_ns));
        slo.insert("p99_ns".into(), num(self.latency.p99_ns));
        slo.insert("status".into(), Json::Str(self.slo_status().name().into()));
        slo.insert("window".into(), self.slo_window.to_json());
        m.insert("slo".into(), Json::Obj(slo));

        Json::Obj(m)
    }

    /// The JSON text `cannyd serve` prints.
    pub fn to_json_string(&self) -> String {
        self.to_json().dump()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_stats_summarize_to_zero() {
        let s = LatencyStats::new().summary();
        assert_eq!(s, LatencySummary::default());
    }

    #[test]
    fn quantiles_ordered() {
        let mut st = LatencyStats::new();
        for v in (1..=1000).rev() {
            st.record(v);
        }
        let s = st.summary();
        assert_eq!(s.n, 1000);
        assert!(s.p50_ns <= s.p95_ns && s.p95_ns <= s.p99_ns && s.p99_ns <= s.max_ns);
        assert_eq!(s.max_ns, 1000);
        assert!(s.p50_ns == 500 || s.p50_ns == 501, "p50={}", s.p50_ns);
        assert!((s.mean_ns - 500.5).abs() < 1e-9);
    }

    #[test]
    fn nearest_rank_edge_cases() {
        // n = 1: every quantile is the single sample.
        let mut one = LatencyStats::new();
        one.record(42);
        let s = one.summary();
        assert_eq!((s.n, s.p50_ns, s.p95_ns, s.p99_ns, s.max_ns), (1, 42, 42, 42, 42));
        assert!((s.mean_ns - 42.0).abs() < 1e-12);

        // n = 2: nearest-rank rounds 0.5 up, so p50 is the *larger*
        // sample (documented convention, shared with util::timer).
        let mut two = LatencyStats::new();
        two.record(10);
        two.record(30);
        let s = two.summary();
        assert_eq!(s.p50_ns, 30);
        assert_eq!(s.p95_ns, 30);
        assert_eq!(s.max_ns, 30);
        assert!((s.mean_ns - 20.0).abs() < 1e-12);

        // All-equal samples: every quantile collapses to that value.
        let mut eq = LatencyStats::new();
        for _ in 0..17 {
            eq.record(7);
        }
        let s = eq.summary();
        assert_eq!((s.p50_ns, s.p95_ns, s.p99_ns, s.max_ns), (7, 7, 7, 7));
        assert!((s.mean_ns - 7.0).abs() < 1e-12);
    }

    #[test]
    fn merge_concatenates_samples() {
        let mut a = LatencyStats::new();
        a.record(1);
        a.record(9);
        let mut b = LatencyStats::new();
        b.record(5);
        a.merge(&b);
        assert_eq!(a.count(), 3);
        assert_eq!(a.summary().p50_ns, 5);
    }

    fn report() -> ServeReport {
        ServeReport {
            label: "t".into(),
            seed: 7,
            clock: "virtual".into(),
            engine: "patterns".into(),
            workers_per_lane: 2,
            interrupted: false,
            offered: 10,
            admitted: 8,
            rejected_full: 2,
            rejected_oversize: 0,
            rejected_shed: 0,
            shed_degraded: 0,
            overload_policy: "none".into(),
            completed: 8,
            queue_depth: 4,
            queue_high_water: 4,
            batch_window_ns: 2_000_000,
            max_batch: 4,
            batches_formed: 2,
            requests_batched: 8,
            makespan_ns: 1_000_000_000,
            edge_pixels: 1234,
            latency: LatencySummary { n: 8, p99_ns: 5_000_000, ..Default::default() },
            queue_wait: LatencySummary::default(),
            lanes: vec![LaneReport {
                lane: 0,
                requests: 8,
                batches: 2,
                busy_ns: 500_000_000,
                latency: LatencySummary::default(),
            }],
            slo_target_p99_ns: 50_000_000,
            slo_window: WindowReport::empty(50_000_000, DEFAULT_SLO_WINDOW),
            cost_model: CostModel::Synthetic { overhead_ns: 100_000, cost_ns_per_pixel: 4 },
            kinds: [("full".to_string(), 8u64)].into_iter().collect(),
            stage_runs: BTreeMap::new(),
            cache: crate::cache::ArtifactCache::disabled().snapshot(),
        }
    }

    #[test]
    fn report_math() {
        let r = report();
        assert_eq!(r.rejected(), 2);
        assert_eq!(r.slo_status(), SloStatus::Met);
        assert!(r.slo_met());
        assert!((r.throughput_rps() - 8.0).abs() < 1e-9);
        assert!((r.mean_batch_fill() - 4.0).abs() < 1e-9);
    }

    #[test]
    fn batch_fill_counts_batched_requests_not_completions() {
        // Regression: 8 requests entered batches but only 5 completed
        // (e.g. a truncated replay). Fill must stay 8/2, not 5/2.
        let mut r = report();
        r.completed = 5;
        assert!((r.mean_batch_fill() - 4.0).abs() < 1e-9);
        let j = r.to_json();
        assert_eq!(j.get("batch").unwrap().get("requests").unwrap().as_usize(), Some(8));
    }

    #[test]
    fn report_json_has_required_fields() {
        let j = report().to_json();
        assert_eq!(j.get("interrupted"), Some(&Json::Bool(false)));
        assert_eq!(j.get("kinds").unwrap().get("full").unwrap().as_usize(), Some(8));
        let cache = j.get("cache").unwrap();
        assert_eq!(cache.get("enabled"), Some(&Json::Bool(false)));
        assert_eq!(cache.get("hits").unwrap().as_usize(), Some(0));
        assert!(cache.get("tiers").unwrap().get("serve").is_some());
        assert!(cache.get("tiers").unwrap().get("stream").is_some());
        assert!(j.get("stages").unwrap().as_obj().unwrap().is_empty());
        assert_eq!(j.get("queue").unwrap().get("high_water").unwrap().as_usize(), Some(4));
        assert_eq!(j.get("batch").unwrap().get("formed").unwrap().as_usize(), Some(2));
        assert_eq!(j.get("rejected").unwrap().as_usize(), Some(2));
        assert_eq!(j.get("clock").unwrap().as_str(), Some("virtual"));
        let calib = j.get("calibration").unwrap();
        assert_eq!(calib.get("source").unwrap().as_str(), Some("synthetic"));
        assert_eq!(calib.get("overhead_ns").unwrap().as_usize(), Some(100_000));
        let lanes = j.get("lanes").unwrap().as_arr().unwrap();
        assert!(lanes[0].get("latency_ns").unwrap().get("p99").is_some());
        assert_eq!(j.get("slo").unwrap().get("status").unwrap().as_str(), Some("met"));
        let window = j.get("slo").unwrap().get("window").unwrap();
        assert_eq!(window.get("status").unwrap().as_str(), Some("no-data"));
        assert_eq!(window.get("window").unwrap().as_usize(), Some(DEFAULT_SLO_WINDOW));
        assert_eq!(window.get("transitions").unwrap().as_arr().unwrap().len(), 0);
        let overload = j.get("overload").unwrap();
        assert_eq!(overload.get("policy").unwrap().as_str(), Some("none"));
        assert_eq!(overload.get("shed_rejected").unwrap().as_usize(), Some(0));
        assert_eq!(j.get("queue").unwrap().get("rejected_shed").unwrap().as_usize(), Some(0));
        // The dump round-trips through the parser.
        let text = report().to_json_string();
        assert_eq!(Json::parse(&text).unwrap(), j);
    }

    #[test]
    fn shed_rejections_count_toward_conservation() {
        let mut r = report();
        r.rejected_shed = 3;
        r.offered = 13;
        assert_eq!(r.rejected(), 5);
        assert_eq!(r.offered, r.completed + r.rejected());
        let j = r.to_json();
        assert_eq!(j.get("rejected").unwrap().as_usize(), Some(5));
        assert_eq!(j.get("overload").unwrap().get("shed_rejected").unwrap().as_usize(), Some(3));
    }

    #[test]
    fn window_transitions_met_missed_met() {
        // Capacity 4, target 100ns: a latency step up then back down
        // must walk the status met -> missed -> met with timestamps.
        let mut w = SloWindow::new(100, 4);
        assert_eq!(w.status(), SloStatus::NoData);
        w.record(10, 50);
        w.record(20, 60);
        assert_eq!(w.status(), SloStatus::Met);
        // Step: slow completions flood the window.
        w.record(30, 500);
        assert_eq!(w.status(), SloStatus::Missed);
        assert!(w.missed());
        w.record(40, 600);
        // Recovery: fast completions push the slow ones out of the ring.
        for t in [50, 60, 70, 80] {
            w.record(t, 40);
        }
        assert_eq!(w.status(), SloStatus::Met);
        let transitions: Vec<_> = w.transitions().to_vec();
        assert_eq!(
            transitions,
            vec![(10, SloStatus::Met), (30, SloStatus::Missed), (80, SloStatus::Met)]
        );
        let j = w.to_json();
        assert_eq!(j.get("status").unwrap().as_str(), Some("met"));
        let ts = j.get("transitions").unwrap().as_arr().unwrap();
        assert_eq!(ts.len(), 3);
        assert_eq!(ts[1].get("status").unwrap().as_str(), Some("missed"));
        assert_eq!(ts[1].get("t_ns").unwrap().as_usize(), Some(30));
    }

    #[test]
    fn window_nearest_rank_edges() {
        // n = 1: the single sample is every quantile; it alone decides.
        let mut w = SloWindow::new(100, 8);
        w.record(1, 101);
        assert_eq!(w.status(), SloStatus::Missed);
        assert_eq!(w.summary().p99_ns, 101);
        assert_eq!(w.summary().n, 1);

        // Window smaller than the completion stream: only the last
        // `capacity` samples count. 10 slow then 2 fast with capacity
        // 2 -> the slow ones are gone.
        let mut w = SloWindow::new(100, 2);
        for t in 0..10 {
            w.record(t, 1_000);
        }
        assert_eq!(w.len(), 2);
        assert_eq!(w.status(), SloStatus::Missed);
        w.record(10, 10);
        w.record(11, 20);
        assert_eq!(w.summary().max_ns, 20);
        assert_eq!(w.status(), SloStatus::Met);

        // Capacity clamps to 1; exactly-at-target is met (<=).
        let mut w = SloWindow::new(100, 0);
        assert_eq!(w.capacity(), 1);
        w.record(1, 100);
        assert_eq!(w.status(), SloStatus::Met);

        // Zero target: quantiles tracked, status pinned to no-data.
        let mut w = SloWindow::new(0, 4);
        w.record(1, 42);
        assert_eq!(w.status(), SloStatus::NoData);
        assert!(w.transitions().is_empty());
        assert_eq!(w.summary().p50_ns, 42);
    }

    #[test]
    fn window_transition_timeline_truncates() {
        // Alternate fast/slow with capacity 1 so every completion flips
        // the status: the timeline must cap at MAX_TRANSITIONS and
        // count the overflow instead of growing without bound.
        let mut w = SloWindow::new(100, 1);
        for t in 0..(MAX_TRANSITIONS as u64 + 50) {
            w.record(t, if t % 2 == 0 { 10 } else { 1_000 });
        }
        assert_eq!(w.transitions().len(), MAX_TRANSITIONS);
        let r = w.report();
        assert_eq!(r.transitions_truncated, 50);
        assert_eq!(
            r.to_json().get("transitions_truncated").unwrap().as_usize(),
            Some(50)
        );
    }

    #[test]
    fn calibrated_cost_model_serializes_provenance() {
        let mut r = report();
        r.cost_model = CostModel::Calibrated(Calibration {
            engine: "tiled".into(),
            workers: 4,
            overhead_ns: 88_000,
            cost_ns_per_pixel: 3.25,
            stages: Vec::new(),
            probes: Vec::new(),
        });
        let c = r.to_json();
        let calib = c.get("calibration").unwrap();
        assert_eq!(calib.get("source").unwrap().as_str(), Some("measured"));
        assert_eq!(calib.get("engine").unwrap().as_str(), Some("tiled"));
        assert_eq!(calib.get("probes").unwrap().as_usize(), Some(0));
    }

    #[test]
    fn slo_three_states() {
        let mut r = report();
        r.slo_target_p99_ns = 1;
        assert_eq!(r.slo_status(), SloStatus::Missed);
        assert!(!r.slo_met());
        assert!(r.to_json_string().contains("\"status\":\"missed\""));
        // Zero completions: no-data, not a vacuous pass.
        r.completed = 0;
        assert_eq!(r.slo_status(), SloStatus::NoData);
        assert!(!r.slo_met());
        assert!(r.to_json_string().contains("\"status\":\"no-data\""));
        assert_eq!(SloStatus::NoData.name(), "no-data");
    }
}
