//! Same-shape request coalescing under a max-delay window.
//!
//! Batching trades a bounded amount of latency for throughput: each
//! dispatch pays a fixed overhead (scheduling, lane wake-up, and — for
//! XLA-backed lanes — executable invocation), so carrying several
//! same-shape requests per dispatch amortizes it. A batch closes when
//! it reaches `max_batch` requests, or when the *oldest* request in it
//! has waited `window_ns` — the max-delay guarantee that keeps the
//! latency cost bounded.

use std::collections::BTreeMap;

use crate::service::request::{Request, RequestKind, Shape};

/// A closed batch ready for dispatch; all requests share one shape and
/// one [`RequestKind`] discriminant (their stage sets — and service
/// costs — must match; re-threshold thresholds may vary per request).
#[derive(Clone, Debug)]
pub struct FormedBatch {
    pub shape: Shape,
    /// The kind every request in the batch shares (for re-threshold,
    /// the first request's thresholds — only the discriminant is a
    /// batching key).
    pub kind: RequestKind,
    pub requests: Vec<Request>,
    /// Virtual time the batch was closed.
    pub formed_ns: u64,
}

impl FormedBatch {
    pub fn len(&self) -> usize {
        self.requests.len()
    }

    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }

    /// Total pixels across the batch (the service-cost driver).
    pub fn pixels(&self) -> usize {
        self.requests.len() * self.shape.pixels()
    }
}

#[derive(Clone, Debug)]
struct Group {
    requests: Vec<Request>,
    /// Close-by time: first admission into the group + window.
    deadline_ns: u64,
}

/// Coalescing key: geometry plus the request-kind discriminant
/// ([`RequestKind::tag`]) — a re-threshold must never share a dispatch
/// with a full detection, whose service cost it doesn't pay.
type BatchKey = (Shape, u8);

/// Coalesces admitted requests into [`FormedBatch`]es, keyed by
/// (shape, kind). All state is ordinary maps in virtual time —
/// determinism falls out of `BTreeMap`'s sorted iteration.
#[derive(Clone, Debug)]
pub struct Batcher {
    window_ns: u64,
    max_batch: usize,
    groups: BTreeMap<BatchKey, Group>,
    pub batches_formed: u64,
    pub requests_batched: u64,
}

impl Batcher {
    pub fn new(window_ns: u64, max_batch: usize) -> Batcher {
        Batcher {
            window_ns,
            max_batch: max_batch.max(1),
            groups: BTreeMap::new(),
            batches_formed: 0,
            requests_batched: 0,
        }
    }

    fn close(&mut self, key: BatchKey, group: Group, now_ns: u64) -> FormedBatch {
        self.batches_formed += 1;
        self.requests_batched += group.requests.len() as u64;
        let kind = group.requests.first().map(|r| r.kind).unwrap_or(RequestKind::Full);
        FormedBatch { shape: key.0, kind, requests: group.requests, formed_ns: now_ns }
    }

    /// Add an admitted request at virtual time `now_ns`; returns the
    /// closed batch if this push filled one to `max_batch`.
    pub fn push(&mut self, req: Request, now_ns: u64) -> Option<FormedBatch> {
        let key = (req.shape(), req.kind.tag());
        let deadline_ns = now_ns.saturating_add(self.window_ns);
        let group = self
            .groups
            .entry(key)
            .or_insert_with(|| Group { requests: Vec::new(), deadline_ns });
        group.requests.push(req);
        if group.requests.len() >= self.max_batch {
            let group = self.groups.remove(&key).expect("group just inserted");
            return Some(self.close(key, group, now_ns));
        }
        None
    }

    /// Earliest open-group deadline, if any (the event loop's timer).
    pub fn next_deadline(&self) -> Option<u64> {
        self.groups.values().map(|g| g.deadline_ns).min()
    }

    /// Close every group whose window has expired at `now_ns`, in
    /// (shape, kind) order (deterministic).
    pub fn expire(&mut self, now_ns: u64) -> Vec<FormedBatch> {
        let due: Vec<BatchKey> =
            self.groups.iter().filter(|(_, g)| g.deadline_ns <= now_ns).map(|(&k, _)| k).collect();
        due.into_iter()
            .map(|key| {
                let group = self.groups.remove(&key).expect("due group exists");
                self.close(key, group, now_ns)
            })
            .collect()
    }

    /// Close everything regardless of deadline (drain at shutdown).
    pub fn flush(&mut self, now_ns: u64) -> Vec<FormedBatch> {
        let keys: Vec<BatchKey> = self.groups.keys().copied().collect();
        keys.into_iter()
            .map(|key| {
                let group = self.groups.remove(&key).expect("group exists");
                self.close(key, group, now_ns)
            })
            .collect()
    }

    /// Requests currently coalescing (admitted, not yet in a closed batch).
    pub fn pending(&self) -> usize {
        self.groups.values().map(|g| g.requests.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::synth::Scene;

    fn req(id: u64, w: usize, h: usize) -> Request {
        Request {
            id,
            arrival_ns: 0,
            scene: Scene::Gradient,
            width: w,
            height: h,
            kind: RequestKind::Full,
        }
    }

    #[test]
    fn fills_close_at_max_batch() {
        let mut b = Batcher::new(1_000_000, 3);
        assert!(b.push(req(0, 64, 64), 0).is_none());
        assert!(b.push(req(1, 64, 64), 10).is_none());
        let batch = b.push(req(2, 64, 64), 20).expect("third push fills the batch");
        assert_eq!(batch.len(), 3);
        assert_eq!(batch.formed_ns, 20);
        assert_eq!(b.pending(), 0);
        assert_eq!(b.batches_formed, 1);
        assert_eq!(b.requests_batched, 3);
    }

    #[test]
    fn shapes_do_not_mix() {
        let mut b = Batcher::new(1_000_000, 2);
        assert!(b.push(req(0, 64, 64), 0).is_none());
        assert!(b.push(req(1, 32, 32), 0).is_none());
        assert_eq!(b.pending(), 2);
        let batch = b.push(req(2, 64, 64), 5).unwrap();
        assert_eq!(batch.shape, Shape { width: 64, height: 64 });
        assert_eq!(b.pending(), 1);
    }

    #[test]
    fn kinds_do_not_mix_even_at_one_shape() {
        let mut b = Batcher::new(1_000_000, 2);
        let mut re = req(0, 64, 64);
        re.kind = RequestKind::ReThreshold { lo: 0.02, hi: 0.2 };
        assert!(b.push(re, 0).is_none());
        // Same shape, different kind: opens a second group.
        assert!(b.push(req(1, 64, 64), 0).is_none());
        assert_eq!(b.pending(), 2);
        let mut re2 = req(2, 64, 64);
        re2.kind = RequestKind::ReThreshold { lo: 0.05, hi: 0.3 };
        let batch = b.push(re2, 5).expect("second re-threshold fills that group");
        assert_eq!(batch.kind.tag(), re.kind.tag());
        assert_eq!(batch.len(), 2);
        // Differing thresholds may share a batch — only the
        // discriminant keys the group.
        assert_eq!(b.pending(), 1, "the full-kind request still coalescing");
        let rest = b.flush(10);
        assert_eq!(rest.len(), 1);
        assert_eq!(rest[0].kind, RequestKind::Full);
    }

    #[test]
    fn window_expiry_closes_partial_batches() {
        let mut b = Batcher::new(100, 8);
        b.push(req(0, 64, 64), 0);
        b.push(req(1, 32, 32), 40);
        assert_eq!(b.next_deadline(), Some(100));
        assert!(b.expire(99).is_empty());
        let closed = b.expire(100);
        assert_eq!(closed.len(), 1);
        assert_eq!(closed[0].shape, Shape { width: 64, height: 64 });
        assert_eq!(b.next_deadline(), Some(140));
        let rest = b.expire(140);
        assert_eq!(rest.len(), 1);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn mid_window_join_keeps_the_oldest_requests_deadline() {
        // The max-delay guarantee is anchored to the request that
        // opened the group: a join mid-window must NOT extend the
        // deadline, and the closed batch carries both requests.
        let mut b = Batcher::new(100, 8);
        b.push(req(0, 64, 64), 0);
        assert_eq!(b.next_deadline(), Some(100));
        assert!(b.push(req(1, 64, 64), 60).is_none(), "join below max fill stays open");
        assert_eq!(b.next_deadline(), Some(100), "deadline anchored to the opener");
        assert!(b.expire(99).is_empty());
        let closed = b.expire(100);
        assert_eq!(closed.len(), 1);
        assert_eq!(closed[0].len(), 2);
        assert_eq!(closed[0].formed_ns, 100);
        // A post-close arrival opens a fresh group with a fresh window.
        b.push(req(2, 64, 64), 130);
        assert_eq!(b.next_deadline(), Some(230));
        assert_eq!(b.requests_batched, 2);
    }

    #[test]
    fn zero_window_means_immediate_expiry() {
        let mut b = Batcher::new(0, 8);
        b.push(req(0, 64, 64), 7);
        assert_eq!(b.next_deadline(), Some(7));
        assert_eq!(b.expire(7).len(), 1);
    }

    #[test]
    fn flush_drains_every_group() {
        let mut b = Batcher::new(1_000_000, 8);
        b.push(req(0, 64, 64), 0);
        b.push(req(1, 32, 32), 0);
        let all = b.flush(50);
        assert_eq!(all.len(), 2);
        assert_eq!(b.pending(), 0);
        assert_eq!(b.next_deadline(), None);
        // Shape order: 32x32 before 64x64 (BTreeMap).
        assert_eq!(all[0].shape, Shape { width: 32, height: 32 });
    }
}
