//! Bounded admission control for the serving tier.
//!
//! The queue is the *waiting room bound* between arrival and dispatch:
//! admitted-but-undispatched requests (whether still coalescing in the
//! batcher or closed and waiting for a lane) may never exceed `depth`.
//! When the room is full the request is rejected immediately with a
//! reason — load sheds at the door instead of growing an unbounded
//! backlog (the serving tier's backpressure contract).

/// Why a request was turned away at the door.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RejectReason {
    /// The waiting room is at capacity (backpressure).
    QueueFull { depth: usize },
    /// The request exceeds the per-request pixel budget.
    Oversize { pixels: usize, max_pixels: usize },
    /// The overload policy shed the arrival while the rolling SLO was
    /// missed ([`crate::obs::OverloadPolicy::RejectNew`]).
    Shed,
}

impl RejectReason {
    pub fn name(&self) -> &'static str {
        match self {
            RejectReason::QueueFull { .. } => "queue-full",
            RejectReason::Oversize { .. } => "oversize",
            RejectReason::Shed => "shed",
        }
    }
}

impl std::fmt::Display for RejectReason {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RejectReason::QueueFull { depth } => write!(f, "queue full (depth {depth})"),
            RejectReason::Oversize { pixels, max_pixels } => {
                write!(f, "request too large ({pixels} px > {max_pixels} px budget)")
            }
            RejectReason::Shed => write!(f, "shed by the overload policy (rolling SLO missed)"),
        }
    }
}

/// Occupancy accounting for the bounded waiting room. The batcher owns
/// the actual request objects; the queue owns the *bound* and the
/// admission counters the report is built from.
#[derive(Clone, Debug)]
pub struct AdmissionQueue {
    depth: usize,
    max_pixels: usize,
    occupancy: usize,
    /// Highest occupancy ever reached (report: queue high-water mark).
    pub high_water: usize,
    pub admitted: u64,
    pub rejected_full: u64,
    pub rejected_oversize: u64,
    /// Arrivals shed by the overload policy before reaching the room.
    pub rejected_shed: u64,
}

impl AdmissionQueue {
    pub fn new(depth: usize) -> AdmissionQueue {
        AdmissionQueue {
            depth: depth.max(1),
            max_pixels: usize::MAX,
            occupancy: 0,
            high_water: 0,
            admitted: 0,
            rejected_full: 0,
            rejected_oversize: 0,
            rejected_shed: 0,
        }
    }

    /// Cap the per-request pixel count (admission control beyond the
    /// depth bound; default unlimited).
    pub fn with_max_pixels(mut self, max_pixels: usize) -> Self {
        self.max_pixels = max_pixels.max(1);
        self
    }

    /// Admit one request of `pixels` size, or say why not.
    pub fn try_admit(&mut self, pixels: usize) -> std::result::Result<(), RejectReason> {
        if pixels > self.max_pixels {
            self.rejected_oversize += 1;
            return Err(RejectReason::Oversize { pixels, max_pixels: self.max_pixels });
        }
        if self.occupancy >= self.depth {
            self.rejected_full += 1;
            return Err(RejectReason::QueueFull { depth: self.depth });
        }
        self.occupancy += 1;
        self.high_water = self.high_water.max(self.occupancy);
        self.admitted += 1;
        Ok(())
    }

    /// Count one arrival shed by the overload policy. Sheds happen
    /// *before* the room (the request never occupies a slot) but are
    /// part of the queue's conservation arithmetic:
    /// `offered == admitted + rejected()`.
    pub fn reject_shed(&mut self) -> RejectReason {
        self.rejected_shed += 1;
        RejectReason::Shed
    }

    /// `n` requests left the waiting room (dispatched to a lane).
    pub fn release(&mut self, n: usize) {
        self.occupancy = self.occupancy.saturating_sub(n);
    }

    pub fn occupancy(&self) -> usize {
        self.occupancy
    }

    pub fn depth(&self) -> usize {
        self.depth
    }

    /// Total rejections, all reasons.
    pub fn rejected(&self) -> u64 {
        self.rejected_full + self.rejected_oversize + self.rejected_shed
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admits_up_to_depth_then_rejects() {
        let mut q = AdmissionQueue::new(3);
        for _ in 0..3 {
            assert!(q.try_admit(100).is_ok());
        }
        assert_eq!(q.try_admit(100), Err(RejectReason::QueueFull { depth: 3 }));
        assert_eq!(q.admitted, 3);
        assert_eq!(q.rejected_full, 1);
        assert_eq!(q.high_water, 3);
    }

    #[test]
    fn release_reopens_the_door() {
        let mut q = AdmissionQueue::new(2);
        assert!(q.try_admit(1).is_ok());
        assert!(q.try_admit(1).is_ok());
        assert!(q.try_admit(1).is_err());
        q.release(2);
        assert_eq!(q.occupancy(), 0);
        assert!(q.try_admit(1).is_ok());
        // High water remembers the peak, not the present.
        assert_eq!(q.high_water, 2);
    }

    #[test]
    fn oversize_is_rejected_regardless_of_room() {
        let mut q = AdmissionQueue::new(8).with_max_pixels(1000);
        assert!(q.try_admit(1000).is_ok());
        let r = q.try_admit(1001);
        assert_eq!(r, Err(RejectReason::Oversize { pixels: 1001, max_pixels: 1000 }));
        assert_eq!(q.rejected(), 1);
        assert_eq!(q.occupancy(), 1);
    }

    #[test]
    fn reasons_render() {
        assert_eq!(RejectReason::QueueFull { depth: 4 }.name(), "queue-full");
        assert!(RejectReason::QueueFull { depth: 4 }.to_string().contains("4"));
        assert_eq!(RejectReason::Shed.name(), "shed");
        assert!(RejectReason::Shed.to_string().contains("overload"));
    }

    #[test]
    fn sheds_count_without_occupying_the_room() {
        let mut q = AdmissionQueue::new(2);
        assert!(q.try_admit(1).is_ok());
        assert_eq!(q.reject_shed(), RejectReason::Shed);
        assert_eq!(q.reject_shed(), RejectReason::Shed);
        assert_eq!(q.occupancy(), 1, "shed arrivals never enter the room");
        assert_eq!(q.rejected_shed, 2);
        assert_eq!(q.rejected(), 2);
        // Conservation at the queue: offered = admitted + rejected.
        assert_eq!(q.admitted + q.rejected(), 3);
    }
}
