//! The L3 **serving tier** — the multi-client front door above the
//! per-image detector. The unit of work here is a *request stream*,
//! not an image: long-lived serving is what the ROADMAP's "heavy
//! traffic" north star needs, and what every later scaling PR
//! (sharding, caching, async backends) plugs into.
//!
//! Request path:
//!
//! ```text
//! arrivals ──> AdmissionQueue ──> Batcher ──> lane 0 (Detector) ──┐
//!  (open-loop) (bounded; rejects  (same-shape └> lane 1 (Detector) ├─> SLO report
//!               with a reason      coalescing,  …                  │   (p50/p95/p99,
//!               when full)        max-delay     lane N-1 ──────────┘    per lane)
//!                                 window)
//! ```
//!
//! * [`queue::AdmissionQueue`] — bounded waiting room with
//!   backpressure: a full room rejects immediately with a
//!   [`queue::RejectReason`] instead of growing an unbounded backlog.
//! * [`batcher::Batcher`] — coalesces same-shape requests into one
//!   dispatch under a configurable max-delay window, amortizing
//!   per-dispatch overhead without unbounded latency cost.
//! * [`server::serve`] — N sharded worker lanes, each owning a
//!   [`crate::coordinator::Detector`] (engine/workers chosen by the
//!   GCP [`crate::coordinator::Planner`]), driven by a virtual-time
//!   event loop so replays are deterministic.
//! * [`slo`] — per-request latency tracking (enqueue→dispatch→
//!   complete) rolled into p50/p95/p99 summaries per lane and in
//!   aggregate, emitted as a deterministic JSON report.
//!
//! Entry points: `cannyd serve --synthetic 200 --lanes 2` (or
//! `--requests trace.json`), or programmatically:
//!
//! ```no_run
//! use canny_par::config::RunConfig;
//! use canny_par::service::{serve, ServeOptions, Trace};
//!
//! let cfg = RunConfig::default();
//! let trace = Trace::synthetic(200, cfg.seed, cfg.arrival_rate_hz);
//! let report = serve("demo", &trace, &ServeOptions::from_config(&cfg)).unwrap();
//! println!("{}", report.to_json_string());
//! ```

pub mod batcher;
pub mod queue;
pub mod request;
pub mod server;
pub mod slo;

pub use batcher::{Batcher, FormedBatch};
pub use queue::{AdmissionQueue, RejectReason};
pub use request::{Request, Shape, Trace};
pub use server::{serve, ServeOptions};
pub use slo::{LaneReport, LatencyStats, LatencySummary, ServeReport};
