//! The L3 **serving tier** — the multi-client front door above the
//! per-image detector. The unit of work here is a *request stream*,
//! not an image: long-lived serving is what the ROADMAP's "heavy
//! traffic" north star needs, and what every later scaling PR
//! (sharding, caching, async backends) plugs into.
//!
//! Request path:
//!
//! ```text
//! arrivals ──> AdmissionQueue ──> Batcher ──> lane 0 (Detector) ──┐
//!  (open-loop) (bounded; rejects  (same-shape └> lane 1 (Detector) ├─> SLO report
//!               with a reason      coalescing,  …                  │   (p50/p95/p99,
//!               when full)        max-delay     lane N-1 ──────────┘    per lane)
//!                                 window)
//! ```
//!
//! * [`queue::AdmissionQueue`] — bounded waiting room with
//!   backpressure: a full room rejects immediately with a
//!   [`queue::RejectReason`] instead of growing an unbounded backlog.
//! * [`batcher::Batcher`] — coalesces same-shape requests into one
//!   dispatch under a configurable max-delay window, amortizing
//!   per-dispatch overhead without unbounded latency cost.
//! * [`server::serve`] — N sharded worker lanes, each owning a
//!   [`crate::coordinator::Detector`] (engine/workers chosen by the
//!   GCP [`crate::coordinator::Planner`]), driven by the clock selected
//!   in [`server::ServeOptions`].
//! * [`slo`] — per-request latency tracking (enqueue→dispatch→
//!   complete) rolled into p50/p95/p99 summaries per lane and in
//!   aggregate, emitted as a deterministic JSON report with a
//!   three-state `slo.status` (`met`/`missed`/`no-data`), plus a
//!   **rolling** SLO window ([`slo::SloWindow`], `--slo-window N`)
//!   evaluating the same target over the most recent N completions,
//!   with a met/missed/no-data transition timeline in the report's
//!   `slo.window` section.
//!
//! ## The ops plane ([`crate::obs`])
//!
//! Serving runs publish live telemetry: every lane feeds a
//! [`crate::obs::Telemetry`] registry (queue depths, per-lane
//! in-flight/completed, latency histograms, per-stage tallies, shed
//! counters), which `--telemetry-log file.jsonl
//! --telemetry-interval-ms N` turns into a periodic JSONL snapshot
//! stream — emitted at modeled tick times under the virtual clock
//! (byte-identical across replays) and by a real sampler thread with a
//! per-core `utilization` section under wall. While the rolling SLO is
//! missed, `--overload-policy` decides the fate of new arrivals:
//! `none` (observe only — the default, byte-identical to pre-ops-plane
//! runs), `reject-new` (shed at the door, counted as `rejected_shed`),
//! or `degrade-to-front-only` (rewrite `full` requests to the cheap
//! cache-warming front). Every shed decision is visible both live and
//! in the final report's `overload` section.
//!
//! ## Two clocks
//!
//! The event loop runs under either clock ([`clock::ClockMode`]):
//!
//! * **virtual** (default) — deterministic modeled-time replay: lane
//!   occupancy advances by the service-cost model, and the same trace +
//!   seed produces a byte-identical report regardless of host load.
//! * **wall** (`cannyd serve --clock wall`) — the same admission →
//!   batch → lane pipeline against real worker threads draining a
//!   shared dispatch channel, with arrivals paced to their trace
//!   offsets on a monotonic clock. Latencies are measured, and the
//!   report carries `clock: "wall"` with an otherwise identical schema.
//!
//! ## Request kinds (partial pipelines over the wire)
//!
//! Every [`request::Request`] carries a [`request::RequestKind`] — the
//! stage-graph API ([`crate::canny::StagePlan`]) surfaced at the
//! serving boundary:
//!
//! * `full` (default) — the whole pipeline, edge totals in the report;
//! * `front-only` — Gaussian→Sobel→NMS only; warms the **shared
//!   artifact cache** ([`crate::cache::ArtifactCache`]) with the
//!   suppressed-magnitude map under a content-addressed key;
//! * `re-threshold` — re-run Threshold + Hysteresis with new `lo`/`hi`
//!   against the cached suppressed map: a hit skips
//!   Gaussian/Sobel/NMS entirely (the report's `stages` section counts
//!   executed phases, and the `cache` section the shared tier).
//!
//! The cache is one process-wide, sharded, byte-budgeted tier shared by
//! **all** lanes (and by stream executors handed the same handle via
//! [`server::ServeOptions::shared_cache`]): sized by `--cache-mb`
//! (0 disables), sharded by `--cache-shards`, with cost-aware admission
//! under `--cache-admit-ns-per-byte`. Keys digest the image bytes, so a
//! warm-up on one lane serves every lane, and identical content
//! deduplicates across clients and tiers.
//!
//! Batches never mix kinds (their stage sets, and so their service
//! costs, differ), and the virtual clock charges each kind only its
//! stage set — per-stage calibration fits when installed, synthetic
//! fractions of the full cost otherwise — plus a modeled cache-lookup
//! cost for the kinds that hash content and probe the tier.
//!
//! ### Cache report section (`"cache"`, same schema in stream reports)
//!
//! ```json
//! {
//!   "enabled": true, "budget_bytes": 67108864, "shards": 8,
//!   "admit_min_ns_per_byte": 0,
//!   "bytes": 1048576, "entries": 4, "high_water_bytes": 1310720,
//!   "evictions": 1, "lookups": 12, "hits": 9, "misses": 3,
//!   "inserts": 4, "admission_rejects": 0, "too_large": 0,
//!   "negative_hits": 0, "negative_entries": 0,
//!   "tiers": {
//!     "serve":  {"lookups": 12, "hits": 9, "hit_rate": 0.75, "misses": 3,
//!                "inserts": 4, "admission_rejects": 0, "too_large": 0},
//!     "stream": {"lookups": 0, "hits": 0, "hit_rate": 0, "misses": 0,
//!                "inserts": 0, "admission_rejects": 0, "too_large": 0}
//!   }
//! }
//! ```
//!
//! Top-level counters aggregate the per-tier ones; `hits + misses ==
//! lookups` always, and `bytes <= budget_bytes` is enforced by
//! per-shard LRU eviction. `admission_rejects` counts offers that
//! failed the cost-per-byte bar; `too_large` counts artifacts bigger
//! than a shard's slice of the budget (`budget_bytes / shards`), which
//! no eviction could ever make room for. Rejected digests are
//! remembered in a bounded negative set: `negative_hits` counts repeat
//! offers refused straight from it (the original reject counter is
//! replayed too, so totals stay comparable), `negative_entries` is its
//! current size.
//!
//! ### Serve report schema (what `cannyd serve` prints)
//!
//! One JSON object per run ([`slo::ServeReport::to_json`]); keys are
//! sorted, so virtual-clock reports diff cleanly. Abridged example —
//! `latency_ns` sections share the `queue_wait_ns` summary shape, the
//! `cache` section is documented above, and `kinds` / `stages` carry
//! one counter per request kind / executed stage:
//!
//! ```json
//! {
//!   "label": "serve", "seed": 42, "clock": "virtual",
//!   "engine": "patterns", "workers_per_lane": 2, "interrupted": false,
//!   "offered": 200, "admitted": 198, "rejected": 2, "completed": 198,
//!   "makespan_ns": 812345678, "throughput_rps": 243.7,
//!   "edge_pixels": 1048576,
//!   "calibration": {"source": "synthetic", "overhead_ns": 120000,
//!                   "cost_ns_per_pixel": 3.72, "engine": "patterns",
//!                   "workers": 4, "probes": 9, "stages": 6},
//!   "queue": {"depth": 64, "high_water": 17, "rejected_full": 2,
//!             "rejected_oversize": 0, "rejected_shed": 0},
//!   "overload": {"policy": "none", "shed_degraded": 0,
//!                "shed_rejected": 0},
//!   "batch": {"window_ns": 2000000, "max": 8, "formed": 51,
//!             "requests": 198, "mean_fill": 3.88},
//!   "kinds": {"full": 180}, "stages": {"gaussian": 192},
//!   "cache": {"enabled": true},
//!   "latency_ns": {"n": 198, "p50": 3100000, "p95": 5200000,
//!                  "p99": 6900000, "max": 7400000, "mean": 3400000.5},
//!   "queue_wait_ns": {"n": 198},
//!   "lanes": [{"lane": 0, "requests": 99, "batches": 26,
//!              "busy_ns": 700000000, "utilization": 0.86,
//!              "latency_ns": {"n": 99}}],
//!   "slo": {
//!     "target_p99_ns": 8000000, "p99_ns": 6900000, "status": "met",
//!     "window": {"window": 64, "target_p99_ns": 8000000, "n": 64,
//!                "p50_ns": 3100000, "p95_ns": 5200000,
//!                "p99_ns": 6900000, "status": "met",
//!                "transitions": [{"status": "met", "t_ns": 12000000}],
//!                "transitions_truncated": false}
//!   }
//! }
//! ```
//!
//! ### Request JSON schema (`cannyd serve --requests trace.json`)
//!
//! ```json
//! {"requests": [
//!   {"arrival_us": 0,   "width": 128, "height": 128, "scene": "shapes:3"},
//!   {"arrival_us": 120, "width": 128, "height": 128, "scene": "shapes:3",
//!    "kind": "front-only"},
//!   {"arrival_us": 250, "width": 128, "height": 128, "scene": "shapes:3",
//!    "kind": "re-threshold", "lo": 0.03, "hi": 0.2}
//! ]}
//! ```
//!
//! `kind` defaults to `"full"`; `re-threshold` requires finite
//! `0 <= lo <= hi`; `id` defaults to the array index and `scene` to
//! `shapes:<id>`.
//!
//! ## Calibration
//!
//! [`calibrate::Calibration`] closes the loop between the two: it
//! measures per-stage [`crate::canny::StageRecord`]s on a probe grid of
//! shapes (min-of-repeats), least-squares fits
//! `service_ns = overhead_ns + cost_ns_per_pixel * pixels` — end-to-end
//! *and* per stage ([`calibrate::StageCost`]) — and replaces the
//! synthetic virtual-time constants, so virtual p50/p95/p99 predictions
//! track wall-clock reality and partial-pipeline kinds are charged only
//! the stages they run. Probe at startup with
//! `cannyd serve --calibration probe`, or persist a probe with
//! `cannyd calibrate --output calib.json` and replay it
//! deterministically via `cannyd serve --calibration calib.json`.
//!
//! ### Calibration JSON schema
//!
//! ```json
//! {
//!   "format": 1,
//!   "engine": "patterns",          // provenance (optional)
//!   "workers": 4,                  // provenance (optional)
//!   "overhead_ns": 120000,         // required, finite, >= 0
//!   "cost_ns_per_pixel": 3.72,     // required, finite, >= 0
//!   "stages": [                    // optional per-stage fits
//!     {"stage": "gaussian", "overhead_ns": 20000, "cost_ns_per_pixel": 1.1}
//!   ],
//!   "probes": [                    // optional provenance
//!     {"width": 96, "height": 96, "ns": 812345}
//!   ]
//! }
//! ```
//!
//! ## Graceful shutdown
//!
//! A wall-clock `cannyd serve` installs a SIGINT handler
//! ([`server::install_sigint_drain`]): on Ctrl-C the arrival replay
//! stops, admitted requests drain to completion, and the partial
//! report is printed with `"interrupted": true`.
//!
//! Entry points: `cannyd serve --synthetic 200 --lanes 2` (or
//! `--requests trace.json`, `--clock wall`, `--calibration …`), or
//! programmatically:
//!
//! ```no_run
//! use canny_par::config::RunConfig;
//! use canny_par::service::{serve, ServeOptions, Trace};
//!
//! let cfg = RunConfig::default();
//! let trace = Trace::synthetic(200, cfg.seed, cfg.arrival_rate_hz);
//! let report = serve("demo", &trace, &ServeOptions::from_config(&cfg)).unwrap();
//! println!("{}", report.to_json_string());
//! ```

pub mod batcher;
pub mod calibrate;
pub mod clock;
pub mod queue;
pub mod request;
pub mod server;
pub mod slo;

pub use batcher::{Batcher, FormedBatch};
pub use calibrate::{Calibration, ProbePoint, StageCost};
pub use clock::{ClockMode, WallClock};
pub use queue::{AdmissionQueue, RejectReason};
pub use request::{Request, RequestKind, Shape, Trace};
pub use server::{calibrate_for, install_sigint_drain, kind_stage_names, serve, ServeOptions};
pub use slo::{
    CostModel, LaneReport, LatencyStats, LatencySummary, ServeReport, SloStatus, SloWindow,
    WindowReport, DEFAULT_SLO_WINDOW,
};
