//! Request-stream vocabulary for the serving tier: a [`Request`] is one
//! client detection call (what image, when it arrived), a [`Trace`] is a
//! whole replayable client workload — either synthesized (deterministic
//! open-loop arrivals from [`crate::util::Prng`]) or loaded from a JSON
//! trace file recorded by a client.

use std::path::Path;

use crate::error::{Error, Result};
use crate::image::synth::Scene;
use crate::util::json::Json;
use crate::util::Prng;

/// Image geometry — the batching key: only same-shape requests can be
/// coalesced into one lane dispatch.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Shape {
    pub width: usize,
    pub height: usize,
}

impl Shape {
    pub fn pixels(&self) -> usize {
        self.width * self.height
    }
}

/// What a request asks the pipeline to run — a [`crate::canny::StagePlan`]
/// selector at the serving-tier boundary.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum RequestKind {
    /// The whole pipeline: image in, edge count out.
    Full,
    /// Run the front only (stop after NMS) and warm the lane's
    /// suppressed-magnitude cache; no edges are produced.
    FrontOnly,
    /// Re-threshold the scene's cached suppressed-magnitude map with
    /// new thresholds — hits the per-lane LRU and skips
    /// Gaussian/Sobel/NMS entirely on a hit.
    ReThreshold { lo: f32, hi: f32 },
}

impl RequestKind {
    /// Report / JSON name.
    pub fn name(&self) -> &'static str {
        match self {
            RequestKind::Full => "full",
            RequestKind::FrontOnly => "front-only",
            RequestKind::ReThreshold { .. } => "re-threshold",
        }
    }

    /// Batching-key discriminant: requests coalesce only within a kind
    /// (their stage sets — and so their service costs — differ).
    pub fn tag(&self) -> u8 {
        match self {
            RequestKind::Full => 0,
            RequestKind::FrontOnly => 1,
            RequestKind::ReThreshold { .. } => 2,
        }
    }

    /// Does this kind touch the shared [`crate::cache::ArtifactCache`]
    /// (warm it, or consult it)? Drives both the real execution path
    /// and the virtual clock's modeled lookup charge.
    pub fn uses_artifact_cache(&self) -> bool {
        !matches!(self, RequestKind::Full)
    }
}

/// One client request, timestamped in virtual nanoseconds since serve
/// start. Arrivals are open-loop: clients do not wait for completions,
/// which is what makes the admission queue's backpressure meaningful.
#[derive(Clone, Copy, Debug)]
pub struct Request {
    pub id: u64,
    /// Virtual arrival time (ns since serve start).
    pub arrival_ns: u64,
    /// What to detect edges on (generated at dispatch — traces stay
    /// tiny and runs stay deterministic).
    pub scene: Scene,
    pub width: usize,
    pub height: usize,
    /// Which pipeline span to run (see [`RequestKind`]).
    pub kind: RequestKind,
}

impl Request {
    pub fn shape(&self) -> Shape {
        Shape { width: self.width, height: self.height }
    }

    pub fn pixels(&self) -> usize {
        self.width * self.height
    }
}

/// Synthetic workloads draw sizes from this palette — a handful of
/// repeated shapes so the batcher has same-shape runs to coalesce.
pub const SIZE_PALETTE: &[(usize, usize)] = &[(96, 96), (128, 128), (128, 96), (192, 192)];

/// Largest per-dimension size a JSON trace may request (64k: keeps
/// `width * height` and the per-pixel cost model far from overflow).
pub const MAX_DIM: usize = 1 << 16;

/// Largest arrival timestamp a JSON trace may carry (µs; ~11.5 virtual
/// days — keeps `arrival_ns + service_ns` far from u64::MAX).
pub const MAX_ARRIVAL_US: f64 = 1e15;

/// A replayable request stream, sorted by arrival time.
#[derive(Clone, Debug, Default)]
pub struct Trace {
    pub requests: Vec<Request>,
}

impl Trace {
    /// Deterministic open-loop synthetic workload: Poisson arrivals at
    /// `rate_hz` (exponential inter-arrival gaps), sizes from
    /// [`SIZE_PALETTE`], scene content varying per request. Same
    /// `(n, seed, rate_hz)` ⇒ identical trace.
    pub fn synthetic(n: usize, seed: u64, rate_hz: f64) -> Trace {
        let rate = if rate_hz.is_finite() && rate_hz > 0.0 { rate_hz } else { 1000.0 };
        let mut rng = Prng::new(seed ^ 0x5e44_7e5e_ed00_0001);
        let mut t = 0u64;
        let mut requests = Vec::with_capacity(n);
        for k in 0..n {
            // Exponential gap: u in [0,1) so 1-u in (0,1] and ln() <= 0.
            let u = rng.next_f64();
            let dt = (-(1.0 - u).ln() / rate * 1e9).round() as u64;
            t += dt.max(1);
            let (width, height) = SIZE_PALETTE[rng.next_below(SIZE_PALETTE.len())];
            requests.push(Request {
                id: k as u64,
                arrival_ns: t,
                scene: Scene::Shapes { seed: seed.wrapping_add(k as u64) },
                width,
                height,
                kind: RequestKind::Full,
            });
        }
        Trace { requests }
    }

    /// Load a client trace from JSON text:
    ///
    /// ```json
    /// {"requests": [
    ///   {"arrival_us": 0,   "width": 128, "height": 128, "scene": "shapes:3"},
    ///   {"arrival_us": 120, "width": 128, "height": 128, "scene": "shapes:3",
    ///    "kind": "front-only"},
    ///   {"arrival_us": 250, "width": 128, "height": 128, "scene": "shapes:3",
    ///    "kind": "re-threshold", "lo": 0.03, "hi": 0.2}
    /// ]}
    /// ```
    ///
    /// `id` defaults to the array index, `scene` to `shapes:<id>`,
    /// `kind` to `full`. A `re-threshold` request must carry finite
    /// thresholds with `0 <= lo <= hi`. Requests are sorted by
    /// `(arrival, id)` after parsing.
    pub fn from_json(text: &str) -> Result<Trace> {
        let j = Json::parse(text)?;
        let reqs = j
            .get("requests")
            .and_then(Json::as_arr)
            .ok_or_else(|| Error::Config("trace: missing `requests` array".into()))?;
        let mut requests = Vec::with_capacity(reqs.len());
        for (k, r) in reqs.iter().enumerate() {
            let field = |name: &str| -> Result<f64> {
                r.get(name).and_then(Json::as_f64).ok_or_else(|| {
                    Error::Config(format!("trace request {k}: missing/invalid `{name}`"))
                })
            };
            // Bounds: this is the untrusted-input boundary
            // (`cannyd serve --requests file.json`) — reject geometry
            // and timestamps that would overflow downstream arithmetic
            // instead of wrapping/saturating into nonsense.
            let dim = |name: &str| -> Result<usize> {
                let v = field(name)?;
                if !(v >= 1.0 && v <= MAX_DIM as f64 && v.fract() == 0.0) {
                    return Err(Error::Config(format!(
                        "trace request {k}: `{name}` must be an integer in 1..={MAX_DIM}, got {v}"
                    )));
                }
                Ok(v as usize)
            };
            let width = dim("width")?;
            let height = dim("height")?;
            let arrival_us = field("arrival_us")?;
            if !(arrival_us >= 0.0 && arrival_us <= MAX_ARRIVAL_US) {
                return Err(Error::Config(format!(
                    "trace request {k}: `arrival_us` must be in 0..={MAX_ARRIVAL_US}"
                )));
            }
            let id = r.get("id").and_then(Json::as_f64).map(|v| v as u64).unwrap_or(k as u64);
            let scene = match r.get("scene").and_then(Json::as_str) {
                Some(s) => Scene::parse(s).ok_or_else(|| {
                    Error::Config(format!("trace request {k}: unknown scene `{s}`"))
                })?,
                None => Scene::Shapes { seed: id },
            };
            let kind = match r.get("kind").and_then(Json::as_str) {
                None | Some("full") => RequestKind::Full,
                Some("front-only") => RequestKind::FrontOnly,
                Some("re-threshold") => {
                    let lo = field("lo")? as f32;
                    let hi = field("hi")? as f32;
                    if !(lo.is_finite() && hi.is_finite() && lo >= 0.0 && lo <= hi) {
                        return Err(Error::Config(format!(
                            "trace request {k}: re-threshold needs 0 <= lo <= hi, \
                             got lo={lo} hi={hi}"
                        )));
                    }
                    RequestKind::ReThreshold { lo, hi }
                }
                Some(other) => {
                    return Err(Error::Config(format!(
                        "trace request {k}: unknown kind `{other}` \
                         (full | front-only | re-threshold)"
                    )))
                }
            };
            requests.push(Request {
                id,
                arrival_ns: (arrival_us * 1e3) as u64,
                scene,
                width,
                height,
                kind,
            });
        }
        requests.sort_by_key(|r| (r.arrival_ns, r.id));
        Ok(Trace { requests })
    }

    /// [`Trace::from_json`] over a file.
    pub fn from_json_file(path: &Path) -> Result<Trace> {
        Trace::from_json(&std::fs::read_to_string(path)?)
    }

    pub fn len(&self) -> usize {
        self.requests.len()
    }

    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }

    /// Every distinct shape in the trace, sorted — the calibration
    /// probe grid for this workload (probing exactly the shapes that
    /// will be served beats a generic grid).
    pub fn distinct_shapes(&self) -> Vec<Shape> {
        let set: std::collections::BTreeSet<Shape> =
            self.requests.iter().map(|r| r.shape()).collect();
        set.into_iter().collect()
    }

    /// The most frequent shape (ties → smallest) — the planner's
    /// representative workload when sizing lane detectors.
    pub fn dominant_shape(&self) -> Option<Shape> {
        let mut counts: std::collections::BTreeMap<Shape, usize> = Default::default();
        for r in &self.requests {
            *counts.entry(r.shape()).or_insert(0) += 1;
        }
        let mut best: Option<(Shape, usize)> = None;
        for (shape, n) in counts {
            // Strict `>` keeps the first (smallest) shape on ties.
            if best.is_none_or(|(_, bn)| n > bn) {
                best = Some((shape, n));
            }
        }
        best.map(|(s, _)| s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_is_deterministic_and_sorted() {
        let a = Trace::synthetic(50, 7, 2000.0);
        let b = Trace::synthetic(50, 7, 2000.0);
        assert_eq!(a.len(), 50);
        for (ra, rb) in a.requests.iter().zip(&b.requests) {
            assert_eq!(ra.arrival_ns, rb.arrival_ns);
            assert_eq!((ra.width, ra.height), (rb.width, rb.height));
        }
        assert!(a.requests.windows(2).all(|w| w[0].arrival_ns <= w[1].arrival_ns));
    }

    #[test]
    fn synthetic_seeds_diverge() {
        let a = Trace::synthetic(20, 1, 2000.0);
        let b = Trace::synthetic(20, 2, 2000.0);
        assert!(a.requests.iter().zip(&b.requests).any(|(x, y)| x.arrival_ns != y.arrival_ns));
    }

    #[test]
    fn from_json_roundtrip_fields() {
        let t = Trace::from_json(
            r#"{"requests": [
                {"arrival_us": 100, "width": 64, "height": 48, "scene": "checker:8"},
                {"arrival_us": 20,  "width": 32, "height": 32}
            ]}"#,
        )
        .unwrap();
        assert_eq!(t.len(), 2);
        // Sorted by arrival: the 20 µs request first.
        assert_eq!(t.requests[0].arrival_ns, 20_000);
        assert_eq!(t.requests[0].shape(), Shape { width: 32, height: 32 });
        assert_eq!(t.requests[1].scene, Scene::Checker { cell: 8 });
    }

    #[test]
    fn from_json_rejects_bad_traces() {
        assert!(Trace::from_json("{}").is_err());
        assert!(Trace::from_json(r#"{"requests":[{"arrival_us":0,"width":0,"height":4}]}"#)
            .is_err());
        assert!(Trace::from_json(
            r#"{"requests":[{"arrival_us":0,"width":4,"height":4,"scene":"nope"}]}"#
        )
        .is_err());
        // Overflow-bait geometry and timestamps are rejected, not wrapped.
        assert!(Trace::from_json(
            r#"{"requests":[{"arrival_us":0,"width":4294967296,"height":4294967296}]}"#
        )
        .is_err());
        assert!(Trace::from_json(r#"{"requests":[{"arrival_us":0,"width":4.5,"height":4}]}"#)
            .is_err());
        assert!(Trace::from_json(r#"{"requests":[{"arrival_us":1e300,"width":4,"height":4}]}"#)
            .is_err());
        assert!(Trace::from_json(r#"{"requests":[{"arrival_us":-1,"width":4,"height":4}]}"#)
            .is_err());
    }

    #[test]
    fn from_json_parses_request_kinds() {
        let t = Trace::from_json(
            r#"{"requests": [
                {"arrival_us": 0,  "width": 64, "height": 64, "scene": "shapes:1"},
                {"arrival_us": 10, "width": 64, "height": 64, "scene": "shapes:1",
                 "kind": "front-only"},
                {"arrival_us": 20, "width": 64, "height": 64, "scene": "shapes:1",
                 "kind": "re-threshold", "lo": 0.03, "hi": 0.2}
            ]}"#,
        )
        .unwrap();
        assert_eq!(t.requests[0].kind, RequestKind::Full);
        assert_eq!(t.requests[1].kind, RequestKind::FrontOnly);
        match t.requests[2].kind {
            RequestKind::ReThreshold { lo, hi } => {
                assert!((lo - 0.03).abs() < 1e-6 && (hi - 0.2).abs() < 1e-6);
            }
            other => panic!("expected re-threshold, got {other:?}"),
        }
        // Unknown kinds and malformed thresholds are rejected.
        assert!(Trace::from_json(
            r#"{"requests":[{"arrival_us":0,"width":4,"height":4,"kind":"nope"}]}"#
        )
        .is_err());
        assert!(Trace::from_json(
            r#"{"requests":[{"arrival_us":0,"width":4,"height":4,"kind":"re-threshold"}]}"#
        )
        .is_err());
        assert!(Trace::from_json(
            r#"{"requests":[{"arrival_us":0,"width":4,"height":4,
                "kind":"re-threshold","lo":0.5,"hi":0.1}]}"#
        )
        .is_err());
    }

    #[test]
    fn kind_names_and_tags_are_distinct() {
        let kinds =
            [RequestKind::Full, RequestKind::FrontOnly, RequestKind::ReThreshold { lo: 0.1, hi: 0.2 }];
        for (i, a) in kinds.iter().enumerate() {
            for (j, b) in kinds.iter().enumerate() {
                assert_eq!(i == j, a.tag() == b.tag());
                assert_eq!(i == j, a.name() == b.name());
            }
        }
    }

    #[test]
    fn only_partial_kinds_use_the_artifact_cache() {
        assert!(!RequestKind::Full.uses_artifact_cache());
        assert!(RequestKind::FrontOnly.uses_artifact_cache());
        assert!(RequestKind::ReThreshold { lo: 0.1, hi: 0.2 }.uses_artifact_cache());
    }

    #[test]
    fn distinct_shapes_sorted_and_deduped() {
        let mk = |w, h, t| Request {
            id: t,
            arrival_ns: t,
            scene: Scene::Gradient,
            width: w,
            height: h,
            kind: RequestKind::Full,
        };
        let t = Trace {
            requests: vec![mk(96, 96, 0), mk(64, 64, 1), mk(96, 96, 2), mk(64, 64, 3)],
        };
        assert_eq!(
            t.distinct_shapes(),
            vec![Shape { width: 64, height: 64 }, Shape { width: 96, height: 96 }]
        );
        assert!(Trace::default().distinct_shapes().is_empty());
    }

    #[test]
    fn dominant_shape_majority_and_ties() {
        let mk = |w, h, t| Request {
            id: t,
            arrival_ns: t,
            scene: Scene::Gradient,
            width: w,
            height: h,
            kind: RequestKind::Full,
        };
        let t = Trace { requests: vec![mk(64, 64, 0), mk(96, 96, 1), mk(96, 96, 2)] };
        assert_eq!(t.dominant_shape(), Some(Shape { width: 96, height: 96 }));
        // Tie -> smallest shape.
        let t2 = Trace { requests: vec![mk(96, 96, 0), mk(64, 64, 1)] };
        assert_eq!(t2.dominant_shape(), Some(Shape { width: 64, height: 64 }));
        assert_eq!(Trace::default().dominant_shape(), None);
    }
}
